"""Roofline table builder (deliverable g): reads experiments/dryrun/*.json.

For every (arch x shape x mesh) record, prints the three terms in seconds,
the dominant bottleneck, MODEL_FLOPS / HLO_FLOPS, and (for decode cells)
the implied global tokens/s at the roofline bound. --markdown emits the
EXPERIMENTS.md table.
"""

from __future__ import annotations

import argparse
import glob
import json
import os
from typing import List

DRYRUN_DIR = os.environ.get("REPRO_DRYRUN_DIR", "experiments/final")

COLS = ["arch", "shape", "mesh", "policy", "compute_s", "memory_s",
        "collective_s", "dominant", "useful_flop_frac"]


def load(dirname=DRYRUN_DIR) -> List[dict]:
    out = []
    for p in sorted(glob.glob(f"{dirname}/*.json")):
        r = json.load(open(p))
        rl = r.get("roofline", {})
        out.append({
            "arch": r["arch"], "shape": r["shape"], "mesh": r["mesh"],
            "policy": r["policy"], "kind": r["kind"],
            "compute_s": rl.get("compute_s", 0.0),
            "memory_s": rl.get("memory_s", 0.0),
            "collective_s": rl.get("collective_s", 0.0),
            "bound_s": rl.get("bound_s", 0.0),
            "dominant": rl.get("dominant", "?"),
            "useful_flop_frac": rl.get("useful_flop_frac", 0.0),
            "temp_gb": (r.get("memory_analysis", {})
                        .get("temp_size_in_bytes") or 0) / 2 ** 30,
            "collectives": r.get("collectives", {}),
        })
    return out


def roofline_fraction(row) -> float:
    """compute_term / bound — how close the cell sits to the compute roof."""
    if row["bound_s"] <= 0:
        return 0.0
    return row["compute_s"] / row["bound_s"]


def markdown(rows) -> str:
    hdr = ("| arch | shape | mesh | policy | compute (s) | memory (s) | "
           "collective (s) | dominant | MODEL/HLO flops | roofline frac |")
    sep = "|" + "---|" * 10
    lines = [hdr, sep]
    for r in rows:
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['policy']} | "
            f"{r['compute_s']:.4g} | {r['memory_s']:.4g} | "
            f"{r['collective_s']:.4g} | {r['dominant'].replace('_s','')} | "
            f"{r['useful_flop_frac']:.2f} | {roofline_fraction(r):.2f} |")
    return "\n".join(lines)


def run():
    rows = load()
    for r in rows:
        if r["mesh"] != "16x16":
            continue
        print(f"roofline_{r['arch']}_{r['shape']}_{r['policy']},"
              f"{r['bound_s']*1e6:.1f},"
              f"dominant={r['dominant']};frac={roofline_fraction(r):.2f}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--markdown", action="store_true")
    ap.add_argument("--mesh", default=None)
    args = ap.parse_args()
    rows = load()
    if args.mesh:
        rows = [r for r in rows if r["mesh"] == args.mesh]
    if args.markdown:
        print(markdown(rows))
    else:
        run()


if __name__ == "__main__":
    main()
