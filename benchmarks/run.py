"""Benchmark driver: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows:
  fig10_*     — paper Fig. 10 (model size + throughput across precisions)
  tableII_*   — paper Table II (MAC/qmm unit per precision mode)
  tableIII_*  — paper Table III (FASST NAF unit per function)
  tableIV_*   — paper Table IV (end-to-end accelerator throughput)
  roofline_*  — per (arch x shape) roofline bound from the dry-run records
  serve_*     — request-level engine tok/s per weight policy

``--smoke`` runs the reduced sweeps (modules that support it) so CI's
bench-smoke job can accumulate a per-PR perf trajectory cheaply.
"""

from __future__ import annotations

import argparse
import inspect
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced sweeps where supported")
    args = ap.parse_args()

    print("name,us_per_call,derived")
    from . import (bench_fasst, bench_qmm, bench_quant_formats,
                   bench_serving, bench_throughput, roofline)
    failed = []
    for mod in (bench_quant_formats, bench_qmm, bench_fasst,
                bench_throughput, bench_serving, roofline):
        try:
            if "smoke" in inspect.signature(mod.run).parameters:
                mod.run(smoke=args.smoke)
            else:
                mod.run()
        except Exception:
            failed.append(mod.__name__)
            print(f"# {mod.__name__} FAILED", file=sys.stderr)
            traceback.print_exc()
    if failed:
        # later modules still ran (partial trajectories stay useful),
        # but CI must see benchmark breakage as a red check
        sys.exit(f"benchmark modules failed: {', '.join(failed)}")


if __name__ == "__main__":
    main()
