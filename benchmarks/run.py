"""Benchmark driver: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows:
  fig10_*     — paper Fig. 10 (model size + throughput across precisions)
  tableII_*   — paper Table II (MAC/qmm unit per precision mode)
  tableIII_*  — paper Table III (FASST NAF unit per function)
  tableIV_*   — paper Table IV (end-to-end accelerator throughput)
  roofline_*  — per (arch x shape) roofline bound from the dry-run records
  serve_*     — request-level engine tok/s per weight policy
"""

from __future__ import annotations

import sys
import traceback


def main() -> None:
    print("name,us_per_call,derived")
    from . import (bench_fasst, bench_qmm, bench_quant_formats,
                   bench_serving, bench_throughput, roofline)
    for mod in (bench_quant_formats, bench_qmm, bench_fasst,
                bench_throughput, bench_serving, roofline):
        try:
            mod.run()
        except Exception:
            print(f"# {mod.__name__} FAILED", file=sys.stderr)
            traceback.print_exc()


if __name__ == "__main__":
    main()
