"""Paper Table IV analogue: end-to-end accelerator throughput.

The FPGA table compares GOPS across NLP accelerators; the TPU counterpart
is projected decode throughput per architecture from the dry-run roofline
records (memory-bound tokens/s on the production mesh), plus measured CPU
serve-step latency on reduced configs as a relative signal across weight
policies (bf16 vs the paper's int4 deployment).
"""

from __future__ import annotations

import glob
import json
import os

import jax.numpy as jnp

from repro.configs import SHAPES
from repro.models import Ctx
from repro.serving import SamplingParams, deploy

from .common import csv_row, time_fn

DRYRUN_DIR = os.environ.get("REPRO_DRYRUN_DIR", "experiments/final")


def projected_from_dryrun():
    for path in sorted(glob.glob(f"{DRYRUN_DIR}/*decode_32k__16x16*.json")):
        r = json.load(open(path))
        sp = SHAPES["decode_32k"]
        bound = r["roofline"]["bound_s"]
        if bound <= 0:
            continue
        tps = sp.global_batch / bound
        csv_row(f"tableIV_proj_{r['arch']}_{r['policy']}", bound * 1e6,
                f"global_tok_s={tps:.0f};dominant={r['roofline']['dominant']}")


def measured_reduced():
    ctx = Ctx(compute_dtype=jnp.float32)
    for arch in ("qwen2.5-14b", "moonshot-v1-16b-a3b", "mamba2-780m"):
        for pol in ("bf16", "int4"):
            pipe = deploy(arch, pol, slots=8, max_len=64, smoke=True, ctx=ctx)
            eng = pipe.engine
            for i in range(8):     # fill every slot, then time the fused step
                eng.submit({"tokens": jnp.ones((1, 32), jnp.int32)},
                           SamplingParams(max_new_tokens=64 - 32))
            us = time_fn(eng.step, iters=5)
            csv_row(f"tableIV_cpu_{arch}_{pol}", us,
                    f"host_tok_s={8e6/us:.1f}")


def run():
    projected_from_dryrun()
    measured_reduced()


if __name__ == "__main__":
    run()
