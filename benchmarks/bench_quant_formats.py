"""Paper Fig. 10: NLLB-600M size / latency / throughput across precisions.

Two measurements per precision policy:
  * model footprint of the FULL nllb600m config (abstract — no 600M
    allocation on this host) -> size-reduction factor vs the f32 baseline
    (paper: 4.1x at FP4, 0.56 GB);
  * measured CPU decode latency on the REDUCED config (relative speedup
    signal) + the projected TPU-v5e decode throughput for the full model
    from the memory-roofline (decode is bandwidth-bound: tokens/s ~=
    HBM_bw / bytes-per-token) — the mechanism behind the paper's 4.2x
    speedup / 66 tok/s claim.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs import REGISTRY, reduce_config
from repro.core import quantize_tree, resolve_spec
from repro.launch.hlo_analysis import HW
from repro.models import Ctx, build_model
from repro.serving import SamplingParams, ServeEngine

from .common import csv_row, time_fn, tree_bytes_abstract

POLICIES = ["f32", "bf16", "int8", "fp8", "int4", "fp4", "nf4"]


def full_model_bytes(policy_name: str) -> int:
    cfg = REGISTRY["nllb600m"]
    model = build_model(cfg)
    params = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    spec = resolve_spec(policy_name)
    if spec.weights != "f32":
        params = jax.eval_shape(
            lambda p: quantize_tree(p, spec.policy()), params)
    return tree_bytes_abstract(params)


def run():
    base = full_model_bytes("f32")
    rc = reduce_config(REGISTRY["nllb600m"])
    model = build_model(rc)
    params_f32 = model.init(jax.random.PRNGKey(0))
    src = jax.random.randint(jax.random.PRNGKey(1), (4, rc.enc_len), 0,
                             rc.vocab_size)
    batch = {"src_tokens": src,
             "tgt_in": jnp.ones((4, 1), jnp.int32)}

    for pol in POLICIES:
        spec = resolve_spec(pol)
        fb = full_model_bytes(pol)
        params = (params_f32 if spec.weights == "f32"
                  else quantize_tree(params_f32, spec.policy()))
        ctx = Ctx(compute_dtype=jnp.float32)
        kv = spec.kv if spec.weights != "f32" else "bf16"

        # one engine per policy, reused across timed iterations: its
        # jitted prefill/step compile during warmup, so the rows measure
        # decode, not XLA compile
        eng = ServeEngine(model, params, slots=4, max_len=16, kv_dtype=kv,
                          ctx=ctx)
        rows = [{k: v[i:i + 1] for k, v in batch.items()} for i in range(4)]
        sp = SamplingParams(max_new_tokens=8)

        def gen():
            for r in rows:
                eng.submit(r, sp)
            return eng.run_until_drained()

        us = time_fn(gen, iters=5)
        # bandwidth-bound decode projection for the FULL model on 1 v5e chip
        proj_tps = HW["hbm_bw"] / fb
        # bytes-per-param columns come from the resolved spec — the one
        # source every size column derives from (no local bit math)
        bpp = spec.bytes_per_param
        csv_row(f"fig10_{pol}", us / 8,
                f"spec={spec};full_GB={fb/2**30:.3f};"
                f"reduction_vs_f32={base/fb:.2f}x;"
                f"bpp_w={bpp['weights']:.2f};bpp_embed={bpp['embed']:.2f};"
                f"bpp_kv={bpp['kv']:.2f};proj_v5e_tok_s={proj_tps:.0f}")


if __name__ == "__main__":
    run()
