"""Shared benchmark utilities.

Serving-latency percentiles (p50/p95 TTFT, per-output-token) live in
``repro.serving.latency_percentiles`` — one definition shared by
bench_serving rows and the quality suite (repro.eval.suite), imported
directly by each so kernel benches don't pay the serving import.
"""

from __future__ import annotations

import time

import jax
import numpy as np

__all__ = ["time_fn", "tree_bytes_abstract", "csv_row"]


def time_fn(fn, *args, iters=10, warmup=2):
    """Median wall time (us) of a jitted callable on this host."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append((time.perf_counter() - t0) * 1e6)
    return float(np.median(ts))


def tree_bytes_abstract(tree) -> int:
    """Storage bytes of a pytree of arrays / ShapeDtypeStructs / QTensors."""
    total = 0
    for leaf in jax.tree_util.tree_leaves(tree):
        if leaf is None:
            continue
        shape = getattr(leaf, "shape", None)
        dtype = getattr(leaf, "dtype", None)
        if shape is None or dtype is None:
            continue
        total += int(np.prod(shape)) * np.dtype(dtype).itemsize
    return total


def csv_row(name: str, us: float, derived: str = ""):
    print(f"{name},{us:.1f},{derived}")
