"""Paper Table III analogue: the FASST NAF unit per function/precision.

The FPGA table reports op-frequency/LUT/energy per activation function;
here we measure per-element wall time of the shared NAF datapath (the
jitted XLA path that the model uses — identical math to the Pallas
kernel) for every supported function at bf16 and f32, demonstrating the
"one reusable datapath, many NAFs" property the paper argues for.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.fasst import MODES, _naf

from .common import csv_row, time_fn

ROWS, COLS = 4096, 1024


def run():
    rng = np.random.default_rng(0)
    for dtype, tag in [(jnp.float32, "f32"), (jnp.bfloat16, "bf16")]:
        x = jnp.asarray(rng.standard_normal((ROWS, COLS)), dtype)
        for mode in MODES:
            if mode == "identity":
                continue
            f = jax.jit(lambda v, m=mode: _naf(v.astype(jnp.float32), m
                                               ).astype(v.dtype))
            us = time_fn(f, x, iters=8)
            gops = ROWS * COLS / us / 1e3
            csv_row(f"tableIII_naf_{mode}_{tag}", us, f"Gelem_s={gops:.2f}")

        # fused softmax (the paper's SoftMax row)
        f = jax.jit(lambda v: jax.nn.softmax(v.astype(jnp.float32), -1
                                             ).astype(v.dtype))
        us = time_fn(f, x, iters=8)
        csv_row(f"tableIII_softmax_{tag}", us,
                f"Gelem_s={ROWS*COLS/us/1e3:.2f}")


if __name__ == "__main__":
    run()
