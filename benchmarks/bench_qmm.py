"""Paper Table II analogue: the MAC unit, per precision mode.

The FPGA table reports LUT/FF/delay/power per precision; the TPU-native
equivalents are (a) HBM bytes moved per matmul — the quantity the RMMEC
SIMD packing actually improves — and (b) arithmetic intensity (FLOP/byte),
plus measured CPU wall time of the XLA dequant-matmul path as a relative
latency signal. The Pallas kernel itself is validated in tests (interpret
mode is a correctness tool, not a timing tool).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import QTensor, qmatmul
from repro.core.formats import get_format

from .common import csv_row, time_fn

M, K, N = 256, 2048, 2048
FMTS = ["bf16", "int8", "fp8", "int4", "fp4", "nf4"]


def weight_bytes(fmt: str, block=64) -> int:
    if fmt == "bf16":
        return K * N * 2
    f = get_format(fmt)
    scale_bytes = (K // block) * N * 4
    return int(K * N * f.bits / 8) + scale_bytes


def run():
    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.standard_normal((K, N)) * 0.02, jnp.float32)
    x = jnp.asarray(rng.standard_normal((M, K)), jnp.float32)
    flops = 2 * M * K * N

    for fmt in FMTS:
        if fmt == "bf16":
            wq = w.astype(jnp.bfloat16)
        else:
            wq = QTensor.quantize(w, fmt, block_size=64)
        act = "int8" if fmt == "int8" else "bf16"
        f = jax.jit(lambda xx, ww=wq: qmatmul(xx, ww, act=act,
                                              compute_dtype=jnp.bfloat16))
        us = time_fn(f, x, iters=8)
        wb = weight_bytes(fmt)
        total_b = wb + M * K * 2 + M * N * 2        # w + x + y traffic
        csv_row(f"tableII_qmm_{fmt}", us,
                f"weight_bytes={wb};arith_intensity={flops/total_b:.1f}"
                f";bytes_vs_bf16={weight_bytes('bf16')/wb:.2f}x")


if __name__ == "__main__":
    run()
