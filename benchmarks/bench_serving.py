"""Serving throughput trajectory: the request-level engine under load.

The paper's headline deployment numbers (66 tok/s real-time NMT, 4.8x
throughput from quantization) are end-to-end *serving* figures, not bare
kernel times. This benchmark measures the deploy() pipeline the way
traffic hits it, at the bf16 / int8 / int4 presets on the reduced NLLB
config, along two axes the paged-KV engine moves:

  * dense vs paged at an EQUAL self-attention KV budget — the paged
    engine spends the same page pool across 2x the decode slots
    (requests reserve their actual prompt+decode budget, not the worst
    case), so burst traffic sees more concurrent decode lanes. For the
    enc-dec model benchmarked here the per-slot cross-attention cache
    still scales with slots, so total KV bytes are NOT equal — compare
    the kv_mb column, which reports the whole cache honestly;
  * tok/s vs request rate — requests arrive ``rate`` per engine step
    instead of as one burst, exercising continuous mid-flight admission.

``--horizon K`` runs every engine with K-step horizon-fused decode (one
host sync per K decode steps instead of per token); rows then report
``decode_syncs`` and ``tokens_per_sync`` so the BENCH trajectory tracks
host-overhead elimination, and a tripwire reds the run if the fused
path silently fell back to per-token syncing (``decode_syncs`` above
``ceil(tokens/horizon) + slots``). ``--impl pallas`` routes matmuls
through the Pallas qmm kernel and paged attention through the Pallas
block-table kernel (on CPU set REPRO_PALLAS_INTERPRET=1).

``--spec-decode SPEC`` additionally measures each policy with a
speculative draft arm (the same checkpoint quantized at SPEC drafts
``LOOKAHEAD`` tokens per verify round; greedy output is unchanged).
Spec rows report acceptance rate, mean accepted tokens per verify
round, and verify calls per generated token; two tripwires red the run
if the draft arm is dead weight — acceptance must be > 0 and the spec
arm must need FEWER target-model forwards than the target-only run of
the same burst (``verify_calls`` below the baseline's decode steps;
run at --horizon 1 for an exact dispatch-level comparison).

Rows (CSV on stdout; ``--json PATH`` additionally writes the artifact
consumed by CI's bench-smoke job):
  serve_{policy}_{dense|paged}   burst throughput + occupancy + kv MB
  serve_{policy}_paged_rate{r}   continuous-arrival throughput
  serve_{policy}_{mode}_specdec  speculative-decoding arm (--spec-decode)
Every serving row also records per-request latency percentiles
(p50/p95 TTFT and per-output-token time, from RequestStats via the
latency_percentiles helper the eval suite shares).

    PYTHONPATH=src python -m benchmarks.bench_serving [--smoke] [--json P]
        [--horizon K] [--impl xla|pallas] [--spec-decode w4a8kv8]
"""

from __future__ import annotations

import argparse
import json
import math
import time

import jax.numpy as jnp

from repro.core import resolve_spec
from repro.data import SyntheticTranslation
from repro.serving import (IMPL_CHOICES, SamplingParams, deploy, impl_routes,
                           latency_percentiles, pages_needed)

from .common import csv_row

POLICIES = ("bf16", "int8", "int4")
REQUESTS = 8
GEN = 8
SLOTS = 4
MAX_LEN = 32
PAGE = 4
LOOKAHEAD = 4       # draft tokens per verify round (--spec-decode arm)


def _requests(cfg, n):
    ds = SyntheticTranslation(cfg.vocab_size, cfg.enc_len, seed=0)
    reqs = []
    for _ in range(n):
        b = ds.sample(1)
        reqs.append({"src_tokens": jnp.asarray(b["src_tokens"]),
                     "tgt_in": jnp.asarray(b["tgt_in"][:, :1])})
    return reqs


def serve_burst(eng, reqs, gen):
    """All requests at t=0; returns (tokens, seconds, occupancy, outputs)."""
    sp = SamplingParams(max_new_tokens=gen)
    t0 = time.perf_counter()
    for r in reqs:
        eng.submit(r, sp)
    outs = eng.run_until_drained()
    dt = time.perf_counter() - t0
    return sum(o.num_generated for o in outs), dt, eng.occupancy, outs


def serve_rate(eng, reqs, gen, rate):
    """``rate`` new requests per engine step (continuous admission)."""
    sp = SamplingParams(max_new_tokens=gen)
    pending = list(reqs)
    t0 = time.perf_counter()
    outs = []
    while pending or len(outs) < len(reqs):
        for r in pending[:rate]:
            eng.submit(r, sp)
        pending = pending[rate:]
        outs.extend(eng.step())
    dt = time.perf_counter() - t0
    return sum(o.num_generated for o in outs), dt, eng.occupancy, outs


def _deploy(pol, paged, slots, smoke, horizon=1, impl="xla", draft=None):
    # paged engine: same page pool as the dense engine's KV capacity,
    # spread over twice the slots — memory buys concurrency, not padding
    impls = impl_routes(impl)
    if draft is not None:
        impls.update(draft_spec=draft, draft_lookahead=LOOKAHEAD)
    if paged:
        pages = slots * pages_needed(MAX_LEN, PAGE)
        return deploy("nllb600m", pol, slots=2 * slots, max_len=MAX_LEN,
                      smoke=smoke, paged=True, page_size=PAGE,
                      num_pages=pages * (2 if draft else 1),
                      horizon=horizon, **impls)
    return deploy("nllb600m", pol, slots=slots, max_len=MAX_LEN, smoke=smoke,
                  horizon=horizon, **impls)


def _sync_bound(toks: int, horizon: int, extra: int) -> int:
    """Most decode syncs a healthy fused engine may need: one per full
    horizon of tokens plus ``extra`` partially-filled horizons — one
    per slot under burst admission (requests retire in waves), one per
    request under trickle admission (each admission lands at its own
    horizon boundary and can finish inside its own clamped scan)."""
    return math.ceil(toks / max(horizon, 1)) + extra


def run(smoke: bool = False, json_path: str | None = None,
        horizon: int = 1, impl: str = "xla",
        policies: list[str] | None = None,
        spec_decode: str | None = None):
    if policies is None:
        policies = list(POLICIES[:2] if smoke else POLICIES)
    for pol in policies:                 # fail on typos before any build
        resolve_spec(pol)
    if spec_decode is not None:
        resolve_spec(spec_decode)
    n_req = REQUESTS
    rows = []
    tripped = []

    def emit(name, us, derived: dict):
        txt = ";".join(f"{k}={v}" for k, v in derived.items())
        csv_row(name, us, txt)
        rows.append({"name": name, "us_per_call": round(us, 1),
                     "derived": derived})

    def check_syncs(name, eng, toks, extra):
        # silent-fallback tripwire: a fused engine that still syncs per
        # token reports ~toks syncs, far above the horizon-level bound
        bound = _sync_bound(toks, horizon, extra)
        if eng.decode_syncs > bound:
            tripped.append(
                f"{name}: decode_syncs {eng.decode_syncs} > "
                f"ceil({toks}/{horizon}) + {extra} = {bound}")

    for pol in policies:
        occ = {}
        base_steps = {}
        for mode in ("dense", "paged"):
            pipe = _deploy(pol, mode == "paged", SLOTS, smoke=True,
                           horizon=horizon, impl=impl)
            reqs = _requests(pipe.cfg, n_req)
            serve_burst(pipe.engine, reqs, GEN)          # warmup: compiles
            pipe.engine.reset_metrics()                  # measured run only
            toks, dt, _, outs = serve_burst(pipe.engine, reqs, GEN)
            occ[mode] = pipe.engine.occupancy
            base_steps[mode] = pipe.engine.decode_steps
            check_syncs(f"serve_{pol}_{mode}", pipe.engine, toks,
                        pipe.engine.n_slots)
            emit(f"serve_{pol}_{mode}", dt * 1e6 / max(toks, 1), {
                "tok_s": round(toks / dt, 1),
                "requests": n_req,
                "occupancy": round(pipe.engine.occupancy, 3),
                "page_util": round(pipe.engine.page_utilization, 3),
                "kv_mb": round(pipe.engine.kv_cache_bytes / 2**20, 3),
                "compression": f"{pipe.compression:.2f}x",
                "prefill_compiles": pipe.engine.prefill_compiles,
                "horizon": horizon,
                "decode_syncs": pipe.engine.decode_syncs,
                "tokens_per_sync": round(pipe.engine.mean_tokens_per_sync, 2),
                **latency_percentiles(outs),
            })
            if spec_decode is None:
                continue
            # speculative arm: same checkpoint, same burst — the draft
            # quantized at --spec-decode proposes LOOKAHEAD tokens per
            # round, the target verifies them in one batched forward
            pipe = _deploy(pol, mode == "paged", SLOTS, smoke=True,
                           horizon=horizon, impl=impl, draft=spec_decode)
            reqs = _requests(pipe.cfg, n_req)
            serve_burst(pipe.engine, reqs, GEN)          # warmup: compiles
            pipe.engine.reset_metrics()                  # measured run only
            toks, dt, _, outs = serve_burst(pipe.engine, reqs, GEN)
            eng = pipe.engine
            name = f"serve_{pol}_{mode}_specdec"
            emit(name, dt * 1e6 / max(toks, 1), {
                "tok_s": round(toks / dt, 1),
                "requests": n_req,
                "draft_spec": pipe.draft_spec_str,
                "lookahead": LOOKAHEAD,
                "acceptance_rate": round(eng.acceptance_rate, 4),
                "mean_accepted_per_verify":
                    round(eng.mean_accepted_per_verify, 3),
                "verify_calls": eng.verify_calls,
                "verify_per_token": round(eng.verify_calls / max(toks, 1), 4),
                "target_fw_baseline": base_steps[mode],
                "drafted": eng.drafted_tokens,
                "accepted": eng.accepted_tokens,
                **latency_percentiles(outs),
            })
            # tripwires: a draft arm that never agrees with the target,
            # or that costs MORE target forwards than decoding without
            # it, is dead weight — red the run (after the JSON artifact)
            if not eng.acceptance_rate > 0:
                tripped.append(f"{name}: acceptance_rate "
                               f"{eng.acceptance_rate:.4f} is not > 0")
            if eng.verify_calls >= base_steps[mode]:
                tripped.append(
                    f"{name}: verify_calls {eng.verify_calls} >= "
                    f"target-only decode steps {base_steps[mode]} — "
                    "speculation saved no target forwards")
        # acceptance tripwire: continuous paged admission must keep the
        # engine at least as busy as the dense baseline — a violation
        # reds the bench-smoke CI job (raised after the JSON artifact is
        # written so it still carries the numbers)
        ok = occ["paged"] >= occ["dense"] - 1e-9
        emit(f"serve_{pol}_occupancy_check", 0.0, {
            "paged": round(occ["paged"], 3), "dense": round(occ["dense"], 3),
            "paged_ge_dense": int(ok)})
        if not ok:
            tripped.append(
                f"{pol}: paged occupancy {occ['paged']:.3f} < dense "
                f"{occ['dense']:.3f}")

        for rate in ((2,) if smoke else (1, 2, 4)):
            pipe = _deploy(pol, True, SLOTS, smoke=True, horizon=horizon,
                           impl=impl)
            reqs = _requests(pipe.cfg, n_req)
            serve_rate(pipe.engine, reqs, GEN, rate)     # warmup
            pipe.engine.reset_metrics()                  # measured run only
            toks, dt, occ_r, outs = serve_rate(pipe.engine, reqs, GEN, rate)
            check_syncs(f"serve_{pol}_paged_rate{rate}", pipe.engine, toks,
                        n_req)
            emit(f"serve_{pol}_paged_rate{rate}", dt * 1e6 / max(toks, 1), {
                "tok_s": round(toks / dt, 1), "rate_per_step": rate,
                "occupancy": round(occ_r, 3),
                "decode_syncs": pipe.engine.decode_syncs,
                "tokens_per_sync": round(pipe.engine.mean_tokens_per_sync, 2),
                **latency_percentiles(outs)})

    if json_path:
        with open(json_path, "w") as f:
            json.dump({"benchmark": "bench_serving", "smoke": smoke,
                       "horizon": horizon, "impl": impl,
                       "spec_decode": spec_decode, "rows": rows},
                      f, indent=2)
    if tripped:
        raise RuntimeError("serving tripwire: " + "; ".join(tripped))
    return rows


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced sweep for CI perf-trajectory tracking")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write rows as a JSON artifact")
    ap.add_argument("--horizon", type=int, default=1, metavar="K",
                    help="decode steps fused per host sync (1 = per-token)")
    ap.add_argument("--impl", choices=IMPL_CHOICES, default="xla",
                    help="kernel route: pallas = Pallas qmm matmuls + "
                         "Pallas paged attention (CPU runs need "
                         "REPRO_PALLAS_INTERPRET=1)")
    ap.add_argument("--policies", default=None, metavar="SPECS",
                    help="comma list of quantization specs (aliases or "
                         "grammar strings, e.g. bf16,w4a8kv8); default: "
                         "the standard preset sweep")
    ap.add_argument("--spec-decode", default=None, metavar="SPEC",
                    help="also measure each policy with a speculative "
                         "draft arm quantized at SPEC (e.g. w4a8kv8); "
                         "adds serve_*_specdec rows with acceptance "
                         "rate and verify-calls-per-token")
    args = ap.parse_args()
    pols = ([p.strip() for p in args.policies.split(",") if p.strip()]
            if args.policies else None)
    run(smoke=args.smoke, json_path=args.json, horizon=args.horizon,
        impl=args.impl, policies=pols, spec_decode=args.spec_decode)


if __name__ == "__main__":
    main()
