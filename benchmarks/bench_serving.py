"""Serving throughput trajectory: tok/s through the request-level engine.

The paper's headline deployment numbers (66 tok/s real-time NMT, 4.8x
throughput from quantization) are end-to-end *serving* figures, not bare
kernel times. This benchmark measures the deploy() pipeline the way
traffic hits it — a burst of requests through the scheduler-owned
engine — at the bf16 / int8 / int4 presets on the reduced NLLB config,
so future PRs have a comparable serving perf trajectory.

    PYTHONPATH=src python -m benchmarks.bench_serving
"""

from __future__ import annotations

import time

import jax.numpy as jnp

from repro.data import SyntheticTranslation
from repro.serving import SamplingParams, deploy

from .common import csv_row

POLICIES = ("bf16", "int8", "int4")
REQUESTS = 8
GEN = 8
SLOTS = 4
MAX_LEN = 32


def _requests(cfg):
    ds = SyntheticTranslation(cfg.vocab_size, cfg.enc_len, seed=0)
    reqs = []
    for _ in range(REQUESTS):
        b = ds.sample(1)
        reqs.append({"src_tokens": jnp.asarray(b["src_tokens"]),
                     "tgt_in": jnp.asarray(b["tgt_in"][:, :1])})
    return reqs


def serve_once(pipe, reqs):
    sp = SamplingParams(max_new_tokens=GEN)
    t0 = time.perf_counter()
    for r in reqs:
        pipe.engine.submit(r, sp)
    outs = pipe.engine.run_until_drained()
    dt = time.perf_counter() - t0
    toks = sum(o.num_generated for o in outs)
    return toks, dt


def run():
    for pol in POLICIES:
        pipe = deploy("nllb600m", pol, slots=SLOTS, max_len=MAX_LEN,
                      smoke=True)
        reqs = _requests(pipe.cfg)
        serve_once(pipe, reqs)                    # warmup: compiles
        toks, dt = serve_once(pipe, reqs)
        csv_row(f"serve_{pol}", dt * 1e6 / max(toks, 1),
                f"tok_s={toks/dt:.1f};requests={REQUESTS};"
                f"compression={pipe.compression:.2f}x;"
                f"prefill_compiles={pipe.engine.prefill_compiles}")


if __name__ == "__main__":
    run()
