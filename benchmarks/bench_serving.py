"""Serving throughput trajectory: the request-level engine under load.

The paper's headline deployment numbers (66 tok/s real-time NMT, 4.8x
throughput from quantization) are end-to-end *serving* figures, not bare
kernel times. This benchmark measures the deploy() pipeline the way
traffic hits it, at the bf16 / int8 / int4 presets on the reduced NLLB
config, along two axes the paged-KV engine moves:

  * dense vs paged at an EQUAL self-attention KV budget — the paged
    engine spends the same page pool across 2x the decode slots
    (requests reserve their actual prompt+decode budget, not the worst
    case), so burst traffic sees more concurrent decode lanes. For the
    enc-dec model benchmarked here the per-slot cross-attention cache
    still scales with slots, so total KV bytes are NOT equal — compare
    the kv_mb column, which reports the whole cache honestly;
  * tok/s vs request rate — requests arrive ``rate`` per engine step
    instead of as one burst, exercising continuous mid-flight admission.

Rows (CSV on stdout; ``--json PATH`` additionally writes the artifact
consumed by CI's bench-smoke job):
  serve_{policy}_{dense|paged}   burst throughput + occupancy + kv MB
  serve_{policy}_paged_rate{r}   continuous-arrival throughput

    PYTHONPATH=src python -m benchmarks.bench_serving [--smoke] [--json P]
"""

from __future__ import annotations

import argparse
import json
import time

import jax.numpy as jnp

from repro.data import SyntheticTranslation
from repro.serving import SamplingParams, deploy, pages_needed

from .common import csv_row

POLICIES = ("bf16", "int8", "int4")
REQUESTS = 8
GEN = 8
SLOTS = 4
MAX_LEN = 32
PAGE = 4


def _requests(cfg, n):
    ds = SyntheticTranslation(cfg.vocab_size, cfg.enc_len, seed=0)
    reqs = []
    for _ in range(n):
        b = ds.sample(1)
        reqs.append({"src_tokens": jnp.asarray(b["src_tokens"]),
                     "tgt_in": jnp.asarray(b["tgt_in"][:, :1])})
    return reqs


def serve_burst(eng, reqs, gen):
    """All requests at t=0; returns (tokens, seconds, occupancy)."""
    sp = SamplingParams(max_new_tokens=gen)
    t0 = time.perf_counter()
    for r in reqs:
        eng.submit(r, sp)
    outs = eng.run_until_drained()
    dt = time.perf_counter() - t0
    return sum(o.num_generated for o in outs), dt, eng.occupancy


def serve_rate(eng, reqs, gen, rate):
    """``rate`` new requests per engine step (continuous admission)."""
    sp = SamplingParams(max_new_tokens=gen)
    pending = list(reqs)
    t0 = time.perf_counter()
    outs = []
    while pending or len(outs) < len(reqs):
        for r in pending[:rate]:
            eng.submit(r, sp)
        pending = pending[rate:]
        outs.extend(eng.step())
    dt = time.perf_counter() - t0
    return sum(o.num_generated for o in outs), dt, eng.occupancy


def _deploy(pol, paged, slots, smoke):
    # paged engine: same page pool as the dense engine's KV capacity,
    # spread over twice the slots — memory buys concurrency, not padding
    if paged:
        return deploy("nllb600m", pol, slots=2 * slots, max_len=MAX_LEN,
                      smoke=smoke, paged=True, page_size=PAGE,
                      num_pages=slots * pages_needed(MAX_LEN, PAGE))
    return deploy("nllb600m", pol, slots=slots, max_len=MAX_LEN, smoke=smoke)


def run(smoke: bool = False, json_path: str | None = None):
    policies = POLICIES[:2] if smoke else POLICIES
    n_req = REQUESTS
    rows = []
    tripped = []

    def emit(name, us, derived: dict):
        txt = ";".join(f"{k}={v}" for k, v in derived.items())
        csv_row(name, us, txt)
        rows.append({"name": name, "us_per_call": round(us, 1),
                     "derived": derived})

    for pol in policies:
        occ = {}
        for mode in ("dense", "paged"):
            pipe = _deploy(pol, mode == "paged", SLOTS, smoke=True)
            reqs = _requests(pipe.cfg, n_req)
            serve_burst(pipe.engine, reqs, GEN)          # warmup: compiles
            pipe.engine.reset_metrics()                  # measured run only
            toks, dt, _ = serve_burst(pipe.engine, reqs, GEN)
            occ[mode] = pipe.engine.occupancy
            emit(f"serve_{pol}_{mode}", dt * 1e6 / max(toks, 1), {
                "tok_s": round(toks / dt, 1),
                "requests": n_req,
                "occupancy": round(pipe.engine.occupancy, 3),
                "page_util": round(pipe.engine.page_utilization, 3),
                "kv_mb": round(pipe.engine.kv_cache_bytes / 2**20, 3),
                "compression": f"{pipe.compression:.2f}x",
                "prefill_compiles": pipe.engine.prefill_compiles,
            })
        # acceptance tripwire: continuous paged admission must keep the
        # engine at least as busy as the dense baseline — a violation
        # reds the bench-smoke CI job (raised after the JSON artifact is
        # written so it still carries the numbers)
        ok = occ["paged"] >= occ["dense"] - 1e-9
        emit(f"serve_{pol}_occupancy_check", 0.0, {
            "paged": round(occ["paged"], 3), "dense": round(occ["dense"], 3),
            "paged_ge_dense": int(ok)})
        if not ok:
            tripped.append(
                f"{pol}: paged occupancy {occ['paged']:.3f} < dense "
                f"{occ['dense']:.3f}")

        for rate in ((2,) if smoke else (1, 2, 4)):
            pipe = _deploy(pol, True, SLOTS, smoke=True)
            reqs = _requests(pipe.cfg, n_req)
            serve_rate(pipe.engine, reqs, GEN, rate)     # warmup
            pipe.engine.reset_metrics()                  # measured run only
            toks, dt, occ_r = serve_rate(pipe.engine, reqs, GEN, rate)
            emit(f"serve_{pol}_paged_rate{rate}", dt * 1e6 / max(toks, 1), {
                "tok_s": round(toks / dt, 1), "rate_per_step": rate,
                "occupancy": round(occ_r, 3)})

    if json_path:
        with open(json_path, "w") as f:
            json.dump({"benchmark": "bench_serving", "smoke": smoke,
                       "rows": rows}, f, indent=2)
    if tripped:
        raise RuntimeError("occupancy tripwire: " + "; ".join(tripped))
    return rows


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced sweep for CI perf-trajectory tracking")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write rows as a JSON artifact")
    args = ap.parse_args()
    run(smoke=args.smoke, json_path=args.json)


if __name__ == "__main__":
    main()
