"""Serving throughput trajectory: the request-level engine under load.

The paper's headline deployment numbers (66 tok/s real-time NMT, 4.8x
throughput from quantization) are end-to-end *serving* figures, not bare
kernel times. This benchmark measures the deploy() pipeline the way
traffic hits it, at the bf16 / int8 / int4 presets on the reduced NLLB
config, along two axes the paged-KV engine moves:

  * dense vs paged at an EQUAL self-attention KV budget — the paged
    engine spends the same page pool across 2x the decode slots
    (requests reserve their actual prompt+decode budget, not the worst
    case), so burst traffic sees more concurrent decode lanes. For the
    enc-dec model benchmarked here the per-slot cross-attention cache
    still scales with slots, so total KV bytes are NOT equal — compare
    the kv_mb column, which reports the whole cache honestly;
  * tok/s vs request rate — requests arrive as seeded Poisson traffic
    (mean ``rate`` arrivals per scheduler round, injected through
    ``engine.stream(on_round=...)``) instead of as one burst,
    exercising continuous mid-flight admission through the overlapped
    scheduler. The seed is fixed, so CI trajectories compare identical
    arrival traces.

``--horizon K`` runs every engine with K-step horizon-fused decode (one
host sync per K decode steps instead of per token); rows then report
``decode_syncs`` and ``tokens_per_sync`` so the BENCH trajectory tracks
host-overhead elimination, and a tripwire reds the run if the fused
path silently fell back to per-token syncing (``decode_syncs`` above
``ceil(tokens/horizon) + slots``). At K > 1 every row also reports
``overlap_rounds`` — rounds whose host walk was hidden behind an
already-dispatched next scan — and a second tripwire reds the run when
a burst long enough to need several horizons per request never
overlapped once (the double-buffered loop silently degenerated to
dispatch-then-walk). ``--impl pallas`` routes matmuls through the
Pallas qmm kernel and paged attention through the Pallas block-table
kernel (on CPU set REPRO_PALLAS_INTERPRET=1).

``--sla-ttft-ms`` / ``--sla-tpot-ms`` add one serve_{policy}_sla row
per policy: the paged engine re-deployed with
``deploy(..., sla=SLATarget(...))``, served under the same Poisson
arrivals, reporting the measured p95s next to the targets, whether the
final observation window held them, how often the controller retuned,
and the horizon/prefill-cap it settled on.

``--faults`` adds one serve_{policy}_faults chaos row per policy: the
same burst is served twice on identically-configured tight-pool paged
engines — once fault-free (the reference), once under a fixed-seed
``FaultPlan`` injecting page-pool exhaustion (forcing preemption +
resume), NaN logits (forcing a slot error), and deadline-clock skew
against one deadline-carrying request. The row reports the engine's
fault counters plus ``survivor_diffs`` (surviving requests whose token
streams differ from the reference — the fault-isolation claim) and
``prefix_violations`` (failed requests whose partial tokens are not a
prefix of their reference stream). Tripwires red the run unless
survivor_diffs == 0, every planned fault class actually fired
(preemptions, slot errors, deadline expirations all > 0), and the page
allocator's invariant holds after the drain.

``--spec-decode SPEC`` additionally measures each policy with a
speculative draft arm (the same checkpoint quantized at SPEC drafts
``LOOKAHEAD`` tokens per verify round; greedy output is unchanged).
Spec rows report acceptance rate, mean accepted tokens per verify
round, and verify calls per generated token; two tripwires red the run
if the draft arm is dead weight — acceptance must be > 0 and the spec
arm must need FEWER target-model forwards than the target-only run of
the same burst (``verify_calls`` below the baseline's decode steps;
run at --horizon 1 for an exact dispatch-level comparison).

``--trace`` adds one serve_{policy}_traced observability row per
policy: the same burst is served twice on identically-configured paged
engines — untraced reference, then with
``deploy(..., trace=TraceConfig())`` — and tripwires red the run
unless the tracer behaved as a pure observer: traced token streams and
``decode_syncs`` exactly equal the reference's, the trace carries one
CLOSED request span per submitted request (no warmup pass, so the
counts line up), the span/phase stack passes ``Tracer.check()`` with
zero ring drops, the four round-phase timers (admit / dispatch / sync
/ walk) sum to more than zero and at most the measured wall clock, and
the export parses as Chrome/Perfetto trace_event JSON.
``--trace-out`` / ``--metrics-out`` (each implies ``--trace``) write
the traced arm's Perfetto JSON and Prometheus text exposition as CI
artifacts.

``--mesh dp<N>,tp<K>`` adds one serve_{policy}_dp{N}_tp{K} cluster row
per policy (``repro.cluster``): the burst is served through a
``ReplicaRouter`` over N replicas (each tensor-parallel over its own
K-device ``("model",)`` mesh — on CPU force devices with
``XLA_FLAGS=--xla_force_host_platform_device_count=8``) next to a
single engine configured exactly like one replica serving one
replica's share of the burst (equal per-engine work, same collectives
— the honest scale-out baseline on any core count). The row reports
cluster tok/s next to that single-replica baseline, per-replica
occupancy, and merged-histogram p95s (``Histogram.merge`` across
replicas — never averaged percentiles). Tripwires red the run unless
the routed token streams exactly match a full-burst single-engine
reference, the merged histogram count equals the sum of the
per-replica counts, and the router's aggregate throughput holds the
single-replica baseline (full serialization already ties it, so
falling 15% below means the routing layer itself burns the time).

Rows (CSV on stdout; ``--json PATH`` additionally writes the artifact
consumed by CI's bench-smoke job):
  serve_{policy}_{dense|paged}   burst throughput + occupancy + kv MB
  serve_{policy}_paged_rate{r}   Poisson continuous-arrival throughput
  serve_{policy}_{mode}_specdec  speculative-decoding arm (--spec-decode)
  serve_{policy}_sla             SLA-admission arm (--sla-ttft-ms/...)
  serve_{policy}_faults          fault-injection chaos arm (--faults)
  serve_{policy}_traced          observability arm (--trace)
  serve_{policy}_dp{N}_tp{K}     replica-router cluster arm (--mesh)
Every serving row also records per-request latency percentiles
(p50/p95 TTFT and per-output-token time, from RequestStats via the
latency_percentiles helper the eval suite shares).

    PYTHONPATH=src python -m benchmarks.bench_serving [--smoke] [--json P]
        [--horizon K] [--rate R] [--impl xla|pallas] [--faults]
        [--trace] [--trace-out P] [--metrics-out P] [--mesh dp2,tp2]
        [--spec-decode w4a8kv8] [--sla-ttft-ms T --sla-tpot-ms T]
"""

from __future__ import annotations

import argparse
import json
import math
import time

import jax.numpy as jnp
import numpy as np

from repro.cluster import deploy_replicas, parse_mesh_spec, tp_mesh
from repro.configs import get_config, reduce_config
from repro.core import resolve_spec
from repro.data import SyntheticTranslation
from repro.obs import PHASES
from repro.serving import (IMPL_CHOICES, FaultPlan, SamplingParams,
                           SLATarget, TraceConfig, deploy, impl_routes,
                           latency_percentiles, pages_needed)

from .common import csv_row

POLICIES = ("bf16", "int8", "int4")
REQUESTS = 8
GEN = 8
SLOTS = 4
MAX_LEN = 32
PAGE = 4
LOOKAHEAD = 4       # draft tokens per verify round (--spec-decode arm)


def _requests(cfg, n):
    ds = SyntheticTranslation(cfg.vocab_size, cfg.enc_len, seed=0)
    reqs = []
    for _ in range(n):
        b = ds.sample(1)
        reqs.append({"src_tokens": jnp.asarray(b["src_tokens"]),
                     "tgt_in": jnp.asarray(b["tgt_in"][:, :1])})
    return reqs


def serve_burst(eng, reqs, gen):
    """All requests at t=0; returns (tokens, seconds, occupancy, outputs)."""
    sp = SamplingParams(max_new_tokens=gen)
    t0 = time.perf_counter()
    for r in reqs:
        eng.submit(r, sp)
    outs = eng.run_until_drained()
    dt = time.perf_counter() - t0
    return sum(o.num_generated for o in outs), dt, eng.occupancy, outs


def serve_rate(eng, reqs, gen, rate, seed=0):
    """Poisson arrivals (mean ``rate`` per scheduler round, seeded rng)
    injected through the overlapped streaming loop. A drained engine
    with arrivals still pending is force-fed one request so the stream
    never exits early on an unlucky run of zero draws."""
    sp = SamplingParams(max_new_tokens=gen)
    pending = list(reqs)
    rng = np.random.default_rng(seed)

    def arrive():
        if not pending:
            return
        n = int(rng.poisson(rate))
        if n == 0 and eng.num_active == 0 and eng.num_pending == 0:
            n = 1
        for r in pending[:n]:
            eng.submit(r, sp)
        del pending[:n]

    t0 = time.perf_counter()
    outs = []
    arrive()
    while pending or len(outs) < len(reqs):
        outs.extend(eng.stream(on_round=arrive))
    dt = time.perf_counter() - t0
    return sum(o.num_generated for o in outs), dt, eng.occupancy, outs


FAULT_SLOTS = 4
FAULT_PAGES = 12    # == FAULT_SLOTS full chains: zero slack once stolen


def _fault_plan():
    """The fixed chaos schedule the --faults arm injects (one instance
    per engine — plans are stateful). Exhaustion at round 1 grabs the
    pool's whole slack before the first wave's chains finish growing —
    at any horizon — and holds it long enough that growth must preempt
    (the round-6 steal stresses the second admission wave the same
    way); the NaN poisons slot 0 at decode dispatch 1 (slot 0 holds the
    oldest in-flight request, which preemption never victimizes, so the
    slot is guaranteed live and the poison guaranteed to register as a
    slot error), and the round-4 clock skew expires the one
    deadline-carrying request. All coordinates are explicit, so the
    fault counts CI tripwires on are guaranteed, not probabilistic."""
    return FaultPlan(seed=0,
                     exhaust_at=[(1, 6, 4), (6, 6, 3)],
                     nan_at=[(1, 0, 0)],
                     skew_at=[(4, 60_000.0)])


def serve_faults(pol, reqs, gen, horizon, impl):
    """Serve one burst twice — fault-free, then under _fault_plan() on
    an identical engine — and compare streams request-by-request.
    Returns (row dict, tripwire list)."""
    sp = SamplingParams(max_new_tokens=gen)
    # the last request carries a deadline; the round-4 skew expires it
    dl_sp = SamplingParams(max_new_tokens=gen, deadline_ms=500.0)

    def burst(plan):
        pipe = deploy("nllb600m", pol, slots=FAULT_SLOTS, max_len=MAX_LEN,
                      smoke=True, paged=True, page_size=PAGE,
                      num_pages=FAULT_PAGES, horizon=horizon, faults=plan,
                      **impl_routes(impl))
        ids = []
        for i, r in enumerate(reqs):
            p = dl_sp if (plan is not None and i == len(reqs) - 1) else sp
            ids.append(pipe.engine.submit(r, p))
        t0 = time.perf_counter()
        outs = {o.request_id: o for o in pipe.engine.run_until_drained()}
        dt = time.perf_counter() - t0
        if plan is not None:
            plan.release_all(pipe.engine)
        pipe.engine.allocator.check()
        return [outs[i] for i in ids], dt, pipe.engine

    ref, _, _ = burst(None)
    plan = _fault_plan()
    outs, dt, eng = burst(plan)

    survivor_diffs = prefix_violations = 0
    for o, r in zip(outs, ref):
        if o.finish_reason in ("eos", "length"):
            survivor_diffs += int(o.token_ids != r.token_ids)
        else:
            prefix_violations += int(
                o.token_ids != r.token_ids[:len(o.token_ids)])
    m = eng.metrics()
    toks = sum(o.num_generated for o in outs)
    name = f"serve_{pol}_faults"
    row = {
        "tok_s": round(toks / dt, 1),
        "requests": len(reqs),
        "horizon": horizon,
        "faults_injected": len(plan.events),
        "preemptions": m.preemptions,
        "resumed": m.resumed_requests,
        "deadline_expirations": m.deadline_expirations,
        "slot_errors": m.slot_errors,
        "admission_rejections": m.admission_rejections,
        "survivor_diffs": survivor_diffs,
        "prefix_violations": prefix_violations,
        "pages_in_use_after_drain": eng.allocator.pages_in_use,
    }
    tripped = []
    if survivor_diffs:
        tripped.append(f"{name}: {survivor_diffs} surviving requests "
                       "diverged from the fault-free reference")
    if prefix_violations:
        tripped.append(f"{name}: {prefix_violations} failed requests' "
                       "partial tokens are not a reference prefix")
    for counter in ("preemptions", "slot_errors", "deadline_expirations"):
        if not row[counter]:
            tripped.append(f"{name}: planned fault class never fired "
                           f"({counter} == 0)")
    if eng.allocator.pages_in_use:
        tripped.append(f"{name}: {eng.allocator.pages_in_use} pages leaked "
                       "after drain")
    return name, dt, toks, row, tripped


def serve_traced(pol, reqs, gen, horizon, impl,
                 trace_out=None, metrics_out=None):
    """Serve one burst twice on identically-configured paged engines —
    untraced reference, then with ``deploy(..., trace=TraceConfig())``
    — and hold the tracer to its observer contract. No warmup pass:
    every request lands in a fresh engine, so the trace must carry
    exactly one closed, stack-discipline-clean request span per
    request with zero ring drops; and the traced engine's token
    streams and ``decode_syncs`` must equal the untraced reference's
    exactly (tracing must not add host syncs or change scheduling).
    Returns (name, dt, toks, row, tripwires)."""
    def burst(trace):
        pipe = _deploy(pol, True, SLOTS, smoke=True, horizon=horizon,
                       impl=impl, trace=trace)
        toks, dt, _, outs = serve_burst(pipe.engine, reqs, gen)
        return toks, dt, sorted(outs, key=lambda o: o.request_id), pipe

    _, _, ref, ref_pipe = burst(None)
    toks, dt, outs, pipe = burst(TraceConfig())

    tr = pipe.tracer
    problems = tr.check()
    spans = tr.request_spans()
    closed = sum(1 for s in spans.values() if s["closed"])
    m = pipe.engine.metrics()
    phase_ms = {p: getattr(m, f"phase_{p}_ms") for p in PHASES}
    phase_sum = sum(phase_ms.values())
    wall_ms = dt * 1e3
    streams_match = all(o.token_ids == r.token_ids
                        for o, r in zip(outs, ref))
    syncs, ref_syncs = pipe.engine.decode_syncs, ref_pipe.engine.decode_syncs

    name = f"serve_{pol}_traced"
    row = {
        "tok_s": round(toks / dt, 1),
        "requests": len(reqs),
        "horizon": horizon,
        "events": len(tr),
        "dropped": tr.dropped,
        "spans": len(spans),
        "spans_closed": closed,
        "check_problems": len(problems),
        "streams_match": int(streams_match),
        "decode_syncs": syncs,
        "decode_syncs_ref": ref_syncs,
        **{f"phase_{p}_ms": round(v, 3) for p, v in phase_ms.items()},
        "phase_sum_ms": round(phase_sum, 3),
        "wall_ms": round(wall_ms, 3),
        **latency_percentiles(outs),
    }
    tripped = []
    if not streams_match:
        tripped.append(f"{name}: traced token streams diverged from the "
                       "untraced reference — the tracer is not an observer")
    if syncs != ref_syncs:
        tripped.append(f"{name}: traced decode_syncs {syncs} != untraced "
                       f"{ref_syncs} — tracing added host syncs")
    if len(spans) != len(reqs):
        tripped.append(f"{name}: {len(spans)} request spans != "
                       f"{len(reqs)} requests")
    if closed != len(spans):
        tripped.append(f"{name}: {len(spans) - closed} request spans "
                       "never closed")
    if problems:
        tripped.append(f"{name}: trace discipline: "
                       + "; ".join(problems[:3]))
    if tr.dropped:
        tripped.append(f"{name}: ring buffer dropped {tr.dropped} events "
                       "on a burst this small")
    if not 0.0 < phase_sum <= wall_ms * 1.05:
        tripped.append(f"{name}: phase sum {phase_sum:.1f} ms outside "
                       f"(0, {wall_ms:.1f} * 1.05] ms wall — phase timers "
                       "are not measuring disjoint slices of the run")
    try:
        chrome = json.loads(json.dumps(tr.to_chrome()))
        if not isinstance(chrome.get("traceEvents"), list):
            raise ValueError("no traceEvents list")
    except (TypeError, ValueError) as exc:
        tripped.append(f"{name}: trace is not valid Chrome JSON ({exc})")
    if trace_out:
        tr.dump_json(trace_out)
    if metrics_out:
        with open(metrics_out, "w") as f:
            f.write(pipe.engine.prometheus())
    return name, dt, toks, row, tripped


def serve_mesh(pol, reqs, gen, horizon, impl, dp, tp):
    """Serve the burst through a ReplicaRouter over ``dp`` replicas
    (each tensor-parallel over its own ``tp``-device mesh) next to a
    single engine configured exactly like one replica serving one
    replica's SHARE of the burst, and hold the cluster to its
    contract: routed token streams identical to the single engine's,
    merged histograms that account for every per-replica sample, and
    aggregate throughput at least the single replica's — per-engine
    work is identical on both sides, so even a router that fully
    serializes its replicas only ties the baseline, and any
    cross-replica overlap pushes it above; falling meaningfully below
    means the routing layer itself burns the time. Returns
    (name, dt, toks, row, tripwires)."""
    sp = SamplingParams(max_new_tokens=gen)
    # every slot can hold a full chain: deterministic, preemption-free
    pages = SLOTS * pages_needed(MAX_LEN, PAGE)
    kwargs = dict(slots=SLOTS, max_len=MAX_LEN, smoke=True,
                  paged=True, page_size=PAGE, num_pages=pages,
                  horizon=horizon, **impl_routes(impl))

    def burst(eng, rs):
        for r in rs:
            eng.submit(r, sp)
        t0 = time.perf_counter()
        outs = eng.run_until_drained()
        return (sum(o.num_generated for o in outs),
                time.perf_counter() - t0,
                sorted(outs, key=lambda o: o.request_id))

    single = deploy("nllb600m", pol,
                    mesh=tp_mesh(tp) if tp > 1 else None, **kwargs)
    # full burst once: compiles + the stream-equivalence reference
    _, _, ref = burst(single.engine, reqs)
    single.engine.reset_metrics()
    # timed baseline: one replica serving one replica's share
    share = reqs[:max(1, len(reqs) // dp)]

    cluster = deploy_replicas("nllb600m", pol, replicas=dp, tp=tp, **kwargs)
    router = cluster.engine
    burst(router, reqs)                              # warmup: compiles
    router.reset_metrics()

    # alternate A/B repeats and compare best-of-n floors: shared CI
    # boxes jitter 2-3x run to run, and a noisy phase long enough to
    # cover consecutive runs would bias back-to-back arms — pairing
    # the draws spreads it over both (streams are identical anyway)
    ref_runs, runs = [], []
    for _ in range(3):
        ref_runs.append(burst(single.engine, share))
        runs.append(burst(router, reqs))
    ref_toks, ref_dt, _ = min(ref_runs, key=lambda r: r[1])
    toks, dt, outs = min(runs, key=lambda r: r[1])

    m = router.metrics()
    merged = router.merged_latency_histograms()
    per = [e.latency_histograms() for e in router.replicas]
    merged_counts = {k: h.count for k, h in merged.items()}
    summed_counts = {k: sum(p[k].count for p in per) for k in merged}
    tok_s, ref_tok_s = toks / dt, ref_toks / ref_dt

    name = f"serve_{pol}_dp{dp}_tp{tp}"
    row = {
        "tok_s": round(tok_s, 1),
        "single_tok_s": round(ref_tok_s, 1),
        "requests": len(reqs),
        "dp": dp, "tp": tp, "horizon": horizon,
        **{f"occupancy_r{i}": round(e.occupancy, 3)
           for i, e in enumerate(router.replicas)},
        "ttft_p95_ms": m.ttft_p95_ms,     # from Histogram.merge, not
        "tpot_p95_ms": m.tpot_p95_ms,     # averaged per-replica p95s
        "merged_ttft_count": merged_counts["ttft_ms"],
        "merged_tpot_count": merged_counts["tpot_ms"],
        "preemptions": m.preemptions,
    }
    tripped = []
    streams_match = all(
        o.token_ids == r.token_ids and o.finish_reason == r.finish_reason
        for o, r in zip(outs, ref))
    if len(outs) != len(ref) or not streams_match:
        tripped.append(f"{name}: routed token streams diverged from the "
                       "single-engine reference")
    if merged_counts != summed_counts:
        tripped.append(f"{name}: merged histogram counts {merged_counts} "
                       f"!= per-replica sums {summed_counts}")
    if tok_s < ref_tok_s * 0.85:
        # per-engine work is identical on both sides (each serves one
        # share), so full serialization already ties the baseline and
        # any cross-replica overlap wins; the 15% guard absorbs what
        # best-of-3 timing floors still jitter on shared CI runners
        tripped.append(
            f"{name}: router {tok_s:.1f} tok/s fell below the "
            f"single-replica baseline {ref_tok_s:.1f} tok/s")
    return name, dt, toks, row, tripped


def _deploy(pol, paged, slots, smoke, horizon=1, impl="xla", draft=None,
            sla=None, trace=None):
    # paged engine: same page pool as the dense engine's KV capacity,
    # spread over twice the slots — memory buys concurrency, not padding
    impls = impl_routes(impl)
    if draft is not None:
        impls.update(draft_spec=draft, draft_lookahead=LOOKAHEAD)
    if sla is not None:
        impls.update(sla=sla)
    if trace is not None:
        impls.update(trace=trace)
    if paged:
        pages = slots * pages_needed(MAX_LEN, PAGE)
        return deploy("nllb600m", pol, slots=2 * slots, max_len=MAX_LEN,
                      smoke=smoke, paged=True, page_size=PAGE,
                      num_pages=pages * (2 if draft else 1),
                      horizon=horizon, **impls)
    return deploy("nllb600m", pol, slots=slots, max_len=MAX_LEN, smoke=smoke,
                  horizon=horizon, **impls)


def _sync_bound(toks: int, horizon: int, extra: int) -> int:
    """Most decode syncs a healthy fused engine may need: one per full
    horizon of tokens plus ``extra`` partially-filled horizons — one
    per slot under burst admission (requests retire in waves), one per
    request under trickle admission (each admission lands at its own
    horizon boundary and can finish inside its own clamped scan)."""
    return math.ceil(toks / max(horizon, 1)) + extra


def run(smoke: bool = False, json_path: str | None = None,
        horizon: int = 1, impl: str = "xla",
        policies: list[str] | None = None,
        spec_decode: str | None = None,
        rate: int | None = None,
        sla_ttft_ms: float | None = None,
        sla_tpot_ms: float | None = None,
        faults: bool = False,
        trace: bool = False,
        trace_out: str | None = None,
        metrics_out: str | None = None,
        mesh: str | None = None):
    trace = trace or bool(trace_out) or bool(metrics_out)
    dp = tp = 1
    if mesh is not None:
        dp, tp = parse_mesh_spec(mesh)
        import jax
        need = dp * tp
        if len(jax.devices()) < need:
            raise RuntimeError(
                f"--mesh {mesh} needs {need} devices, have "
                f"{len(jax.devices())} (on CPU set XLA_FLAGS="
                f"--xla_force_host_platform_device_count={need})")
    if policies is None:
        policies = list(POLICIES[:2] if smoke else POLICIES)
    for pol in policies:                 # fail on typos before any build
        resolve_spec(pol)
    if spec_decode is not None:
        resolve_spec(spec_decode)
    sla = (SLATarget(p95_ttft_ms=sla_ttft_ms, p95_tpot_ms=sla_tpot_ms,
                     window=REQUESTS)
           if (sla_ttft_ms is not None or sla_tpot_ms is not None) else None)
    rates = [rate] if rate is not None else ([2] if smoke else [1, 2, 4])
    n_req = REQUESTS
    rows = []
    tripped = []

    def emit(name, us, derived: dict):
        txt = ";".join(f"{k}={v}" for k, v in derived.items())
        csv_row(name, us, txt)
        rows.append({"name": name, "us_per_call": round(us, 1),
                     "derived": derived})

    def check_syncs(name, eng, toks, extra):
        # silent-fallback tripwire: a fused engine that still syncs per
        # token reports ~toks syncs, far above the horizon-level bound
        bound = _sync_bound(toks, horizon, extra)
        if eng.decode_syncs > bound:
            tripped.append(
                f"{name}: decode_syncs {eng.decode_syncs} > "
                f"ceil({toks}/{horizon}) + {extra} = {bound}")

    def check_overlap(name, eng):
        # overlap tripwire: a run whose requests each span several
        # horizons must have dispatched ahead at least once — zero
        # means the double-buffered loop silently fell back to serial
        # dispatch-then-walk (spec-decode arms disable overlap by
        # design and are never checked here)
        if 1 < horizon < GEN - 1 and eng.metrics().overlap_rounds == 0:
            tripped.append(
                f"{name}: overlap_rounds == 0 at horizon {horizon} with "
                f"{GEN}-token requests — host walks are not being hidden "
                "behind dispatched-ahead scans")

    for pol in policies:
        occ = {}
        base_steps = {}
        for mode in ("dense", "paged"):
            pipe = _deploy(pol, mode == "paged", SLOTS, smoke=True,
                           horizon=horizon, impl=impl)
            reqs = _requests(pipe.cfg, n_req)
            serve_burst(pipe.engine, reqs, GEN)          # warmup: compiles
            pipe.engine.reset_metrics()                  # measured run only
            toks, dt, _, outs = serve_burst(pipe.engine, reqs, GEN)
            m = pipe.engine.metrics()
            occ[mode] = m.occupancy
            base_steps[mode] = m.decode_steps
            check_syncs(f"serve_{pol}_{mode}", pipe.engine, toks,
                        pipe.engine.n_slots)
            check_overlap(f"serve_{pol}_{mode}", pipe.engine)
            emit(f"serve_{pol}_{mode}", dt * 1e6 / max(toks, 1), {
                "tok_s": round(toks / dt, 1),
                "requests": n_req,
                "occupancy": round(m.occupancy, 3),
                "page_util": round(m.page_utilization, 3),
                "kv_mb": round(m.kv_cache_bytes / 2**20, 3),
                "compression": f"{pipe.compression:.2f}x",
                "prefill_compiles": m.prefill_compiles,
                "horizon": horizon,
                "decode_syncs": m.decode_syncs,
                "tokens_per_sync": round(m.mean_tokens_per_sync, 2),
                "overlap_rounds": m.overlap_rounds,
                **latency_percentiles(outs),
            })
            if spec_decode is None:
                continue
            # speculative arm: same checkpoint, same burst — the draft
            # quantized at --spec-decode proposes LOOKAHEAD tokens per
            # round, the target verifies them in one batched forward
            pipe = _deploy(pol, mode == "paged", SLOTS, smoke=True,
                           horizon=horizon, impl=impl, draft=spec_decode)
            reqs = _requests(pipe.cfg, n_req)
            serve_burst(pipe.engine, reqs, GEN)          # warmup: compiles
            pipe.engine.reset_metrics()                  # measured run only
            toks, dt, _, outs = serve_burst(pipe.engine, reqs, GEN)
            sm = pipe.engine.metrics()
            name = f"serve_{pol}_{mode}_specdec"
            emit(name, dt * 1e6 / max(toks, 1), {
                "tok_s": round(toks / dt, 1),
                "requests": n_req,
                "draft_spec": pipe.draft_spec_str,
                "lookahead": LOOKAHEAD,
                "acceptance_rate": round(sm.acceptance_rate, 4),
                "mean_accepted_per_verify":
                    round(sm.mean_accepted_per_verify, 3),
                "verify_calls": sm.verify_calls,
                "verify_per_token": round(sm.verify_calls / max(toks, 1), 4),
                "target_fw_baseline": base_steps[mode],
                "drafted": sm.drafted_tokens,
                "accepted": sm.accepted_tokens,
                **latency_percentiles(outs),
            })
            # tripwires: a draft arm that never agrees with the target,
            # or that costs MORE target forwards than decoding without
            # it, is dead weight — red the run (after the JSON artifact)
            if not sm.acceptance_rate > 0:
                tripped.append(f"{name}: acceptance_rate "
                               f"{sm.acceptance_rate:.4f} is not > 0")
            if sm.verify_calls >= base_steps[mode]:
                tripped.append(
                    f"{name}: verify_calls {sm.verify_calls} >= "
                    f"target-only decode steps {base_steps[mode]} — "
                    "speculation saved no target forwards")
        # acceptance tripwire: continuous paged admission must keep the
        # engine at least as busy as the dense baseline — a violation
        # reds the bench-smoke CI job (raised after the JSON artifact is
        # written so it still carries the numbers)
        ok = occ["paged"] >= occ["dense"] - 1e-9
        emit(f"serve_{pol}_occupancy_check", 0.0, {
            "paged": round(occ["paged"], 3), "dense": round(occ["dense"], 3),
            "paged_ge_dense": int(ok)})
        if not ok:
            tripped.append(
                f"{pol}: paged occupancy {occ['paged']:.3f} < dense "
                f"{occ['dense']:.3f}")

        for r in rates:
            pipe = _deploy(pol, True, SLOTS, smoke=True, horizon=horizon,
                           impl=impl)
            reqs = _requests(pipe.cfg, n_req)
            serve_rate(pipe.engine, reqs, GEN, r)        # warmup
            pipe.engine.reset_metrics()                  # measured run only
            toks, dt, occ_r, outs = serve_rate(pipe.engine, reqs, GEN, r)
            m = pipe.engine.metrics()
            check_syncs(f"serve_{pol}_paged_rate{r}", pipe.engine, toks,
                        n_req)
            emit(f"serve_{pol}_paged_rate{r}", dt * 1e6 / max(toks, 1), {
                "tok_s": round(toks / dt, 1), "rate_per_round": r,
                "occupancy": round(occ_r, 3),
                "decode_syncs": m.decode_syncs,
                "tokens_per_sync": round(m.mean_tokens_per_sync, 2),
                "overlap_rounds": m.overlap_rounds,
                **latency_percentiles(outs)})

        if faults:
            # chaos arm: fault-injected burst vs fault-free reference on
            # identical engines — stream equivalence is the product here,
            # so no warmup pass (timing is reported but not compared)
            fault_cfg = reduce_config(get_config("nllb600m"))
            fname, fdt, ftoks, frow, ftripped = serve_faults(
                pol, _requests(fault_cfg, n_req), GEN, horizon, impl)
            emit(fname, fdt * 1e6 / max(ftoks, 1), frow)
            tripped.extend(ftripped)

        if trace:
            # observability arm: traced burst vs untraced reference on
            # identical engines — observer equivalence is the product,
            # so no warmup pass (span count must equal request count);
            # trace/metrics artifacts come from the LAST traced policy
            trace_cfg = reduce_config(get_config("nllb600m"))
            tname, tdt, ttoks, trow, ttripped = serve_traced(
                pol, _requests(trace_cfg, n_req), GEN, horizon, impl,
                trace_out=trace_out, metrics_out=metrics_out)
            emit(tname, tdt * 1e6 / max(ttoks, 1), trow)
            tripped.extend(ttripped)

        if mesh is not None:
            # cluster arm: single-replica baseline vs ReplicaRouter over
            # dp replicas x tp-device meshes — stream equivalence and
            # merged-histogram accounting are the product (both runs get
            # their own warmup; see serve_mesh for the tripwires)
            mesh_cfg = reduce_config(get_config("nllb600m"))
            mname, mdt, mtoks, mrow, mtripped = serve_mesh(
                pol, _requests(mesh_cfg, n_req), GEN, horizon, impl, dp, tp)
            emit(mname, mdt * 1e6 / max(mtoks, 1), mrow)
            tripped.extend(mtripped)

        if sla is not None:
            # SLA-admission arm: same Poisson traffic, the engine's own
            # controller retunes horizon/prefill admission against the
            # measured percentiles (no sync-count tripwire here — the
            # controller changes the horizon mid-run by design)
            r = rates[0]
            pipe = _deploy(pol, True, SLOTS, smoke=True, horizon=horizon,
                           impl=impl, sla=sla)
            reqs = _requests(pipe.cfg, n_req)
            serve_rate(pipe.engine, reqs, GEN, r)        # warmup
            pipe.engine.reset_metrics()                  # measured run only
            toks, dt, _, outs = serve_rate(pipe.engine, reqs, GEN, r)
            m = pipe.engine.metrics()
            ctl = pipe.engine.sla
            lat = latency_percentiles(outs)
            held = ctl.holding()
            name = f"serve_{pol}_sla"
            emit(name, dt * 1e6 / max(toks, 1), {
                "tok_s": round(toks / dt, 1), "rate_per_round": r,
                "sla_ttft_ms": sla_ttft_ms, "sla_tpot_ms": sla_tpot_ms,
                "sla_held": None if held is None else int(held),
                "retunes": ctl.retunes,
                "final_horizon": ctl.horizon,
                "final_prefill_cap": ctl.prefill_cap,
                "overlap_rounds": m.overlap_rounds,
                **lat})
            if held is False:
                tripped.append(
                    f"{name}: final window missed the SLA "
                    f"(ttft_p95 {lat['ttft_p95_ms']}ms vs "
                    f"{sla_ttft_ms}, tpot_p95 {lat['tpot_p95_ms']}ms "
                    f"vs {sla_tpot_ms})")

    if json_path:
        with open(json_path, "w") as f:
            json.dump({"benchmark": "bench_serving", "smoke": smoke,
                       "horizon": horizon, "impl": impl,
                       "rate": rate, "sla_ttft_ms": sla_ttft_ms,
                       "sla_tpot_ms": sla_tpot_ms,
                       "spec_decode": spec_decode, "faults": faults,
                       "trace": trace, "mesh": mesh, "rows": rows},
                      f, indent=2)
    if tripped:
        raise RuntimeError("serving tripwire: " + "; ".join(tripped))
    return rows


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced sweep for CI perf-trajectory tracking")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write rows as a JSON artifact")
    ap.add_argument("--horizon", type=int, default=1, metavar="K",
                    help="decode steps fused per host sync (1 = per-token)")
    ap.add_argument("--impl", choices=IMPL_CHOICES, default="xla",
                    help="kernel route: pallas = Pallas qmm matmuls + "
                         "Pallas paged attention (CPU runs need "
                         "REPRO_PALLAS_INTERPRET=1)")
    ap.add_argument("--policies", default=None, metavar="SPECS",
                    help="comma list of quantization specs (aliases or "
                         "grammar strings, e.g. bf16,w4a8kv8); default: "
                         "the standard preset sweep")
    ap.add_argument("--spec-decode", default=None, metavar="SPEC",
                    help="also measure each policy with a speculative "
                         "draft arm quantized at SPEC (e.g. w4a8kv8); "
                         "adds serve_*_specdec rows with acceptance "
                         "rate and verify-calls-per-token")
    ap.add_argument("--rate", type=int, default=None, metavar="R",
                    help="mean Poisson arrivals per scheduler round for "
                         "the continuous-admission rows (default: the "
                         "standard 1/2/4 sweep, 2 under --smoke)")
    ap.add_argument("--sla-ttft-ms", type=float, default=None, metavar="T",
                    help="p95 TTFT target: adds serve_*_sla rows served "
                         "under deploy(sla=SLATarget(...)) admission "
                         "control; a final window that misses the "
                         "target reds the run")
    ap.add_argument("--sla-tpot-ms", type=float, default=None, metavar="T",
                    help="p95 per-output-token target (see --sla-ttft-ms)")
    ap.add_argument("--faults", action="store_true",
                    help="add serve_*_faults chaos rows: the burst is "
                         "re-served under a fixed-seed FaultPlan (page "
                         "exhaustion, NaN logits, clock skew) and the "
                         "run reds unless survivors match the fault-free "
                         "reference and every fault class fired")
    ap.add_argument("--trace", action="store_true",
                    help="add serve_*_traced observability rows: the "
                         "burst is re-served with lifecycle tracing on "
                         "and the run reds unless the trace carries one "
                         "closed span per request, phase times sum to "
                         "at most the wall clock, and the traced token "
                         "streams + decode_syncs exactly match an "
                         "untraced reference")
    ap.add_argument("--trace-out", default=None, metavar="FILE",
                    help="write the traced arm's Chrome/Perfetto "
                         "trace_event JSON here (implies --trace)")
    ap.add_argument("--metrics-out", default=None, metavar="FILE",
                    help="write the traced arm's Prometheus text "
                         "exposition here (implies --trace)")
    ap.add_argument("--mesh", default=None, metavar="SPEC",
                    help="add serve_*_dp{N}_tp{K} cluster rows: the "
                         "burst re-served through a ReplicaRouter over "
                         "N replicas x K-device tensor-parallel meshes "
                         "(e.g. dp2,tp2; on CPU set XLA_FLAGS="
                         "--xla_force_host_platform_device_count=8); "
                         "reds the run on stream divergence, histogram "
                         "miscounts, or throughput below the "
                         "single-replica baseline")
    args = ap.parse_args()
    pols = ([p.strip() for p in args.policies.split(",") if p.strip()]
            if args.policies else None)
    run(smoke=args.smoke, json_path=args.json, horizon=args.horizon,
        impl=args.impl, policies=pols, spec_decode=args.spec_decode,
        rate=args.rate, sla_ttft_ms=args.sla_ttft_ms,
        sla_tpot_ms=args.sla_tpot_ms, faults=args.faults,
        trace=args.trace, trace_out=args.trace_out,
        metrics_out=args.metrics_out, mesh=args.mesh)


if __name__ == "__main__":
    main()
