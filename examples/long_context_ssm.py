"""Long-context decoding with O(1) state (the long_500k cell, miniaturized).

Attention-free Mamba-2 carries a constant-size recurrent state, so decode
cost is flat in context length — the property that makes the 524k-token
long_500k dry-run cell feasible (DESIGN.md §4). This demo decodes after
short and long prompts and shows identical state size + per-step cost,
with int8-quantized projections (the paper's policy on an SSM).

    PYTHONPATH=src python examples/long_context_ssm.py
"""

import time

import jax
import jax.numpy as jnp

from repro.configs import REGISTRY, reduce_config
from repro.core import PRESETS, quantize_tree
from repro.models import Ctx, build_model

ctx = Ctx(compute_dtype=jnp.float32)
cfg = reduce_config(REGISTRY["mamba2-780m"])
model = build_model(cfg)
# SSMs serve best at int8 (EXPERIMENTS SS Perf iteration A: int4 unpack
# round-trips dominate when weights are a small fraction of state traffic)
params = quantize_tree(model.init(jax.random.PRNGKey(0)), PRESETS["int8"])

decode = jax.jit(lambda p, t, c: model.decode_step(ctx, p, t, c))

for prompt_len in (32, 512):
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, prompt_len), 0,
                              cfg.vocab_size)
    cache = model.init_cache(2, prompt_len + 8, "bf16")
    cache, logits = model.prefill(ctx, params, cache, {"tokens": toks})
    state_bytes = sum(x.size * x.dtype.itemsize
                      for x in jax.tree.leaves(cache))
    tok = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
    cache, _ = decode(params, tok, cache)          # compile
    t0 = time.perf_counter()
    for _ in range(16):
        cache, lg = decode(params, tok, cache)
        tok = jnp.argmax(lg[:, -1:], -1).astype(jnp.int32)
    jax.block_until_ready(lg)
    dt = (time.perf_counter() - t0) / 16 * 1e3
    print(f"prompt {prompt_len:4d} tokens: state {state_bytes/1024:7.1f} KiB"
          f" (constant), decode {dt:.2f} ms/step (flat in context)")
