"""Continuous-batching quantized serving + the paper's quality grid.

Part 1 (the paper's deployment mode): one deploy() call stands up an
INT4-weight / INT8-KV pipeline — the TPU analogue of the paper's
real-time FPGA translation node. The engine owns admission and slot
scheduling: we submit 8 requests with *mixed* per-request SamplingParams
(greedy next to seeded nucleus sampling, all served by one compiled step
function — one of them streaming token-by-token through an on_token
callback) and consume outputs as each request finishes
(``engine.stream()``; the overlapped scheduler dispatches the next
horizon while the host walks the previous block).

Part 2 (the paper's evaluation mode, Fig. 9): fit the synthetic
many-to-many task, deploy the checkpoint at int8, and print the
bidirectional per-pair chrF grid via repro.eval — every sentence decoded
through the same request-level engine as part 1.

    PYTHONPATH=src python examples/serve_multilingual.py
"""

import time

import jax.numpy as jnp

from repro.configs import REGISTRY, reduce_config
from repro.data import LANG_CODES, SyntheticTranslation, pairs
from repro.eval import evaluate_pairs, summarize
from repro.launch.eval import train_params
from repro.models import Ctx
from repro.serving import SamplingParams, deploy

# -- part 1: mixed-params continuous batching at a custom QuantSpec --------
# No preset needed: "w4a8kv8" is a grammar string (int4 weights, int8
# activations, int8 KV pages) — any precision mix the paper's Fig. 10
# grid names deploys the same way (see core.spec for the grammar).

cal_ds = SyntheticTranslation(reduce_config(REGISTRY["nllb600m"]).vocab_size,
                              reduce_config(REGISTRY["nllb600m"]).enc_len,
                              seed=1)
calib = ({k: jnp.asarray(v) for k, v in cal_ds.sample(8).items()
          if not isinstance(v, str)} for _ in range(2))
pipe = deploy("nllb600m", "w4a8kv8", slots=4, max_len=32, smoke=True,
              horizon=4, calib_batches=calib)
print(f"deployed nllb600m @ {pipe.policy} (= {pipe.spec_str}): "
      f"{pipe.fp_bytes/2**20:.2f} MB -> "
      f"{pipe.quantized_bytes/2**20:.2f} MB ({pipe.compression:.1f}x), "
      f"{len(pipe.ctx.act_scales)} calibrated act sites")
ds = SyntheticTranslation(pipe.cfg.vocab_size, pipe.cfg.enc_len, seed=0)

t0 = time.perf_counter()
live = []            # request 0 streams token-by-token as blocks sync
for rid in range(8):
    b = ds.sample(1)
    req = {"src_tokens": jnp.asarray(b["src_tokens"]),
           "tgt_in": jnp.asarray([[LANG_CODES[b["tgt_lang"]]]])}
    sp = (SamplingParams(max_new_tokens=6) if rid % 2 == 0 else
          SamplingParams(temperature=0.8, top_p=0.9, max_new_tokens=6,
                         seed=rid))
    pipe.engine.submit(req, sp, on_token=live.append if rid == 0 else None)

served = 0
for o in pipe.engine.stream():           # yields as each request finishes
    mode = "greedy" if o.request_id % 2 == 0 else "top-p "
    print(f"request {o.request_id} ({mode}, slot {o.slot}, "
          f"{o.finish_reason}, ttft {o.ttft_ms:.1f} ms): {o.token_ids}")
    served += o.num_generated
dt = time.perf_counter() - t0
m = pipe.engine.metrics()
print(f"\n8 requests, {served} tokens in {dt:.2f}s "
      f"({served/dt:.1f} tok/s on this host, "
      f"{m.decode_syncs} host syncs, {m.overlap_rounds} overlapped rounds; "
      f"request 0 streamed {len(live)} tokens live)")

# -- part 2: converge the task, print the per-pair chrF grid ---------------

LANGS = ["hin", "eng", "ita"]
GRID = pairs(("hin",), ("eng", "ita"))        # hin<->eng, hin<->ita
STEPS = 4000          # 3 languages = 3 permutations to fit; ~1.5 min CPU

cfg = reduce_config(REGISTRY["nllb600m"])
print(f"\nfitting the synthetic many-to-many task ({STEPS} steps)...")
params = train_params(cfg, LANGS, steps=STEPS, batch=32, lr=3e-3, seed=0)

qpipe = deploy(cfg, "int8", params=params, slots=4, max_len=16,
               ctx=Ctx(compute_dtype=jnp.float32))
scores = evaluate_pairs(qpipe, GRID, n_sent=8, seed=0, languages=LANGS)

tgts = sorted({s.tgt for s in scores})
cell = {(s.src, s.tgt): s.chrf for s in scores}
print("\nheld-out per-pair chrF @ int8 (src rows, tgt cols):")
print(f"{'':>6}" + "".join(f"{t:>8}" for t in tgts))
for src in sorted({s.src for s in scores}):
    row = "".join(f"{cell[(src, t)]:8.3f}" if (src, t) in cell else
                  f"{'—':>8}" for t in tgts)
    print(f"{src:>6}" + row)
agg = summarize(scores)
print(f"\n{agg['pairs']} directions, mean BLEU {agg['mean_bleu']:.3f}, "
      f"mean chrF {agg['mean_chrf']:.3f}, "
      f"{agg['gen_tokens']} tokens @ {agg['mean_tok_s']:.0f} tok/s")
