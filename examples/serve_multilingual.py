"""Continuous-batching quantized serving (the paper's deployment mode).

An INT4-weight / INT8-KV ServeEngine handles interleaved requests in
fixed batch slots — the TPU analogue of the paper's real-time FPGA
translation node.

    PYTHONPATH=src python examples/serve_multilingual.py
"""

import time

import jax
import jax.numpy as jnp

from repro.configs import REGISTRY, reduce_config
from repro.core import PRESETS, quantize_tree
from repro.data import LANG_CODES, SyntheticTranslation
from repro.models import Ctx, build_model
from repro.serving import ServeEngine

ctx = Ctx(compute_dtype=jnp.float32)
cfg = reduce_config(REGISTRY["nllb600m"])
model = build_model(cfg)
params = quantize_tree(model.init(jax.random.PRNGKey(0)), PRESETS["int4"])

eng = ServeEngine(model, params, slots=4, max_len=32, kv_dtype="int8",
                  ctx=ctx)
ds = SyntheticTranslation(cfg.vocab_size, 12, seed=0)

t0 = time.perf_counter()
queue = []
for rid in range(8):
    b = ds.sample(1)
    queue.append((rid, {"src_tokens": jnp.asarray(b["src_tokens"]),
                        "tgt_in": jnp.asarray([[LANG_CODES[b["tgt_lang"]]]])}))

inflight, served = {}, 0
while queue or inflight:
    while queue and eng.free_slot() is not None:
        rid, req = queue.pop(0)
        inflight[eng.add_request(req, gen_tokens=6)] = rid
    for slot in eng.tick():
        rid = inflight.pop(slot)
        print(f"request {rid} (slot {slot}): {eng.result(slot)}")
        served += len(eng.result(slot))
dt = time.perf_counter() - t0
print(f"\n8 requests, {served} tokens in {dt:.2f}s "
      f"({served/dt:.1f} tok/s on this host)")
