"""Continuous-batching quantized serving (the paper's deployment mode).

One deploy() call stands up an INT4-weight / INT8-KV pipeline — the TPU
analogue of the paper's real-time FPGA translation node. The engine owns
admission and slot scheduling: we submit 8 requests with *mixed*
per-request SamplingParams (greedy next to seeded nucleus sampling, all
served by one compiled step function) and drain.

    PYTHONPATH=src python examples/serve_multilingual.py
"""

import time

import jax.numpy as jnp

from repro.data import LANG_CODES, SyntheticTranslation
from repro.serving import SamplingParams, deploy

pipe = deploy("nllb600m", "int4", slots=4, max_len=32, smoke=True)
print(f"deployed nllb600m @ int4: {pipe.fp_bytes/2**20:.2f} MB -> "
      f"{pipe.quantized_bytes/2**20:.2f} MB ({pipe.compression:.1f}x)")
ds = SyntheticTranslation(pipe.cfg.vocab_size, pipe.cfg.enc_len, seed=0)

t0 = time.perf_counter()
for rid in range(8):
    b = ds.sample(1)
    req = {"src_tokens": jnp.asarray(b["src_tokens"]),
           "tgt_in": jnp.asarray([[LANG_CODES[b["tgt_lang"]]]])}
    sp = (SamplingParams(max_new_tokens=6) if rid % 2 == 0 else
          SamplingParams(temperature=0.8, top_p=0.9, max_new_tokens=6,
                         seed=rid))
    pipe.engine.submit(req, sp)

served = 0
for o in sorted(pipe.engine.run_until_drained(), key=lambda o: o.request_id):
    mode = "greedy" if o.request_id % 2 == 0 else "top-p "
    print(f"request {o.request_id} ({mode}, slot {o.slot}, "
          f"{o.finish_reason}): {o.token_ids}")
    served += o.num_generated
dt = time.perf_counter() - t0
print(f"\n8 requests, {served} tokens in {dt:.2f}s "
      f"({served/dt:.1f} tok/s on this host)")
