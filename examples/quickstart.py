"""Quickstart: the paper's full pipeline in ~90 lines.

Train a reduced NLLB-600M on the synthetic many-to-many translation task,
post-training-quantize it to INT4 (the paper's deployment format),
translate the same sources into two different languages with one model,
stream a translation token-by-token as each fused horizon block lands,
redeploy with an FP4 speculative draft arm (same checkpoint, same
tokens, fewer target-model forwards), observe a traced deployment
(lifecycle spans, round-phase timing, Perfetto + Prometheus exports),
exercise the failure surface: bounded admission (EngineSaturated),
per-request deadlines, and finish_reason on every output — and finally
scale the same checkpoint out over replicas with ``repro.cluster``.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp

from repro.configs import REGISTRY, reduce_config
from repro.data import SyntheticTranslation
from repro.models import Ctx, build_model
from repro.optim import warmup_linear
from repro.serving import (EngineSaturated, SamplingParams, TraceConfig,
                           deploy)
from repro.train import make_train_step

ctx = Ctx(compute_dtype=jnp.float32)
cfg = reduce_config(REGISTRY["nllb600m"])
model = build_model(cfg)
ds = SyntheticTranslation(cfg.vocab_size, cfg.enc_len, seed=0,
                          languages=("hin", "eng", "ita"))

# --- train ------------------------------------------------------------
STEPS = 60
init_state, step = make_train_step(
    model, lr_fn=lambda s: warmup_linear(s, peak_lr=1e-2, warmup=5,
                                         total=STEPS), ctx=ctx)
state = init_state(model.init(jax.random.PRNGKey(0)))
step = jax.jit(step)
for i in range(STEPS):
    batch = {k: jnp.asarray(v) for k, v in ds.sample(16).items()
             if not isinstance(v, str)}
    state, metrics = step(state, batch)
    if i % 10 == 0:
        print(f"step {i:3d}  loss {float(metrics['loss']):.3f}")
params = state["params"]

# --- deploy (paper: BitsAndBytes-style blockwise PTQ to INT4) ----------
pipe = deploy(cfg, "int4", slots=2, max_len=16, params=params, ctx=ctx)
print(f"\nmodel size: {pipe.fp_bytes/2**20:.2f} MB -> "
      f"{pipe.quantized_bytes/2**20:.2f} MB "
      f"({pipe.compression:.1f}x reduction; paper: 4.1x)")

# --- translate (one model, many directions: paper Fig. 2b) -------------
src = jnp.asarray(ds.sample(2)["src_tokens"])
for lang in ("ita", "hin"):
    outs = pipe.translate(src, lang, SamplingParams(max_new_tokens=6))
    print(f"-> {lang}: {[o.token_ids for o in outs]}")

# --- stream one translation token-by-token -----------------------------
# translate_stream yields each token id as its horizon block syncs; the
# finished RequestOutput (with TTFT / per-token latency) is the
# generator's return value.
stream = pipe.translate_stream(src[:1], "ita",
                               SamplingParams(max_new_tokens=6))
print("-> ita (streamed):", end=" ", flush=True)
while True:
    try:
        print(next(stream), end=" ", flush=True)
    except StopIteration as fin:
        out = fin.value
        break
print(f"| ttft {out.ttft_ms:.1f} ms, {out.tpot_ms:.2f} ms/token")

# --- speculative decoding: draft at FP4, verify at INT8 ----------------
# The same checkpoint deploys twice — an aggressive wfp4a8 draft arm
# proposes tokens, the int8 target verifies them in one batched
# forward. Greedy output is token-for-token identical to target-only
# decoding; the draft only changes how fast tokens arrive.
spec_pipe = deploy(cfg, "int8", slots=2, max_len=16, params=params,
                   ctx=ctx, draft_spec="wfp4a8", draft_lookahead=4)
for lang in ("ita", "hin"):
    outs = spec_pipe.translate(src, lang, SamplingParams(max_new_tokens=6))
    print(f"-> {lang} (speculative): {[o.token_ids for o in outs]}")
m = spec_pipe.engine.metrics()
print(f"draft {spec_pipe.draft_spec_str}: acceptance "
      f"{m.acceptance_rate:.2f} ({m.accepted_tokens}/"
      f"{m.drafted_tokens} drafted, {m.verify_calls} verify rounds)")

# --- observing a deployment --------------------------------------------
# deploy(..., trace=TraceConfig()) wires a lifecycle tracer into the
# engine: every request becomes a span (queued -> prefill ->
# decode-round* -> retired) and every scheduler round records where its
# time went (admit / dispatch / sync / walk). Tracing is a pure
# observer — token streams and host-sync counts are identical to an
# untraced engine (CI asserts this), so it is safe to leave on while
# debugging latency. The trace exports as Chrome/Perfetto JSON (open
# chrome://tracing or https://ui.perfetto.dev) and the metrics snapshot
# + always-on TTFT/TPOT histograms render as Prometheus text.
obs_pipe = deploy(cfg, "int4", slots=2, max_len=16, params=params,
                  ctx=ctx, trace=TraceConfig())
obs_pipe.translate(src, "ita", SamplingParams(max_new_tokens=6))
m = obs_pipe.engine.metrics()
print(f"\nttft p50/p95 {m.ttft_p50_ms:.1f}/{m.ttft_p95_ms:.1f} ms | "
      f"phases: admit {m.phase_admit_ms:.0f} ms, "
      f"dispatch {m.phase_dispatch_ms:.0f} ms, "
      f"sync {m.phase_sync_ms:.1f} ms, walk {m.phase_walk_ms:.1f} ms")
obs_pipe.tracer.dump_json("quickstart_trace.json")
print(f"perfetto trace: {len(obs_pipe.tracer)} events "
      "-> quickstart_trace.json")
prom = obs_pipe.engine.prometheus()              # scrape-ready text
print("prometheus:", [ln for ln in prom.splitlines()
                      if ln.startswith("repro_serving_decode_syncs ")][0])

# --- failure handling ---------------------------------------------------
# Every RequestOutput carries a finish_reason ("eos", "length", "abort",
# "deadline", "preempted_limit", "error"). deadline_ms gives a request
# a wall-clock budget (it retires with its partial tokens), and
# max_pending bounds the admission queue: past the limit submit()
# raises the typed EngineSaturated instead of queueing without bound —
# catch it, drain a round, and retry.
tiny = deploy(cfg, "int4", slots=1, max_len=16, params=params, ctx=ctx,
              max_pending=1)
b = ds.sample(1)
req = {"src_tokens": jnp.asarray(b["src_tokens"]),
       "tgt_in": jnp.asarray(b["tgt_in"][:, :1])}
sp = SamplingParams(max_new_tokens=6)
outs = []                                        # step() returns finishers
tiny.engine.submit(req, sp)                      # -> the one slot
tiny.engine.submit(req, sp)                      # -> the one queue seat
try:
    tiny.engine.submit(req, sp)                  # queue full
except EngineSaturated as exc:
    print(f"\nbackpressure: EngineSaturated "
          f"({exc.pending}/{exc.limit} pending)")
    while tiny.engine.num_pending >= exc.limit:  # retry with backoff
        outs += tiny.engine.step()
    tiny.engine.submit(req, sp)
# a microscopic deadline expires at the first round boundary: the
# request still returns, finish_reason "deadline", tokens-so-far intact
while tiny.engine.num_pending >= 1:                # free the queue seat
    outs += tiny.engine.step()
tiny.engine.submit(req, SamplingParams(max_new_tokens=6,
                                       deadline_ms=0.001))
outs += tiny.engine.run_until_drained()
print("finish reasons:", sorted(o.finish_reason for o in outs))
print(f"rejections absorbed: "
      f"{tiny.engine.metrics().admission_rejections}")

# --- scaling out a deployment ------------------------------------------
# Two composable layers (repro.cluster), both preserving token-for-token
# parity with a lone engine:
#   * tensor parallel — deploy(..., mesh=tp_mesh(K)) shards one
#     engine's weights and KV storage over K devices (GSPMD);
#   * data parallel — deploy_replicas(...) runs N independent replicas
#     behind a least-outstanding-work ReplicaRouter; requests spread by
#     priority-aware load, saturated replicas fail over, and metrics
#     merge (counters sum, percentiles from Histogram.merge).
# Everything is CPU-testable: force 8 host devices with
#   XLA_FLAGS=--xla_force_host_platform_device_count=8
# before importing jax, then mesh widths and replica counts behave as
# they would on real accelerators. On the CLI the same stack is
#   python -m repro.launch.serve --arch nllb600m --mesh dp2,tp2 \
#       --metrics-port 9100     # live GET /metrics while serving
from repro.cluster import deploy_replicas

cluster = deploy_replicas(cfg, "int4", replicas=2, params=params,
                          slots=2, max_len=16, ctx=ctx)
outs = cluster.translate(src, "ita", SamplingParams(max_new_tokens=6))
print(f"\ncluster (dp2): {[o.token_ids for o in outs]}")
cm = cluster.engine.metrics()                    # merged across replicas
print(f"cluster ttft p95 {cm.ttft_p95_ms:.1f} ms over "
      f"{[e.metrics().synced_tokens for e in cluster.engine.replicas]} "
      "tokens/replica")
