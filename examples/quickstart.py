"""Quickstart: the paper's full pipeline in ~60 lines.

Train a reduced NLLB-600M on the synthetic many-to-many translation task,
post-training-quantize it to INT4 (the paper's deployment format), and
translate the same sources into two different languages with one model.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp

from repro.configs import REGISTRY, reduce_config
from repro.data import SyntheticTranslation
from repro.models import Ctx, build_model
from repro.optim import warmup_linear
from repro.serving import SamplingParams, deploy
from repro.train import make_train_step

ctx = Ctx(compute_dtype=jnp.float32)
cfg = reduce_config(REGISTRY["nllb600m"])
model = build_model(cfg)
ds = SyntheticTranslation(cfg.vocab_size, cfg.enc_len, seed=0,
                          languages=("hin", "eng", "ita"))

# --- train ------------------------------------------------------------
STEPS = 60
init_state, step = make_train_step(
    model, lr_fn=lambda s: warmup_linear(s, peak_lr=1e-2, warmup=5,
                                         total=STEPS), ctx=ctx)
state = init_state(model.init(jax.random.PRNGKey(0)))
step = jax.jit(step)
for i in range(STEPS):
    batch = {k: jnp.asarray(v) for k, v in ds.sample(16).items()
             if not isinstance(v, str)}
    state, metrics = step(state, batch)
    if i % 10 == 0:
        print(f"step {i:3d}  loss {float(metrics['loss']):.3f}")
params = state["params"]

# --- deploy (paper: BitsAndBytes-style blockwise PTQ to INT4) ----------
pipe = deploy(cfg, "int4", slots=2, max_len=16, params=params, ctx=ctx)
print(f"\nmodel size: {pipe.fp_bytes/2**20:.2f} MB -> "
      f"{pipe.quantized_bytes/2**20:.2f} MB "
      f"({pipe.compression:.1f}x reduction; paper: 4.1x)")

# --- translate (one model, many directions: paper Fig. 2b) -------------
src = jnp.asarray(ds.sample(2)["src_tokens"])
for lang in ("ita", "hin"):
    outs = pipe.translate(src, lang, SamplingParams(max_new_tokens=6))
    print(f"-> {lang}: {[o.token_ids for o in outs]}")
