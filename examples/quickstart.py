"""Quickstart: the paper's full pipeline in ~60 lines.

Train a reduced NLLB-600M on the synthetic many-to-many translation task,
post-training-quantize it to INT4 (the paper's deployment format), and
translate the same sources into two different languages with one model.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp

from repro.configs import REGISTRY, reduce_config
from repro.core import PRESETS, quantize_tree, tree_nbytes
from repro.data import LANG_CODES, SyntheticTranslation
from repro.models import Ctx, build_model
from repro.optim import warmup_linear
from repro.serving import translate
from repro.train import make_train_step

ctx = Ctx(compute_dtype=jnp.float32)
cfg = reduce_config(REGISTRY["nllb600m"])
model = build_model(cfg)
ds = SyntheticTranslation(cfg.vocab_size, cfg.enc_len, seed=0,
                          languages=("hin", "eng", "ita"))

# --- train ------------------------------------------------------------
STEPS = 60
init_state, step = make_train_step(
    model, lr_fn=lambda s: warmup_linear(s, peak_lr=1e-2, warmup=5,
                                         total=STEPS), ctx=ctx)
state = init_state(model.init(jax.random.PRNGKey(0)))
step = jax.jit(step)
for i in range(STEPS):
    batch = {k: jnp.asarray(v) for k, v in ds.sample(16).items()
             if not isinstance(v, str)}
    state, metrics = step(state, batch)
    if i % 10 == 0:
        print(f"step {i:3d}  loss {float(metrics['loss']):.3f}")
params = state["params"]

# --- quantize (paper: BitsAndBytes-style blockwise PTQ) ----------------
fp_bytes = tree_nbytes(params)
qparams = quantize_tree(params, PRESETS["int4"])
print(f"\nmodel size: {fp_bytes/2**20:.2f} MB -> "
      f"{tree_nbytes(qparams)/2**20:.2f} MB "
      f"({fp_bytes/tree_nbytes(qparams):.1f}x reduction; paper: 4.1x)")

# --- translate (one model, many directions: paper Fig. 2b) -------------
src = jnp.asarray(ds.sample(2)["src_tokens"])
for lang in ("ita", "hin"):
    out = translate(model, ctx, qparams, src, LANG_CODES[lang], steps=6,
                    max_len=16, kv_dtype="int8")
    print(f"-> {lang}: {out.tolist()}")
