"""QLoRA finetuning (paper III): frozen 4-bit base + trainable adapters.

Quantize a base NLLB to NF4 with double quantization, attach rank-4 LoRA
adapters, finetune only the adapters on a new language pair, then merge
for export.

    PYTHONPATH=src python examples/qlora_finetune.py
"""

import jax
import jax.numpy as jnp

from repro.configs import REGISTRY, reduce_config
from repro.core import (PRESETS, attach_lora, count_adapter_params,
                        extract_adapters, merge_lora, quantize_tree,
                        tree_nbytes)
from repro.data import SyntheticTranslation
from repro.models import Ctx, build_model
from repro.train import make_qlora_step

ctx = Ctx(compute_dtype=jnp.float32)
cfg = reduce_config(REGISTRY["nllb600m"])
model = build_model(cfg)

base = model.init(jax.random.PRNGKey(0))
qbase = quantize_tree(base, PRESETS["nf4"])           # frozen 4-bit base
qbase = attach_lora(qbase, jax.random.PRNGKey(1), rank=4)
ad = extract_adapters(qbase)
print(f"base {tree_nbytes(base)/2**20:.2f} MB -> nf4 "
      f"{tree_nbytes(qbase)/2**20:.2f} MB; trainable adapter params: "
      f"{count_adapter_params(ad)}")

ds = SyntheticTranslation(cfg.vocab_size, cfg.enc_len, seed=7,
                          languages=("tam", "deu"))   # "new" pair
init_state, step = make_qlora_step(model, lr_fn=lambda s: 5e-2, ctx=ctx)
state = init_state(qbase)
step = jax.jit(step)
for i in range(40):
    b = {k: jnp.asarray(v) for k, v in ds.sample(8).items()
         if not isinstance(v, str)}
    state, metrics = step(state, qbase, b)
    if i % 8 == 0:
        print(f"step {i:3d}  loss {float(metrics['loss']):.3f}")

from repro.core import inject_adapters

tuned = inject_adapters(qbase, state["adapters"])
merged = merge_lora(tuned["encoder"]["layers"]["attn"]["wq"])
print("merged adapter into dense export weight:", merged.shape, merged.dtype)
