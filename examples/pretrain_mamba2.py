"""Pretrain an attention-free Mamba-2 LM with the production train loop:
checkpointing, auto-resume, 8-bit optimizer states, straggler watchdog.

    PYTHONPATH=src python examples/pretrain_mamba2.py
"""

import jax
import jax.numpy as jnp

from repro.configs import REGISTRY, reduce_config
from repro.data import SyntheticLM
from repro.models import Ctx, build_model
from repro.optim import warmup_cosine
from repro.train import TrainLoop, make_train_step

cfg = reduce_config(REGISTRY["mamba2-780m"])
model = build_model(cfg)
ds = SyntheticLM(cfg.vocab_size, 32, seed=0)

STEPS = 80
init_state, step = make_train_step(
    model, lr_fn=lambda s: warmup_cosine(s, peak_lr=5e-3, warmup=10,
                                         total=STEPS),
    state_bits=8,                       # blockwise-int8 Adam moments
    ctx=Ctx(compute_dtype=jnp.float32))


def batches():
    while True:
        yield {"tokens": jnp.asarray(ds.sample(8)["tokens"])}


loop = TrainLoop(jax.jit(step), "/tmp/repro_mamba2_ckpt", ckpt_every=25,
                 log_every=10)
state = init_state(model.init(jax.random.PRNGKey(0)))
state, start = loop.maybe_resume(state)
state, history = loop.run(state, batches(), STEPS, start_step=start)
print(f"loss {history[0]:.3f} -> {history[-1]:.3f}; "
      f"checkpoints in /tmp/repro_mamba2_ckpt (restart me to auto-resume)")
