"""Distribution layer: sharding rules + a real 8-device lowering (subprocess).

The in-process tests validate rule resolution on a 1-device mesh (shape
logic only); the subprocess test forces 8 host devices and actually
lowers + compiles a reduced train step and a decode step on a (4, 2)
(data, model) mesh — a miniature of the production dry-run.
"""

import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import pytest

from repro.core import PRESETS, quantize_tree
from repro.parallel.sharding import _leaf_spec


class _FakeMesh:
    shape = {"data": 4, "model": 2}
    axis_names = ("data", "model")


class _Leaf:
    def __init__(self, shape):
        self.shape = shape


@pytest.mark.parametrize("path,shape,expect", [
    ("['layers']['attn']['wq']", (48, 6144, 6144), (None, "data", "model")),
    ("['layers']['attn']['wo']", (48, 6144, 6144), (None, "model", "data")),
    ("['embedding']", (256000, 1024), ("model", "data")),
    ("['layers']['norm1_scale']", (48, 64), ()),   # no rule -> replicated
    ("['layers']['moe']['router']", (16, 64, 8), (None, None, None)),
])
def test_param_rules(path, shape, expect):
    spec = _leaf_spec(_FakeMesh(), path, _Leaf(shape), expert_axis=None)
    assert tuple(spec) == tuple(expect), (path, spec)


def test_expert_axis_no_reuse():
    spec = _leaf_spec(_FakeMesh(), "['moe']['experts']['w_gate']",
                      _Leaf((16, 64, 2048, 1408)), expert_axis="model")
    # expert dim takes "model"; the trailing ff dim must NOT reuse it
    assert tuple(spec) == (None, "model", "data", None)


def test_fsdp_scope_opt_only():
    p = "['params']['layers']['attn']['wq']"
    o = "['opt']['m']['layers']['attn']['wq']"
    sp = _leaf_spec(_FakeMesh(), p, _Leaf((48, 64, 64)), None, fsdp_scope="opt")
    so = _leaf_spec(_FakeMesh(), o, _Leaf((48, 64, 64)), None, fsdp_scope="opt")
    assert tuple(sp) == (None, None, "model")      # live params TP-only
    assert tuple(so) == (None, "data", "model")    # opt state FSDP-2D


def test_nondividing_dims_replicate():
    # vocab 51865 does not divide by 2 -> that dim replicates
    spec = _leaf_spec(_FakeMesh(), "['embedding']", _Leaf((51865, 512)), None)
    assert tuple(spec) == (None, "data")


def test_quantized_tree_shardable():
    """QTensor children resolve through the same rules (data vs scales)."""
    params = {"layers": {"attn": {"wq": jnp.ones((2, 64, 32))}}}
    qp = quantize_tree(params, PRESETS["int4"])
    from repro.parallel.sharding import param_shardings
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    sh = param_shardings(mesh, qp)
    flat = jax.tree_util.tree_flatten_with_path(sh)[0]
    keys = {jax.tree_util.keystr(k): v for k, v in flat}
    assert any(".data" in k for k in keys)
    assert any(".scales" in k for k in keys)


@pytest.mark.slow
def test_eight_device_lowering_subprocess():
    """Miniature dry-run: 8 host devices, (4,2) mesh, train + decode."""
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp
        import repro.configs.base as cb
        from repro.configs import get_config, reduce_config
        from repro.launch.dryrun import build_cell
        from repro.parallel import set_mesh

        mesh = jax.make_mesh((4, 2), ("data", "model"))
        for arch in ("internlm2-20b", "olmoe-1b-7b"):
            cfg = reduce_config(get_config(arch), d_model=64, num_layers=2,
                                num_heads=4, num_kv_heads=2, head_dim=16,
                                d_ff=96, vocab_size=256)
            cb.SHAPES["train_4k"] = cb.ShapeSpec("train_4k", 64, 8, "train")
            cb.SHAPES["decode_32k"] = cb.ShapeSpec("decode_32k", 64, 8,
                                                   "decode")
            for shp in ("train_4k", "decode_32k"):
                fn, shapes, in_sh, out_sh, donate = build_cell(
                    cfg, shp, mesh, "int4" if shp != "train_4k" else "bf16")
                with set_mesh(mesh):
                    c = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                                donate_argnums=donate).lower(*shapes).compile()
                assert c.cost_analysis() is not None
                print("OK", arch, shp)
    """)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("JAX_PLATFORMS", None)
    env["JAX_PLATFORMS"] = "cpu"
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env, timeout=900)
    assert r.returncode == 0, r.stderr[-3000:]
    assert r.stdout.count("OK") == 4
