"""Synthetic data pipeline: determinism, shapes, learnable structure."""

import numpy as np

from repro.configs import REGISTRY, reduce_config
from repro.data import LANG_CODES, SyntheticLM, SyntheticTranslation, make_batch


def test_translation_determinism():
    a = SyntheticTranslation(512, 16, seed=3).sample(4)
    b = SyntheticTranslation(512, 16, seed=3).sample(4)
    for k in ("src_tokens", "tgt_in", "tgt_out"):
        np.testing.assert_array_equal(a[k], b[k])


def test_translation_is_functional_mapping():
    """Same content + same language pair => same target (learnable task)."""
    ds = SyntheticTranslation(512, 16, seed=0, languages=("hin", "eng"))
    b = ds.sample(64)
    # bijection: content token <-> target token given the language pair
    src, tgt = b["src_tokens"][:, 1:-1].ravel(), b["tgt_out"][:, :-2].ravel()
    mapping = {}
    for s, t in zip(src, tgt):
        assert mapping.setdefault(int(s), int(t)) == int(t)


def test_language_codes_prefix():
    ds = SyntheticTranslation(512, 16, seed=1)
    b = ds.sample(4)
    assert b["tgt_in"][0, 0] == LANG_CODES[b["tgt_lang"]]
    assert b["src_tokens"][0, 0] == LANG_CODES[b["tgt_lang"]]


def test_lm_stream_has_copy_structure():
    ds = SyntheticLM(256, 64, seed=0, lag=4)
    b = ds.sample(32)
    toks = b["tokens"]
    match = (toks[:, 4:] == toks[:, :-4]).mean()
    assert match > 0.4   # ~50% copy probability by construction


def test_make_batch_matches_arch_inputs():
    for name in ("qwen2.5-14b", "whisper-base", "llava-next-mistral-7b",
                 "nllb600m"):
        rc = reduce_config(REGISTRY[name])

        class _Spec:
            seq_len = 16
            global_batch = 2
        b = make_batch(rc, _Spec, seed=0)
        if rc.family == "audio":
            assert b["frames"].shape == (2, rc.enc_len, rc.d_model)
            assert b["tgt_in"].shape == (2, 16)
        elif rc.family == "encdec":
            assert b["tgt_in"].shape == (2, 16)
        elif rc.family == "vlm":
            assert b["img_embeds"].shape[1] == rc.num_patches
        else:
            assert b["tokens"].shape == (2, 16)
        for v in b.values():
            if hasattr(v, "dtype") and v.dtype.kind == "i":
                assert v.min() >= 0 and v.max() < rc.vocab_size
