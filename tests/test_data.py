"""Synthetic data pipeline: determinism, shapes, learnable structure."""

import numpy as np
import pytest

from repro.configs import REGISTRY, reduce_config
from repro.data import (INDIC_LANGS, LANG_CODES, OVERSEAS_LANGS, SyntheticLM,
                        SyntheticTranslation, make_batch, pairs)


def test_translation_determinism():
    a = SyntheticTranslation(512, 16, seed=3).sample(4)
    b = SyntheticTranslation(512, 16, seed=3).sample(4)
    for k in ("src_tokens", "tgt_in", "tgt_out"):
        np.testing.assert_array_equal(a[k], b[k])


def test_translation_is_functional_mapping():
    """Same content + same language pair => same target (learnable task)."""
    ds = SyntheticTranslation(512, 16, seed=0, languages=("hin", "eng"))
    b = ds.sample(64)
    # bijection: content token <-> target token given the language pair
    src, tgt = b["src_tokens"][:, 1:-1].ravel(), b["tgt_out"][:, :-2].ravel()
    mapping = {}
    for s, t in zip(src, tgt):
        assert mapping.setdefault(int(s), int(t)) == int(t)


def test_language_codes_prefix():
    ds = SyntheticTranslation(512, 16, seed=1)
    b = ds.sample(4)
    assert b["tgt_in"][0, 0] == LANG_CODES[b["tgt_lang"]]
    assert b["src_tokens"][0, 0] == LANG_CODES[b["tgt_lang"]]


def test_eval_split_is_heldout_but_same_mapping():
    """eval content is disjoint from train; the translation bijection
    (the thing the model learns) is identical across splits."""
    tr = SyntheticTranslation(512, 16, seed=0, languages=("hin", "eng"))
    ev = SyntheticTranslation(512, 16, seed=0, languages=("hin", "eng"),
                              split="eval")
    bt = tr.sample(16, pair=("hin", "eng"))
    be = ev.sample(16, pair=("hin", "eng"))
    assert not np.array_equal(bt["src_tokens"], be["src_tokens"])
    mapping = {}
    for b in (bt, be):
        src = b["src_tokens"][:, 1:-1].ravel()
        tgt = b["tgt_out"][:, :-2].ravel()
        for s, t in zip(src, tgt):
            assert mapping.setdefault(int(s), int(t)) == int(t)


def test_eval_split_deterministic_and_train_unchanged():
    e1 = SyntheticTranslation(256, 12, seed=3, split="eval").sample(4)
    e2 = SyntheticTranslation(256, 12, seed=3, split="eval").sample(4)
    np.testing.assert_array_equal(e1["src_tokens"], e2["src_tokens"])
    # default split stays the historical train stream
    t1 = SyntheticTranslation(256, 12, seed=3).sample(4)
    t2 = SyntheticTranslation(256, 12, seed=3, split="train").sample(4)
    np.testing.assert_array_equal(t1["src_tokens"], t2["src_tokens"])
    with pytest.raises(ValueError, match="split"):
        SyntheticTranslation(256, 12, split="test")


def test_pair_forced_sampling():
    ds = SyntheticTranslation(512, 16, seed=0)
    b = ds.sample(4, pair=("ita", "hin"))
    assert (b["src_lang"], b["tgt_lang"]) == ("ita", "hin")
    assert b["tgt_in"][0, 0] == LANG_CODES["hin"]
    with pytest.raises(KeyError):
        ds.sample(4, pair=("hin", "deu"))    # deu not in default languages
    with pytest.raises(KeyError):
        ds.sample(4, pair=("hin_inv", "eng"))  # internal key, not a language
    with pytest.raises(ValueError):
        ds.sample(4, pair=("hin", "hin"))


def test_pairs_enumerates_bidirectional_fig9_grid():
    grid = pairs()
    assert len(grid) == 2 * len(INDIC_LANGS) * len(OVERSEAS_LANGS)
    assert ("hin", "eng") in grid and ("eng", "hin") in grid
    assert len(set(grid)) == len(grid)
    for s, t in grid:
        assert s != t and s in LANG_CODES and t in LANG_CODES
    assert pairs(("hin",), ("eng",)) == [("hin", "eng"), ("eng", "hin")]


def test_lm_stream_has_copy_structure():
    ds = SyntheticLM(256, 64, seed=0, lag=4)
    b = ds.sample(32)
    toks = b["tokens"]
    match = (toks[:, 4:] == toks[:, :-4]).mean()
    assert match > 0.4   # ~50% copy probability by construction


def test_make_batch_matches_arch_inputs():
    for name in ("qwen2.5-14b", "whisper-base", "llava-next-mistral-7b",
                 "nllb600m"):
        rc = reduce_config(REGISTRY[name])

        class _Spec:
            seq_len = 16
            global_batch = 2
        b = make_batch(rc, _Spec, seed=0)
        if rc.family == "audio":
            assert b["frames"].shape == (2, rc.enc_len, rc.d_model)
            assert b["tgt_in"].shape == (2, 16)
        elif rc.family == "encdec":
            assert b["tgt_in"].shape == (2, 16)
        elif rc.family == "vlm":
            assert b["img_embeds"].shape[1] == rc.num_patches
        else:
            assert b["tokens"].shape == (2, 16)
        for v in b.values():
            if hasattr(v, "dtype") and v.dtype.kind == "i":
                assert v.min() >= 0 and v.max() < rc.vocab_size
