"""Mamba-2 SSD: chunked dual form == naive step-by-step recurrence."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import SSMCfg
from repro.models import Ctx
from repro.models.ssm import (ssm_apply, ssm_decode_step, ssm_init,
                              ssm_init_state, ssm_naive_ref)

CTX = Ctx(compute_dtype=jnp.float32)


@pytest.mark.parametrize("chunk", [4, 8, 16])
def test_chunked_equals_naive(chunk):
    d_model = 32
    cfg = SSMCfg(state_dim=16, head_dim=8, expand=2, chunk=chunk)
    params = ssm_init(jax.random.PRNGKey(0), d_model, cfg)
    x = 0.5 * jax.random.normal(jax.random.PRNGKey(1), (2, 16, d_model))
    y_chunk = ssm_apply(CTX, params, x, d_model=d_model, ssm_cfg=cfg)
    y_naive = ssm_naive_ref(CTX, params, x, d_model=d_model, ssm_cfg=cfg)
    np.testing.assert_allclose(np.asarray(y_chunk), np.asarray(y_naive),
                               atol=2e-4, rtol=2e-3)


def test_prefill_state_continues_decode():
    """State returned by the chunked prefill continues exactly."""
    d_model = 32
    cfg = SSMCfg(state_dim=16, head_dim=8, expand=2, chunk=8)
    params = ssm_init(jax.random.PRNGKey(0), d_model, cfg)
    x = 0.5 * jax.random.normal(jax.random.PRNGKey(1), (2, 18, d_model))
    y_full = ssm_naive_ref(CTX, params, x, d_model=d_model, ssm_cfg=cfg)
    _, state = ssm_apply(CTX, params, x[:, :16], d_model=d_model, ssm_cfg=cfg,
                         return_state=True)
    state = (state[0].astype(jnp.bfloat16), state[1])
    outs = []
    for t in range(16, 18):
        y, state = ssm_decode_step(CTX, params, x[:, t:t + 1], state,
                                   d_model=d_model, ssm_cfg=cfg)
        outs.append(y)
    got = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(y_full[:, 16:18]),
                               atol=5e-3, rtol=5e-2)


def test_state_is_constant_size():
    """Attention-free: decode state does not grow with context length."""
    cfg = SSMCfg(state_dim=16, head_dim=8, expand=2, chunk=8)
    conv, h = ssm_init_state(None, 2, 32, cfg)
    assert conv.shape == (2, 3, 2 * 32 + 2 * 16)
    assert h.shape == (2, (2 * 32) // 8, 8, 16)
