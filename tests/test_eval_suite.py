"""End-to-end quality evaluation: the paper's parity claim, measured.

Trains the reduced NLLB to convergence on a 2-language synthetic task
(once per module), then drives the pair-matrix suite and quant sweep
through the real serving engine and asserts:

  * the converged bf16 deployment scores high BLEU on the *held-out*
    eval split (learning transferred, no eval-on-train contamination);
  * int8 quality lands within tolerance of bf16 (paper §IV);
  * scores are invariant to serving internals — dense vs paged KV,
    horizon 1 vs >1 — because the suite decodes only through
    `repro.serving` (the engine's equivalence guarantee, observed at
    the metric level);
  * the calibrated w8a8 arm deploys with a static activation scale;
  * the report artifact round-trips exactly.
"""

import jax
import jax.numpy as jnp
import pytest

from repro.configs import REGISTRY, reduce_config
from repro.data import SyntheticTranslation
from repro.eval import (evaluate_pairs, make_report, quant_sweep, load,
                        render_markdown, save, summarize)
from repro.models import Ctx, build_model
from repro.optim import warmup_cosine
from repro.serving import deploy
from repro.train import make_train_step

LANGS = ["hin", "eng"]
PAIRS = [("hin", "eng"), ("eng", "hin")]
N_SENT = 6
TRAIN_STEPS = 1500


def _ctx(act="bf16"):
    return Ctx(compute_dtype=jnp.float32, act_fmt=act)


@pytest.fixture(scope="module")
def trained():
    """Reduced NLLB fit to the 2-language permutation task (~BLEU 1)."""
    rc = reduce_config(REGISTRY["nllb600m"])
    model = build_model(rc)
    ds = SyntheticTranslation(rc.vocab_size, rc.enc_len, seed=0,
                              languages=LANGS)
    init_state, step = make_train_step(
        model, lr_fn=lambda s: warmup_cosine(s, peak_lr=3e-3, warmup=20,
                                             total=TRAIN_STEPS),
        ctx=_ctx())
    state = init_state(model.init(jax.random.PRNGKey(0)))
    step = jax.jit(step, donate_argnums=0)
    for _ in range(TRAIN_STEPS):
        b = ds.sample(32)
        state, _ = step(state, {k: jnp.asarray(v) for k, v in b.items()
                                if not isinstance(v, str)})
    return rc, state["params"]


@pytest.fixture(scope="module")
def sweep_rows(trained):
    rc, params = trained
    return quant_sweep(
        rc, ["bf16", "int8"], params=params, pair_list=PAIRS,
        languages=LANGS, n_sent=N_SENT, seed=0,
        deploy_kwargs={"slots": 4, "max_len": 16, "ctx": _ctx()},
        log=lambda *_: None)


def _grid(scores):
    """The quality cells of a score list (serving figures excluded)."""
    return [(s.src, s.tgt, s.bleu, s.chrf, s.token_acc, s.exact_match)
            for s in scores]


def test_converged_bf16_quality_high_on_heldout(sweep_rows):
    bf16 = sweep_rows[0]
    assert bf16.fmt == "bf16"
    assert bf16.mean_bleu > 0.8, bf16
    assert bf16.mean_chrf > 0.8, bf16
    # every requested (pair, direction) cell populated
    assert {(p.src, p.tgt) for p in bf16.pair_scores} == set(PAIRS)
    for p in bf16.pair_scores:
        assert p.n_sent == N_SENT and p.gen_tokens > 0
        assert p.ttft_p95_ms >= p.ttft_p50_ms >= 0.0


def test_int8_quality_within_tolerance_of_bf16(sweep_rows):
    bf16, int8 = sweep_rows
    assert int8.fmt == "int8"
    assert int8.bleu_delta is not None and bf16.bleu_delta is None
    assert abs(int8.bleu_delta) <= 0.15, sweep_rows
    assert abs(int8.chrf_delta) <= 0.15, sweep_rows
    # quantization actually shrank the deployed model
    assert int8.model_bytes < bf16.model_bytes
    assert int8.compression > bf16.compression


def test_scores_invariant_to_serving_internals(trained):
    """Dense/paged x horizon 1/4 must yield the identical quality grid —
    the engine equivalence guarantee observed end to end at the metric
    level (and proof the suite decodes only through repro.serving)."""
    rc, params = trained
    grids = {}
    for paged in (False, True):
        for horizon in (1, 4):
            pipe = deploy(rc, "int8", params=params, slots=4, max_len=16,
                          ctx=_ctx(), paged=paged, page_size=4,
                          horizon=horizon)
            scores = evaluate_pairs(pipe, PAIRS, n_sent=N_SENT, seed=0,
                                    languages=LANGS)
            grids[(paged, horizon)] = _grid(scores)
    base = grids[(False, 1)]
    for key, grid in grids.items():
        assert grid == base, f"{key} diverged from dense/horizon=1"


def test_w8a8_calibrated_deploy_scores(trained):
    rc, params = trained

    def calib():
        ds = SyntheticTranslation(rc.vocab_size, rc.enc_len, seed=0,
                                  languages=LANGS)
        for _ in range(3):
            b = ds.sample(8)
            yield {k: jnp.asarray(v) for k, v in b.items()
                   if not isinstance(v, str)}

    pipe = deploy(rc, "w8a8", params=params, slots=4, max_len=16,
                  ctx=_ctx("int8"), calib_batches=calib())
    scales = dict(pipe.ctx.act_scales or ())
    assert scales and all(v > 0 for v in scales.values())
    # per-site calibration: the registry distinguishes matmul sites —
    # at least two sites carry genuinely different static scales
    assert len(set(scales.values())) >= 2, scales
    agg = summarize(evaluate_pairs(pipe, PAIRS, n_sent=N_SENT, seed=0,
                                   languages=LANGS))
    assert agg["mean_bleu"] > 0.5, agg


def test_report_round_trips_and_renders(sweep_rows, tmp_path):
    report = make_report(arch="nllb600m-smoke",
                         rows=[r.as_row() for r in sweep_rows],
                         config={"pairs": ["hin-eng", "eng-hin"],
                                 "n_sent": N_SENT})
    path = tmp_path / "eval_report.json"
    save(report, str(path))
    loaded = load(path.read_text())
    assert loaded == report
    md = render_markdown(report)
    assert "| bf16 |" in md and "| int8 |" in md
    assert "per-pair chrf" in md
    with pytest.raises(TypeError):
        make_report(arch="x", rows=[{"bad": object()}])


def test_eval_requires_encdec():
    pipe = deploy("gemma3-1b", "int8", slots=1, max_len=16, smoke=True,
                  ctx=_ctx())
    with pytest.raises(TypeError, match="enc-dec"):
        evaluate_pairs(pipe, PAIRS, n_sent=1)
