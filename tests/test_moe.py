"""MoE dispatch correctness vs dense per-token loop, aux loss properties."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import Ctx
from repro.models.moe import moe_apply, moe_init

CTX = Ctx(compute_dtype=jnp.float32)


def _dense_oracle(params, x, top_k, act="silu_glu"):
    """Per-token loop: every token runs its top-k experts, no capacity."""
    B, S, d = x.shape
    xt = np.asarray(x.reshape(-1, d), np.float32)
    router = np.asarray(params["router"], np.float32)
    wg = np.asarray(params["experts"]["w_gate"], np.float32)
    wu = np.asarray(params["experts"]["w_up"], np.float32)
    wd = np.asarray(params["experts"]["w_down"], np.float32)
    logits = xt @ router
    probs = np.exp(logits - logits.max(-1, keepdims=True))
    probs /= probs.sum(-1, keepdims=True)
    out = np.zeros_like(xt)
    for t in range(xt.shape[0]):
        top = np.argsort(-probs[t])[:top_k]
        w = probs[t, top] / probs[t, top].sum()
        for e, wt in zip(top, w):
            h = xt[t] @ wg[e]
            h = (h * (1 / (1 + np.exp(-h)))) * (xt[t] @ wu[e])  # silu glu
            out[t] += wt * (h @ wd[e])
    return out.reshape(B, S, d)


def test_dispatch_matches_dense_loop_dropless():
    E, k, d, ff = 4, 2, 16, 24
    params = moe_init(jax.random.PRNGKey(0), d, ff, E, "silu_glu")
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 6, d))
    y, aux = moe_apply(CTX, params, x, top_k=k, dropless=True)
    yref = _dense_oracle(params, x, k)
    np.testing.assert_allclose(np.asarray(y), yref, atol=2e-4, rtol=1e-3)
    assert float(aux) > 0


def test_capacity_drops_are_bounded():
    """With cf=1.0 some tokens drop; outputs stay finite and norm-bounded."""
    E, k, d, ff = 4, 2, 16, 24
    params = moe_init(jax.random.PRNGKey(0), d, ff, E, "silu_glu")
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, d))
    y_drop, _ = moe_apply(CTX, params, x, top_k=k, capacity_factor=1.0)
    y_full, _ = moe_apply(CTX, params, x, top_k=k, dropless=True)
    assert bool(jnp.all(jnp.isfinite(y_drop)))
    # dropped tokens output 0 -> norm can only shrink
    assert float(jnp.linalg.norm(y_drop)) <= float(jnp.linalg.norm(y_full)) + 1e-4


def test_aux_loss_penalizes_collapse():
    """Uniform routing gives aux ~= 1; collapsed routing gives ~E."""
    E, d, ff = 4, 16, 24
    params = moe_init(jax.random.PRNGKey(0), d, ff, E, "silu_glu")
    # positive activations so a one-column router always wins -> collapse
    x = jnp.abs(jax.random.normal(jax.random.PRNGKey(1), (1, 64, d))) + 0.1
    collapsed = dict(params)
    r = np.zeros((d, E), np.float32)
    r[:, 0] = 2.0
    collapsed["router"] = jnp.asarray(r)
    _, aux_rand = moe_apply(CTX, params, x, top_k=1)
    _, aux_coll = moe_apply(CTX, collapsed, x, top_k=1)
    assert float(aux_coll) > 2.0 * float(aux_rand)
    assert float(aux_coll) == pytest.approx(E, rel=0.1)


def test_tensor_parallel_mode_same_result():
    """expert vs tensor placement is a sharding choice, not a math change."""
    E, k, d, ff = 4, 2, 16, 24
    params = moe_init(jax.random.PRNGKey(0), d, ff, E, "silu_glu")
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, d))
    y1, _ = moe_apply(CTX, params, x, top_k=k, parallel_mode="expert",
                      dropless=True)
    y2, _ = moe_apply(CTX, params, x, top_k=k, parallel_mode="tensor",
                      dropless=True)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-5)
