"""Paged decode-attention kernel vs oracle (block tables, ragged chains)."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


def _setup(B, Hkv, d, P, ps, maxp, seed=0):
    rng = np.random.default_rng(seed)
    k = jnp.asarray(rng.standard_normal((P, ps, Hkv, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((P, ps, Hkv, d)), jnp.float32)
    # disjoint chains over the pool, page 0 reserved as trash
    perm = 1 + rng.permutation(P - 1)
    tables = perm[:B * maxp].reshape(B, maxp).astype(np.int32)
    return rng, k, v, jnp.asarray(tables)


def _run_int8(B, H, Hkv, d, P, ps, maxp, lengths, seed=0):
    rng, k, v, tables = _setup(B, Hkv, d, P, ps, maxp, seed)
    q = jnp.asarray(rng.standard_normal((B, H, d)), jnp.float32)
    kc, ks = ops.quantize_kv(k)
    vc, vs = ops.quantize_kv(v)
    lens = jnp.asarray(lengths, jnp.int32)
    out = ops.paged_decode_attention(q, kc, vc, tables, lens, k_scales=ks,
                                     v_scales=vs, out_dtype=jnp.float32)
    G = H // Hkv
    orf = ref.paged_attn_ref(
        q.reshape(B, Hkv, G, d),
        jnp.transpose(kc, (0, 2, 1, 3)), jnp.transpose(ks, (0, 2, 1)),
        jnp.transpose(vc, (0, 2, 1, 3)), jnp.transpose(vs, (0, 2, 1)),
        tables, lens, d ** -0.5).reshape(B, H, d)
    return float(jnp.max(jnp.abs(out - orf)))


@pytest.mark.parametrize("H,Hkv,d", [(8, 2, 64), (4, 1, 128), (16, 16, 64),
                                     (10, 2, 64)])
def test_gqa_configs(H, Hkv, d):
    assert _run_int8(2, H, Hkv, d, 17, 16, 4, [64, 33]) < 1e-5


def test_ragged_chain_lengths():
    assert _run_int8(4, 8, 2, 64, 33, 8, 4, [32, 1, 17, 29]) < 1e-5


def test_bf16_pages_match_oracle():
    B, H, Hkv, d, P, ps, maxp = 2, 8, 2, 64, 9, 16, 4
    rng, k, v, tables = _setup(B, Hkv, d, P, ps, maxp, seed=1)
    q = jnp.asarray(rng.standard_normal((B, H, d)), jnp.float32)
    kb, vb = k.astype(jnp.bfloat16), v.astype(jnp.bfloat16)
    lens = jnp.asarray([50, 64], jnp.int32)
    out = ops.paged_decode_attention(q, kb, vb, tables, lens,
                                     out_dtype=jnp.float32)
    G = H // Hkv
    orf = ref.paged_attn_ref(
        q.reshape(B, Hkv, G, d), jnp.transpose(kb, (0, 2, 1, 3)), None,
        jnp.transpose(vb, (0, 2, 1, 3)), None, tables, lens,
        d ** -0.5).reshape(B, H, d)
    assert float(jnp.max(jnp.abs(out - orf))) < 1e-5


def test_trash_page_is_masked_out():
    """Out-of-chain table entries point at page 0; length masking must
    make its contents unobservable."""
    B, H, Hkv, d, P, ps = 1, 4, 2, 64, 5, 8
    rng = np.random.default_rng(2)
    q = jnp.asarray(rng.standard_normal((B, H, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((P, ps, Hkv, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((P, ps, Hkv, d)), jnp.float32)
    lens = jnp.asarray([ps], jnp.int32)          # only the first page valid
    tbl = jnp.asarray([[1, 0, 0, 0]], jnp.int32)

    def run(kk, vv):
        kc, ks = ops.quantize_kv(kk)
        vc, vs = ops.quantize_kv(vv)
        return ops.paged_decode_attention(q, kc, vc, tbl, lens, k_scales=ks,
                                          v_scales=vs, out_dtype=jnp.float32)

    base = run(k, v)
    poisoned = run(k.at[0].set(1e3), v.at[0].set(-1e3))
    np.testing.assert_array_equal(np.asarray(base), np.asarray(poisoned))
