"""Observability: lifecycle tracing, round-phase timing, metrics export.

The contract under test: the tracer is a pure OBSERVER — a traced
engine emits token-for-token the same streams with exactly the same
host-sync count as an untraced one, across every scheduling mode
(dense/paged, fused horizons, overlap on/off, speculative drafts,
injected faults) — while the trace itself is well-formed: one closed
request span per request, stack-discipline-clean nesting
(``Tracer.check()``), non-decreasing span stamps even under injected
clock skew, and a valid Chrome/Perfetto export. The metrics side pins
the repo-wide nearest-rank percentile (one definition shared by
``latency_percentiles``, the SLA controller, and the histogram-backed
``EngineMetrics`` columns) and the Prometheus text rendering.
"""

import dataclasses
import json
import socket
import types
import urllib.error
import urllib.request

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import REGISTRY, reduce_config
from repro.eval import report as report_mod
from repro.models import Ctx, build_model
from repro.obs import (PHASES, SCHED_TID, Histogram, MetricsServer,
                       TraceConfig, Tracer, percentile, render_prometheus)
from repro.serving import (EngineMetrics, FaultPlan, SamplingParams,
                           ServeEngine, SLATarget, deploy,
                           latency_percentiles)
from repro.serving.metrics import SLAController

CTX = Ctx(compute_dtype=jnp.float32)

P1 = np.array([[5, 6, 7, 8, 9]], np.int32)
P2 = np.array([[3, 4, 5, 6, 2]], np.int32)
P3 = np.array([[9, 8, 7, 6, 5]], np.int32)

GREEDY8 = SamplingParams(max_new_tokens=8, eos_id=-1)
SAMPLED6 = SamplingParams(temperature=0.8, top_p=0.9, max_new_tokens=6,
                          seed=7, eos_id=-1)


@pytest.fixture(scope="module")
def lm():
    rc = reduce_config(REGISTRY["gemma3-1b"])
    model = build_model(rc)
    params = model.init(jax.random.PRNGKey(0))
    return rc, model, params


def _engine(lm, **kw):
    _, model, params = lm
    kw.setdefault("slots", 2)
    kw.setdefault("max_len", 32)
    if kw.pop("paged", False):
        kw.update(paged=True, page_size=4)
        kw.setdefault("num_pages", 8)
    return ServeEngine(model, params, ctx=CTX, **kw)


def _serve(eng, prompts, sps):
    ids = [eng.submit({"tokens": p}, sp) for p, sp in zip(prompts, sps)]
    outs = {o.request_id: o for o in eng.run_until_drained()}
    return [outs[i] for i in ids]


# ---------------------------------------------------------------------------
# percentile: the one repo-wide nearest-rank definition
# ---------------------------------------------------------------------------

def test_percentile_hand_computed_pins():
    vals = list(range(1, 11))                      # 1..10
    assert percentile(vals, 0) == 1
    assert percentile(vals, 50) == 5               # rank round(.5*9)=4
    assert percentile(vals, 95) == 10              # rank round(.95*9)=9
    assert percentile(vals, 100) == 10
    assert percentile([42.0], 95) == 42.0
    assert percentile(reversed(vals), 50) == 5     # order-insensitive
    assert percentile([], 95) == 0.0               # empty -> 0, not a raise


def test_percentile_rejects_out_of_range_q():
    with pytest.raises(ValueError):
        percentile([1.0], -1)
    with pytest.raises(ValueError):
        percentile([1.0], 100.5)


def test_latency_percentiles_uses_nearest_rank():
    outs = [types.SimpleNamespace(ttft_ms=float(i), tpot_ms=float(10 * i))
            for i in range(1, 11)]
    lat = latency_percentiles(outs)
    assert lat == {"ttft_p50_ms": 5.0, "ttft_p95_ms": 10.0,
                   "tpot_p50_ms": 50.0, "tpot_p95_ms": 100.0}


def test_sla_controller_p95_matches_shared_percentile():
    """The controller's admission decisions ride on the same definition
    the latency columns report — the consolidation invariant."""
    ctl = SLAController(SLATarget(p95_ttft_ms=100.0, window=10),
                        horizon=4, slots=4)
    ctl._window = [(float(i), float(2 * i)) for i in range(1, 11)]
    assert ctl._p95(0) == percentile(range(1, 11), 95) == 10.0
    assert ctl._p95(1) == percentile(range(2, 21, 2), 95) == 20.0


# ---------------------------------------------------------------------------
# Histogram
# ---------------------------------------------------------------------------

def test_histogram_record_mean_percentile():
    h = Histogram(lo=1.0, growth=2.0, n_buckets=8)
    for v in (0.5, 1.5, 3.0, 3.0, 100.0):
        h.record(v)
    assert h.count == 5
    assert h.total == pytest.approx(108.0)
    assert h.mean == pytest.approx(108.0 / 5)
    # percentile reports the covering bucket's UPPER edge
    assert h.percentile(50.0) == 4.0               # 3.0 falls in (2, 4]
    assert h.percentile(0.0) == 1.0                # 0.5 lands in (0, 1]
    assert Histogram().percentile(95.0) == 0.0     # empty histogram


def test_histogram_overflow_clamps_to_top_edge():
    h = Histogram(lo=1.0, growth=2.0, n_buckets=4)
    h.record(1e9)                                   # beyond every bound
    assert h.count == 1
    assert h.overflow == 1
    assert h.percentile(95.0) == h.bounds[-1] == 8.0


def test_histogram_merge_and_reset():
    a, b = Histogram(), Histogram()
    a.record(1.0), a.record(2.0)
    b.record(4.0)
    assert a.merge(b) is a
    assert (a.count, a.total) == (3, 7.0)
    with pytest.raises(ValueError, match="config"):
        a.merge(Histogram(lo=0.5))
    a.reset()
    assert (a.count, a.total) == (0, 0.0)
    assert a.percentile(95.0) == 0.0


# ---------------------------------------------------------------------------
# Prometheus rendering
# ---------------------------------------------------------------------------

class _Snap:
    GAUGES = ("kv_bytes",)

    def as_dict(self):
        return {"requests": 3, "kv_bytes": 4096, "occupancy": 0.5}


def test_render_prometheus_types_and_buckets():
    h = Histogram(lo=1.0, growth=2.0, n_buckets=3)
    for v in (0.5, 1.5, 99.0):
        h.record(v)
    text = render_prometheus(_Snap(), {"ttft_ms": h}, prefix="x")
    lines = text.splitlines()
    assert "# TYPE x_requests counter" in lines      # int -> counter
    assert "# TYPE x_kv_bytes gauge" in lines        # declared gauge
    assert "# TYPE x_occupancy gauge" in lines       # float -> gauge
    assert "# TYPE x_ttft_ms histogram" in lines
    # cumulative buckets, terminated by +Inf == count
    assert 'x_ttft_ms_bucket{le="1"} 1' in lines
    assert 'x_ttft_ms_bucket{le="2"} 2' in lines
    assert 'x_ttft_ms_bucket{le="+Inf"} 3' in lines
    assert "x_ttft_ms_count 3" in lines
    assert any(ln.startswith("x_ttft_ms_sum ") for ln in lines)


# ---------------------------------------------------------------------------
# Tracer primitives
# ---------------------------------------------------------------------------

def test_tracer_balanced_spans_pass_check(tmp_path):
    tr = Tracer(TraceConfig())
    tr.name_track(1, "req 0")
    tr.begin(SCHED_TID, "round", 1.0)
    tr.complete(SCHED_TID, "dispatch", 1.0, 0.5, K=4)
    tr.begin(1, "request", 1.1)
    tr.instant(1, "decode-round", 1.2, planned=4)
    tr.end(1, "request", 1.9)
    tr.end(SCHED_TID, "round", 2.0)
    assert tr.check() == []
    chrome = tr.to_chrome()
    assert chrome["displayTimeUnit"] == "ms"
    phs = [e["ph"] for e in chrome["traceEvents"]]
    assert {"B", "E", "X", "i", "M"} <= set(phs)
    p = tmp_path / "trace.json"
    tr.dump_json(str(p))
    assert json.loads(p.read_text())["traceEvents"]


def test_tracer_check_flags_discipline_violations():
    tr = Tracer(TraceConfig())
    tr.begin(0, "round", 1.0)
    assert any("never closed" in p for p in tr.check())
    tr.end(0, "other-name", 2.0)                   # closes the wrong name
    assert any("closes" in p for p in tr.check())
    tr2 = Tracer(TraceConfig())
    tr2.end(0, "round", 1.0)                       # end with no begin
    assert any("without open span" in p for p in tr2.check())


def test_tracer_ring_drops_oldest_and_counts():
    tr = Tracer(TraceConfig(capacity=16))
    for i in range(20):
        tr.instant(0, f"e{i}", float(i))
    assert len(tr) == 16
    assert tr.dropped == 4
    names = [e.name for e in tr.events]
    assert names[0] == "e4" and names[-1] == "e19"


def test_tracer_clamps_span_stamps_against_backward_clock():
    """Negative skew must not produce end < begin (Perfetto rejects
    it); instants keep their raw stamp so the jump stays visible."""
    tr = Tracer(TraceConfig())
    tr.instant(0, "fault:skew", 3.0, ms=-7000)     # instants keep raw ts
    tr.begin(0, "round", 10.0)
    tr.end(0, "round", 5.0)                        # clock went backward
    assert tr.check() == []
    by_ph = {e.ph: e for e in tr.events}
    assert by_ph["E"].ts_us == by_ph["B"].ts_us == pytest.approx(10.0 * 1e6)
    assert by_ph["i"].ts_us == pytest.approx(3.0 * 1e6)


# ---------------------------------------------------------------------------
# flow links: s/f pairs tying a preempted request's two residencies
# ---------------------------------------------------------------------------


def test_tracer_flow_pair_passes_check_and_exports():
    tr = Tracer(TraceConfig())
    tr.begin(1, "queued", 1.0)
    fid = tr.flow_start(1, "resume", 1.0, count=1)
    tr.end(1, "queued", 2.0)
    tr.begin(1, "request", 2.0)
    tr.flow_end(1, "resume", 2.0, fid)
    tr.end(1, "request", 3.0)
    assert tr.check() == []
    chrome = [e for e in tr.to_chrome()["traceEvents"]
              if e["ph"] in ("s", "f")]
    assert [e["ph"] for e in chrome] == ["s", "f"]
    assert chrome[0]["id"] == chrome[1]["id"] == fid
    assert chrome[1]["bp"] == "e"          # bind to the enclosing slice
    assert "bp" not in chrome[0]


def test_tracer_flow_violations_flagged():
    tr = Tracer(TraceConfig())
    tr.flow_end(0, "resume", 1.0, 99)                 # f with no s
    assert any("without matching s" in p for p in tr.check())

    tr2 = Tracer(TraceConfig())
    tr2.flow_start(0, "resume", 1.0)                  # s never consumed
    assert any("never finished" in p for p in tr2.check())

    tr3 = Tracer(TraceConfig())
    fid = tr3.flow_start(0, "resume", 5.0)
    tr3.flow_end(0, "resume", 4.0, fid)               # ends before it starts
    assert any("before it starts" in p for p in tr3.check())

    tr4 = Tracer(TraceConfig())
    fid = tr4.flow_start(0, "resume", 1.0)
    tr4.flow_end(0, "other", 2.0, fid)                # name mismatch
    assert any("closes s" in p for p in tr4.check())


def test_preemption_links_residencies_with_flow(lm):
    """A preempted-and-resumed request's two slot residencies are tied
    by a ``resume`` flow pair: the Perfetto arrow from the eviction's
    re-queue to the replayed admission. One pair per round trip, all
    consumed, and the trace still passes check()."""
    eng = _engine(lm, paged=True, num_pages=5, preempt_limit=16,
                  trace=TraceConfig())
    _serve(eng, (P1, P2), (GREEDY8, GREEDY8))
    m = eng.metrics()
    assert m.preemptions >= 1 and m.resumed_requests >= 1
    starts = [e for e in eng.trace.events if e.ph == "s"]
    ends = [e for e in eng.trace.events if e.ph == "f"]
    assert len(starts) == m.preemptions == len(ends)
    assert {e.name for e in starts + ends} == {"resume"}
    assert sorted(e.flow_id for e in starts) \
        == sorted(e.flow_id for e in ends)
    assert eng.trace.check() == []


def test_flow_closed_when_preempted_request_dies_queued(lm):
    """An abort that lands while the victim sits re-queued must still
    consume its flow start (flow_end at retirement) — otherwise the
    trace leaks a dangling ``s`` and check() flags it."""
    eng = _engine(lm, paged=True, num_pages=5, preempt_limit=16,
                  trace=TraceConfig())
    r1 = eng.submit({"tokens": P1}, GREEDY8)
    r2 = eng.submit({"tokens": P2}, GREEDY8)
    for _ in range(64):
        if eng.metrics().preemptions:
            break
        eng.step()
    assert eng.metrics().preemptions >= 1
    assert eng.num_pending == 1            # the evicted younger request
    out = eng.abort(r2)
    assert out.finish_reason == "abort"
    outs = eng.run_until_drained()
    assert [o.request_id for o in outs] == [r1]
    assert eng.trace.check() == []         # no dangling flow starts


# ---------------------------------------------------------------------------
# traced == untraced: streams, syncs, and scheduling are untouched
# ---------------------------------------------------------------------------

def _run_mode(lm, trace, **kw):
    eng = _engine(lm, trace=TraceConfig() if trace else None, **kw)
    outs = _serve(eng, (P1, P2, P3), (GREEDY8, SAMPLED6, GREEDY8))
    return outs, eng


@pytest.mark.parametrize("kw", [
    dict(horizon=1),                               # dense, per-token
    dict(horizon=16),                              # dense, fused
    dict(horizon=16, paged=True),                  # paged, fused
    dict(horizon=4, overlap=False),                # serial rounds
], ids=["dense-h1", "dense-h16", "paged-h16", "no-overlap"])
def test_traced_equals_untraced(lm, kw):
    base, ref_eng = _run_mode(lm, False, **kw)
    outs, eng = _run_mode(lm, True, **kw)
    for b, g in zip(base, outs):
        assert g.token_ids == b.token_ids
        assert g.finish_reason == b.finish_reason
    assert eng.decode_syncs == ref_eng.decode_syncs
    assert eng.metrics().overlap_rounds == ref_eng.metrics().overlap_rounds
    assert eng.trace.check() == []
    spans = eng.trace.request_spans()
    assert len(spans) == 3 and all(s["closed"] for s in spans.values())


def test_traced_equals_untraced_draft_arm():
    def run(trace):
        pipe = deploy("gemma3-1b", "int8", slots=2, max_len=32, smoke=True,
                      paged=True, page_size=4, horizon=4,
                      draft_spec="wfp4a8",
                      trace=TraceConfig() if trace else None)
        outs = _serve(pipe.engine, (P1, P2), (GREEDY8, GREEDY8))
        return outs, pipe.engine

    base, ref_eng = run(False)
    outs, eng = run(True)
    assert [o.token_ids for o in outs] == [o.token_ids for o in base]
    assert eng.decode_syncs == ref_eng.decode_syncs
    assert eng.trace.check() == []
    # every verify round left its instant, stamped with the draft ledger
    verifies = [e for e in eng.trace.events if e.name == "verify"]
    assert verifies and all(
        e.args["drafted"] >= e.args["accepted"] >= 0 for e in verifies)


def test_traced_faulted_run_keeps_monotonic_spans(lm):
    """Injected clock skew jumps the engine clock mid-run: the trace
    records the fault instants on the scheduler track and every span's
    B/E stamps stay non-decreasing (floor-clamped), so the export is
    still loadable."""
    def run(trace):
        plan = FaultPlan(skew_at=[(2, 600_000.0)])
        eng = _engine(lm, slots=1,
                      trace=TraceConfig() if trace else None, faults=plan)
        dl = SamplingParams(max_new_tokens=8, eos_id=-1,
                            deadline_ms=60_000.0)
        return _serve(eng, (P1, P2), (dl, GREEDY8)), eng

    base, _ = run(False)
    outs, eng = run(True)
    assert [(o.token_ids, o.finish_reason) for o in outs] \
        == [(o.token_ids, o.finish_reason) for o in base]
    assert outs[0].finish_reason == "deadline"
    tr = eng.trace
    assert tr.check() == []
    assert any(e.name == "fault:skew" for e in tr.events)
    # the expired request's span closed with the deadline marker inside
    spans = tr.request_spans()
    assert spans[0]["closed"] and spans[0]["reason"] == "deadline"
    assert "deadline" in spans[0]["events"]
    # per-track B/E stamps never run backward, skew notwithstanding
    last = {}
    for e in tr.events:
        if e.ph in ("B", "E"):
            assert e.ts_us >= last.get(e.tid, 0.0)
            last[e.tid] = e.ts_us


def test_lifecycle_event_order_and_phase_totals(lm):
    eng = _engine(lm, paged=True, horizon=4, trace=TraceConfig())
    _serve(eng, (P1, P2), (GREEDY8, SAMPLED6))
    spans = eng.trace.request_spans()
    for rid, span in spans.items():
        names = span["events"]                     # child names, in order
        assert names[0] == "queued"
        assert names[1] == "prefill"
        assert names[-1] == "retired"
        assert "decode-round" in names
        assert span["end_us"] >= span["begin_us"]
    # the scheduler track carries round spans with phase X events inside
    sched = [e for e in eng.trace.events if e.tid == SCHED_TID]
    assert any(e.ph == "B" and e.name == "round" for e in sched)
    assert {e.name for e in sched if e.ph == "X"} <= set(PHASES)
    m = eng.metrics()
    for p in PHASES:
        assert getattr(m, f"phase_{p}_ms") >= 0.0
    assert m.phase_admit_ms > 0 and m.phase_dispatch_ms > 0
    # histogram-backed latency columns populate on retirement
    assert m.ttft_p95_ms > 0 and m.tpot_p95_ms > 0
    assert m.ttft_p50_ms <= m.ttft_p95_ms


def test_untraced_engine_reports_zero_phase_time(lm):
    """The zero-cost path: an untraced engine accumulates no phase
    time at all (the timers never run), while the always-on latency
    histograms still feed the ttft/tpot columns."""
    eng = _engine(lm, horizon=4)
    _serve(eng, (P1,), (GREEDY8,))
    assert eng.trace is None
    m = eng.metrics()
    assert all(getattr(m, f"phase_{p}_ms") == 0.0 for p in PHASES)
    assert m.ttft_p95_ms > 0 and m.tpot_p95_ms > 0


def test_engine_prometheus_export(lm):
    eng = _engine(lm, horizon=4, trace=TraceConfig())
    _serve(eng, (P1,), (GREEDY8,))
    text = eng.prometheus()
    assert "# TYPE repro_serving_decode_syncs counter" in text
    assert "# TYPE repro_serving_ttft_ms histogram" in text
    assert 'repro_serving_ttft_ms_bucket{le="+Inf"} 1' in text
    for p in PHASES:
        assert f"repro_serving_round_phase_{p}_ms_count" in text


def test_trace_config_validates_capacity():
    with pytest.raises(ValueError, match="capacity"):
        TraceConfig(capacity=4)


# ---------------------------------------------------------------------------
# live /metrics endpoint (obs.promhttp)
# ---------------------------------------------------------------------------


def test_metrics_server_serves_renderer_at_metrics_path():
    with MetricsServer(lambda: "up 1\n") as srv:
        assert srv.url == f"http://127.0.0.1:{srv.port}/metrics"
        with urllib.request.urlopen(srv.url, timeout=5) as resp:
            assert resp.status == 200
            assert resp.headers["Content-Type"].startswith("text/plain")
            assert resp.read() == b"up 1\n"
        with pytest.raises(urllib.error.HTTPError) as ei:
            urllib.request.urlopen(
                f"http://127.0.0.1:{srv.port}/other", timeout=5)
        assert ei.value.code == 404


def test_metrics_server_scrapes_live_engine(lm):
    """The renderer runs per scrape: counters served before work differ
    from counters served after — a live endpoint, not a snapshot."""
    eng = _engine(lm)
    with MetricsServer(eng.prometheus) as srv:
        def scrape():
            with urllib.request.urlopen(srv.url, timeout=5) as r:
                return r.read().decode()
        before = scrape()
        _serve(eng, (P1,), (GREEDY8,))
        after = scrape()

    def synced(text):
        line = [ln for ln in text.splitlines()
                if ln.startswith("repro_serving_synced_tokens ")]
        return float(line[0].split()[-1])

    assert synced(before) == 0
    assert synced(after) > 0


def test_metrics_server_render_failure_is_500_and_survives():
    calls = []

    def render():
        calls.append(1)
        if len(calls) == 1:
            raise RuntimeError("collector down")
        return "ok 1\n"

    with MetricsServer(render) as srv:
        with pytest.raises(urllib.error.HTTPError) as ei:
            urllib.request.urlopen(srv.url, timeout=5)
        assert ei.value.code == 500
        with urllib.request.urlopen(srv.url, timeout=5) as resp:
            assert resp.read() == b"ok 1\n"    # server outlived the error


def test_metrics_server_graceful_shutdown_frees_port():
    srv = MetricsServer(lambda: "x 0\n").start()
    port = srv.port
    urllib.request.urlopen(srv.url, timeout=5).read()
    srv.close()
    srv.close()                                # idempotent
    # the listener is gone: connections are refused, and the port
    # rebinds immediately (socket closed, not leaked to TIME_WAIT)
    with pytest.raises(OSError):
        urllib.request.urlopen(srv.url, timeout=1)
    s = socket.socket()
    s.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
    try:
        s.bind(("127.0.0.1", port))
    finally:
        s.close()


def test_metrics_snapshot_carries_histogram_fields():
    names = {f.name for f in dataclasses.fields(EngineMetrics)}
    assert {"ttft_p50_ms", "ttft_p95_ms", "tpot_p50_ms", "tpot_p95_ms",
            "phase_admit_ms", "phase_dispatch_ms", "phase_sync_ms",
            "phase_walk_ms"} <= names


# ---------------------------------------------------------------------------
# report schema v5: round_phases rides the sweep rows
# ---------------------------------------------------------------------------

def test_report_v4_upgrades_to_v5_and_round_trips():
    v4 = {"schema": 4, "kind": "repro.eval", "arch": "x", "git_rev": None,
          "config": {}, "rows": [
              {"fmt": "int8", "spec": "w8", "ttft_p95_ms": 9.0,
               "tpot_p95_ms": 2.0, "pair_scores": []}]}
    loaded = report_mod.load(json.dumps(v4))
    assert loaded["schema"] == report_mod.SCHEMA_VERSION == 5
    assert loaded["rows"][0]["round_phases"] is None   # untraced sentinel
    assert loaded["rows"][0]["ttft_p95_ms"] == 9.0     # payload preserved
    assert report_mod.load(report_mod.dump(loaded)) == loaded


def test_report_with_round_phases_round_trips():
    r = report_mod.make_report(arch="x", rows=[{
        "fmt": "int8", "spec": "w8", "mean_bleu": 1.0,
        "round_phases": {"admit_ms": 1.5, "dispatch_ms": 2.5,
                         "sync_ms": 0.1, "walk_ms": 0.4},
        "pair_scores": []}])
    assert report_mod.load(report_mod.dump(r)) == r


def test_quant_sweep_traced_records_round_phases():
    from repro.eval import quant_sweep
    rc = reduce_config(REGISTRY["nllb600m"])
    params = build_model(rc).init(jax.random.PRNGKey(0))
    rows = quant_sweep(
        rc, ["int8"], params=params, pair_list=[("hin", "eng")],
        languages=["hin", "eng"], n_sent=2,
        deploy_kwargs={"slots": 2, "max_len": 16, "ctx": CTX},
        trace=True, log=lambda *_: None)
    rp = rows[0].round_phases
    assert rp is not None
    assert set(rp) == {f"{p}_ms" for p in PHASES}
    assert rp["admit_ms"] > 0 and rp["dispatch_ms"] > 0
    # the traced column survives the report round-trip
    rep = report_mod.make_report(arch=rc.name,
                                 rows=[r.as_row() for r in rows])
    assert report_mod.load(report_mod.dump(rep)) == rep
    assert rep["rows"][0]["round_phases"] == rp
