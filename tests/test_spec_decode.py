"""Speculative decoding: quantized draft arm, exact target verification.

Covers the pure acceptance rule (accept_longest_prefix), the engine
integration (greedy spec decode must be token-for-token identical to
target-only decoding — dense and paged, EOS mid-block, temperature
fallback), paged page accounting (draft chains freed exactly once, no
leak after abort mid-flight), the drafted/accepted/rejected metrics and
reset_metrics, the eval-suite equivalence gate across dense/paged x
horizon, the report schema v2 -> v3 upgrade, and a hypothesis property
that the emitted stream never depends on the draft spec.
"""

import json
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import REGISTRY, reduce_config
from repro.eval import assert_spec_decode_equivalence, decode_token_grid
from repro.eval import report as report_mod
from repro.eval.suite import evaluate_pairs
from repro.models import Ctx, build_model
from repro.serving import (SamplingParams, ServeEngine,
                           accept_longest_prefix, build_draft_arm, deploy)

CTX = Ctx(compute_dtype=jnp.float32)


@pytest.fixture(scope="module")
def lm():
    rc = reduce_config(REGISTRY["gemma3-1b"])
    model = build_model(rc)
    params = model.init(jax.random.PRNGKey(0))
    return rc, model, params


def _draft(model, params, spec, lookahead=4):
    # uncalibrated a8 draft specs warn about dynamic act quantization —
    # expected here, not under test
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", UserWarning)
        return build_draft_arm(model, params, CTX, spec,
                               lookahead=lookahead)


def _outputs_by_id(eng, ids):
    outs = {o.request_id: o for o in eng.run_until_drained()}
    return [outs[i] for i in ids]


def _assert_equiv(base, got, tag):
    for b, g in zip(base, got):
        assert g.token_ids == b.token_ids, \
            f"{tag}: {g.token_ids} != {b.token_ids}"
        assert g.finish_reason == b.finish_reason
        assert g.num_generated == b.num_generated


# ---------------------------------------------------------------------------
# accept_longest_prefix: the pure rule
# ---------------------------------------------------------------------------

def test_accept_all_match():
    d = jnp.array([[5, 7], [6, 8], [9, 3]], jnp.int32)       # (K=3, S=2)
    out, n_emit, acc, cur = accept_longest_prefix(d, d, jnp.ones(2))
    np.testing.assert_array_equal(np.asarray(acc), [3, 3])
    np.testing.assert_array_equal(np.asarray(n_emit), [3, 3])
    np.testing.assert_array_equal(np.asarray(out), np.asarray(d))
    # new_cur is the LAST draft token — no bonus token at position K,
    # so both arms' caches stay symmetric
    np.testing.assert_array_equal(np.asarray(cur), [9, 3])


def test_first_token_reject_emits_target():
    d = jnp.array([[5], [6], [9]], jnp.int32)
    t = jnp.array([[4], [6], [9]], jnp.int32)                # diverges at 0
    out, n_emit, acc, cur = accept_longest_prefix(d, t, jnp.ones(1))
    assert int(acc[0]) == 0 and int(n_emit[0]) == 1
    # one token emitted: the target's choice at the divergence — exactly
    # what target-only decoding would have produced
    np.testing.assert_array_equal(np.asarray(out[:, 0]), [4, 0, 0])
    assert int(cur[0]) == 4


def test_mid_block_divergence():
    d = jnp.array([[5, 1], [6, 2], [9, 3], [7, 4]], jnp.int32)
    t = jnp.array([[5, 1], [6, 9], [8, 9], [1, 9]], jnp.int32)
    out, n_emit, acc, cur = accept_longest_prefix(d, t, jnp.ones(2))
    np.testing.assert_array_equal(np.asarray(acc), [2, 1])
    np.testing.assert_array_equal(np.asarray(n_emit), [3, 2])
    np.testing.assert_array_equal(np.asarray(out[:, 0]), [5, 6, 8, 0])
    np.testing.assert_array_equal(np.asarray(out[:, 1]), [1, 9, 0, 0])
    np.testing.assert_array_equal(np.asarray(cur), [8, 9])


def test_dead_slot_emits_pad():
    d = jnp.array([[5, 5], [6, 6]], jnp.int32)
    out, n_emit, acc, cur = accept_longest_prefix(
        d, d, jnp.array([1, 0]), pad_id=0)
    assert int(acc[1]) == 0
    np.testing.assert_array_equal(np.asarray(out[:, 1]), [0, 0])
    assert int(cur[1]) == 0
    # the live slot is unaffected by its dead neighbour
    np.testing.assert_array_equal(np.asarray(out[:, 0]), [5, 6])
    assert int(acc[0]) == 2


def test_draft_arm_validation(lm):
    rc, model, params = lm
    with pytest.raises(ValueError, match="lookahead"):
        _draft(model, params, "int4", lookahead=0)
    with pytest.raises(ValueError):
        _draft(model, params, "not-a-spec")


# ---------------------------------------------------------------------------
# engine integration: the greedy-equivalence invariant
# ---------------------------------------------------------------------------

def test_spec_decode_matches_target_only_dense(lm):
    rc, model, params = lm
    prompts = [jax.random.randint(jax.random.PRNGKey(i), (1, 4 + i), 0,
                                  rc.vocab_size) for i in range(3)]
    sp = SamplingParams(max_new_tokens=7)

    def run(draft):
        eng = ServeEngine(model, params, slots=2, max_len=24, ctx=CTX,
                          draft=draft)
        ids = [eng.submit({"tokens": p}, sp) for p in prompts]
        return eng, _outputs_by_id(eng, ids)

    _, base = run(None)
    eng, got = run(_draft(model, params, "w4a8kv8"))
    _assert_equiv(base, got, "w4a8kv8 draft")
    assert eng.drafted_tokens > 0 and eng.verify_calls > 0
    assert eng.accepted_tokens + eng.rejected_tokens == eng.drafted_tokens
    assert 0.0 <= eng.acceptance_rate <= 1.0
    for o in got:
        assert o.stats.drafted > 0
        assert o.stats.accepted + o.stats.rejected == o.stats.drafted


def test_spec_decode_eos_mid_block(lm):
    """EOS landing inside an accepted draft prefix must retire the slot
    at the same position and reason as target-only decode."""
    rc, model, params = lm
    p = jax.random.randint(jax.random.PRNGKey(7), (1, 5), 0, rc.vocab_size)

    def run(draft, eos=None):
        eng = ServeEngine(model, params, slots=1, max_len=24, ctx=CTX,
                          draft=draft)
        ids = [eng.submit({"tokens": p},
                          SamplingParams(max_new_tokens=8, eos_id=eos))]
        return _outputs_by_id(eng, ids)

    ref = run(None)[0]
    eos = ref.token_ids[2]              # a token the stream actually emits
    base = run(None, eos)
    assert base[0].finish_reason == "eos"
    got = run(_draft(model, params, "wfp4a8"), eos)
    _assert_equiv(base, got, "eos mid-block")


def test_spec_decode_matches_target_only_paged():
    """deploy(draft_spec=...) paged: identical streams, full page
    reclaim for BOTH arms' chains, strict allocator invariants hold."""
    def run(draft_spec):
        pipe = deploy("gemma3-1b", "int8", slots=3, max_len=32, smoke=True,
                      paged=True, page_size=4, draft_spec=draft_spec)
        cfg, eng = pipe.cfg, pipe.engine
        sp = SamplingParams(max_new_tokens=6)
        ids = [eng.submit({"tokens": jax.random.randint(
            jax.random.PRNGKey(i), (1, 5 + i), 0, cfg.vocab_size)}, sp)
            for i in range(3)]
        outs = _outputs_by_id(eng, ids)
        assert eng.allocator.pages_in_use == 0      # full reclaim
        eng.allocator.check()
        return outs

    _assert_equiv(run(None), run("w4a8kv8"), "paged w4a8kv8")


def test_spec_paged_draft_pages_freed_exactly_once():
    """Abort mid-flight with a draft arm: both chains are freed exactly
    once (the strict allocator raises on double-free), the engine keeps
    serving, and nothing leaks."""
    pipe = deploy("gemma3-1b", "int8", slots=2, max_len=32, smoke=True,
                  paged=True, page_size=4, draft_spec="w4a8kv8")
    eng = pipe.engine
    p = jax.random.randint(jax.random.PRNGKey(0), (1, 5), 0,
                           pipe.cfg.vocab_size)
    rid = eng.submit({"tokens": p}, SamplingParams(max_new_tokens=20))
    eng.step()                          # admit + at least one spec round
    assert eng.allocator.pages_in_use > 0
    out = eng.abort(rid)
    assert out.finish_reason == "abort"
    assert eng.allocator.pages_in_use == 0     # target + draft chains
    eng.allocator.check()
    assert eng.abort(rid) is None              # idempotent, no double free
    rid2 = eng.submit({"tokens": p}, SamplingParams(max_new_tokens=6))
    outs = eng.run_until_drained()
    assert [o.request_id for o in outs] == [rid2]
    assert eng.allocator.pages_in_use == 0
    eng.allocator.check()


def test_temperature_fallback_matches_target_only(lm):
    """Sampled requests run the target-only path: identical streams to a
    draft-less engine with the same seeds, and no tokens are drafted
    while any sampled slot is active."""
    rc, model, params = lm
    p1 = jax.random.randint(jax.random.PRNGKey(1), (1, 6), 0, rc.vocab_size)
    p2 = jax.random.randint(jax.random.PRNGKey(2), (1, 4), 0, rc.vocab_size)
    sp_s = SamplingParams(temperature=0.8, top_p=0.9, max_new_tokens=6,
                          seed=3)
    sp_g = SamplingParams(max_new_tokens=6)

    def run(draft):
        eng = ServeEngine(model, params, slots=2, max_len=24, ctx=CTX,
                          draft=draft)
        ids = [eng.submit({"tokens": p1}, sp_s),
               eng.submit({"tokens": p2}, sp_g)]
        return eng, _outputs_by_id(eng, ids)

    _, base = run(None)
    eng, got = run(_draft(model, params, "w4a8kv8"))
    _assert_equiv(base, got, "temperature fallback")
    # the greedy slot decoded alongside a sampled one the whole time, so
    # speculation never engaged
    assert eng.drafted_tokens == 0 and eng.verify_calls == 0


def test_spec_metrics_and_reset(lm):
    rc, model, params = lm
    eng = ServeEngine(model, params, slots=1, max_len=24, ctx=CTX,
                      draft=_draft(model, params, "int4", lookahead=3))
    p = jax.random.randint(jax.random.PRNGKey(5), (1, 4), 0, rc.vocab_size)
    eng.submit({"tokens": p}, SamplingParams(max_new_tokens=7))
    eng.run_until_drained()
    assert eng.drafted_tokens > 0
    assert eng.acceptance_rate == pytest.approx(
        eng.accepted_tokens / eng.drafted_tokens)
    assert eng.mean_accepted_per_verify == pytest.approx(
        eng.accepted_tokens / eng.verify_calls)
    eng.reset_metrics()
    assert (eng.drafted_tokens, eng.accepted_tokens, eng.rejected_tokens,
            eng.verify_calls) == (0, 0, 0, 0)
    assert eng.acceptance_rate == 0.0
    assert eng.mean_accepted_per_verify == 0.0


def test_deploy_rejects_bad_draft_spec():
    with pytest.raises(ValueError):
        deploy("gemma3-1b", "int8", slots=1, max_len=16, smoke=True,
               draft_spec="not-a-spec")


# ---------------------------------------------------------------------------
# eval-suite gate: spec grids == target-only grids, dense/paged x horizon
# ---------------------------------------------------------------------------

def test_eval_suite_spec_decode_equivalence_gate():
    pairs = [("hin", "eng"), ("eng", "hin")]
    for paged in (False, True):
        for horizon in (1, 4):
            kw = dict(slots=4, max_len=16, smoke=True, paged=paged,
                      page_size=4, horizon=horizon, ctx=CTX)
            target = deploy("nllb600m", "int8", **kw)
            spec = deploy("nllb600m", "int8", draft_spec="wfp4a8", **kw)
            assert_spec_decode_equivalence(spec, target, pairs, n_sent=2,
                                           max_new_tokens=5)
    # the grid helper itself is deterministic for a fixed pipe
    g1 = decode_token_grid(target, pairs, n_sent=2, max_new_tokens=5)
    g2 = decode_token_grid(target, pairs, n_sent=2, max_new_tokens=5)
    assert g1 == g2 and set(g1) == set(pairs)


def test_pair_scores_carry_acceptance_rate():
    pairs = [("hin", "eng")]
    spec = deploy("nllb600m", "int8", draft_spec="wfp4a8", slots=4,
                  max_len=16, smoke=True, ctx=CTX)
    target = deploy("nllb600m", "int8", slots=4, max_len=16, smoke=True,
                    ctx=CTX)
    s = evaluate_pairs(spec, pairs, n_sent=2, max_new_tokens=5)[0]
    t = evaluate_pairs(target, pairs, n_sent=2, max_new_tokens=5)[0]
    assert s.acceptance_rate is not None and 0.0 <= s.acceptance_rate <= 1.0
    assert t.acceptance_rate is None
    # quality cells are untouched by the draft arm
    assert (s.bleu, s.chrf, s.token_acc) == (t.bleu, t.chrf, t.token_acc)


# ---------------------------------------------------------------------------
# report schema v3
# ---------------------------------------------------------------------------

def _v2_report():
    return {"schema": 2, "kind": "repro.eval", "arch": "x", "git_rev": None,
            "config": {}, "rows": [{
                "fmt": "int8", "spec": "w8",
                "pair_scores": [{"src": "hin", "tgt": "eng", "bleu": 0.5}]}]}


def test_report_v2_upgrades_through_v3():
    loaded = report_mod.load(json.dumps(_v2_report()))
    assert loaded["schema"] == report_mod.SCHEMA_VERSION
    ps = loaded["rows"][0]["pair_scores"][0]
    assert ps["acceptance_rate"] is None         # target-only sentinel
    assert ps["bleu"] == 0.5                     # payload preserved
    # upgraded artifacts round-trip like native ones
    assert report_mod.load(report_mod.dump(loaded)) == loaded


def test_report_v1_upgrade_chains_to_current():
    v1 = _v2_report()
    v1["schema"] = 1
    del v1["rows"][0]["spec"]
    loaded = report_mod.load(json.dumps(v1))
    assert loaded["schema"] == report_mod.SCHEMA_VERSION
    assert loaded["rows"][0]["spec"]             # v1->v2 resolved the spec
    assert loaded["rows"][0]["pair_scores"][0]["acceptance_rate"] is None


def test_current_report_with_acceptance_round_trips():
    r = report_mod.make_report(arch="x", rows=[{
        "fmt": "int8", "spec": "w8", "mean_bleu": 1.0, "bleu_delta": None,
        "mean_chrf": 1.0, "chrf_delta": None, "model_bytes": 1,
        "compression": 1.0, "kv_cache_bytes": 1, "mean_tok_s": 1.0,
        "calibrated": False,
        "pair_scores": [{"src": "a", "tgt": "b", "chrf": 1.0,
                         "acceptance_rate": 0.42}]}])
    assert report_mod.load(report_mod.dump(r)) == r


# ---------------------------------------------------------------------------
# hypothesis: the emitted stream never depends on the draft spec
# ---------------------------------------------------------------------------

try:
    import hypothesis.strategies as st
    from hypothesis import given, settings
    _HAVE_HYPOTHESIS = True
except ImportError:                  # CI installs hypothesis; local
    _HAVE_HYPOTHESIS = False         # runs without it still cover the rest

_ENV: dict = {}


def _spec_env():
    if not _ENV:
        rc = reduce_config(REGISTRY["gemma3-1b"])
        model = build_model(rc)
        params = model.init(jax.random.PRNGKey(0))
        _ENV.update(rc=rc, model=model, params=params, engines={}, refs={})
    return _ENV


if _HAVE_HYPOTHESIS:
    _hyp_params = given(spec=st.sampled_from(["w4a8kv8", "wfp4a8", "int4"]),
                        seed=st.integers(0, 4))
    _hyp_settings = settings(max_examples=8, deadline=None)
else:
    def _params(spec="w4a8kv8", seed=1):       # one fixed example
        def deco(fn):
            def run_one():
                return fn(spec, seed)
            return run_one
        return deco

    def _identity(fn):
        return fn

    _hyp_params, _hyp_settings = _params(), _identity


@_hyp_params
@_hyp_settings
def test_output_independent_of_draft_spec(spec, seed):
    env = _spec_env()
    rc, model, params = env["rc"], env["model"], env["params"]
    p = jax.random.randint(jax.random.PRNGKey(seed), (1, 4 + seed % 3), 0,
                           rc.vocab_size)
    sp = SamplingParams(max_new_tokens=6)
    if seed not in env["refs"]:
        eng = ServeEngine(model, params, slots=1, max_len=24, ctx=CTX)
        eng.submit({"tokens": p}, sp)
        o = eng.run_until_drained()[0]
        env["refs"][seed] = (o.token_ids, o.finish_reason)
    if spec not in env["engines"]:
        env["engines"][spec] = ServeEngine(
            model, params, slots=1, max_len=24, ctx=CTX,
            draft=_draft(model, params, spec, lookahead=3))
    eng = env["engines"][spec]
    eng.submit({"tokens": p}, sp)
    o = eng.run_until_drained()[0]
    assert (o.token_ids, o.finish_reason) == env["refs"][seed], \
        f"draft_spec={spec} changed the emitted stream"
