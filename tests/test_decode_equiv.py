"""Serving correctness: prefill + decode_step == full teacher-forced forward.

The strongest system invariant — exercises KV caches (dense + int8
quantized), rolling buffers, recurrent states, cross-attention caches and
dropless-MoE decode across every architecture family.
"""

import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import REGISTRY, reduce_config
from repro.models import Ctx, build_model

CTX = Ctx(compute_dtype=jnp.float32)
B, S_FULL, S_PREF = 2, 12, 8


def _setup(name):
    rc = reduce_config(REGISTRY[name])
    if rc.moe is not None:  # large capacity: no train/serve routing drops
        rc = dataclasses.replace(
            rc, moe=dataclasses.replace(rc.moe, capacity_factor=8.0))
    model = build_model(rc)
    params = model.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S_FULL), 0,
                              rc.vocab_size)
    if rc.family == "audio":
        extra = {"frames": 0.1 * jax.random.normal(
            jax.random.PRNGKey(2), (B, rc.enc_len, rc.d_model))}
        tkey = "tgt_in"
    elif rc.family == "encdec":
        extra = {"src_tokens": jax.random.randint(
            jax.random.PRNGKey(3), (B, rc.enc_len), 0, rc.vocab_size)}
        tkey = "tgt_in"
    else:
        extra = {}
        tkey = "tokens"
    return rc, model, params, toks, extra, tkey


def _max_err(model, params, toks, extra, tkey, kv_dtype):
    full, _ = model.forward(CTX, params, {tkey: toks, **extra})
    cache = model.init_cache(B, 16, kv_dtype)
    cache, lg = model.prefill(CTX, params, cache,
                              {tkey: toks[:, :S_PREF], **extra})
    errs = [float(jnp.max(jnp.abs(lg[:, -1] - full[:, S_PREF - 1])))]
    for t in range(S_PREF, S_FULL):
        cache, lg = model.decode_step(CTX, params, toks[:, t:t + 1], cache)
        errs.append(float(jnp.max(jnp.abs(lg[:, 0] - full[:, t]))))
    return max(errs)


@pytest.mark.parametrize("arch", list(REGISTRY))
def test_decode_matches_forward(arch):
    rc, model, params, toks, extra, tkey = _setup(arch)
    kv = "bf16" if rc.family in ("ssm", "hybrid") else "f32"
    # bf16 cross-attn caches (enc-dec) round at ~1e-3 on random-init logits
    assert _max_err(model, params, toks, extra, tkey, kv) < 5e-3


@pytest.mark.parametrize("arch", ["qwen2.5-14b", "gemma3-1b", "nllb600m"])
def test_decode_with_int8_kv_cache(arch):
    """Paper technique on the KV cache: small, bounded degradation."""
    rc, model, params, toks, extra, tkey = _setup(arch)
    err = _max_err(model, params, toks, extra, tkey, "int8")
    assert err < 0.15, err   # int8 KV noise, still tracks full forward


def test_long_prompt_rolling_buffer_hybrid():
    """recurrentgemma: prompt longer than the local window stays exact."""
    rc = reduce_config(REGISTRY["recurrentgemma-9b"])
    model = build_model(rc)
    params = model.init(jax.random.PRNGKey(0))
    S = 3 * rc.local_window       # prompt >> window
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S + 2), 0,
                              rc.vocab_size)
    full, _ = model.forward(CTX, params, {"tokens": toks})
    cache = model.init_cache(B, S + 2, "bf16")
    cache, lg = model.prefill(CTX, params, cache, {"tokens": toks[:, :S]})
    errs = [float(jnp.max(jnp.abs(lg[:, -1] - full[:, S - 1])))]
    for t in range(S, S + 2):
        cache, lg = model.decode_step(CTX, params, toks[:, t:t + 1], cache)
        errs.append(float(jnp.max(jnp.abs(lg[:, 0] - full[:, t]))))
    assert max(errs) < 5e-3, errs


def test_decode_with_fp8_kv_cache():
    """fp8(e4m3)+scale KV storage tracks the full forward.

    e4m3 carries a 3-bit mantissa vs int8's ~7 effective bits under
    per-(token, head) scaling, so its logit error is ~2.4x int8's
    (measured: 0.10 vs 0.042 on this config) — bounded, not exact.
    """
    rc, model, params, toks, extra, tkey = _setup("qwen2.5-14b")
    err = _max_err(model, params, toks, extra, tkey, "fp8")
    assert err < 0.25, err


def test_grouped_remat_scan_matches_plain():
    """Two-level remat scan is a pure memory optimization: same math."""
    import jax
    import numpy as np
    from repro.models.transformer import grouped_scan

    def body(c, w):
        return jnp.tanh(c @ w), jnp.sum(c)

    ws = jax.random.normal(jax.random.PRNGKey(0), (6, 8, 8)) * 0.5
    c0 = jax.random.normal(jax.random.PRNGKey(1), (4, 8))

    def loss(c0, remat, groups):
        c, ys = grouped_scan(body, c0, ws, 6, remat=remat, groups=groups)
        return jnp.sum(c ** 2) + jnp.sum(ys), ys

    for groups in (2, 3):
        (l0, ys0), g0 = jax.value_and_grad(
            lambda c: loss(c, False, 1), has_aux=True)(c0)
        (l1, ys1), g1 = jax.value_and_grad(
            lambda c: loss(c, True, groups), has_aux=True)(c0)
        np.testing.assert_allclose(float(l0), float(l1), rtol=1e-6)
        np.testing.assert_allclose(np.asarray(ys0), np.asarray(ys1),
                                   rtol=1e-6)
        np.testing.assert_allclose(np.asarray(g0), np.asarray(g1), rtol=1e-5)
