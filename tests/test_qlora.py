"""QLoRA (paper §III): frozen quantized base + trainable adapters."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (PRESETS, QTensor, attach_lora, count_adapter_params,
                        extract_adapters, inject_adapters, merge_lora,
                        qmatmul, quantize_tree)


def _toy_qparams():
    rng = np.random.default_rng(0)
    params = {"wq": jnp.asarray(rng.standard_normal((64, 32)) * 0.1,
                                jnp.float32),
              "norm": jnp.ones((64,))}
    qp = quantize_tree(params, PRESETS["nf4"])
    return attach_lora(qp, jax.random.PRNGKey(1), rank=4, targets="wq"), params


def test_adapter_gradients_flow_base_frozen():
    qp, _ = _toy_qparams()
    x = jnp.asarray(np.random.default_rng(2).standard_normal((8, 64)),
                    jnp.float32)

    def loss(adapters):
        p = inject_adapters(qp, adapters)
        return jnp.sum(qmatmul(x, p["wq"], compute_dtype=jnp.float32) ** 2)

    ad = extract_adapters(qp)
    g = jax.grad(loss)(ad)
    # B is zero-init -> dL/dA == 0 at init, dL/dB != 0 (standard LoRA)
    assert float(jnp.abs(g["wq"]["a"]).max()) == 0.0
    assert float(jnp.abs(g["wq"]["b"]).max()) > 0.0
    # base payload is int (no grad path); scales shielded by stop_gradient
    assert count_adapter_params(ad) == 64 * 4 + 4 * 32


def test_zero_init_b_preserves_base_output():
    qp, _ = _toy_qparams()
    x = jnp.asarray(np.random.default_rng(3).standard_normal((4, 64)),
                    jnp.float32)
    y_lora = qmatmul(x, qp["wq"], compute_dtype=jnp.float32)
    no_lora = QTensor.quantize(qp["wq"].dequantize(jnp.float32), "nf4", 64)
    y_base = qmatmul(x, QTensor(
        qp["wq"].data, qp["wq"].scales, qp["wq"].scales_q,
        qp["wq"].scales_cscale, qp["wq"].scales_offset, None, None,
        fmt=qp["wq"].fmt, q_axis=qp["wq"].q_axis, shape=qp["wq"].shape,
        scales_shape=qp["wq"].scales_shape), compute_dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(y_lora), np.asarray(y_base),
                               atol=1e-5)


def test_merge_lora_exports_dense_update():
    qp, _ = _toy_qparams()
    ad = extract_adapters(qp)
    ad = jax.tree.map(lambda a: a + 0.01, ad)
    qp2 = inject_adapters(qp, ad)
    merged = merge_lora(qp2["wq"], jnp.float32)
    x = jnp.asarray(np.random.default_rng(4).standard_normal((4, 64)),
                    jnp.float32)
    y_live = qmatmul(x, qp2["wq"], compute_dtype=jnp.float32)
    y_merged = x @ merged
    np.testing.assert_allclose(np.asarray(y_live), np.asarray(y_merged),
                               atol=1e-3, rtol=1e-3)


def test_qlora_training_reduces_loss():
    """End-to-end QLoRA finetune step on a reduced NLLB (paper's setup)."""
    from repro.configs import REGISTRY, reduce_config
    from repro.data import SyntheticTranslation
    from repro.models import Ctx, build_model
    from repro.train import make_qlora_step

    rc = reduce_config(REGISTRY["nllb600m"])
    model = build_model(rc)
    params = model.init(jax.random.PRNGKey(0))
    qp = quantize_tree(params, PRESETS["nf4"])
    qp = attach_lora(qp, jax.random.PRNGKey(1), rank=4)
    init_state, step = make_qlora_step(model, lr_fn=lambda s: 5e-2,
                                       ctx=Ctx(compute_dtype=jnp.float32))
    state = init_state(qp)
    ds = SyntheticTranslation(rc.vocab_size, 12, seed=0)
    step = jax.jit(step)
    losses = []
    for i in range(12):
        b = {k: jnp.asarray(v) for k, v in ds.sample(8).items()
             if not isinstance(v, str)}
        state, metrics = step(state, qp, b)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0] - 0.1, losses
