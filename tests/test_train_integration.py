"""End-to-end training integration: losses actually decrease.

The paper's system claim is a single many-to-many NMT model driven by
target-language codes; the NLLB integration test trains the reduced model
on the synthetic permutation-translation task and checks learning across
two language directions (translation knowledge transfer, §I).
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import REGISTRY, reduce_config
from repro.data import SyntheticLM, SyntheticTranslation
from repro.models import Ctx, build_model
from repro.optim import warmup_linear
from repro.train import make_train_step

CTX = Ctx(compute_dtype=jnp.float32)


def _train(model, batches, steps, lr=3e-2, **kw):
    init_state, step = make_train_step(
        model, lr_fn=lambda s: warmup_linear(s, peak_lr=lr, warmup=5,
                                             total=steps), ctx=CTX, **kw)
    state = init_state(model.init(jax.random.PRNGKey(0)))
    step = jax.jit(step)
    losses = []
    for _ in range(steps):
        state, m = step(state, next(batches))
        losses.append(float(m["loss"]))
    return losses, state


def test_nllb_translation_loss_decreases():
    rc = reduce_config(REGISTRY["nllb600m"])
    model = build_model(rc)
    ds = SyntheticTranslation(rc.vocab_size, rc.enc_len, seed=0)

    def batches():
        while True:
            yield {k: jnp.asarray(v) for k, v in ds.sample(16).items()
                   if not isinstance(v, str)}

    # measured on this config: ~0.78 ratio at 60 steps (tiny 2+2-layer model
    # on the permutation-translation task); assert clear learning w/ margin
    losses, _ = _train(model, batches(), steps=60, lr=1e-2)
    assert losses[-1] < 0.88 * losses[0], losses[::10]


def test_lm_loss_decreases_with_microbatching_and_remat():
    rc = reduce_config(REGISTRY["qwen2.5-14b"])
    model = build_model(rc)
    ds = SyntheticLM(rc.vocab_size, 24, seed=0)

    def batches():
        while True:
            yield {"tokens": jnp.asarray(ds.sample(8)["tokens"])}

    losses, _ = _train(model, batches(), steps=25, microbatches=2, remat=True)
    assert losses[-1] < 0.85 * losses[0], losses[::5]


def test_moe_train_balances_experts():
    import dataclasses
    rc = reduce_config(REGISTRY["olmoe-1b-7b"])
    rc = dataclasses.replace(
        rc, moe=dataclasses.replace(rc.moe, aux_loss_weight=0.5))
    model = build_model(rc)
    ds = SyntheticLM(rc.vocab_size, 16, seed=0)

    def batches():
        while True:
            yield {"tokens": jnp.asarray(ds.sample(8)["tokens"])}

    init_state, step = make_train_step(model, lr_fn=lambda s: 1e-2, ctx=CTX)
    state = init_state(model.init(jax.random.PRNGKey(0)))
    step = jax.jit(step)
    auxes = []
    for _ in range(25):
        state, m = step(state, next(batches()))
        auxes.append(float(m["aux_loss"]))
    assert all(np.isfinite(auxes))
    # with a strong weight the load-balancing loss is driven DOWN toward
    # its uniform-routing floor instead of collapsing (which drives it up
    # toward E=4). The starting value depends on router init (jax-version
    # RNG: 2.67 historically, ~2.22 on the current pin), so assert the
    # trend — a clear sustained drop — rather than a fixed fraction of a
    # start point that sits at a different distance from the floor.
    assert np.mean(auxes[-5:]) < auxes[0] - 0.05, auxes[::4]
    assert max(auxes[-5:]) < auxes[0] + 0.1, auxes[::4]  # no collapse


def test_8bit_optimizer_trains():
    rc = reduce_config(REGISTRY["gemma3-1b"])
    model = build_model(rc)
    ds = SyntheticLM(rc.vocab_size, 16, seed=0)

    def batches():
        while True:
            yield {"tokens": jnp.asarray(ds.sample(8)["tokens"])}

    losses, _ = _train(model, batches(), steps=20, state_bits=8)
    assert losses[-1] < 0.9 * losses[0], losses[::4]


def test_bf16_params_with_master_weights_train():
    rc = reduce_config(REGISTRY["internlm2-20b"])
    model = build_model(rc)
    ds = SyntheticLM(rc.vocab_size, 16, seed=0)

    def batches():
        while True:
            yield {"tokens": jnp.asarray(ds.sample(8)["tokens"])}

    losses, state = _train(model, batches(), steps=20,
                           param_dtype=jnp.bfloat16)
    assert losses[-1] < 0.9 * losses[0], losses[::4]
    assert state["params"]["embedding"].dtype == jnp.bfloat16
    assert state["opt"]["master"]["embedding"].dtype == jnp.float32
