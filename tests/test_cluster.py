"""Cluster serving: replica routing, merged metrics, scale-out parity.

The subsystem's standing bar: a routed (data-parallel) or sharded
(tensor-parallel) deployment serves token-for-token the streams a lone
single-device engine serves. Fast tests cover the router's control
plane on one device — least-outstanding-work placement, priority-aware
competition counts, saturated-replica failover and the cluster-wide
``EngineSaturated`` re-raise, abort/deadline routed to the owning
replica, global id remapping, and metric merging (counters sum,
percentiles from ``Histogram.merge``, labelled Prometheus rendering).
A seeded hypothesis property checks fairness: under mixed priorities
no replica starves. The ``slow``-marked subprocess tests force 8 host
devices and drive the real parity grids: ``mesh=tp_mesh(K)`` engines
and ``deploy_replicas`` clusters vs the single-device reference, dense
and paged, horizon 1 and 16, greedy and seeded sampling.
"""

import dataclasses
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.cluster import (ReplicaRouter, deploy_replicas, parse_mesh_spec,
                           tp_mesh)
from repro.configs import REGISTRY, reduce_config
from repro.eval import assert_serving_equivalence
from repro.models import Ctx, build_model
from repro.serving import (EngineSaturated, SamplingParams, ServeEngine,
                           deploy)
from repro.serving.metrics import EngineMetrics, merge_metrics
from repro.obs import Histogram
from repro.obs.metrics import render_prometheus_labeled

CTX = Ctx(compute_dtype=jnp.float32)

P1 = np.array([[5, 6, 7, 8, 9]], np.int32)
P2 = np.array([[3, 4, 5, 6, 2]], np.int32)
P3 = np.array([[9, 8, 7, 6, 5]], np.int32)
P4 = np.array([[2, 3, 9, 1, 4]], np.int32)


@pytest.fixture(scope="module")
def lm():
    rc = reduce_config(REGISTRY["gemma3-1b"])
    model = build_model(rc)
    params = model.init(jax.random.PRNGKey(0))
    return rc, model, params


def _replicas(lm, n, **kw):
    """N engine replicas over ONE checkpoint on the default device —
    the routing control plane doesn't need device parallelism."""
    _, model, params = lm
    kw.setdefault("slots", 1)
    kw.setdefault("max_len", 32)
    return [ServeEngine(model, params, ctx=CTX, **kw) for _ in range(n)]


# ---------------------------------------------------------------------------
# mesh-spec parsing + mesh construction
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("spec,want", [
    ("dp2,tp2", (2, 2)),
    ("tp4", (1, 4)),
    ("dp3", (3, 1)),
    ("tp2,dp3", (3, 2)),          # order-free
    (" dp2 , tp2 ", (2, 2)),      # whitespace tolerated
])
def test_parse_mesh_spec(spec, want):
    assert parse_mesh_spec(spec) == want


@pytest.mark.parametrize("bad", ["dp2,dp3", "pp2", "dp", "dp0", "2"])
def test_parse_mesh_spec_rejects(bad):
    with pytest.raises(ValueError):
        parse_mesh_spec(bad)


def test_tp_mesh_shape_and_device_bound():
    m = tp_mesh(1)
    assert m.axis_names == ("model",) and m.devices.shape == (1,)
    with pytest.raises(ValueError, match="host_platform_device_count"):
        tp_mesh(len(jax.devices()) + 1)


def test_router_needs_at_least_one_replica():
    with pytest.raises(ValueError, match="at least one replica"):
        ReplicaRouter([])


# ---------------------------------------------------------------------------
# routing control plane (real engines, single device)
# ---------------------------------------------------------------------------


def test_router_spreads_load_and_remaps_ids(lm):
    """Four submits over two 1-slot replicas alternate 0,1,0,1; the
    caller sees cluster-global ids and streams identical to a lone
    engine serving the same requests."""
    eng = _replicas(lm, 1)[0]
    sps = [SamplingParams(max_new_tokens=4, seed=i) for i in range(4)]
    want = {}
    for p, sp in zip((P1, P2, P3, P4), sps):
        rid = eng.submit({"tokens": p}, sp)
        want[rid] = eng.run_until_drained()[0].token_ids

    router = ReplicaRouter(_replicas(lm, 2))
    gids = [router.submit({"tokens": p}, sp)
            for p, sp in zip((P1, P2, P3, P4), sps)]
    assert gids == [0, 1, 2, 3]
    assert [router._owner[g][0] for g in gids] == [0, 1, 0, 1]
    outs = {o.request_id: o for o in router.run_until_drained()}
    assert sorted(outs) == gids
    for i, g in enumerate(gids):
        assert outs[g].token_ids == want[i], f"request {g} diverged"
        assert outs[g].finish_reason == "length"
    # bookkeeping drained with the requests
    assert router._owner == {} and all(m == {} for m in router._local)
    assert router.num_pending == router.num_active == 0


def test_abort_routes_to_owning_replica(lm):
    router = ReplicaRouter(_replicas(lm, 2))
    sp = SamplingParams(max_new_tokens=8)
    g0 = router.submit({"tokens": P1}, sp)           # replica 0, active
    g1 = router.submit({"tokens": P2}, sp)           # replica 1, active
    g2 = router.submit({"tokens": P3}, sp)           # replica 0, queued
    assert router._owner[g2][0] == 0
    assert router.replicas[0].num_pending == 1
    out = router.abort(g2)
    assert out.request_id == g2 and out.finish_reason == "abort"
    assert out.token_ids == []                       # never reached a slot
    assert router.replicas[0].num_pending == 0       # owner took the abort
    assert router.replicas[1].num_pending == 0
    assert router.abort(999) is None                 # unknown id
    outs = router.run_until_drained()
    assert sorted(o.request_id for o in outs) == [g0, g1]
    assert router.abort(g0) is None                  # already finished


def test_saturated_replica_failover_then_cluster_raise(lm):
    """Submission skips a saturated replica for the next-least-loaded
    one; the typed error resurfaces — with cluster totals — only when
    every replica rejects. Nothing already admitted is lost."""
    r0, r1 = _replicas(lm, 1, max_pending=1)[0], \
        _replicas(lm, 1, max_pending=2)[0]
    router = ReplicaRouter([r0, r1])
    sp = SamplingParams(max_new_tokens=3)
    gids = [router.submit({"tokens": p}, sp)
            for p in (P1, P2, P3, P4)]
    # placement so far: r0=[P1 active, P3 queued], r1=[P2 active,
    # P4 queued] — alternating by competition count
    assert [router._owner[g][0] for g in gids] == [0, 1, 0, 1]
    # 5th submit ties on load, tries r0 first (index), bounces off its
    # full queue, and fails over to r1's deeper queue
    g4 = router.submit({"tokens": P1}, sp)
    assert router._owner[g4][0] == 1
    assert r0.metrics().admission_rejections == 1
    # 6th: r0 and r1 both full -> the router re-raises with summed
    # pending/limit so callers can back off on cluster capacity
    with pytest.raises(EngineSaturated) as ei:
        router.submit({"tokens": P2}, sp)
    assert ei.value.pending == 3 and ei.value.limit == 3
    outs = router.run_until_drained()
    assert sorted(o.request_id for o in outs) == gids + [g4]
    assert all(o.finish_reason == "length" for o in outs)


def test_deadline_expires_on_backlogged_replica(lm):
    """A tight-deadline request queued behind a long generation expires
    on its owning replica while the other replica's work is untouched;
    the expiry shows up in the merged cluster metrics."""
    router = ReplicaRouter(_replicas(lm, 2))
    g_long = router.submit({"tokens": P1},
                           SamplingParams(max_new_tokens=24, eos_id=-1))
    g_other = router.submit({"tokens": P2},
                            SamplingParams(max_new_tokens=4, eos_id=-1))
    g_late = router.submit({"tokens": P3},
                           SamplingParams(max_new_tokens=4, eos_id=-1,
                                          deadline_ms=1.0))
    assert router._owner[g_late][0] == 0             # behind the long run
    outs = {o.request_id: o for o in router.run_until_drained()}
    assert outs[g_late].finish_reason == "deadline"
    assert outs[g_long].finish_reason == "length"
    assert outs[g_other].finish_reason == "length"
    m = router.metrics()
    assert m.deadline_expirations == 1
    assert router.replicas[0].metrics().deadline_expirations == 1
    assert router.replicas[1].metrics().deadline_expirations == 0


def test_priority_routes_past_lower_priority_backlog(lm):
    """A high-priority request counts only >=priority work as
    competition: it routes to the replica whose backlog it outranks,
    not the emptier-looking one holding peer-priority work."""
    router = ReplicaRouter(_replicas(lm, 2, slots=2))
    lo = SamplingParams(max_new_tokens=4, priority=0)
    hi = SamplingParams(max_new_tokens=4, priority=1)
    router.submit({"tokens": P1}, lo)                # r0
    router.submit({"tokens": P2}, lo)                # r1
    router.submit({"tokens": P3}, lo)                # r0 (index tiebreak)
    g_hi = router.submit({"tokens": P4}, hi)
    # r0 carries 2 low-priority requests, r1 carries 1 — but neither
    # competes at priority 1, so the tiebreak falls through to total
    # backlog and the high-priority request lands on r1
    assert router._owner[g_hi][0] == 1
    router.run_until_drained()


def test_stream_request_unsupported_at_router(lm):
    router = ReplicaRouter(_replicas(lm, 2))
    with pytest.raises(NotImplementedError, match="on_token"):
        router.stream_request({"tokens": P1})


# ---------------------------------------------------------------------------
# fairness: no replica starves under mixed priorities (seeded property)
# ---------------------------------------------------------------------------


class _StubEngine:
    """ServeEngine stand-in for routing-policy properties: live-count
    bookkeeping only, no model, no JAX — hypothesis can afford
    thousands of submits."""

    def __init__(self, max_pending=None):
        self.max_pending = max_pending
        self.num_active = 0
        self._queue = []
        self._next = 0

    @property
    def num_pending(self):
        return len(self._queue)

    def submit(self, request, params=None, on_token=None):
        if self.max_pending is not None \
                and len(self._queue) >= self.max_pending:
            raise EngineSaturated(len(self._queue), self.max_pending)
        lid = self._next
        self._next += 1
        self._queue.append(lid)
        return lid


def _check_fairness(priorities, n_rep):
    """Mixed-priority arrival stream: every placement matches the
    policy's least-competition order, no replica starves, and a
    uniform-priority stream balances perfectly (within +-1)."""
    router = ReplicaRouter([_StubEngine() for _ in range(n_rep)])
    for i, p in enumerate(priorities):
        want = router._order(p)[0]
        gid = router.submit({"tokens": [i]},
                            SamplingParams(max_new_tokens=1, priority=p))
        assert router._owner[gid][0] == want
    loads = [e.num_pending for e in router.replicas]
    assert sum(loads) == len(priorities)
    assert min(loads) >= 1                 # len(priorities) >= n_rep: no
    #                                        replica starves, whatever the
    #                                        priority mix
    if len(set(priorities)) == 1:
        assert max(loads) - min(loads) <= 1


@pytest.mark.parametrize("priorities,n_rep", [
    ([0] * 12, 3),                             # uniform: perfect balance
    ([3, 0, 0, 0, 3, 0, 0, 0, 3], 2),          # sparse high priorities
    ([0, 1, 2, 3] * 4, 4),                     # rotating mix
    ([2, 2, 1, 0, 0, 0, 0, 3], 3),             # front-loaded urgency
])
def test_router_fairness_fixed_streams(priorities, n_rep):
    """Fixed-stream arm of the fairness property — always runs, even
    where hypothesis is unavailable."""
    _check_fairness(priorities, n_rep)


def test_router_fairness_no_replica_starves():
    """Property: under ANY mixed-priority arrival stream, placement
    follows the least-competition order and no replica starves."""
    hyp = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")

    @hyp.settings(max_examples=80, deadline=None, derandomize=True)
    @hyp.given(priorities=st.lists(st.integers(min_value=0, max_value=3),
                                   min_size=8, max_size=40),
               n_rep=st.integers(min_value=2, max_value=4))
    def check(priorities, n_rep):
        _check_fairness(priorities, n_rep)

    check()


# ---------------------------------------------------------------------------
# metric merging: counters sum, percentiles come from merged histograms
# ---------------------------------------------------------------------------


def _snap(**over):
    base = {f.name: 0 for f in dataclasses.fields(EngineMetrics)}
    base.update(over)
    return EngineMetrics(**base)


def test_merge_metrics_sums_and_reweights():
    a = _snap(decode_steps=10, synced_tokens=40, decode_syncs=10,
              preemptions=1, occupancy=0.5, kv_cache_bytes=100)
    b = _snap(decode_steps=30, synced_tokens=30, decode_syncs=10,
              preemptions=2, occupancy=0.9, kv_cache_bytes=300)
    ttft, tpot = Histogram(), Histogram()
    for v in (1.0, 2.0, 100.0):
        ttft.record(v)
        tpot.record(v / 10)
    m = merge_metrics([a, b], ttft_hist=ttft, tpot_hist=tpot)
    assert m.decode_steps == 40 and m.synced_tokens == 70
    assert m.preemptions == 3 and m.kv_cache_bytes == 400
    # ratio recomputed from summed counters, not averaged
    assert m.mean_tokens_per_sync == pytest.approx(70 / 20)
    # occupancy: decode_steps-weighted mean (pooled ratio for
    # homogeneous replicas)
    assert m.occupancy == pytest.approx((0.5 * 10 + 0.9 * 30) / 40)
    # percentiles from the merged histogram (bucket upper edges)
    assert m.ttft_p95_ms == pytest.approx(ttft.percentile(95.0))
    assert merge_metrics([a]).ttft_p95_ms == 0.0     # no hist, no claim
    with pytest.raises(ValueError, match="at least one"):
        merge_metrics([])


def test_router_merged_histograms_match_replica_sums(lm):
    router = ReplicaRouter(_replicas(lm, 2))
    for i, p in enumerate((P1, P2, P3, P4)):
        router.submit({"tokens": p}, SamplingParams(max_new_tokens=3,
                                                    seed=i))
    router.run_until_drained()
    merged = router.merged_latency_histograms()
    for name in ("ttft_ms", "tpot_ms"):
        per = [e.latency_histograms()[name] for e in router.replicas]
        assert merged[name].count == sum(h.count for h in per) == 4
        assert merged[name].counts == [
            sum(h.counts[i] for h in per)
            for i in range(merged[name].n_buckets)]
        # merging into a fresh accumulator left the sources alone
        assert all(h.count == 2 for h in per)
    m = router.metrics()
    assert m.synced_tokens == sum(
        e.metrics().synced_tokens for e in router.replicas)
    assert m.ttft_p95_ms == pytest.approx(merged["ttft_ms"].percentile(95.0),
                                          abs=1e-3)
    router.reset_metrics()
    assert router.metrics().synced_tokens == 0
    assert router.merged_latency_histograms()["ttft_ms"].count == 0


def test_cluster_prometheus_has_merged_and_labelled_sections(lm):
    router = ReplicaRouter(_replicas(lm, 2))
    router.submit({"tokens": P1}, SamplingParams(max_new_tokens=3))
    router.run_until_drained()
    text = router.prometheus()
    assert "# TYPE repro_cluster_decode_syncs counter" in text
    assert "# TYPE repro_cluster_ttft_ms histogram" in text
    for i in range(2):
        assert f'repro_cluster_replica_synced_tokens{{replica="{i}"}}' \
            in text
    # one TYPE declaration per family, however many replicas
    assert text.count(
        "# TYPE repro_cluster_replica_synced_tokens counter") == 1


def test_render_prometheus_labeled_groups_families():
    rows = [({"replica": "0"}, _snap(decode_syncs=3)),
            ({"replica": "1"}, _snap(decode_syncs=5))]
    text = render_prometheus_labeled(rows, prefix="t")
    lines = text.splitlines()
    i = lines.index("# TYPE t_decode_syncs counter")
    assert lines[i + 1] == 't_decode_syncs{replica="0"} 3'
    assert lines[i + 2] == 't_decode_syncs{replica="1"} 5'
    # gauges keep their gauge type under labels too
    assert "# TYPE t_kv_cache_bytes gauge" in text


# ---------------------------------------------------------------------------
# single-device cluster parity through the eval suite's grid gate
# ---------------------------------------------------------------------------


def test_deploy_replicas_grid_matches_single_engine():
    """deploy_replicas on one device (no meshes) must serve the eval
    suite's greedy pair grid identically to a lone deploy — the
    routed-parity gate the 8-device subprocess tests rerun sharded."""
    kwargs = dict(slots=2, max_len=16, ctx=CTX, init_seed=0, paged=True,
                  page_size=4, horizon=4)
    single = deploy("nllb600m", "int8", smoke=True, **kwargs)
    cluster = deploy_replicas("nllb600m", "int8", replicas=2, smoke=True,
                              **kwargs)
    assert isinstance(cluster.engine, ReplicaRouter)
    assert cluster.engine.max_len == single.engine.max_len
    assert_serving_equivalence(
        cluster, single, pair_list=[("hin", "eng"), ("eng", "hin")],
        label="dp2 router", n_sent=2, max_new_tokens=6)


# ---------------------------------------------------------------------------
# 8-device parity: tensor-parallel engines and routed clusters
# (subprocess: conftest pins this process to one CPU device)
# ---------------------------------------------------------------------------


def _run_forced_8dev(code: str) -> str:
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env["JAX_PLATFORMS"] = "cpu"
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, env=env, timeout=900)
    assert r.returncode == 0, f"subprocess failed:\n{r.stderr[-3000:]}"
    return r.stdout


_PARITY_PRELUDE = """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax
    import jax.numpy as jnp

    from repro.configs import REGISTRY, reduce_config
    from repro.data import SyntheticTranslation
    from repro.models import Ctx
    from repro.serving import SamplingParams, deploy

    assert len(jax.devices()) == 8, jax.devices()
    cfg = reduce_config(REGISTRY["nllb600m"])
    ctx = Ctx(compute_dtype=jnp.float32)
    ds = SyntheticTranslation(cfg.vocab_size, cfg.enc_len, seed=0,
                              languages=("hin", "eng", "ita"))
    src = jnp.asarray(ds.sample(3)["src_tokens"])
    GREEDY = SamplingParams(max_new_tokens=8)
    SAMPLED = SamplingParams(max_new_tokens=8, temperature=0.8, top_k=8,
                             seed=7)

    def grids(pipe):
        return (
            [(o.token_ids, o.finish_reason)
             for o in pipe.translate(src, "ita", GREEDY)],
            [(o.token_ids, o.finish_reason)
             for o in pipe.translate(src, "hin", SAMPLED)])

    def common(paged, K):
        return dict(slots=2, max_len=16, params=None, ctx=ctx,
                    paged=paged, page_size=4, horizon=K, init_seed=0)
"""


@pytest.mark.slow
def test_tensor_parallel_streams_match_single_device():
    """deploy(mesh=tp_mesh(K)) parity grid: dense/paged x horizon 1/16,
    greedy + seeded sampling, tp2 everywhere plus a tp4 widest case —
    token-for-token against the unmeshed single-device engine."""
    out = _run_forced_8dev(_PARITY_PRELUDE + """
    from repro.cluster import tp_mesh

    cases = 0
    for paged in (False, True):
        for K in (1, 16):
            base = deploy(cfg, "int8", **common(paged, K))
            ref = grids(base)
            widths = (2, 4) if (paged and K == 16) else (2,)
            for tp in widths:
                pipe = deploy(cfg, "int8", mesh=tp_mesh(tp),
                              **common(paged, K))
                assert grids(pipe) == ref, (paged, K, tp)
                print(f"OK paged={paged} K={K} tp={tp}")
                cases += 1
    print("CASES", cases)
    """)
    assert "CASES 5" in out


@pytest.mark.slow
def test_replica_router_streams_match_single_device():
    """deploy_replicas parity grid: dp2 routed clusters (tp1 pinned
    meshes, plus the composed dp2,tp2 stack) serve the single-device
    streams exactly, dense/paged x horizon 1/16, greedy + sampled;
    merged metrics stay consistent with per-replica sums."""
    out = _run_forced_8dev(_PARITY_PRELUDE + """
    from repro.cluster import deploy_replicas

    cases = 0
    for paged in (False, True):
        for K in (1, 16):
            base = deploy(cfg, "int8", **common(paged, K))
            ref = grids(base)
            stacks = ((2, 1), (2, 2)) if (paged and K == 16) else ((2, 1),)
            for dp, tp in stacks:
                pipe = deploy_replicas(cfg, "int8", replicas=dp, tp=tp,
                                       **common(paged, K))
                assert grids(pipe) == ref, (paged, K, dp, tp)
                router = pipe.engine
                m = router.metrics()
                per = [e.metrics() for e in router.replicas]
                assert m.synced_tokens == sum(p.synced_tokens
                                              for p in per)
                h = router.merged_latency_histograms()["ttft_ms"]
                assert h.count == sum(
                    e.latency_histograms()["ttft_ms"].count
                    for e in router.replicas)
                prom = router.prometheus()
                assert "repro_cluster_ttft_ms_bucket" in prom
                assert 'repro_cluster_replica_occupancy{replica="1"}' \
                    in prom
                print(f"OK paged={paged} K={K} dp={dp} tp={tp}")
                cases += 1
    print("CASES", cases)
    """)
    assert "CASES 5" in out
