import os

# Tests run on the single host CPU device (the 512-device override is
# dryrun.py-only, per the assignment).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax  # noqa: E402

jax.config.update("jax_enable_x64", False)
