"""Property tests for the blockwise quantization core (hypothesis)."""

import pytest

hypothesis = pytest.importorskip("hypothesis")
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings

from repro.core import QTensor, quantize_blockwise, dequantize_blockwise
from repro.core.formats import (get_format, nibble_from_signed, pack_nibbles,
                                signed_from_nibble, unpack_nibbles)
from repro.core.quantize import dequantize_scales, quantize_scales

FMTS = ["int4", "fp4", "nf4", "int8", "fp8"]
# worst-case relative block error bounds (absmax-normalized grids)
ERR_BOUND = {"int4": 1 / 7, "fp4": 0.26, "nf4": 0.18, "int8": 1 / 127,
             "fp8": 0.07}


@st.composite
def weight_case(draw):
    k = draw(st.sampled_from([16, 32, 64, 128]))
    n = draw(st.sampled_from([8, 24, 64]))
    block = draw(st.sampled_from([8, 16, 32, 0]))   # 0 -> whole-dim block
    fmt = draw(st.sampled_from(FMTS))
    seed = draw(st.integers(0, 2 ** 16))
    scale = draw(st.sampled_from([1e-3, 0.05, 1.0, 40.0]))
    return k, n, block, fmt, seed, scale


@settings(max_examples=40, deadline=None)
@given(weight_case())
def test_roundtrip_error_bound(case):
    """|dequant(quant(w)) - w| <= bound * blockwise absmax."""
    k, n, block, fmt, seed, scale = case
    w = np.random.default_rng(seed).standard_normal((k, n)).astype(np.float32)
    w *= scale
    codes, scales = quantize_blockwise(jnp.asarray(w), fmt, block, q_axis=-2)
    deq = np.asarray(dequantize_blockwise(codes, scales, fmt, q_axis=-2,
                                          out_dtype=jnp.float32))
    f = get_format(fmt)
    nb = scales.shape[-2]
    wb = w.reshape(nb, k // nb, n)
    absmax = np.abs(wb).max(axis=1, keepdims=True)
    bound = ERR_BOUND[fmt] * absmax + 1e-12
    err = np.abs(deq.reshape(nb, k // nb, n) - wb)
    assert (err <= bound + 1e-6).all(), (fmt, err.max(), bound.min())


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 2 ** 16), st.sampled_from([(6, 16), (4, 32), (2, 8, 16)]))
def test_pack_unpack_inverse(seed, shape):
    rng = np.random.default_rng(seed)
    vals = rng.integers(-8, 8, size=shape).astype(np.int8)
    nib = nibble_from_signed(jnp.asarray(vals))
    packed = pack_nibbles(nib, axis=-2)
    back = signed_from_nibble(unpack_nibbles(packed, axis=-2))
    np.testing.assert_array_equal(np.asarray(back), vals)


def test_zero_weights_roundtrip():
    w = jnp.zeros((32, 8))
    for fmt in FMTS:
        qt = QTensor.quantize(w, fmt, block_size=16)
        np.testing.assert_allclose(np.asarray(qt.dequantize(jnp.float32)), 0.0)


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2 ** 16))
def test_double_quant_scales(seed):
    scales = np.abs(np.random.default_rng(seed).standard_normal(
        (37, 11)).astype(np.float32)) * 0.1
    q = quantize_scales(jnp.asarray(scales))
    back = np.asarray(dequantize_scales(*q))
    # int8 over a mean-centred grid: 1% of the chunk dynamic range
    assert np.abs(back - scales).max() <= 0.02 * scales.max() + 1e-6


def test_scale_equivariance():
    """quant is scale-equivariant: dequant(quant(c*w)) ~= c*dequant(quant(w))."""
    w = np.random.default_rng(0).standard_normal((64, 16)).astype(np.float32)
    for fmt in ["int4", "nf4", "int8"]:
        a = QTensor.quantize(jnp.asarray(w), fmt, 16).dequantize(jnp.float32)
        b = QTensor.quantize(jnp.asarray(4.0 * w), fmt, 16).dequantize(jnp.float32)
        np.testing.assert_allclose(np.asarray(b), 4.0 * np.asarray(a),
                                   rtol=1e-5, atol=1e-5)


def test_embedding_axis_quant():
    emb = np.random.default_rng(1).standard_normal((40, 32)).astype(np.float32)
    qt = QTensor.quantize(jnp.asarray(emb), "int8", 16, q_axis=-1)
    from repro.core import embed_lookup
    ids = jnp.asarray([0, 7, 39])
    got = np.asarray(embed_lookup(qt, ids, jnp.float32))
    assert np.abs(got - emb[[0, 7, 39]]).max() < 0.02 * np.abs(emb).max()


def test_qtensor_pytree_roundtrip():
    w = jnp.asarray(np.random.default_rng(2).standard_normal((32, 16)),
                    dtype=jnp.float32)
    qt = QTensor.quantize(w, "nf4", 16, double_quant=True)
    leaves, treedef = jax.tree_util.tree_flatten(qt)
    qt2 = jax.tree_util.tree_unflatten(treedef, leaves)
    np.testing.assert_array_equal(np.asarray(qt2.dequantize(jnp.float32)),
                                  np.asarray(qt.dequantize(jnp.float32)))


def test_policy_presets_quantize_tree():
    from repro.core import PRESETS, quantize_tree, tree_nbytes
    params = {"layers": {"attn": {"wq": jnp.ones((64, 64))},
                         "norm1_scale": jnp.ones((64,))},
              "embedding": jnp.ones((128, 64))}
    base = tree_nbytes(jax.tree.map(lambda x: x.astype(jnp.float32), params))
    for name in ["int4", "fp4", "nf4", "int8", "fp8", "w8a8"]:
        qp = quantize_tree(params, PRESETS[name])
        assert tree_nbytes(qp) < base, name
        from repro.core import QTensor as QT
        assert isinstance(qp["layers"]["attn"]["wq"], QT)
        assert not isinstance(qp["layers"]["norm1_scale"], QT)
    q4 = quantize_tree(params, PRESETS["int4"])
    assert tree_nbytes(q4) < base / 3.5  # int4 weights + int8 embeddings


def test_w8a8_weights_are_per_channel_at_any_k():
    """The w8a8 integer-MAC path (qlinear._int8_path) needs ONE K-block
    of weight scales; at K > the default 64-block this only holds
    because the w8a8 preset forces per-channel quantization — blockwise
    int8 would silently fall back to dequantized matmuls and defeat
    activation calibration (deploy(calib_batches=...))."""
    from repro.core import PRESETS, quantize_tree
    params = {"layers": {"attn": {"wq": jnp.asarray(
        np.random.default_rng(0).standard_normal((1024, 64)), jnp.float32)}}}
    qt = quantize_tree(params, PRESETS["w8a8"])["layers"]["attn"]["wq"]
    assert qt.fmt == "int8"
    assert qt.block_scales().shape[-2] == 1      # int8 MAC eligibility
    q8 = quantize_tree(params, PRESETS["int8"])["layers"]["attn"]["wq"]
    assert q8.block_scales().shape[-2] == 1024 // 64  # plain int8: blockwise
