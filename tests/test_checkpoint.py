"""Checkpointing + fault tolerance: atomic publish, keep-k, resume."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (CheckpointManager, latest_step, restore_tree,
                              save_tree)


def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {"params": {"w": jnp.asarray(rng.standard_normal((8, 4)),
                                        jnp.float32),
                       "b": jnp.asarray(rng.standard_normal(4), jnp.bfloat16)},
            "opt": {"step": jnp.asarray(3, jnp.int32), "none": None}}


def test_roundtrip(tmp_path):
    t = _tree()
    save_tree(str(tmp_path), t, step=7, extra={"loss": 1.5})
    restored, step, extra = restore_tree(str(tmp_path), t)
    assert step == 7 and extra["loss"] == 1.5
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(
            np.asarray(a, dtype=np.float32), np.asarray(b, dtype=np.float32))
    assert restored["params"]["b"].dtype == np.dtype("bfloat16") or \
        str(restored["params"]["b"].dtype) == "bfloat16"


def test_latest_and_keep_k(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, async_save=False)
    t = _tree()
    for s in (1, 5, 9):
        mgr.save(t, s)
    assert mgr.latest_step() == 9
    steps = sorted(int(n.split("_")[1]) for n in os.listdir(tmp_path))
    assert steps == [5, 9]            # keep-last-2 GC


def test_async_save_then_restore(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3, async_save=True)
    t = _tree(1)
    mgr.save(t, 4)
    mgr.wait()
    restored, step, _ = mgr.restore_latest(t)
    assert step == 4
    np.testing.assert_array_equal(np.asarray(restored["params"]["w"]),
                                  np.asarray(t["params"]["w"]))


def test_no_partial_checkpoint_on_crash(tmp_path):
    """A .tmp dir (simulated crash) is never visible as a checkpoint."""
    t = _tree()
    save_tree(str(tmp_path), t, step=2)
    os.makedirs(tmp_path / "step_5.tmp")       # crashed writer leftovers
    assert latest_step(str(tmp_path)) == 2


def test_incompatible_template_rejected(tmp_path):
    save_tree(str(tmp_path), {"a": jnp.zeros(3)}, step=1)
    with pytest.raises(ValueError):
        restore_tree(str(tmp_path), {"a": jnp.zeros(3), "b": jnp.zeros(2)})


def test_train_loop_resume(tmp_path):
    """TrainLoop picks up from the latest checkpoint (elastic restart)."""
    from repro.train import TrainLoop

    def step_fn(state, batch):
        state = {"x": state["x"] + 1}
        return state, {"loss": jnp.asarray(1.0 / (1 + state["x"]))}

    batches = iter([{"t": jnp.zeros(1)}] * 100)
    loop = TrainLoop(step_fn, str(tmp_path), ckpt_every=4, log_every=100,
                     log_fn=lambda s: None)
    state, _ = loop.run({"x": jnp.asarray(0.0)}, batches, num_steps=10)
    loop.mgr.wait()
    # new loop restores
    loop2 = TrainLoop(step_fn, str(tmp_path), ckpt_every=4, log_every=100,
                      log_fn=lambda s: None)
    state2, start = loop2.maybe_resume({"x": jnp.asarray(0.0)})
    assert start == 8
    assert float(state2["x"]) == 8.0
