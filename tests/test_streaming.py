"""Streaming request API, overlapped scheduler, and SLA-aware admission.

The overlapped scheduler's contract: with ``overlap=True`` (the
default) the engine dispatches horizon N+1 from the in-flight scan's
device carry while the host walks horizon N's token block — and the
emitted streams are token-for-token identical to serial
dispatch-then-walk rounds (``overlap=False``) at any horizon, dense
and paged, through mid-stream admission and abort. Streaming delivery
(``submit(on_token=...)``, ``stream_request``, ``stream(on_round=)``)
must observe exactly the tokens the drained RequestOutput reports.

Also covered: the frozen EngineMetrics snapshot (reset_metrics zeroes
every non-gauge field — asserted by dataclass introspection, so a new
counter can't dodge the reset), the SLAController retune policy, and
the report schema v3 -> v4 upgrade (per-format ttft/tpot columns).
"""

import dataclasses
import json
import types

import jax
import jax.numpy as jnp
import pytest

from repro.configs import REGISTRY, reduce_config
from repro.eval import report as report_mod
from repro.models import Ctx, build_model
from repro.serving import (EngineMetrics, SamplingParams, ServeEngine,
                           SLATarget, TraceConfig, deploy, greedy_generate,
                           translate)
from repro.serving.metrics import SLAController

CTX = Ctx(compute_dtype=jnp.float32)


def _lm(name="gemma3-1b"):
    rc = reduce_config(REGISTRY[name])
    model = build_model(rc)
    params = model.init(jax.random.PRNGKey(0))
    return rc, model, params


def _prompts(rc, n=2):
    return [jax.random.randint(jax.random.PRNGKey(i + 1), (1, 4 + 2 * i),
                               0, rc.vocab_size) for i in range(n)]


# ---------------------------------------------------------------------------
# overlapped == serial equivalence
# ---------------------------------------------------------------------------

def _drain_by_id(eng, ids):
    outs = {o.request_id: o for o in eng.run_until_drained()}
    return [outs[i] for i in ids]


def test_overlap_equivalence_dense_mixed_params():
    """Overlapped dispatch must not change a single token: greedy and
    seeded top-p slots, plus a request admitted mid-stream."""
    rc, model, params = _lm()
    p1, p2 = _prompts(rc)
    sp_g = SamplingParams(max_new_tokens=9)
    sp_s = SamplingParams(temperature=0.8, top_p=0.9, max_new_tokens=7,
                          seed=3)

    def run(overlap, K):
        eng = ServeEngine(model, params, slots=2, max_len=24, ctx=CTX,
                          horizon=K, overlap=overlap)
        ids = [eng.submit({"tokens": p1}, sp_g)]
        early = eng.step()               # first horizon in flight
        ids.append(eng.submit({"tokens": p2}, sp_s))
        outs = {o.request_id: o for o in early + eng.run_until_drained()}
        return [outs[i] for i in ids], eng

    base, serial = run(False, 4)
    assert serial.overlap_rounds == 0    # serial engine never runs ahead
    for K in (4, 8):
        got, eng = run(True, K)
        for b, g in zip(base if K == 4 else run(False, K)[0], got):
            assert g.token_ids == b.token_ids, K
            assert g.finish_reason == b.finish_reason
        if K == 4:
            # 8 decode tokens across 4-step blocks: some round must
            # have dispatched ahead (at K=8 the budget fits one block,
            # so there is legitimately nothing to run ahead of)
            assert eng.overlap_rounds > 0, \
                "no round overlapped host walk with dispatch"


def test_overlap_equivalence_paged():
    """Paged engine: overlapped and serial rounds emit the same streams
    and both reclaim every page."""
    def run(overlap):
        pipe = deploy("gemma3-1b", "int8", slots=2, max_len=32, smoke=True,
                      paged=True, page_size=4, horizon=4, overlap=overlap)
        eng = pipe.engine
        p1, p2 = _prompts(pipe.cfg)
        ids = [eng.submit({"tokens": p1}, SamplingParams(max_new_tokens=8)),
               eng.submit({"tokens": p2},
                          SamplingParams(temperature=0.7, top_k=8,
                                         max_new_tokens=6, seed=11))]
        outs = _drain_by_id(eng, ids)
        assert eng.allocator.pages_in_use == 0
        return outs, eng

    base, _ = run(False)
    got, eng = run(True)
    for b, g in zip(base, got):
        assert g.token_ids == b.token_ids
        assert g.finish_reason == b.finish_reason
    assert eng.overlap_rounds > 0


def test_overlap_sync_counts_match_serial():
    """Dispatch-ahead must not skew the sync ledger: a dead ahead-block
    is dropped without a host sync, so overlapped and serial engines
    report identical decode_syncs for the same work."""
    rc, model, params = _lm()
    p = _prompts(rc, 1)[0]
    sp = SamplingParams(max_new_tokens=9)    # 1 prefill + 8 decode

    def syncs(overlap):
        eng = ServeEngine(model, params, slots=1, max_len=16, ctx=CTX,
                          horizon=4, overlap=overlap)
        eng.submit({"tokens": p}, sp)
        eng.run_until_drained()
        return eng.decode_syncs

    assert syncs(True) == syncs(False) == 2


def test_draft_arm_disables_overlap():
    """Speculative rounds are host decision points: a draft-armed
    engine streams through the same API but never dispatches ahead,
    and its tokens still match the target-only engine."""
    kw = dict(slots=1, max_len=32, smoke=True)
    target = deploy("gemma3-1b", "int8", **kw)
    spec = deploy("gemma3-1b", "int8", draft_spec="wfp4a8",
                  draft_lookahead=4, **kw)
    p = _prompts(target.cfg, 1)[0]
    sp = SamplingParams(max_new_tokens=8)
    ref = target.generate([p[0]], sp)[0]
    out = spec.generate([p[0]], sp)[0]
    assert out.token_ids == ref.token_ids
    assert spec.engine.metrics().overlap_rounds == 0
    assert spec.engine.metrics().verify_calls > 0


# ---------------------------------------------------------------------------
# streaming delivery
# ---------------------------------------------------------------------------

def test_on_token_callback_sees_every_token():
    rc, model, params = _lm()
    eng = ServeEngine(model, params, slots=1, max_len=24, ctx=CTX,
                      horizon=4)
    p = _prompts(rc, 1)[0]
    live = []
    rid = eng.submit({"tokens": p}, SamplingParams(max_new_tokens=7),
                     on_token=live.append)
    out = _drain_by_id(eng, [rid])[0]
    assert live == out.token_ids
    assert out.ttft_ms > 0.0
    assert out.tpot_ms > 0.0
    # TTFT is part of the total span, never larger than it
    assert out.stats.ttft_s <= out.stats.total_s


def test_stream_request_tokens_match_drained_output():
    """stream_request yields exactly the finished output's token list,
    returns the RequestOutput via StopIteration.value, and other
    in-flight requests stay claimable afterwards."""
    rc, model, params = _lm()
    p1, p2 = _prompts(rc)
    sp = SamplingParams(max_new_tokens=6)

    ref_eng = ServeEngine(model, params, slots=2, max_len=24, ctx=CTX,
                          horizon=4)
    ids = [ref_eng.submit({"tokens": p1}, sp),
           ref_eng.submit({"tokens": p2}, sp)]
    refs = _drain_by_id(ref_eng, ids)

    eng = ServeEngine(model, params, slots=2, max_len=24, ctx=CTX,
                      horizon=4)
    other = eng.submit({"tokens": p2}, sp)
    gen = eng.stream_request({"tokens": p1}, sp)
    toks = []
    while True:
        try:
            toks.append(next(gen))
        except StopIteration as fin:
            out = fin.value
            break
    assert toks == out.token_ids == refs[0].token_ids
    assert out.finish_reason == refs[0].finish_reason
    rest = eng.run_until_drained()
    assert [o.request_id for o in rest] == [other]
    assert rest[0].token_ids == refs[1].token_ids


def test_stream_yields_per_finish_and_on_round_admission():
    """stream() yields each output as its request retires; arrivals
    submitted from the on_round callback keep the loop alive (the
    bench_serving Poisson driver's contract)."""
    rc, model, params = _lm()
    eng = ServeEngine(model, params, slots=2, max_len=24, ctx=CTX,
                      horizon=4)
    p1, p2 = _prompts(rc)
    sp = SamplingParams(max_new_tokens=5)
    ids = [eng.submit({"tokens": p1}, sp)]

    def on_round():
        if len(ids) == 1:
            ids.append(eng.submit({"tokens": p2}, sp))

    outs = list(eng.stream(on_round=on_round))
    assert sorted(o.request_id for o in outs) == sorted(ids)
    assert len(ids) == 2                 # the callback really admitted
    # a drained engine exits before the first round: no yields, no calls
    calls = []
    assert list(eng.stream(on_round=lambda: calls.append(1))) == []
    assert calls == []


def test_abort_from_own_on_token_callback():
    """A request may abort itself from its streaming callback mid-walk:
    tokens truncate at the callback's position, abort() hands the
    output to the callback's caller, and the engine keeps serving."""
    rc, model, params = _lm()
    eng = ServeEngine(model, params, slots=1, max_len=32, ctx=CTX,
                      horizon=4)
    p = _prompts(rc, 1)[0]
    seen, got = [], []

    def cb(tok):
        seen.append(tok)
        if len(seen) == 3:
            got.append(eng.abort(rid))

    rid = eng.submit({"tokens": p}, SamplingParams(max_new_tokens=16),
                     on_token=cb)
    assert eng.run_until_drained() == []     # abort() returned the output
    out = got[0]
    assert out.finish_reason == "abort"
    assert out.token_ids == seen and len(seen) == 3
    assert out.stats.new_tokens == 3
    rid2 = eng.submit({"tokens": p}, SamplingParams(max_new_tokens=4))
    outs = eng.run_until_drained()
    assert [o.request_id for o in outs] == [rid2]
    assert outs[0].num_generated == 4


# ---------------------------------------------------------------------------
# EngineMetrics
# ---------------------------------------------------------------------------

def test_metrics_snapshot_is_complete_and_frozen():
    rc, model, params = _lm()
    eng = ServeEngine(model, params, slots=1, max_len=16, ctx=CTX,
                      horizon=4)
    eng.submit({"tokens": _prompts(rc, 1)[0]},
               SamplingParams(max_new_tokens=9))
    eng.run_until_drained()
    m = eng.metrics()
    assert isinstance(m, EngineMetrics)
    assert m.decode_syncs == eng.decode_syncs > 0
    assert m.synced_tokens > 0 and m.occupancy > 0
    assert m.overlap_rounds == eng.overlap_rounds > 0
    with pytest.raises(dataclasses.FrozenInstanceError):
        m.decode_syncs = 0
    assert set(m.as_dict()) == {f.name
                                for f in dataclasses.fields(EngineMetrics)}


def test_reset_metrics_zeroes_every_non_gauge_field():
    """Introspective reset check: any counter added to EngineMetrics
    without joining the reset (or declaring itself a gauge) fails here."""
    rc, model, params = _lm()
    eng = ServeEngine(model, params, slots=1, max_len=16, ctx=CTX,
                      horizon=4)
    eng.submit({"tokens": _prompts(rc, 1)[0]},
               SamplingParams(max_new_tokens=9))
    eng.run_until_drained()
    eng.reset_metrics()
    m = eng.metrics()
    for f in dataclasses.fields(EngineMetrics):
        if f.name not in EngineMetrics.GAUGES:
            assert getattr(m, f.name) == 0, \
                f"{f.name} survived reset_metrics()"
    # gauges reflect live engine state, not accumulation
    assert m.kv_cache_bytes > 0


def test_reset_metrics_zeroes_traced_histograms():
    """The introspective test above guarantees the EngineMetrics fields
    zero; this pins the backing accumulators actually RECORDING under
    tracing first — a reset test over fields that never moved proves
    nothing."""
    rc, model, params = _lm()
    eng = ServeEngine(model, params, slots=1, max_len=16, ctx=CTX,
                      horizon=4, trace=TraceConfig())
    eng.submit({"tokens": _prompts(rc, 1)[0]},
               SamplingParams(max_new_tokens=9))
    eng.run_until_drained()
    m = eng.metrics()
    assert m.ttft_p50_ms > 0 and m.ttft_p95_ms > 0
    assert m.tpot_p50_ms > 0 and m.tpot_p95_ms > 0
    assert m.phase_admit_ms > 0 and m.phase_dispatch_ms > 0
    assert m.phase_walk_ms > 0
    eng.reset_metrics()
    m = eng.metrics()
    for name in ("ttft_p50_ms", "ttft_p95_ms", "tpot_p50_ms",
                 "tpot_p95_ms", "phase_admit_ms", "phase_dispatch_ms",
                 "phase_sync_ms", "phase_walk_ms"):
        assert getattr(m, name) == 0.0, f"{name} survived reset_metrics()"


# ---------------------------------------------------------------------------
# SLA-aware admission
# ---------------------------------------------------------------------------

def _obs(ttft_ms, tpot_ms):
    return types.SimpleNamespace(ttft_ms=ttft_ms, tpot_ms=tpot_ms)


def test_sla_target_validation():
    with pytest.raises(ValueError, match="constrains nothing"):
        SLATarget()
    with pytest.raises(ValueError, match="positive"):
        SLATarget(p95_ttft_ms=-1)
    with pytest.raises(ValueError, match="window"):
        SLATarget(p95_ttft_ms=10, window=0)
    with pytest.raises(ValueError, match="max_horizon"):
        SLATarget(p95_ttft_ms=10, min_horizon=4, max_horizon=2)


def test_sla_controller_ttft_breach_halves_admission_knobs():
    c = SLAController(SLATarget(p95_ttft_ms=10.0, window=4),
                      horizon=8, slots=4)
    assert c.holding() is None           # no full window yet
    for _ in range(3):
        assert not c.observe(_obs(100.0, 1.0))
    assert c.retunes == 0 and c.horizon == 8
    assert c.observe(_obs(100.0, 1.0))   # window full -> retune fires
    assert (c.horizon, c.prefill_cap, c.retunes) == (4, 2, 1)
    assert c.holding() is False


def test_sla_controller_tpot_breach_doubles_horizon():
    c = SLAController(SLATarget(p95_tpot_ms=1.0, window=2, max_horizon=16),
                      horizon=4, slots=2)
    for _ in range(2):
        c.observe(_obs(0.0, 50.0))
    assert c.horizon == 8                # longer scans amortize syncs
    for _ in range(2):
        c.observe(_obs(0.0, 50.0))
    for _ in range(2):
        c.observe(_obs(0.0, 50.0))
    assert c.horizon == 16               # clamped at max_horizon
    assert c.holding() is False


def test_sla_controller_relaxes_toward_deploy_config():
    c = SLAController(SLATarget(p95_ttft_ms=10.0, p95_tpot_ms=100.0,
                                window=1), horizon=8, slots=4)
    c.observe(_obs(50.0, 1.0))           # breach: 8/4 -> 4/2
    assert (c.horizon, c.prefill_cap) == (4, 2)
    c.observe(_obs(1.0, 1.0))            # good window: horizon first
    assert (c.horizon, c.prefill_cap) == (8, 2)
    c.observe(_obs(1.0, 1.0))            # then the prefill cap
    assert (c.horizon, c.prefill_cap) == (8, 4)
    assert c.holding() is True
    retunes = c.retunes
    c.observe(_obs(1.0, 1.0))            # at deploy config: no-op
    assert c.retunes == retunes


def test_deploy_sla_attaches_controller_and_serves():
    pipe = deploy("gemma3-1b", "int8", slots=2, max_len=16, smoke=True,
                  horizon=4,
                  sla=SLATarget(p95_ttft_ms=60_000.0, p95_tpot_ms=60_000.0,
                                window=2))
    eng = pipe.engine
    assert eng.sla is not None and eng.sla.horizon == 4
    outs = pipe.generate([p[0] for p in _prompts(pipe.cfg)],
                         SamplingParams(max_new_tokens=6))
    assert all(o.num_generated == 6 for o in outs)
    # two retirements filled the window: the controller has observed
    assert eng.sla.windows >= 1
    assert eng.sla.holding() is True     # targets are unmissable here


# ---------------------------------------------------------------------------
# legacy wrapper deprecation
# ---------------------------------------------------------------------------

def test_legacy_wrappers_warn_deprecation():
    rc, model, params = _lm()
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 4), 0,
                              rc.vocab_size)
    with pytest.warns(DeprecationWarning, match="greedy_generate"):
        greedy_generate(model, CTX, params, {"tokens": toks}, steps=2,
                        max_len=8)
    nc = reduce_config(REGISTRY["nllb600m"])
    nmodel = build_model(nc)
    nparams = nmodel.init(jax.random.PRNGKey(0))
    src = jax.random.randint(jax.random.PRNGKey(1), (1, nc.enc_len), 0,
                             nc.vocab_size)
    with pytest.warns(DeprecationWarning, match="translate") as rec:
        translate(nmodel, CTX, nparams, src, 8, steps=2, max_len=8)
    # translate delegates internally, it must not warn twice
    assert len([w for w in rec.list
                if issubclass(w.category, DeprecationWarning)]) == 1


# ---------------------------------------------------------------------------
# report schema v4 latency roll-up (upgrade chains to current)
# ---------------------------------------------------------------------------

def _v3_report():
    return {"schema": 3, "kind": "repro.eval", "arch": "x", "git_rev": None,
            "config": {}, "rows": [
                {"fmt": "int8", "spec": "w8",
                 "pair_scores": [
                     {"src": "hin", "tgt": "eng", "bleu": 0.5,
                      "ttft_p95_ms": 12.0, "tpot_p95_ms": 3.0},
                     {"src": "eng", "tgt": "hin", "bleu": 0.4,
                      "ttft_p95_ms": 20.0, "tpot_p95_ms": 2.5}]},
                {"fmt": "bf16", "spec": "w16", "pair_scores": []}]}


def test_report_v3_upgrades_to_current():
    loaded = report_mod.load(json.dumps(_v3_report()))
    assert loaded["schema"] == report_mod.SCHEMA_VERSION == 5
    row = loaded["rows"][0]
    # worst direction over the pair grid — what an SLATarget is set on
    assert row["ttft_p95_ms"] == 20.0
    assert row["tpot_p95_ms"] == 3.0
    # no per-pair latency recorded -> explicit None, not a KeyError
    assert loaded["rows"][1]["ttft_p95_ms"] is None
    assert loaded["rows"][1]["tpot_p95_ms"] is None
    # v4 -> v5: pre-trace rows gain the untraced sentinel
    assert all(r["round_phases"] is None for r in loaded["rows"])
    assert report_mod.load(report_mod.dump(loaded)) == loaded
