"""Serving engine: greedy generation, translation API, continuous batching."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import REGISTRY, reduce_config
from repro.data import LANG_CODES
from repro.models import Ctx, build_model
from repro.serving import ServeEngine, greedy_generate, translate

CTX = Ctx(compute_dtype=jnp.float32)


def _lm(name="gemma3-1b"):
    rc = reduce_config(REGISTRY[name])
    model = build_model(rc)
    params = model.init(jax.random.PRNGKey(0))
    return rc, model, params


def test_greedy_generate_deterministic():
    rc, model, params = _lm()
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 6), 0, rc.vocab_size)
    out1, _ = greedy_generate(model, CTX, params, {"tokens": toks}, steps=5,
                              max_len=16)
    out2, _ = greedy_generate(model, CTX, params, {"tokens": toks}, steps=5,
                              max_len=16)
    np.testing.assert_array_equal(np.asarray(out1), np.asarray(out2))
    assert out1.shape == (2, 5)


def test_translate_api_shapes():
    rc = reduce_config(REGISTRY["nllb600m"])
    model = build_model(rc)
    params = model.init(jax.random.PRNGKey(0))
    src = jax.random.randint(jax.random.PRNGKey(1), (3, rc.enc_len), 0,
                             rc.vocab_size)
    toks = translate(model, CTX, params, src, LANG_CODES["ita"], steps=6,
                     max_len=16)
    assert toks.shape == (3, 6)
    assert int(toks.min()) >= 0 and int(toks.max()) < rc.vocab_size


def test_int8_kv_generation_tracks_bf16():
    rc, model, params = _lm("qwen2.5-14b")
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, rc.vocab_size)
    g16, _ = greedy_generate(model, CTX, params, {"tokens": toks}, steps=4,
                             max_len=16, kv_dtype="bf16")
    g8, _ = greedy_generate(model, CTX, params, {"tokens": toks}, steps=4,
                            max_len=16, kv_dtype="int8")
    # argmax ids may deviate eventually; first step must agree on a
    # random-init model with typical logit gaps
    assert int(g16[0, 0]) == int(g8[0, 0])


def test_continuous_batching_matches_single_stream():
    rc, model, params = _lm()
    eng = ServeEngine(model, params, slots=3, max_len=24, ctx=CTX)
    p1 = jax.random.randint(jax.random.PRNGKey(1), (1, 6), 0, rc.vocab_size)
    p2 = jax.random.randint(jax.random.PRNGKey(2), (1, 4), 0, rc.vocab_size)
    s1 = eng.add_request({"tokens": p1}, gen_tokens=5)
    s2 = eng.add_request({"tokens": p2}, gen_tokens=5)
    while eng.slots[s1].active or eng.slots[s2].active:
        eng.tick()
    ref1, _ = greedy_generate(model, CTX, params, {"tokens": p1}, steps=5,
                              max_len=24)
    ref2, _ = greedy_generate(model, CTX, params, {"tokens": p2}, steps=5,
                              max_len=24)
    assert eng.result(s1) == list(np.asarray(ref1[0]))
    assert eng.result(s2) == list(np.asarray(ref2[0]))


def test_slot_reuse_after_completion():
    rc, model, params = _lm()
    eng = ServeEngine(model, params, slots=1, max_len=16, ctx=CTX)
    p = jax.random.randint(jax.random.PRNGKey(3), (1, 4), 0, rc.vocab_size)
    s = eng.add_request({"tokens": p}, gen_tokens=2)
    while eng.slots[s].active:
        eng.tick()
    assert eng.free_slot() == s          # slot released
    s2 = eng.add_request({"tokens": p}, gen_tokens=2)
    while eng.slots[s2].active:
        eng.tick()
    assert eng.result(s2) == eng.result(s)   # cache fully re-primed
