"""Serving engine: request-level API, sampling, continuous batching.

Covers the legacy single-shot wrappers (greedy_generate / translate,
back-compat), the scheduler-owned ServeEngine (submit / step /
run_until_drained, EOS-aware retirement, mixed per-slot SamplingParams,
prefill-length bucketing), the deploy() pipeline, and the horizon-fused
decode path (horizon=K must be token-for-token identical to horizon=1
for dense and paged caches, greedy and seeded sampling, mixed per-slot
params, mid-stream admission, and abort).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import REGISTRY, reduce_config
from repro.data import LANG_CODES
from repro.models import Ctx, build_model
from repro.serving import (SamplingParams, ServeEngine, deploy,
                           greedy_generate, translate)

CTX = Ctx(compute_dtype=jnp.float32)


def _lm(name="gemma3-1b"):
    rc = reduce_config(REGISTRY[name])
    model = build_model(rc)
    params = model.init(jax.random.PRNGKey(0))
    return rc, model, params


# ---------------------------------------------------------------------------
# legacy wrapper back-compat
# ---------------------------------------------------------------------------

# the single-shot wrappers are deprecated in favor of the streaming
# pipeline surface; tests covering them opt out of the CI pinned leg's
# -W error::DeprecationWarning (test marks outrank the command line)
legacy = pytest.mark.filterwarnings("ignore::DeprecationWarning")


@legacy
def test_greedy_generate_deterministic():
    rc, model, params = _lm()
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 6), 0, rc.vocab_size)
    out1, _ = greedy_generate(model, CTX, params, {"tokens": toks}, steps=5,
                              max_len=16)
    out2, _ = greedy_generate(model, CTX, params, {"tokens": toks}, steps=5,
                              max_len=16)
    np.testing.assert_array_equal(np.asarray(out1), np.asarray(out2))
    assert out1.shape == (2, 5)


@legacy
def test_translate_api_shapes():
    rc = reduce_config(REGISTRY["nllb600m"])
    model = build_model(rc)
    params = model.init(jax.random.PRNGKey(0))
    src = jax.random.randint(jax.random.PRNGKey(1), (3, rc.enc_len), 0,
                             rc.vocab_size)
    toks = translate(model, CTX, params, src, LANG_CODES["ita"], steps=6,
                     max_len=16)
    assert toks.shape == (3, 6)
    assert int(toks.min()) >= 0 and int(toks.max()) < rc.vocab_size


@legacy
def test_translate_overflow_raises():
    rc = reduce_config(REGISTRY["nllb600m"])
    model = build_model(rc)
    params = model.init(jax.random.PRNGKey(0))
    src = jax.random.randint(jax.random.PRNGKey(1), (1, rc.enc_len), 0,
                             rc.vocab_size)
    # 1 (lang-code prompt) + 8 steps > max_len=8: must raise, not wrap
    with pytest.raises(ValueError, match="max_len"):
        translate(model, CTX, params, src, LANG_CODES["ita"], steps=8,
                  max_len=8)


@legacy
def test_int8_kv_generation_tracks_bf16():
    rc, model, params = _lm("qwen2.5-14b")
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, rc.vocab_size)
    g16, _ = greedy_generate(model, CTX, params, {"tokens": toks}, steps=4,
                             max_len=16, kv_dtype="bf16")
    g8, _ = greedy_generate(model, CTX, params, {"tokens": toks}, steps=4,
                            max_len=16, kv_dtype="int8")
    # argmax ids may deviate eventually; first step must agree on a
    # random-init model with typical logit gaps
    assert int(g16[0, 0]) == int(g8[0, 0])


@legacy
def test_continuous_batching_matches_single_stream():
    rc, model, params = _lm()
    eng = ServeEngine(model, params, slots=3, max_len=24, ctx=CTX)
    p1 = jax.random.randint(jax.random.PRNGKey(1), (1, 6), 0, rc.vocab_size)
    p2 = jax.random.randint(jax.random.PRNGKey(2), (1, 4), 0, rc.vocab_size)
    s1 = eng.add_request({"tokens": p1}, gen_tokens=5)
    s2 = eng.add_request({"tokens": p2}, gen_tokens=5)
    while eng.slots[s1].active or eng.slots[s2].active:
        eng.tick()
    ref1, _ = greedy_generate(model, CTX, params, {"tokens": p1}, steps=5,
                              max_len=24)
    ref2, _ = greedy_generate(model, CTX, params, {"tokens": p2}, steps=5,
                              max_len=24)
    assert eng.result(s1) == list(np.asarray(ref1[0]))
    assert eng.result(s2) == list(np.asarray(ref2[0]))


def test_slot_reuse_after_completion():
    rc, model, params = _lm()
    eng = ServeEngine(model, params, slots=1, max_len=16, ctx=CTX)
    p = jax.random.randint(jax.random.PRNGKey(3), (1, 4), 0, rc.vocab_size)
    s = eng.add_request({"tokens": p}, gen_tokens=2)
    while eng.slots[s].active:
        eng.tick()
    assert eng.free_slot() == s          # slot released
    s2 = eng.add_request({"tokens": p}, gen_tokens=2)
    while eng.slots[s2].active:
        eng.tick()
    assert eng.result(s2) == eng.result(s)   # cache fully re-primed


# ---------------------------------------------------------------------------
# request-level API
# ---------------------------------------------------------------------------

def test_sampling_params_validation():
    with pytest.raises(ValueError):
        SamplingParams(temperature=-0.1)
    with pytest.raises(ValueError):
        SamplingParams(top_p=0.0)
    with pytest.raises(ValueError):
        SamplingParams(max_new_tokens=0)
    assert SamplingParams().greedy
    assert not SamplingParams(temperature=0.5).greedy


def test_submit_rejects_overflowing_request():
    rc, model, params = _lm()
    eng = ServeEngine(model, params, slots=1, max_len=8, ctx=CTX)
    p = jax.random.randint(jax.random.PRNGKey(0), (1, 6), 0, rc.vocab_size)
    with pytest.raises(ValueError, match="max_len"):
        eng.submit({"tokens": p}, SamplingParams(max_new_tokens=4))


@legacy
def test_eos_stops_generation_and_reports_reason():
    rc, model, params = _lm()
    p = jax.random.randint(jax.random.PRNGKey(1), (1, 6), 0, rc.vocab_size)
    eng = ServeEngine(model, params, slots=1, max_len=24, ctx=CTX)
    rid = eng.submit({"tokens": p}, SamplingParams(max_new_tokens=6))
    ref = {o.request_id: o for o in eng.run_until_drained()}[rid]
    assert ref.finish_reason == "length"
    # pick a token the greedy stream actually emits as the EOS id
    eos = ref.token_ids[2]
    pos = ref.token_ids.index(eos)       # first occurrence may be earlier
    eng2 = ServeEngine(model, params, slots=1, max_len=24, ctx=CTX)
    rid = eng2.submit({"tokens": p},
                      SamplingParams(max_new_tokens=6, eos_id=eos))
    out = {o.request_id: o for o in eng2.run_until_drained()}[rid]
    assert out.finish_reason == "eos"
    assert out.token_ids == ref.token_ids[:pos + 1]   # EOS included, then stop
    # wrapper: same EOS id masks every position after the stop
    toks, _ = greedy_generate(model, CTX, params, {"tokens": p}, steps=6,
                              max_len=24, eos_id=eos)
    assert toks.shape == (1, 6)
    assert list(np.asarray(toks[0, :pos + 1])) == ref.token_ids[:pos + 1]
    assert all(int(t) == eos for t in np.asarray(toks[0, pos + 1:]))


def test_temperature_zero_equals_greedy():
    rc, model, params = _lm()
    p = jax.random.randint(jax.random.PRNGKey(4), (1, 5), 0, rc.vocab_size)
    eng = ServeEngine(model, params, slots=2, max_len=16, ctx=CTX)
    r0 = eng.submit({"tokens": p}, SamplingParams(max_new_tokens=4))
    r1 = eng.submit({"tokens": p},
                    SamplingParams(temperature=0.0, top_k=3, top_p=0.5,
                                   max_new_tokens=4, seed=123))
    outs = {o.request_id: o for o in eng.run_until_drained()}
    assert outs[r0].token_ids == outs[r1].token_ids


def test_sampling_seed_determinism():
    rc, model, params = _lm()
    p = jax.random.randint(jax.random.PRNGKey(5), (1, 5), 0, rc.vocab_size)
    sp = SamplingParams(temperature=0.9, top_k=8, top_p=0.9,
                        max_new_tokens=5, seed=11)

    def run(slots):
        eng = ServeEngine(model, params, slots=slots, max_len=16, ctx=CTX)
        rid = eng.submit({"tokens": p}, sp)
        return {o.request_id: o for o in eng.run_until_drained()}[rid]

    a, b = run(1), run(1)
    assert a.token_ids == b.token_ids          # same seed -> same stream
    assert a.finish_reason == "length"
    # top_k=1 collapses sampling to greedy regardless of temperature/seed
    eng = ServeEngine(model, params, slots=2, max_len=16, ctx=CTX)
    rg = eng.submit({"tokens": p}, SamplingParams(max_new_tokens=5))
    rk = eng.submit({"tokens": p},
                    SamplingParams(temperature=1.3, top_k=1,
                                   max_new_tokens=5, seed=77))
    outs = {o.request_id: o for o in eng.run_until_drained()}
    assert outs[rg].token_ids == outs[rk].token_ids


def test_mixed_sampling_params_one_batch():
    """Greedy and sampled slots share one step fn; each stream is exactly
    what it would be served alone (slot placement doesn't leak)."""
    rc, model, params = _lm()
    p1 = jax.random.randint(jax.random.PRNGKey(1), (1, 6), 0, rc.vocab_size)
    p2 = jax.random.randint(jax.random.PRNGKey(2), (1, 4), 0, rc.vocab_size)
    sp_samp = SamplingParams(temperature=0.8, top_p=0.9, max_new_tokens=5,
                             seed=3)

    def solo(prompt, sp):
        eng = ServeEngine(model, params, slots=1, max_len=24, ctx=CTX)
        rid = eng.submit({"tokens": prompt}, sp)
        return {o.request_id: o for o in eng.run_until_drained()}[rid]

    ref_g = solo(p1, SamplingParams(max_new_tokens=5))
    ref_s = solo(p2, sp_samp)

    eng = ServeEngine(model, params, slots=3, max_len=24, ctx=CTX)
    rg = eng.submit({"tokens": p1}, SamplingParams(max_new_tokens=5))
    rs = eng.submit({"tokens": p2}, sp_samp)
    outs = {o.request_id: o for o in eng.run_until_drained()}
    assert outs[rg].token_ids == ref_g.token_ids
    assert outs[rs].token_ids == ref_s.token_ids
    # greedy + sampled slots ran under ONE compiled step executable:
    # SamplingParams enter as traced arrays, never as static args
    cache_size = getattr(eng._step_fn, "_cache_size", None)
    if cache_size is not None:
        assert cache_size() == 1


def test_engine_queue_overcommit_and_stats():
    """More requests than slots: the engine queues and drains them all."""
    rc, model, params = _lm()
    eng = ServeEngine(model, params, slots=2, max_len=16, ctx=CTX)
    ids = []
    for i in range(5):
        p = jax.random.randint(jax.random.PRNGKey(i), (1, 4), 0,
                               rc.vocab_size)
        ids.append(eng.submit({"tokens": p},
                              SamplingParams(max_new_tokens=3, seed=i)))
    assert eng.num_pending == 3 and eng.num_active == 2
    outs = eng.run_until_drained()
    assert sorted(o.request_id for o in outs) == ids
    for o in outs:
        assert o.finish_reason == "length"
        assert o.num_generated == 3
        assert o.stats.prompt_len == 4
        assert o.stats.finished_s >= o.stats.first_token_s >= o.stats.arrival_s


def test_abort_request():
    rc, model, params = _lm()
    eng = ServeEngine(model, params, slots=1, max_len=16, ctx=CTX)
    p = jax.random.randint(jax.random.PRNGKey(0), (1, 4), 0, rc.vocab_size)
    r1 = eng.submit({"tokens": p}, SamplingParams(max_new_tokens=8))
    r2 = eng.submit({"tokens": p}, SamplingParams(max_new_tokens=8))
    assert eng.num_pending == 1              # r2 waits behind r1
    o2 = eng.abort(r2)
    assert o2.finish_reason == "abort" and o2.token_ids == []
    o1 = eng.abort(r1)
    assert o1.finish_reason == "abort" and len(o1.token_ids) >= 1
    assert eng.run_until_drained() == []
    assert eng.abort(999) is None


def test_prefill_length_bucketing_bounds_compiles():
    """Distinct prompt lengths must not each trigger a fresh prefill
    compile: lengths bucket to powers of two (here 4 and 8)."""
    rc, model, params = _lm()
    eng = ServeEngine(model, params, slots=1, max_len=32, ctx=CTX)
    for i, plen in enumerate((3, 4, 5, 6, 7, 8)):
        p = jax.random.randint(jax.random.PRNGKey(i), (1, plen), 0,
                               rc.vocab_size)
        eng.submit({"tokens": p}, SamplingParams(max_new_tokens=2))
        eng.run_until_drained()
    assert eng.prefill_compiles == 2
    # the jit cache agrees with the engine's own accounting
    cache_size = getattr(eng._prefill_fn, "_cache_size", None)
    if cache_size is not None:
        assert cache_size() == 2


def test_bucketed_prefill_matches_exact_prefill():
    """Right-padding + lengths masking must not change the decoded
    stream (pos=-1 slots are masked out of attention)."""
    rc, model, params = _lm()
    p = jax.random.randint(jax.random.PRNGKey(9), (1, 5), 0, rc.vocab_size)

    eng = ServeEngine(model, params, slots=1, max_len=16, ctx=CTX)
    rid = eng.submit({"tokens": p}, SamplingParams(max_new_tokens=4))
    bucketed = {o.request_id: o for o in eng.run_until_drained()}[rid]

    # exact-length reference: batched prefill with no padding
    cache = model.init_cache(1, 16, "bf16")
    cache, logits = model.prefill(CTX, params, cache, {"tokens": p})
    tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    ref = [int(tok[0, 0])]
    for _ in range(3):
        cache, logits = model.decode_step(CTX, params, tok, cache)
        tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        ref.append(int(tok[0, 0]))
    assert bucketed.token_ids == ref


# ---------------------------------------------------------------------------
# deploy() pipeline
# ---------------------------------------------------------------------------

@legacy
def test_deploy_translate_pipeline():
    pipe = deploy("nllb600m", "int4", slots=2, max_len=16, smoke=True)
    assert pipe.compression > 2.0            # int4 shrinks the checkpoint
    src = jax.random.randint(jax.random.PRNGKey(1), (3, pipe.cfg.enc_len), 0,
                             pipe.cfg.vocab_size)
    outs = pipe.translate(src, "ita", SamplingParams(max_new_tokens=6))
    assert len(outs) == 3
    assert [o.num_generated for o in outs] == [6, 6, 6]
    assert all(o.finish_reason == "length" for o in outs)
    # wrapper path and pipeline path agree (same engine underneath)
    toks = translate(pipe.model, pipe.ctx, pipe.params, src,
                     LANG_CODES["ita"], steps=6, max_len=16, kv_dtype="int8")
    assert [list(np.asarray(r)) for r in toks] == [o.token_ids for o in outs]


# ---------------------------------------------------------------------------
# horizon-fused decode
# ---------------------------------------------------------------------------

def _outputs_by_id(eng, ids):
    outs = {o.request_id: o for o in eng.run_until_drained()}
    return [outs[i] for i in ids]


def _assert_equiv(base, got, K):
    for b, g in zip(base, got):
        assert g.token_ids == b.token_ids, \
            f"horizon={K}: {g.token_ids} != {b.token_ids}"
        assert g.finish_reason == b.finish_reason
        assert g.num_generated == b.num_generated == g.stats.new_tokens


def test_horizon_equivalence_dense_mixed_params():
    """horizon=K token streams, finish reasons, and stats must match
    horizon=1 exactly — greedy and seeded top-p slots side by side."""
    rc, model, params = _lm()
    p1 = jax.random.randint(jax.random.PRNGKey(1), (1, 6), 0, rc.vocab_size)
    p2 = jax.random.randint(jax.random.PRNGKey(2), (1, 4), 0, rc.vocab_size)
    sp_g = SamplingParams(max_new_tokens=7)
    sp_s = SamplingParams(temperature=0.8, top_p=0.9, max_new_tokens=5,
                          seed=3)

    def run(K):
        eng = ServeEngine(model, params, slots=2, max_len=24, ctx=CTX,
                          horizon=K)
        ids = [eng.submit({"tokens": p1}, sp_g),
               eng.submit({"tokens": p2}, sp_s)]
        return _outputs_by_id(eng, ids)

    base = run(1)
    for K in (4, 16):
        _assert_equiv(base, run(K), K)


def test_horizon_equivalence_dense_eos():
    """EOS emitted mid-horizon retires the slot at the same position and
    with the same finish reason as per-token decode."""
    rc, model, params = _lm()
    p = jax.random.randint(jax.random.PRNGKey(7), (1, 5), 0, rc.vocab_size)

    def run(K, eos=None):
        eng = ServeEngine(model, params, slots=1, max_len=24, ctx=CTX,
                          horizon=K)
        ids = [eng.submit({"tokens": p},
                          SamplingParams(max_new_tokens=8, eos_id=eos))]
        return _outputs_by_id(eng, ids)

    ref = run(1)[0]
    eos = ref.token_ids[2]              # a token the stream actually emits
    base = run(1, eos)
    assert base[0].finish_reason == "eos"
    for K in (4, 16):
        _assert_equiv(base, run(K, eos), K)


def test_horizon_equivalence_paged():
    """Paged engine (block tables static across the horizon): fused and
    per-token decode agree for greedy + sampled slots, and every page
    returns to the pool."""
    pipes = {}
    for K in (1, 4, 16):
        pipes[K] = deploy("gemma3-1b", "int8", slots=3, max_len=32,
                          smoke=True, paged=True, page_size=4, horizon=K)
    cfg = pipes[1].cfg
    p1 = jax.random.randint(jax.random.PRNGKey(1), (1, 6), 0, cfg.vocab_size)
    p2 = jax.random.randint(jax.random.PRNGKey(2), (1, 6), 0, cfg.vocab_size)
    sp_g = SamplingParams(max_new_tokens=6)
    sp_s = SamplingParams(temperature=0.7, top_k=8, max_new_tokens=5, seed=11)

    def run(K):
        eng = pipes[K].engine
        ids = [eng.submit({"tokens": p1}, sp_g),
               eng.submit({"tokens": p2}, sp_s)]
        outs = _outputs_by_id(eng, ids)
        assert eng.allocator.pages_in_use == 0      # full reclaim
        return outs

    base = run(1)
    for K in (4, 16):
        _assert_equiv(base, run(K), K)


def test_horizon_equivalence_encdec_midstream_admission():
    """A request submitted between horizons (continuous batching refill)
    must decode the same stream as under per-token admission."""
    def run(K):
        pipe = deploy("nllb600m", "int8", slots=2, max_len=16, smoke=True,
                      paged=True, page_size=4, horizon=K)
        cfg = pipe.cfg
        srcs = [jax.random.randint(jax.random.PRNGKey(i), (1, cfg.enc_len),
                                   0, cfg.vocab_size) for i in range(3)]
        tgt = jnp.full((1, 1), 8, jnp.int32)
        eng = pipe.engine
        sp = SamplingParams(temperature=0.6, top_p=0.9, max_new_tokens=6,
                            seed=5)
        ids = [eng.submit({"src_tokens": srcs[0], "tgt_in": tgt}, sp),
               eng.submit({"src_tokens": srcs[1], "tgt_in": tgt}, sp)]
        early = eng.step()   # at large K a request can finish right here
        ids.append(eng.submit({"src_tokens": srcs[2], "tgt_in": tgt}, sp))
        outs = {o.request_id: o for o in early + eng.run_until_drained()}
        return [outs[i] for i in ids]

    base = run(1)
    for K in (4, 8):
        _assert_equiv(base, run(K), K)


def test_horizon_one_is_legacy_path():
    """horizon=1 (explicit or default) never builds a fused scan — the
    back-compat guarantee is the original executable, not a K=1 scan."""
    rc, model, params = _lm()
    eng = ServeEngine(model, params, slots=1, max_len=16, ctx=CTX)
    p = jax.random.randint(jax.random.PRNGKey(0), (1, 4), 0, rc.vocab_size)
    eng.submit({"tokens": p}, SamplingParams(max_new_tokens=3))
    eng.run_until_drained()
    assert eng.horizon == 1 and eng._horizon_fns == {}
    with pytest.raises(ValueError, match="horizon"):
        eng.step(horizon=0)
    with pytest.raises(ValueError, match="horizon"):
        ServeEngine(model, params, slots=1, max_len=16, ctx=CTX, horizon=0)


def test_horizon_decode_syncs_metric():
    """One request needing 8 decode tokens: 8 syncs per-token, 1 sync at
    horizon=8; mean_tokens_per_sync reports the fusion win."""
    rc, model, params = _lm()
    p = jax.random.randint(jax.random.PRNGKey(1), (1, 4), 0, rc.vocab_size)
    sp = SamplingParams(max_new_tokens=9)    # 1 prefill + 8 decode tokens

    def syncs(K):
        eng = ServeEngine(model, params, slots=1, max_len=16, ctx=CTX,
                          horizon=K)
        eng.submit({"tokens": p}, sp)
        eng.run_until_drained()
        return eng.decode_syncs, eng.mean_tokens_per_sync

    assert syncs(1) == (8, 1.0)
    assert syncs(8) == (1, 8.0)
    # reset_metrics zeroes the sync counters alongside occupancy
    eng = ServeEngine(model, params, slots=1, max_len=16, ctx=CTX, horizon=8)
    eng.submit({"tokens": p}, sp)
    eng.run_until_drained()
    eng.reset_metrics()
    assert eng.decode_syncs == 0 and eng.mean_tokens_per_sync == 0.0


def test_horizon_abort_truncates_and_frees_pages_once():
    """Abort after a partial horizon: tokens truncate at the last synced
    position, the page chain is freed exactly once (the strict allocator
    raises on double-free), and the engine keeps serving."""
    pipe = deploy("gemma3-1b", "int8", slots=2, max_len=32, smoke=True,
                  paged=True, page_size=4, horizon=4)
    eng = pipe.engine
    p = jax.random.randint(jax.random.PRNGKey(0), (1, 5), 0,
                           pipe.cfg.vocab_size)
    rid = eng.submit({"tokens": p}, SamplingParams(max_new_tokens=20))
    eng.step()                            # admit + one fused horizon of 4
    assert eng.allocator.pages_in_use > 0
    out = eng.abort(rid)
    assert out.finish_reason == "abort"
    assert out.num_generated == 5         # 1 prefill + 4 synced tokens
    assert out.stats.new_tokens == 5
    assert eng.allocator.pages_in_use == 0      # chain freed, exactly once
    assert eng.abort(rid) is None               # idempotent, no double free
    rid2 = eng.submit({"tokens": p}, SamplingParams(max_new_tokens=6))
    outs = eng.run_until_drained()
    assert [o.request_id for o in outs] == [rid2]
    assert outs[0].num_generated == 6
    assert eng.allocator.pages_in_use == 0


def test_deploy_horizon_and_impl_knobs():
    """deploy() threads horizon into the engine and kernel routes into
    the pipeline Ctx; invalid routes fail fast."""
    pipe = deploy("gemma3-1b", "int8", slots=1, max_len=16, smoke=True,
                  horizon=4, matmul_impl="xla", paged_attn_impl="gather")
    assert pipe.engine.horizon == 4
    assert pipe.ctx.matmul_impl == "xla"
    assert pipe.ctx.paged_attn_impl == "gather"
    with pytest.raises(ValueError, match="matmul_impl"):
        deploy("gemma3-1b", "int8", smoke=True, matmul_impl="cuda")
    with pytest.raises(ValueError, match="paged_attn_impl"):
        deploy("gemma3-1b", "int8", smoke=True, paged_attn_impl="flash")


def test_deploy_generate_lm():
    pipe = deploy("gemma3-1b", "int8", slots=2, max_len=16, smoke=True)
    prompts = [jnp.arange(4) % pipe.cfg.vocab_size,
               jnp.arange(6) % pipe.cfg.vocab_size]
    outs = pipe.generate(prompts, SamplingParams(max_new_tokens=4))
    assert [o.num_generated for o in outs] == [4, 4]
    assert outs[0].request_id < outs[1].request_id   # input order
    with pytest.raises(TypeError, match="enc-dec"):
        pipe.translate(jnp.ones((1, 4), jnp.int32), "ita")
