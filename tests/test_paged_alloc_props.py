"""Property tests for the page allocator (hypothesis).

Random interleavings of alloc / free / abort must never double-allocate
a page, never leak after every chain is reclaimed, and must preserve
chain order across splice/reclaim cycles. Skipped when hypothesis is
not installed (CI's tier-1 matrix installs it).
"""

import pytest

hypothesis = pytest.importorskip("hypothesis")

from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.serving.paged_cache import PageAllocator, pages_needed  # noqa: E402


@settings(max_examples=200, deadline=None)
@given(st.lists(st.integers(min_value=0, max_value=6), min_size=1,
                max_size=40),
       st.integers(min_value=4, max_value=32))
def test_alloc_free_interleavings_keep_invariants(sizes, usable):
    """Allocate chains of the given sizes, freeing a random-ish victim
    whenever the pool can't satisfy the next chain; every page is always
    free xor in-use exactly once, and chains never overlap."""
    a = PageAllocator(usable + 1)
    live = {}
    for i, n in enumerate(sizes):
        while not a.can_alloc(n) and live:
            victim = sorted(live)[i % len(live)]    # deterministic victim
            a.free_chain(live.pop(victim))
            a.check()
        if not a.can_alloc(n):
            with pytest.raises(MemoryError):
                a.alloc_chain(n)
            continue
        chain = a.alloc_chain(n)
        assert len(chain) == n and len(set(chain)) == n
        assert 0 not in chain                       # trash page protected
        for other in live.values():
            assert not set(chain) & set(other)      # no double-allocation
        live[i] = chain
        a.check()
    for chain in live.values():                     # EOS/abort: reclaim all
        a.free_chain(chain)
    a.check()
    assert a.pages_in_use == 0 and a.num_free == usable


@settings(max_examples=100, deadline=None)
@given(st.lists(st.integers(min_value=1, max_value=5), min_size=1,
                max_size=8))
def test_chain_order_preserved_across_reclaim(sizes):
    """A chain read back page-by-page is exactly the allocation order
    (token t lives at chain[t // ps]); reclaim + realloc cycles must not
    scramble held chains."""
    a = PageAllocator(sum(sizes) + 1)
    chains = [a.alloc_chain(n) for n in sizes]
    snapshots = [list(c) for c in chains]
    # splice/reclaim churn: free and reallocate every other chain
    for i in range(0, len(chains), 2):
        a.free_chain(chains[i])
        chains[i] = a.alloc_chain(len(chains[i]))
    for i in range(1, len(chains), 2):              # held chains untouched
        assert chains[i] == snapshots[i]
    seen = set()
    for c in chains:                                # still pairwise disjoint
        assert not set(c) & seen
        seen |= set(c)
    a.check()


@settings(max_examples=100, deadline=None)
@given(st.integers(min_value=0, max_value=1000),
       st.integers(min_value=1, max_value=64))
def test_pages_needed_is_exact_ceiling(tokens, ps):
    n = pages_needed(tokens, ps)
    assert n * ps >= tokens
    assert (n - 1) * ps < tokens or n == 0


@settings(max_examples=50, deadline=None)
@given(st.integers(min_value=2, max_value=16))
def test_double_free_always_raises(n):
    a = PageAllocator(n + 1)
    c = a.alloc_chain(n)
    a.free_chain(c)
    with pytest.raises(ValueError):
        a.free_chain(c[:1])
    a.check()
