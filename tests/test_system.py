"""End-to-end behaviour tests for the paper's system (Bhasha-Rupantarika).

The paper's pipeline: train/finetune a single many-to-many NLLB model,
post-training-quantize it to sub-octet formats, deploy for bidirectional
Indic<->overseas translation. This test walks that exact path on the
reduced config and asserts the paper's two headline properties:

  * model size shrinks ~4x at 4-bit (paper: 4.1x for FP4);
  * translation capability survives quantization (greedy outputs track
    the full-precision model).
"""

import jax
import jax.numpy as jnp
import pytest

from repro.configs import REGISTRY, reduce_config
from repro.core import PRESETS, quantize_tree, tree_nbytes
from repro.data import LANG_CODES, SyntheticTranslation
from repro.models import Ctx, build_model
from repro.optim import warmup_linear
from repro.serving import translate
from repro.train import make_train_step

CTX = Ctx(compute_dtype=jnp.float32)


def _trained_nllb(steps=60):
    rc = reduce_config(REGISTRY["nllb600m"])
    model = build_model(rc)
    ds = SyntheticTranslation(rc.vocab_size, rc.enc_len, seed=0,
                              languages=("hin", "eng", "ita"))
    init_state, step = make_train_step(
        model, lr_fn=lambda s: warmup_linear(s, peak_lr=1e-2, warmup=5,
                                             total=steps), ctx=CTX)
    state = init_state(model.init(jax.random.PRNGKey(0)))
    step = jax.jit(step)
    first = last = None
    for i in range(steps):
        b = {k: jnp.asarray(v) for k, v in ds.sample(8).items()
             if not isinstance(v, str)}
        state, m = step(state, b)
        first = first if first is not None else float(m["loss"])
        last = float(m["loss"])
    return rc, model, state["params"], ds, first, last


@pytest.mark.filterwarnings("ignore::DeprecationWarning")
def test_full_pipeline_train_quantize_translate():
    rc, model, params, ds, first, last = _trained_nllb()
    assert last < 0.9 * first, (first, last)

    fp_bytes = tree_nbytes(params)
    b = ds.sample(4)
    src = jnp.asarray(b["src_tokens"])
    code = LANG_CODES[b["tgt_lang"]]
    ref_out = translate(model, CTX, params, src, code, steps=6, max_len=16)

    for preset, min_ratio in [("int4", 4.0), ("fp4", 4.0), ("nf4", 4.0),
                              ("int8", 2.8), ("fp8", 2.8)]:
        qp = quantize_tree(params, PRESETS[preset])
        ratio = fp_bytes / tree_nbytes(qp)
        assert ratio > min_ratio, (preset, ratio)   # paper: 4.1x at 4-bit
        q_out = translate(model, CTX, qp, src, code, steps=6, max_len=16)
        agree = float((q_out == ref_out).mean())
        assert agree > 0.6, (preset, agree)   # capability survives PTQ


def test_bidirectional_single_model():
    """One unified model serves both directions (paper's core question)."""
    rc, model, params, ds, _, _ = _trained_nllb(steps=25)
    b = ds.sample(2)
    src = jnp.asarray(b["src_tokens"])
    batch = {"src_tokens": src,
             "tgt_in": jnp.full((2, 1), LANG_CODES["ita"], jnp.int32)}
    logits_ita, _ = model.forward(CTX, params, batch)
    batch["tgt_in"] = jnp.full((2, 1), LANG_CODES["hin"], jnp.int32)
    logits_hin, _ = model.forward(CTX, params, batch)
    # the target-language code token must steer the decoder distribution
    assert float(jnp.max(jnp.abs(logits_ita - logits_hin))) > 1e-3
