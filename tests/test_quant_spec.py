"""QuantSpec grammar + resolver + per-site calibration + fp8 arm.

The PR 5 acceptance surface:
  * the grammar round-trips (parse -> str -> parse is identity);
  * every legacy preset alias resolves to a policy that quantizes a
    tree byte-for-byte identically to the hand-written PR 4 table, and
    an alias and its grammar spelling decode token-for-token equal;
  * spec resolution errors name the bad spec and the valid choices;
  * per-site calibration is deterministic, merges max-associatively,
    and an a8 spec with zero calibration batches falls back to dynamic
    quantization with a warning (the silent-bf16-activations guard);
  * the fp8 end-to-end arm serves through fp8 page pools and lands in
    the sweep with a resolved spec string.
"""

import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import REGISTRY, reduce_config
from repro.core import (ALIASES, PRESETS, QuantSpec, QTensor, quantize_tree,
                        resolve_spec, tree_nbytes)
from repro.core.calibration import ActSiteStats
from repro.core.policy import PrecisionPolicy
from repro.data import SyntheticTranslation
from repro.eval import quant_sweep
from repro.models import Ctx, build_model
from repro.serving import SamplingParams, deploy

# the PR 4 preset table, hand-written — the compatibility contract
_LEGACY = {
    "f32": PrecisionPolicy("f32", weights="f32", embed="f32",
                           compute_dtype=jnp.float32),
    "bf16": PrecisionPolicy("bf16"),
    "int8": PrecisionPolicy("int8", weights="int8", embed="int8"),
    "w8a8": PrecisionPolicy("w8a8", weights="int8", embed="int8", act="int8",
                            kv_cache="int8", block_size=2**20),
    "fp8": PrecisionPolicy("fp8", weights="fp8", embed="fp8", kv_cache="fp8"),
    "int4": PrecisionPolicy("int4", weights="int4", embed="int8",
                            kv_cache="int8"),
    "fp4": PrecisionPolicy("fp4", weights="fp4", embed="int8",
                           kv_cache="int8"),
    "nf4": PrecisionPolicy("nf4", weights="nf4", embed="int8",
                           kv_cache="int8", double_quant=True),
}

GRAMMAR_CASES = ["w4a8kv8", "w8a8kv8g32", "wfp4a8", "wfp8e4m3afp8kvfp8",
                 "w4kv8", "w16", "wf32", "w8", "wnf4kv8dq", "wfp8kvfp8",
                 "w8a8kv8", "w4a8kv8e16g32", "wfp8e5m2kv8",
                 "w8a8kv8x8", "w4kv8xfp8", "w16x8", "w8a8kv8x8e16g32"]


def _smoke_params():
    rc = reduce_config(REGISTRY["nllb600m"])
    return rc, build_model(rc).init(jax.random.PRNGKey(0))


def _tree():
    key = jax.random.PRNGKey(3)
    return {"layers": {"attn": {"wq": jax.random.normal(key, (128, 64))},
                       "norm1_scale": jnp.ones((64,))},
            "embedding": jax.random.normal(key, (96, 64))}


# -- grammar ----------------------------------------------------------------

@pytest.mark.parametrize("text", GRAMMAR_CASES)
def test_grammar_round_trips(text):
    spec = QuantSpec.parse(text)
    assert QuantSpec.parse(str(spec)) == spec
    # acceptance criterion, literally:
    assert QuantSpec.parse(text) == QuantSpec.parse(str(QuantSpec.parse(text)))


def test_grammar_fields():
    s = QuantSpec.parse("w4a8kv8")
    assert (s.weights, s.act, s.kv, s.embed) == ("int4", "int8", "int8",
                                                 "int8")
    s = QuantSpec.parse("wfp8e4m3afp8kvfp8")
    assert (s.weights, s.act, s.kv) == ("fp8", "fp8", "fp8")
    assert QuantSpec.parse("w8a8kv8g32").group == 32
    assert QuantSpec.parse("w8a8").group == 0          # per-channel default
    assert QuantSpec.parse("w8").group == 64
    assert QuantSpec.parse("wnf4kv8dq").double_quant


def test_x_slot_routes_attention_matmuls():
    """x<fmt> is the attention QK/PV activation format: orthogonal to
    the weight tree (act x act products never touch qmatmul), so it
    composes with any weight format — including unquantized w16."""
    s = QuantSpec.parse("w8a8kv8x8")
    assert s.attn == "int8" and s.quantizes_attn
    assert str(s) == "w8a8kv8x8"                       # canonical slot order
    assert QuantSpec.parse("w4kv8xfp8").attn == "fp8"
    assert QuantSpec.parse("w16x8").weights == "bf16"  # no weight tree needed
    # default: attention stays bf16 and the token is never emitted
    assert QuantSpec.parse("w8a8kv8").attn == "bf16"
    assert not QuantSpec.parse("w8a8kv8").quantizes_attn
    assert "x" not in str(QuantSpec.parse("w8a8kv8"))
    with pytest.raises(ValueError, match="attention-matmul"):
        QuantSpec(weights="int8", attn="int4")         # not an act format
    with pytest.raises(ValueError):
        QuantSpec.parse("w8x4")                        # rejected in-grammar


def test_bad_specs_raise_with_choices():
    for bad in ("int9", "w4a7", "kv8", "w4x", ""):
        with pytest.raises(ValueError) as e:
            resolve_spec(bad)
        msg = str(e.value)
        assert repr(bad) in msg           # names the bad spec
        assert "int4" in msg              # lists aliases/formats
    with pytest.raises(TypeError):
        resolve_spec(42)


def test_act_quant_requires_quantized_weights():
    """w16a8 / wf32a8 would deploy a zero-QTensor tree whose matmuls
    never quantize activations — the spec must refuse, not silently
    mean bf16."""
    for bad in ("w16a8", "wf32a8", "w16afp8"):
        with pytest.raises(ValueError, match="passthrough"):
            resolve_spec(bad)
    with pytest.raises(ValueError, match="passthrough"):
        QuantSpec(weights="bf16", act="int8")


def test_bytes_per_param_from_spec():
    bpp = resolve_spec("w4a8kv8").bytes_per_param
    assert bpp == {"weights": 0.5, "embed": 1.0, "kv": 1.0}
    assert resolve_spec("bf16").bytes_per_param["weights"] == 2.0


# -- legacy preset equivalence ----------------------------------------------

@pytest.mark.parametrize("name", sorted(_LEGACY))
def test_alias_policy_matches_legacy_table(name):
    assert PRESETS[name] == _LEGACY[name]
    assert resolve_spec(name).policy(name=name) == _LEGACY[name]
    # the resolved grammar string re-resolves to the same deployment
    # (name differs; every quantization-relevant field is equal)
    rt = resolve_spec(str(resolve_spec(name))).policy()
    import dataclasses
    for f in dataclasses.fields(PrecisionPolicy):
        if f.name != "name":
            assert getattr(rt, f.name) == getattr(_LEGACY[name], f.name), \
                (name, f.name)


@pytest.mark.parametrize("name", ["int4", "w8a8", "nf4", "fp8"])
def test_alias_tree_bytes_identical(name):
    params = _tree()
    qa = quantize_tree(params, _LEGACY[name])
    qb = quantize_tree(params, resolve_spec(name).policy())
    assert tree_nbytes(qa) == tree_nbytes(qb)
    wa, wb = qa["layers"]["attn"]["wq"], qb["layers"]["attn"]["wq"]
    assert isinstance(wa, QTensor) and wa.fmt == wb.fmt
    np.testing.assert_array_equal(np.asarray(wa.data), np.asarray(wb.data))


def test_alias_and_grammar_decode_identically():
    """deploy("int4") and deploy("w4kv8") are the same deployment:
    token-for-token equal greedy decodes (the alias-compat acceptance
    criterion observed end to end)."""
    rc, params = _smoke_params()
    ds = SyntheticTranslation(rc.vocab_size, rc.enc_len, seed=0)
    b = ds.sample(2)
    streams = {}
    for spec in ("int4", "w4kv8"):
        pipe = deploy(rc, spec, params=params, slots=2, max_len=16,
                      ctx=Ctx(compute_dtype=jnp.float32))
        outs = pipe.translate(jnp.asarray(b["src_tokens"]), "eng",
                              SamplingParams(max_new_tokens=6))
        streams[spec] = [o.token_ids for o in outs]
        assert pipe.quantized_bytes == tree_nbytes(
            quantize_tree(params, _LEGACY["int4"]))
    assert streams["int4"] == streams["w4kv8"]


# -- per-site calibration ---------------------------------------------------

def _calib_batches(rc, n=2, batch=4, seed=0):
    ds = SyntheticTranslation(rc.vocab_size, rc.enc_len, seed=seed)
    return ({k: jnp.asarray(v) for k, v in ds.sample(batch).items()
             if not isinstance(v, str)} for _ in range(n))


def test_site_stats_merge_is_max_associative():
    obs = [("a", 1.0), ("b", 3.0), ("a", 2.0), ("c", 0.5), ("b", 1.0)]
    regs = []
    for chunk in (obs[:2], obs[2:4], obs[4:]):
        r = ActSiteStats()
        for site, v in chunk:
            r.update(site, v)
        regs.append(r)
    a, b, c = regs
    left = a.merge(b).merge(c)
    right = a.merge(b.merge(c))
    assert left.absmax == right.absmax == {"a": 2.0, "b": 3.0, "c": 0.5}
    assert b.merge(a).absmax == a.merge(b).absmax      # commutative too
    assert left.scales(127.0)["b"] == pytest.approx(3.0 / 127.0)


def test_calibration_deterministic_and_per_site():
    rc, params = _smoke_params()

    def scales_of():
        pipe = deploy(rc, "w8a8", params=params, slots=2, max_len=16,
                      ctx=Ctx(compute_dtype=jnp.float32),
                      calib_batches=_calib_batches(rc))
        return dict(pipe.ctx.act_scales)

    s1, s2 = scales_of(), scales_of()
    assert s1 == s2                                    # deterministic
    # distinct matmul sites observed, with genuinely different scales
    assert {"enc.attn.qkv", "dec.ffn.in"} <= set(s1), sorted(s1)
    assert len(set(s1.values())) >= 2
    assert all(v > 0 for v in s1.values())


def test_a8_without_calib_warns_and_stays_dynamic():
    """Regression for the silent-bf16-activations bug class: an a8 spec
    with zero calibration batches must fall back to *dynamic* act
    quantization — loudly — and still serve."""
    rc, params = _smoke_params()
    for calib in (None, iter(())):                    # absent and empty
        with pytest.warns(UserWarning, match="dynamic per-token"):
            pipe = deploy(rc, "w8a8", params=params, slots=1, max_len=16,
                          ctx=Ctx(compute_dtype=jnp.float32),
                          calib_batches=calib)
        assert pipe.ctx.act_scales is None
        assert pipe.ctx.act_fmt == "int8"             # still quantizing
    ds = SyntheticTranslation(rc.vocab_size, rc.enc_len, seed=0)
    outs = pipe.translate(jnp.asarray(ds.sample(1)["src_tokens"]), "eng",
                          SamplingParams(max_new_tokens=4))
    assert outs[0].token_ids


def test_bf16_spec_never_warns():
    rc, params = _smoke_params()
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        deploy(rc, "int8", params=params, slots=1, max_len=16,
               ctx=Ctx(compute_dtype=jnp.float32))


# -- fp8 end to end ---------------------------------------------------------

def test_fp8_arm_serves_through_paged_fp8_pools():
    rc, params = _smoke_params()
    pipe = deploy(rc, "wfp8e4m3afp8kvfp8", params=params, slots=2,
                  max_len=16, paged=True, page_size=4,
                  ctx=Ctx(compute_dtype=jnp.float32),
                  calib_batches=_calib_batches(rc))
    cache = pipe.engine.cache
    assert cache["k"].dtype == jnp.float8_e4m3fn       # real fp8 pages
    assert cache["cross_k"].dtype == jnp.float8_e4m3fn
    assert "k_scales" in cache and "k_codes" not in cache
    assert dict(pipe.ctx.act_scales)                   # calibrated afp8
    ds = SyntheticTranslation(rc.vocab_size, rc.enc_len, seed=0)
    outs = pipe.translate(jnp.asarray(ds.sample(3)["src_tokens"]), "eng",
                          SamplingParams(max_new_tokens=6))
    assert len(outs) == 3 and all(o.token_ids for o in outs)
    assert pipe.engine.kv_cache_bytes > 0


def test_fp8_dense_paged_same_tokens():
    """fp8 KV: the paged engine reproduces the dense engine's streams
    (the PR 2 equivalence contract extended to fp8 pages)."""
    rc, params = _smoke_params()
    ds = SyntheticTranslation(rc.vocab_size, rc.enc_len, seed=0)
    b = ds.sample(2)
    streams = {}
    for paged in (False, True):
        pipe = deploy(rc, "fp8e2e", params=params, slots=2, max_len=16,
                      paged=paged, page_size=4,
                      ctx=Ctx(compute_dtype=jnp.float32))
        outs = pipe.translate(jnp.asarray(b["src_tokens"]), "ita",
                              SamplingParams(max_new_tokens=6))
        streams[paged] = [o.token_ids for o in outs]
    assert streams[False] == streams[True]


# -- x<fmt> end to end ------------------------------------------------------

def test_x8_attention_sites_calibrate_and_serve():
    """w8a8kv8x8 end to end: calibration observes both QK/PV operands
    per attention tower (the x slot routes through Ctx.attn_dot, not
    the weight tree), a spec without the slot never touches those
    sites, and the deployment serves."""
    rc, params = _smoke_params()
    pipe = deploy(rc, "w8a8kv8x8", params=params, slots=2, max_len=16,
                  ctx=Ctx(compute_dtype=jnp.float32),
                  calib_batches=_calib_batches(rc))
    assert pipe.ctx.attn_act_fmt == "int8"
    scales = dict(pipe.ctx.act_scales)
    assert {"enc.attn.qk.a", "enc.attn.qk.b", "enc.attn.pv.a",
            "enc.attn.pv.b", "dec.attn.qk.a", "dec.cross.pv.b"} \
        <= set(scales), sorted(scales)
    assert all(v > 0 for v in scales.values())
    base = deploy(rc, "w8a8kv8", params=params, slots=2, max_len=16,
                  ctx=Ctx(compute_dtype=jnp.float32),
                  calib_batches=_calib_batches(rc))
    assert not any(".qk." in s or ".pv." in s
                   for s in dict(base.ctx.act_scales))
    ds = SyntheticTranslation(rc.vocab_size, rc.enc_len, seed=0)
    outs = pipe.translate(jnp.asarray(ds.sample(2)["src_tokens"]), "eng",
                          SamplingParams(max_new_tokens=4))
    assert len(outs) == 2 and all(o.token_ids for o in outs)


def test_sweep_reports_resolved_spec_strings():
    rc, params = _smoke_params()
    rows = quant_sweep(
        rc, ["bf16", "wfp8e4m3afp8kvfp8"], params=params,
        pair_list=[("hin", "eng")], languages=["hin", "eng"], n_sent=2,
        deploy_kwargs={"slots": 2, "max_len": 16, "paged": True,
                       "page_size": 4,
                       "ctx": Ctx(compute_dtype=jnp.float32)},
        log=lambda *_: None)
    by_fmt = {r.fmt: r for r in rows}
    fp8 = by_fmt["wfp8e4m3afp8kvfp8"]
    assert fp8.spec == "wfp8a8kvfp8" or fp8.spec == str(
        resolve_spec("wfp8e4m3afp8kvfp8"))
    assert by_fmt["bf16"].spec == "w16"
    assert fp8.bleu_delta is not None                  # anchored delta
    assert fp8.model_bytes < by_fmt["bf16"].model_bytes
    assert fp8.mean_tok_s > 0
    d = fp8.as_row()
    assert d["spec"] == fp8.spec                       # lands in reports


def test_report_v1_shim_upgrades_rows():
    from repro.eval import report
    v1 = report.dump({"schema": 1, "kind": "repro.eval", "arch": "x",
                      "git_rev": None, "config": {},
                      "rows": [{"fmt": "int4", "mean_bleu": 1.0},
                               {"fmt": "mystery", "mean_bleu": 0.5}]})
    r = report.load(v1)
    assert r["schema"] == report.SCHEMA_VERSION
    assert r["rows"][0]["spec"] == "w4kv8"             # alias resolved
    assert r["rows"][1]["spec"] == "mystery"           # graceful fallback
    assert report.load(report.dump(r)) == r            # still round-trips


def test_aliases_cover_presets():
    assert set(ALIASES) == set(PRESETS)
