"""Optimizer substrate: AdamW, 8-bit states, schedules, grad compression."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.optim import (adamw_init, adamw_update, compressed_psum,
                         warmup_cosine, warmup_linear)


def _run_adam(state_bits, steps=25):
    rng = np.random.default_rng(0)
    params = {"w": jnp.asarray(rng.standard_normal((64, 32)), jnp.float32)}
    state = adamw_init(params, state_bits=state_bits)
    traj = []
    for i in range(steps):
        g = {"w": jnp.asarray(rng.standard_normal((64, 32)), jnp.float32)}
        params, state, _ = adamw_update(g, state, params, lr=1e-2,
                                        state_bits=state_bits)
        traj.append(np.asarray(params["w"]))
    return traj


def test_8bit_states_track_fp32():
    """Blockwise-int8 moments stay close to the fp32 optimizer trajectory."""
    t32 = _run_adam(32)
    t8 = _run_adam(8)
    rel = np.linalg.norm(t8[-1] - t32[-1]) / np.linalg.norm(t32[-1])
    assert rel < 0.05, rel


def test_8bit_state_memory():
    params = {"w": jnp.zeros((1024, 256), jnp.float32)}
    s32 = adamw_init(params, state_bits=32)
    s8 = adamw_init(params, state_bits=8)
    b32 = sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(s32))
    b8 = sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(s8))
    assert b8 < 0.35 * b32   # ~2.06 vs 8 bytes/param


def test_master_weights_update_bf16_params():
    params = {"w": jnp.ones((32, 16), jnp.bfloat16)}
    state = adamw_init(params, master=True)
    g = {"w": jnp.full((32, 16), 0.5, jnp.float32)}
    new_p, new_s, _ = adamw_update(g, state, params, lr=1e-3)
    assert new_p["w"].dtype == jnp.bfloat16
    assert new_s["master"]["w"].dtype == jnp.float32
    assert float(new_s["master"]["w"][0, 0]) < 1.0   # actually stepped


def test_grad_clipping():
    params = {"w": jnp.zeros((8,), jnp.float32)}
    state = adamw_init(params)
    g = {"w": jnp.full((8,), 100.0)}
    _, _, m = adamw_update(g, state, params, lr=1e-3, clip_norm=1.0)
    assert float(m["grad_norm"]) > 100


def test_schedules_shape():
    lr = [float(warmup_linear(s, peak_lr=1.0, warmup=10, total=100))
          for s in range(100)]
    assert lr[0] == 0 and abs(lr[10] - 1.0) < 1e-6 and lr[-1] < 0.05
    lc = [float(warmup_cosine(s, peak_lr=1.0, warmup=10, total=100))
          for s in range(100)]
    assert max(lc) <= 1.0 + 1e-6 and lc[50] > lc[90]


def test_compressed_psum_single_device():
    """shard_map over a 1-device mesh: compression is near-lossless psum."""
    mesh = jax.make_mesh((1,), ("dp",))
    g = {"w": jnp.asarray(np.random.default_rng(0).standard_normal((256, 8)),
                          jnp.float32)}

    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    f = shard_map(lambda t: compressed_psum(t, "dp"), mesh=mesh,
                  in_specs=(P(),), out_specs=P())
    out = f(g)
    rel = float(jnp.linalg.norm(out["w"] - g["w"]) / jnp.linalg.norm(g["w"]))
    assert rel < 2e-2   # int8 grid error only
