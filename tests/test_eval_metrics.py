"""Golden tests for the native BLEU / chrF implementations.

Every expected value below is hand-computed from the metric definitions
(clipped n-gram precisions, brevity penalty, smoothing; chrF averaged
precision/recall then F_beta) so the implementation is validated against
the math, not against itself.
"""

import math

import pytest

from repro.eval.metrics import (BleuStat, ChrFStat, CorpusStat, corpus_bleu,
                                corpus_chrf, exact_match, token_accuracy)

# ---------------------------------------------------------------------------
# BLEU
# ---------------------------------------------------------------------------


def test_bleu_identical_corpus_is_one():
    hyps = [[1, 2, 3, 4, 5], [6, 7, 8, 9]]
    s = corpus_bleu(hyps, hyps)
    assert s.score == pytest.approx(1.0)
    assert s.brevity_penalty == 1.0
    assert all(p == 1.0 for p in s.precisions)


def test_bleu_hand_computed_add_k():
    # hyp [1,2,3,4] vs ref [1,2,3,5]:
    #   p1 = 3/4; raw p2 = 2/3, p3 = 1/2, p4 = 0/1
    #   add-k (k=1, orders>1): p2 = 3/4, p3 = 2/3, p4 = 1/2; BP = 1
    #   bleu = (0.75 * 0.75 * (2/3) * 0.5) ** 0.25
    s = corpus_bleu([[1, 2, 3, 4]], [[1, 2, 3, 5]])
    expected = (0.75 * 0.75 * (2 / 3) * 0.5) ** 0.25
    assert s.score == pytest.approx(expected)
    assert s.precisions == pytest.approx((0.75, 0.75, 2 / 3, 0.5))


def test_bleu_no_smoothing_zero_on_missing_order():
    # same pair without smoothing: p4 = 0 -> geometric mean collapses
    s = corpus_bleu([[1, 2, 3, 4]], [[1, 2, 3, 5]], smoothing="none")
    assert s.score == 0.0
    assert s.precisions[3] == 0.0


def test_bleu_floor_smoothing():
    # floor replaces the zero order with eps/total = 0.1/1
    s = corpus_bleu([[1, 2, 3, 4]], [[1, 2, 3, 5]], smoothing="floor")
    expected = (0.75 * (2 / 3) * 0.5 * 0.1) ** 0.25
    assert s.score == pytest.approx(expected)


def test_bleu_brevity_penalty():
    # hyp [1,2] vs ref [1,2,3] at max_n=2: p1 = 1, p2 = 1 (the single
    # hyp bigram appears in ref); BP = exp(1 - 3/2)
    s = corpus_bleu([[1, 2]], [[1, 2, 3]], max_n=2, smoothing="none")
    assert s.brevity_penalty == pytest.approx(math.exp(-0.5))
    assert s.score == pytest.approx(math.exp(-0.5))
    # no penalty when the hypothesis is longer
    s2 = corpus_bleu([[1, 2, 3]], [[1, 2]], max_n=1, smoothing="none")
    assert s2.brevity_penalty == 1.0


def test_bleu_clipping():
    # hyp repeats a token 4x, ref holds it 2x: clipped p1 = 2/4
    s = corpus_bleu([[7, 7, 7, 7]], [[7, 7]], max_n=1, smoothing="none")
    assert s.precisions[0] == pytest.approx(0.5)


def test_bleu_empty_inputs_score_zero():
    assert corpus_bleu([], []).score == 0.0
    assert corpus_bleu([[]], [[1, 2]]).score == 0.0
    assert corpus_bleu([[1, 2]], [[]]).score == 0.0   # ref empty: BP = 1, but
    # every order has zero reference matches beyond... p1 = 0 -> score 0
    with pytest.raises(ValueError):
        corpus_bleu([[1]], [])                        # length mismatch


def test_bleu_streaming_matches_batch_and_merge():
    hyps = [[1, 2, 3, 4], [5, 6, 7], [1, 2]]
    refs = [[1, 2, 3, 5], [5, 6, 8], [1, 2, 3]]
    batch = corpus_bleu(hyps, refs)
    one = BleuStat()
    for h, r in zip(hyps, refs):
        one.update(h, r)
    assert one.score().score == pytest.approx(batch.score)
    a, b = BleuStat(), BleuStat()
    a.update(hyps[0], refs[0])
    b.update(hyps[1], refs[1])
    b.update(hyps[2], refs[2])
    assert a.merge(b).score().score == pytest.approx(batch.score)


def test_bleu_detok_words():
    detok = lambda ids: " ".join("w%d" % i for i in ids)   # noqa: E731
    s = corpus_bleu([[1, 2, 3]], [[1, 2, 3]], detok=detok)
    assert s.score == pytest.approx(1.0)


# ---------------------------------------------------------------------------
# chrF
# ---------------------------------------------------------------------------


def test_chrf_hand_computed():
    # hyp [1,2,3] vs ref [1,2,4] at max_n=2:
    #   order1: matches 2 of 3/3 -> P1 = R1 = 2/3
    #   order2: matches 1 of 2/2 -> P2 = R2 = 1/2
    #   avgP = avgR = 7/12; F_2 = P when P == R
    val = corpus_chrf([[1, 2, 3]], [[1, 2, 4]], max_n=2)
    assert val == pytest.approx(7 / 12)


def test_chrf_identical_is_one_and_empty_is_zero():
    assert corpus_chrf([[1, 2, 3, 4, 5, 6, 7]],
                       [[1, 2, 3, 4, 5, 6, 7]]) == pytest.approx(1.0)
    assert corpus_chrf([], []) == 0.0
    assert corpus_chrf([[]], [[1, 2]]) == 0.0


def test_chrf_short_sequences_skip_absent_orders():
    # 2-token sequences have no n-grams for n > 2: those orders must be
    # skipped, not counted as zero-precision
    assert corpus_chrf([[1, 2]], [[1, 2]], max_n=6) == pytest.approx(1.0)


def test_chrf_beta_weights_recall():
    # hyp misses a ref token (recall hurt, precision perfect):
    # max_n=1: P = 1, R = 1/2; F_2 = 5PR/(4P+R) = 2.5/4.5
    v = corpus_chrf([[1]], [[1, 2]], max_n=1)
    assert v == pytest.approx(5 * 1 * 0.5 / (4 * 1 + 0.5))
    # beta=1 (harmonic mean) scores higher than beta=2 here
    v1 = corpus_chrf([[1]], [[1, 2]], max_n=1, beta=1.0)
    assert v1 == pytest.approx(2 * 1 * 0.5 / (1 + 0.5))
    assert v1 > v


def test_chrf_plus_plus_word_order():
    # word_order=2 adds word n-gram slots; identical streams stay 1.0
    assert corpus_chrf([[1, 2, 3]], [[1, 2, 3]], max_n=2,
                       word_order=2) == pytest.approx(1.0)
    # detok: chars come from the string, words from the split
    detok = lambda ids: " ".join(str(i) for i in ids)      # noqa: E731
    v = corpus_chrf([[1, 2, 3]], [[1, 2, 3]], word_order=2, detok=detok)
    assert v == pytest.approx(1.0)


def test_chrf_streaming_matches_batch_and_merge():
    hyps = [[1, 2, 3, 4], [5, 6, 7]]
    refs = [[1, 2, 3, 5], [5, 6, 8]]
    batch = corpus_chrf(hyps, refs)
    a, b = ChrFStat(), ChrFStat()
    a.update(hyps[0], refs[0])
    b.update(hyps[1], refs[1])
    assert a.merge(b).score() == pytest.approx(batch)


# ---------------------------------------------------------------------------
# token accuracy / exact match / combined accumulator
# ---------------------------------------------------------------------------


def test_token_accuracy_and_exact_match():
    assert token_accuracy([1, 2, 3], [1, 2, 3]) == 1.0
    assert token_accuracy([1, 2, 3], [1, 9, 3]) == pytest.approx(2 / 3)
    # length mismatch counts against the longer side
    assert token_accuracy([1, 2], [1, 2, 3, 4]) == pytest.approx(0.5)
    assert token_accuracy([], []) == 1.0
    assert exact_match([1, 2], [1, 2])
    assert not exact_match([1, 2], [1, 2, 3])


def test_corpus_stat_bundles_all_metrics():
    hyps = [[1, 2, 3, 4], [5, 6, 7]]
    refs = [[1, 2, 3, 4], [5, 6, 8]]
    st = CorpusStat()
    for h, r in zip(hyps, refs):
        st.update(h, r)
    res = st.results()
    assert res["bleu"] == pytest.approx(corpus_bleu(hyps, refs).score)
    assert res["chrf"] == pytest.approx(corpus_chrf(hyps, refs))
    assert res["exact_match"] == 0.5
    assert res["token_acc"] == pytest.approx((1.0 + 2 / 3) / 2)
    other = CorpusStat()
    other.update([9], [9])
    st.merge(other)
    assert st.n_sent == 3
