"""qmm Pallas kernel vs pure-jnp oracle: shape/dtype/format sweep."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import QTensor
from repro.kernels import ops, ref


def _case(m, k, n, fmt, block, seed=0):
    rng = np.random.default_rng(seed)
    w = jnp.asarray(rng.standard_normal((k, n)), jnp.float32) * 0.05
    x = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)
    qt = QTensor.quantize(w, fmt, block_size=block)
    y = ops.qmm(x, qt, compute_dtype=jnp.float32)
    yr = ref.qmm_ref(x, qt.data, qt.block_scales(), fmt)
    rel = float(jnp.linalg.norm(y - yr) / (jnp.linalg.norm(yr) + 1e-9))
    return rel


@pytest.mark.parametrize("fmt", ["int4", "fp4", "nf4", "int8"])
@pytest.mark.parametrize("m,k,n,block", [
    (8, 128, 64, 32),
    (48, 256, 128, 64),
    (1, 64, 96, 16),       # decode-like single row
    (130, 512, 256, 128),  # M not tile-aligned -> padding path
])
def test_qmm_matches_oracle(fmt, m, k, n, block):
    # bf16 MXU vs f32 oracle: tolerance covers bf16 mantissa rounding
    assert _case(m, k, n, fmt, block) < 6e-3


def test_qmm_batched_input_reshape():
    rng = np.random.default_rng(1)
    w = jnp.asarray(rng.standard_normal((128, 64)), jnp.float32) * 0.05
    qt = QTensor.quantize(w, "int4", 32)
    x = jnp.asarray(rng.standard_normal((2, 3, 128)), jnp.float32)
    y = ops.qmm(x, qt, compute_dtype=jnp.float32)
    assert y.shape == (2, 3, 64)
    yr = ref.qmm_ref(x.reshape(-1, 128), qt.data, qt.block_scales(), "int4")
    assert float(jnp.linalg.norm(y.reshape(-1, 64) - yr)
                 / jnp.linalg.norm(yr)) < 6e-3


def test_qmm_whole_dim_block():
    """block_size > K falls back to one block per column."""
    assert _case(16, 96, 32, "int8", 0) < 6e-3


def test_qlinear_pallas_path_matches_xla_path():
    from repro.core.qlinear import qmatmul
    rng = np.random.default_rng(2)
    w = jnp.asarray(rng.standard_normal((256, 128)), jnp.float32) * 0.03
    x = jnp.asarray(rng.standard_normal((8, 256)), jnp.float32)
    qt = QTensor.quantize(w, "nf4", 64)
    y_xla = qmatmul(x, qt, compute_dtype=jnp.float32, impl="xla")
    y_pl = qmatmul(x, qt, compute_dtype=jnp.float32, impl="pallas")
    assert float(jnp.max(jnp.abs(y_xla - y_pl))) < 0.05 * float(
        jnp.max(jnp.abs(y_xla)) + 1e-9)
