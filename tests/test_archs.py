"""Per-architecture smoke tests (assignment deliverable f).

For each of the 10 assigned archs (+ the paper's NLLB configs): a REDUCED
config of the same family runs one forward and one train step on CPU;
output shapes and finiteness are asserted. Full configs are exercised only
via the dry-run.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import (ASSIGNED, REGISTRY, SHAPES, input_specs,
                           param_count, reduce_config, supported_shapes)
from repro.data import make_batch
from repro.models import Ctx, build_model
from repro.train import make_train_step

ARCHS = list(REGISTRY)


def _smoke_batch(rc, B=2, S=16):
    class _Spec:
        seq_len = S
        global_batch = B
    b = make_batch(rc, _Spec, seed=0)
    return {k: jnp.asarray(v) for k, v in b.items()
            if not isinstance(v, str)}


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finite(arch):
    rc = reduce_config(REGISTRY[arch])
    model = build_model(rc)
    params = model.init(jax.random.PRNGKey(0))
    batch = _smoke_batch(rc)
    logits, aux = model.forward(Ctx(compute_dtype=jnp.float32), params, batch)
    tok = batch.get("tokens", batch.get("tgt_in"))
    S_exp = tok.shape[1] + (rc.num_patches if rc.family == "vlm" else 0)
    assert logits.shape == (tok.shape[0], S_exp, rc.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits))), "non-finite logits"
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ARCHS)
def test_one_train_step(arch):
    rc = reduce_config(REGISTRY[arch])
    model = build_model(rc)
    init_state, step = make_train_step(
        model, lr_fn=lambda s: 1e-3, remat=True,
        ctx=Ctx(compute_dtype=jnp.float32))
    state = init_state(model.init(jax.random.PRNGKey(0)))
    batch = _smoke_batch(rc)
    state, metrics = jax.jit(step)(state, batch)
    assert bool(jnp.isfinite(metrics["loss"])), metrics
    assert float(metrics["grad_norm"]) > 0


@pytest.mark.parametrize("arch", ASSIGNED)
def test_input_specs_cover_supported_shapes(arch):
    cfg = REGISTRY[arch]
    shapes = supported_shapes(cfg)
    assert "train_4k" in shapes and "decode_32k" in shapes
    if cfg.family in ("ssm", "hybrid"):
        assert "long_500k" in shapes
    else:
        assert "long_500k" not in shapes
    for s in shapes:
        specs = input_specs(cfg, s)
        sp = SHAPES[s]
        for v in specs.values():
            assert v.shape[0] == sp.global_batch


def test_param_counts_match_assignment_scale():
    """Analytic totals stay near the names' advertised sizes."""
    expect = {"mamba2-780m": 0.78, "nemotron-4-15b": 15.6,
              "internlm2-20b": 19.9, "qwen2.5-14b": 14.8, "gemma3-1b": 1.0,
              "olmoe-1b-7b": 6.9, "llava-next-mistral-7b": 7.2,
              "whisper-base": 0.072, "recurrentgemma-9b": 9.4}
    for name, b in expect.items():
        got = param_count(REGISTRY[name]) / 1e9
        assert abs(got - b) / b < 0.15, (name, got, b)


def test_gemma3_window_pattern():
    from repro.models.transformer import window_array
    cfg = REGISTRY["gemma3-1b"]
    w = np.asarray(window_array(cfg))
    assert len(w) == 26
    assert (w[5::6] == 0).all()          # every 6th layer global
    assert (w[w > 0] == 512).all()       # locals use the 512 window
    assert (w > 0).sum() == 26 - len(w[5::6])
