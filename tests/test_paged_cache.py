"""Paged KV serving: allocator invariants, paged==dense decode, reclaim.

The headline contract of the paged engine (ISSUE 2): block-paged decode
is token-for-token identical to the dense-cache engine at bf16 and int8
KV, pages are reclaimed on EOS/abort with zero leaks, mixed source
lengths share one enc-dec engine, and continuous paged admission keeps
occupancy at or above the dense baseline.
"""

import jax
import jax.numpy as jnp
import pytest

from repro.configs import REGISTRY, reduce_config
from repro.models import Ctx, build_model
from repro.serving import PageAllocator, SamplingParams, ServeEngine, deploy
from repro.serving.paged_cache import pages_needed

CTX = Ctx(compute_dtype=jnp.float32)


def _lm(name="gemma3-1b"):
    rc = reduce_config(REGISTRY[name])
    model = build_model(rc)
    params = model.init(jax.random.PRNGKey(0))
    return rc, model, params


# ---------------------------------------------------------------------------
# allocator
# ---------------------------------------------------------------------------

def test_allocator_basic_invariants():
    a = PageAllocator(9)                     # 8 usable, page 0 reserved
    assert a.num_free == 8 and a.pages_in_use == 0
    c1 = a.alloc_chain(3)
    c2 = a.alloc_chain(2)
    assert len(set(c1) | set(c2)) == 5       # disjoint chains
    assert 0 not in c1 + c2                  # trash page never handed out
    assert a.pages_in_use == 5 and a.num_free == 3
    a.free_chain(c1)
    a.check()
    assert a.num_free == 6
    c3 = a.alloc_chain(6)
    assert set(c3) & set(c1) == set(c1)      # freed pages are reusable
    with pytest.raises(MemoryError, match="exhausted"):
        a.alloc_chain(1)


def test_allocator_double_free_raises():
    a = PageAllocator(5)
    c = a.alloc_chain(2)
    a.free_chain(c)
    with pytest.raises(ValueError, match="free"):
        a.free_chain(c)
    with pytest.raises(ValueError, match="free"):
        a.free_chain([4])                    # never allocated
    with pytest.raises(ValueError, match="duplicate"):
        a.free_chain(a.alloc_chain(2) * 2)


def test_pages_needed():
    assert pages_needed(0, 4) == 0
    assert pages_needed(1, 4) == 1
    assert pages_needed(4, 4) == 1
    assert pages_needed(5, 4) == 2


# ---------------------------------------------------------------------------
# paged engine == dense engine, token for token
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kv", ["bf16", "int8"])
def test_paged_matches_dense_token_for_token(kv):
    rc, model, params = _lm()
    prompts = [jax.random.randint(jax.random.PRNGKey(i), (1, 3 + i % 5), 0,
                                  rc.vocab_size) for i in range(6)]
    sp = SamplingParams(max_new_tokens=5)

    dense = ServeEngine(model, params, slots=2, max_len=16, kv_dtype=kv,
                        ctx=CTX)
    ids_d = [dense.submit({"tokens": p}, sp) for p in prompts]
    outs_d = {o.request_id: o for o in dense.run_until_drained()}

    paged = ServeEngine(model, params, slots=2, max_len=16, kv_dtype=kv,
                        ctx=CTX, paged=True, page_size=4)
    ids_p = [paged.submit({"tokens": p}, sp) for p in prompts]
    outs_p = {o.request_id: o for o in paged.run_until_drained()}

    for a, b in zip(ids_d, ids_p):
        assert outs_d[a].token_ids == outs_p[b].token_ids
    assert paged.allocator.pages_in_use == 0   # everything reclaimed
    paged.allocator.check()


def test_paged_encdec_matches_dense_int8():
    pipe_p = deploy("nllb600m", "int8", slots=2, max_len=16, smoke=True,
                    paged=True, page_size=4)
    pipe_d = deploy("nllb600m", "int8", slots=2, max_len=16, smoke=True)
    cfg = pipe_p.cfg
    src = jax.random.randint(jax.random.PRNGKey(1), (3, cfg.enc_len), 0,
                             cfg.vocab_size)
    sp = SamplingParams(max_new_tokens=6)
    outs_p = pipe_p.translate(src, "ita", sp)
    outs_d = pipe_d.translate(src, "ita", sp)
    assert [o.token_ids for o in outs_p] == [o.token_ids for o in outs_d]
    assert pipe_p.engine.allocator.pages_in_use == 0


def test_paged_sampled_stream_matches_dense():
    """Same seed, same stream — independent of paging and slot layout."""
    rc, model, params = _lm()
    p = jax.random.randint(jax.random.PRNGKey(5), (1, 5), 0, rc.vocab_size)
    sp = SamplingParams(temperature=0.9, top_k=8, top_p=0.9,
                        max_new_tokens=5, seed=11)

    def run(**kw):
        eng = ServeEngine(model, params, slots=2, max_len=16, ctx=CTX, **kw)
        rid = eng.submit({"tokens": p}, sp)
        return {o.request_id: o for o in eng.run_until_drained()}[rid]

    assert run().token_ids == run(paged=True, page_size=4).token_ids


# ---------------------------------------------------------------------------
# reclaim / leak behaviour
# ---------------------------------------------------------------------------

def test_no_leaked_pages_after_abort_and_eos():
    rc, model, params = _lm()
    eng = ServeEngine(model, params, slots=2, max_len=16, ctx=CTX,
                      paged=True, page_size=4)
    p = jax.random.randint(jax.random.PRNGKey(0), (1, 4), 0, rc.vocab_size)
    r1 = eng.submit({"tokens": p}, SamplingParams(max_new_tokens=8))
    ref = {o.request_id: o for o in eng.run_until_drained()}[r1]
    eos = ref.token_ids[2]                   # a token the stream emits

    r_eos = eng.submit({"tokens": p},
                       SamplingParams(max_new_tokens=8, eos_id=eos))
    r_abort = eng.submit({"tokens": p}, SamplingParams(max_new_tokens=8))
    collected = eng.step()                   # admits both requests
    assert eng.allocator.pages_in_use > 0
    out = eng.abort(r_abort)
    assert out.finish_reason == "abort"
    outs = {o.request_id: o
            for o in collected + [out] + eng.run_until_drained()}
    assert outs[r_eos].finish_reason == "eos"
    assert eng.allocator.pages_in_use == 0
    eng.allocator.check()
    # the freed pages are immediately reusable for a fresh request
    r2 = eng.submit({"tokens": p}, SamplingParams(max_new_tokens=8))
    outs = {o.request_id: o for o in eng.run_until_drained()}
    assert outs[r2].token_ids == ref.token_ids


def test_admission_waits_for_pages_then_resumes():
    """A pool smaller than the burst forces queueing; freed pages admit
    the queue mid-flight (continuous batching) and nothing starves."""
    rc, model, params = _lm()
    # pool fits exactly one request's budget (4 prompt + 4 gen = 2 pages)
    eng = ServeEngine(model, params, slots=2, max_len=16, ctx=CTX,
                      paged=True, page_size=4, num_pages=2)
    p = jax.random.randint(jax.random.PRNGKey(1), (1, 4), 0, rc.vocab_size)
    sp = SamplingParams(max_new_tokens=4)
    ids = [eng.submit({"tokens": p}, sp) for _ in range(3)]
    eng.step()
    # two free slots but pages for only one request: one admitted
    assert eng.num_active == 1 and eng.num_pending == 2
    outs = {o.request_id: o for o in eng.run_until_drained()}
    assert sorted(outs) == sorted(ids)
    assert len({tuple(outs[i].token_ids) for i in ids}) == 1
    assert eng.allocator.pages_in_use == 0


def test_oversized_request_rejected_not_wedged():
    rc, model, params = _lm()
    eng = ServeEngine(model, params, slots=1, max_len=8, ctx=CTX,
                      paged=True, page_size=4)
    p = jax.random.randint(jax.random.PRNGKey(0), (1, 6), 0, rc.vocab_size)
    with pytest.raises(ValueError, match="max_len"):
        eng.submit({"tokens": p}, SamplingParams(max_new_tokens=4))


def test_request_larger_than_pool_fails_fast():
    """A reservation that can NEVER fit the pool must raise at submit,
    not wedge the FIFO admission head forever."""
    rc, model, params = _lm()
    eng = ServeEngine(model, params, slots=1, max_len=16, ctx=CTX,
                      paged=True, page_size=4, num_pages=2)
    p = jax.random.randint(jax.random.PRNGKey(0), (1, 4), 0, rc.vocab_size)
    with pytest.raises(ValueError, match="pages"):
        eng.submit({"tokens": p}, SamplingParams(max_new_tokens=8))


def test_paged_kernel_impl_tracks_gather_impl():
    """Ctx(paged_attn_impl='kernel') routes decode through the Pallas
    paged-attention kernel (write-then-attend); its logits track the
    gather path closely. The paths differ only in when the fresh token
    is quantized, so int8 tolerates more than bf16."""
    from repro.models.layers import Ctx as MCtx
    rc, model, params = _lm("qwen2.5-14b")     # no attention windows
    p = jax.random.randint(jax.random.PRNGKey(3), (1, 5), 0, rc.vocab_size)
    for kv, tol in (("bf16", 5e-2), ("int8", 0.3)):
        eng = ServeEngine(model, params, slots=2, max_len=16, kv_dtype=kv,
                          ctx=CTX, paged=True, page_size=4)
        eng.submit({"tokens": p}, SamplingParams(max_new_tokens=6))
        eng.step()
        eng.step()                             # a couple of cache tokens
        ctx_k = MCtx(compute_dtype=jnp.float32, paged_attn_impl="kernel")
        _, lg_g = model.decode_step(CTX, params, eng.cur, eng.cache)
        _, lg_k = model.decode_step(ctx_k, params, eng.cur, eng.cache)
        err = float(jnp.max(jnp.abs(lg_g[0] - lg_k[0])))
        assert err < tol, (kv, err)
        # and greedy argmax agrees on this step
        assert int(jnp.argmax(lg_g[0, -1])) == int(jnp.argmax(lg_k[0, -1]))


# ---------------------------------------------------------------------------
# mixed source lengths (cross-attention cache fix)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("paged", [False, True])
def test_mixed_source_lengths_one_engine(paged):
    """Requests with different source lengths coexist; each stream equals
    its solo run (no cross-cache contamination from the padding)."""
    rc = reduce_config(REGISTRY["nllb600m"])
    model = build_model(rc)
    params = model.init(jax.random.PRNGKey(0))
    kw = dict(paged=True, page_size=4) if paged else {}
    sp = SamplingParams(max_new_tokens=5)
    srcs = [jax.random.randint(jax.random.PRNGKey(i), (1, se), 0,
                               rc.vocab_size)
            for i, se in enumerate((rc.enc_len, rc.enc_len - 2,
                                    rc.enc_len - 3))]

    def req(src):
        return {"src_tokens": src, "tgt_in": jnp.full((1, 1), 8, jnp.int32)}

    solo = []
    for src in srcs:
        eng = ServeEngine(model, params, slots=1, max_len=16, ctx=CTX, **kw)
        rid = eng.submit(req(src), sp)
        solo.append({o.request_id: o
                     for o in eng.run_until_drained()}[rid].token_ids)

    eng = ServeEngine(model, params, slots=3, max_len=16, ctx=CTX, **kw)
    ids = [eng.submit(req(src), sp) for src in srcs]
    outs = {o.request_id: o for o in eng.run_until_drained()}
    assert [outs[i].token_ids for i in ids] == solo


def test_source_longer_than_capacity_rejected():
    rc = reduce_config(REGISTRY["nllb600m"])
    model = build_model(rc)
    params = model.init(jax.random.PRNGKey(0))
    eng = ServeEngine(model, params, slots=1, max_len=16, ctx=CTX)
    src = jax.random.randint(jax.random.PRNGKey(0), (1, rc.enc_len + 1), 0,
                             rc.vocab_size)
    with pytest.raises(ValueError, match="capacity"):
        eng.submit({"src_tokens": src,
                    "tgt_in": jnp.full((1, 1), 8, jnp.int32)},
                   SamplingParams(max_new_tokens=4))


# ---------------------------------------------------------------------------
# occupancy / batched admission
# ---------------------------------------------------------------------------

def test_paged_occupancy_at_least_dense():
    """Equal page pool, paged spread over 2x slots: occupancy >= dense."""
    rc, model, params = _lm()
    prompts = [jax.random.randint(jax.random.PRNGKey(i), (1, 4), 0,
                                  rc.vocab_size) for i in range(8)]
    sp = SamplingParams(max_new_tokens=4)

    def occupancy(**kw):
        eng = ServeEngine(model, params, slots=kw.pop("slots"), max_len=16,
                          ctx=CTX, **kw)
        for p in prompts:
            eng.submit({"tokens": p}, sp)
        eng.run_until_drained()
        return eng.occupancy

    occ_d = occupancy(slots=4)
    occ_p = occupancy(slots=8, paged=True, page_size=4,
                      num_pages=4 * pages_needed(16, 4))
    assert occ_p >= occ_d - 1e-9


def test_group_admission_is_batched_and_bounded():
    """A same-shape burst admits as ONE batched multi-slot prefill (one
    jitted executable), not one compile per request."""
    rc, model, params = _lm()
    eng = ServeEngine(model, params, slots=4, max_len=16, ctx=CTX,
                      paged=True, page_size=4)
    prompts = [jax.random.randint(jax.random.PRNGKey(i), (1, 4), 0,
                                  rc.vocab_size) for i in range(4)]
    for p in prompts:
        eng.submit({"tokens": p}, SamplingParams(max_new_tokens=3))
    assert eng.num_active == 0               # admission deferred to step()
    eng.step()
    assert eng.num_active == 4               # one burst, all admitted
    assert eng.prefill_compiles == 1         # a single (4, 4) prefill shape
    eng.run_until_drained()
    cache_size = getattr(eng._prefill_paged_fn, "_cache_size", None)
    if cache_size is not None:
        assert cache_size() == 1


def test_group_admission_mixed_lengths_buckets():
    """Different prompt lengths in one burst: the group pads to the head
    request's bucket; distinct buckets admit as separate groups."""
    rc, model, params = _lm()
    sp = SamplingParams(max_new_tokens=3)

    def solo(p):
        eng = ServeEngine(model, params, slots=1, max_len=16, ctx=CTX,
                          paged=True, page_size=4)
        rid = eng.submit({"tokens": p}, sp)
        return {o.request_id: o for o in eng.run_until_drained()}[rid]

    prompts = [jax.random.randint(jax.random.PRNGKey(i), (1, n), 0,
                                  rc.vocab_size)
               for i, n in enumerate((3, 4, 6, 5))]
    refs = [solo(p).token_ids for p in prompts]
    eng = ServeEngine(model, params, slots=4, max_len=16, ctx=CTX,
                      paged=True, page_size=4)
    ids = [eng.submit({"tokens": p}, sp) for p in prompts]
    outs = {o.request_id: o for o in eng.run_until_drained()}
    assert [outs[i].token_ids for i in ids] == refs
