"""FASST reconfigurable NAF kernel vs oracle (all modes x dtypes)."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.fasst import MODES


@pytest.mark.parametrize("mode", MODES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fasst_modes(mode, dtype):
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((37, 100)) * 3, dtype)
    y = ops.fasst(x, mode)
    yr = ref.fasst_act_ref(x, mode)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    assert float(jnp.max(jnp.abs(y.astype(jnp.float32)
                                 - yr.astype(jnp.float32)))) <= tol


@pytest.mark.parametrize("rows,cols", [(8, 64), (33, 100), (1, 128)])
def test_fasst_softmax(rows, cols):
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.standard_normal((rows, cols)) * 5, jnp.float32)
    y = ops.fasst_softmax(x, scale=0.7)
    yr = ref.fasst_softmax_ref(x, scale=0.7)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=1e-6)
    np.testing.assert_allclose(np.asarray(jnp.sum(y, -1)), 1.0, atol=1e-5)


def test_fasst_softmax_masked_padding():
    x = jnp.ones((4, 32), jnp.float32)
    y = ops.fasst_softmax(x, valid_cols=8)
    assert float(jnp.max(jnp.abs(jnp.sum(y, -1) - 1.0))) < 1e-5
    assert float(jnp.max(y[:, 8:])) == 0.0
    np.testing.assert_allclose(np.asarray(y[:, :8]), 1 / 8, atol=1e-6)


def test_fasst_fp8_io():
    """Paper: FASST operates at FP8/BF16 I/O with internal f32 math."""
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.standard_normal((16, 64)), jnp.float8_e4m3fn)
    y = ops.fasst(x.astype(jnp.bfloat16), "sigmoid", out_dtype=jnp.bfloat16)
    yr = ref.fasst_act_ref(x.astype(jnp.float32), "sigmoid")
    assert float(jnp.max(jnp.abs(y.astype(jnp.float32) - yr))) < 2e-2


def test_model_naf_matches_kernel():
    """Single source of truth: model path and kernel agree by construction."""
    from repro.models.layers import Ctx
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.standard_normal((8, 32)), jnp.float32)
    ctx_host = Ctx(use_fasst_kernel=False)
    ctx_kern = Ctx(use_fasst_kernel=True)
    for mode in ("gelu", "silu", "squared_relu"):
        a = ctx_host.naf(x, mode)
        b = ctx_kern.naf(x, mode)
        assert float(jnp.max(jnp.abs(a - b))) < 1e-6
