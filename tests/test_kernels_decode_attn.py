"""Quantized-KV flash-decode kernel vs oracle (ragged lengths, GQA sweep)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


def _run(B, H, Hkv, d, S, lengths, seed=0):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.standard_normal((B, H, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, S, Hkv, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, S, Hkv, d)), jnp.float32)
    kc, ks = ops.quantize_kv(k)
    vc, vs = ops.quantize_kv(v)
    lens = jnp.asarray(lengths, jnp.int32)
    out = ops.decode_attention(q, kc, ks, vc, vs, lens, out_dtype=jnp.float32)
    G = H // Hkv
    orf = ref.decode_attn_ref(
        q.reshape(B, Hkv, G, d),
        jnp.transpose(kc, (0, 2, 1, 3)), jnp.transpose(ks, (0, 2, 1)),
        jnp.transpose(vc, (0, 2, 1, 3)), jnp.transpose(vs, (0, 2, 1)),
        lens, d ** -0.5).reshape(B, H, d)
    return float(jnp.max(jnp.abs(out - orf)))


@pytest.mark.parametrize("H,Hkv,d", [(8, 2, 64), (4, 1, 128), (16, 16, 64),
                                     (10, 2, 64)])
def test_gqa_configs(H, Hkv, d):
    assert _run(2, H, Hkv, d, 256, [256, 100]) < 1e-5


def test_ragged_lengths_match_oracle():
    assert _run(4, 8, 2, 64, 384, [384, 1, 17, 200]) < 1e-5


def test_matches_unquantized_reference_closely():
    """int8 KV vs exact bf16 attention: relative error stays small."""
    rng = np.random.default_rng(1)
    B, H, Hkv, d, S = 2, 4, 2, 64, 128
    q = jnp.asarray(rng.standard_normal((B, H, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, S, Hkv, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, S, Hkv, d)), jnp.float32)
    kc, ks = ops.quantize_kv(k)
    vc, vs = ops.quantize_kv(v)
    lens = jnp.full((B,), S, jnp.int32)
    out = ops.decode_attention(q, kc, ks, vc, vs, lens, out_dtype=jnp.float32)
    # exact attention on the unquantized cache
    G = H // Hkv
    qg = q.reshape(B, Hkv, G, d)
    scores = jnp.einsum("bhgd,bshd->bhgs", qg, k) * d ** -0.5
    p = jax.nn.softmax(scores, -1)
    exact = jnp.einsum("bhgs,bshd->bhgd", p, v).reshape(B, H, d)
    rel = float(jnp.linalg.norm(out - exact) / jnp.linalg.norm(exact))
    assert rel < 0.03   # int8 KV quantization noise only
