"""Fault-tolerant serving: deadlines, backpressure, preemption, NaN
isolation, and the deterministic fault-injection harness.

The contract under test: no injected fault ever raises out of step() /
run_until_drained() — every request comes back with a typed
finish_reason — and fault handling never corrupts a neighbour:
survivors of a faulted run are token-for-token identical to a
fault-free run (resumed preemption victims included, via bit-exact
teacher-forced prefill replay and offset-indexed per-request PRNG
streams), every non-survivor's partial tokens are a prefix of its
fault-free stream, and the page allocator's invariants hold after
every drain.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import REGISTRY, reduce_config
from repro.models import Ctx, build_model
from repro.serving import (EngineSaturated, FaultPlan, SamplingParams,
                           ServeEngine, pages_needed)

CTX = Ctx(compute_dtype=jnp.float32)

P1 = np.array([[5, 6, 7, 8, 9]], np.int32)
P2 = np.array([[3, 4, 5, 6, 2]], np.int32)
P3 = np.array([[9, 8, 7, 6, 5]], np.int32)
P4 = np.array([[2, 3, 9, 1, 4]], np.int32)
PROMPTS = (P1, P2, P3, P4)

GREEDY8 = SamplingParams(max_new_tokens=8, eos_id=-1)
SAMPLED8 = SamplingParams(temperature=0.8, top_p=0.9, max_new_tokens=8,
                          seed=7, eos_id=-1)


@pytest.fixture(scope="module")
def lm():
    rc = reduce_config(REGISTRY["gemma3-1b"])
    model = build_model(rc)
    params = model.init(jax.random.PRNGKey(0))
    return rc, model, params


def _engine(lm, **kw):
    _, model, params = lm
    kw.setdefault("slots", 2)
    kw.setdefault("max_len", 32)
    if kw.pop("paged", False):
        kw.update(paged=True, page_size=4)
        kw.setdefault("num_pages", 8)
    return ServeEngine(model, params, ctx=CTX, **kw)


def _serve(eng, prompts, sps):
    ids = [eng.submit({"tokens": p}, sp) for p, sp in zip(prompts, sps)]
    outs = {o.request_id: o for o in eng.run_until_drained()}
    return [outs[i] for i in ids]


def _reference(lm, prompts, sps, **kw):
    """Fault-free, uncontended run: the stream every survivor of a
    faulted run must reproduce exactly."""
    return _serve(_engine(lm, **kw), prompts, sps)


def _assert_prefix(got, ref):
    assert got.token_ids == ref.token_ids[:len(got.token_ids)], \
        f"{got.token_ids} is not a prefix of {ref.token_ids}"


# ---------------------------------------------------------------------------
# FaultPlan: validation + determinism
# ---------------------------------------------------------------------------

def test_fault_plan_validation():
    with pytest.raises(ValueError, match="exhaust_prob"):
        FaultPlan(exhaust_prob=1.5)
    with pytest.raises(ValueError, match="hold"):
        FaultPlan(exhaust_hold=0)
    with pytest.raises(ValueError, match="hold"):
        FaultPlan(exhaust_at=[(2, 4, 0)])   # a forever-hold would wedge


def test_fault_plan_same_seed_same_events_same_streams(lm):
    """Two plans with the same seed driving identical engines produce
    identical event logs and identical outputs — the determinism every
    chaos test stands on."""
    def run():
        plan = FaultPlan(seed=42, exhaust_prob=0.5, exhaust_pages=3,
                         exhaust_hold=2, nan_prob=0.3, skew_prob=0.2,
                         skew_ms=10.0)
        eng = _engine(lm, paged=True, horizon=4, faults=plan)
        outs = _serve(eng, (P1, P2, P3), (GREEDY8, SAMPLED8, GREEDY8))
        plan.release_all(eng)
        eng.allocator.check()
        return plan.events, [(o.token_ids, o.finish_reason) for o in outs]

    ev_a, outs_a = run()
    ev_b, outs_b = run()
    assert ev_a == ev_b
    assert outs_a == outs_b


# ---------------------------------------------------------------------------
# deadlines
# ---------------------------------------------------------------------------

def test_deadline_expires_active_and_queued(lm):
    """Clock skew at round 2 pushes both an in-flight and a
    still-queued request past their deadlines: the active one retires
    with its partial tokens (a prefix of its fault-free stream), the
    queued one with none, and an undeadlined neighbour is untouched.
    The deadline is far beyond real wall time (JIT compiles take
    seconds) so only the injected 600 s skew can expire it."""
    ref = _reference(lm, (P1,), (GREEDY8,), slots=1)[0]
    dl = SamplingParams(max_new_tokens=8, eos_id=-1, deadline_ms=60_000.0)
    eng = _engine(lm, slots=1, faults=FaultPlan(skew_at=[(2, 600_000.0)]))
    outs = _serve(eng, (P1, P2, P3), (dl, dl, GREEDY8))
    assert [o.finish_reason for o in outs] == ["deadline", "deadline",
                                              "length"]
    assert len(outs[0].token_ids) >= 1          # partial tokens kept
    _assert_prefix(outs[0], ref)
    assert outs[1].token_ids == []              # expired while queued
    assert eng.metrics().deadline_expirations == 2


# ---------------------------------------------------------------------------
# bounded admission / backpressure
# ---------------------------------------------------------------------------

def test_engine_saturated_is_typed_and_retryable(lm):
    eng = _engine(lm, slots=1, max_pending=1)
    eng.submit({"tokens": P1}, GREEDY8)          # -> the one slot
    eng.submit({"tokens": P2}, GREEDY8)          # -> the one queue seat
    with pytest.raises(EngineSaturated) as ei:
        eng.submit({"tokens": P3}, GREEDY8)
    assert ei.value.pending == 1 and ei.value.limit == 1
    assert eng.metrics().admission_rejections == 1
    while eng.num_pending >= 1:                  # drain, then retry
        eng.step()
    rid = eng.submit({"tokens": P3}, GREEDY8)
    outs = {o.request_id: o for o in eng.run_until_drained()}
    assert outs[rid].finish_reason == "length"
    with pytest.raises(ValueError, match="max_pending"):
        _engine(lm, max_pending=0)


def test_on_demand_admission_beats_whole_budget_reservation(lm):
    """Whole-budget reservation would need 4 pages per request (prompt
    5 + 8 new tokens at page_size 4), so a 4-page pool could only ever
    run one at a time. On-demand admission reserves just the prefill
    pages and both requests decode concurrently."""
    assert 2 * pages_needed(P1.shape[1] + 8, 4) > 4     # old math blocks
    eng = _engine(lm, paged=True, num_pages=4)
    ref = _reference(lm, (P1, P2), (GREEDY8, SAMPLED8), paged=True,
                     num_pages=16)
    ids = [eng.submit({"tokens": P1}, GREEDY8),
           eng.submit({"tokens": P2}, SAMPLED8)]
    eng.step()
    assert eng.num_active == 2                   # admitted side by side
    outs = {o.request_id: o for o in eng.run_until_drained()}
    for i, r in zip(ids, ref):
        assert outs[i].token_ids == r.token_ids
    assert eng.allocator.pages_in_use == 0


# ---------------------------------------------------------------------------
# preemption + recompute-on-resume
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("K", [1, 4])
@pytest.mark.parametrize("sp", [GREEDY8, SAMPLED8],
                         ids=["greedy", "sampled"])
def test_preemption_resume_streams_identical(lm, K, sp):
    """A 5-page pool cannot hold two full 4-page chains: the younger
    request is evicted mid-decode and resumed by prefill replay. Its
    stream — and the survivor's — must match an uncontended run token
    for token, greedy and sampled, per-token and fused dispatch."""
    ref = _reference(lm, (P1, P2), (sp, sp), paged=True, num_pages=16,
                     horizon=K)
    # a generous preempt_limit: the victim re-admits (and re-evicts)
    # until the survivor's chain frees — thrash-retirement is
    # test_preempt_limit_retires_with_partial_prefix's subject
    eng = _engine(lm, paged=True, num_pages=5, horizon=K,
                  preempt_limit=16)
    outs = _serve(eng, (P1, P2), (sp, sp))
    m = eng.metrics()
    assert m.preemptions >= 1 and m.resumed_requests >= 1
    for o, r in zip(outs, ref):
        assert o.token_ids == r.token_ids, \
            f"K={K}: {o.token_ids} != {r.token_ids}"
        assert o.finish_reason == "length"
    assert eng.allocator.pages_in_use == 0
    eng.allocator.check()


def test_preemption_victims_ordered_by_priority_then_age(lm):
    """Page pressure evicts the lowest-priority request even when it is
    the oldest; the high-priority neighbour is never touched. Both
    still finish with their uncontended streams."""
    lo = SamplingParams(max_new_tokens=8, eos_id=-1, priority=0)
    hi = SamplingParams(max_new_tokens=8, eos_id=-1, priority=1)
    ref = _reference(lm, (P1, P2), (lo, hi), paged=True, num_pages=16)
    eng = _engine(lm, paged=True, num_pages=5, preempt_limit=16)
    outs = _serve(eng, (P1, P2), (lo, hi))
    assert outs[0].stats.preemptions >= 1        # old but low priority
    assert outs[1].stats.preemptions == 0        # high priority: immune
    for o, r in zip(outs, ref):
        assert o.token_ids == r.token_ids
    assert eng.allocator.pages_in_use == 0


def test_preempt_limit_retires_with_partial_prefix(lm):
    """preempt_limit=0: the first eviction retires the victim as
    'preempted_limit' with its partial tokens (a prefix of its
    uncontended stream) instead of thrashing the pool."""
    ref = _reference(lm, (P1, P2), (GREEDY8, GREEDY8), paged=True,
                     num_pages=16)
    eng = _engine(lm, paged=True, num_pages=5, preempt_limit=0)
    outs = _serve(eng, (P1, P2), (GREEDY8, GREEDY8))
    reasons = sorted(o.finish_reason for o in outs)
    assert reasons == ["length", "preempted_limit"]
    for o, r in zip(outs, ref):
        if o.finish_reason == "length":
            assert o.token_ids == r.token_ids
        else:
            assert 1 <= len(o.token_ids) < len(r.token_ids)
            _assert_prefix(o, r)
    assert eng.metrics().resumed_requests == 0
    assert eng.allocator.pages_in_use == 0


# ---------------------------------------------------------------------------
# poisoned-request isolation
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("K", [1, 16])
@pytest.mark.parametrize("paged", [False, True], ids=["dense", "paged"])
def test_nan_logits_fail_only_the_offending_slot(lm, K, paged):
    """Forced-NaN logits on one slot retire ONLY that request
    (finish_reason 'error', partial tokens a prefix of its clean
    stream); the groupmate sharing the fused batch is bit-identical to
    a fault-free run, dense and paged, per-token and fused."""
    kw = dict(paged=True, num_pages=16) if paged else {}
    ref = _reference(lm, (P1, P2), (GREEDY8, SAMPLED8), horizon=K, **kw)
    plan = FaultPlan(nan_at=[(0, 1, 2)])     # dispatch 0, slot 1
    eng = _engine(lm, horizon=K, faults=plan, **kw)
    outs = _serve(eng, (P1, P2), (GREEDY8, SAMPLED8))
    assert outs[0].finish_reason == "length"
    assert outs[0].token_ids == ref[0].token_ids   # survivor untouched
    assert outs[1].finish_reason == "error"
    assert 1 <= len(outs[1].token_ids) < len(ref[1].token_ids)
    _assert_prefix(outs[1], ref[1])
    assert eng.metrics().slot_errors == 1
    if paged:
        assert eng.allocator.pages_in_use == 0
        eng.allocator.check()


# ---------------------------------------------------------------------------
# scheduler regressions
# ---------------------------------------------------------------------------

def test_abort_groupmate_from_first_token_callback(lm):
    """Regression: aborting a request that is still inside the pending
    prefill admission group (from a groupmate's first-token callback)
    must retire it — every group slot goes live before any callback
    fires — not leave a dead slot to be decoded and thrown away."""
    eng = _engine(lm, paged=True)
    state = {}

    def cb(tok):
        if "aborted" not in state:
            state["aborted"] = eng.abort(state["victim"])

    ref = _reference(lm, (P1,), (GREEDY8,), paged=True)[0]
    rid = eng.submit({"tokens": P1}, GREEDY8, on_token=cb)
    state["victim"] = eng.submit({"tokens": P2}, GREEDY8)
    outs = {o.request_id: o for o in eng.run_until_drained()}
    assert state["aborted"].finish_reason == "abort"
    assert state["victim"] not in outs           # abort returned it
    assert outs[rid].token_ids == ref.token_ids  # survivor unaffected
    assert eng.allocator.pages_in_use == 0
    eng.allocator.check()


def test_overlapped_block_not_swallowed_by_new_occupant(lm):
    """Regression (stale-block seq gate): with asymmetric budgets a
    short request retires in-scan and its slot is refilled while the
    overlapped block dispatched against the OLD occupancy is still in
    flight; the new occupant must not swallow that block's rows."""
    long_sp = SamplingParams(max_new_tokens=12, eos_id=-1)
    short_sp = SamplingParams(max_new_tokens=3, eos_id=-1)
    prompts = (P1, P2[:, :4], P3)
    sps = (long_sp, short_sp, long_sp)
    ref = _reference(lm, prompts, sps, slots=3, horizon=4)
    got = _serve(_engine(lm, slots=2, horizon=4), prompts, sps)
    for g, r in zip(got, ref):
        assert g.token_ids == r.token_ids
        assert g.finish_reason == r.finish_reason


# ---------------------------------------------------------------------------
# metrics
# ---------------------------------------------------------------------------

def test_fault_counters_reported_and_reset(lm):
    plan = FaultPlan(nan_at=[(0, 1, 0)], skew_at=[(2, 600_000.0)],
                     exhaust_at=[(1, 3, 4)])
    dl = SamplingParams(max_new_tokens=8, eos_id=-1, deadline_ms=60_000.0)
    # paged admission is deferred to the next step(), so every submit
    # queues first: the fourth hits the max_pending=3 bound
    eng = _engine(lm, paged=True, num_pages=5, max_pending=3,
                  faults=plan)
    eng.submit({"tokens": P1}, GREEDY8)
    eng.submit({"tokens": P2}, GREEDY8)
    eng.submit({"tokens": P3}, dl)
    with pytest.raises(EngineSaturated):
        eng.submit({"tokens": P4}, GREEDY8)
    eng.run_until_drained()
    m = eng.metrics()
    assert m.preemptions >= 1
    assert m.deadline_expirations == 1
    assert m.admission_rejections == 1
    assert m.slot_errors == 1
    eng.reset_metrics()
    m = eng.metrics()
    assert (m.preemptions, m.resumed_requests, m.deadline_expirations,
            m.admission_rejections, m.slot_errors) == (0, 0, 0, 0, 0)


# ---------------------------------------------------------------------------
# chaos: every fault class in one run, dense and paged, K=1 and K=16
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("K", [1, 16])
@pytest.mark.parametrize("paged", [False, True], ids=["dense", "paged"])
def test_chaos_equivalence_gate(lm, K, paged):
    """The PR's acceptance gate: allocator exhaustion + forced NaN +
    deadline expiry injected into ONE run. Survivors and resumed
    preemption victims are token-for-token identical to the fault-free
    engine, every casualty's tokens are a prefix of its fault-free
    stream, no fault raises out of the serving loop, and the page pool
    drains clean."""
    kw = dict(paged=True, num_pages=8) if paged else {}
    sps = [GREEDY8, SAMPLED8, GREEDY8,
           SamplingParams(max_new_tokens=8, eos_id=-1,
                          deadline_ms=60_000.0)]
    ref_eng = _engine(lm, horizon=K, **kw)
    ref = _serve(ref_eng, PROMPTS, sps)
    assert ref_eng.metrics().preemptions == 0    # pool adequate unfaulted

    plan = FaultPlan(exhaust_at=[(0, 4, 8)],     # shrink the pool early,
                                                 # hold past the decode
                     nan_at=[(0, 0, 2)],         # poison slot 0's logits
                     skew_at=[(1, 600_000.0)])   # expire the deadline
    eng = _engine(lm, horizon=K, faults=plan, preempt_limit=16, **kw)
    outs = _serve(eng, PROMPTS, sps)             # must not raise

    by_reason = {o.request_id: o.finish_reason for o in outs}
    assert by_reason[outs[0].request_id] == "error"       # poisoned
    assert by_reason[outs[3].request_id] == "deadline"    # expired
    for o, r in zip(outs, ref):
        if o.finish_reason in ("eos", "length"):
            assert o.token_ids == r.token_ids, \
                f"survivor diverged: {o.token_ids} != {r.token_ids}"
        else:
            _assert_prefix(o, r)
    m = eng.metrics()
    assert m.slot_errors == 1 and m.deadline_expirations == 1
    if paged:
        assert m.preemptions >= 1                # the steal forced evictions
        assert m.resumed_requests >= 1
        plan.release_all(eng)
        assert eng.allocator.pages_in_use == 0
        eng.allocator.check()


def _check_random_plan(lm, ref, seed):
    """One random-plan trial of the chaos property: survivors
    byte-identical to the fault-free run, casualties prefixes, and the
    allocator invariant-clean after drain + release."""
    plan = FaultPlan(seed=seed, exhaust_prob=0.5, exhaust_pages=4,
                     exhaust_hold=2, nan_prob=0.25, skew_prob=0.2,
                     skew_ms=25.0)
    eng = _engine(lm, paged=True, num_pages=8, horizon=4, faults=plan)
    outs = _serve(eng, (P1, P2, P3), (GREEDY8, SAMPLED8, GREEDY8))
    for o, r in zip(outs, ref):
        if o.finish_reason in ("eos", "length"):
            assert o.token_ids == r.token_ids
        else:
            _assert_prefix(o, r)
    plan.release_all(eng)
    assert eng.allocator.pages_in_use == 0
    eng.allocator.check()


@pytest.fixture(scope="module")
def chaos_ref(lm):
    return _reference(lm, (P1, P2, P3), (GREEDY8, SAMPLED8, GREEDY8),
                      paged=True, num_pages=16, horizon=4)


@pytest.mark.parametrize("seed", [0, 7, 1234])
def test_chaos_property_fixed_seeds(lm, chaos_ref, seed):
    """Fixed-seed arm of the chaos property — always runs, so the
    property is exercised even where hypothesis is unavailable."""
    _check_random_plan(lm, chaos_ref, seed)


def test_chaos_property_random_plans(lm, chaos_ref):
    """Property: under ANY seeded random FaultPlan, survivors are
    byte-identical to the fault-free run, casualties are prefixes, and
    the allocator's invariants hold after drain + release."""
    hyp = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")

    @hyp.settings(max_examples=5, deadline=None)
    @hyp.given(seed=st.integers(min_value=0, max_value=2**31 - 1))
    def check(seed):
        _check_random_plan(lm, chaos_ref, seed)

    check()
