"""QLoRA: low-rank adapters over frozen quantized weights (paper §III).

The paper finetunes the 4-bit NLLB deployment with QLoRA: base weights
stay quantized+frozen, small trainable A/B adapters learn the update.
Adapters live *inside* the QTensor (lora_a / lora_b children) so the
param tree shape is stable; training extracts the adapter subtree,
differentiates only it, and injects updates back.
"""

from __future__ import annotations

import re
from typing import Any

import jax
import jax.numpy as jnp

from .qtensor import QTensor

__all__ = ["attach_lora", "extract_adapters", "inject_adapters",
           "count_adapter_params", "merge_lora"]

_DEFAULT_TARGETS = r"(wq|wk|wv|wo|wqkv|w_in|w_gate|w_up|w_down|w_out)"


def attach_lora(params: Any, key: jax.Array, rank: int = 16,
                targets: str = _DEFAULT_TARGETS, alpha: float = 16.0) -> Any:
    """Attach zero-init-B / gaussian-A adapters to matching QTensors."""
    pat = re.compile(targets)
    flat, treedef = jax.tree_util.tree_flatten_with_path(
        params, is_leaf=lambda x: isinstance(x, QTensor))
    keys = jax.random.split(key, max(len(flat), 1))
    out = []
    for (path, leaf), k in zip(flat, keys):
        pstr = jax.tree_util.keystr(path)
        if isinstance(leaf, QTensor) and pat.search(pstr) and len(leaf.shape) >= 2:
            *batch, kdim, ndim = leaf.shape
            a = jax.random.normal(k, (*batch, kdim, rank), jnp.float32) * (1.0 / kdim ** 0.5)
            b = jnp.zeros((*batch, rank, ndim), jnp.float32)
            leaf = leaf.with_lora(a, b, alpha=alpha)
        out.append(leaf)
    return jax.tree_util.tree_unflatten(treedef, out)


def extract_adapters(params: Any) -> Any:
    """Parallel tree holding {'a','b'} per adapted QTensor, None elsewhere."""
    def get(leaf):
        if isinstance(leaf, QTensor) and leaf.lora_a is not None:
            return {"a": leaf.lora_a, "b": leaf.lora_b}
        return None
    return jax.tree_util.tree_map(
        get, params, is_leaf=lambda x: isinstance(x, QTensor))


def inject_adapters(params: Any, adapters: Any) -> Any:
    """Inverse of extract_adapters: write adapter arrays into the QTensors."""
    def put(leaf, ad):
        if isinstance(leaf, QTensor) and ad is not None:
            return leaf.with_lora(ad["a"], ad["b"], alpha=leaf.lora_alpha)
        return leaf
    return jax.tree_util.tree_map(
        put, params, adapters,
        is_leaf=lambda x: isinstance(x, QTensor) or x is None)


def count_adapter_params(adapters: Any) -> int:
    return sum(leaf.size for leaf in jax.tree_util.tree_leaves(adapters))


def merge_lora(qt: QTensor, dtype=jnp.bfloat16) -> jnp.ndarray:
    """Export path: dense W' = dequant(W) + A @ B * alpha/r."""
    w = qt.dequantize(jnp.float32)
    if qt.lora_a is not None:
        r = qt.lora_a.shape[-1]
        w = w + jnp.matmul(qt.lora_a, qt.lora_b) * (qt.lora_alpha / r)
    return w.astype(dtype)
