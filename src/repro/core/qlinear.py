"""Quantized linear algebra front-end.

Every matmul in the model zoo routes through :func:`qmatmul`, which
dispatches on the weight's storage:

  * plain array            -> bf16 MXU matmul (baseline);
  * QTensor, act bf16      -> fused dequant-matmul (w4a16 / w8a16 / fp8):
                              XLA path dequantizes next to the dot (HBM
                              reads stay sub-octet); the Pallas path
                              (kernels/qmm.py) does it in VMEM tiles;
  * QTensor int8 + act int8-> integer matmul on the int8 MXU mode with
                              per-token x per-channel rescale (the TPU
                              realisation of the paper's 6xINT4/
                              3xFP8 SIMD MAC lanes — see DESIGN.md).

QLoRA adapters attached to the QTensor contribute the trainable low-rank
update: y += (x @ A) @ B * (alpha / r), with the base frozen via
stop_gradient (paper §III: QLoRA keeps original quantized weights fixed).
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from .qtensor import QTensor

__all__ = ["qmatmul", "embed_lookup", "quantize_activations_int8",
           "int8_mac_eligible"]


def int8_mac_eligible(w: Any) -> bool:
    """True when ``w`` routes through the integer-MAC w8a8 path: int8
    storage with per-channel scales (one K-block). The single source of
    this predicate — activation calibration (Ctx.act_collector) keys on
    it so the calibrated scale observes exactly the matmuls it will be
    applied to."""
    return (isinstance(w, QTensor) and w.fmt == "int8"
            and w.block_scales().shape[-2] == 1)


def quantize_activations_int8(x: jnp.ndarray, scale=None):
    """Symmetric int8 quantization of activations.

    ``scale=None`` (default) is the dynamic per-token path: each token
    row gets its own absmax-derived scale. A static ``scale`` (a scalar
    from ``core.calibration``, the paper's w8a8 calibrated deployment)
    skips the runtime absmax reduction — outliers beyond the calibrated
    range saturate at +-127 instead of stretching the grid.
    """
    if scale is None:
        absmax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1,
                         keepdims=True)
        scale = jnp.where(absmax == 0, 1.0, absmax / 127.0)
    else:
        scale = jnp.asarray(scale, jnp.float32)
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def _lora_term(x, w: QTensor, compute_dtype):
    if w.lora_a is None:
        return None
    r = w.lora_a.shape[-1]
    scaling = w.lora_alpha / r
    xa = jnp.matmul(x.astype(compute_dtype), w.lora_a.astype(compute_dtype))
    return jnp.matmul(xa, w.lora_b.astype(compute_dtype)) * scaling


def _int8_path(x, w: QTensor, compute_dtype, act_scale=None):
    """w8a8 integer matmul. Requires per-channel weight scales (1 K-block)."""
    if not int8_mac_eligible(w):
        return None                    # blockwise int8: fall back to dequant
    scales = w.block_scales()          # (..., 1, N)
    xq, sx = quantize_activations_int8(x, act_scale)
    out = jax.lax.dot_general(
        xq, w.data,
        dimension_numbers=(((x.ndim - 1,), (w.data.ndim - 2,)), ((), ())),
        preferred_element_type=jnp.int32)
    sw = jnp.squeeze(scales, axis=-2)  # (..., N)
    return (out.astype(jnp.float32) * sx * sw).astype(compute_dtype)


def qmatmul(
    x: jnp.ndarray,
    w: Any,
    *,
    act: str = "bf16",
    compute_dtype=jnp.bfloat16,
    impl: str = "xla",
    act_scale=None,
) -> jnp.ndarray:
    """y = x @ w for plain or quantized ``w`` (last-2-axis contraction).

    ``act_scale``: optional calibrated static scale for the int8
    activation path (see quantize_activations_int8); ignored elsewhere.
    """
    if not isinstance(w, QTensor):
        return jnp.matmul(x.astype(compute_dtype), w.astype(compute_dtype))

    lora = _lora_term(x, w, compute_dtype)

    if act == "int8" and w.fmt == "int8":
        y = _int8_path(x, w, compute_dtype, act_scale)
        if y is None:
            y = jnp.matmul(x.astype(compute_dtype),
                           jax.lax.stop_gradient(w.dequantize(compute_dtype)))
    elif impl == "pallas" and w.fmt in ("int4", "fp4", "nf4") and w.data.ndim == 2:
        from ..kernels import ops as kops  # lazy: avoid import cycle
        y = kops.qmm(x, w, compute_dtype=compute_dtype)
    else:
        wd = jax.lax.stop_gradient(w.dequantize(compute_dtype))
        y = jnp.matmul(x.astype(compute_dtype), wd)

    if lora is not None:
        y = y + lora.astype(y.dtype)
    return y


def embed_lookup(table: Any, ids: jnp.ndarray, compute_dtype=jnp.bfloat16):
    """Embedding gather with row-wise dequantization for QTensor tables."""
    if not isinstance(table, QTensor):
        return jnp.take(table, ids, axis=0).astype(compute_dtype)
    rows = jnp.take(table.data, ids, axis=0)
    scales = jnp.take(table.block_scales(), ids, axis=0)
    from .quantize import dequantize_blockwise
    return dequantize_blockwise(rows, scales, table.fmt, q_axis=-1,
                                out_dtype=compute_dtype)
