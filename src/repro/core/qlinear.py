"""Quantized linear algebra front-end.

Every matmul in the model zoo routes through :func:`qmatmul`, which
dispatches on the weight's storage and the activation format:

  * plain array            -> bf16 MXU matmul (baseline);
  * QTensor, act bf16      -> fused dequant-matmul (w4a16 / w8a16 / fp8):
                              XLA path dequantizes next to the dot (HBM
                              reads stay sub-octet); the Pallas path
                              (kernels/qmm.py) does it in VMEM tiles;
  * QTensor int8 + act int8-> integer matmul on the int8 MXU mode with
                              per-token x per-channel rescale (the TPU
                              realisation of the paper's 6xINT4/
                              3xFP8 SIMD MAC lanes — see DESIGN.md);
  * act int8/fp8 otherwise -> the activations are genuinely quantized
                              (absmax grid / e4m3 codes) then widened
                              back for a bf16-accumulate matmul — the
                              software twin of the paper's narrow-
                              multiply / wide-accumulate RMMEC lanes.
                              An ``a8`` spec never silently runs bf16
                              activations.

Static per-site activation scales (core.calibration) arrive via
``act_scale``; ``None`` means dynamic per-token quantization.

QLoRA adapters attached to the QTensor contribute the trainable low-rank
update: y += (x @ A) @ B * (alpha / r), with the base frozen via
stop_gradient (paper §III: QLoRA keeps original quantized weights fixed).
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from .qtensor import QTensor

__all__ = ["qmatmul", "embed_lookup", "quantize_activations",
           "quantize_activations_int8", "int8_mac_eligible",
           "act_quant_eligible"]


def int8_mac_eligible(w: Any) -> bool:
    """True when ``w`` routes through the integer-MAC w8a8 path: int8
    storage with per-channel scales (one K-block). The single source of
    this predicate — activation calibration keys on it so calibrated
    scales observe exactly the matmuls they will be applied to."""
    return (isinstance(w, QTensor) and w.fmt == "int8"
            and w.block_scales().shape[-2] == 1)


def act_quant_eligible(w: Any) -> bool:
    """True when a matmul against ``w`` quantizes its activations under
    an act-quantizing spec (a8 / afp8) — the sites the calibration
    collector (Ctx.act_collector) observes. Every quantized weight
    qualifies: eligible formats take the integer-MAC path, the rest
    fake-quantize their activations (see qmatmul)."""
    return isinstance(w, QTensor)


def quantize_activations(x: jnp.ndarray, fmt: str = "int8", scale=None):
    """Symmetric quantization of activations to int8 or fp8 (e4m3).

    ``scale=None`` (default) is the dynamic per-token path: each token
    row gets its own absmax-derived scale. A static ``scale`` (a
    per-site scalar from ``core.calibration``, the paper's calibrated
    PTQ deployment) skips the runtime absmax reduction — outliers beyond
    the calibrated range saturate at the format edge instead of
    stretching the grid. Returns ``(codes, scale)``.
    """
    if fmt == "int8":
        max_code = 127.0
    elif fmt == "fp8":
        max_code = 448.0
    else:
        raise ValueError(f"activation format must be int8 | fp8, got {fmt!r}")
    if scale is None:
        absmax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1,
                         keepdims=True)
        scale = jnp.where(absmax == 0, 1.0, absmax / max_code)
    else:
        scale = jnp.asarray(scale, jnp.float32)
    if fmt == "int8":
        q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    else:
        q = (jnp.clip(x.astype(jnp.float32) / scale, -448.0, 448.0)
             ).astype(jnp.float8_e4m3fn)
    return q, scale.astype(jnp.float32)


def quantize_activations_int8(x: jnp.ndarray, scale=None):
    """Legacy alias for ``quantize_activations(x, "int8", scale)``."""
    return quantize_activations(x, "int8", scale)


def _lora_term(x, w: QTensor, compute_dtype):
    if w.lora_a is None:
        return None
    r = w.lora_a.shape[-1]
    scaling = w.lora_alpha / r
    xa = jnp.matmul(x.astype(compute_dtype), w.lora_a.astype(compute_dtype))
    return jnp.matmul(xa, w.lora_b.astype(compute_dtype)) * scaling


def _int8_path(x, w: QTensor, compute_dtype, act_scale=None):
    """w8a8 integer matmul. Requires per-channel weight scales (1 K-block)."""
    if not int8_mac_eligible(w):
        return None                    # blockwise int8: fake-quant fallback
    scales = w.block_scales()          # (..., 1, N)
    xq, sx = quantize_activations(x, "int8", act_scale)
    out = jax.lax.dot_general(
        xq, w.data,
        dimension_numbers=(((x.ndim - 1,), (w.data.ndim - 2,)), ((), ())),
        preferred_element_type=jnp.int32)
    sw = jnp.squeeze(scales, axis=-2)  # (..., N)
    return (out.astype(jnp.float32) * sx * sw).astype(compute_dtype)


def _fake_quant_act(x, fmt: str, act_scale, compute_dtype):
    """Quantize-then-widen activations for formats/weights with no native
    MAC route here: the quantization error is real (the quality signal
    the eval grid measures), the accumulate stays wide (paper's
    quire-style accumulation)."""
    xq, sx = quantize_activations(x, fmt, act_scale)
    return (xq.astype(jnp.float32) * sx).astype(compute_dtype)


def qmatmul(
    x: jnp.ndarray,
    w: Any,
    *,
    act: str = "bf16",
    compute_dtype=jnp.bfloat16,
    impl: str = "xla",
    act_scale=None,
) -> jnp.ndarray:
    """y = x @ w for plain or quantized ``w`` (last-2-axis contraction).

    ``act_scale``: optional calibrated static scale for the int8/fp8
    activation paths (see quantize_activations); ignored elsewhere.
    """
    if not isinstance(w, QTensor):
        return jnp.matmul(x.astype(compute_dtype), w.astype(compute_dtype))

    lora = _lora_term(x, w, compute_dtype)

    y = None
    if act == "int8" and w.fmt == "int8":
        y = _int8_path(x, w, compute_dtype, act_scale)
    if y is None:
        if act in ("int8", "fp8"):
            # no integer/native route for this (weight fmt, act fmt)
            # pair: quantize the activations anyway — an act-quantizing
            # spec must never silently run bf16 activations
            x = _fake_quant_act(x, act, act_scale, compute_dtype)
        if impl == "pallas" and w.fmt in ("int4", "fp4", "nf4") \
                and w.data.ndim == 2:
            from ..kernels import ops as kops  # lazy: avoid import cycle
            y = kops.qmm(x, w, compute_dtype=compute_dtype)
        else:
            wd = jax.lax.stop_gradient(w.dequantize(compute_dtype))
            y = jnp.matmul(x.astype(compute_dtype), wd)

    if lora is not None:
        y = y + lora.astype(y.dtype)
    return y


def embed_lookup(table: Any, ids: jnp.ndarray, compute_dtype=jnp.bfloat16):
    """Embedding gather with row-wise dequantization for QTensor tables."""
    if not isinstance(table, QTensor):
        return jnp.take(table, ids, axis=0).astype(compute_dtype)
    rows = jnp.take(table.data, ids, axis=0)
    scales = jnp.take(table.block_scales(), ids, axis=0)
    from .quantize import dequantize_blockwise
    return dequantize_blockwise(rows, scales, table.fmt, q_axis=-1,
                                out_dtype=compute_dtype)
