"""Numeric formats for sub-octet quantization (paper §II-A / §III).

The paper deploys NLLB-600M at FP8 / INT8 / FP4 / INT4 (+BF16 accumulate).
Each format here defines how a real value maps to a code and back:

  * uniform integer formats (INT4, INT8): symmetric absmax scaling,
    code = round(x / scale) clipped to the symmetric range;
  * codebook formats (FP4 = E2M1 value set, NF4 = QLoRA normal-float):
    code = index of the nearest codebook entry of x / scale;
  * FP8 (E4M3 / E5M2): native jnp float8 storage with blockwise scale
    so the dynamic range of each block is centred on the format's max.

All formats quantize *blockwise* (paper uses BitsAndBytes blockwise PTQ):
a block of `block_size` consecutive values along the quantization axis
shares one scale = absmax(block) / fmt.max_code.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp
import numpy as np

__all__ = ["Format", "get_format", "FORMATS", "SUB_OCTET", "pack_nibbles", "unpack_nibbles"]


# E2M1 value set (sign x {0, 0.5, 1, 1.5, 2, 3, 4, 6}), sorted ascending.
# 15 distinct values; index 7 and 8 both decode near zero (+-0).
_FP4_E2M1 = np.sort(np.array(
    [-6.0, -4.0, -3.0, -2.0, -1.5, -1.0, -0.5, -0.0,
     0.0, 0.5, 1.0, 1.5, 2.0, 3.0, 4.0, 6.0], dtype=np.float32))

# QLoRA NF4 table (Dettmers et al., 2023) — information-theoretically optimal
# for N(0,1) weights; the paper's QLoRA arm uses this via bitsandbytes.
_NF4 = np.array(
    [-1.0, -0.6961928009986877, -0.5250730514526367, -0.39491748809814453,
     -0.28444138169288635, -0.18477343022823334, -0.09105003625154495, 0.0,
     0.07958029955625534, 0.16093020141124725, 0.24611230194568634,
     0.33791524171829224, 0.44070982933044434, 0.5626170039176941,
     0.7229568362236023, 1.0], dtype=np.float32)


@dataclasses.dataclass(frozen=True)
class Format:
    """A storage number format for quantized tensors."""

    name: str
    bits: int
    kind: str                      # "int" | "codebook" | "float8" | "none"
    max_code: float                # |value| that absmax maps to (scale divisor)
    codebook: Optional[np.ndarray] = None
    storage_dtype: Optional[jnp.dtype] = None

    @property
    def packed(self) -> bool:
        """4-bit formats store two codes per uint8 byte."""
        return self.bits == 4

    @property
    def bytes_per_param(self) -> float:
        return self.bits / 8.0

    def boundaries(self) -> np.ndarray:
        """Decision boundaries (midpoints) for codebook nearest-neighbour."""
        assert self.codebook is not None
        cb = self.codebook
        return (cb[1:] + cb[:-1]) / 2.0


FORMATS: dict[str, Format] = {
    "int4": Format("int4", 4, "int", 7.0, storage_dtype=jnp.uint8),
    "int8": Format("int8", 8, "int", 127.0, storage_dtype=jnp.int8),
    "fp4": Format("fp4", 4, "codebook", 6.0, codebook=_FP4_E2M1,
                  storage_dtype=jnp.uint8),
    "nf4": Format("nf4", 4, "codebook", 1.0, codebook=_NF4,
                  storage_dtype=jnp.uint8),
    "fp8": Format("fp8", 8, "float8", 448.0,
                  storage_dtype=jnp.float8_e4m3fn),
    "fp8_e5m2": Format("fp8_e5m2", 8, "float8", 57344.0,
                       storage_dtype=jnp.float8_e5m2),
    # passthrough (no quantization) — used by PrecisionPolicy for exempt layers
    "bf16": Format("bf16", 16, "none", 0.0, storage_dtype=jnp.bfloat16),
    "f32": Format("f32", 32, "none", 0.0, storage_dtype=jnp.float32),
}

SUB_OCTET = ("int4", "fp4", "nf4")  # formats packed two-per-byte


def get_format(name: str) -> Format:
    if name not in FORMATS:
        raise ValueError(f"unknown format {name!r}; have {sorted(FORMATS)}")
    return FORMATS[name]


def pack_nibbles(codes: jnp.ndarray, axis: int) -> jnp.ndarray:
    """Pack uint8 codes (values 0..15) two-per-byte along ``axis``.

    Even positions go to the low nibble, odd to the high nibble — the
    TPU-side analogue of the paper's RMMEC lane packing (6x INT4 operands
    per MAC issue; here: 2x INT4 weights per HBM byte).
    """
    axis = axis % codes.ndim
    if codes.shape[axis] % 2 != 0:
        raise ValueError(f"axis {axis} length {codes.shape[axis]} must be even to pack")
    lo = jnp.take(codes, jnp.arange(0, codes.shape[axis], 2), axis=axis)
    hi = jnp.take(codes, jnp.arange(1, codes.shape[axis], 2), axis=axis)
    return (lo.astype(jnp.uint8) | (hi.astype(jnp.uint8) << 4)).astype(jnp.uint8)


def unpack_nibbles(packed: jnp.ndarray, axis: int) -> jnp.ndarray:
    """Inverse of :func:`pack_nibbles` (returns uint8 codes 0..15)."""
    axis = axis % packed.ndim
    lo = packed & jnp.uint8(0x0F)
    hi = (packed >> 4) & jnp.uint8(0x0F)
    stacked = jnp.stack([lo, hi], axis=axis + 1)  # (..., K/2, 2, ...)
    new_shape = list(packed.shape)
    new_shape[axis] = packed.shape[axis] * 2
    return stacked.reshape(new_shape)


def signed_from_nibble(codes: jnp.ndarray) -> jnp.ndarray:
    """uint8 nibble (0..15) -> int8 two's-complement int4 value (-8..7)."""
    return (codes.astype(jnp.int8) ^ jnp.int8(8)) - jnp.int8(8)


def nibble_from_signed(vals: jnp.ndarray) -> jnp.ndarray:
    """int values (-8..7) -> uint8 nibble (0..15)."""
    return (vals.astype(jnp.int8) & jnp.int8(0x0F)).astype(jnp.uint8)
