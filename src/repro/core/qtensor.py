"""QTensor: a quantized-weight pytree leaf-group.

Holds the packed payload + blockwise scales (optionally double-quantized)
+ optional QLoRA adapters. Registered as a JAX pytree so QTensors live
inside param trees, shard under pjit, checkpoint, and donate like plain
arrays. The *format metadata* is static (part of treedef) so jit traces
specialize on it — the TPU analogue of the paper's RMMEC mode-control
signal selecting the SIMD precision mode at issue time.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from .formats import Format, get_format
from .quantize import (dequantize_blockwise, dequantize_scales,
                       quantize_blockwise, quantize_scales)

__all__ = ["QTensor", "maybe_dequantize", "tensor_nbytes"]


@jax.tree_util.register_pytree_with_keys_class
@dataclasses.dataclass
class QTensor:
    # --- dynamic children (arrays) ---
    data: jnp.ndarray                      # packed codes
    scales: Optional[jnp.ndarray]          # f32 block scales (None if double-quantized)
    scales_q: Optional[jnp.ndarray]        # int8 scale codes (double quant)
    scales_cscale: Optional[jnp.ndarray]   # f32 per-chunk scale of scales
    scales_offset: Optional[jnp.ndarray]   # f32 per-chunk offset of scales
    lora_a: Optional[jnp.ndarray]          # (K, r) QLoRA adapter (trainable)
    lora_b: Optional[jnp.ndarray]          # (r, N) QLoRA adapter (trainable)
    # --- static aux ---
    fmt: str = "int4"
    q_axis: int = -2
    shape: tuple = ()                      # logical (dequantized) shape
    scales_shape: tuple = ()               # shape of the f32 scales tensor
    lora_alpha: float = 16.0

    # -- pytree protocol ----------------------------------------------------
    _CHILDREN = ("data", "scales", "scales_q", "scales_cscale",
                 "scales_offset", "lora_a", "lora_b")

    def tree_flatten_with_keys(self):
        children = tuple(
            (jax.tree_util.GetAttrKey(n), getattr(self, n))
            for n in self._CHILDREN)
        aux = (self.fmt, self.q_axis, self.shape, self.scales_shape, self.lora_alpha)
        return children, aux

    def tree_flatten(self):
        aux = (self.fmt, self.q_axis, self.shape, self.scales_shape, self.lora_alpha)
        return tuple(getattr(self, n) for n in self._CHILDREN), aux

    @classmethod
    def tree_unflatten(cls, aux, children):
        fmt, q_axis, shape, scales_shape, lora_alpha = aux
        return cls(*children, fmt=fmt, q_axis=q_axis, shape=shape,
                   scales_shape=scales_shape, lora_alpha=lora_alpha)

    # -- construction --------------------------------------------------------
    @classmethod
    def quantize(cls, w: jnp.ndarray, fmt: str | Format, block_size: int = 64,
                 q_axis: int = -2, double_quant: bool = False) -> "QTensor":
        fmt_name = fmt if isinstance(fmt, str) else fmt.name
        codes, scales = quantize_blockwise(w, fmt_name, block_size, q_axis)
        scales_shape = tuple(scales.shape)
        if double_quant:
            sq, sc, so, _ = quantize_scales(scales)
            return cls(codes, None, sq, sc, so, None, None, fmt=fmt_name,
                       q_axis=q_axis % w.ndim - w.ndim, shape=tuple(w.shape),
                       scales_shape=scales_shape)
        return cls(codes, scales, None, None, None, None, None, fmt=fmt_name,
                   q_axis=q_axis % w.ndim - w.ndim, shape=tuple(w.shape),
                   scales_shape=scales_shape)

    # -- access ---------------------------------------------------------------
    def block_scales(self) -> jnp.ndarray:
        if self.scales is not None:
            return self.scales
        # target shape derived from the *runtime* data shape (leading layer
        # dims may have been sliced away by lax.scan); only the q_axis dim
        # differs from data's (nb blocks vs packed codes), and q_axis is a
        # negative index so it survives slicing.
        nb = self.scales_shape[self.q_axis]
        shape = list(self.data.shape)
        shape[self.q_axis] = nb
        return dequantize_scales(self.scales_q, self.scales_cscale,
                                 self.scales_offset, tuple(shape))

    def dequantize(self, dtype=jnp.bfloat16) -> jnp.ndarray:
        w = dequantize_blockwise(self.data, self.block_scales(), self.fmt,
                                 q_axis=self.q_axis, out_dtype=dtype)
        return w

    def with_lora(self, lora_a: jnp.ndarray, lora_b: jnp.ndarray,
                  alpha: float = 16.0) -> "QTensor":
        return dataclasses.replace(self, lora_a=lora_a, lora_b=lora_b,
                                   lora_alpha=alpha)

    @property
    def format(self) -> Format:
        return get_format(self.fmt)

    def nbytes(self) -> int:
        total = 0
        for arr in (self.data, self.scales, self.scales_q, self.scales_cscale,
                    self.scales_offset, self.lora_a, self.lora_b):
            if arr is not None:
                total += arr.size * arr.dtype.itemsize
        return total

    def __repr__(self):  # pragma: no cover
        return (f"QTensor({self.fmt}, shape={self.shape}, "
                f"packed={tuple(self.data.shape)}, dq={self.scales is None}, "
                f"lora={'yes' if self.lora_a is not None else 'no'})")


def maybe_dequantize(w: Any, dtype=jnp.bfloat16) -> jnp.ndarray:
    """QTensor -> dense array; plain arrays pass through (cast)."""
    if isinstance(w, QTensor):
        return w.dequantize(dtype)
    return w.astype(dtype)


def tensor_nbytes(w: Any) -> int:
    if isinstance(w, QTensor):
        return w.nbytes()
    return w.size * w.dtype.itemsize
