"""PrecisionPolicy — per-layer format selection.

The paper's RMMEC MAC reconfigures per issue between 1xBF16 / 3xFP8 /
6xFP4 / 6xINT4 via a mode-control signal ("run-time adaptivity", Table I).
The software analogue: a policy mapping each parameter path to a storage
format, so one model definition deploys at any precision mix. The paper's
deployed configuration keeps norms/biases high-precision, embeddings at
8-bit, and the matmul weights sub-octet — exposed here as presets.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Any, Tuple

import jax
import jax.numpy as jnp

from .qtensor import QTensor, tensor_nbytes
from .spec import ALIASES

__all__ = ["PrecisionPolicy", "PRESETS", "quantize_tree", "tree_nbytes"]

# parameter-path fragments never quantized (tiny and/or precision-critical)
_EXEMPT = re.compile(
    r"(norm|bias|scale_|rope|a_log|dt_|conv|rglru|router|a_param|\['D'\])")
_EMBED = re.compile(r"(embedding|lm_head|pos_embed)")


@dataclasses.dataclass(frozen=True)
class PrecisionPolicy:
    name: str = "bf16"
    weights: str = "bf16"          # matmul weight storage format
    embed: str = "bf16"            # embedding / lm-head storage format
    kv_cache: str = "bf16"         # KV-cache storage: bf16 | int8 | fp8
    act: str = "bf16"              # matmul activation format: bf16 | int8
    block_size: int = 64
    double_quant: bool = False
    compute_dtype: Any = jnp.bfloat16
    overrides: Tuple[Tuple[str, str], ...] = ()   # (path regex, fmt)

    def format_for(self, path: str) -> str:
        for pat, fmt in self.overrides:
            if re.search(pat, path):
                return fmt
        if _EXEMPT.search(path):
            return "bf16"
        if _EMBED.search(path):
            return self.embed
        return self.weights


# Presets mirror the paper's evaluated precisions (Fig. 10): the Baseline
# (bf16 here; the paper's FP32 baseline maps to f32), INT8/FP8, INT4/FP4,
# and the QLoRA NF4 deployment. Each name is a registered QuantSpec alias
# (core.spec.ALIASES) — this table is derived from it, so an alias and
# its grammar spelling deploy byte-for-byte identical trees. Notably,
# w8a8 stores weights with per-CHANNEL scales (spec group 0: one K-block
# spanning any K) — the integer-MAC path in qlinear needs a single scale
# per output channel to rescale the int32 accumulator.
PRESETS = {name: s.policy(name=name) for name, s in ALIASES.items()}


def _is_quantizable(path: str, leaf: Any, fmt: str) -> bool:
    if fmt in ("bf16", "f32"):
        return False
    if not hasattr(leaf, "ndim") or leaf.ndim < 2:
        return False
    if not jnp.issubdtype(leaf.dtype, jnp.floating):
        return False
    return True


def quantize_tree(params: Any, policy: PrecisionPolicy) -> Any:
    """PTQ an entire parameter tree per the policy (paper §III setup)."""

    def visit(path, leaf):
        pstr = jax.tree_util.keystr(path)
        fmt = policy.format_for(pstr)
        if not _is_quantizable(pstr, leaf, fmt):
            if hasattr(leaf, "astype") and jnp.issubdtype(leaf.dtype, jnp.floating):
                return leaf.astype(policy.compute_dtype)
            return leaf
        q_axis = -1 if _EMBED.search(pstr) else -2
        return QTensor.quantize(leaf, fmt, block_size=policy.block_size,
                                q_axis=q_axis, double_quant=policy.double_quant)

    return jax.tree_util.tree_map_with_path(
        visit, params, is_leaf=lambda x: isinstance(x, QTensor))


def tree_nbytes(params: Any) -> int:
    """Total storage bytes of a (possibly quantized) parameter tree."""
    leaves = jax.tree_util.tree_leaves(
        params, is_leaf=lambda x: isinstance(x, QTensor))
    return sum(tensor_nbytes(leaf) for leaf in leaves
               if isinstance(leaf, QTensor) or hasattr(leaf, "dtype"))
