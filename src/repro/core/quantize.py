"""Blockwise absmax quantization (paper §III: BitsAndBytes-style PTQ).

A tensor is quantized along one axis (``q_axis``) in contiguous blocks of
``block_size`` values; each block shares one scale = absmax / fmt.max_code.
Supported axes:

  * ``q_axis=-2`` — weight matrices ``(..., K, N)``: blocks run along the
    contraction dim K, so the matmul kernel dequantizes K-slabs in VMEM
    (mirrors the paper's output-stationary systolic accumulation);
  * ``q_axis=-1`` — embedding tables ``(V, D)`` and vectors: blocks run
    along the feature dim so row-gathers stay cheap.

Double quantization (QLoRA trick, used by the paper's 4-bit arm): the f32
block scales are themselves quantized to int8 in chunks of 256, cutting
scale overhead from 32/block_size to ~8.25/block_size bits per weight.
"""

from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp
import numpy as np

from .formats import (Format, get_format, nibble_from_signed, pack_nibbles,
                      signed_from_nibble, unpack_nibbles)

__all__ = [
    "quantize_blockwise", "dequantize_blockwise",
    "quantize_scales", "dequantize_scales", "effective_block_size",
]

_DQ_CHUNK = 256  # scales-of-scales chunk (bitsandbytes default)


def effective_block_size(dim: int, block_size: int) -> int:
    """Largest usable block size: must divide ``dim`` (fallback: whole dim)."""
    if block_size <= 0 or dim % block_size != 0:
        return dim
    return block_size


def _block_view(x: jnp.ndarray, q_axis: int, block: int) -> jnp.ndarray:
    """Reshape so blocks get their own axis right after the split q_axis."""
    q_axis = q_axis % x.ndim
    dim = x.shape[q_axis]
    shape = list(x.shape)
    shape[q_axis:q_axis + 1] = [dim // block, block]
    return x.reshape(shape)


def quantize_blockwise(
    w: jnp.ndarray,
    fmt: Format | str,
    block_size: int = 64,
    q_axis: int = -2,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Quantize ``w`` -> (codes, scales).

    codes:  packed uint8 (4-bit fmts), int8 (int8), float8 (fp8 fmts)
    scales: f32, shape = w.shape with q_axis replaced by n_blocks
    """
    if isinstance(fmt, str):
        fmt = get_format(fmt)
    if fmt.kind == "none":
        raise ValueError(f"format {fmt.name} is a passthrough; nothing to quantize")
    q_axis = q_axis % w.ndim
    block = effective_block_size(w.shape[q_axis], block_size)
    xb = _block_view(w.astype(jnp.float32), q_axis, block)   # (..., nb, B, ...)
    absmax = jnp.max(jnp.abs(xb), axis=q_axis + 1)            # (..., nb, ...)
    scales = (absmax / fmt.max_code).astype(jnp.float32)
    safe = jnp.where(scales == 0, 1.0, scales)
    xs = xb / jnp.expand_dims(safe, q_axis + 1)               # normalized block

    if fmt.kind == "int":
        q = jnp.clip(jnp.round(xs), -fmt.max_code, fmt.max_code)
        codes = q.reshape(w.shape)
        if fmt.bits == 4:
            codes = pack_nibbles(nibble_from_signed(codes), axis=q_axis)
        else:
            codes = codes.astype(jnp.int8)
    elif fmt.kind == "codebook":
        cb = jnp.asarray(fmt.codebook)
        bounds = jnp.asarray(fmt.boundaries())
        idx = jnp.searchsorted(bounds, xs).astype(jnp.uint8)  # nearest entry
        del cb
        codes = pack_nibbles(idx.reshape(w.shape), axis=q_axis)
    elif fmt.kind == "float8":
        codes = xs.reshape(w.shape).astype(fmt.storage_dtype)
    else:  # pragma: no cover
        raise ValueError(fmt.kind)
    return codes, scales


def dequantize_blockwise(
    codes: jnp.ndarray,
    scales: jnp.ndarray,
    fmt: Format | str,
    q_axis: int = -2,
    out_dtype: jnp.dtype = jnp.bfloat16,
) -> jnp.ndarray:
    """Inverse of :func:`quantize_blockwise` (up to rounding error)."""
    if isinstance(fmt, str):
        fmt = get_format(fmt)
    q_axis = q_axis % codes.ndim

    if fmt.kind == "int" and fmt.bits == 4:
        vals = signed_from_nibble(unpack_nibbles(codes, axis=q_axis)).astype(jnp.float32)
    elif fmt.kind == "int":
        vals = codes.astype(jnp.float32)
    elif fmt.kind == "codebook":
        idx = unpack_nibbles(codes, axis=q_axis)
        vals = jnp.asarray(fmt.codebook)[idx]
    elif fmt.kind == "float8":
        vals = codes.astype(jnp.float32)
    else:  # pragma: no cover
        raise ValueError(fmt.kind)

    dim = vals.shape[q_axis]
    nb = scales.shape[q_axis]
    block = dim // nb
    vb = _block_view(vals, q_axis, block)
    out = vb * jnp.expand_dims(scales.astype(jnp.float32), q_axis + 1)
    return out.reshape(vals.shape).astype(out_dtype)


# ---------------------------------------------------------------------------
# Double quantization: int8-quantize the f32 block scales themselves.
# Scales are positive, so we quantize (scale - mean) symmetrically per chunk.
# ---------------------------------------------------------------------------

def quantize_scales(scales: jnp.ndarray):
    """f32 scales -> (int8 codes, f32 chunk scale, f32 offset, orig shape).

    Stacked-layer scales (ndim >= 3, leading layer axis) keep that axis on
    every output so the QTensor stays lax.scan-sliceable.
    """
    shape = scales.shape
    lead = shape[0] if len(shape) >= 3 else 1
    flat = scales.reshape(lead, -1).astype(jnp.float32)
    n = flat.shape[1]
    pad = (-n) % _DQ_CHUNK
    flat = jnp.pad(flat, ((0, 0), (0, pad)))
    chunks = flat.reshape(lead, -1, _DQ_CHUNK)
    offset = jnp.mean(chunks, axis=-1, keepdims=True)
    centred = chunks - offset
    absmax = jnp.max(jnp.abs(centred), axis=-1, keepdims=True)
    cscale = jnp.where(absmax == 0, 1.0, absmax / 127.0)
    codes = jnp.clip(jnp.round(centred / cscale), -127, 127).astype(jnp.int8)
    if len(shape) < 3:   # unstacked: drop the synthetic batch dim
        codes, cscale, offset = codes[0], cscale[0], offset[0]
    return codes, cscale.astype(jnp.float32), offset.astype(jnp.float32), shape


def dequantize_scales(codes, cscale, offset, shape) -> jnp.ndarray:
    flat = codes.astype(jnp.float32) * cscale + offset
    n = int(np.prod(shape))
    return flat.reshape(-1)[:n].reshape(shape)
