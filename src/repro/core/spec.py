"""QuantSpec — the composable quantization-spec surface (paper Fig. 10).

The paper's deployment story is a *grid* of precision mixes selected by
the RMMEC mode signal: weights, activations, and the KV cache each pick
a format independently. A closed preset dict cannot enumerate a grid, so
every entry point (deploy, launch.serve/eval, eval.sweep,
bench_quant_formats, dryrun) accepts a ``QuantSpec`` instead: a frozen,
validated spec object with a string grammar, resolved in exactly one
place (:func:`resolve_spec`).

Grammar (one ``w`` field, the rest optional, in this order)::

    w<fmt> [a<fmt>] [kv<fmt>] [x<fmt>] [e<fmt>] [g<int>] [dq]

    w   weight storage         4|8|16|fp4|nf4|fp8|fp8e4m3|fp8e5m2|f32 ...
    a   activation format      8 (int8) | fp8 | 16 (bf16, default)
    kv  KV-cache storage       8 | fp8 | 16 (default) | f32
    x   attention-matmul format — the QK/PV activation-activation
                               einsums (both operands fake-quantized,
                               wide f32 accumulate; the sparseml
                               QuantizableMatMul shape): 8 | fp8 |
                               16 (bf16, default = untouched)
    e   embedding storage      default: int8 for 4-bit weights, else = w
    g   weight block size      g0 = per-channel (one K-block); default 64,
                               or per-channel when w8 meets a8 so the
                               integer-MAC path stays eligible
    dq  double-quantize the block scales (QLoRA trick)

Examples: ``w4a8kv8``, ``w8a8kv8g32``, ``wfp4a8``, ``wfp8e4m3afp8kvfp8``,
``w8a8kv8x8``.
Legacy preset names (``int4``, ``w8a8``, ``nf4``, ...) are registered
aliases in :data:`ALIASES`; ``str(spec)`` is the canonical grammar form
and round-trips: ``QuantSpec.parse(str(spec)) == spec``.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

from .formats import FORMATS

__all__ = ["QuantSpec", "ALIASES", "resolve_spec", "SPEC_GRAMMAR"]

SPEC_GRAMMAR = "w<fmt>[a<fmt>][kv<fmt>][x<fmt>][e<fmt>][g<int>][dq]"

# grammar token -> core.formats name (longest token wins during parsing)
_TOKENS = {
    "4": "int4", "8": "int8", "16": "bf16",
    "int4": "int4", "int8": "int8", "bf16": "bf16", "f32": "f32",
    "fp4": "fp4", "nf4": "nf4",
    "fp8": "fp8", "fp8e4m3": "fp8", "fp8e5m2": "fp8_e5m2",
}
# formats name -> canonical grammar token (shortest spelling)
_CANON = {"int4": "4", "int8": "8", "bf16": "16", "f32": "f32",
          "fp4": "fp4", "nf4": "nf4", "fp8": "fp8", "fp8_e5m2": "fp8e5m2"}

_ACT_FMTS = ("bf16", "int8", "fp8")
_KV_FMTS = ("bf16", "f32", "int8", "fp8")

_FMT_ALT = "|".join(sorted(_TOKENS, key=len, reverse=True))
_SPEC_RE = re.compile(
    rf"^w(?P<w>{_FMT_ALT})(?:a(?P<a>{_FMT_ALT}))?(?:kv(?P<kv>{_FMT_ALT}))?"
    rf"(?:x(?P<x>{_FMT_ALT}))?"
    rf"(?:e(?P<e>{_FMT_ALT}))?(?:g(?P<g>\d+))?(?P<dq>dq)?$")


def _default_embed(weights: str) -> str:
    """Embeddings ride at int8 under 4-bit weights (paper's 0.56 GB FP4
    footprint for 600M), otherwise share the weight format."""
    return {"int4": "int8", "fp4": "int8", "nf4": "int8"}.get(weights, weights)


def _default_group(weights: str, act: str) -> int:
    """0 = per-channel (one K-block). w8+a8 defaults to per-channel so
    the integer-MAC path in qlinear stays eligible; everything else uses
    the BitsAndBytes-style 64-value block."""
    return 0 if (weights == "int8" and act == "int8") else 64


@dataclasses.dataclass(frozen=True)
class QuantSpec:
    """A validated precision mix: weight/act/KV formats + block layout.

    ``embed`` and ``group`` default to ``None`` and are normalized to
    their derived values at construction, so two specs spelling the same
    deployment compare equal regardless of how they were written.
    """

    weights: str = "bf16"
    act: str = "bf16"
    kv: str = "bf16"
    attn: str = "bf16"              # QK/PV attention-matmul format (x<fmt>)
    embed: Optional[str] = None
    group: Optional[int] = None     # weight block size; 0 = per-channel
    double_quant: bool = False

    def __post_init__(self):
        if self.weights not in FORMATS:
            raise ValueError(
                f"unknown weight format {self.weights!r}; have "
                f"{sorted(FORMATS)}")
        if self.act not in _ACT_FMTS:
            raise ValueError(
                f"activation format must be one of {_ACT_FMTS}, got "
                f"{self.act!r}")
        if self.act != "bf16" and FORMATS[self.weights].kind == "none":
            # a passthrough weight tree has no QTensors, so qmatmul's
            # plain-array branch would never quantize activations — the
            # spec would silently mean bf16, the exact bug class the
            # act path guards against
            raise ValueError(
                f"activation format {self.act!r} requires quantized "
                f"weights, but {self.weights!r} is a passthrough — "
                "activations only quantize at quantized-weight matmuls "
                "(try w8a8 / w4a8 / wfp8afp8)")
        if self.kv not in _KV_FMTS:
            raise ValueError(
                f"KV-cache format must be one of {_KV_FMTS}, got "
                f"{self.kv!r}")
        if self.attn not in _ACT_FMTS:
            # attention matmuls are activation x activation: no weight
            # tree involved, so (unlike a<fmt>) any weight format may
            # carry an x<fmt> slot
            raise ValueError(
                f"attention-matmul format must be one of {_ACT_FMTS}, "
                f"got {self.attn!r}")
        if self.embed is None:
            object.__setattr__(self, "embed", _default_embed(self.weights))
        elif self.embed not in FORMATS:
            raise ValueError(
                f"unknown embed format {self.embed!r}; have "
                f"{sorted(FORMATS)}")
        if self.group is None:
            object.__setattr__(self, "group",
                               _default_group(self.weights, self.act))
        elif self.group < 0:
            raise ValueError(f"group must be >= 0, got {self.group}")

    # -- grammar --------------------------------------------------------

    @classmethod
    def parse(cls, text: str) -> "QuantSpec":
        """Parse a grammar string (see module docstring) into a spec."""
        m = _SPEC_RE.match(text.strip())
        if not m:
            raise ValueError(
                f"{text!r} does not match the spec grammar {SPEC_GRAMMAR}")
        g = m.group("g")
        return cls(
            weights=_TOKENS[m.group("w")],
            act=_TOKENS[m.group("a")] if m.group("a") else "bf16",
            kv=_TOKENS[m.group("kv")] if m.group("kv") else "bf16",
            attn=_TOKENS[m.group("x")] if m.group("x") else "bf16",
            embed=_TOKENS[m.group("e")] if m.group("e") else None,
            group=int(g) if g is not None else None,
            double_quant=m.group("dq") is not None)

    def __str__(self) -> str:
        """Canonical grammar form; omits fields at their derived default
        so ``parse(str(spec)) == spec`` exactly."""
        out = ["w", _CANON[self.weights]]
        if self.act != "bf16":
            out += ["a", _CANON[self.act]]
        if self.kv != "bf16":
            out += ["kv", _CANON[self.kv]]
        if self.attn != "bf16":
            out += ["x", _CANON[self.attn]]
        if self.embed != _default_embed(self.weights):
            out += ["e", _CANON[self.embed]]
        if self.group != _default_group(self.weights, self.act):
            out += ["g", str(self.group)]
        if self.double_quant:
            out.append("dq")
        return "".join(out)

    # -- derived views --------------------------------------------------

    def policy(self, name: Optional[str] = None):
        """The PrecisionPolicy that quantizes a parameter tree per this
        spec (byte-for-byte identical to the legacy preset table for
        every registered alias)."""
        import jax.numpy as jnp

        from .policy import PrecisionPolicy
        return PrecisionPolicy(
            name=name or str(self),
            weights=self.weights, embed=self.embed, kv_cache=self.kv,
            act=self.act,
            block_size=self.group if self.group > 0 else 2 ** 20,
            double_quant=self.double_quant,
            compute_dtype=jnp.float32 if self.weights == "f32"
            else jnp.bfloat16)

    @property
    def bytes_per_param(self) -> Dict[str, float]:
        """Storage bytes per parameter implied by the spec, per class —
        the single source benchmarks derive size columns from."""
        return {"weights": FORMATS[self.weights].bytes_per_param,
                "embed": FORMATS[self.embed].bytes_per_param,
                "kv": FORMATS[self.kv].bytes_per_param}

    @property
    def quantizes_act(self) -> bool:
        return self.act != "bf16"

    @property
    def quantizes_attn(self) -> bool:
        """True when the QK/PV attention matmuls run fake-quantized
        (the x<fmt> slot; routed via Ctx.attn_act_fmt, not the weight
        tree — see models.layers.Ctx.attn_dot)."""
        return self.attn != "bf16"


# Legacy preset names as registered aliases — field-for-field the PR 4
# PRESETS table, so every alias deploys an identical quantized tree.
ALIASES: Dict[str, QuantSpec] = {
    "f32": QuantSpec(weights="f32"),
    "bf16": QuantSpec(),
    "int8": QuantSpec(weights="int8"),
    "w8a8": QuantSpec(weights="int8", act="int8", kv="int8"),
    "fp8": QuantSpec(weights="fp8", kv="fp8"),
    "int4": QuantSpec(weights="int4", kv="int8"),
    "fp4": QuantSpec(weights="fp4", kv="int8"),
    "nf4": QuantSpec(weights="nf4", kv="int8", double_quant=True),
    # the end-to-end fp8 arm (weights + activations + KV all e4m3)
    "fp8e2e": QuantSpec(weights="fp8", act="fp8", kv="fp8"),
}


def resolve_spec(spec) -> QuantSpec:
    """The one resolver every entry point routes through.

    Accepts a QuantSpec (returned as-is), a registered alias name, or a
    grammar string. Unknown strings raise a ValueError naming the bad
    spec and listing the valid aliases + grammar.
    """
    if isinstance(spec, QuantSpec):
        return spec
    if isinstance(spec, str):
        if spec in ALIASES:
            return ALIASES[spec]
        try:
            return QuantSpec.parse(spec)
        except ValueError as e:
            raise ValueError(
                f"unknown quantization spec {spec!r} ({e}); use an alias "
                f"from {sorted(ALIASES)} or the grammar {SPEC_GRAMMAR} "
                f"with formats {sorted(_TOKENS)} (e.g. 'w4a8kv8', "
                f"'wfp8e4m3afp8kvfp8')") from None
    raise TypeError(
        f"spec must be a QuantSpec or string, got {type(spec).__name__}")
