"""Core library: the paper's contribution (sub-octet quantization +
co-designed kernels' software interface) as composable JAX modules."""

from .calibration import ActStats, calibrate, calibrate_act_scale
from .formats import FORMATS, Format, get_format
from .policy import PRESETS, PrecisionPolicy, quantize_tree, tree_nbytes
from .qlinear import embed_lookup, qmatmul, quantize_activations_int8
from .qlora import (attach_lora, count_adapter_params, extract_adapters,
                    inject_adapters, merge_lora)
from .qtensor import QTensor, maybe_dequantize, tensor_nbytes
from .quantize import dequantize_blockwise, quantize_blockwise

__all__ = [
    "FORMATS", "Format", "get_format",
    "PRESETS", "PrecisionPolicy", "quantize_tree", "tree_nbytes",
    "QTensor", "maybe_dequantize", "tensor_nbytes",
    "quantize_blockwise", "dequantize_blockwise",
    "qmatmul", "embed_lookup", "quantize_activations_int8",
    "ActStats", "calibrate", "calibrate_act_scale",
    "attach_lora", "extract_adapters", "inject_adapters", "merge_lora",
    "count_adapter_params",
]
