"""Core library: the paper's contribution (sub-octet quantization +
co-designed kernels' software interface) as composable JAX modules."""

from .calibration import (ActSiteStats, ActStats, SiteCollector, calibrate,
                          calibrate_act_scale, calibrate_act_scales)
from .formats import FORMATS, Format, get_format
from .policy import PRESETS, PrecisionPolicy, quantize_tree, tree_nbytes
from .qlinear import (act_quant_eligible, embed_lookup, int8_mac_eligible,
                      qmatmul, quantize_activations,
                      quantize_activations_int8)
from .qlora import (attach_lora, count_adapter_params, extract_adapters,
                    inject_adapters, merge_lora)
from .qtensor import QTensor, maybe_dequantize, tensor_nbytes
from .quantize import dequantize_blockwise, quantize_blockwise
from .spec import ALIASES, SPEC_GRAMMAR, QuantSpec, resolve_spec

__all__ = [
    "FORMATS", "Format", "get_format",
    "QuantSpec", "resolve_spec", "ALIASES", "SPEC_GRAMMAR",
    "PRESETS", "PrecisionPolicy", "quantize_tree", "tree_nbytes",
    "QTensor", "maybe_dequantize", "tensor_nbytes",
    "quantize_blockwise", "dequantize_blockwise",
    "qmatmul", "embed_lookup", "quantize_activations",
    "quantize_activations_int8", "int8_mac_eligible", "act_quant_eligible",
    "ActStats", "ActSiteStats", "SiteCollector", "calibrate",
    "calibrate_act_scale", "calibrate_act_scales",
    "attach_lora", "extract_adapters", "inject_adapters", "merge_lora",
    "count_adapter_params",
]
