"""Per-site activation calibration for static quantization (paper §III).

For the act-quantizing arms (w8a8, the fp8 end-to-end arm) the paper
calibrates on ~1000 queries/language. Quantization impact in MT is
uneven across matmul sites (Bhandare et al., 2019: int8 NMT needs
per-matmul scale placement), so the calibrator keeps one absmax
statistic *per matmul site path* (``enc.attn.qkv``, ``dec.ffn.in``,
``dec.cross.kv``, ``head``, ... — the labels model code passes to
``Ctx.dot``) instead of one global scalar:

    scales = calibrate_act_scales(model, params, ctx, batches)
    ctx = dataclasses.replace(ctx, act_scales=tuple(sorted(scales.items())))

Each site's static scale is ``absmax / max_code`` for the deployed
activation format; sites never observed during calibration fall back to
dynamic per-token quantization at serve time (qlinear).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Dict, Iterable

import jax
import jax.numpy as jnp

__all__ = ["ActSiteStats", "SiteCollector", "calibrate_act_scales",
           "calibrate_act_scale", "ActStats", "calibrate"]

_UNSITED = "unsited"      # matmuls whose call site passed no label


class ActSiteStats:
    """Streaming per-site absmax registry.

    ``update`` folds one observation; ``merge`` combines registries from
    independent batch streams. Both reduce with ``max``, so merging is
    associative and commutative — multi-host / multi-batch calibration
    gives the same scales in any order.
    """

    def __init__(self, absmax: Dict[str, float] | None = None):
        self.absmax: Dict[str, float] = dict(absmax or {})

    def update(self, site: str, value: float) -> None:
        v = float(value)
        self.absmax[site] = max(self.absmax.get(site, 0.0), v)

    def merge(self, other: "ActSiteStats") -> "ActSiteStats":
        out = ActSiteStats(self.absmax)
        for site, v in other.absmax.items():
            out.update(site, v)
        return out

    def scales(self, max_code: float = 127.0) -> Dict[str, float]:
        """site -> static activation scale (absmax / max_code)."""
        return {site: max(v, 1e-8) / max_code
                for site, v in self.absmax.items()}

    def __len__(self) -> int:
        return len(self.absmax)


class SiteCollector:
    """The host-side sink ``Ctx.dot`` ships per-site |x| maxima to (via
    jax.debug.callback, scan-safe). Bind a site with ``bind(site)``."""

    def __init__(self):
        self.stats = ActSiteStats()

    def bind(self, site: str | None) -> Callable:
        return functools.partial(self.stats.update, site or _UNSITED)


def calibrate_act_scales(model, params, ctx, batches: Iterable,
                         max_code: float = 127.0) -> Dict[str, float]:
    """Per-site static activation scales for an act-quantizing deploy.

    Runs eager forward passes over ``batches`` with a collector-carrying
    Ctx: every activation entering a quantized-weight matmul
    (qlinear.act_quant_eligible) reports its absmax under the site label
    the layer passed to ``Ctx.dot``; statistics fold with ``max`` across
    batches (and across the layers a lax.scan stacks onto one site).
    ``params`` should be the already-quantized tree being deployed, so
    the observed activations are exactly what the quantized path sees.

    ``max_code`` is the deployed activation format's absmax code (127
    for int8, 448 for fp8 e4m3). Returns ``{}`` when ``batches`` is
    empty — callers fall back to dynamic quantization (deploy() warns).
    """
    collector = SiteCollector()
    # bf16 act route: observe the float activations the quantized path
    # would quantize, through the same quantized weights
    cctx = dataclasses.replace(ctx, act_fmt="bf16", act_collector=collector)
    saw_batch = False
    for batch in batches:
        saw_batch = True
        logits, _ = model.forward(cctx, params, batch)
        jax.block_until_ready(logits)
        jax.effects_barrier()           # flush the collector callbacks:
        # block_until_ready covers the value, not the host-callback
        # queue — without the barrier an async backend can reach the
        # registry read before the updates land
    if saw_batch and not len(collector.stats):
        raise ValueError(
            "calibration saw no quantized-weight matmuls — the deployed "
            "tree has no QTensor sites to calibrate (was the policy a "
            "bf16/f32 passthrough?)")
    return collector.stats.scales(max_code)


def calibrate_act_scale(model, params, ctx, batches: Iterable,
                        max_code: float = 127.0) -> float:
    """Legacy single-scalar calibration: the max per-site scale (the
    envelope every site saturates within). Prefer calibrate_act_scales —
    a global scalar wastes grid resolution at quiet sites."""
    scales = calibrate_act_scales(model, params, ctx, batches,
                                  max_code=max_code)
    if not scales:
        raise ValueError(
            "calibration consumed no batches — pass a non-empty (fresh, "
            "not already-iterated) batch iterable")
    return max(scales.values())


# -- generic streaming statistics (kept for direct library use) ------------

class ActStats:
    """Streaming absmax + histogram-free percentile estimate (P^2-lite)."""

    def __init__(self, percentile: float = 99.9):
        self.percentile = percentile
        self.absmax = 0.0
        self.samples: list[float] = []

    def update(self, x: jnp.ndarray):
        ax = float(jnp.max(jnp.abs(x)))
        self.absmax = max(self.absmax, ax)
        # store per-batch percentile; final estimate = median of batch stats
        self.samples.append(float(jnp.percentile(jnp.abs(x), self.percentile)))

    def scale(self, max_code: float = 127.0) -> float:
        if not self.samples:
            return 1.0
        import statistics
        pct = statistics.median(self.samples)
        return max(pct, 1e-8) / max_code


def calibrate(apply_fn: Callable, batches: Iterable, percentile=99.9) -> ActStats:
    """Run ``apply_fn(batch) -> activation`` over batches, fold statistics."""
    stats = ActStats(percentile)
    for b in batches:
        stats.update(apply_fn(b))
    return stats
