"""Activation calibration for static quantization (paper §III PTQ setup).

For the w8a8 arm the paper calibrates on ~1000 queries/language; here the
calibrator folds absmax / percentile statistics over sample activation
batches and produces per-tensor scales usable by qlinear's int8 path.
"""

from __future__ import annotations

from typing import Callable, Iterable

import jax.numpy as jnp

__all__ = ["ActStats", "calibrate"]


class ActStats:
    """Streaming absmax + histogram-free percentile estimate (P^2-lite)."""

    def __init__(self, percentile: float = 99.9):
        self.percentile = percentile
        self.absmax = 0.0
        self.samples: list[float] = []

    def update(self, x: jnp.ndarray):
        ax = float(jnp.max(jnp.abs(x)))
        self.absmax = max(self.absmax, ax)
        # store per-batch percentile; final estimate = median of batch stats
        self.samples.append(float(jnp.percentile(jnp.abs(x), self.percentile)))

    def scale(self, max_code: float = 127.0) -> float:
        if not self.samples:
            return 1.0
        import statistics
        pct = statistics.median(self.samples)
        return max(pct, 1e-8) / max_code


def calibrate(apply_fn: Callable, batches: Iterable, percentile=99.9) -> ActStats:
    """Run ``apply_fn(batch) -> activation`` over batches, fold statistics."""
    stats = ActStats(percentile)
    for b in batches:
        stats.update(apply_fn(b))
    return stats
