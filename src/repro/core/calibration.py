"""Activation calibration for static quantization (paper §III PTQ setup).

For the w8a8 arm the paper calibrates on ~1000 queries/language; here the
calibrator folds absmax / percentile statistics over sample activation
batches and produces per-tensor scales usable by qlinear's int8 path.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Iterable

import jax
import jax.numpy as jnp

__all__ = ["ActStats", "calibrate", "calibrate_act_scale"]


class ActStats:
    """Streaming absmax + histogram-free percentile estimate (P^2-lite)."""

    def __init__(self, percentile: float = 99.9):
        self.percentile = percentile
        self.absmax = 0.0
        self.samples: list[float] = []

    def update(self, x: jnp.ndarray):
        ax = float(jnp.max(jnp.abs(x)))
        self.absmax = max(self.absmax, ax)
        # store per-batch percentile; final estimate = median of batch stats
        self.samples.append(float(jnp.percentile(jnp.abs(x), self.percentile)))

    def scale(self, max_code: float = 127.0) -> float:
        if not self.samples:
            return 1.0
        import statistics
        pct = statistics.median(self.samples)
        return max(pct, 1e-8) / max_code


def calibrate(apply_fn: Callable, batches: Iterable, percentile=99.9) -> ActStats:
    """Run ``apply_fn(batch) -> activation`` over batches, fold statistics."""
    stats = ActStats(percentile)
    for b in batches:
        stats.update(apply_fn(b))
    return stats


def calibrate_act_scale(model, params, ctx, batches: Iterable,
                        percentile: float = 99.9,
                        max_code: float = 127.0) -> float:
    """ONE global static activation scale for the w8a8 int8 matmul path.

    Runs eager forward passes over ``batches`` with a collector-carrying
    Ctx: every activation entering an integer-MAC-eligible matmul
    (qlinear.int8_mac_eligible) contributes its |x| distribution
    (Ctx.dot appends to ``act_collector``), and one forward's worth is
    folded per calibrate() step — absmax plus a percentile estimate,
    scale = percentile / max_code. ``params`` should be the
    already-quantized tree being deployed, so the observed activations
    are exactly what the int8 path will see.

    Deliberately coarser than the paper's per-matmul calibration: the
    scale is a single scalar shared by every int8 matmul (layers whose
    activation range sits far below the global percentile lose part of
    their int8 grid). Per-matmul scale trees are a listed follow-up in
    ROADMAP; this threads the plumbing end to end.
    """
    def apply_fn(batch):
        sink: list = []
        # bf16 act route: observe the float activations the int8 path
        # would quantize, through the same quantized weights
        cctx = dataclasses.replace(ctx, act_fmt="bf16", act_collector=sink)
        logits, _ = model.forward(cctx, params, batch)
        jax.block_until_ready(logits)
        jax.effects_barrier()           # flush the collector callbacks:
        # block_until_ready covers the value, not the host-callback
        # queue — without the barrier an async backend can reach the
        # sink read before the appends land
        if not sink:
            raise ValueError(
                "calibration saw no per-channel int8-weight matmuls — the "
                "deployed policy has no active w8a8 path to calibrate "
                "(int8 weights must carry one K-block of scales; see "
                "PRESETS['w8a8'])")
        return jnp.concatenate([jnp.ravel(jnp.asarray(a)) for a in sink])

    stats = calibrate(apply_fn, batches, percentile)
    if not stats.samples:
        # an exhausted generator would otherwise yield ActStats' empty
        # fallback scale of 1.0 — catastrophic for O(1) activations, and
        # indistinguishable from a calibrated deployment downstream
        raise ValueError(
            "calibration consumed no batches — pass a non-empty (fresh, "
            "not already-iterated) batch iterable")
    return stats.scale(max_code)
