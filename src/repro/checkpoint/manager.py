"""Checkpointing + fault-tolerance substrate.

Design points for 1000+-node fleets (DESIGN.md §3):
  * mesh-agnostic layout: leaves are saved as *full logical arrays*
    (device-gathered), so a job restarted on a different device count /
    mesh shape resharding-restores cleanly (elastic scaling);
  * atomic publish: write to ``step_XXXX.tmp`` then os.rename — a
    preempted writer never corrupts the latest checkpoint;
  * keep-last-k GC, step discovery, auto-resume (restore latest);
  * async save (background thread) so the train loop overlaps I/O;
  * preemption hook: SIGTERM flips a flag the train loop polls, final
    checkpoint is written before exit (straggler/eviction tolerance).

Multi-host note: in a real multi-process job only process 0 writes after
a jax.experimental.multihost_utils gather, or each process writes its
addressable shards; on this single-process container the gather is the
identity. The format (one .npy per leaf + JSON manifest of keystr paths)
is host-count independent.
"""

from __future__ import annotations

import json
import os
import re
import shutil
import signal
import threading
from typing import Any, Optional

import jax
import numpy as np

__all__ = ["save_tree", "restore_tree", "latest_step", "CheckpointManager"]

_STEP_RE = re.compile(r"^step_(\d+)$")


def _flatten(tree):
    return jax.tree_util.tree_flatten_with_path(tree)


_RAW_VIEW = {2: np.uint16, 1: np.uint8, 4: np.uint32}


def save_tree(path: str, tree: Any, step: int, extra: Optional[dict] = None):
    """Atomic full-array checkpoint at ``path/step_{step}``."""
    final = os.path.join(path, f"step_{step}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    flat, _ = _flatten(tree)
    manifest = {"step": step, "extra": extra or {}, "leaves": []}
    for i, (kp, leaf) in enumerate(flat):
        name = f"leaf_{i:05d}.npy"
        arr = np.asarray(jax.device_get(leaf)) if leaf is not None else None
        if arr is None:
            manifest["leaves"].append({"path": jax.tree_util.keystr(kp),
                                       "file": None})
            continue
        dtype_name = str(arr.dtype)
        if arr.dtype.kind == "V" or dtype_name not in np.sctypeDict:
            # ml_dtypes (bfloat16 / float8): store raw bits, record dtype
            arr = arr.view(_RAW_VIEW[arr.dtype.itemsize])
        np.save(os.path.join(tmp, name), arr)
        manifest["leaves"].append({"path": jax.tree_util.keystr(kp),
                                   "file": name, "dtype": dtype_name,
                                   "shape": list(arr.shape)})
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)      # atomic publish
    return final


def restore_tree(path: str, template: Any, step: Optional[int] = None,
                 shardings: Any = None):
    """Restore into ``template``'s structure; reshard via ``shardings``."""
    if step is None:
        step = latest_step(path)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {path}")
    d = os.path.join(path, f"step_{step}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    flat, treedef = _flatten(template)
    if len(flat) != len(manifest["leaves"]):
        raise ValueError(
            f"checkpoint has {len(manifest['leaves'])} leaves, template "
            f"expects {len(flat)} — incompatible tree")
    shard_flat = (treedef.flatten_up_to(shardings)
                  if shardings is not None else [None] * len(flat))
    leaves = []
    for (kp, tmpl), meta, shd in zip(flat, manifest["leaves"], shard_flat):
        if meta["file"] is None:
            leaves.append(None)
            continue
        arr = np.load(os.path.join(d, meta["file"]))
        if str(arr.dtype) != meta["dtype"]:    # raw-bits ml_dtypes leaf
            import ml_dtypes
            arr = arr.view(np.dtype(getattr(ml_dtypes, meta["dtype"])))
        if shd is not None:
            arr = jax.device_put(arr, shd)     # elastic reshard on restore
        leaves.append(arr)
    return treedef.unflatten(leaves), manifest["step"], manifest["extra"]


def latest_step(path: str) -> Optional[int]:
    if not os.path.isdir(path):
        return None
    steps = [int(m.group(1)) for n in os.listdir(path)
             if (m := _STEP_RE.match(n))]
    return max(steps) if steps else None


class CheckpointManager:
    """keep-last-k + async save + preemption handling."""

    def __init__(self, path: str, keep: int = 3, async_save: bool = True,
                 install_sigterm: bool = False):
        self.path = path
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        self.preempted = False
        os.makedirs(path, exist_ok=True)
        if install_sigterm:
            signal.signal(signal.SIGTERM, self._on_sigterm)

    def _on_sigterm(self, signum, frame):   # pragma: no cover
        self.preempted = True

    def _gc(self):
        steps = sorted(int(m.group(1)) for n in os.listdir(self.path)
                       if (m := _STEP_RE.match(n)))
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.path, f"step_{s}"),
                          ignore_errors=True)

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def save(self, tree: Any, step: int, extra: Optional[dict] = None,
             blocking: Optional[bool] = None):
        self.wait()                      # one in-flight save at a time
        host_tree = jax.tree.map(
            lambda x: np.asarray(jax.device_get(x)) if x is not None else None,
            tree, is_leaf=lambda x: x is None)

        def run():
            save_tree(self.path, host_tree, step, extra)
            self._gc()

        if blocking is False or (blocking is None and self.async_save):
            self._thread = threading.Thread(target=run, daemon=True)
            self._thread.start()
        else:
            run()

    def restore_latest(self, template: Any, shardings: Any = None):
        return restore_tree(self.path, template, None, shardings)

    def latest_step(self) -> Optional[int]:
        return latest_step(self.path)
