"""Mesh context + logical sharding rules (GSPMD/pjit distribution layer).

Mesh axes:
  single-pod (16, 16): ("data", "model")
  multi-pod (2, 16, 16): ("pod", "data", "model")

"pod"+"data" form the DP/FSDP domain (batch + parameter-shard axis);
"model" is the tensor/expert-parallel domain. Model code never touches the
mesh directly — it calls :func:`hint` with *logical* axis names which
resolve against the active mesh (identity when no mesh is set, so tests
and CPU smoke runs need no distribution machinery).

Param shardings are derived from path-pattern rules (:func:`param_shardings`)
so plain arrays and QTensor children (packed codes / scales) both resolve.
"""

from __future__ import annotations

import contextlib
import contextvars
import re
from typing import Any, Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = ["set_mesh", "current_mesh", "hint", "hint_pick", "batch_axes",
           "activation_spec", "param_shardings", "batch_shardings",
           "cache_shardings", "paged_pool_shardings"]

_MESH: contextvars.ContextVar[Optional[Mesh]] = contextvars.ContextVar(
    "repro_mesh", default=None)


@contextlib.contextmanager
def set_mesh(mesh: Optional[Mesh]):
    tok = _MESH.set(mesh)
    try:
        yield mesh
    finally:
        _MESH.reset(tok)


def current_mesh() -> Optional[Mesh]:
    return _MESH.get()


def batch_axes(mesh: Mesh) -> tuple:
    """Mesh axes forming the DP domain ('pod' + 'data' when present)."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def _resolve(mesh: Mesh, logical: Optional[str]):
    if logical is None:
        return None
    if logical == "batch":
        ax = batch_axes(mesh)
        return ax if len(ax) > 1 else (ax[0] if ax else None)
    if logical == "fsdp":  # parameter-shard domain == DP domain
        ax = batch_axes(mesh)
        return ax if len(ax) > 1 else (ax[0] if ax else None)
    if logical in mesh.axis_names:
        return logical
    return None


def activation_spec(mesh: Mesh, *logical) -> P:
    return P(*[_resolve(mesh, ax) for ax in logical])


def hint_pick(x, *specs):
    """Apply the first logical spec whose named axes all divide x's dims.

    Unlike :func:`hint` (which drops only the offending dim), this keeps a
    spec atomic — used where alternatives are semantically different
    layouts (e.g. attention scores: heads-sharded vs sequence-sharded).
    """
    mesh = _MESH.get()
    if mesh is None:
        return x
    for spec in specs:
        resolved = [_resolve(mesh, ax) for ax in spec]
        ok = True
        for dim, ax in zip(x.shape, resolved):
            if ax is None:
                continue
            size = 1
            for a in (ax if isinstance(ax, tuple) else (ax,)):
                size *= mesh.shape[a]
            if dim % size != 0:
                ok = False
                break
        if ok:
            return jax.lax.with_sharding_constraint(
                x, NamedSharding(mesh, P(*resolved)))
    return x


def hint(x, *logical):
    """with_sharding_constraint against the context mesh (no-op if unset).

    Logical names: "batch", "fsdp", "model", None. Constraint is skipped
    for any dim the resolved axes do not divide (robustness for reduced
    smoke configs on tiny meshes).
    """
    mesh = _MESH.get()
    if mesh is None:
        return x
    spec = activation_spec(mesh, *logical)
    # drop constraints that do not divide the dim (GSPMD pads activations,
    # but uneven *activation* sharding is usually a perf bug -> replicate)
    fixed = []
    for dim, ax in zip(x.shape, spec):
        if ax is None:
            fixed.append(None)
            continue
        size = 1
        for a in (ax if isinstance(ax, tuple) else (ax,)):
            size *= mesh.shape[a]
        fixed.append(ax if dim % size == 0 else None)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*fixed)))


# ---------------------------------------------------------------------------
# Parameter sharding rules.
#
# Matched top-down against the flattened param path; first hit wins. The
# rule gives logical axes for the trailing dims (leading stacked-layer and
# expert dims are handled explicitly). Biases/norms/scalars replicate.
# ---------------------------------------------------------------------------

# (path regex, spec for the last N dims, N). Paths look like
# ['layers']['attn']['wq'].data  (dict keys quoted, QTensor children as attrs)
_RULES: list[tuple[str, tuple, int]] = [
    (r"'(embedding|pos_embed)'", ("model", "fsdp"), 2),
    (r"'lm_head'", ("fsdp", "model"), 2),
    (r"'(wq|wk|wv|wqkv|w_gate|w_up|w_in)'", ("fsdp", "model"), 2),
    (r"'(wo|w_down|w_out)'", ("model", "fsdp"), 2),
    (r"'router'", (None, None), 2),
    (r"'(in_proj|gate_proj)'", ("fsdp", "model"), 2),
    (r"'(out_proj)'", ("model", "fsdp"), 2),
    (r"'conv_w'", (None, "model"), 2),
]


def _leaf_spec(mesh: Mesh, path: str, leaf: Any, expert_axis: Optional[str],
               fsdp_scope: str = "all"):
    shape = getattr(leaf, "shape", ())
    ndim = len(shape)
    if ndim <= 1:
        return P()
    # scales of weight QTensors replicate (tiny; avoids divisibility traps)
    if re.search(r"(scales|cscale|offset)", path) and "embedding" not in path:
        return P()
    if re.search(r"(lora_a|lora_b)", path):
        return P()  # adapters are small; replicate
    # fsdp_scope="opt": only optimizer state (master/m/v) is FSDP-2D-sharded;
    # live params are TP-only so forward/backward propagation has a single
    # stable solution (no FSDP-gather vs batch-gather ambiguity)
    use_fsdp = (fsdp_scope == "all"
                or (fsdp_scope == "opt" and re.search(r"'opt'", path)))
    for pat, spec, n in _RULES:
        if re.search(pat, path):
            if ndim < n:
                return P()
            lead: list = [None] * (ndim - n)
            spec = list(spec)
            if not use_fsdp:
                spec = [None if s == "fsdp" else s for s in spec]
            # stacked MoE experts: (L, E, ...) -> shard E on the expert axis
            # and release that axis from the trailing dims (no axis reuse)
            if expert_axis and re.search(r"experts", path) and ndim >= n + 1:
                lead[-1] = expert_axis
                spec = [None if s == expert_axis else s for s in spec]
            full = lead + spec
            # drop non-dividing axes (GSPMD would pad; for weights we prefer
            # replication over padded shards for odd dims like vocab=51865)
            out = []
            for dim, ax in zip(shape, full):
                if ax is None:
                    out.append(None)
                    continue
                axes = ax if isinstance(ax, tuple) else (ax,)
                resolved = []
                for a in axes:
                    r = _resolve(mesh, a)
                    if r is None:
                        continue
                    resolved.extend(r if isinstance(r, tuple) else [r])
                size = 1
                for a in resolved:
                    size *= mesh.shape[a]
                if resolved and dim % size == 0:
                    out.append(tuple(resolved) if len(resolved) > 1 else resolved[0])
                else:
                    out.append(None)
            return P(*out)
    return P()


def param_shardings(mesh: Mesh, params: Any, expert_mode: str = "expert",
                    fsdp_scope: str = "all"):
    """NamedSharding tree for a (possibly quantized) parameter pytree.

    fsdp_scope: "all" (2-D FSDPxTP everywhere — inference default, weights
    are read-only), "opt" (TP-only live params, FSDP-2D optimizer state —
    training default), "none" (TP-only).
    """
    expert_axis = "model" if expert_mode == "expert" else None

    def visit(path, leaf):
        pstr = jax.tree_util.keystr(path)
        return NamedSharding(mesh, _leaf_spec(mesh, pstr, leaf, expert_axis,
                                              fsdp_scope))

    return jax.tree_util.tree_map_with_path(visit, params)


def _divides(mesh: Mesh, axes, dim: int) -> bool:
    if axes is None:
        return False
    size = 1
    for a in (axes if isinstance(axes, tuple) else (axes,)):
        size *= mesh.shape[a]
    return size > 0 and dim % size == 0


def batch_shardings(mesh: Mesh, batch: Any):
    """Shard every batch leaf's leading (batch) dim over the DP domain."""
    dp = batch_axes(mesh)
    dp = dp if len(dp) > 1 else (dp[0] if dp else None)

    def visit(leaf):
        shape = getattr(leaf, "shape", ())
        if len(shape) >= 1 and _divides(mesh, dp, shape[0]):
            return NamedSharding(mesh, P(dp, *([None] * (len(shape) - 1))))
        return NamedSharding(mesh, P())

    return jax.tree.map(visit, batch)


def cache_shardings(mesh: Mesh, cache: Any):
    """Decode-cache shardings (DESIGN.md §3):

    KV leaves (L, B, S, Hkv, hd): batch -> DP axes; heads -> model when
    divisible (kv=16 archs), otherwise the *sequence* dim shards on model
    (flash-decoding-style split-S — required for GQA kv=8 / MQA kv=1 on a
    16-way tensor axis). Recurrent states shard their channel dim on model.
    """
    dp = batch_axes(mesh)
    dp = dp if len(dp) > 1 else (dp[0] if dp else None)

    def visit(path, leaf):
        pstr = jax.tree_util.keystr(path)
        shape = getattr(leaf, "shape", ())
        nd = len(shape)
        if re.search(r"'(pos|len|pos_roll)'", pstr) or nd <= 1:
            return NamedSharding(mesh, P())
        spec = [None] * nd
        if re.search(r"'(k|v|k_codes|v_codes|cross_k|cross_v|cross_k_codes|cross_v_codes|b_k|b_v)'", pstr) and nd == 5:
            L, B, S, Hkv, hd = shape
            if _divides(mesh, dp, B):
                spec[1] = dp
            if _divides(mesh, "model", Hkv):
                spec[3] = "model"
            elif _divides(mesh, "model", S):
                spec[2] = "model"
        elif re.search(r"'(k_scales|v_scales|cross_k_scales|cross_v_scales)'", pstr) and nd == 4:
            L, B, S, Hkv = shape
            if _divides(mesh, dp, B):
                spec[1] = dp
            if _divides(mesh, "model", Hkv):
                spec[3] = "model"
            elif _divides(mesh, "model", S):
                spec[2] = "model"
        elif re.search(r"'(conv|b_conv1|b_conv2|t_conv)'", pstr) and nd == 4:
            if _divides(mesh, dp, shape[1]):
                spec[1] = dp
            if _divides(mesh, "model", shape[3]):
                spec[3] = "model"
        elif re.search(r"'ssd'", pstr) and nd == 5:
            if _divides(mesh, dp, shape[1]):
                spec[1] = dp
            if _divides(mesh, "model", shape[2]):
                spec[2] = "model"
        elif re.search(r"'(b_h1|b_h2|t_h)'", pstr) and nd == 3:
            if _divides(mesh, dp, shape[1]):
                spec[1] = dp
            if _divides(mesh, "model", shape[2]):
                spec[2] = "model"
        return NamedSharding(mesh, P(*spec))

    return jax.tree_util.tree_map_with_path(visit, cache)


def paged_pool_shardings(mesh: Mesh, cache: Any):
    """Paged-pool shardings for the serving engine's shared page pool.

    Pool leaves are (L, P, ps, Hkv, hd) — the page axis P is shared by
    all requests (a chain may land on any page), so only the head axes
    shard: Hkv on "model" when divisible, else hd. Scale leaves
    (L, P, ps, Hkv) shard Hkv the same way. Per-slot dense cross buffers
    (L, slots, S, Hkv[, hd]) shard their head dim. Block tables,
    lengths, and active flags stay host-replicated — the allocator is
    host-side state and every device needs the full chain view.
    """
    def visit(path, leaf):
        pstr = jax.tree_util.keystr(path)
        shape = getattr(leaf, "shape", ())
        nd = len(shape)
        if re.search(r"'(block_tables|len|active|cross_len|pos)'", pstr) or nd <= 1:
            return NamedSharding(mesh, P())
        spec = [None] * nd
        if re.search(r"'(k|v|k_codes|v_codes|cross_k|cross_v|cross_k_codes|cross_v_codes)'", pstr) and nd == 5:
            if _divides(mesh, "model", shape[3]):
                spec[3] = "model"
            elif _divides(mesh, "model", shape[4]):
                spec[4] = "model"
        elif re.search(r"'(k_scales|v_scales|cross_k_scales|cross_v_scales)'", pstr) and nd == 4:
            if _divides(mesh, "model", shape[3]):
                spec[3] = "model"
        return NamedSharding(mesh, P(*spec))

    return jax.tree_util.tree_map_with_path(visit, cache)
