from .sharding import (activation_spec, batch_axes, batch_shardings,
                       cache_shardings, current_mesh, hint, hint_pick,
                       paged_pool_shardings, param_shardings, set_mesh)

__all__ = ["hint", "set_mesh", "current_mesh", "batch_axes",
           "activation_spec", "param_shardings", "batch_shardings",
           "cache_shardings", "paged_pool_shardings", "hint_pick"]
