"""Observability: host-side tracing + metrics export for serving.

The paper's headline numbers (66 tok/s, 4.2x speedup) are whole-run
averages over an opaque pipeline; this package is the layer that breaks
such numbers down — *where does a round spend its time, and what did
each request live through?* Two modules:

* ``obs.trace`` — a low-overhead ring-buffered structured tracer
  (``Tracer`` / ``TraceConfig``). The serving engine emits per-request
  lifecycle spans (queued -> prefill -> decode-round* -> retired, plus
  preempted/resumed/verify/fault events) and per-round scheduler phase
  spans (admit / dispatch / sync / walk), all stamped from the engine's
  own clock so fault-injected skew shows up in traces. Exports
  Chrome/Perfetto ``trace_event`` JSON (open in chrome://tracing or
  ui.perfetto.dev).
* ``obs.metrics`` — the single nearest-rank ``percentile`` definition
  (shared by ``serving.latency_percentiles`` and the SLA controller), a
  fixed log-bucket ``Histogram`` with merge, and Prometheus
  text-exposition renderers over ``EngineMetrics`` snapshots plus
  histograms (single-snapshot and per-replica labelled).
* ``obs.promhttp`` — a stdlib daemon-thread HTTP server exposing any
  ``prometheus()``-shaped renderer at ``GET /metrics`` (the live
  scrape endpoint behind ``launch.serve --metrics-port``).

This package imports nothing from ``repro.serving`` (serving imports
it), so it can also observe future subsystems (mesh replicas, the
background pump) without a cycle.
"""

from .metrics import (Histogram, percentile, render_prometheus,
                      render_prometheus_labeled)
from .promhttp import MetricsServer
from .trace import PHASES, SCHED_TID, TraceConfig, TraceEvent, Tracer

__all__ = ["Histogram", "MetricsServer", "percentile", "render_prometheus",
           "render_prometheus_labeled", "PHASES", "SCHED_TID",
           "TraceConfig", "TraceEvent", "Tracer"]
