"""Live Prometheus scrape endpoint over a metrics-render callable.

``launch.serve --metrics-port`` (and anything else with a
``prometheus()``-shaped renderer: a single engine, a ReplicaRouter)
serves its text exposition at ``GET /metrics`` from a stdlib
``ThreadingHTTPServer`` on a daemon thread — no dependencies, no
event loop, nothing the serving engine has to yield to. The render
callable runs on the scrape thread; engine counters are plain Python
ints/floats mutated under the GIL, so a scrape mid-round reads a
slightly stale but internally ordinary snapshot and never blocks the
scheduler.

    srv = MetricsServer(engine.prometheus, port=9100).start()
    ...
    srv.close()      # graceful: unbinds the socket, joins the thread

``port=0`` binds an ephemeral port (``srv.port`` reports the real one)
— the shape the shutdown test uses.
"""

from __future__ import annotations

import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Callable

__all__ = ["MetricsServer"]

_CONTENT_TYPE = "text/plain; version=0.0.4; charset=utf-8"


class MetricsServer:
    """Serve ``render()`` at ``GET /metrics`` until :meth:`close`."""

    def __init__(self, render: Callable[[], str], port: int = 0,
                 host: str = "127.0.0.1"):
        self.render = render

        outer = self

        class Handler(BaseHTTPRequestHandler):
            def do_GET(self):                        # noqa: N802 (stdlib API)
                if self.path.split("?", 1)[0] != "/metrics":
                    self.send_error(404, "try /metrics")
                    return
                try:
                    body = outer.render().encode("utf-8")
                except Exception as exc:  # scrape must not kill the server
                    self.send_error(500, f"render failed: {exc!r}")
                    return
                self.send_response(200)
                self.send_header("Content-Type", _CONTENT_TYPE)
                self.send_header("Content-Length", str(len(body)))
                self.end_headers()
                self.wfile.write(body)

            def log_message(self, *args):  # keep scrapes off stderr
                pass

        self._httpd = ThreadingHTTPServer((host, port), Handler)
        self._thread = threading.Thread(target=self._httpd.serve_forever,
                                        name="metrics-http", daemon=True)
        self.host = host
        self.port = self._httpd.server_address[1]

    def start(self) -> "MetricsServer":
        self._thread.start()
        return self

    @property
    def url(self) -> str:
        return f"http://{self.host}:{self.port}/metrics"

    def close(self) -> None:
        """Graceful shutdown: stop accepting, unbind the socket, join
        the serve thread. Idempotent."""
        self._httpd.shutdown()
        self._httpd.server_close()
        if self._thread.is_alive():
            self._thread.join(timeout=5.0)

    def __enter__(self) -> "MetricsServer":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.close()
