"""Histogram + percentile primitives and Prometheus text exposition.

One percentile definition for the whole repo
--------------------------------------------
Before this module existed the repo had three percentile
implementations: ``serving.latency_percentiles`` (numpy linear
interpolation), ``SLAController``'s windowed p95 (nearest rank), and
bench_serving's ad-hoc row math. They disagreed on small samples — the
exact regime CI smoke runs live in — so an SLA the controller held
could look violated in the report. :func:`percentile` is now the single
definition (nearest rank, the controller's original semantics) and the
other call sites import it.

``Histogram`` is a fixed log-bucket histogram: O(1) memory regardless
of sample count, mergeable across engines/replicas, and cheap enough to
record into from the serving engine's retire path unconditionally. Its
``percentile`` returns the *upper edge* of the bucket holding the
nearest-rank sample (the standard Prometheus-style bound; exact values
are not retained).
"""

from __future__ import annotations

import bisect
from typing import Dict, Iterable, List, Mapping, Optional, Sequence, Tuple

__all__ = ["percentile", "Histogram", "render_prometheus",
           "render_prometheus_labeled"]


def percentile(vals: Iterable[float], q: float) -> float:
    """Nearest-rank percentile over raw samples.

    ``rank = round(q/100 * (n - 1))`` on the sorted sample — exactly the
    definition ``SLAController`` shipped with, so consolidating onto
    this helper changes no admission decisions. Returns 0.0 on an empty
    sample (callers treat "no data" as "no latency to report").
    """
    s = sorted(float(v) for v in vals)
    if not s:
        return 0.0
    if not 0.0 <= q <= 100.0:
        raise ValueError(f"percentile q must be in [0, 100], got {q}")
    rank = int(round(q / 100.0 * (len(s) - 1)))
    return s[max(0, min(len(s) - 1, rank))]


# Bucket boundaries are derived from (lo, growth, n_buckets) once per
# config and shared between histograms so merge() can compare cheaply.
_BOUNDS_CACHE: Dict[Tuple[float, float, int], Tuple[float, ...]] = {}


def _bounds(lo: float, growth: float, n_buckets: int) -> Tuple[float, ...]:
    key = (lo, growth, n_buckets)
    b = _BOUNDS_CACHE.get(key)
    if b is None:
        if lo <= 0.0:
            raise ValueError(f"histogram lo must be > 0, got {lo}")
        if growth <= 1.0:
            raise ValueError(f"histogram growth must be > 1, got {growth}")
        if n_buckets < 1:
            raise ValueError(f"histogram needs >= 1 bucket, got {n_buckets}")
        b = tuple(lo * growth**i for i in range(n_buckets))
        _BOUNDS_CACHE[key] = b
    return b


class Histogram:
    """Fixed log-bucket histogram with merge and nearest-rank quantiles.

    Bucket ``i`` counts samples in ``(bounds[i-1], bounds[i]]``; bucket 0
    additionally absorbs everything ``<= lo`` (including zeros), and one
    overflow bucket absorbs samples above the last bound. Defaults cover
    1 microsecond to ~18 minutes when samples are milliseconds.
    """

    __slots__ = ("lo", "growth", "n_buckets", "bounds", "counts",
                 "overflow", "count", "total")

    def __init__(self, lo: float = 1e-3, growth: float = 2.0,
                 n_buckets: int = 30):
        self.lo = float(lo)
        self.growth = float(growth)
        self.n_buckets = int(n_buckets)
        self.bounds = _bounds(self.lo, self.growth, self.n_buckets)
        self.counts = [0] * self.n_buckets
        self.overflow = 0
        self.count = 0
        self.total = 0.0

    def config(self) -> Tuple[float, float, int]:
        return (self.lo, self.growth, self.n_buckets)

    def record(self, v: float) -> None:
        v = float(v)
        self.count += 1
        self.total += v
        b = self.bounds
        if v > b[-1]:
            self.overflow += 1
            return
        self.counts[bisect.bisect_left(b, v)] += 1

    def merge(self, other: "Histogram") -> "Histogram":
        """Add ``other``'s samples into self (in place); returns self."""
        if other.config() != self.config():
            raise ValueError(
                f"cannot merge histograms with configs {self.config()} "
                f"and {other.config()}")
        for i, c in enumerate(other.counts):
            self.counts[i] += c
        self.overflow += other.overflow
        self.count += other.count
        self.total += other.total
        return self

    def reset(self) -> None:
        self.counts = [0] * self.n_buckets
        self.overflow = 0
        self.count = 0
        self.total = 0.0

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0

    def percentile(self, q: float) -> float:
        """Upper bucket edge holding the nearest-rank sample (0.0 if empty)."""
        if self.count == 0:
            return 0.0
        if not 0.0 <= q <= 100.0:
            raise ValueError(f"percentile q must be in [0, 100], got {q}")
        rank = int(round(q / 100.0 * (self.count - 1)))
        rank = max(0, min(self.count - 1, rank))
        seen = 0
        for i, c in enumerate(self.counts):
            seen += c
            if seen > rank:
                return self.bounds[i]
        return self.bounds[-1]  # nearest-rank sample sits in overflow

    def as_dict(self) -> Dict[str, object]:
        return {
            "lo": self.lo, "growth": self.growth,
            "n_buckets": self.n_buckets, "count": self.count,
            "total": self.total, "overflow": self.overflow,
            "counts": list(self.counts),
        }


def _fmt(v: float) -> str:
    """Prometheus sample value: integral floats render without exponent."""
    f = float(v)
    if f == int(f) and abs(f) < 1e15:
        return str(int(f))
    return repr(f)


def _label_str(labels: Optional[Mapping[str, str]],
               extra: Optional[Tuple[str, str]] = None) -> str:
    """Rendered ``{k="v",...}`` block (empty string when no labels)."""
    pairs = sorted((labels or {}).items())
    if extra is not None:
        pairs.append(extra)
    if not pairs:
        return ""
    return "{" + ",".join(f'{k}="{v}"' for k, v in pairs) + "}"


def render_prometheus(
    snapshot: object,
    histograms: Optional[Mapping[str, Histogram]] = None,
    prefix: str = "repro_serving",
    labels: Optional[Mapping[str, str]] = None,
    emit_type: bool = True,
) -> str:
    """Render an ``EngineMetrics``-like snapshot + histograms as
    Prometheus text exposition (version 0.0.4).

    ``snapshot`` needs ``as_dict()`` (or may already be a mapping); a
    ``GAUGES`` class attribute names fields that are levels rather than
    monotone counters. Everything else integral is typed ``counter``,
    floats are typed ``gauge`` (derived values such as percentiles).

    ``labels`` stamps every sample line with the same label set (e.g.
    ``{"replica": "2"}`` for a per-replica cluster section); histogram
    buckets merge them with their ``le`` label. ``emit_type=False``
    drops the ``# TYPE`` comments — required when a caller renders one
    metric family several times with different label values (the text
    format allows each TYPE declaration at most once per exposition).
    """
    if hasattr(snapshot, "as_dict"):
        d = snapshot.as_dict()  # type: ignore[attr-defined]
    else:
        d = dict(snapshot)  # type: ignore[arg-type]
    gauges = frozenset(getattr(type(snapshot), "GAUGES", ()) or ())
    lbl = _label_str(labels)
    lines: List[str] = []
    for k in sorted(d):
        v = d[k]
        if v is None or isinstance(v, (str, bytes, dict, list, tuple)):
            continue
        name = f"{prefix}_{k}"
        typ = "gauge" if (k in gauges or isinstance(v, float)) else "counter"
        if emit_type:
            lines.append(f"# TYPE {name} {typ}")
        lines.append(f"{name}{lbl} {_fmt(v)}")
    for hname in sorted(histograms or {}):
        h = histograms[hname]  # type: ignore[index]
        name = f"{prefix}_{hname}"
        if emit_type:
            lines.append(f"# TYPE {name} histogram")
        cum = 0
        for le, c in zip(h.bounds, h.counts):
            cum += c
            bl = _label_str(labels, ("le", _fmt(le)))
            lines.append(f"{name}_bucket{bl} {cum}")
        lines.append(f'{name}_bucket{_label_str(labels, ("le", "+Inf"))} '
                     f"{h.count}")
        lines.append(f"{name}_sum{lbl} {_fmt(h.total)}")
        lines.append(f"{name}_count{lbl} {h.count}")
    return "\n".join(lines) + "\n"


def render_prometheus_labeled(
    snapshots: Sequence[Tuple[Mapping[str, str], object]],
    prefix: str = "repro_serving",
) -> str:
    """One exposition over N label-distinguished snapshots of the same
    family set (e.g. per-replica EngineMetrics, labelled
    ``{"replica": "0"}`` .. ``{"replica": "N-1"}``).

    Unlike calling :func:`render_prometheus` once per snapshot and
    concatenating — which interleaves metric families and repeats TYPE
    declarations, both invalid in the text format — this groups lines
    per family: one TYPE comment, then one labelled sample per
    snapshot, for every field present in any snapshot.
    """
    dicts = []
    gauges: set = set()
    for labels, snap in snapshots:
        d = snap.as_dict() if hasattr(snap, "as_dict") else dict(snap)
        dicts.append((labels, d))
        gauges.update(getattr(type(snap), "GAUGES", ()) or ())
    keys = sorted({k for _, d in dicts for k in d})
    lines: List[str] = []
    for k in keys:
        vals = [(labels, d[k]) for labels, d in dicts
                if k in d and d[k] is not None
                and not isinstance(d[k], (str, bytes, dict, list, tuple))]
        if not vals:
            continue
        name = f"{prefix}_{k}"
        typ = ("gauge" if (k in gauges
                           or any(isinstance(v, float) for _, v in vals))
               else "counter")
        lines.append(f"# TYPE {name} {typ}")
        for labels, v in vals:
            lines.append(f"{name}{_label_str(labels)} {_fmt(v)}")
    return "\n".join(lines) + "\n"
