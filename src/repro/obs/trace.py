"""Ring-buffered structured tracer with a Chrome/Perfetto exporter.

Design constraints, in order:

1. **Zero cost when disabled.** The serving engine guards every emission
   behind ``if self.trace is not None`` — no tracer object, no event
   allocation, no clock read. The tracer itself never touches the
   device, so enabling it cannot add host<->device syncs (bench and
   tests assert ``decode_syncs`` parity between traced/untraced runs).
2. **Bounded memory.** Events land in a ring of ``capacity`` entries;
   once full the oldest events are dropped and counted in
   ``Tracer.dropped``. Smoke-scale runs must never drop (tripwired).
3. **Engine-clock timestamps.** Callers stamp events from the engine's
   own ``_now()`` (perf_counter + fault-injected skew), so a
   ``FaultPlan`` skew step is visible as a jump in the trace. Skew in
   the repo's fault plans only moves the clock forward; as a belt for
   hypothetical negative skew, ``begin``/``end`` stamps are clamped to
   be non-decreasing so span nesting stays valid.

Track (``tid``) convention: tid 0 (:data:`SCHED_TID`) is the scheduler
track carrying ``round`` spans with ``admit``/``dispatch``/``sync``/
``walk`` phase events; each request gets tid ``rid + 1`` carrying its
lifecycle span (``request`` wrapping ``queued``, a ``prefill`` complete
event, ``decode-round``/``verify``/``preempted``/``resumed`` instants,
and a ``retired`` instant with the finish reason). A ``resume`` flow
pair (``flow_start`` at each preemption, ``flow_end`` at the matching
resume — or at retirement if the stashed request dies queued) links a
preempted request's two slot residencies, so Perfetto draws the
continuity arrow across the gap.

Export is the Chrome ``trace_event`` JSON array format — load the file
in ``chrome://tracing`` or https://ui.perfetto.dev.
"""

from __future__ import annotations

import dataclasses
import json
from collections import deque
from typing import Any, Deque, Dict, List, Optional, Tuple

__all__ = ["PHASES", "SCHED_TID", "TraceConfig", "TraceEvent", "Tracer"]

# Scheduler round phases, in the order they run inside a round.
PHASES: Tuple[str, ...] = ("admit", "dispatch", "sync", "walk")

SCHED_TID = 0
_PID = 1  # single-process engine; one pid for the whole trace


@dataclasses.dataclass(frozen=True)
class TraceConfig:
    """Tracer knobs. ``capacity`` bounds resident events (ring buffer)."""

    capacity: int = 1 << 16

    def __post_init__(self) -> None:
        if self.capacity < 16:
            raise ValueError(f"trace capacity must be >= 16, got {self.capacity}")


@dataclasses.dataclass
class TraceEvent:
    """One structured event. ``ph`` follows the Chrome trace_event
    phases this exporter emits: B/E (span begin/end), X (complete, with
    ``dur_us``), i (instant), s/f (flow start/finish, carrying
    ``flow_id`` — Perfetto draws an arrow between the slices enclosing
    the two endpoints)."""

    ph: str
    name: str
    ts_us: float
    tid: int
    dur_us: float = 0.0
    args: Optional[Dict[str, Any]] = None
    flow_id: Optional[int] = None

    def to_chrome(self) -> Dict[str, Any]:
        d: Dict[str, Any] = {
            "name": self.name, "cat": "serving", "ph": self.ph,
            "ts": self.ts_us, "pid": _PID, "tid": self.tid,
        }
        if self.ph == "X":
            d["dur"] = self.dur_us
        if self.ph == "i":
            d["s"] = "t"  # instant scoped to its thread/track
        if self.ph in ("s", "f"):
            d["id"] = self.flow_id
            if self.ph == "f":
                d["bp"] = "e"  # bind to the enclosing slice, not the next
        if self.args:
            d["args"] = self.args
        return d


class Tracer:
    """Collects :class:`TraceEvent` s; see module docstring for the
    track/span conventions the serving engine uses."""

    def __init__(self, config: Optional[TraceConfig] = None):
        self.config = config or TraceConfig()
        self.events: Deque[TraceEvent] = deque(maxlen=self.config.capacity)
        self.dropped = 0
        self._floor_us = float("-inf")
        self._next_flow = 0
        self._track_names: Dict[int, str] = {SCHED_TID: "scheduler"}

    def __len__(self) -> int:
        return len(self.events)

    def name_track(self, tid: int, name: str) -> None:
        self._track_names.setdefault(tid, name)

    def _record(self, ev: TraceEvent) -> None:
        if len(self.events) == self.events.maxlen:
            self.dropped += 1
        self.events.append(ev)

    def _stamp(self, ts_s: float) -> float:
        """Span-edge stamp, clamped non-decreasing (negative-skew belt)."""
        us = ts_s * 1e6
        if us < self._floor_us:
            return self._floor_us
        self._floor_us = us
        return us

    def begin(self, tid: int, name: str, ts_s: float, **args: Any) -> None:
        self._record(TraceEvent("B", name, self._stamp(ts_s), tid,
                                args=args or None))

    def end(self, tid: int, name: str, ts_s: float, **args: Any) -> None:
        self._record(TraceEvent("E", name, self._stamp(ts_s), tid,
                                args=args or None))

    def instant(self, tid: int, name: str, ts_s: float, **args: Any) -> None:
        # Instants are points: they cannot break B/E nesting, so they
        # keep their caller-supplied timestamp un-clamped (a decode
        # round's instant is stamped at its walk start, which may
        # precede an already-recorded retire edge from another slot).
        self._record(TraceEvent("i", name, ts_s * 1e6, tid,
                                args=args or None))

    def complete(self, tid: int, name: str, ts_s: float, dur_s: float,
                 **args: Any) -> None:
        self._record(TraceEvent("X", name, ts_s * 1e6, tid,
                                dur_us=max(dur_s, 0.0) * 1e6,
                                args=args or None))

    def flow_start(self, tid: int, name: str, ts_s: float,
                   **args: Any) -> int:
        """Open a flow link (Chrome ``s`` event) and return its fresh
        flow id. The serving engine links a preempted request's two
        slot residencies this way: ``flow_start`` at the preemption,
        ``flow_end`` with the returned id at the resume (or at
        retirement, if the request dies while stashed) — Perfetto draws
        the arrow, and :meth:`check` enforces the pairing. Flow stamps
        are points, un-clamped like instants."""
        self._next_flow += 1
        self._record(TraceEvent("s", name, ts_s * 1e6, tid,
                                args=args or None,
                                flow_id=self._next_flow))
        return self._next_flow

    def flow_end(self, tid: int, name: str, ts_s: float, flow_id: int,
                 **args: Any) -> None:
        """Close the flow link opened by :meth:`flow_start` under the
        same ``name`` and the id it returned."""
        self._record(TraceEvent("f", name, ts_s * 1e6, tid,
                                args=args or None, flow_id=flow_id))

    # ------------------------------------------------------------------
    # Validation — used by bench/CI tripwires and tests.
    # ------------------------------------------------------------------

    def check(self) -> List[str]:
        """Validate span discipline; returns a list of problems (empty
        means the trace is well-formed).

        Checks, per track, in recorded order: every E closes the
        matching innermost B (same name, end >= begin), child events do
        not start before their enclosing span, a span does not end
        before a child event recorded inside it ended, and nothing is
        left open. Flow links are pair-checked globally: every ``f``
        must consume a prior ``s`` with the same flow id and name at a
        non-earlier stamp, each id is consumed at most once, and no
        link is left dangling (the engine closes every preemption link
        — at the resume, or at retirement if the stashed request dies
        queued). Recorded order is the ground truth for nesting —
        the engine emits strictly stack-disciplined spans.
        """
        problems: List[str] = []
        # tid -> stack of [begin_event, max_child_end_us]
        stacks: Dict[int, List[List[Any]]] = {}
        open_flows: Dict[int, TraceEvent] = {}
        for ev in self.events:
            st = stacks.setdefault(ev.tid, [])
            if ev.ph in ("s", "f"):
                if ev.ph == "s":
                    if ev.flow_id in open_flows:
                        problems.append(
                            f"tid {ev.tid}: flow {ev.flow_id} started twice")
                    open_flows[ev.flow_id] = ev
                else:
                    s = open_flows.pop(ev.flow_id, None)
                    if s is None:
                        problems.append(
                            f"tid {ev.tid}: f {ev.name!r} flow {ev.flow_id} "
                            f"without matching s")
                    else:
                        if s.name != ev.name:
                            problems.append(
                                f"flow {ev.flow_id}: f {ev.name!r} closes "
                                f"s {s.name!r}")
                        if ev.ts_us < s.ts_us:
                            problems.append(
                                f"flow {ev.flow_id} ({ev.name!r}) ends "
                                f"before it starts")
                continue
            if ev.ph == "B":
                if st and ev.ts_us < st[-1][0].ts_us:
                    problems.append(
                        f"tid {ev.tid}: B {ev.name!r} at {ev.ts_us:.1f}us "
                        f"starts before parent {st[-1][0].name!r}")
                st.append([ev, ev.ts_us])
            elif ev.ph == "E":
                if not st:
                    problems.append(f"tid {ev.tid}: E {ev.name!r} without open span")
                    continue
                b, max_child_end = st.pop()
                if b.name != ev.name:
                    problems.append(
                        f"tid {ev.tid}: E {ev.name!r} closes B {b.name!r}")
                if ev.ts_us < b.ts_us:
                    problems.append(
                        f"tid {ev.tid}: span {ev.name!r} ends before it begins")
                if ev.ts_us < max_child_end:
                    problems.append(
                        f"tid {ev.tid}: span {ev.name!r} ends at "
                        f"{ev.ts_us:.1f}us before child at {max_child_end:.1f}us")
                if st:
                    st[-1][1] = max(st[-1][1], ev.ts_us)
            else:  # X / i
                end = ev.ts_us + ev.dur_us
                if st:
                    if ev.ts_us + 1e-3 < st[-1][0].ts_us:  # 1ns grace
                        problems.append(
                            f"tid {ev.tid}: {ev.ph} {ev.name!r} starts before "
                            f"enclosing {st[-1][0].name!r}")
                    st[-1][1] = max(st[-1][1], end)
        for tid, st in stacks.items():
            for b, _ in st:
                problems.append(f"tid {tid}: span {b.name!r} never closed")
        for fid, s in open_flows.items():
            problems.append(
                f"tid {s.tid}: flow {fid} ({s.name!r}) never finished")
        return problems

    def request_spans(self) -> Dict[int, Dict[str, Any]]:
        """Summarize request lifecycle spans, keyed by request id.

        Each entry has ``closed`` (the ``request`` span got its E),
        ``begin_us``/``end_us``, ``reason`` (from the ``retired``
        instant), and ``events`` (child event names in recorded order).
        """
        spans: Dict[int, Dict[str, Any]] = {}
        open_by_tid: Dict[int, int] = {}
        for ev in self.events:
            if ev.tid == SCHED_TID:
                continue
            if ev.ph == "B" and ev.name == "request":
                rid = int((ev.args or {}).get("rid", ev.tid - 1))
                spans[rid] = {"closed": False, "begin_us": ev.ts_us,
                              "end_us": None, "reason": None, "events": []}
                open_by_tid[ev.tid] = rid
                continue
            rid = open_by_tid.get(ev.tid)
            if rid is None:
                continue
            span = spans[rid]
            if ev.ph == "E" and ev.name == "request":
                span["closed"] = True
                span["end_us"] = ev.ts_us
                del open_by_tid[ev.tid]
            elif ev.ph not in ("E", "s", "f"):
                span["events"].append(ev.name)
                if ev.name == "retired":
                    span["reason"] = (ev.args or {}).get("reason")
        return spans

    # ------------------------------------------------------------------
    # Export
    # ------------------------------------------------------------------

    def to_chrome(self) -> Dict[str, Any]:
        """Chrome ``trace_event`` JSON object format."""
        meta: List[Dict[str, Any]] = [{
            "name": "process_name", "ph": "M", "pid": _PID,
            "args": {"name": "repro.serving"},
        }]
        for tid in sorted(self._track_names):
            meta.append({"name": "thread_name", "ph": "M", "pid": _PID,
                         "tid": tid,
                         "args": {"name": self._track_names[tid]}})
            meta.append({"name": "thread_sort_index", "ph": "M", "pid": _PID,
                         "tid": tid, "args": {"sort_index": tid}})
        return {
            "traceEvents": meta + [ev.to_chrome() for ev in self.events],
            "displayTimeUnit": "ms",
            "otherData": {"dropped_events": self.dropped},
        }

    def dump_json(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_chrome(), f)
            f.write("\n")
