"""Deterministic synthetic corpora (offline container: no downloads).

The paper evaluates many-to-many translation between Indic and overseas
languages with target-language code tokens (NLLB convention). We model
that interface exactly with a *learnable* synthetic task:

  * SyntheticTranslation — parallel (src, tgt) pairs. Each "language" is
    an affine token permutation; tgt_t = perm_tgt(inv_perm_src(src_t)),
    prefixed with the target-language code token. A model that learns the
    per-language permutations + code conditioning drives loss -> ~0, so
    integration tests can assert learning.
  * SyntheticLM — Zipf-ish autoregressive stream with short-range copy
    structure (tokens repeat with lag), learnable by small LMs.

Everything is seeded numpy; batches are dicts matching configs.input_specs.
"""

from __future__ import annotations

from typing import Iterator, List, Optional, Sequence, Tuple

import numpy as np

__all__ = ["LANG_CODES", "INDIC_LANGS", "OVERSEAS_LANGS", "pairs",
           "SyntheticTranslation", "SyntheticLM", "make_batch",
           "batch_iterator"]

# paper Fig. 9 languages (token ids 1..N reserved as language codes)
LANG_CODES = {
    "hin": 1, "tam": 2, "tel": 3, "kan": 4, "ben": 5, "mar": 6,   # Indic
    "eng": 7, "ita": 8, "fra": 9, "deu": 10, "spa": 11, "jpn": 12,  # overseas
}
INDIC_LANGS = ("hin", "tam", "tel", "kan", "ben", "mar")
OVERSEAS_LANGS = ("eng", "ita", "fra", "deu", "spa", "jpn")
_N_RESERVED = 16  # 0 = pad/bos, 1..15 language codes


def pairs(src_langs: Sequence[str] = INDIC_LANGS,
          tgt_langs: Sequence[str] = OVERSEAS_LANGS
          ) -> List[Tuple[str, str]]:
    """Bidirectional (src, tgt) pair grid, both directions of every
    cross-group combination — the paper's Fig. 9 Indic<->overseas
    evaluation matrix by default (6 x 6 x 2 = 72 pairs). Deduplicated
    (order-preserving), so overlapping groups don't double-weight a
    direction."""
    fwd = [(s, t) for s in src_langs for t in tgt_langs if s != t]
    return list(dict.fromkeys(fwd + [(t, s) for s, t in fwd]))


class SyntheticTranslation:
    """Many-to-many parallel corpus over `languages` with shared content.

    ``split`` selects the sentence-content stream: ``"train"`` keeps the
    historical stream bit-for-bit; ``"eval"`` draws from a disjoint
    seeded stream so evaluation never scores on training sentences.
    The per-language permutations (the "languages" themselves) depend
    only on ``(seed, languages)`` and are shared across splits — the
    eval split tests generalization to unseen sentences of the *same*
    translation mapping, which is the point.
    """

    def __init__(self, vocab_size: int, seq_len: int, seed: int = 0,
                 languages=("hin", "eng", "ita", "tam"),
                 split: str = "train"):
        assert vocab_size > 2 * _N_RESERVED
        if split not in ("train", "eval"):
            raise ValueError(f"split must be 'train' or 'eval', got {split!r}")
        self.vocab = vocab_size
        self.seq = seq_len
        self.langs = list(languages)
        self.split = split
        rng = np.random.default_rng(seed)
        self._perm = {}
        n_content = vocab_size - _N_RESERVED
        for lang in self.langs:
            p = rng.permutation(n_content)
            self._perm[lang] = p
            self._perm[lang + "_inv"] = np.argsort(p)
        # train: the pre-split stream, unchanged; eval: a seed-sequence
        # stream no integer seed of the train form can collide with
        self.rng = np.random.default_rng(seed + 1) if split == "train" \
            else np.random.default_rng([seed + 1, 0x0E7A])

    def _content(self, batch: int) -> np.ndarray:
        # zipf-flavoured content ids in [0, vocab - reserved)
        z = self.rng.zipf(1.3, size=(batch, self.seq - 2)).astype(np.int64)
        return (z - 1) % (self.vocab - _N_RESERVED)

    def sample(self, batch: int, pair: Optional[Tuple[str, str]] = None):
        """Returns dict: src_tokens (B,S), tgt_in (B,S), tgt_out (B,S), mask.

        ``pair=(src_lang, tgt_lang)`` pins the direction (the eval
        suite's per-pair matrix); default draws a random ordered pair.
        """
        if pair is not None:
            src_l, tgt_l = pair
            for lang in (src_l, tgt_l):
                if lang not in self.langs:
                    raise KeyError(
                        f"language {lang!r} not in this corpus "
                        f"(languages={self.langs})")
            if src_l == tgt_l:
                raise ValueError(f"pair must be two languages, got {pair}")
        else:
            src_l, tgt_l = self.rng.choice(self.langs, 2, replace=False)
        content = self._content(batch)
        src = self._perm[src_l][content] + _N_RESERVED
        tgt = self._perm[tgt_l][content] + _N_RESERVED
        code = LANG_CODES[tgt_l]
        B, S = batch, self.seq
        src_tok = np.zeros((B, S), np.int32)
        src_tok[:, 0] = code                      # target code prefixes source
        src_tok[:, 1:S - 1] = src
        tgt_in = np.zeros((B, S), np.int32)
        tgt_in[:, 0] = code                       # decoder starts from code
        tgt_in[:, 1:S - 1] = tgt[:, :S - 2]
        tgt_out = np.zeros((B, S), np.int32)
        tgt_out[:, :S - 2] = tgt
        mask = (tgt_out != 0).astype(np.float32)
        return {"src_tokens": src_tok, "tgt_in": tgt_in,
                "tgt_out": tgt_out, "loss_mask": mask,
                "src_lang": src_l, "tgt_lang": tgt_l}


class SyntheticLM:
    """Autoregressive stream with learnable copy/lag structure."""

    def __init__(self, vocab_size: int, seq_len: int, seed: int = 0,
                 lag: int = 4):
        self.vocab = vocab_size
        self.seq = seq_len
        self.lag = lag
        self.rng = np.random.default_rng(seed)

    def sample(self, batch: int):
        z = self.rng.zipf(1.5, size=(batch, self.seq)).astype(np.int64)
        toks = 1 + (z - 1) % (self.vocab - 1)
        # copy structure: token repeats from `lag` back with p=0.5
        copy = self.rng.random((batch, self.seq)) < 0.5
        for t in range(self.lag, self.seq):
            toks[:, t] = np.where(copy[:, t], toks[:, t - self.lag], toks[:, t])
        toks = toks.astype(np.int32)
        mask = np.ones((batch, self.seq), np.float32)
        return {"tokens": toks, "loss_mask": mask}


def make_batch(cfg, shape_spec, seed: int = 0, batch: Optional[int] = None,
               seq: Optional[int] = None):
    """One concrete (host) batch for an (arch x shape) cell."""
    B = batch or shape_spec.global_batch
    S = seq or shape_spec.seq_len
    rng = np.random.default_rng(seed)
    if cfg.family in ("encdec", "audio"):
        ds = SyntheticTranslation(cfg.vocab_size, S, seed)
        b = ds.sample(B)
        if cfg.family == "audio":   # stub conv frontend output
            b = {"tgt_in": b["tgt_in"], "tgt_out": b["tgt_out"],
                 "loss_mask": b["loss_mask"],
                 "frames": rng.standard_normal(
                     (B, cfg.enc_len, cfg.d_model)).astype(np.float32) * 0.1}
        else:
            b["src_tokens"] = b["src_tokens"][:, :cfg.enc_len] if \
                cfg.enc_len < S else b["src_tokens"]
        return b
    ds = SyntheticLM(cfg.vocab_size, S, seed)
    b = ds.sample(B)
    if cfg.family == "vlm":
        P = cfg.num_patches
        b["tokens"] = b["tokens"][:, :max(S - P, 8)]
        b["loss_mask"] = b["loss_mask"][:, :max(S - P, 8)]
        b["img_embeds"] = rng.standard_normal(
            (B, P, cfg.d_model)).astype(np.float32) * 0.1
    return b


def batch_iterator(cfg, shape_spec, seed: int = 0, batch=None,
                   seq=None) -> Iterator[dict]:
    step = 0
    while True:
        yield make_batch(cfg, shape_spec, seed + step, batch, seq)
        step += 1
