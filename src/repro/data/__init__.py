from .synthetic import (INDIC_LANGS, LANG_CODES, OVERSEAS_LANGS, SyntheticLM,
                        SyntheticTranslation, batch_iterator, make_batch,
                        pairs)

__all__ = ["SyntheticTranslation", "SyntheticLM", "LANG_CODES", "INDIC_LANGS",
           "OVERSEAS_LANGS", "pairs", "make_batch", "batch_iterator"]
