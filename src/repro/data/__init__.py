from .synthetic import (LANG_CODES, SyntheticLM, SyntheticTranslation,
                        make_batch, batch_iterator)

__all__ = ["SyntheticTranslation", "SyntheticLM", "LANG_CODES", "make_batch",
           "batch_iterator"]
