"""Cluster serving: tensor-parallel engines + data-parallel routing.

Two composable scale-out layers over the single serving engine:

* **Tensor parallel** — ``deploy(..., mesh=tp_mesh(K))`` shards one
  engine's params and KV storage over K devices (GSPMD; see
  ``parallel.sharding`` and the ``mesh=`` docs on ``serving.deploy``).
* **Data parallel** — :class:`ReplicaRouter` load-balances requests
  over N independent engine replicas; :func:`deploy_replicas` builds
  the whole stack (N replicas x K-way tensor parallel on disjoint
  device groups) behind the ordinary ``TranslationPipeline`` surface.

Both layers hold the engine's standing invariant: routed and sharded
token streams are token-for-token identical to a single-device engine
serving the same requests. Everything is CPU-testable via
``XLA_FLAGS=--xla_force_host_platform_device_count=8``.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Optional, Sequence, Tuple

import numpy as np

from .router import ReplicaRouter

__all__ = ["ReplicaRouter", "deploy_replicas", "parse_mesh_spec",
           "tp_mesh"]


def parse_mesh_spec(spec: str) -> Tuple[int, int]:
    """Parse the CLI mesh convention ``"dp2,tp2"`` -> ``(dp, tp)``.

    Comma-separated ``dp<N>`` / ``tp<N>`` factors in either order;
    omitted factors default to 1 (``"tp4"`` -> (1, 4); ``"dp2"`` ->
    (2, 1)). dp is the replica count, tp the per-replica mesh width.
    """
    dp = tp = 1
    seen = set()
    for part in filter(None, (p.strip() for p in spec.split(","))):
        m = re.fullmatch(r"(dp|tp)(\d+)", part)
        if m is None:
            raise ValueError(
                f"bad mesh factor {part!r} in {spec!r}; expected "
                "comma-separated dp<N>/tp<N>, e.g. 'dp2,tp2'")
        axis, n = m.group(1), int(m.group(2))
        if axis in seen:
            raise ValueError(f"duplicate {axis!r} factor in {spec!r}")
        seen.add(axis)
        if n < 1:
            raise ValueError(f"mesh factor {part!r} must be >= 1")
        if axis == "dp":
            dp = n
        else:
            tp = n
    return dp, tp


def tp_mesh(tp: int, devices: Optional[Sequence] = None):
    """A ``("model",)``-axis Mesh over ``tp`` devices (the serving
    engine's tensor-parallel domain). Defaults to the first ``tp`` of
    ``jax.devices()`` — force 8 host devices on CPU with
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8``."""
    import jax
    from jax.sharding import Mesh

    devs = list(devices) if devices is not None else jax.devices()
    if len(devs) < tp:
        raise ValueError(
            f"tensor parallelism tp={tp} needs {tp} devices, have "
            f"{len(devs)} (on CPU set XLA_FLAGS="
            f"--xla_force_host_platform_device_count={tp})")
    return Mesh(np.asarray(devs[:tp]), ("model",))


def deploy_replicas(arch_or_cfg, policy="int4", *, replicas: int = 2,
                    tp: int = 1, devices: Optional[Sequence] = None,
                    **deploy_kwargs):
    """Deploy ``replicas`` independent engines behind a ReplicaRouter.

    Each replica is a full ``serving.deploy`` of the same config/policy
    (pass ``params=`` to share one checkpoint; otherwise ``init_seed``
    makes every replica initialize identically). Device placement:

    * ``tp > 1`` — replica ``i`` gets its own ``("model",)`` mesh over
      devices ``[i*tp, (i+1)*tp)``: disjoint tensor-parallel groups.
    * ``tp == 1`` with at least ``replicas`` devices — each replica is
      pinned to its own device via a width-1 mesh, so replicas execute
      concurrently instead of queueing on the default device.
    * otherwise — no mesh (all replicas on the default device; routing
      and backpressure still apply, only device concurrency is lost).

    Returns a ``TranslationPipeline`` whose ``engine`` is the router —
    ``translate``/``generate`` fan over replicas transparently
    (``translate_stream`` needs a single-engine pipeline). The
    per-replica engines stay reachable via ``pipe.engine.replicas``.
    """
    import jax
    from jax.sharding import Mesh

    from ..serving import deploy

    if replicas < 1:
        raise ValueError(f"replicas must be >= 1, got {replicas}")
    devs = list(devices) if devices is not None else jax.devices()
    if tp > 1:
        need = replicas * tp
        if len(devs) < need:
            raise ValueError(
                f"dp{replicas},tp{tp} needs {need} devices, have "
                f"{len(devs)} (on CPU set XLA_FLAGS="
                f"--xla_force_host_platform_device_count={need})")
        meshes = [Mesh(np.asarray(devs[i * tp:(i + 1) * tp]), ("model",))
                  for i in range(replicas)]
    elif replicas > 1 and len(devs) >= replicas:
        meshes = [Mesh(np.asarray(devs[i:i + 1]), ("model",))
                  for i in range(replicas)]
    else:
        meshes = [None] * replicas
    pipes = [deploy(arch_or_cfg, policy, mesh=m, **deploy_kwargs)
             for m in meshes]
    router = ReplicaRouter([p.engine for p in pipes])
    return dataclasses.replace(pipes[0], engine=router)
