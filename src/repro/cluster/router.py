"""Data-parallel replica routing over independent ServeEngines.

The paper's deployment target is translation for "millions of users";
one continuous-batching engine — however well quantized — caps out at
its slot count. This module scales *out*: a :class:`ReplicaRouter` owns
N fully independent ``ServeEngine`` replicas (each optionally
tensor-parallel over its own device mesh — see ``deploy_replicas``) and
presents the engine's own request surface, so every existing caller
(``TranslationPipeline``, benchmarks, the eval suite) serves through a
cluster by swapping the object behind ``.engine``:

    router = ReplicaRouter([engine0, engine1, ...])
    gid  = router.submit(inputs, SamplingParams(...))
    outs = router.run_until_drained()          # fans over replicas

Routing policy
--------------
``submit()`` places each request on the replica with the least
outstanding work, where "outstanding" defers to per-request
``SamplingParams.priority``: a priority-p request counts only live
requests of priority >= p as competition (a high-priority request
routes to the replica where the least important work stands in its
way), tie-broken by total backlog then replica index — deterministic,
so routed runs are reproducible. A saturated replica
(``EngineSaturated`` from its bounded queue) is skipped for the
next-least-loaded one; the typed error is re-raised only when EVERY
replica is saturated, with cluster-wide pending/limit totals.

Request ids returned by the router are *global*: the router remaps each
replica's local ids, so two replicas assigning the same local id never
collide in caller-visible outputs. ``abort`` routes to the owning
replica.

Draining (``run_until_drained`` / ``stream``) interleaves every busy
replica's overlapped round generator (``ServeEngine.serve_rounds``) one
round at a time: while the host syncs one replica's token block, every
other replica's dispatched horizon keeps running on its own devices —
cross-replica overlap on top of each engine's internal double
buffering. Token streams are per-request identical to serving the same
request on a lone engine (replicas share nothing), which is the
subsystem's standing correctness bar.

Metrics aggregate via ``serving.metrics.merge_metrics`` +
``obs.Histogram.merge`` (counters sum, latency percentiles come from
merged histograms — never from averaging per-replica percentiles);
``prometheus()`` renders the merged cluster snapshot plus a
per-replica gauge section labelled ``{replica="i"}``.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Iterator, List, Optional, Sequence

from ..obs import Histogram
from ..obs.metrics import render_prometheus, render_prometheus_labeled
from ..serving.engine import ServeEngine
from ..serving.metrics import EngineMetrics, merge_metrics
from ..serving.params import (EngineSaturated, Request, RequestOutput,
                              SamplingParams)

__all__ = ["ReplicaRouter"]


class ReplicaRouter:
    """Least-outstanding-work router over N independent engine replicas.

    Presents the ``ServeEngine`` request surface (submit / step /
    run_until_drained / stream / abort / metrics / prometheus /
    reset_metrics / num_pending / num_active) so a
    ``TranslationPipeline`` can carry a router as its ``engine``.
    """

    def __init__(self, replicas: Sequence[ServeEngine]):
        self.replicas: List[ServeEngine] = list(replicas)
        if not self.replicas:
            raise ValueError("ReplicaRouter needs at least one replica")
        self._next_gid = 0
        # gid -> (replica idx, local id, priority); entries live from
        # submit until the remapped output is handed to the caller
        self._owner: dict = {}
        # per-replica local id -> gid (the reverse map used on claim)
        self._local: List[dict] = [dict() for _ in self.replicas]

    # ------------------------------------------------------------------
    # routing
    # ------------------------------------------------------------------

    def _competing(self, ridx: int, priority: int) -> int:
        """Live requests on replica ``ridx`` that outrank-or-match
        ``priority`` (the work that would be served ahead of or beside
        a new request at that priority)."""
        return sum(1 for (r, _lid, p) in self._owner.values()
                   if r == ridx and p >= priority)

    def _order(self, priority: int) -> List[int]:
        """Replica indices, least-loaded first: fewest >=priority
        competitors, then total backlog, then index (deterministic)."""
        def key(i: int):
            eng = self.replicas[i]
            return (self._competing(i, priority),
                    eng.num_pending + eng.num_active, i)
        return sorted(range(len(self.replicas)), key=key)

    def submit(self, request, params: Optional[SamplingParams] = None, *,
               on_token: Optional[Callable[[int], None]] = None) -> int:
        """Route one request to the least-loaded replica; returns its
        cluster-global request id.

        Skips saturated replicas (bounded queues) in load order and
        re-raises ``EngineSaturated`` — with cluster-wide totals — only
        when every replica rejected. Validation errors (over-long
        request, unfittable page reservation) raise from the first
        attempted replica: they would fail identically everywhere.
        """
        if params is not None:
            priority = params.priority
        elif isinstance(request, Request):
            priority = request.params.priority
        else:
            priority = 0
        for i in self._order(priority):
            try:
                lid = self.replicas[i].submit(request, params,
                                              on_token=on_token)
            except EngineSaturated:
                continue
            gid = self._next_gid
            self._next_gid += 1
            self._owner[gid] = (i, lid, priority)
            self._local[i][lid] = gid
            return gid
        raise EngineSaturated(
            sum(e.num_pending for e in self.replicas),
            sum(e.max_pending or 0 for e in self.replicas))

    def _remap(self, ridx: int,
               outs: Sequence[RequestOutput]) -> List[RequestOutput]:
        remapped = []
        for out in outs:
            gid = self._local[ridx].pop(out.request_id)
            self._owner.pop(gid, None)
            remapped.append(dataclasses.replace(out, request_id=gid))
        return remapped

    def _claim(self, ridx: int) -> List[RequestOutput]:
        return self._remap(ridx, self.replicas[ridx].take_finished())

    # ------------------------------------------------------------------
    # serving
    # ------------------------------------------------------------------

    def step(self, horizon: Optional[int] = None) -> List[RequestOutput]:
        """One scheduler round on every replica with work; returns the
        remapped outputs of every request that finished."""
        outs: List[RequestOutput] = []
        for i, eng in enumerate(self.replicas):
            if eng.num_pending or eng.num_active:
                eng.step(horizon)
            outs.extend(self._claim(i))
        return outs

    def stream(self, horizon: Optional[int] = None,
               on_round: Optional[Callable[[], None]] = None,
               max_rounds: int = 1_000_000
               ) -> Iterator[RequestOutput]:
        """Serve until every replica drains, yielding each remapped
        RequestOutput as its request finishes.

        Interleaves the replicas' overlapped round generators: one
        cluster round advances every busy replica by one round, so each
        host sync overlaps the other replicas' in-flight horizons.
        ``on_round`` fires once per cluster round (arrival injection,
        as in ``bench_serving --rate``); work it submits keeps the loop
        alive.
        """
        for i in range(len(self.replicas)):
            yield from self._claim(i)
        rounds: dict = {}
        try:
            for _ in range(max_rounds):
                for i, eng in enumerate(self.replicas):
                    if i not in rounds and (eng.num_pending
                                            or eng.num_active):
                        rounds[i] = eng.serve_rounds(horizon)
                if not rounds:
                    break
                for i in sorted(rounds):
                    try:
                        next(rounds[i])
                    except StopIteration:
                        del rounds[i]
                    yield from self._claim(i)
                if on_round is not None:
                    on_round()
        finally:
            for gen in rounds.values():
                gen.close()     # walks any dispatched-ahead block
        for i in range(len(self.replicas)):
            yield from self._claim(i)

    def run_until_drained(self, max_steps: int = 1_000_000,
                          horizon: Optional[int] = None
                          ) -> List[RequestOutput]:
        """Serve every queued/in-flight request across all replicas;
        returns all remapped outputs."""
        return list(self.stream(horizon=horizon, max_rounds=max_steps))

    def stream_request(self, request, params=None, horizon=None):
        """Not supported at the router level: per-token streaming of a
        single request binds the caller to one replica's round loop,
        which would stall the others. Submit with ``on_token=`` and
        drive ``stream()`` instead."""
        raise NotImplementedError(
            "ReplicaRouter does not stream single requests; use "
            "submit(..., on_token=cb) + stream(), or deploy a "
            "single-engine pipeline for translate_stream()")

    def abort(self, request_id: int) -> Optional[RequestOutput]:
        """Cancel a routed request on its owning replica. Returns the
        remapped output (finish_reason 'abort'), or None if the id is
        unknown or the request already finished (its output stays
        claimable through step()/stream())."""
        info = self._owner.get(request_id)
        if info is None:
            return None
        ridx, lid, _ = info
        out = self.replicas[ridx].abort(lid)
        if out is None:
            return None
        return self._remap(ridx, [out])[0]

    # ------------------------------------------------------------------
    # cluster state + metrics
    # ------------------------------------------------------------------

    @property
    def max_len(self) -> int:
        """Per-request cache budget (min across replicas — deploys are
        homogeneous, but a conservative bound is always admissible)."""
        return min(e.max_len for e in self.replicas)

    @property
    def trace(self):
        """Replica 0's tracer (each engine owns its own trace; reach
        the rest via ``router.replicas[i].trace``)."""
        return self.replicas[0].trace

    @property
    def num_pending(self) -> int:
        return sum(e.num_pending for e in self.replicas)

    @property
    def num_active(self) -> int:
        return sum(e.num_active for e in self.replicas)

    def merged_latency_histograms(self) -> dict:
        """Fresh ``Histogram``s holding every replica's TTFT/TPOT
        samples (``Histogram.merge`` into new accumulators — the
        replicas' own histograms are never mutated)."""
        merged = {"ttft_ms": Histogram(), "tpot_ms": Histogram()}
        for eng in self.replicas:
            for name, h in eng.latency_histograms().items():
                merged[name].merge(h)
        return merged

    def metrics(self) -> EngineMetrics:
        """One merged cluster snapshot: counters summed across
        replicas, latency percentiles from the merged histograms."""
        hists = self.merged_latency_histograms()
        return merge_metrics([e.metrics() for e in self.replicas],
                             ttft_hist=hists["ttft_ms"],
                             tpot_hist=hists["tpot_ms"])

    def prometheus(self) -> str:
        """Prometheus text: the merged cluster snapshot + merged
        latency histograms under ``repro_cluster_*``, then a
        per-replica section under ``repro_cluster_replica_*`` with a
        ``replica`` label distinguishing the series."""
        text = render_prometheus(self.metrics(),
                                 self.merged_latency_histograms(),
                                 prefix="repro_cluster")
        text += render_prometheus_labeled(
            [({"replica": str(i)}, eng.metrics())
             for i, eng in enumerate(self.replicas)],
            prefix="repro_cluster_replica")
        return text

    def reset_metrics(self) -> None:
        for eng in self.replicas:
            eng.reset_metrics()
