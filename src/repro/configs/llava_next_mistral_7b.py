"""llava-next-mistral-7b [vlm] — mistral-7B backbone, anyres tiling via a
STUB frontend (input_specs provides precomputed patch embeddings)
[hf:llava-hf/llava-v1.6-mistral-7b-hf]."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-mistral-7b", family="vlm",
    num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8, head_dim=128,
    d_ff=14336, vocab_size=32000, mlp_act="silu_glu",
    rope_theta=1e6, norm_eps=1e-5,
    window_pattern=(4096,),               # mistral sliding window
    num_patches=576,
    source="[hf:llava-hf/llava-v1.6-mistral-7b-hf; assignment line]",
)
