"""gemma3-1b [dense] — 5:1 local:global (window 512), GQA kv=1, 128k ctx
[hf:google/gemma-3-1b-pt]."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-1b", family="dense",
    num_layers=26, d_model=1152, num_heads=4, num_kv_heads=1, head_dim=256,
    d_ff=6912, vocab_size=262144, mlp_act="gelu_glu", qk_norm=True,
    rope_theta=1e6, norm_eps=1e-6,
    window_pattern=(512, 512, 512, 512, 512, 0),   # 5 local : 1 global
    tie_embeddings=True, embed_scale=True,
    source="[hf:google/gemma-3-1b-pt; assignment line]",
)
