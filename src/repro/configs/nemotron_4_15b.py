"""nemotron-4-15b [dense] — GQA kv=8, squared-ReLU [arXiv:2402.16819]."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="nemotron-4-15b", family="dense",
    num_layers=32, d_model=6144, num_heads=48, num_kv_heads=8, head_dim=128,
    d_ff=24576, vocab_size=256000, mlp_act="squared_relu",
    rope_theta=1e4, norm_eps=1e-5,
    source="[arXiv:2402.16819; assignment line]",
)
