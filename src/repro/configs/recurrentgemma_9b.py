"""recurrentgemma-9b [hybrid] — RG-LRU + local attention 2:1
[arXiv:2402.19427]."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b", family="hybrid",
    num_layers=38, d_model=4096, num_heads=16, num_kv_heads=1, head_dim=256,
    d_ff=12288, vocab_size=256000, mlp_act="gelu_glu",
    rope_theta=1e4, norm_eps=1e-6,
    tie_embeddings=True, embed_scale=True,
    d_rec=4096, local_window=2048,
    source="[arXiv:2402.19427; assignment line]",
)
