"""Config registry: 10 assigned architectures + the paper's NLLB-600M."""

from . import (gemma3_1b, internlm2_20b, llava_next_mistral_7b, mamba2_780m,
               moonshot_v1_16b_a3b, nemotron_4_15b, nllb600m, olmoe_1b_7b,
               qwen2_5_14b, recurrentgemma_9b, whisper_base)
from .base import (SHAPES, ModelConfig, MoECfg, ShapeSpec, SSMCfg,
                   active_param_count, input_specs, param_count,
                   reduce_config, supported_shapes)

_ALL = [
    mamba2_780m.CONFIG,
    nemotron_4_15b.CONFIG,
    internlm2_20b.CONFIG,
    qwen2_5_14b.CONFIG,
    gemma3_1b.CONFIG,
    moonshot_v1_16b_a3b.CONFIG,
    olmoe_1b_7b.CONFIG,
    llava_next_mistral_7b.CONFIG,
    whisper_base.CONFIG,
    recurrentgemma_9b.CONFIG,
    nllb600m.CONFIG,
    nllb600m.CONFIG_MOE,
]

REGISTRY = {c.name: c for c in _ALL}
ASSIGNED = [c.name for c in _ALL[:10]]     # the graded 10-arch pool


def get_config(name: str) -> ModelConfig:
    if name not in REGISTRY:
        raise KeyError(f"unknown arch {name!r}; have {sorted(REGISTRY)}")
    return REGISTRY[name]


__all__ = ["get_config", "REGISTRY", "ASSIGNED", "SHAPES", "ModelConfig",
           "MoECfg", "SSMCfg", "ShapeSpec", "input_specs", "param_count",
           "active_param_count", "reduce_config", "supported_shapes"]
