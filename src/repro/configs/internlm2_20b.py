"""internlm2-20b [dense] — GQA kv=8 [arXiv:2403.17297; hf]."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="internlm2-20b", family="dense",
    num_layers=48, d_model=6144, num_heads=48, num_kv_heads=8, head_dim=128,
    d_ff=16384, vocab_size=92544, mlp_act="silu_glu",
    rope_theta=1e6, norm_eps=1e-5,
    source="[arXiv:2403.17297; hf:internlm/internlm2-20b]",
)
