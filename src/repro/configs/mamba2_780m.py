"""mamba2-780m [ssm] — SSD, attention-free [arXiv:2405.21060]."""
from .base import ModelConfig, SSMCfg

CONFIG = ModelConfig(
    name="mamba2-780m", family="ssm",
    num_layers=48, d_model=1536, num_heads=48, num_kv_heads=48, head_dim=64,
    d_ff=0, vocab_size=50280, mlp_act="silu",
    tie_embeddings=True, norm_eps=1e-5,
    ssm=SSMCfg(state_dim=128, head_dim=64, expand=2, chunk=128),
    source="[arXiv:2405.21060; assignment line]",
)
