"""NLLB-200 600M distilled (the paper's model, arXiv nllb / Nature 2024).

Paper II-A: 600M-parameter transformer encoder-decoder, six layers each,
pre-norm residual, MHA, two-layer FFNs, SentencePiece vocab, many-to-many
translation via target-language code tokens. The -moe variant is Fig. 3b.
"""
from .base import ModelConfig, MoECfg

CONFIG = ModelConfig(
    name="nllb600m", family="encdec",
    num_layers=6, enc_layers=6, enc_len=256,
    d_model=1024, num_heads=16, num_kv_heads=16, head_dim=64,
    d_ff=8192, vocab_size=256204, mlp_act="relu",
    tie_embeddings=True, norm_eps=1e-5,
    source="[Nature 2024 / arXiv:2207.04672; paper II-A]",
)

CONFIG_MOE = ModelConfig(
    name="nllb600m-moe", family="encdec",
    num_layers=6, enc_layers=6, enc_len=256,
    d_model=1024, num_heads=16, num_kv_heads=16, head_dim=64,
    d_ff=8192, vocab_size=256204, mlp_act="relu",
    tie_embeddings=True, norm_eps=1e-5,
    moe=MoECfg(num_experts=16, top_k=2),
    source="[paper Fig. 3b MoE variant]",
)
