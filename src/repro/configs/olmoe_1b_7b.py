"""olmoe-1b-7b [moe] — 64 experts top-8 [arXiv:2409.02060; hf]."""
from .base import ModelConfig, MoECfg

CONFIG = ModelConfig(
    name="olmoe-1b-7b", family="moe",
    num_layers=16, d_model=2048, num_heads=16, num_kv_heads=16, head_dim=128,
    d_ff=1024, vocab_size=50304, mlp_act="silu_glu",
    rope_theta=1e4, norm_eps=1e-5,
    moe=MoECfg(num_experts=64, top_k=8),
    source="[arXiv:2409.02060; hf:allenai/OLMoE-1B-7B-0924]",
)
