"""moonshot-v1-16b-a3b [moe] — 64 experts top-6, GQA kv=16
[hf:moonshotai/Moonlight-16B-A3B]."""
from .base import ModelConfig, MoECfg

CONFIG = ModelConfig(
    name="moonshot-v1-16b-a3b", family="moe",
    num_layers=48, d_model=2048, num_heads=16, num_kv_heads=16, head_dim=128,
    d_ff=1408, vocab_size=163840, mlp_act="silu_glu",
    rope_theta=5e4, norm_eps=1e-5,
    moe=MoECfg(num_experts=64, top_k=6),
    source="[hf:moonshotai/Moonlight-16B-A3B; assignment line]",
)
