"""whisper-base [audio] — enc-dec backbone; conv frontend is a STUB
(input_specs provides precomputed frame embeddings) [arXiv:2212.04356]."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-base", family="audio",
    num_layers=6, enc_layers=6, enc_len=1500,
    d_model=512, num_heads=8, num_kv_heads=8, head_dim=64,
    d_ff=2048, vocab_size=51865, mlp_act="gelu",
    tie_embeddings=True, norm_eps=1e-5,
    source="[arXiv:2212.04356; assignment line]",
)
