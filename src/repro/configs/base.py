"""Config dataclasses, the assigned shape grid, and input_specs().

Shapes (assignment):
  train_4k     seq=4096,   global_batch=256  (training;   lowers train_step)
  prefill_32k  seq=32768,  global_batch=32   (inference;  lowers prefill)
  decode_32k   seq=32768,  global_batch=128  (one new token, KV cache = seq)
  long_500k    seq=524288, global_batch=1    (decode; SSM/hybrid only)

input_specs() returns ShapeDtypeStruct stand-ins (weak-type correct,
shardable, no device allocation) for every model input of a given
(arch x shape) cell — the dry-run lowers against these.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = ["MoECfg", "SSMCfg", "ModelConfig", "ShapeSpec", "SHAPES",
           "supported_shapes", "input_specs", "reduce_config", "param_count"]


@dataclasses.dataclass(frozen=True)
class MoECfg:
    num_experts: int
    top_k: int
    capacity_factor: float = 1.25
    parallel_mode: str = "expert"        # expert | tensor
    aux_loss_weight: float = 0.01
    dispatch_groups: int = 0             # 0 = auto (DP-aligned); 1 = global


@dataclasses.dataclass(frozen=True)
class SSMCfg:
    state_dim: int = 128
    head_dim: int = 64
    expand: int = 2
    chunk: int = 128


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                          # dense|moe|ssm|hybrid|vlm|audio|encdec
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    mlp_act: str = "silu_glu"
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 1e4
    norm_eps: float = 1e-6
    window_pattern: Tuple[int, ...] = ()   # per-layer windows, cycled; 0=full
    tie_embeddings: bool = False
    embed_scale: bool = False
    moe: Optional[MoECfg] = None
    ssm: Optional[SSMCfg] = None
    # enc-dec
    enc_layers: int = 0
    enc_len: int = 0                     # encoder sequence (frames/src tokens)
    # hybrid (RG-LRU)
    d_rec: int = 0
    local_window: int = 0
    # vlm
    num_patches: int = 0
    # source provenance
    source: str = ""

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str                            # train | prefill | decode


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


def supported_shapes(cfg: ModelConfig) -> list[str]:
    """long_500k only for O(1)-state families (DESIGN.md §4 skip notes)."""
    shapes = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.family in ("ssm", "hybrid"):
        shapes.append("long_500k")
    return shapes


def _tok(shape):
    return jax.ShapeDtypeStruct(shape, jnp.int32)


def input_specs(cfg: ModelConfig, shape_name: str) -> dict:
    """Batch ShapeDtypeStructs for one (arch x shape) cell.

    train/prefill -> the full-sequence batch; decode -> the one-token batch
    (the KV cache is built separately via jax.eval_shape(init_cache)).
    """
    sp = SHAPES[shape_name]
    B, S = sp.global_batch, sp.seq_len
    if sp.kind == "decode":
        return {"tokens": _tok((B, 1))}

    train = sp.kind == "train"
    if cfg.family == "vlm":
        P = cfg.num_patches
        return {"tokens": _tok((B, S - P)),
                "img_embeds": jax.ShapeDtypeStruct((B, P, cfg.d_model),
                                                   jnp.bfloat16)}
    if cfg.family == "audio":
        specs = {"tgt_in": _tok((B, S)),
                 "frames": jax.ShapeDtypeStruct((B, cfg.enc_len, cfg.d_model),
                                                jnp.bfloat16)}
    elif cfg.family == "encdec":
        specs = {"tgt_in": _tok((B, S)), "src_tokens": _tok((B, cfg.enc_len))}
    else:
        return {"tokens": _tok((B, S))}
    if train:   # teacher-forcing labels for the enc-dec loss
        specs["tgt_out"] = _tok((B, S))
        specs["loss_mask"] = jax.ShapeDtypeStruct((B, S), jnp.float32)
    return specs


def reduce_config(cfg: ModelConfig, **over) -> ModelConfig:
    """Smoke-test variant: same family/topology, tiny dims."""
    heads = 4
    kv = max(1, min(cfg.num_kv_heads * heads // max(cfg.num_heads, 1), heads))
    if cfg.family == "hybrid":
        layers = 4        # 1 super-block (r,r,a) + 1 tail recurrent layer
    else:
        layers = 2
    changes = dict(
        name=cfg.name + "-smoke",
        num_layers=layers,
        d_model=64,
        num_heads=heads,
        num_kv_heads=kv,
        head_dim=16,
        d_ff=0 if cfg.d_ff == 0 else 96,
        vocab_size=256,
        enc_layers=2 if cfg.enc_layers else 0,
        enc_len=12 if cfg.enc_len else 0,
        d_rec=64 if cfg.d_rec else 0,
        local_window=8 if cfg.local_window else 0,
        num_patches=4 if cfg.num_patches else 0,
        window_pattern=tuple(min(w, 8) for w in cfg.window_pattern),
        moe=None if cfg.moe is None else dataclasses.replace(
            cfg.moe, num_experts=4, top_k=2),
        ssm=None if cfg.ssm is None else SSMCfg(state_dim=16, head_dim=16,
                                                expand=2, chunk=8),
    )
    changes.update(over)
    return dataclasses.replace(cfg, **changes)


def param_count(cfg: ModelConfig) -> int:
    """Analytic parameter count (also used for MODEL_FLOPS in roofline)."""
    d, hd = cfg.d_model, cfg.head_dim
    H, Hkv, V, ff = cfg.num_heads, cfg.num_kv_heads, cfg.vocab_size, cfg.d_ff
    embed = V * d * (1 if cfg.tie_embeddings else 2)

    def attn():
        return d * H * hd * 2 + d * Hkv * hd * 2

    def ffn(width):
        mult = 3 if cfg.mlp_act.endswith("_glu") else 2
        return mult * d * width

    if cfg.family == "ssm":
        d_inner = cfg.ssm.expand * d
        nh = d_inner // cfg.ssm.head_dim
        per = d * (2 * d_inner + 2 * cfg.ssm.state_dim + nh) + d_inner * d
        return embed + cfg.num_layers * per

    if cfg.family == "hybrid":
        n_super = cfg.num_layers // 3
        tail = cfg.num_layers - 3 * n_super
        rec = (2 * d * cfg.d_rec + 2 * cfg.d_rec ** 2 + cfg.d_rec * d
               + ffn(ff))
        at = attn() + ffn(ff)
        return embed + (2 * n_super + tail) * rec + n_super * at

    if cfg.moe is not None:
        per = attn() + d * cfg.moe.num_experts + cfg.moe.num_experts * ffn(ff)
        dec = cfg.num_layers * per
        if cfg.enc_layers:
            dec += cfg.enc_layers * (attn() + ffn(ff))
        return embed + dec

    per = attn() + ffn(ff)
    total = embed + cfg.num_layers * per
    if cfg.enc_layers:
        total += cfg.enc_layers * (attn() + ffn(ff))
        total += cfg.num_layers * attn()      # decoder cross-attention
    return total


def active_param_count(cfg: ModelConfig) -> int:
    """Active (per-token) params — MoE uses top_k experts only."""
    if cfg.moe is None:
        return param_count(cfg)
    d, ff = cfg.d_model, cfg.d_ff
    mult = 3 if cfg.mlp_act.endswith("_glu") else 2
    expert_delta = (cfg.moe.num_experts - cfg.moe.top_k) * mult * d * ff
    layers = cfg.num_layers + (cfg.enc_layers or 0)
    return param_count(cfg) - layers * expert_delta
