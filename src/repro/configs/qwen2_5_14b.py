"""qwen2.5-14b [dense] — GQA kv=8, QKV bias [hf:Qwen/Qwen2.5-14B]."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-14b", family="dense",
    num_layers=48, d_model=5120, num_heads=40, num_kv_heads=8, head_dim=128,
    d_ff=13824, vocab_size=152064, mlp_act="silu_glu", qkv_bias=True,
    rope_theta=1e6, norm_eps=1e-6,
    source="[hf:Qwen/Qwen2.5-0.5B family; assignment line]",
)
