"""Compiled-HLO analysis: collective-byte accounting + roofline terms.

The roofline's collective term is not in cost_analysis(): we parse the
post-SPMD optimized HLO (compiled.as_text()) and sum operand sizes of
every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute, converting to *per-device link bytes* with ring-
algorithm factors. Hardware model: TPU v5e.
"""

from __future__ import annotations

import re
from typing import Optional

__all__ = ["HW", "parse_collectives", "roofline_terms"]

# TPU v5e hardware constants (assignment-specified)
HW = {
    "peak_flops_bf16": 197e12,     # FLOP/s per chip
    "hbm_bw": 819e9,               # B/s per chip
    "ici_bw": 50e9,                # B/s per link (~per direction)
}

_DTYPE_BYTES = {
    "f64": 8, "s64": 8, "u64": 8, "c64": 8,
    "f32": 4, "s32": 4, "u32": 4,
    "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e4m3b11fnuz": 1,
    "s8": 1, "u8": 1, "pred": 1, "s4": 0.5, "u4": 0.5,
}

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([\d,]*)\]")
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
_GROUPS_RE = re.compile(r"replica_groups=\{([^}]*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(dtype: str, dims: str) -> float:
    if dtype not in _DTYPE_BYTES:
        return 0.0
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def _result_bytes(line: str) -> float:
    """Sum of the result-side array sizes of an HLO instruction line."""
    eq = line.find(" = ")
    if eq < 0:
        return 0.0
    head = line[:line.find("(", eq) if "(" in line[eq:] else len(line)]
    # result shapes live between '=' and the op name; op name has no '['
    total = 0.0
    for m in _SHAPE_RE.finditer(head[eq:]):
        total += _shape_bytes(m.group(1), m.group(2))
    return total


def _group_size(line: str, total_devices: int) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:   # replica_groups=[G,N] iota form: N per group
        return max(int(m.group(2)), 1)
    m = _GROUPS_RE.search(line)
    if m:
        first = m.group(1).split("}")[0]
        n = len([x for x in first.split(",") if x.strip() != ""])
        return max(n, 1)
    return total_devices


def parse_collectives(hlo_text: str, total_devices: int):
    """Returns (ops list, per_device_link_bytes).

    Per-device ring-model link bytes:
      all-gather      R*(g-1)/g          (R = gathered result, per device)
      reduce-scatter  R*(g-1)            (R = scattered result)
      all-reduce      2*R*(g-1)/g
      all-to-all      R*(g-1)/g
      collective-permute  R
    """
    ops = []
    per_dev = 0.0
    for line in hlo_text.splitlines():
        s = line.strip()
        if not any(f"{c}(" in s or f"{c}-start(" in s or f"{c}-done(" in s
                   for c in _COLLECTIVES):
            continue
        if "-done(" in s:           # bytes counted at -start
            continue
        kind = next(c for c in _COLLECTIVES if f"{c}(" in s or f"{c}-start(" in s)
        r = _result_bytes(s)
        if r == 0:
            continue
        g = _group_size(s, total_devices)
        if kind == "all-gather":
            b = r * (g - 1) / g
        elif kind == "reduce-scatter":
            b = r * (g - 1)
        elif kind == "all-reduce":
            b = 2 * r * (g - 1) / g
        elif kind == "all-to-all":
            b = r * (g - 1) / g
        else:
            b = r
        ops.append({"kind": kind, "result_bytes": r, "group": g,
                    "link_bytes": b})
        per_dev += b
    return ops, per_dev


def roofline_terms(flops_total: float, hbm_bytes_total: float,
                   collective_link_bytes_per_dev: float, chips: int,
                   *, model_flops: Optional[float] = None):
    """The three roofline terms in seconds (assignment formulas).

    cost_analysis flops/bytes on post-SPMD HLO are *per device*; the
    assignment formulas divide totals by chips, so totals = per_dev*chips.
    """
    compute_t = flops_total / (chips * HW["peak_flops_bf16"])
    memory_t = hbm_bytes_total / (chips * HW["hbm_bw"])
    coll_t = (collective_link_bytes_per_dev * chips) / (chips * HW["ici_bw"])
    terms = {"compute_s": compute_t, "memory_s": memory_t,
             "collective_s": coll_t}
    dom = max(terms, key=terms.get)
    out = dict(terms, dominant=dom,
               bound_s=max(compute_t, memory_t, coll_t))
    if model_flops is not None and flops_total > 0:
        out["model_flops"] = model_flops
        out["useful_flop_frac"] = model_flops / flops_total
    return out
