import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# --- multi-pod dry-run driver (MUST set XLA_FLAGS before any jax import) ---
#
# For every (architecture x input shape x mesh) cell this lowers + compiles
# the real step function (train_step / prefill / serve_step) against
# ShapeDtypeStruct inputs on the production mesh, then records
# memory_analysis / cost_analysis / collective schedule for the roofline.
#
#   PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2.5-14b \
#       --shape decode_32k [--multi-pod] [--policy int4] [--out DIR]
#   PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]

import argparse     # noqa: E402
import json         # noqa: E402
import time         # noqa: E402
import traceback    # noqa: E402

import jax          # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from ..configs import (ASSIGNED, SHAPES, active_param_count, get_config,  # noqa: E402
                       input_specs, param_count, supported_shapes)
from ..core.policy import quantize_tree  # noqa: E402
from ..core.spec import resolve_spec  # noqa: E402
from ..models import Ctx, build_model  # noqa: E402
from ..parallel import (batch_axes, batch_shardings, cache_shardings,  # noqa: E402
                        param_shardings, set_mesh)
from ..train import make_train_step  # noqa: E402
from .hlo_analysis import roofline_terms  # noqa: E402
from .hlo_cost import analyze_hlo  # noqa: E402
from .mesh import make_production_mesh  # noqa: E402

__all__ = ["run_cell", "main"]


def _ctx_for(shape_spec):
    chunked = shape_spec.seq_len >= 4096 and shape_spec.kind != "decode"
    # chunk sizes bound the per-layer f32 score tile (see EXPERIMENTS §Perf)
    chunk = 256 if shape_spec.kind == "train" else 512
    return Ctx(compute_dtype=jnp.bfloat16,
               attn_impl="chunked" if chunked else "full",
               attn_chunk=chunk)


def _replicated(mesh, tree):
    return jax.tree.map(lambda _: NamedSharding(mesh, P()), tree)


def build_cell(cfg, shape_name: str, mesh, policy_name: str):
    """Returns (fn, arg_shapes, in_shardings, out_shardings, donate)."""
    sp = SHAPES[shape_name]
    ctx = _ctx_for(sp)
    model = build_model(cfg)
    key = jax.random.PRNGKey(0)
    dp = batch_axes(mesh)
    dp = dp if len(dp) > 1 else (dp[0] if dp else None)

    if sp.kind == "train":
        init_state, step = make_train_step(
            model, lr_fn=lambda s: 1e-4, remat=True, ctx=ctx,
            state_bits=32, param_dtype=jnp.bfloat16)
        params_shape = jax.eval_shape(model.init, key)
        state_shape = jax.eval_shape(init_state, params_shape)
        batch_shape = input_specs(cfg, shape_name)
        ss = param_shardings(mesh, state_shape,
                             expert_mode=cfg.moe.parallel_mode if cfg.moe else "expert",
                             fsdp_scope="opt")
        bs = batch_shardings(mesh, batch_shape)
        metrics_shape = jax.eval_shape(step, state_shape, batch_shape)[1]
        out_sh = (ss, _replicated(mesh, metrics_shape))
        return (step, (state_shape, batch_shape), (ss, bs), out_sh, (0,))

    policy = resolve_spec(policy_name).policy()
    params_shape = jax.eval_shape(
        lambda k: quantize_tree(model.init(k), policy), key)
    ps = param_shardings(mesh, params_shape,
                         expert_mode=cfg.moe.parallel_mode if cfg.moe else "expert")
    B = sp.global_batch

    if sp.kind == "prefill":
        cache_shape = jax.eval_shape(
            lambda: model.init_cache(B, sp.seq_len + 8, policy.kv_cache))
        batch_shape = input_specs(cfg, shape_name)
        cs = cache_shardings(mesh, cache_shape)
        bs = batch_shardings(mesh, batch_shape)

        def fn(params, cache, batch):
            cache, logits = model.prefill(ctx, params, cache, batch)
            nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
            return cache, nxt

        nxt_sh = NamedSharding(
            mesh, P(dp) if dp and B % _axsize(mesh, dp) == 0 else P())
        return (fn, (params_shape, cache_shape, batch_shape),
                (ps, cs, bs), (cs, nxt_sh), (1,))

    # decode: serve_step = one token against a full cache
    cache_shape = jax.eval_shape(
        lambda: model.init_cache(B, sp.seq_len, policy.kv_cache))
    tok_shape = jax.ShapeDtypeStruct((B, 1), jnp.int32)
    cs = cache_shardings(mesh, cache_shape)
    ts = NamedSharding(
        mesh, P(dp) if dp and B % _axsize(mesh, dp) == 0 else P())

    def fn(params, tokens, cache):
        cache, logits = model.decode_step(ctx, params, tokens, cache)
        nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return cache, nxt

    return (fn, (params_shape, tok_shape, cache_shape),
            (ps, ts, cs), (cs, ts), (2,))


def _axsize(mesh, ax):
    size = 1
    for a in (ax if isinstance(ax, tuple) else (ax,)):
        size *= mesh.shape[a]
    return size


def _model_flops(cfg, sp):
    n_active = active_param_count(cfg)
    n_total = param_count(cfg)
    if sp.kind == "train":
        return 6.0 * n_active * sp.global_batch * sp.seq_len, n_total
    if sp.kind == "prefill":
        return 2.0 * n_active * sp.global_batch * sp.seq_len, n_total
    return 2.0 * n_active * sp.global_batch, n_total   # decode: 1 tok/seq


def run_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
             policy: str = "int4", out_dir: str = "experiments/dryrun",
             save_hlo: bool = False, moe_groups: int = 0):
    import dataclasses
    cfg = get_config(arch)
    if moe_groups and cfg.moe is not None:   # ablation: 1 = global dispatch
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, dispatch_groups=moe_groups))
    sp = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.size
    mesh_name = "2x16x16" if multi_pod else "16x16"
    pol = policy if sp.kind != "train" else "bf16"

    t0 = time.perf_counter()
    fn, arg_shapes, in_sh, out_sh, donate = build_cell(cfg, shape_name, mesh,
                                                       pol)
    with set_mesh(mesh):
        jfn = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                      donate_argnums=donate)
        lowered = jfn.lower(*arg_shapes)
    t_lower = time.perf_counter() - t0

    t0 = time.perf_counter()
    compiled = lowered.compile()
    t_compile = time.perf_counter() - t0

    # --- analyses ---
    mem = {}
    try:
        ma = compiled.memory_analysis()
        for k in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "alias_size_in_bytes",
                  "generated_code_size_in_bytes"):
            mem[k] = getattr(ma, k, None)
    except Exception as e:   # pragma: no cover
        mem["error"] = repr(e)

    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}

    # loop-aware cost model (XLA's cost_analysis counts scan bodies ONCE —
    # flops/bytes/collectives would be ~num_layers x under-reported)
    hlo = compiled.as_text()
    acc = analyze_hlo(hlo, chips)
    flops_dev = acc["flops"]
    bytes_dev = acc["bytes"]
    link_bytes_dev = acc["link_bytes"]
    by_kind = acc["coll"]

    mf, n_total = _model_flops(cfg, sp)
    terms = roofline_terms(flops_dev * chips, bytes_dev * chips,
                           link_bytes_dev, chips, model_flops=mf)

    record = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name, "chips": chips,
        "policy": pol, "kind": sp.kind,
        "params_total": n_total, "params_active": active_param_count(cfg),
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "flops_per_dev": flops_dev, "bytes_per_dev": bytes_dev,
        "collective_link_bytes_per_dev": link_bytes_dev,
        "xla_cost_analysis_flops_per_dev": float(cost.get("flops", 0.0)),
        "xla_cost_analysis_bytes_per_dev": float(cost.get("bytes accessed", 0.0)),
        "collectives": by_kind,
        "memory_analysis": mem,
        "roofline": terms,
    }
    os.makedirs(out_dir, exist_ok=True)
    tag = f"{arch}__{shape_name}__{mesh_name}__{pol}"
    with open(os.path.join(out_dir, tag + ".json"), "w") as f:
        json.dump(record, f, indent=1)
    if save_hlo:
        with open(os.path.join(out_dir, tag + ".hlo.txt"), "w") as f:
            f.write(hlo)
    print(f"[ok] {tag}: compile {t_compile:.1f}s  "
          f"flops/dev {flops_dev:.3e}  bytes/dev {bytes_dev:.3e}  "
          f"link B/dev {link_bytes_dev:.3e}  dominant {terms['dominant']}")
    return record


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--policy", default="int4",
                    help="serve-cell quantization spec — alias or grammar "
                         "string, e.g. int4 / w4a8kv8 (train cells use "
                         "bf16)")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--moe-groups", type=int, default=0,
                    help="override MoE dispatch groups (1 = global dispatch)")
    args = ap.parse_args()

    cells = []
    if args.all:
        for arch in ASSIGNED:
            for shp in supported_shapes(get_config(arch)):
                cells.append((arch, shp))
    else:
        if not args.arch or not args.shape:
            ap.error("--arch and --shape required (or --all)")
        cells = [(args.arch, args.shape)]

    failures = []
    for arch, shp in cells:
        try:
            run_cell(arch, shp, multi_pod=args.multi_pod,
                     policy=args.policy, out_dir=args.out,
                     save_hlo=args.save_hlo, moe_groups=args.moe_groups)
        except Exception:
            failures.append((arch, shp))
            print(f"[FAIL] {arch} x {shp}")
            traceback.print_exc()
    if failures:
        raise SystemExit(f"{len(failures)} cell(s) failed: {failures}")


if __name__ == "__main__":
    main()
