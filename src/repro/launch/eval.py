"""Quality-evaluation launcher: the paper's experimental grid, end to end.

Train the synthetic many-to-many task to convergence (train/loop.py),
deploy the checkpoint at every requested precision preset, run the
bidirectional pair matrix through the serving engine per format, and
write the JSON + markdown quality report:

  PYTHONPATH=src python -m repro.launch.eval --smoke \
      --formats bf16,int8,int4 --pairs hin-eng,eng-hin --json out.json

Mirrors launch/serve.py's knobs (--paged/--horizon/--impl pass straight
into deploy), plus --train-steps for the convergence fit — without it
the smoke default (1500 steps, ~1 min on a laptop CPU) drives the
reduced NLLB to BLEU ~1.0 on the held-out split, so per-format deltas
measure quantization, not an untrained model.

When both ``bf16`` and ``int8`` are requested, the run asserts the
paper's parity claim: int8 mean BLEU within ``--parity-tol`` of the
bf16 anchor (exit 1 otherwise — CI's eval-smoke job runs exactly this).
"""

from __future__ import annotations

import argparse
import tempfile
import time

import jax
import jax.numpy as jnp

from ..configs import REGISTRY, get_config, reduce_config
from ..core import ALIASES, resolve_spec
from ..data import LANG_CODES, SyntheticTranslation, pairs as fig9_pairs
from ..eval import make_report, quant_sweep, render_markdown, save
from ..eval.suite import _ordered_langs
from ..models import Ctx, build_model
from ..optim import warmup_cosine
from ..serving import IMPL_CHOICES, impl_routes
from ..train import TrainLoop, make_train_step


def parse_pairs(text: str):
    """'hin-eng,eng-hin' -> [('hin', 'eng'), ('eng', 'hin')]."""
    out = []
    for chunk in text.split(","):
        parts = chunk.strip().split("-")
        if len(parts) != 2 or not all(parts):
            raise argparse.ArgumentTypeError(
                f"bad pair {chunk!r}; expected src-tgt like hin-eng")
        out.append((parts[0], parts[1]))
    return out


def _pow2_at_least(n: int) -> int:
    b = 1
    while b < n:
        b *= 2
    return b


def train_params(cfg, langs, *, steps: int, batch: int, lr: float,
                 seed: int, log=print):
    """Fit the synthetic task (train split) via the production TrainLoop."""
    model = build_model(cfg)
    ctx = Ctx(compute_dtype=jnp.float32)
    ds = SyntheticTranslation(cfg.vocab_size, cfg.enc_len, seed=seed,
                              languages=langs)

    def batches():
        while True:
            b = ds.sample(batch)
            yield {k: jnp.asarray(v) for k, v in b.items()
                   if not isinstance(v, str)}

    init_state, step = make_train_step(
        model, lr_fn=lambda s: warmup_cosine(s, peak_lr=lr, warmup=20,
                                             total=steps), ctx=ctx)
    loop = TrainLoop(jax.jit(step, donate_argnums=0),
                     tempfile.mkdtemp(prefix="repro_eval_ckpt_"),
                     ckpt_every=0, log_every=max(steps // 5, 1), log_fn=log)
    state = init_state(model.init(jax.random.PRNGKey(seed)))
    state, history = loop.run(state, batches(), steps)
    log(f"[train] {len(history)} steps, loss {history[0]:.4f} -> "
        f"{history[-1]:.4f}")
    return state["params"]


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--model", "--arch", dest="model", default="nllb600m",
                    choices=sorted(REGISTRY))
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config, f32 compute (CPU-runnable)")
    ap.add_argument("--formats", default="bf16,int8,int4",
                    help="comma list of quantization specs: aliases "
                         f"({', '.join(sorted(ALIASES))}) and/or grammar "
                         "strings like w4a8kv8 / wfp8e4m3afp8kvfp8")
    ap.add_argument("--pairs", type=parse_pairs, default=None,
                    help="comma list of src-tgt directions (hin-eng,eng-hin);"
                         " default: --smoke 2 directions, else the full "
                         "bidirectional Indic<->overseas Fig. 9 grid")
    ap.add_argument("--n-sent", type=int, default=8,
                    help="held-out sentences per direction")
    ap.add_argument("--train-steps", type=int, default=None,
                    help="convergence-fit steps before evaluating "
                         "(default: 1500 under --smoke, else 0 = skip; "
                         "0 evaluates the random init — floor scores)")
    ap.add_argument("--train-batch", type=int, default=32)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--seed", type=int, default=0)
    # serving knobs, mirrored from launch.serve
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=0,
                    help="engine decode budget; 0 = smallest power of two "
                         "covering lang-code prompt + reference length")
    ap.add_argument("--paged", action="store_true")
    ap.add_argument("--page-size", type=int, default=8)
    ap.add_argument("--num-pages", type=int, default=None)
    ap.add_argument("--horizon", type=int, default=1)
    ap.add_argument("--draft-spec", default=None, metavar="SPEC",
                    help="speculative-decoding draft arm for every "
                         "deployed format (same alias/grammar as "
                         "--formats); grids are token-identical by the "
                         "greedy-equivalence invariant, pair rows gain "
                         "an acceptance_rate column")
    ap.add_argument("--draft-lookahead", type=int, default=4)
    ap.add_argument("--no-overlap", action="store_true",
                    help="serve the grid with serial dispatch-then-walk "
                         "rounds (default: overlapped scheduler; grids "
                         "are token-identical either way)")
    ap.add_argument("--trace", action="store_true",
                    help="serve the grid with lifecycle tracing on: each "
                         "report row gains its round_phases column — "
                         "where serving time went, per scheduler phase "
                         "(grids are token-identical either way)")
    ap.add_argument("--impl", choices=IMPL_CHOICES, default="xla")
    ap.add_argument("--calib-batches", type=int, default=4,
                    help="calibration batches for act-quantizing presets "
                         "(w8a8); 0 = dynamic per-token act quantization")
    # artifacts + gating
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write the round-trip-guaranteed report JSON")
    ap.add_argument("--markdown", default=None, metavar="PATH",
                    help="also write the rendered markdown report")
    ap.add_argument("--parity-tol", type=float, default=0.1,
                    help="max allowed bf16->int8 mean-BLEU drop when both "
                         "formats run (negative disables the check)")
    args = ap.parse_args(argv)

    formats = [f.strip() for f in args.formats.split(",") if f.strip()]
    # fail on argument typos BEFORE the multi-minute training fit
    for f in formats:
        try:
            resolve_spec(f)
        except ValueError as e:
            raise SystemExit(f"bad --formats entry: {e}")
    if args.draft_spec is not None:
        try:
            resolve_spec(args.draft_spec)
        except ValueError as e:
            raise SystemExit(f"bad --draft-spec: {e}")
    pair_list = args.pairs if args.pairs is not None else (
        [("hin", "eng"), ("eng", "hin")] if args.smoke else fig9_pairs())
    bad = sorted({lang for p in pair_list for lang in p
                  if lang not in LANG_CODES})
    if bad:
        raise SystemExit(f"unknown languages {bad} in --pairs; "
                         f"have {sorted(LANG_CODES)}")
    same = [f"{s}-{t}" for s, t in pair_list if s == t]
    if same:
        raise SystemExit(f"--pairs needs two distinct languages, got {same}")
    langs = _ordered_langs(pair_list)
    cfg = get_config(args.model)
    if args.smoke:
        cfg = reduce_config(cfg)
    if cfg.family != "encdec":
        raise SystemExit(f"--model {args.model} is family {cfg.family!r}; "
                         "quality eval needs an enc-dec NMT model")
    train_steps = args.train_steps if args.train_steps is not None \
        else (1500 if args.smoke else 0)

    t0 = time.perf_counter()
    if train_steps > 0:
        params = train_params(cfg, langs, steps=train_steps,
                              batch=args.train_batch, lr=args.lr,
                              seed=args.seed)
    else:
        print("[train] skipped (--train-steps 0): evaluating the random "
              "init — scores are the task floor, not the paper's grid")
        params = build_model(cfg).init(jax.random.PRNGKey(args.seed))

    def calib_batches_fn():
        ds = SyntheticTranslation(cfg.vocab_size, cfg.enc_len,
                                  seed=args.seed, languages=langs)
        return ({k: jnp.asarray(v) for k, v in ds.sample(16).items()
                 if not isinstance(v, str)}
                for _ in range(args.calib_batches))

    gen = cfg.enc_len - 2
    max_len = args.max_len or _pow2_at_least(gen + 1)
    deploy_kwargs = dict(
        slots=args.slots, max_len=max_len, paged=args.paged,
        page_size=args.page_size, num_pages=args.num_pages,
        horizon=args.horizon, draft_spec=args.draft_spec,
        draft_lookahead=args.draft_lookahead, overlap=not args.no_overlap,
        ctx=Ctx(compute_dtype=jnp.float32 if args.smoke else jnp.bfloat16),
        **impl_routes(args.impl))
    rows = quant_sweep(
        cfg, formats, params=params, pair_list=pair_list, languages=langs,
        n_sent=args.n_sent, seed=args.seed,
        calib_batches_fn=calib_batches_fn if args.calib_batches else None,
        deploy_kwargs=deploy_kwargs, trace=args.trace)
    dt = time.perf_counter() - t0

    report = make_report(
        arch=cfg.name,
        rows=[r.as_row() for r in rows],
        config={"formats": formats,
                "pairs": [f"{s}-{t}" for s, t in pair_list],
                "n_sent": args.n_sent, "seed": args.seed,
                "train_steps": train_steps, "train_batch": args.train_batch,
                "lr": args.lr, "slots": args.slots, "max_len": max_len,
                "paged": args.paged, "horizon": args.horizon,
                "overlap": not args.no_overlap,
                "draft_spec": args.draft_spec,
                "draft_lookahead": args.draft_lookahead,
                "impl": args.impl, "calib_batches": args.calib_batches,
                "trace": args.trace,
                "smoke": args.smoke, "wall_s": round(dt, 1)})
    print()
    print(render_markdown(report))
    if args.json:
        save(report, args.json)
        print(f"[report] wrote {args.json}")
    if args.markdown:
        with open(args.markdown, "w") as f:
            f.write(render_markdown(report) + "\n")
        print(f"[report] wrote {args.markdown}")

    by_fmt = {r.fmt: r for r in rows}
    if args.parity_tol >= 0 and "bf16" in by_fmt and "int8" in by_fmt:
        drop = by_fmt["bf16"].mean_bleu - by_fmt["int8"].mean_bleu
        if drop > args.parity_tol:
            raise SystemExit(
                f"quality parity violated: int8 mean BLEU "
                f"{by_fmt['int8'].mean_bleu:.4f} is {drop:.4f} below bf16 "
                f"{by_fmt['bf16'].mean_bleu:.4f} (tol {args.parity_tol}) — "
                "the paper's sub-octet parity claim does not hold here")
        print(f"[parity] int8 within {drop:.4f} BLEU of bf16 "
              f"(tol {args.parity_tol}): OK")


if __name__ == "__main__":
    main()
