"""Serving launcher (the paper's deployment mode: quantized NMT inference).

One deploy() call builds the quantized pipeline; the scheduler-owned
engine handles admission and slot scheduling internally — the launcher
submits requests and streams outputs as each finishes (the overlapped
scheduler dispatches horizon N+1 while the host walks horizon N;
``--no-overlap`` restores serial dispatch-then-walk, and
``--sla-ttft-ms``/``--sla-tpot-ms`` attach the percentile-feedback
admission controller).

Failure handling is first-class: ``--max-pending`` bounds the queue
(the submit loop retries with backoff on the typed EngineSaturated),
``--deadline-ms`` gives every request a wall-clock budget, and the
shutdown line reports the engine's fault counters (preemptions,
deadline expirations, admission rejections, slot errors). Every
shutdown number is read from ONE frozen ``engine.metrics()`` snapshot,
so the printed summary cannot drift from what benchmarks record.

Observability: ``--trace-out FILE`` serves with lifecycle + round-phase
tracing enabled and dumps Chrome/Perfetto ``trace_event`` JSON at
shutdown (open in chrome://tracing or ui.perfetto.dev);
``--metrics-out FILE`` writes the Prometheus text exposition of the
final metrics snapshot + latency histograms; ``--metrics-port N``
additionally serves the LIVE exposition at ``GET /metrics`` on a
stdlib daemon thread for the whole run.

Scale-out: ``--mesh dp2,tp2`` deploys 2 router-balanced engine
replicas, each tensor-parallel over its own 2-device ``("model",)``
mesh (``repro.cluster``) — routed/sharded streams stay token-identical
to a single-device engine. On CPU force devices with
``XLA_FLAGS=--xla_force_host_platform_device_count=8``.

  PYTHONPATH=src python -m repro.launch.serve --arch nllb600m --smoke \
      --policy int4 --requests 6 --gen 8 --temperature 0.7 --top-p 0.9
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from ..cluster import deploy_replicas, parse_mesh_spec, tp_mesh
from ..configs import REGISTRY
from ..core import ALIASES, resolve_spec
from ..data import SyntheticTranslation
from ..obs import MetricsServer
from ..serving import (IMPL_CHOICES, EngineSaturated, SamplingParams,
                       SLATarget, TraceConfig, deploy, impl_routes)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="nllb600m", choices=sorted(REGISTRY))
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--policy", default="int4", metavar="SPEC",
                    help="quantization spec: an alias "
                         f"({', '.join(sorted(ALIASES))}) or a grammar "
                         "string like w4a8kv8 / wfp8e4m3afp8kvfp8")
    ap.add_argument("--draft-spec", default=None, metavar="SPEC",
                    help="speculative-decoding draft arm: the same "
                         "checkpoint quantized at this spec drafts "
                         "tokens the target verifies (greedy output is "
                         "unchanged, same alias/grammar as --policy)")
    ap.add_argument("--draft-lookahead", type=int, default=4,
                    help="tokens drafted per speculative verify round")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--gen", type=int, default=8)
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--paged", action="store_true",
                    help="block-paged KV cache + batched prefill admission")
    ap.add_argument("--page-size", type=int, default=8)
    ap.add_argument("--num-pages", type=int, default=None)
    ap.add_argument("--horizon", type=int, default=1,
                    help="decode steps fused on-device per host sync "
                         "(1 = per-token dispatch; K trades admission "
                         "latency for 1/K the host syncs)")
    ap.add_argument("--impl", choices=IMPL_CHOICES, default="xla",
                    help="kernel route: pallas = Pallas qmm + Pallas "
                         "paged attention")
    ap.add_argument("--no-overlap", action="store_true",
                    help="serial dispatch-then-walk rounds instead of "
                         "dispatching horizon N+1 while walking N")
    ap.add_argument("--sla-ttft-ms", type=float, default=None, metavar="T",
                    help="p95 time-to-first-token target: the engine "
                         "auto-tunes horizon and prefill admission "
                         "against measured percentiles")
    ap.add_argument("--sla-tpot-ms", type=float, default=None, metavar="T",
                    help="p95 per-output-token target (see --sla-ttft-ms)")
    ap.add_argument("--max-pending", type=int, default=None, metavar="N",
                    help="bounded admission queue: submit() raises the "
                         "typed EngineSaturated past N pending requests "
                         "(the launcher retries with backoff)")
    ap.add_argument("--deadline-ms", type=float, default=None, metavar="T",
                    help="per-request wall-clock budget from submit; an "
                         "expired request retires with finish_reason "
                         "'deadline' and its partial tokens")
    ap.add_argument("--trace-out", default=None, metavar="FILE",
                    help="enable lifecycle/round-phase tracing and dump "
                         "Chrome/Perfetto trace_event JSON here at "
                         "shutdown (open in chrome://tracing)")
    ap.add_argument("--metrics-out", default=None, metavar="FILE",
                    help="write the final metrics snapshot + latency "
                         "histograms as Prometheus text exposition")
    ap.add_argument("--metrics-port", type=int, default=None, metavar="N",
                    help="serve the live Prometheus exposition at "
                         "http://127.0.0.1:N/metrics for the whole run "
                         "(stdlib http.server daemon thread; 0 = "
                         "ephemeral port, printed at startup)")
    ap.add_argument("--mesh", default=None, metavar="SPEC",
                    help="scale-out spec 'dp<N>,tp<K>' (either factor "
                         "optional): N router-balanced replicas, each "
                         "tensor-parallel over K devices; e.g. "
                         "--mesh dp2,tp2 wants 4 devices (on CPU set "
                         "XLA_FLAGS=--xla_force_host_platform_"
                         "device_count=4)")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--top-k", type=int, default=0)
    ap.add_argument("--top-p", type=float, default=1.0)
    ap.add_argument("--eos-id", type=int, default=None)
    args = ap.parse_args()

    resolve_spec(args.policy)        # fail on typos before any build work
    if args.draft_spec is not None:
        resolve_spec(args.draft_spec)   # same early failure as --policy
    sla = None
    if args.sla_ttft_ms is not None or args.sla_tpot_ms is not None:
        sla = SLATarget(p95_ttft_ms=args.sla_ttft_ms,
                        p95_tpot_ms=args.sla_tpot_ms,
                        window=max(args.requests // 2, 1))
    dp, tp = parse_mesh_spec(args.mesh) if args.mesh else (1, 1)
    deploy_kwargs = dict(
        slots=args.slots, max_len=args.max_len, smoke=args.smoke,
        paged=args.paged, page_size=args.page_size,
        num_pages=args.num_pages, horizon=args.horizon,
        draft_spec=args.draft_spec, draft_lookahead=args.draft_lookahead,
        overlap=not args.no_overlap, sla=sla,
        max_pending=args.max_pending,
        trace=TraceConfig() if args.trace_out else None,
        **impl_routes(args.impl))
    if dp > 1:
        pipe = deploy_replicas(args.arch, args.policy, replicas=dp, tp=tp,
                               **deploy_kwargs)
        print(f"cluster: {dp} replicas x tp{tp} over "
              f"{len(jax.devices())} devices")
    else:
        pipe = deploy(args.arch, args.policy,
                      mesh=tp_mesh(tp) if tp > 1 else None, **deploy_kwargs)
        if tp > 1:
            print(f"tensor parallel: tp{tp} ('model',) mesh")
    print(f"model bytes {pipe.fp_bytes/2**20:.1f} MB -> "
          f"{pipe.quantized_bytes/2**20:.1f} MB "
          f"({args.policy} = {pipe.spec_str}, {pipe.compression:.2f}x)")
    if args.draft_spec is not None:
        print(f"speculative draft arm: {args.draft_spec} = "
              f"{pipe.draft_spec_str}, lookahead {args.draft_lookahead}")

    cfg = pipe.cfg
    # sources up to the engine's cross capacity (default enc_len) are
    # admitted; the decoder budget (1-token lang-code prompt + gen) is
    # independent
    ds = SyntheticTranslation(cfg.vocab_size, cfg.enc_len,
                              seed=0) if cfg.family in ("encdec",) else None

    metrics_srv = None
    if args.metrics_port is not None:
        # live scrape endpoint for the whole run; closed gracefully
        # (socket unbound, thread joined) after the shutdown summary
        metrics_srv = MetricsServer(pipe.engine.prometheus,
                                    port=args.metrics_port).start()
        print(f"metrics: live at {metrics_srv.url}")

    t0 = time.perf_counter()
    for i in range(args.requests):
        sp = SamplingParams(temperature=args.temperature, top_k=args.top_k,
                            top_p=args.top_p, eos_id=args.eos_id,
                            max_new_tokens=args.gen, seed=i,
                            deadline_ms=args.deadline_ms)
        if ds is not None:
            b = ds.sample(1)
            req = {"src_tokens": jnp.asarray(b["src_tokens"]),
                   "tgt_in": jnp.asarray(b["tgt_in"][:, :1])}
        else:
            # vary prompt lengths: bucketing keeps compiles bounded
            plen = 4 + (i % 4)
            req = {"tokens": jax.random.randint(
                jax.random.PRNGKey(i), (1, plen), 0, cfg.vocab_size)}
        # backpressure loop: a saturated queue is a typed signal, not a
        # crash — drain one scheduler round and retry with backoff
        backoff = 0.01
        while True:
            try:
                rid = pipe.engine.submit(req, sp)
                break
            except EngineSaturated as exc:
                print(f"saturated ({exc.pending}/{exc.limit} pending), "
                      f"stepping + retrying in {backoff*1e3:.0f} ms")
                pipe.engine.step()
                time.sleep(backoff)
                backoff = min(backoff * 2, 0.5)
        print(f"[req {rid}] queued (pending={pipe.engine.num_pending}, "
              f"active={pipe.engine.num_active})")

    # outputs stream back as each request finishes, not at the drain
    outs = []
    for o in pipe.engine.stream():
        outs.append(o)
        print(f"[req {o.request_id}] slot {o.slot} {o.finish_reason:6s} "
              f"ttft {o.ttft_ms:6.1f} ms tpot {o.tpot_ms:5.2f} ms: "
              f"{o.token_ids}")
    dt = time.perf_counter() - t0
    done_tokens = sum(o.num_generated for o in outs)
    m = pipe.engine.metrics()
    line = (f"served {args.requests} requests, {done_tokens} tokens in "
            f"{dt:.2f}s ({done_tokens/dt:.1f} tok/s host, "
            f"{m.prefill_compiles} prefill compiles, "
            f"{m.decode_syncs} decode syncs @ "
            f"{m.mean_tokens_per_sync:.1f} tok/sync, "
            f"{m.overlap_rounds} overlapped rounds, "
            f"occupancy {m.occupancy:.2f}")
    if args.paged:
        line += (f", page util {m.page_utilization:.2f}, "
                 f"kv {m.kv_cache_bytes/2**20:.2f} MB")
    if args.draft_spec is not None:
        line += (f", acceptance {m.acceptance_rate:.2f} "
                 f"({m.accepted_tokens}/{m.drafted_tokens} drafted, "
                 f"{m.verify_calls} verify rounds)")
    print(line + ")")
    # latency summary from the SAME frozen snapshot (histogram-backed
    # nearest-rank percentiles over bucket upper edges)
    print(f"latency: ttft p50/p95 {m.ttft_p50_ms:.1f}/{m.ttft_p95_ms:.1f} "
          f"ms, tpot p50/p95 {m.tpot_p50_ms:.2f}/{m.tpot_p95_ms:.2f} ms")
    # shutdown fault summary: zero across the board on a healthy run
    print(f"faults: {m.preemptions} preemptions "
          f"({m.resumed_requests} resumed), "
          f"{m.deadline_expirations} deadline expirations, "
          f"{m.admission_rejections} admission rejections, "
          f"{m.slot_errors} slot errors")
    if args.trace_out:
        print(f"phases: admit {m.phase_admit_ms:.1f} ms, dispatch "
              f"{m.phase_dispatch_ms:.1f} ms, sync {m.phase_sync_ms:.1f} "
              f"ms, walk {m.phase_walk_ms:.1f} ms")
        pipe.tracer.dump_json(args.trace_out)
        print(f"trace: {len(pipe.tracer)} events "
              f"({pipe.tracer.dropped} dropped) -> {args.trace_out}")
    if args.metrics_out:
        with open(args.metrics_out, "w") as f:
            f.write(pipe.engine.prometheus())
        print(f"metrics: prometheus text -> {args.metrics_out}")
    if metrics_srv is not None:
        metrics_srv.close()
        print("metrics: endpoint closed")
    if getattr(pipe.engine, "sla", None) is not None:
        ctl = pipe.engine.sla
        held = ctl.holding()
        print(f"sla: target ttft_p95 {args.sla_ttft_ms} ms / tpot_p95 "
              f"{args.sla_tpot_ms} ms -> horizon {ctl.horizon}, "
              f"prefill cap {ctl.prefill_cap}, {ctl.retunes} retunes, "
              f"held={'n/a' if held is None else held}")


if __name__ == "__main__":
    main()
