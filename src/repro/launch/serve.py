"""Serving launcher (the paper's deployment mode: quantized NMT inference).

  PYTHONPATH=src python -m repro.launch.serve --arch nllb600m --smoke \
      --policy int4 --requests 6 --gen 8
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import REGISTRY, get_config, reduce_config
from ..core import PRESETS, quantize_tree, tree_nbytes
from ..data import SyntheticTranslation
from ..models import Ctx, build_model
from ..serving import ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="nllb600m", choices=sorted(REGISTRY))
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--policy", default="int4", choices=sorted(PRESETS))
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--gen", type=int, default=8)
    ap.add_argument("--max-len", type=int, default=64)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = reduce_config(cfg)
    model = build_model(cfg)
    ctx = Ctx(compute_dtype=jnp.float32 if args.smoke else jnp.bfloat16)
    params = model.init(jax.random.PRNGKey(0))
    base = tree_nbytes(params)
    if args.policy not in ("f32",):
        params = quantize_tree(params, PRESETS[args.policy])
    print(f"model bytes {base/2**20:.1f} MB -> {tree_nbytes(params)/2**20:.1f}"
          f" MB ({args.policy}, {base/max(tree_nbytes(params),1):.2f}x)")

    kv = PRESETS[args.policy].kv_cache
    eng = ServeEngine(model, params, slots=args.slots, max_len=args.max_len,
                      kv_dtype=kv, ctx=ctx)
    ds = SyntheticTranslation(cfg.vocab_size, min(16, args.max_len - args.gen),
                              seed=0) if cfg.family in ("encdec",) else None

    pending = args.requests
    done_tokens = 0
    t0 = time.perf_counter()
    results = {}
    while pending > 0 or any(s.active for s in eng.slots):
        while pending > 0 and eng.free_slot() is not None:
            if ds is not None:
                b = ds.sample(1)
                req = {"src_tokens": jnp.asarray(b["src_tokens"]),
                       "tgt_in": jnp.asarray(b["tgt_in"][:, :1])}
            else:
                req = {"tokens": jax.random.randint(
                    jax.random.PRNGKey(pending), (1, 8), 0, cfg.vocab_size)}
            slot = eng.add_request(req, gen_tokens=args.gen)
            print(f"[req {pending}] -> slot {slot}")
            pending -= 1
        for slot in eng.tick():
            results[slot] = eng.result(slot)
            done_tokens += len(results[slot])
            print(f"[slot {slot}] done: {results[slot]}")
    dt = time.perf_counter() - t0
    print(f"served {args.requests} requests, {done_tokens} tokens in "
          f"{dt:.2f}s ({done_tokens/dt:.1f} tok/s host)")


if __name__ == "__main__":
    main()
