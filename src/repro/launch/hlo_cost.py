"""Loop-aware cost model over optimized HLO text.

XLA's HloCostAnalysis counts a while body ONCE, so scan-over-layers
programs under-report flops/bytes/collectives by ~num_layers. This module
re-derives the three roofline inputs with trip-count multiplication:

  flops        — 2 * prod(result_dims) * prod(contracting_dims) per dot
                 (incl. dots inside fusion subcomputations);
  hbm bytes    — per top-level instruction: operands + result (resolved
                 through a per-computation symbol table, since optimized
                 HLO does not print operand shapes inline), with in-place
                 ops (dynamic-update-slice) counted at update size and
                 fusion-internal traffic excluded;
  collectives  — ring-model link bytes per device, multiplied by
                 enclosing loop trip counts.

Trip counts come from backend_config known_trip_count on each while op
(present in XLA optimized HLO for lax.scan loops).
"""

from __future__ import annotations

import re
from typing import Dict, List, Tuple

__all__ = ["analyze_hlo"]

_DTYPE_BYTES = {
    "f64": 8, "s64": 8, "u64": 8, "c64": 8, "c128": 16,
    "f32": 4, "s32": 4, "u32": 4,
    "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e4m3b11fnuz": 1,
    "s8": 1, "u8": 1, "pred": 1,
    "s4": 0.5, "u4": 0.5, "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"\b([a-z]\d*[a-z0-9]*)\[([\d,]*)\]")
_CALLS_RE = re.compile(r"(?:calls|body|to_apply)=%?([\w.\-]+)")
_TRIP_RE = re.compile(
    r"known_trip_count[\"']?\s*:\s*\{\s*[\"']n[\"']\s*:\s*[\"']?(\d+)")
_LHS_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_GROUPS_RE = re.compile(r"replica_groups=\{([^}]*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_INST_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*)$")
_ARG_RE = re.compile(r"%([\w.\-]+)")

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
_SKIP_BYTES = {"parameter", "constant", "tuple", "get-tuple-element",
               "bitcast", "after-all", "partition-id", "replica-id", "iota",
               "copy-start", "copy-done"}


def _shape_list(text: str) -> List[Tuple[str, str]]:
    return _SHAPE_RE.findall(text)


def _nbytes_one(dtype: str, dims: str) -> float:
    b = _DTYPE_BYTES.get(dtype)
    if b is None:
        return 0.0
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * b


def _nbytes(shapes: List[Tuple[str, str]]) -> float:
    return sum(_nbytes_one(d, s) for d, s in shapes)


def _split_computations(text: str) -> Dict[str, list]:
    comps: Dict[str, list] = {}
    cur = None
    for line in text.splitlines():
        s = line.rstrip()
        is_inst = re.match(r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s", s)
        m = re.match(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*(?:\([^)]*\))?.*\{\s*$", s)
        if m and not is_inst and not s.lstrip().startswith("//"):
            cur = m.group(1)
            comps[cur] = []
            continue
        if s.strip() == "}":
            cur = None
            continue
        if cur is not None and s.strip():
            comps[cur].append(s.strip())
    return comps


def _op_kind(rhs: str) -> str:
    # result shapes, then "opname(". tuples allowed: (f32[..], s8[..]) op(
    m = re.search(r"(?:^|\)|\}|\s)([a-z][\w\-]*)\(", rhs)
    return m.group(1) if m else "unknown"


def _result_shapes(rhs: str) -> List[Tuple[str, str]]:
    paren = rhs.find("(")
    # tuple results start with '(': find the op name position instead
    m = re.search(r"([a-z][\w\-]*)\(", rhs)
    if not m:
        return _shape_list(rhs[:paren] if paren > 0 else rhs)
    return _shape_list(rhs[:m.start(1)])


def _arg_names(rhs: str) -> List[str]:
    m = re.search(r"([a-z][\w\-]*)\(", rhs)
    if not m:
        return []
    start = m.end()
    depth = 1
    i = start
    while i < len(rhs) and depth:
        if rhs[i] == "(":
            depth += 1
        elif rhs[i] == ")":
            depth -= 1
        i += 1
    return _ARG_RE.findall(rhs[start:i - 1])


def _group_size(rhs: str, total: int) -> int:
    m = _GROUPS_IOTA_RE.search(rhs)
    if m:
        return max(int(m.group(2)), 1)
    m = _GROUPS_RE.search(rhs)
    if m:
        first = m.group(1).split("}")[0]
        return max(len([x for x in first.split(",") if x.strip()]), 1)
    return total


def analyze_hlo(text: str, total_devices: int) -> dict:
    comps = _split_computations(text)
    entry_m = re.search(r"ENTRY\s+%?([\w.\-]+)", text)
    entry = entry_m.group(1) if entry_m else None
    cache: Dict[str, dict] = {}

    # per-computation symbol tables: instruction name -> result shapes
    tables: Dict[str, Dict[str, List[Tuple[str, str]]]] = {}
    for cname, lines in comps.items():
        t: Dict[str, List[Tuple[str, str]]] = {}
        for line in lines:
            m = _INST_RE.match(line)
            if m:
                t[m.group(1)] = _result_shapes(m.group(2))
        tables[cname] = t

    def op_bytes(cname: str, kind: str, rhs: str, inst_name: str = "") -> float:
        res = _nbytes(_result_shapes(rhs))
        if kind == "dynamic-update-slice":
            args = _arg_names(rhs)
            upd = (_nbytes(tables[cname].get(args[1], []))
                   if len(args) > 1 else 0.0)
            return 2 * upd
        if kind in ("dynamic-slice", "gather"):
            return 2 * res
        op_sizes = [_nbytes(tables[cname].get(a, []))
                    for a in _arg_names(rhs)]
        if kind == "fusion" and re.search(
                r"(dynamic-update-slice|scatter)", inst_name):
            # in-place update fused with its buffer: the big operand is
            # aliased with the result — real traffic is the update region
            # (~= remaining operands) read + written, not the whole buffer
            big = max(op_sizes, default=0.0)
            rest = sum(op_sizes) - big
            return 2 * rest
        return res + sum(op_sizes)

    def dot_flops(cname: str, rhs: str) -> float:
        res_n = 1
        shapes = _result_shapes(rhs)
        if not shapes:
            return 0.0
        for d in shapes[0][1].split(","):
            if d:
                res_n *= int(d)
        args = _arg_names(rhs)
        if not args:
            return 0.0
        lhs_shapes = tables[cname].get(args[0], [])
        if not lhs_shapes:
            return 0.0
        lhs_dims = [int(d) for d in lhs_shapes[0][1].split(",") if d]
        k = 1
        m = _LHS_CONTRACT_RE.search(rhs)
        if m:
            for idx in m.group(1).split(","):
                if idx:
                    k *= lhs_dims[int(idx)]
        return 2.0 * res_n * k

    def coll_link_bytes(kind: str, rhs: str) -> float:
        r = _nbytes(_result_shapes(rhs))
        g = _group_size(rhs, total_devices)
        if kind == "all-gather":
            return r * (g - 1) / g
        if kind == "reduce-scatter":
            return r * (g - 1)
        if kind == "all-reduce":
            return 2 * r * (g - 1) / g
        if kind == "all-to-all":
            return r * (g - 1) / g
        return r

    def cost(name: str) -> dict:
        if name in cache:
            return cache[name]
        cache[name] = {"flops": 0.0, "bytes": 0.0, "link_bytes": 0.0,
                       "coll": {}}  # cycle guard
        total = {"flops": 0.0, "bytes": 0.0, "link_bytes": 0.0, "coll": {}}

        def add_sub(sub, trip=1, with_bytes=True):
            total["flops"] += trip * sub["flops"]
            total["link_bytes"] += trip * sub["link_bytes"]
            if with_bytes:
                total["bytes"] += trip * sub["bytes"]
            for ck, cv in sub["coll"].items():
                d = total["coll"].setdefault(ck, {"count": 0,
                                                  "link_bytes": 0.0})
                d["count"] += trip * cv["count"]
                d["link_bytes"] += trip * cv["link_bytes"]

        for line in comps.get(name, ()):
            m = _INST_RE.match(line)
            if not m:
                continue
            inst_name = m.group(1)
            rhs = m.group(2)
            kind = _op_kind(rhs)
            if kind == "while":
                bm = _CALLS_RE.search(rhs)
                tm = _TRIP_RE.search(rhs)
                trip = int(tm.group(1)) if tm else 1
                if bm and bm.group(1) in comps:
                    add_sub(cost(bm.group(1)), trip)
                continue
            if kind in ("conditional",):
                for cn in re.findall(r"(?:true_computation|false_computation|"
                                     r"branch_computations)=\{?%?([\w.\-]+)",
                                     rhs):
                    if cn in comps:
                        add_sub(cost(cn))
                continue
            if kind == "call":
                bm = _CALLS_RE.search(rhs)
                if bm and bm.group(1) in comps:
                    add_sub(cost(bm.group(1)))
                continue
            if kind in ("fusion", "map", "reduce", "reduce-window", "sort",
                        "scatter", "select-and-scatter"):
                bm = _CALLS_RE.search(rhs)
                if bm and bm.group(1) in comps:
                    # flops (+ any collectives) from inside; bytes from the
                    # callsite boundary only (internal traffic stays in regs)
                    sub = cost(bm.group(1))
                    add_sub({"flops": sub["flops"], "bytes": 0.0,
                             "link_bytes": sub["link_bytes"],
                             "coll": sub["coll"]})
                total["bytes"] += op_bytes(name, kind, rhs, inst_name)
                continue
            cname_coll = next(
                (c for c in _COLLECTIVES
                 if rhs.startswith(f"{c}(") or rhs.startswith(f"{c}-start(")
                 or f" {c}(" in rhs or f" {c}-start(" in rhs), None)
            if cname_coll and "-done(" not in rhs:
                lb = coll_link_bytes(cname_coll, rhs)
                total["link_bytes"] += lb
                d = total["coll"].setdefault(cname_coll,
                                             {"count": 0, "link_bytes": 0.0})
                d["count"] += 1
                d["link_bytes"] += lb
                total["bytes"] += _nbytes(_result_shapes(rhs))
                continue
            if kind == "dot":
                total["flops"] += dot_flops(name, rhs)
                total["bytes"] += op_bytes(name, kind, rhs, inst_name)
                continue
            if kind == "convolution":
                # rough: 2 * result elems * (input feature window) — our
                # models lower convs to dots, so this is a safety net only
                total["bytes"] += op_bytes(name, kind, rhs, inst_name)
                continue
            if kind in _SKIP_BYTES:
                continue
            total["bytes"] += op_bytes(name, kind, rhs, inst_name)
        cache[name] = total
        return total

    if entry is None or entry not in comps:
        return {"flops": 0.0, "bytes": 0.0, "link_bytes": 0.0, "coll": {}}
    return cost(entry)
