"""Training launcher (host-scale entry point; the mesh logic is identical
to the production dry-run — on a real TPU fleet the same script runs under
jax.distributed with the 16x16 / 2x16x16 mesh from launch.mesh).

  PYTHONPATH=src python -m repro.launch.train --arch nllb600m --smoke \
      --steps 200 --batch 8 --seq 64 --ckpt-dir /tmp/ckpt
"""

from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from ..configs import REGISTRY, get_config, reduce_config
from ..data import SyntheticLM, SyntheticTranslation
from ..models import Ctx, build_model
from ..optim import warmup_cosine
from ..train import TrainLoop, make_train_step


def batches_for(cfg, batch: int, seq: int, seed: int = 0):
    if cfg.family in ("encdec", "audio"):
        ds = SyntheticTranslation(cfg.vocab_size, min(seq, cfg.enc_len or seq),
                                  seed)
        while True:
            b = ds.sample(batch)
            yield {k: jnp.asarray(v) for k, v in b.items()
                   if not isinstance(v, str)}
    else:
        ds = SyntheticLM(cfg.vocab_size, seq, seed)
        while True:
            yield {"tokens": jnp.asarray(ds.sample(batch)["tokens"])}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="nllb600m", choices=sorted(REGISTRY))
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-2)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--remat", action="store_true")
    ap.add_argument("--state-bits", type=int, default=32, choices=(8, 32))
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = reduce_config(cfg)
    model = build_model(cfg)
    ctx = Ctx(compute_dtype=jnp.float32 if args.smoke else jnp.bfloat16)
    init_state, step = make_train_step(
        model, lr_fn=lambda s: warmup_cosine(s, peak_lr=args.lr, warmup=10,
                                             total=args.steps),
        microbatches=args.microbatches, remat=args.remat,
        state_bits=args.state_bits, ctx=ctx)

    loop = TrainLoop(jax.jit(step, donate_argnums=0), args.ckpt_dir,
                     ckpt_every=args.ckpt_every)
    state = init_state(model.init(jax.random.PRNGKey(0)))
    state, start = loop.maybe_resume(state)
    state, history = loop.run(state, batches_for(cfg, args.batch, args.seq),
                              args.steps, start_step=start)
    print(f"done: {len(history)} steps, loss {history[0]:.4f} -> "
          f"{history[-1]:.4f}, stragglers={loop.stragglers}")


if __name__ == "__main__":
    main()
