"""Production mesh builders (single-pod 16x16, multi-pod 2x16x16 v5e).

Functions, not module-level constants: importing this module never
touches jax device state (device count locks on first backend init).
"""

from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_host_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = 1
    for s in shape:
        n *= s
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices, have {len(devices)} — run under "
            f"XLA_FLAGS=--xla_force_host_platform_device_count=512 (dryrun.py "
            f"sets this automatically)")
    return jax.make_mesh(shape, axes, devices=devices[:n])


def make_host_mesh(model_parallel: int = 1):
    """Degenerate mesh over whatever devices exist (tests / CPU smoke)."""
    n = len(jax.devices())
    mp = model_parallel if n % model_parallel == 0 else 1
    return jax.make_mesh((n // mp, mp), ("data", "model"))
