"""Flash-decode attention over a block-paged KV cache (int8 or bf16).

vLLM-style paged attention for the TPU serving engine: the KV cache
lives in a shared pool of fixed-size pages; each sequence owns a chain
of pages named by a per-sequence block table. The kernel walks the
table with the *grid index map* — the page id selects which block of
the pool is DMA'd into VMEM — so no gathered dense copy of the cache is
ever materialized in HBM. Block tables and valid lengths arrive via
scalar prefetch (available before the body runs, as required for
index-map use).

Layouts (prepared by kernels.ops.paged_decode_attention):
  q          (B, Hkv, G, d)    G = query heads per KV head, padded >= 8
  k_pages    (P, Hkv, ps, d)   int8 codes or bf16   [v_pages likewise]
  k_scales   (P, Hkv, ps) f32  absent on the bf16 path
  block_tables (B, maxp) int32 page ids; out-of-chain entries must name
                               a reserved trash page (masked by length)
  lengths    (B,) int32        valid token count per sequence
Grid (B, Hkv, maxp), page dimension innermost ("arbitrary") so the
online-softmax accumulators carry across a sequence's chain.

This module is kept ruff-format-clean (CI lint job checks it).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .compat import compiler_params

__all__ = ["paged_attn_call"]

_NEG_INF = -1e30


def _kernel(
    len_ref,
    tbl_ref,
    q_ref,
    k_ref,
    ks_ref,
    v_ref,
    vs_ref,
    o_ref,
    acc_ref,
    m_ref,
    l_ref,
    *,
    ps: int,
    sm_scale: float,
    quantized: bool,
):
    b = pl.program_id(0)
    p = pl.program_id(2)

    @pl.when(p == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)  # (G, d)
    k = k_ref[0, 0].astype(jnp.float32)  # (ps, d)
    v = v_ref[0, 0].astype(jnp.float32)
    if quantized:
        k = k * ks_ref[0, 0][:, None]
        v = v * vs_ref[0, 0][:, None]
    scores = (
        jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )
        * sm_scale
    )  # (G, ps)

    # page p of the chain holds token positions [p*ps, (p+1)*ps)
    pos = p * ps + jax.lax.broadcasted_iota(jnp.int32, scores.shape, 1)
    valid = pos < len_ref[b]
    scores = jnp.where(valid, scores, _NEG_INF)

    m_old = m_ref[:, :1]  # (G, 1)
    m_new = jnp.maximum(m_old, jnp.max(scores, axis=-1, keepdims=True))
    alpha = jnp.exp(m_old - m_new)
    prob = jnp.exp(scores - m_new)
    prob = jnp.where(valid, prob, 0.0)

    l_new = l_ref[:, :1] * alpha + jnp.sum(prob, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
        prob, v, preferred_element_type=jnp.float32
    )
    m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
    l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(p == pl.num_programs(2) - 1)
    def _flush():
        denom = jnp.maximum(l_ref[:, :1], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / denom).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("sm_scale", "out_dtype", "interpret"))
def paged_attn_call(
    q,
    k_pages,
    k_scales,
    v_pages,
    v_scales,
    block_tables,
    lengths,
    *,
    sm_scale: float,
    out_dtype=jnp.bfloat16,
    interpret: bool = False,
):
    """q (B,Hkv,G,d) against paged K/V; scales may be None (bf16 path)."""
    B, Hkv, G, d = q.shape
    ps = k_pages.shape[2]
    maxp = block_tables.shape[1]
    quantized = k_scales is not None

    # the page id comes from the prefetched block table: block index maps
    # receive the scalar-prefetch refs after the grid indices
    def kv_map(b, h, p, lens, tbl):
        return (tbl[b, p], h, 0, 0)

    def sc_map(b, h, p, lens, tbl):
        return (tbl[b, p], h, 0)

    def q_map(b, h, p, lens, tbl):
        return (b, h, 0, 0)

    kv_spec = pl.BlockSpec((1, 1, ps, d), kv_map)
    sc_spec = pl.BlockSpec((1, 1, ps), sc_map)
    q_spec = pl.BlockSpec((1, 1, G, d), q_map)

    if quantized:
        in_specs = [q_spec, kv_spec, sc_spec, kv_spec, sc_spec]
        args = [q, k_pages, k_scales, v_pages, v_scales]
    else:
        in_specs = [q_spec, kv_spec, kv_spec]
        args = [q, k_pages, v_pages]

    def kernel(len_ref, tbl_ref, *refs):
        if quantized:
            q_ref, k_ref, ks_ref, v_ref, vs_ref, o_ref, acc, m_sc, l_sc = refs
        else:
            q_ref, k_ref, v_ref, o_ref, acc, m_sc, l_sc = refs
            ks_ref = vs_ref = None
        _kernel(
            len_ref,
            tbl_ref,
            q_ref,
            k_ref,
            ks_ref,
            v_ref,
            vs_ref,
            o_ref,
            acc,
            m_sc,
            l_sc,
            ps=ps,
            sm_scale=sm_scale,
            quantized=quantized,
        )

    spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, Hkv, maxp),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, G, d), q_map),
        scratch_shapes=[
            pltpu.VMEM((G, d), jnp.float32),  # acc
            pltpu.VMEM((G, 128), jnp.float32),  # running max (col-bcast)
            pltpu.VMEM((G, 128), jnp.float32),  # running denom
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=spec,
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, d), out_dtype),
        compiler_params=compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        ),
        interpret=interpret,
        name="paged_decode_attn",
    )(lengths, block_tables, *args)
