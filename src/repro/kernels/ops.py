"""Public, shape-safe wrappers around the Pallas kernels.

Handles tiling choices, padding to tile multiples, layout transforms, and
interpret-mode selection (kernels execute in Python via interpret=True on
CPU — correctness validation; on TPU they compile to Mosaic).
"""

from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp

from ..core.formats import get_format
from ..core.qtensor import QTensor
from . import decode_attn as _da
from . import fasst as _fasst
from . import paged_attn as _pa
from . import qmm as _qmm

__all__ = ["qmm", "fasst", "fasst_softmax", "decode_attention",
           "paged_decode_attention", "quantize_kv", "interpret_mode"]


@functools.lru_cache(maxsize=1)
def interpret_mode() -> bool:
    """Pallas interpret=True everywhere except a real TPU backend.

    ``REPRO_PALLAS_INTERPRET=1`` forces interpret mode regardless of
    backend (CI's kernels-interpret job sets it so kernel regressions
    fail PRs without a TPU runner).
    """
    if os.environ.get("REPRO_PALLAS_INTERPRET", "") == "1":
        return True
    return jax.default_backend() != "tpu"


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def _pick_tile(dim: int, preferred: int, multiple: int = 1) -> int:
    """Largest tile <= preferred that divides dim and is a multiple of m."""
    t = min(preferred, dim)
    while t > multiple:
        if dim % t == 0 and t % multiple == 0:
            return t
        t -= multiple
    return multiple if dim % multiple == 0 else dim


def qmm(x: jnp.ndarray, w: QTensor, *, compute_dtype=jnp.bfloat16,
        bm: int = 128, bn: int = 256, bk: int = 512):
    """x @ dequant(w) via the fused dequant-matmul kernel.

    Accepts x of shape (..., K); w must be an unbatched (K, N) QTensor
    quantized along q_axis=-2.
    """
    fmt = get_format(w.fmt)
    # derive dims from the runtime payload (robust to lax.scan slicing)
    K = w.data.shape[-2] * (2 if fmt.bits == 4 else 1)
    N = w.data.shape[-1]
    sub_block = K // w.scales_shape[-2]
    lead = x.shape[:-1]
    x2 = x.reshape(-1, K)
    M = x2.shape[0]

    bk = _pick_tile(K, bk, multiple=sub_block if sub_block % 2 == 0 or
                    fmt.bits != 4 else sub_block * 2)
    if fmt.bits == 4 and bk % 2:
        bk *= 2
    bn = _pick_tile(N, bn, multiple=128 if N % 128 == 0 else 1)
    Mp = _round_up(max(M, 1), bm) if M % bm else M
    if Mp != M:
        x2 = jnp.pad(x2, ((0, Mp - M), (0, 0)))

    y = _qmm.qmm_kernel_call(
        x2.astype(compute_dtype), w.data, w.block_scales(),
        fmt_name=w.fmt, sub_block=sub_block, bm=min(bm, Mp), bn=bn, bk=bk,
        out_dtype=compute_dtype, interpret=interpret_mode())
    return y[:M].reshape(*lead, N)


def fasst(x: jnp.ndarray, mode: str, *, out_dtype=None, bm: int = 256):
    """Reconfigurable NAF (paper's FASST): elementwise over any shape."""
    shape = x.shape
    C = shape[-1]
    x2 = x.reshape(-1, C)
    M = x2.shape[0]
    bm = _pick_tile(M, bm)
    if M % bm:
        pad = _round_up(M, bm) - M
        x2 = jnp.pad(x2, ((0, pad), (0, 0)))
    y = _fasst.fasst_act_call(x2, mode=mode, bm=bm,
                              out_dtype=out_dtype or x.dtype,
                              interpret=interpret_mode())
    return y[:M].reshape(shape)


def fasst_softmax(x: jnp.ndarray, *, scale: float = 1.0, valid_cols: int = -1,
                  out_dtype=None, bm: int = 8):
    """Fused row-wise softmax over the last axis."""
    shape = x.shape
    C = shape[-1]
    x2 = x.reshape(-1, C)
    M = x2.shape[0]
    bm = _pick_tile(M, bm)
    if M % bm:
        pad = _round_up(M, bm) - M
        x2 = jnp.pad(x2, ((0, pad), (0, 0)))
    y = _fasst.fasst_softmax_call(x2, bm=bm, valid_cols=valid_cols,
                                  scale=scale, out_dtype=out_dtype or x.dtype,
                                  interpret=interpret_mode())
    return y[:M].reshape(shape)


def quantize_kv(kv: jnp.ndarray):
    """Per-(token, head) int8 quantization for KV caches (see ref.py)."""
    from .ref import quantize_kv_ref
    return quantize_kv_ref(kv)


def decode_attention(q, k_codes, k_scales, v_codes, v_scales, lengths, *,
                     sm_scale: float | None = None, bs: int = 128,
                     out_dtype=jnp.bfloat16):
    """GQA decode attention against an int8 KV cache.

    q (B, H, d); k/v codes (B, S, Hkv, d) int8; scales (B, S, Hkv) f32;
    lengths (B,) int32. Returns (B, H, d).
    """
    B, H, d = q.shape
    S, Hkv = k_codes.shape[1], k_codes.shape[2]
    G = H // Hkv
    sm_scale = sm_scale if sm_scale is not None else d ** -0.5

    qg = q.reshape(B, Hkv, G, d)
    Gp = _round_up(G, 8)
    if Gp != G:
        qg = jnp.pad(qg, ((0, 0), (0, 0), (0, Gp - G), (0, 0)))

    bs = _pick_tile(S, bs, multiple=128 if S % 128 == 0 else 1)
    kt = jnp.transpose(k_codes, (0, 2, 1, 3))   # (B,Hkv,S,d)
    vt = jnp.transpose(v_codes, (0, 2, 1, 3))
    kst = jnp.transpose(k_scales, (0, 2, 1))    # (B,Hkv,S)
    vst = jnp.transpose(v_scales, (0, 2, 1))

    out = _da.decode_attn_call(
        qg, kt, kst, vt, vst, lengths.astype(jnp.int32), bs=bs,
        sm_scale=float(sm_scale), out_dtype=out_dtype,
        interpret=interpret_mode())
    return out[:, :, :G, :].reshape(B, H, d)


def paged_decode_attention(q, k_pages, v_pages, block_tables, lengths, *,
                           k_scales=None, v_scales=None,
                           sm_scale: float | None = None,
                           out_dtype=jnp.bfloat16):
    """GQA decode attention against a block-paged KV cache.

    q (B, H, d); k/v pages (P, ps, Hkv, d) — int8 codes with
    (P, ps, Hkv) f32 scales, or bf16 with scales=None; block_tables
    (B, maxp) int32 page ids (out-of-chain entries must point at a
    page that ``lengths`` masks out, e.g. the reserved trash page);
    lengths (B,) int32. Returns (B, H, d).
    """
    B, H, d = q.shape
    Hkv = k_pages.shape[2]
    G = H // Hkv
    sm_scale = sm_scale if sm_scale is not None else d ** -0.5

    qg = q.reshape(B, Hkv, G, d)
    Gp = _round_up(G, 8)
    if Gp != G:
        qg = jnp.pad(qg, ((0, 0), (0, 0), (0, Gp - G), (0, 0)))

    kt = jnp.transpose(k_pages, (0, 2, 1, 3))   # (P, Hkv, ps, d)
    vt = jnp.transpose(v_pages, (0, 2, 1, 3))
    kst = None if k_scales is None else jnp.transpose(k_scales, (0, 2, 1))
    vst = None if v_scales is None else jnp.transpose(v_scales, (0, 2, 1))

    out = _pa.paged_attn_call(
        qg, kt, kst, vt, vst, block_tables.astype(jnp.int32),
        lengths.astype(jnp.int32), sm_scale=float(sm_scale),
        out_dtype=out_dtype, interpret=interpret_mode())
    return out[:, :, :G, :].reshape(B, H, d)
