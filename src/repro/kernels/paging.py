"""Pure-jnp page-walk primitives — the single source of truth for the
paged-KV layout contract.

Token ``t`` of a sequence lives at ``(tables[b, t // ps], t % ps)`` in a
``(P, ps, ...)`` page pool. Everything that touches that contract goes
through here: the model decode paths (gather + per-token scatter inside
jitted scans), the serving engine's batched prefill insertion, and the
oracle for the Pallas kernel in ``paged_attn.py`` (whose index maps walk
the same tables via scalar prefetch instead of a gathered copy).
"""

from __future__ import annotations

import jax.numpy as jnp

__all__ = ["gather_pages", "scatter_token", "scatter_prefill", "TRASH_PAGE"]

# page 0 is never allocated: unused block-table entries name it, and
# idle decode slots harmlessly write their dead token into it
TRASH_PAGE = 0


def gather_pages(pages, block_tables):
    """(P, ps, ...) pool + (B, maxp) tables -> dense (B, maxp*ps, ...)."""
    B, maxp = block_tables.shape
    ps = pages.shape[1]
    return pages[block_tables].reshape((B, maxp * ps) + pages.shape[2:])


def scatter_token(pages, values, page_ids, offsets):
    """Write one token per sequence: values (B, ...) at (page, offset).

    Sequences parked on the trash page may collide; within one step the
    engine guarantees *live* (page, offset) pairs are disjoint because
    chains never share pages.
    """
    return pages.at[page_ids, offsets].set(values.astype(pages.dtype))


def scatter_prefill(pages, values, block_tables, lengths):
    """Write prompt K/V into chains: layer-stacked pages (L, P, ps, ...)
    and values (L, B, S, ...); tokens [0, lengths[b]) of row b land at
    (tables[b, t//ps], t%ps); pad positions (t >= lengths[b]) are
    dumped on the trash page."""
    L, B, S = values.shape[:3]
    ps = pages.shape[2]
    t = jnp.arange(S, dtype=jnp.int32)
    page_slot = jnp.minimum(t // ps, block_tables.shape[1] - 1)  # (S,)
    pid = jnp.take_along_axis(block_tables, page_slot[None, :], axis=1)
    valid = t[None, :] < lengths[:, None]  # (B, S)
    pid = jnp.where(valid, pid, TRASH_PAGE)
    off = jnp.where(valid, t[None, :] % ps, 0)
    flat = values.reshape((L, B * S) + values.shape[3:])
    return pages.at[:, pid.reshape(-1), off.reshape(-1)].set(flat.astype(pages.dtype))
