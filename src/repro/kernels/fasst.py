"""FASST — one reconfigurable non-linear activation kernel (paper Figs. 7-8).

The paper's FASST unit is a single CORDIC datapath reused for SoftMax,
sigmoid, tanh, ReLU (+ GeLU/SiLU/SELU variants) at FP8/BF16 I/O, because
NAFs are up to 60% of NLLB's op count and dedicated per-function hardware
is wasteful. TPU adaptation (see DESIGN.md): the VPU has fast
transcendentals, so iterative CORDIC would be a de-optimisation — we keep
the *architecture* (one kernel, a static mode switch, low-precision I/O,
f32 internal math) and drop the gate-level algorithm.

Two entry points:
  * fasst_act_call   — elementwise NAF, mode in MODES;
  * fasst_softmax_call — fused row-wise softmax (max-sub / exp / norm in
    one VMEM pass; optional column masking for padded rows).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .compat import compiler_params

__all__ = ["MODES", "fasst_act_call", "fasst_softmax_call"]

MODES = ("relu", "sigmoid", "tanh", "gelu", "silu", "squared_relu", "selu",
         "identity")


def _naf(x: jnp.ndarray, mode: str) -> jnp.ndarray:
    """The shared NAF datapath, f32 in/out."""
    if mode == "relu":
        return jnp.maximum(x, 0.0)
    if mode == "sigmoid":
        return jax.nn.sigmoid(x)
    if mode == "tanh":
        return jnp.tanh(x)
    if mode == "gelu":                       # tanh approximation (as in BERT HW)
        c = jnp.float32(0.7978845608028654)  # sqrt(2/pi)
        return 0.5 * x * (1.0 + jnp.tanh(c * (x + 0.044715 * x ** 3)))
    if mode == "silu":
        return x * jax.nn.sigmoid(x)
    if mode == "squared_relu":               # Primer / nemotron-4
        r = jnp.maximum(x, 0.0)
        return r * r
    if mode == "selu":
        alpha, lam = 1.6732632423543772, 1.0507009873554805
        return lam * jnp.where(x > 0, x, alpha * (jnp.exp(x) - 1.0))
    if mode == "identity":
        return x
    raise ValueError(f"unknown NAF mode {mode!r}")


def _act_kernel(x_ref, o_ref, *, mode: str):
    o_ref[...] = _naf(x_ref[...].astype(jnp.float32), mode).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("mode", "bm", "out_dtype",
                                             "interpret"))
def fasst_act_call(x, *, mode: str, bm: int, out_dtype=None,
                   interpret: bool = False):
    """Elementwise NAF over a (M, C) array; M % bm == 0."""
    M, C = x.shape
    out_dtype = out_dtype or x.dtype
    return pl.pallas_call(
        functools.partial(_act_kernel, mode=mode),
        grid=(M // bm,),
        in_specs=[pl.BlockSpec((bm, C), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((bm, C), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((M, C), out_dtype),
        compiler_params=compiler_params(
            dimension_semantics=("parallel",)),
        interpret=interpret,
        name=f"fasst_{mode}",
    )(x)


def _softmax_kernel(x_ref, o_ref, *, valid_cols: int, scale: float):
    x = x_ref[...].astype(jnp.float32) * scale
    C = x.shape[-1]
    if valid_cols < C:  # mask padding columns
        col = jax.lax.broadcasted_iota(jnp.int32, x.shape, x.ndim - 1)
        x = jnp.where(col < valid_cols, x, -jnp.inf)
    m = jnp.max(x, axis=-1, keepdims=True)
    e = jnp.exp(x - m)
    s = jnp.sum(e, axis=-1, keepdims=True)
    o_ref[...] = (e / s).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "valid_cols", "scale",
                                             "out_dtype", "interpret"))
def fasst_softmax_call(x, *, bm: int, valid_cols: int = -1, scale: float = 1.0,
                       out_dtype=None, interpret: bool = False):
    """Fused row softmax over (M, C); M % bm == 0; rows fit VMEM."""
    M, C = x.shape
    out_dtype = out_dtype or x.dtype
    vc = C if valid_cols < 0 else valid_cols
    return pl.pallas_call(
        functools.partial(_softmax_kernel, valid_cols=vc, scale=scale),
        grid=(M // bm,),
        in_specs=[pl.BlockSpec((bm, C), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((bm, C), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((M, C), out_dtype),
        compiler_params=compiler_params(
            dimension_semantics=("parallel",)),
        interpret=interpret,
        name="fasst_softmax",
    )(x)
