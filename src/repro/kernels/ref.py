"""Pure-jnp oracles for every Pallas kernel (tests assert_allclose vs these)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..core.quantize import dequantize_blockwise
from .fasst import _naf
from .paging import gather_pages

__all__ = ["qmm_ref", "fasst_act_ref", "fasst_softmax_ref", "decode_attn_ref",
           "quantize_kv_ref", "gather_pages_ref", "paged_attn_ref"]


def qmm_ref(x, packed, scales, fmt_name: str, out_dtype=jnp.float32):
    """Dense oracle: dequantize fully in f32, then matmul."""
    w = dequantize_blockwise(packed, scales, fmt_name, q_axis=-2,
                             out_dtype=jnp.float32)
    return jnp.matmul(x.astype(jnp.float32), w).astype(out_dtype)


def fasst_act_ref(x, mode: str, out_dtype=None):
    out_dtype = out_dtype or x.dtype
    return _naf(x.astype(jnp.float32), mode).astype(out_dtype)


def fasst_softmax_ref(x, valid_cols: int = -1, scale: float = 1.0,
                      out_dtype=None):
    out_dtype = out_dtype or x.dtype
    xf = x.astype(jnp.float32) * scale
    if valid_cols >= 0:
        col = jax.lax.broadcasted_iota(jnp.int32, xf.shape, xf.ndim - 1)
        xf = jnp.where(col < valid_cols, xf, -jnp.inf)
    return jax.nn.softmax(xf, axis=-1).astype(out_dtype)


def quantize_kv_ref(kv: jnp.ndarray):
    """Per-(token, head) symmetric int8 quantization of a (..., d) cache."""
    absmax = jnp.max(jnp.abs(kv.astype(jnp.float32)), axis=-1)
    scales = jnp.where(absmax == 0, 1.0, absmax / 127.0)
    codes = jnp.clip(jnp.round(kv / scales[..., None]), -127, 127
                     ).astype(jnp.int8)
    return codes, scales.astype(jnp.float32)


# the CPU/interpret-mode counterpart of the paged kernel's DMA walk —
# canonical implementation in kernels/paging.py
gather_pages_ref = gather_pages


def paged_attn_ref(q, k_pages, k_scales, v_pages, v_scales, block_tables,
                   lengths, sm_scale: float, out_dtype=jnp.float32):
    """Oracle for paged_attn_call: gather chains dense, run decode_attn_ref.

    Layouts match the kernel: q (B, Hkv, G, d); pages (P, Hkv, ps, d)
    with optional (P, Hkv, ps) scales (None = bf16 path).
    """
    # (P, Hkv, ps, d) -> (P, ps, Hkv, d) so the page walk is axis 0/1
    k = gather_pages_ref(jnp.swapaxes(k_pages, 1, 2), block_tables)
    v = gather_pages_ref(jnp.swapaxes(v_pages, 1, 2), block_tables)
    k = jnp.swapaxes(k, 1, 2)              # (B, Hkv, S', d)
    v = jnp.swapaxes(v, 1, 2)
    if k_scales is None:
        ks = jnp.ones(k.shape[:-1], jnp.float32)
        vs = jnp.ones(v.shape[:-1], jnp.float32)
        k8, v8 = k, v
    else:
        k8, v8 = k, v
        ks = jnp.swapaxes(gather_pages_ref(
            jnp.swapaxes(k_scales, 1, 2), block_tables), 1, 2)
        vs = jnp.swapaxes(gather_pages_ref(
            jnp.swapaxes(v_scales, 1, 2), block_tables), 1, 2)
    return decode_attn_ref(q, k8, ks, v8, vs, lengths, sm_scale, out_dtype)


def decode_attn_ref(q, k_codes, k_scales, v_codes, v_scales, lengths,
                    sm_scale: float, out_dtype=jnp.float32):
    """Oracle for decode_attn_call; same (B, Hkv, G, d) layouts."""
    k = k_codes.astype(jnp.float32) * k_scales[..., None]   # (B,Hkv,S,d)
    v = v_codes.astype(jnp.float32) * v_scales[..., None]
    scores = jnp.einsum("bhgd,bhsd->bhgs", q.astype(jnp.float32), k) * sm_scale
    S = k.shape[2]
    pos = jnp.arange(S)[None, None, None, :]
    mask = pos < lengths[:, None, None, None]
    scores = jnp.where(mask, scores, -jnp.inf)
    p = jax.nn.softmax(scores, axis=-1)
    p = jnp.where(mask, p, 0.0)
    return jnp.einsum("bhgs,bhsd->bhgd", p, v).astype(out_dtype)
