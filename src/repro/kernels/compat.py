"""Version compatibility for the Pallas TPU surface.

The repo is tested against a pinned jax and jax-at-HEAD (see the CI
matrix); on that span ``pltpu.TPUCompilerParams`` became
``pltpu.CompilerParams``. Every kernel resolves the name through this
shim. (Interpret-mode forcing for CPU runners lives in ``ops.py``:
``REPRO_PALLAS_INTERPRET=1``.)

This module is kept ruff-format-clean (CI lint job checks it).
"""

from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

__all__ = ["compiler_params"]

_CompilerParams = getattr(pltpu, "CompilerParams", None) or getattr(
    pltpu, "TPUCompilerParams"
)


def compiler_params(**kwargs):
    """pltpu.CompilerParams under its current (or pre-rename) name."""
    return _CompilerParams(**kwargs)
