"""Fused dequant-matmul Pallas TPU kernel — the RMMEC SIMD MAC analogue.

Paper (Figs. 5-6): a SIMD MAC issues 6xINT4 / 6xFP4 / 3xFP8 / 1xBF16
multiplies per cycle into an output-stationary systolic array with a wide
("quire") accumulator that is truncated once per dot product.

TPU realisation:
  * weights live in HBM as packed nibbles (2 codes/byte) + blockwise
    scales -> each HBM byte carries 2 sub-octet operands (the SIMD-lane
    packing win, restated as a bandwidth win for the memory-bound side);
  * nibbles are unpacked + dequantized *in VMEM*, immediately before the
    MXU dot — sub-octet data never round-trips through HBM densely;
  * the output tile accumulates across the K grid dimension in an f32
    VMEM scratch (output-stationary: partial sums never leave the "PE"),
    and is cast to the output dtype exactly once, after the last K step
    (the paper's end-of-dot-product quire truncation).

Grid: (M/bm, N/bn, K/bk), K innermost with "arbitrary" semantics.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..core.formats import get_format
from .compat import compiler_params

__all__ = ["qmm_kernel_call"]


def _dequant_tile(w_ref, s_ref, fmt_name: str, bk: int, sub_block: int):
    """Unpack + dequantize one (bk, bn) weight tile in VMEM, f32 out."""
    fmt = get_format(fmt_name)
    if fmt.bits == 4:
        packed = w_ref[...]                       # (bk//2, bn) uint8
        lo = packed & jnp.uint8(0x0F)
        hi = (packed >> 4) & jnp.uint8(0x0F)
        codes = jnp.stack([lo, hi], axis=1).reshape(bk, packed.shape[-1])
        if fmt.kind == "int":                     # int4: two's complement
            vals = ((codes.astype(jnp.int8) ^ jnp.int8(8)) - jnp.int8(8)
                    ).astype(jnp.float32)
        else:                                     # fp4 / nf4: 16-way codebook
            # unrolled compare-select chain — VPU-friendly, no gather
            vals = jnp.zeros(codes.shape, jnp.float32)
            for i, cval in enumerate(fmt.codebook):
                vals = jnp.where(codes == jnp.uint8(i),
                                 jnp.float32(cval), vals)
    elif fmt.name == "int8":
        vals = w_ref[...].astype(jnp.float32)     # (bk, bn) int8
    else:                                         # fp8 storage
        vals = w_ref[...].astype(jnp.float32)

    scales = s_ref[...]                           # (bk//sub_block, bn) f32
    bn = vals.shape[-1]
    vals = vals.reshape(bk // sub_block, sub_block, bn) * scales[:, None, :]
    return vals.reshape(bk, bn)


def _qmm_kernel(x_ref, w_ref, s_ref, o_ref, acc_ref, *,
                fmt_name: str, bk: int, sub_block: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    w = _dequant_tile(w_ref, s_ref, fmt_name, bk, sub_block)
    x = x_ref[...].astype(jnp.float32)
    # MXU dot with f32 accumulate into the output-stationary scratch
    acc_ref[...] += jnp.dot(x.astype(jnp.bfloat16), w.astype(jnp.bfloat16),
                            preferred_element_type=jnp.float32)

    @pl.when(k == pl.num_programs(2) - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)  # quire truncation


@functools.partial(jax.jit, static_argnames=(
    "fmt_name", "sub_block", "bm", "bn", "bk", "out_dtype", "interpret"))
def qmm_kernel_call(x, packed, scales, *, fmt_name: str, sub_block: int,
                    bm: int, bn: int, bk: int, out_dtype=jnp.bfloat16,
                    interpret: bool = False):
    """x:(M,K) @ dequant(packed,scales):(K,N) -> (M,N).

    Preconditions (enforced by kernels.ops): M%bm==0, N%bn==0, K%bk==0,
    bk%sub_block==0, and bk even for packed 4-bit formats.
    """
    M, K = x.shape
    fmt = get_format(fmt_name)
    N = packed.shape[-1]
    pack = 2 if fmt.bits == 4 else 1

    grid = (M // bm, N // bn, K // bk)
    return pl.pallas_call(
        functools.partial(_qmm_kernel, fmt_name=fmt_name, bk=bk,
                          sub_block=sub_block),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk // pack, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((bk // sub_block, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
        name=f"qmm_{fmt_name}",
    )(x, packed, scales)
