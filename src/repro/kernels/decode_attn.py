"""Flash-decode attention over a *quantized* (int8) KV cache.

Beyond-paper kernel: the paper quantizes weights; decode on TPU is bound
by KV-cache HBM reads, so we extend the same blockwise-absmax scheme to
the KV cache and dequantize per tile in VMEM (same move as qmm.py, applied
to activations-at-rest). Online-softmax accumulation over the sequence
grid dimension; per-sequence valid lengths arrive via scalar prefetch so
one compiled kernel serves ragged continuous batches.

Layouts (prepared by kernels.ops.decode_attention):
  q        (B, Hkv, G, d)   G = query heads per KV head, padded to >=8
  k_codes  (B, Hkv, S, d)   int8        k_scales (B, Hkv, S) f32
  v_codes  (B, Hkv, S, d)   int8        v_scales (B, Hkv, S) f32
  lengths  (B,) int32       valid KV length per sequence
Grid (B, Hkv, S/bs), sequence innermost ("arbitrary").
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .compat import compiler_params

__all__ = ["decode_attn_call"]

_NEG_INF = -1e30


def _kernel(len_ref, q_ref, k_ref, ks_ref, v_ref, vs_ref, o_ref,
            acc_ref, m_ref, l_ref, *, bs: int, sm_scale: float):
    b = pl.program_id(0)
    s = pl.program_id(2)

    @pl.when(s == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)                     # (G, d)
    k = k_ref[0, 0].astype(jnp.float32) * ks_ref[0, 0][:, None]   # (bs, d)
    scores = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * sm_scale      # (G, bs)

    pos = s * bs + jax.lax.broadcasted_iota(jnp.int32, scores.shape, 1)
    valid = pos < len_ref[b]
    scores = jnp.where(valid, scores, _NEG_INF)

    m_old = m_ref[:, :1]                                    # (G, 1)
    m_new = jnp.maximum(m_old, jnp.max(scores, axis=-1, keepdims=True))
    alpha = jnp.exp(m_old - m_new)                          # (G, 1)
    p = jnp.exp(scores - m_new)                             # (G, bs)
    p = jnp.where(valid, p, 0.0)

    l_new = l_ref[:, :1] * alpha + jnp.sum(p, axis=-1, keepdims=True)
    v = v_ref[0, 0].astype(jnp.float32) * vs_ref[0, 0][:, None]   # (bs, d)
    acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
        p, v, preferred_element_type=jnp.float32)

    m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
    l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(s == pl.num_programs(2) - 1)
    def _flush():
        denom = jnp.maximum(l_ref[:, :1], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / denom).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bs", "sm_scale", "out_dtype",
                                             "interpret"))
def decode_attn_call(q, k_codes, k_scales, v_codes, v_scales, lengths, *,
                     bs: int, sm_scale: float, out_dtype=jnp.bfloat16,
                     interpret: bool = False):
    B, Hkv, G, d = q.shape
    S = k_codes.shape[2]
    assert S % bs == 0, (S, bs)

    grid = (B, Hkv, S // bs)
    spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, G, d), lambda b, h, s, L: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, bs, d), lambda b, h, s, L: (b, h, s, 0)),
            pl.BlockSpec((1, 1, bs), lambda b, h, s, L: (b, h, s)),
            pl.BlockSpec((1, 1, bs, d), lambda b, h, s, L: (b, h, s, 0)),
            pl.BlockSpec((1, 1, bs), lambda b, h, s, L: (b, h, s)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, d), lambda b, h, s, L: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, d), jnp.float32),     # acc
            pltpu.VMEM((G, 128), jnp.float32),   # running max (col-bcast)
            pltpu.VMEM((G, 128), jnp.float32),   # running denom
        ],
    )
    return pl.pallas_call(
        functools.partial(_kernel, bs=bs, sm_scale=sm_scale),
        grid_spec=spec,
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, d), out_dtype),
        compiler_params=compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
        name="decode_attn_int8kv",
    )(lengths, q, k_codes, k_scales, v_codes, v_scales)
