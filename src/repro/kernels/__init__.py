"""Pallas TPU kernels for the paper's compute hot-spots.

qmm.py         -- fused dequant-matmul (RMMEC SIMD MAC analogue)
fasst.py       -- reconfigurable NAF + fused softmax (FASST analogue)
decode_attn.py -- flash-decode over an int8-quantized KV cache (beyond-paper)
ops.py         -- shape-safe jit wrappers;  ref.py -- pure-jnp oracles
"""

from . import ops, ref  # noqa: F401
