"""Quantized-draft speculative decoding: draft cheap, verify exact.

The paper's FP4/W4 arms are ~4x smaller and faster than bf16, but
post-training quantization loses quality unevenly across language pairs
(Marie & Fujita, PAPERS.md). Speculative decoding sidesteps the quality
question entirely: a *draft* arm — the SAME checkpoint re-quantized at
an aggressive spec (``w4a8kv8``, ``wfp4a8``) — proposes K tokens per
round with the horizon-fused scan, and the *target* arm replays the
drafted block in one teacher-forced fused forward, accepting the longest
prefix that matches its own greedy argmax.

The greedy-equivalence invariant
--------------------------------
For greedy requests (``temperature == 0``) the emitted token stream is
token-for-token identical to target-only decoding, whatever the draft
spec. Per round the engine emits ``accepted + 1`` tokens: the accepted
draft prefix (positions where draft argmax == target argmax) plus the
target's own argmax at the first divergence — exactly the token
target-only decoding would have produced there. The draft arm can only
change *how fast* tokens arrive (acceptance rate), never *which* tokens
arrive; a garbage draft degrades to ~1 token per verify round, i.e.
target-only speed. Rollback after a rejection truncates BOTH arms'
caches to the accepted length, so every retained KV entry corresponds to
an emitted token.

Temperature fallback
--------------------
Sampled requests (``temperature > 0``) draw from a per-request PRNG
stream whose draws are not reproduced by exact-match acceptance, so any
step whose active slots include a sampled request runs the normal
target-only path for the whole batch. The draft arm's cache simply goes
stale during the fallback (its positions lag the target's); staleness
lowers acceptance when speculation resumes but can never corrupt output,
because every emitted token is target-derived.

``DraftArm`` is the deployable bundle (built by ``build_draft_arm`` or
``deploy(..., draft_spec=...)``); ``accept_longest_prefix`` is the pure
acceptance rule, unit-testable without an engine.
"""

from __future__ import annotations

import dataclasses
import warnings
from typing import Any, Iterable, Optional, Tuple

import jax.numpy as jnp

from ..core import (QuantSpec, calibrate_act_scales, get_format,
                    quantize_tree, resolve_spec)
from ..models.layers import Ctx

__all__ = ["DraftArm", "accept_longest_prefix", "build_draft_arm"]


@dataclasses.dataclass(frozen=True)
class DraftArm:
    """The draft side of a speculative deployment: the same checkpoint
    quantized at ``spec``, with its own Ctx (draft act format / scales)
    and KV-cache dtype. ``lookahead`` is K, the tokens drafted per
    verify round."""

    params: Any
    ctx: Ctx
    spec: QuantSpec
    kv_dtype: str
    lookahead: int = 4

    def __post_init__(self):
        if self.lookahead < 1:
            raise ValueError(
                f"draft lookahead must be >= 1, got {self.lookahead}")


def accept_longest_prefix(draft_block, target_block, alive, pad_id: int = 0
                          ) -> Tuple[Any, Any, Any, Any]:
    """The speculative acceptance rule, vectorized over slots.

    draft_block / target_block: (K, S) i32 — the K drafted tokens and
    the target model's greedy argmax at each drafted position (position
    i of ``target_block`` is the target's choice given the prefix
    ``cur, d_0..d_{i-1}``). alive: (S,) i32 mask.

    Returns ``(out, n_emit, accepted, new_cur)``:
      out       (K, S) — emitted tokens: the accepted draft prefix, then
                the target's token at the first divergence, then pad.
      n_emit    (S,)   — tokens emitted this round: min(accepted + 1, K).
      accepted  (S,)   — length of the matching prefix (0..K).
      new_cur   (S,)   — the last emitted token, the next round's
                pending ``cur`` (pad for dead slots).

    When all K draft tokens match, n_emit == K and new_cur is the last
    draft token — the bonus target token at position K is deliberately
    NOT emitted, keeping both arms' caches symmetric (each advanced
    exactly K positions this round, rollback is a shared truncation).
    """
    draft_block = jnp.asarray(draft_block)
    target_block = jnp.asarray(target_block)
    K = draft_block.shape[0]
    alive = jnp.asarray(alive) > 0
    match = (draft_block == target_block) & alive[None, :]
    accepted = jnp.cumprod(match.astype(jnp.int32), axis=0).sum(axis=0)
    n_emit = jnp.minimum(accepted + 1, K)
    idx = jnp.arange(K, dtype=jnp.int32)[:, None]
    out = jnp.where(idx < accepted[None, :], draft_block,
                    jnp.where(idx == accepted[None, :], target_block,
                              jnp.int32(pad_id)))
    out = jnp.where(alive[None, :], out, jnp.int32(pad_id))
    new_cur = jnp.take_along_axis(out, (n_emit - 1)[None, :], axis=0)[0]
    return out, n_emit, jnp.where(alive, accepted, 0), new_cur


def build_draft_arm(model, raw_params, base_ctx: Ctx, draft_spec,
                    *, lookahead: int = 4,
                    calib_batches: Optional[Iterable[dict]] = None
                    ) -> DraftArm:
    """Quantize a second arm of ``raw_params`` (the UN-quantized
    checkpoint) at ``draft_spec`` and bundle it as a DraftArm.

    ``base_ctx`` supplies compute dtype and kernel routes; the draft's
    activation format and (when calibrated) static scales replace the
    target's. Same calibration contract as deploy(): an act-quantizing
    draft spec without calibration batches warns and stays dynamic.
    """
    spec = resolve_spec(draft_spec)
    ctx = dataclasses.replace(base_ctx, act_fmt=spec.act, act_scales=None)
    params = raw_params
    if spec.weights != "f32":
        params = quantize_tree(raw_params, spec.policy())
    if spec.quantizes_act:
        scales = {}
        if calib_batches is not None:
            scales = calibrate_act_scales(
                model, params, ctx, calib_batches,
                max_code=get_format(spec.act).max_code)
        if scales:
            ctx = dataclasses.replace(
                ctx, act_scales=tuple(sorted(scales.items())))
        else:
            warnings.warn(
                f"draft spec {spec} quantizes activations but no "
                "calibration batches were provided (or the iterable was "
                "empty); the draft falls back to dynamic per-token "
                "activation quantization",
                stacklevel=2)
    return DraftArm(params=params, ctx=ctx, spec=spec, kv_dtype=spec.kv,
                    lookahead=int(lookahead))
