"""One-call deployment: config -> model -> quantize -> engine.

`deploy()` composes the whole config/build/quantize/engine dance that
every serving caller used to re-spell by hand, and returns a
`TranslationPipeline` — the canonical inference surface:

    pipe = deploy("nllb600m", "int4", slots=4, max_len=64, smoke=True)
    outs = pipe.translate(src_tokens, "ita",
                          SamplingParams(max_new_tokens=8, eos_id=2))
    outs = pipe.generate(prompts, SamplingParams(temperature=0.7))

Both return `RequestOutput` lists in input order; the scheduler-owned
`pipe.engine` is exposed for request-level control (submit / step /
run_until_drained / abort / stream). The streaming surface delivers
tokens as each fused horizon block lands instead of drain-then-return:

    for tok in pipe.translate_stream(src_row, "ita", sp):
        print(tok)                         # token-at-a-time delivery

and `deploy(..., sla=SLATarget(p95_ttft_ms=...))` attaches the
percentile-feedback admission controller (serving.metrics) that tunes
horizon + prefill batching to hold the target under load.
"""

from __future__ import annotations

import dataclasses
import warnings
from typing import Any, Iterable, Iterator, List, Optional, Sequence, Union

import jax
import jax.numpy as jnp

from ..configs import get_config, reduce_config
from ..core import (QuantSpec, calibrate_act_scales, get_format,
                    quantize_tree, resolve_spec, tree_nbytes)
from ..data import LANG_CODES
from ..models import Ctx, build_model
from ..obs import TraceConfig, Tracer
from .engine import ServeEngine
from .metrics import SLATarget
from .params import Request, RequestOutput, SamplingParams
from .spec_decode import build_draft_arm

__all__ = ["deploy", "TranslationPipeline", "impl_routes", "IMPL_CHOICES"]

# the CLI "--impl" convention (launch.serve, bench_serving), defined once:
# "xla" routes everything through XLA, "pallas" routes matmuls through the
# Pallas qmm kernel and paged attention through the Pallas block-table
# kernel. CLIs derive their argparse choices from IMPL_CHOICES, so adding
# a bundle here is the only edit needed.
_IMPL_ROUTES = {
    "xla": {},
    "pallas": {"matmul_impl": "pallas", "paged_attn_impl": "kernel"},
}
IMPL_CHOICES = tuple(sorted(_IMPL_ROUTES))
_MATMUL_IMPLS = ("xla", "pallas")
_PAGED_ATTN_IMPLS = ("gather", "kernel")


def impl_routes(impl: str) -> dict:
    """deploy() kwargs for the named kernel-route bundle (IMPL_CHOICES)."""
    if impl not in _IMPL_ROUTES:
        raise KeyError(
            f"unknown impl bundle {impl!r}; have {list(IMPL_CHOICES)}")
    return dict(_IMPL_ROUTES[impl])


@dataclasses.dataclass
class TranslationPipeline:
    """A deployed model + scheduler-owned engine behind two calls."""

    cfg: Any
    model: Any
    params: Any
    engine: ServeEngine
    ctx: Ctx
    policy: str                   # the spec as the caller named it
    fp_bytes: int                 # parameter bytes before quantization
    spec: QuantSpec               # the fully-resolved quantization spec
    draft_spec: Optional[QuantSpec] = None  # speculative draft arm spec

    @property
    def spec_str(self) -> str:
        """Canonical grammar spelling of the deployed spec (what reports
        record next to the requested alias)."""
        return str(self.spec)

    @property
    def draft_spec_str(self) -> Optional[str]:
        """Canonical spelling of the speculative draft spec (None on a
        target-only deployment)."""
        return str(self.draft_spec) if self.draft_spec is not None else None

    @property
    def tracer(self) -> Optional[Tracer]:
        """The engine's Tracer when deployed with ``trace=...`` (None
        otherwise) — dump with ``pipe.tracer.dump_json(path)``."""
        return self.engine.trace

    @property
    def quantized_bytes(self) -> int:
        return tree_nbytes(self.params)

    @property
    def compression(self) -> float:
        return self.fp_bytes / max(self.quantized_bytes, 1)

    def generate(self, prompts: Sequence[Any],
                 params: Optional[SamplingParams] = None
                 ) -> List[RequestOutput]:
        """Serve a list of prompts; outputs come back in input order.

        Each prompt is a B=1 model batch dict, or (LM families only) a
        1-D sequence of token ids. All requests share ``params``.
        """
        ids = []
        for p in prompts:
            if not isinstance(p, (dict, Request)):
                if self.cfg.family in ("encdec", "audio"):
                    raise TypeError(
                        "enc-dec prompts must be batch dicts with "
                        "'src_tokens' and 'tgt_in'")
                p = {"tokens": jnp.asarray(p, jnp.int32)[None]}
            ids.append(self.engine.submit(p, params))
        by_id = {o.request_id: o for o in self.engine.run_until_drained()}
        return [by_id[i] for i in ids]

    def translate(self, src_tokens, tgt_lang: Union[str, int],
                  params: Optional[SamplingParams] = None
                  ) -> List[RequestOutput]:
        """Many-to-many NMT (paper Fig. 2b): one output per source row.

        ``tgt_lang`` is a language name from ``data.LANG_CODES`` or a raw
        code-token id; the decoder is prompted with that code token.
        """
        if self.cfg.family not in ("encdec", "audio"):
            raise TypeError(
                f"translate() needs an enc-dec model, got family "
                f"{self.cfg.family!r}; use generate() instead")
        code = LANG_CODES[tgt_lang] if isinstance(tgt_lang, str) else tgt_lang
        src = jnp.asarray(src_tokens)
        if src.ndim == 1:
            src = src[None]
        prompts = [{"src_tokens": src[i:i + 1],
                    "tgt_in": jnp.full((1, 1), code, jnp.int32)}
                   for i in range(src.shape[0])]
        return self.generate(prompts, params)

    def generate_stream(self, prompt: Any,
                        params: Optional[SamplingParams] = None
                        ) -> Iterator[int]:
        """Stream ONE prompt: yields token ids as each fused horizon
        block lands on the host; the finished RequestOutput (tokens,
        finish reason, ttft_ms/tpot_ms stats) is the generator's return
        value (``StopIteration.value``). Other in-flight requests keep
        being served while this one streams."""
        if not isinstance(prompt, (dict, Request)):
            if self.cfg.family in ("encdec", "audio"):
                raise TypeError(
                    "enc-dec prompts must be batch dicts with "
                    "'src_tokens' and 'tgt_in'")
            prompt = {"tokens": jnp.asarray(prompt, jnp.int32)[None]}
        return self.engine.stream_request(prompt, params)

    def translate_stream(self, src_tokens, tgt_lang: Union[str, int],
                         params: Optional[SamplingParams] = None
                         ) -> Iterator[int]:
        """Streaming counterpart of translate() for ONE source row:
        yields target token ids as they arrive (first token at
        prefill), returns the RequestOutput as the generator's return
        value. Batch sources should loop, or submit through
        ``engine.submit(..., on_token=...)`` for interleaved streams."""
        if self.cfg.family not in ("encdec", "audio"):
            raise TypeError(
                f"translate_stream() needs an enc-dec model, got family "
                f"{self.cfg.family!r}; use generate_stream() instead")
        code = LANG_CODES[tgt_lang] if isinstance(tgt_lang, str) else tgt_lang
        src = jnp.asarray(src_tokens)
        if src.ndim == 1:
            src = src[None]
        if src.shape[0] != 1:
            raise ValueError(
                f"translate_stream() streams one source row, got a batch "
                f"of {src.shape[0]}; loop over rows (or submit them via "
                "engine.submit(on_token=...) for interleaved streaming)")
        prompt = {"src_tokens": src,
                  "tgt_in": jnp.full((1, 1), code, jnp.int32)}
        return self.engine.stream_request(prompt, params)


def deploy(arch_or_cfg, policy: Union[str, QuantSpec] = "int4", *,
           slots: int = 4,
           max_len: int = 64, smoke: bool = False, params: Any = None,
           ctx: Optional[Ctx] = None, kv_dtype: Optional[str] = None,
           init_seed: int = 0, paged: bool = False, page_size: int = 8,
           num_pages: Optional[int] = None,
           max_src_len: Optional[int] = None, horizon: int = 1,
           matmul_impl: Optional[str] = None,
           paged_attn_impl: Optional[str] = None,
           calib_batches: Optional[Iterable[dict]] = None,
           draft_spec: Union[str, QuantSpec, None] = None,
           draft_lookahead: int = 4, overlap: bool = True,
           sla: Optional[SLATarget] = None,
           max_pending: Optional[int] = None, preempt_limit: int = 3,
           faults=None, trace: Union[Tracer, TraceConfig, None] = None,
           mesh=None) -> TranslationPipeline:
    """Build a ready-to-serve TranslationPipeline in one call.

    arch_or_cfg: registry name (see configs.REGISTRY) or a ModelConfig.
    policy:      quantization spec — a QuantSpec, a registered alias
                 ("int4", "w8a8", ...), or a grammar string ("w4a8kv8",
                 "wfp8e4m3afp8kvfp8"; see core.spec). The KV-cache dtype
                 follows the spec unless ``kv_dtype`` overrides.
    smoke:       reduce the config to CPU-testable size and compute in
                 f32 (skipped when ``ctx`` is given).
    params:      pre-trained parameters to deploy (still quantized per
                 ``policy``); default: fresh init from ``init_seed``.
    paged:       block-paged KV cache + batched prefill admission
                 (attention families): KV memory is a shared pool of
                 ``num_pages`` pages of ``page_size`` tokens (default
                 pool = dense capacity; pass a smaller ``num_pages`` to
                 cap memory at expected — not worst-case — usage).
    max_src_len: cross-attention capacity for enc-dec families
                 (default cfg.enc_len); admitted requests may carry any
                 source length up to it.
    horizon:     decode micro-steps fused per host sync (see
                 ServeEngine): 1 = per-token dispatch (exact legacy
                 behavior), K = one on-device lax.scan of K steps with
                 admission/retirement at horizon boundaries — same
                 token streams, 1/K the host syncs.
    matmul_impl / paged_attn_impl: kernel routes threaded into the
                 pipeline Ctx (override even an explicit ``ctx``):
                 matmul "xla" | "pallas" (Pallas qmm over quantized
                 weights), paged attention "gather" | "kernel" (Pallas
                 block-table walk; paged engines only).
    calib_batches: sample model batches for static activation
                 calibration (paper §III, ~1000 queries per language at
                 paper scale). When the spec quantizes activations
                 (a8 / afp8), the batches run through
                 core.calibration.calibrate_act_scales against the
                 already-quantized weights and the resulting *per-site*
                 static scales replace dynamic per-token quantization in
                 the qlinear act path. An act-quantizing spec deployed
                 WITHOUT calibration batches warns and stays dynamic
                 (never silently bf16). Ignored for specs that keep
                 activations in bf16.
    draft_spec:  quantization spec for a speculative-decoding draft arm
                 (same grammar/aliases as ``policy`` — e.g. target
                 "int8" with draft "wfp4a8" or "w4a8kv8"): the SAME
                 checkpoint is quantized a second time at this spec,
                 and greedy requests decode speculatively — the draft
                 proposes K tokens, the target verifies them in one
                 batched forward and emits the longest matching prefix.
                 Output stays token-for-token identical to target-only
                 decoding (see serving.spec_decode); sampled requests
                 fall back to target-only. ``calib_batches`` calibrates
                 both arms.
    draft_lookahead: tokens drafted per speculative verify round (K).
    overlap:     double-buffer the decode loop (default on): horizon
                 N+1 is dispatched on device while the host still walks
                 horizon N's token block — same token streams, the host
                 walk hidden behind device work. ``False`` restores the
                 serial dispatch-then-sync order (horizon=1 and draft
                 arms are always serial).
    sla:         SLATarget latency objectives; attaches the
                 percentile-feedback controller (serving.metrics) that
                 auto-tunes the effective horizon and the paged
                 prefill-group cap against measured p95 TTFT/TPOT over
                 retired requests.
    max_pending: bounded admission — ``submit()`` raises the typed
                 ``EngineSaturated`` (with .pending/.limit) once this
                 many requests are queued, instead of buffering
                 unboundedly; callers retry with backoff after draining.
                 None (default) keeps the unbounded queue.
    preempt_limit: on-demand paged engines (paged, no draft arm) admit
                 with only the *prompt's* pages and grow chains as
                 decode advances; on pool exhaustion the lowest-priority
                 youngest request is preempted (pages freed, tokens
                 stashed host-side) and later resumed by prefill replay
                 with identical output. A request preempted more than
                 ``preempt_limit`` times retires with
                 ``finish_reason='preempted_limit'``.
    faults:      a serving.faults.FaultPlan — deterministic injection of
                 allocator exhaustion, NaN logits, and deadline-clock
                 skew at seeded round/dispatch coordinates (chaos tests,
                 ``bench_serving --faults``). None disables injection.
    trace:       an ``obs.TraceConfig`` (or a ready ``Tracer``) enables
                 per-request lifecycle + scheduler round-phase tracing;
                 read it back via ``pipe.tracer`` (Perfetto export:
                 ``pipe.tracer.dump_json(path)``). None (default) keeps
                 the round loop observation-free: no events, no extra
                 clock reads, identical token streams and sync counts.
    mesh:        a ``jax.sharding.Mesh`` for tensor-parallel serving:
                 quantized params and the KV storage (dense caches or
                 the paged page pool) are placed once under
                 NamedSharding at engine init and every jitted serving
                 callable traces with the mesh active, so prefill and
                 the decode scan dispatch as GSPMD programs with no
                 per-round resharding. Block tables and the page
                 allocator stay host-replicated. Token streams are
                 identical to the mesh-less engine (CI asserts this on
                 8 forced host devices). None (default) keeps the
                 single-device path byte-identical to prior releases.
    """
    spec = resolve_spec(policy)
    cfg = get_config(arch_or_cfg) if isinstance(arch_or_cfg, str) \
        else arch_or_cfg
    if smoke:
        cfg = reduce_config(cfg)
    model = build_model(cfg)
    if ctx is None:
        ctx = Ctx(compute_dtype=jnp.float32 if smoke else jnp.bfloat16)
    # the spec owns deployment precision: its activation format wins
    # even over an explicit ctx, else a caller-supplied ctx would
    # silently downgrade w8a8 to bf16 activations (compute dtype and
    # kernel routes remain the caller's); the x<fmt> slot routes the
    # attention QK/PV activation-activation matmuls the same way
    ctx = dataclasses.replace(ctx, act_fmt=spec.act,
                              attn_act_fmt=spec.attn)
    impls = {}
    if matmul_impl is not None:
        if matmul_impl not in _MATMUL_IMPLS:
            raise ValueError(f"matmul_impl must be one of {_MATMUL_IMPLS}, "
                             f"got {matmul_impl!r}")
        impls["matmul_impl"] = matmul_impl
    if paged_attn_impl is not None:
        if paged_attn_impl not in _PAGED_ATTN_IMPLS:
            raise ValueError(f"paged_attn_impl must be one of "
                             f"{_PAGED_ATTN_IMPLS}, got {paged_attn_impl!r}")
        impls["paged_attn_impl"] = paged_attn_impl
    if impls:
        ctx = dataclasses.replace(ctx, **impls)
    if params is None:
        params = model.init(jax.random.PRNGKey(init_seed))
    fp_bytes = tree_nbytes(params)
    raw_params = params             # the draft arm quantizes from here
    if draft_spec is not None and calib_batches is not None \
            and not isinstance(calib_batches, (list, tuple)):
        # both arms calibrate from the same batches; a one-shot
        # iterable would be exhausted by the target pass
        calib_batches = list(calib_batches)
    if spec.weights != "f32":
        params = quantize_tree(params, spec.policy())
    if spec.quantizes_act or spec.quantizes_attn:
        scales = {}
        if calib_batches is not None:
            # static PTQ deployment: observe the quantized model's
            # matmul activations eagerly, one absmax per site, and
            # thread the per-site scale registry into the Ctx (attention
            # QK/PV sites report through the same collector when the
            # spec carries an x<fmt> slot)
            fmt = spec.act if spec.quantizes_act else spec.attn
            scales = calibrate_act_scales(
                model, params, ctx, calib_batches,
                max_code=get_format(fmt).max_code)
        if scales:
            ctx = dataclasses.replace(
                ctx, act_scales=tuple(sorted(scales.items())))
        else:
            # regression guard for the silent-bf16-activations bug
            # class: the act path still *quantizes* (dynamically), but
            # an uncalibrated static deployment should be loud
            warnings.warn(
                f"spec {spec} quantizes activations but no calibration "
                "batches were provided (or the iterable was empty); "
                "falling back to dynamic per-token activation "
                "quantization — pass deploy(calib_batches=...) for the "
                "paper's calibrated static-scale deployment",
                stacklevel=2)
    draft = None
    if draft_spec is not None:
        draft = build_draft_arm(model, raw_params, ctx, draft_spec,
                                lookahead=draft_lookahead,
                                calib_batches=calib_batches)
    kv = kv_dtype or spec.kv
    engine = ServeEngine(model, params, slots=slots, max_len=max_len,
                         kv_dtype=kv, ctx=ctx, paged=paged,
                         page_size=page_size, num_pages=num_pages,
                         max_src_len=max_src_len, horizon=horizon,
                         draft=draft, overlap=overlap, sla=sla,
                         max_pending=max_pending,
                         preempt_limit=preempt_limit, faults=faults,
                         trace=trace, mesh=mesh)
    name = policy if isinstance(policy, str) else str(spec)
    return TranslationPipeline(cfg, model, params, engine, ctx, name,
                               fp_bytes, spec,
                               draft_spec=draft.spec if draft else None)
