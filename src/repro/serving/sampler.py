"""Fused per-slot token sampler: one executable for every SamplingParams.

The engine decodes all slots in one batched step; slots may carry
different SamplingParams (greedy next to nucleus-sampled). To keep a
single compiled function regardless of the mix, the per-slot knobs
(temperature / top_k / top_p / PRNG key / stream offset) enter as traced
arrays and the greedy-vs-sampled choice is a data-dependent `where` —
changing a request's params never recompiles, only re-runs.

Per-slot PRNG streams: each request owns a base key derived from its
``seed``; token ``t`` of that request draws from ``fold_in(key, t)``, so
outputs are reproducible independent of slot placement, admission order,
or what the other slots are doing.

Both entry points are pure jnp, so they compose with ``jax.lax.scan``:
``sample_tokens`` is the per-token form the engine's legacy step uses,
``sample_tokens_scan`` is the horizon-fused scan-body form — identical
sampling, plus an ``alive`` mask so slots retired mid-horizon (EOS /
budget) emit ``pad_id`` instead of a live draw. The PRNG stream is
offset-indexed either way, so fused and per-token decode produce the
same tokens for the same request.

Poisoned-request isolation: a slot whose logits contain NaN/Inf (an
overflowed sub-octet arm, a numerically fragile quant format) samples
the ``ERR_TOKEN`` sentinel instead of garbage. The guard is per-row —
the other slots in the fused batch sample normally — and the engine
retires the offending slot with ``finish_reason='error'`` when the
sentinel reaches the host walk, so one poisoned request never takes
down a batch or escapes ``step()`` as an exception.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["sample_tokens", "sample_tokens_scan", "ERR_TOKEN"]

_NEG = jnp.float32(-1e30)   # mask value: exp() underflows to exactly 0

# sentinel "token" emitted for a slot whose logits are non-finite; never a
# valid vocab id, never equal to a pad (0) or any eos_id, so the host walk
# can detect it unambiguously in a synced block
ERR_TOKEN = -2


def _sample_row(logits, temp, top_k, top_p, key, offset):
    """One slot's next token. logits (V,) f32; scalars are traced."""
    v = logits.shape[-1]
    greedy = jnp.argmax(logits).astype(jnp.int32)

    lg = logits / jnp.maximum(temp, 1e-6)
    # top-k: keep logits >= the k-th largest (k <= 0 disables)
    kk = jnp.where(top_k <= 0, v, jnp.minimum(top_k, v))
    srt = jnp.sort(lg)[::-1]
    kth = srt[jnp.maximum(kk - 1, 0)]
    lg = jnp.where(lg < kth, _NEG, lg)
    # top-p (nucleus): keep the smallest prefix of the sorted probability
    # mass reaching p; the top-1 token is always kept
    probs = jax.nn.softmax(lg)
    sp = jnp.sort(probs)[::-1]
    keep = (jnp.cumsum(sp) - sp) < top_p
    pth = jnp.min(jnp.where(keep, sp, jnp.inf))
    lg = jnp.where(probs < pth, _NEG, lg)

    tok = jax.random.categorical(jax.random.fold_in(key, offset), lg)
    return jnp.where(temp <= 0.0, greedy, tok).astype(jnp.int32)


def sample_tokens(logits, temps, top_ks, top_ps, keys, offsets):
    """Batched next-token sampling across slots.

    logits (S, V) f32, temps/top_ps (S,) f32, top_ks/offsets (S,) i32,
    keys (S, 2) u32 -> tokens (S,) i32. Rows with any non-finite logit
    return ``ERR_TOKEN`` (see module docstring) instead of a draw.
    """
    lg = logits.astype(jnp.float32)
    toks = jax.vmap(_sample_row)(lg, temps, top_ks, top_ps, keys, offsets)
    ok = jnp.all(jnp.isfinite(lg), axis=-1)
    return jnp.where(ok, toks, jnp.int32(ERR_TOKEN))


def sample_tokens_scan(logits, temps, top_ks, top_ps, keys, offsets, alive,
                       pad_id: int = 0):
    """Scan-body form of ``sample_tokens`` for horizon-fused decode.

    Same sampling semantics (including the non-finite-logits ERR_TOKEN
    guard), plus an ``alive`` (S,) i32 mask: slots that retired earlier
    in the horizon (EOS or exhausted ``max_new_tokens`` budget) emit
    ``pad_id`` — the host-side walk of the emitted token block stops at
    each slot's retirement point, so pads are never read as generated
    tokens (a dead slot's poisoned logits are masked, not flagged).
    """
    toks = sample_tokens(logits, temps, top_ks, top_ps, keys, offsets)
    return jnp.where(alive > 0, toks, jnp.int32(pad_id))
