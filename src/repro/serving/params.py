"""Request-level serving types: SamplingParams / Request / RequestOutput.

These are the load-bearing abstraction of the serving stack (the vLLM
convention adapted to the paper's quantized-NMT deployment): every
inference call in the repo is a `Request` carrying its own frozen
`SamplingParams`, and every completion is a `RequestOutput` with an
explicit finish reason and timing stats. Finish reasons cover the
fault-tolerant paths too — a request always comes back with a typed
outcome instead of an exception escaping the serving loop:

  * ``eos`` / ``length``    — normal completion.
  * ``abort``               — cancelled by the caller.
  * ``deadline``            — ``deadline_ms`` elapsed before completion
    (partial tokens are returned).
  * ``preempted_limit``     — preempted for pages more than the
    engine's ``preempt_limit`` times; retired with partial tokens
    rather than thrashing the pool forever.
  * ``error``               — the model produced non-finite logits for
    this request (sampler NaN/Inf guard); only the offending slot
    fails, with its partial tokens, while the fused batch continues.

``EngineSaturated`` is the typed admission rejection raised by
``submit`` when the engine's bounded pending queue (``max_pending``) is
full — callers retry with backoff instead of seeing an allocator error
from deep inside the engine.

Sampling semantics:
  * ``temperature == 0.0``  -> greedy argmax (the default).
  * ``temperature > 0``     -> softmax sampling at that temperature,
    optionally restricted by ``top_k`` (0 = off) and/or nucleus
    ``top_p`` (1.0 = off), drawn from a per-request PRNG stream seeded
    by ``seed`` — same seed, same tokens, regardless of which slot or
    batch the request lands in.
  * ``eos_id``              -> generation stops the step this token is
    emitted (it is included in the output); ``None`` disables EOS
    stopping (token 0 is the pad id in the synthetic corpora, so there
    is deliberately no implicit default).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Sequence

__all__ = ["SamplingParams", "GREEDY", "Request", "RequestOutput",
           "RequestStats", "FINISH_REASONS", "EngineSaturated",
           "latency_percentiles"]

FINISH_REASONS = ("eos", "length", "abort", "deadline", "preempted_limit",
                  "error")


class EngineSaturated(RuntimeError):
    """Typed backpressure signal: the engine's bounded pending queue is
    full. Carries ``pending`` (queue depth at rejection) and ``limit``
    (the engine's ``max_pending``) so callers can implement
    retry-with-backoff without parsing the message."""

    def __init__(self, pending: int, limit: int):
        self.pending = pending
        self.limit = limit
        super().__init__(
            f"engine saturated: {pending} requests pending >= "
            f"max_pending={limit}; retry after draining (engine.step() / "
            f"stream()) or deploy with a larger max_pending")


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """Per-request decode policy. Frozen: shareable across requests."""

    temperature: float = 0.0      # 0.0 = greedy
    top_k: int = 0                # 0 = disabled
    top_p: float = 1.0            # 1.0 = disabled
    eos_id: Optional[int] = None  # None = never stop on a token id
    max_new_tokens: int = 16      # includes the prefill-sampled first token
    seed: int = 0                 # per-request PRNG stream seed
    deadline_ms: Optional[float] = None  # wall-clock budget from submit;
    #                               checked at horizon boundaries (None = no
    #                               deadline); an expired request retires
    #                               with finish_reason "deadline" and
    #                               whatever tokens it has
    priority: int = 0             # preemption victim ordering: on page-pool
    #                               exhaustion the lowest-priority (then
    #                               youngest) request is evicted first

    def __post_init__(self):
        if self.temperature < 0.0:
            raise ValueError(f"temperature must be >= 0, got {self.temperature}")
        if self.top_k < 0:
            raise ValueError(f"top_k must be >= 0, got {self.top_k}")
        if not 0.0 < self.top_p <= 1.0:
            raise ValueError(f"top_p must be in (0, 1], got {self.top_p}")
        if self.max_new_tokens < 1:
            raise ValueError(
                f"max_new_tokens must be >= 1, got {self.max_new_tokens}")
        if self.deadline_ms is not None and self.deadline_ms <= 0:
            raise ValueError(
                f"deadline_ms must be positive, got {self.deadline_ms}")

    @property
    def greedy(self) -> bool:
        return self.temperature == 0.0


GREEDY = SamplingParams()


@dataclasses.dataclass
class Request:
    """One inference request: a B=1 model batch dict + sampling params.

    ``inputs`` follows the ModelAPI batch convention — ``{"tokens"}`` for
    LM families, ``{"src_tokens", "tgt_in"}`` for enc-dec. ``id`` is
    assigned by the engine at submit time.

    ``on_token`` is the streaming hook: the engine calls it with each
    token id as the horizon block carrying that token lands on the host
    (the prefill-sampled first token fires at admission). Callbacks run
    on the scheduler's walk of the synced block — keep them cheap, and
    note that aborting the request from inside its own callback wins
    over an EOS in the same block (finish reason becomes ``abort``).
    """

    inputs: Dict[str, Any]
    params: SamplingParams = GREEDY
    id: Optional[int] = None
    on_token: Optional[Callable[[int], None]] = None


@dataclasses.dataclass
class RequestStats:
    """Wall-clock stamps (time.perf_counter) + derived serving metrics.

    ``new_tokens`` is the count of tokens actually delivered to the
    caller — under horizon-fused decode an aborted request is truncated
    at its last *synced* position, so this is the authoritative count
    (always equal to ``len(RequestOutput.token_ids)``), not the number
    of device-side decode steps the slot participated in.

    ``drafted`` / ``accepted`` / ``rejected`` count speculative-decode
    draft tokens proposed for this request, how many the target model's
    verify pass accepted, and how many it threw away (all zero on a
    target-only engine). ``accepted + rejected == drafted`` for every
    completed verify round the request participated in.

    ``preemptions`` counts how many times the request was evicted from
    its slot for page pressure and later resumed via prefill-replay —
    the token stream is unaffected (resume is provably identical), only
    latency pays.
    """

    arrival_s: float = 0.0
    first_token_s: float = 0.0
    finished_s: float = 0.0
    prompt_len: int = 0
    new_tokens: int = 0
    drafted: int = 0
    accepted: int = 0
    rejected: int = 0
    preemptions: int = 0

    @property
    def ttft_s(self) -> float:
        return self.first_token_s - self.arrival_s

    @property
    def total_s(self) -> float:
        return self.finished_s - self.arrival_s


@dataclasses.dataclass
class RequestOutput:
    """Completion record for one request."""

    request_id: int
    prompt: Dict[str, Any]
    token_ids: List[int]
    finish_reason: str            # one of FINISH_REASONS
    stats: RequestStats
    slot: int = -1                # engine slot that served the request

    @property
    def num_generated(self) -> int:
        return len(self.token_ids)

    @property
    def tok_s(self) -> float:
        dt = self.stats.total_s
        return self.num_generated / dt if dt > 0 else float("inf")

    @property
    def ttft_ms(self) -> float:
        """Time to first token (ms): submit -> prefill token delivered."""
        return self.stats.ttft_s * 1e3

    @property
    def tpot_ms(self) -> float:
        """Per-output-token latency (ms) after the first token.

        The post-first-token span over the decode steps the request took
        (``new_tokens - 1``; a one-token request contributes its whole
        span). Same definition ``latency_percentiles`` aggregates, so a
        single streamed request and a benchmark row read the same way.
        """
        return ((self.stats.total_s - self.stats.ttft_s)
                / max(self.num_generated - 1, 1)) * 1e3


def latency_percentiles(outputs: Sequence["RequestOutput"]) -> Dict[str, float]:
    """p50/p95 TTFT and per-output-token latency (ms) over completions.

    The shared serving-latency summary: benchmarks/bench_serving.py
    records it per BENCH row and repro.eval.suite per language pair, so
    quality and perf artifacts carry identically-defined columns.
    Per-output-token time divides the post-first-token span by the
    number of decode steps the request took (``new_tokens - 1``; a
    one-token request contributes its whole span).

    Percentiles are the repo-wide nearest-rank definition
    (``obs.metrics.percentile`` — also what the SLA controller and
    ``EngineMetrics``' histogram fields use), so the same sample can
    never read as "held" in one surface and "violated" in another.
    """
    from ..obs.metrics import percentile

    ttft = [o.ttft_ms for o in outputs]
    tpot = [o.tpot_ms for o in outputs]
    return {"ttft_p50_ms": round(percentile(ttft, 50), 3),
            "ttft_p95_ms": round(percentile(ttft, 95), 3),
            "tpot_p50_ms": round(percentile(tpot, 50), 3),
            "tpot_p95_ms": round(percentile(tpot, 95), 3)}
