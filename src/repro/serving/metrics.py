"""Engine-level serving metrics and SLA-aware admission control.

``EngineMetrics`` is the one snapshot type for everything the engine
counts: the ad-hoc attribute zoo (``decode_syncs``, ``acceptance_rate``,
``verify_calls``, ``occupancy``, ...) that benchmarks and the eval suite
used to read one attribute at a time is now a single frozen dataclass
returned by ``ServeEngine.metrics()``. Counters accumulate across rounds
and reset together via ``reset_metrics()``; the two gauge fields
(``kv_cache_bytes``, ``prefill_compiles``) are recomputed from live
engine state at snapshot time — a gauge has no accumulation to reset, so
``EngineMetrics.GAUGES`` names them and the reset test asserts every
field *outside* that set returns to zero.

``SLATarget`` + ``SLAController`` close the serving loop on latency:
``deploy(..., sla=SLATarget(p95_ttft_ms=...))`` attaches a controller
that folds every retired request's TTFT/TPOT into a sliding window and
retunes two admission knobs against the measured p95s —

* the effective fused-decode **horizon** (a long scan amortizes the host
  sync, so it lowers TPOT, but admission waits for scan boundaries, so
  it raises queued-prompt TTFT), and
* the paged **prefill group cap** (how many queued prompts one batched
  prefill admits; a big group compiles fewer shapes but holds the queue
  head hostage to stragglers).

The controller is deliberately percentile-feedback only — it never
inspects queue depth or arrival-rate estimates, so the same policy works
under ``bench_serving --rate`` Poisson load and bursty real traffic.
"""

from __future__ import annotations

import dataclasses
from typing import ClassVar, Dict, List, Optional, Sequence, Tuple

from ..obs.metrics import Histogram, percentile

__all__ = ["EngineMetrics", "SLATarget", "SLAController", "merge_metrics"]


@dataclasses.dataclass(frozen=True)
class EngineMetrics:
    """Frozen snapshot of every engine counter + derived ratio.

    All fields except those named in ``GAUGES`` are run-scoped: they
    start at zero, accumulate monotonically, and ``reset_metrics()``
    zeroes them (benchmarks reset after warmup so compile time never
    pollutes measured rates).
    """

    # decode-loop counters
    decode_steps: int            # decode micro-steps dispatched (incl. masked)
    decode_syncs: int            # host blocks on a device token buffer
    synced_tokens: int           # tokens actually emitted to requests
    active_slot_steps: int       # slot-steps that served a live request
    page_slot_steps: int         # page-steps attended (paged occupancy basis)
    overlap_rounds: int          # horizons dispatched before the previous sync
    # speculative-decoding counters
    verify_calls: int
    drafted_tokens: int
    accepted_tokens: int
    rejected_tokens: int
    # fault-tolerance counters
    preemptions: int             # requests evicted for page pressure
    resumed_requests: int        # preempted requests re-admitted (replay)
    deadline_expirations: int    # requests retired past deadline_ms
    admission_rejections: int    # submits bounced with EngineSaturated
    slot_errors: int             # slots failed by the NaN/Inf logits guard
    # derived ratios (0.0 when the denominator counter is still zero)
    mean_tokens_per_sync: float
    occupancy: float             # active slot-steps / dispatched slot-steps
    page_utilization: float
    acceptance_rate: float
    mean_accepted_per_verify: float
    # latency percentiles over retirements since the last reset (from
    # the engine's always-on obs.Histogram accumulators; 0.0 before the
    # first retirement — bucket upper edges, nearest rank)
    ttft_p50_ms: float
    ttft_p95_ms: float
    tpot_p50_ms: float
    tpot_p95_ms: float
    # scheduler round-phase totals (ms); populated only on a traced
    # engine — phase timing needs the tracer's extra clock reads, and
    # the untraced round loop must stay zero-cost
    phase_admit_ms: float
    phase_dispatch_ms: float
    phase_sync_ms: float
    phase_walk_ms: float
    # gauges — live engine state, not resettable accumulation
    kv_cache_bytes: int
    prefill_compiles: int

    GAUGES: ClassVar[Tuple[str, ...]] = ("kv_cache_bytes", "prefill_compiles")

    def as_dict(self) -> Dict[str, float]:
        """Plain dict for JSON rows (benchmarks, eval reports)."""
        return dataclasses.asdict(self)


def _weighted_mean(pairs: Sequence[Tuple[float, float]]) -> float:
    """sum(v * w) / sum(w), 0.0 when no weight accumulated."""
    den = sum(w for _, w in pairs)
    return sum(v * w for v, w in pairs) / den if den else 0.0


def merge_metrics(snapshots: Sequence[EngineMetrics],
                  ttft_hist: Optional[Histogram] = None,
                  tpot_hist: Optional[Histogram] = None) -> EngineMetrics:
    """Aggregate per-replica EngineMetrics into one cluster snapshot.

    Counters and gauges sum. Derived ratios recompute from the summed
    counters where the snapshot retains both sides of the division
    (mean_tokens_per_sync, acceptance_rate, mean_accepted_per_verify);
    occupancy and page_utilization — whose denominators fold in
    per-engine slot/pool sizes that a snapshot does not carry — merge
    as decode_steps-weighted means, which equals the pooled ratio when
    replicas are homogeneous (the router's deployment mode). Latency
    percentiles come from ``ttft_hist``/``tpot_hist`` when given —
    build them by ``Histogram.merge``-ing every replica's accumulators
    into a fresh ``Histogram()`` — and are 0.0 otherwise (a sum or
    mean of percentiles would be statistically meaningless).
    """
    if not snapshots:
        raise ValueError("merge_metrics needs at least one snapshot")

    def tot(field: str):
        return sum(getattr(s, field) for s in snapshots)

    decode_syncs = tot("decode_syncs")
    synced_tokens = tot("synced_tokens")
    drafted = tot("drafted_tokens")
    accepted = tot("accepted_tokens")
    verify_calls = tot("verify_calls")

    def pct(hist: Optional[Histogram], q: float) -> float:
        return round(hist.percentile(q), 4) if hist is not None else 0.0

    return EngineMetrics(
        decode_steps=tot("decode_steps"),
        decode_syncs=decode_syncs,
        synced_tokens=synced_tokens,
        active_slot_steps=tot("active_slot_steps"),
        page_slot_steps=tot("page_slot_steps"),
        overlap_rounds=tot("overlap_rounds"),
        verify_calls=verify_calls,
        drafted_tokens=drafted,
        accepted_tokens=accepted,
        rejected_tokens=tot("rejected_tokens"),
        preemptions=tot("preemptions"),
        resumed_requests=tot("resumed_requests"),
        deadline_expirations=tot("deadline_expirations"),
        admission_rejections=tot("admission_rejections"),
        slot_errors=tot("slot_errors"),
        mean_tokens_per_sync=(synced_tokens / decode_syncs
                              if decode_syncs else 0.0),
        occupancy=_weighted_mean([(s.occupancy, s.decode_steps)
                                  for s in snapshots]),
        page_utilization=_weighted_mean([(s.page_utilization, s.decode_steps)
                                         for s in snapshots]),
        acceptance_rate=accepted / drafted if drafted else 0.0,
        mean_accepted_per_verify=(accepted / verify_calls
                                  if verify_calls else 0.0),
        ttft_p50_ms=pct(ttft_hist, 50.0),
        ttft_p95_ms=pct(ttft_hist, 95.0),
        tpot_p50_ms=pct(tpot_hist, 50.0),
        tpot_p95_ms=pct(tpot_hist, 95.0),
        phase_admit_ms=round(tot("phase_admit_ms"), 4),
        phase_dispatch_ms=round(tot("phase_dispatch_ms"), 4),
        phase_sync_ms=round(tot("phase_sync_ms"), 4),
        phase_walk_ms=round(tot("phase_walk_ms"), 4),
        kv_cache_bytes=tot("kv_cache_bytes"),
        prefill_compiles=tot("prefill_compiles"))


@dataclasses.dataclass(frozen=True)
class SLATarget:
    """Latency objectives for SLA-aware admission.

    Either percentile target may be ``None`` (unconstrained). ``window``
    is how many request completions feed one retune decision — small
    windows react fast but chase noise; the default suits smoke-scale
    benchmarks. ``min_horizon``/``max_horizon`` bound the controller
    (``max_horizon=None`` means the deployed horizon is the ceiling).
    """

    p95_ttft_ms: Optional[float] = None
    p95_tpot_ms: Optional[float] = None
    window: int = 16
    min_horizon: int = 1
    max_horizon: Optional[int] = None

    def __post_init__(self):
        if self.p95_ttft_ms is None and self.p95_tpot_ms is None:
            raise ValueError("SLATarget needs p95_ttft_ms or p95_tpot_ms "
                             "(both None constrains nothing)")
        for name in ("p95_ttft_ms", "p95_tpot_ms"):
            v = getattr(self, name)
            if v is not None and v <= 0:
                raise ValueError(f"{name} must be positive, got {v}")
        if self.window < 1:
            raise ValueError(f"window must be >= 1, got {self.window}")
        if self.min_horizon < 1:
            raise ValueError("min_horizon must be >= 1")
        if self.max_horizon is not None and self.max_horizon < self.min_horizon:
            raise ValueError("max_horizon < min_horizon")


class SLAController:
    """Percentile feedback loop over request completions.

    The engine calls ``observe(output)`` at every retirement; once a full
    window has accumulated the controller compares measured p95 TTFT/TPOT
    against the target and moves its two knobs:

    * p95 TTFT over target → **halve the horizon** and **halve the
      prefill group cap**: queued prompts admit at scan boundaries, so
      shorter scans and smaller admission groups get first tokens out
      sooner at some sync-rate cost.
    * p95 TPOT over target (TTFT fine) → **double the horizon** back:
      steady-state token cadence is gated by host syncs per token.
    * both under target → relax one step toward the deployed
      configuration (horizon first, then group cap), so a transient
      burst doesn't pin the engine in its defensive posture forever.

    TTFT wins ties: a breached first-token SLA is user-visible queueing,
    a breached TPOT usually follows from the same congestion.
    """

    def __init__(self, target: SLATarget, horizon: int, slots: int):
        self.target = target
        self.base_horizon = max(1, int(horizon))
        self.max_horizon = (target.max_horizon
                            if target.max_horizon is not None
                            else self.base_horizon)
        self.max_horizon = max(self.max_horizon, target.min_horizon)
        self.horizon = min(self.base_horizon, self.max_horizon)
        self.slots = max(1, int(slots))
        self.prefill_cap = self.slots
        self.retunes = 0
        self.windows = 0
        self.last: Dict[str, float] = {}
        self._window: List[Tuple[float, float]] = []

    def observe(self, output) -> bool:
        """Fold one retired RequestOutput; True if a retune fired."""
        self._window.append((output.ttft_ms, output.tpot_ms))
        if len(self._window) < self.target.window:
            return False
        return self._retune()

    def _p95(self, idx: int) -> float:
        # the repo-wide nearest-rank definition (obs.metrics.percentile
        # was lifted from this controller, so consolidating onto it
        # changed no admission decisions)
        return percentile((w[idx] for w in self._window), 95.0)

    def _retune(self) -> bool:
        ttft, tpot = self._p95(0), self._p95(1)
        self._window.clear()
        self.windows += 1
        self.last = {"ttft_p95_ms": ttft, "tpot_p95_ms": tpot}
        t = self.target
        old = (self.horizon, self.prefill_cap)
        if t.p95_ttft_ms is not None and ttft > t.p95_ttft_ms:
            self.horizon = max(t.min_horizon, self.horizon // 2)
            self.prefill_cap = max(1, self.prefill_cap // 2)
        elif t.p95_tpot_ms is not None and tpot > t.p95_tpot_ms:
            self.horizon = min(self.max_horizon, max(1, self.horizon * 2))
        elif self.horizon < min(self.base_horizon, self.max_horizon):
            self.horizon = min(self.base_horizon, self.max_horizon,
                               self.horizon * 2)
        elif self.prefill_cap < self.slots:
            self.prefill_cap = min(self.slots, self.prefill_cap * 2)
        changed = (self.horizon, self.prefill_cap) != old
        self.retunes += int(changed)
        return changed

    def holding(self) -> Optional[bool]:
        """Did the last full window meet the target? None before one."""
        if not self.last:
            return None
        t = self.target
        ok = True
        if t.p95_ttft_ms is not None:
            ok &= self.last["ttft_p95_ms"] <= t.p95_ttft_ms
        if t.p95_tpot_ms is not None:
            ok &= self.last["tpot_p95_ms"] <= t.p95_tpot_ms
        return ok
