"""Deterministic fault injection for the serving engine.

The paper's deployment target is resource-limited hardware where pool
exhaustion, stragglers, and numerically fragile sub-octet arms are the
steady state — so the fault paths (deadlines, preemption, the sampler's
NaN guard) need a way to be exercised *deterministically*, not by
hoping a real fault shows up. ``FaultPlan`` is that harness: a seeded
schedule of synthetic faults that ``deploy(..., faults=plan)`` threads
into the engine, which then calls back at two well-defined points:

  * ``on_round(engine)`` — once at every scheduler round boundary
    (``step()`` and each ``_rounds`` iteration, including no-op rounds
    while the queue is blocked, so transient faults always clear).
    Injects **allocator exhaustion** (steal pages from the engine's
    free list and hold them for ``hold`` rounds — the engine sees a
    genuinely shrunken pool and must preempt; ``PageAllocator.check()``
    still passes because the steal is a real allocation) and **clock
    skew** (advance the engine's deadline clock by ``ms`` without
    sleeping — deadline tests run in microseconds of real time).
  * ``poison(n_slots, K)`` — once per decode dispatch; returns a per-
    slot micro-step index at which that slot's logits are forced to
    NaN (or ``None`` for a clean dispatch), driving the sampler's
    poisoned-request isolation path.

Faults come from explicit event lists (exact round / dispatch
coordinates — CI tripwires want guaranteed fault counts) and/or seeded
random rates (chaos testing wants coverage). Every injected fault is
appended to ``plan.events``, so two plans with the same seed driving
the same engine produce identical event logs — the determinism the
chaos equivalence tests assert.

A plan is stateful and belongs to ONE engine at a time: the engine
resets it at construction, and ``release_all(engine)`` returns any
still-held pages after a drain (tests call it before asserting
``pages_in_use == 0``).
"""

from __future__ import annotations

from typing import List, Sequence, Tuple

import numpy as np

__all__ = ["FaultPlan"]


class FaultPlan:
    """Seeded, deterministic schedule of synthetic serving faults.

    Explicit events (all optional, exact coordinates):
      * ``exhaust_at``: ``(round, pages, hold)`` — at scheduler round
        ``round``, steal up to ``pages`` free pages and hold them for
        ``hold`` rounds.
      * ``nan_at``: ``(dispatch, slot, micro_step)`` — at the
        ``dispatch``-th decode dispatch, force slot ``slot``'s logits
        to NaN at micro-step ``micro_step`` (clamped into the scan).
      * ``skew_at``: ``(round, ms)`` — advance the engine's deadline
        clock by ``ms`` at round ``round``.

    Random rates (chaos mode, driven by ``seed``):
      * ``exhaust_prob`` / ``exhaust_pages`` / ``exhaust_hold``: per
        round, with probability ``exhaust_prob``, steal
        ``exhaust_pages`` pages for ``exhaust_hold`` rounds.
      * ``nan_prob``: per dispatch, poison one uniformly-drawn
        (slot, micro_step).
      * ``skew_prob`` / ``skew_ms``: per round, advance the clock.

    Holds are always finite (``hold >= 1``), so a blocked queue drains
    once the hold expires — no plan can wedge the engine forever.
    """

    def __init__(self, seed: int = 0, *,
                 exhaust_at: Sequence[Tuple[int, int, int]] = (),
                 exhaust_prob: float = 0.0, exhaust_pages: int = 0,
                 exhaust_hold: int = 2,
                 nan_at: Sequence[Tuple[int, int, int]] = (),
                 nan_prob: float = 0.0,
                 skew_at: Sequence[Tuple[int, float]] = (),
                 skew_prob: float = 0.0, skew_ms: float = 0.0):
        for name, p in (("exhaust_prob", exhaust_prob),
                        ("nan_prob", nan_prob), ("skew_prob", skew_prob)):
            if not 0.0 <= p <= 1.0:
                raise ValueError(f"{name} must be in [0, 1], got {p}")
        if exhaust_hold < 1:
            raise ValueError(f"exhaust_hold must be >= 1, got {exhaust_hold}")
        for r, pages, hold in exhaust_at:
            if hold < 1:
                raise ValueError(
                    f"exhaust_at hold must be >= 1 (round {r}): a page "
                    "held forever would wedge the admission queue")
        self.seed = int(seed)
        self.exhaust_at = tuple((int(r), int(p), int(h))
                                for r, p, h in exhaust_at)
        self.exhaust_prob = float(exhaust_prob)
        self.exhaust_pages = int(exhaust_pages)
        self.exhaust_hold = int(exhaust_hold)
        self.nan_at = tuple((int(d), int(s), int(m)) for d, s, m in nan_at)
        self.nan_prob = float(nan_prob)
        self.skew_at = tuple((int(r), float(m)) for r, m in skew_at)
        self.skew_prob = float(skew_prob)
        self.skew_ms = float(skew_ms)
        self.reset()

    def reset(self) -> None:
        """Rewind to round/dispatch 0 with a fresh seeded RNG (the
        engine calls this at construction). Drops any held pages
        without freeing them — call ``release_all`` first if the plan
        is being moved off a live engine."""
        self._rng = np.random.default_rng(self.seed)
        self._round = 0
        self._dispatch = 0
        self._holds: List[Tuple[int, list]] = []   # (release_round, chain)
        self.events: List[tuple] = []

    # -- engine hooks ---------------------------------------------------

    def on_round(self, engine) -> None:
        """Tick one scheduler round: release expired holds, then apply
        this round's exhaustion / clock-skew events. On a traced engine
        every injection additionally lands as an instant on the
        scheduler track, so a trace shows faults at the round they
        fired."""
        r = self._round
        self._round += 1
        tr = getattr(engine, "trace", None)
        paged = bool(getattr(engine, "paged", False))
        if paged and self._holds:
            keep = []
            for rel, chain in self._holds:
                if rel <= r:
                    engine.allocator.free_chain(chain)
                    self.events.append(("release", r, len(chain)))
                    if tr is not None:
                        tr.instant(0, "fault:release", engine._now(),
                                   round=r, pages=len(chain))
                else:
                    keep.append((rel, chain))
            self._holds = keep
        pages = hold = 0
        for rr, p, h in self.exhaust_at:
            if rr == r:
                pages, hold = max(pages, p), max(hold, h)
        if self.exhaust_prob and self._rng.random() < self.exhaust_prob:
            pages = max(pages, self.exhaust_pages)
            hold = max(hold, self.exhaust_hold)
        if pages and paged:
            # a real allocation from the engine's free list: the pool
            # genuinely shrinks, allocator invariants keep holding
            k = min(pages, engine.allocator.num_free)
            if k:
                self._holds.append((r + hold, engine.allocator.alloc_chain(k)))
                self.events.append(("exhaust", r, k, hold))
                if tr is not None:
                    tr.instant(0, "fault:exhaust", engine._now(),
                               round=r, pages=k, hold=hold)
        ms = 0.0
        for rr, m in self.skew_at:
            if rr == r:
                ms += m
        if self.skew_prob and self._rng.random() < self.skew_prob:
            ms += self.skew_ms
        if ms:
            engine._skew_s += ms / 1e3
            self.events.append(("skew", r, ms))
            if tr is not None:
                # stamped AFTER the jump: the instant lands where the
                # skewed clock resumed, making the jump visible
                tr.instant(0, "fault:skew", engine._now(), round=r, ms=ms)

    def poison(self, n_slots: int, K: int):
        """NaN-injection schedule for one decode dispatch: an (S,) i32
        array of per-slot micro-step indices (-1 = clean), or None for
        a dispatch with no injection."""
        d = self._dispatch
        self._dispatch += 1
        arr = None
        for dd, slot, step in self.nan_at:
            if dd == d and 0 <= slot < n_slots:
                if arr is None:
                    arr = np.full((n_slots,), -1, np.int32)
                arr[slot] = min(max(step, 0), K - 1)
        if self.nan_prob and self._rng.random() < self.nan_prob:
            if arr is None:
                arr = np.full((n_slots,), -1, np.int32)
            arr[int(self._rng.integers(n_slots))] = int(self._rng.integers(K))
        if arr is not None:
            self.events.append(("nan", d, tuple(arr.tolist())))
        return arr

    # -- test / bench helpers -------------------------------------------

    @property
    def held_pages(self) -> int:
        return sum(len(chain) for _, chain in self._holds)

    def release_all(self, engine) -> None:
        """Free every still-held page back to the engine's allocator
        (after a drain, before asserting ``pages_in_use == 0``)."""
        for _, chain in self._holds:
            engine.allocator.free_chain(chain)
        self._holds = []
