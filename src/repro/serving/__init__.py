"""Serving: the request-level inference surface for the whole repo.

Canonical path:  deploy() -> TranslationPipeline -> SamplingParams /
Request / RequestOutput, scheduled by the queue-owning ServeEngine
(submit / step / run_until_drained / stream). Tokens stream as each
fused horizon block lands — `submit(..., on_token=cb)`,
`engine.stream_request(...)`, `pipe.translate_stream(...)` — and
`deploy(..., sla=SLATarget(...))` attaches percentile-feedback
admission control; `engine.metrics()` returns the one frozen
EngineMetrics snapshot every benchmark reads. Speculative decoding
deploys a second arm of the same checkpoint via
`deploy(..., draft_spec=...)` (see spec_decode).

`greedy_generate` / `translate` remain as deprecated single-shot
wrappers for legacy callers.
"""

from .engine import ServeEngine, greedy_generate, translate
from .metrics import EngineMetrics, SLATarget
from .paged_cache import PageAllocator, pages_needed
from .params import (GREEDY, Request, RequestOutput, RequestStats,
                     SamplingParams, latency_percentiles)
from .pipeline import IMPL_CHOICES, TranslationPipeline, deploy, impl_routes
from .spec_decode import DraftArm, accept_longest_prefix, build_draft_arm

__all__ = ["ServeEngine", "greedy_generate", "translate", "SamplingParams",
           "GREEDY", "Request", "RequestOutput", "RequestStats",
           "latency_percentiles", "TranslationPipeline", "deploy",
           "PageAllocator", "pages_needed", "impl_routes", "IMPL_CHOICES",
           "DraftArm", "accept_longest_prefix", "build_draft_arm",
           "EngineMetrics", "SLATarget"]
