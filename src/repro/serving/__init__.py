"""Serving: the request-level inference surface for the whole repo.

Canonical path:  deploy() -> TranslationPipeline -> SamplingParams /
Request / RequestOutput, scheduled by the queue-owning ServeEngine
(submit / step / run_until_drained). `greedy_generate` / `translate`
remain as thin single-shot wrappers for legacy callers. Speculative
decoding deploys a second arm of the same checkpoint via
`deploy(..., draft_spec=...)` (see spec_decode).
"""

from .engine import ServeEngine, greedy_generate, translate
from .paged_cache import PageAllocator, pages_needed
from .params import (GREEDY, Request, RequestOutput, RequestStats,
                     SamplingParams, latency_percentiles)
from .pipeline import IMPL_CHOICES, TranslationPipeline, deploy, impl_routes
from .spec_decode import DraftArm, accept_longest_prefix, build_draft_arm

__all__ = ["ServeEngine", "greedy_generate", "translate", "SamplingParams",
           "GREEDY", "Request", "RequestOutput", "RequestStats",
           "latency_percentiles", "TranslationPipeline", "deploy",
           "PageAllocator", "pages_needed", "impl_routes", "IMPL_CHOICES",
           "DraftArm", "accept_longest_prefix", "build_draft_arm"]
