"""Serving: the request-level inference surface for the whole repo.

Canonical path:  deploy() -> TranslationPipeline -> SamplingParams /
Request / RequestOutput, scheduled by the queue-owning ServeEngine
(submit / step / run_until_drained / stream). Tokens stream as each
fused horizon block lands — `submit(..., on_token=cb)`,
`engine.stream_request(...)`, `pipe.translate_stream(...)` — and
`deploy(..., sla=SLATarget(...))` attaches percentile-feedback
admission control; `engine.metrics()` returns the one frozen
EngineMetrics snapshot every benchmark reads. Speculative decoding
deploys a second arm of the same checkpoint via
`deploy(..., draft_spec=...)` (see spec_decode).

Fault tolerance: requests carry `SamplingParams(deadline_ms=...,
priority=...)` and retire with a `finish_reason` from FINISH_REASONS;
`deploy(..., max_pending=N)` bounds admission (`submit` raises the
typed EngineSaturated under saturation); on-demand paged engines
preempt and transparently resume requests under page pressure; and
`deploy(..., faults=FaultPlan(...))` injects deterministic allocator
exhaustion / NaN logits / clock skew for chaos testing.

Observability: `deploy(..., trace=TraceConfig())` wires an `obs.Tracer`
into the engine — per-request lifecycle spans and scheduler round-phase
timing, exportable as Chrome/Perfetto JSON (`pipe.tracer.dump_json`);
`engine.prometheus()` renders the metrics snapshot + ttft/tpot/phase
histograms as Prometheus text (see `repro.obs`).

Scale-out: `deploy(..., mesh=...)` tensor-shards one engine over a
`("model",)` device mesh; `repro.cluster` adds the data-parallel
`ReplicaRouter` / `deploy_replicas` layer on top, aggregating replica
snapshots with `merge_metrics`.

`greedy_generate` / `translate` remain as deprecated single-shot
wrappers for legacy callers.
"""

from ..obs import TraceConfig, Tracer
from .engine import ServeEngine, greedy_generate, translate
from .faults import FaultPlan
from .metrics import EngineMetrics, SLATarget, merge_metrics
from .paged_cache import PageAllocator, pages_needed
from .params import (FINISH_REASONS, GREEDY, EngineSaturated, Request,
                     RequestOutput, RequestStats, SamplingParams,
                     latency_percentiles)
from .pipeline import IMPL_CHOICES, TranslationPipeline, deploy, impl_routes
from .sampler import ERR_TOKEN
from .spec_decode import DraftArm, accept_longest_prefix, build_draft_arm

__all__ = ["ServeEngine", "greedy_generate", "translate", "SamplingParams",
           "GREEDY", "Request", "RequestOutput", "RequestStats",
           "latency_percentiles", "TranslationPipeline", "deploy",
           "PageAllocator", "pages_needed", "impl_routes", "IMPL_CHOICES",
           "DraftArm", "accept_longest_prefix", "build_draft_arm",
           "EngineMetrics", "SLATarget", "merge_metrics", "EngineSaturated",
           "FaultPlan",
           "FINISH_REASONS", "ERR_TOKEN", "TraceConfig", "Tracer"]
