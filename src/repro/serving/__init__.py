from .engine import ServeEngine, greedy_generate, translate

__all__ = ["ServeEngine", "greedy_generate", "translate"]
