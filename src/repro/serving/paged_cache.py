"""Block-paged KV cache: free-list page allocator + shared block storage.

Dense serving caches allocate ``slots x max_len`` KV positions up front,
so HBM scales with the *worst-case* request and idles whenever actual
lengths are shorter. This module replaces that with vLLM-style paging:

  * the KV cache is a shared pool of ``num_pages`` fixed-size pages
    (``page_size`` tokens each), stored layer-stacked as
    ``(L, P, ps, Hkv, hd)`` in bf16, int8 codes + f32 scales, or fp8
    (e4m3) codes + f32 scales (storage dtypes come from
    ``core.formats.FORMATS``);
  * each in-flight request owns a *chain* of pages handed out by the
    host-side ``PageAllocator`` free list; token ``t`` of a request
    lives at ``(chain[t // ps], t % ps)``;
  * the device-side view of a chain is a row of the engine's block
    table ``(slots, max_pages)`` int32; unused entries point at the
    reserved trash page 0, which valid-length masking excludes from
    attention and which absorbs writes from idle slots.

Under tensor parallelism (``deploy(..., mesh=...)``) the pool shards
on the *head* axes: ``parallel.sharding.paged_pool_shardings`` places
``Hkv`` (and ``hd`` when heads don't divide the mesh) over the
``"model"`` axis while ``L``/``P``/``ps`` stay replicated, so a page
is the same page on every shard and the host-side ``PageAllocator``,
chains, and block tables need no distribution at all — one free list
drives every device. Storage is ``device_put`` once at engine init;
page-walk gathers/scatters then run under GSPMD with no per-round
resharding.

The page-walk jnp primitives (`gather_pages` / `scatter_token` /
`scatter_prefill`) live in `kernels/paging.py` — one source of truth
shared by the model decode paths, this engine layer, and the kernel
oracle — and are re-exported here; the TPU-path equivalent is the
Pallas kernel in `kernels/paged_attn.py`, which walks block tables via
scalar-prefetched index maps instead of a gathered dense copy.

This module is kept ruff-format-clean (CI lint job checks it).
"""

from __future__ import annotations

from typing import List, Sequence

import jax.numpy as jnp

from ..core.formats import get_format
from ..kernels.paging import (
    TRASH_PAGE,
    gather_pages,
    scatter_prefill,
    scatter_token,
)

__all__ = [
    "PageAllocator",
    "pages_needed",
    "init_paged_kv",
    "gather_pages",
    "scatter_token",
    "scatter_prefill",
    "paged_insert",
    "TRASH_PAGE",
]


def pages_needed(num_tokens: int, page_size: int) -> int:
    """Pages required to hold ``num_tokens`` cache positions."""
    return max(0, -(-num_tokens // page_size))


class PageAllocator:
    """Host-side free-list allocator over the shared page pool.

    Pages are plain ints in ``[reserved, capacity)``; page ids below
    ``reserved`` (the trash page) are never handed out. The allocator
    is strict: freeing a page that is not currently allocated raises,
    as does allocating beyond capacity — serving bugs surface as
    exceptions instead of silent cache corruption.
    """

    def __init__(self, capacity: int, reserved: int = 1):
        if capacity <= reserved:
            raise ValueError(f"capacity {capacity} must exceed reserved {reserved}")
        self.capacity = capacity
        self.reserved = reserved
        self._free: List[int] = list(range(reserved, capacity))
        self._in_use: set = set()

    @property
    def num_free(self) -> int:
        return len(self._free)

    @property
    def pages_in_use(self) -> int:
        return len(self._in_use)

    def can_alloc(self, n: int) -> bool:
        return n <= self.num_free

    def alloc_chain(self, n: int) -> List[int]:
        """Allocate ``n`` pages; returns the chain in token order."""
        if n < 0:
            raise ValueError(f"cannot allocate {n} pages")
        if n > self.num_free:
            raise MemoryError(
                f"paged KV cache exhausted: need {n} pages, "
                f"{self.num_free}/{self.capacity - self.reserved} free"
            )
        chain = self._free[:n]
        del self._free[:n]
        self._in_use.update(chain)
        return chain

    def try_alloc_chain(self, n: int) -> "List[int] | None":
        """``alloc_chain`` that returns ``None`` on shortage instead of
        raising — the engine's on-demand growth path turns a shortage
        into victim preemption, never into a MemoryError escaping the
        serving loop."""
        if n < 0:
            raise ValueError(f"cannot allocate {n} pages")
        if n > self.num_free:
            return None
        return self.alloc_chain(n)

    def free_chain(self, chain: Sequence[int]) -> None:
        """Return a request's pages to the free list (chain order kept)."""
        chain = list(chain)
        if len(set(chain)) != len(chain):
            raise ValueError(f"chain contains duplicate pages: {chain}")
        for p in chain:
            if p not in self._in_use:
                raise ValueError(
                    f"double free / foreign page {p} (in use: "
                    f"{sorted(self._in_use)})"
                )
        for p in chain:
            self._in_use.remove(p)
        self._free.extend(chain)

    def check(self) -> None:
        """Invariant: every page is free xor in-use, exactly once."""
        assert len(self._free) == len(set(self._free))
        assert not set(self._free) & self._in_use
        total = len(self._free) + len(self._in_use)
        assert total == self.capacity - self.reserved


def init_paged_kv(
    num_layers: int,
    num_pages: int,
    page_size: int,
    num_kv_heads: int,
    head_dim: int,
    kv_dtype: str = "bf16",
):
    """Shared paged K/V storage leaves, layer-stacked for lax.scan.

    Returns the storage dict only (no block table / lengths — those are
    per-engine); leaves are (L, P, ps, Hkv, hd) [+ (L, P, ps, Hkv)
    scales for int8], dtypes resolved via core.formats.
    """
    L, P, ps = num_layers, num_pages, page_size
    Hkv, hd = num_kv_heads, head_dim
    if kv_dtype == "int8":
        code_dt = get_format("int8").storage_dtype
        return {
            "k_codes": jnp.zeros((L, P, ps, Hkv, hd), code_dt),
            "k_scales": jnp.zeros((L, P, ps, Hkv), jnp.float32),
            "v_codes": jnp.zeros((L, P, ps, Hkv, hd), code_dt),
            "v_scales": jnp.zeros((L, P, ps, Hkv), jnp.float32),
        }
    if kv_dtype == "fp8":
        # e4m3 codes + per-(token, head) scales, int8-pool layout with
        # float8 storage; keys "k"/"v" so the fp8 path is detected as
        # "k_scales present, k_codes absent" (matches the dense caches)
        code_dt = get_format("fp8").storage_dtype
        return {
            "k": jnp.zeros((L, P, ps, Hkv, hd), code_dt),
            "k_scales": jnp.zeros((L, P, ps, Hkv), jnp.float32),
            "v": jnp.zeros((L, P, ps, Hkv, hd), code_dt),
            "v_scales": jnp.zeros((L, P, ps, Hkv), jnp.float32),
        }
    if kv_dtype not in ("bf16", "f32"):
        raise ValueError(
            f"paged KV storage supports bf16|f32|int8|fp8, got {kv_dtype!r}"
        )
    dt = get_format(kv_dtype).storage_dtype
    return {
        "k": jnp.zeros((L, P, ps, Hkv, hd), dt),
        "v": jnp.zeros((L, P, ps, Hkv, hd), dt),
    }


_CROSS_KEYS = (
    "cross_k",
    "cross_v",
    "cross_k_codes",
    "cross_k_scales",
    "cross_v_codes",
    "cross_v_scales",
)
_SELF_KEYS = ("k", "v", "k_codes", "k_scales", "v_codes", "v_scales")


def paged_insert(cache, mini, slot_ids, page_rows, lengths):
    """Commit a dense prefill mini-cache into the paged batch cache.

    ``mini`` is the (n, S_bucket)-shaped dense cache a batched prefill
    produced; its self-attention KV scatters into the page chains named
    by ``page_rows`` (n, maxp), its cross-attention leaves (enc-dec)
    splice into the per-slot dense cross buffers at ``slot_ids`` (n,),
    and the block table / length / active rows flip to live. Pure jnp —
    runs inside the engine's jitted admission step.
    """
    new = dict(cache)
    for key in _SELF_KEYS:
        if key in cache and key in mini:
            new[key] = scatter_prefill(cache[key], mini[key], page_rows, lengths)
    for key in _CROSS_KEYS:
        if key in cache and key in mini:
            se = mini[key].shape[2]
            new[key] = cache[key].at[:, slot_ids, :se].set(
                mini[key].astype(cache[key].dtype)
            )
    if "cross_len" in cache:
        new["cross_len"] = cache["cross_len"].at[slot_ids].set(mini["cross_len"])
    new["block_tables"] = cache["block_tables"].at[slot_ids].set(page_rows)
    new["len"] = cache["len"].at[slot_ids].set(lengths)
    new["active"] = cache["active"].at[slot_ids].set(1)
    return new
