"""Scheduler-owned serving engine: request-level continuous batching
with horizon-fused decode.

The paper's deployment is real-time quantized translation; the TPU
counterpart is a fixed-slot continuous-batching decode loop over a
(possibly int8-quantized) KV cache. This module owns the whole serving
loop — admission queue, slot scheduling, prefill, fused sampling, and
EOS-aware retirement — behind three calls:

    rid  = engine.submit(inputs, SamplingParams(...))   # enqueue
    outs = engine.step()       # admit + one fused decode horizon
    outs = engine.run_until_drained()                   # serve everything

Streaming + the overlapped scheduler
------------------------------------
``submit(..., on_token=cb)`` registers a per-request streaming callback:
the engine fires it with each token id as the horizon block carrying
that token lands on the host (first token at prefill). ``stream()``
yields RequestOutputs as requests finish; ``stream_request()`` submits
one request and yields its tokens as they arrive.

Internally everything drains through ONE loop, ``_rounds()``: a
double-buffered step generator that dispatches horizon N+1 *before*
syncing horizon N's token block, using the scan's own final alive/rem
carry as the next scan's masks. JAX async dispatch makes this the whole
trick — the host walk of block N (retire/stream/admit, all Python) runs
while the device is already busy with N+1, and freed slots refill from
the prompt queue between dispatches instead of waiting for a drain
point. The in-scan retirement rule (EOS + budget) is exactly the rule
the host walk applies, so the device carry always equals the host's
post-walk view for continuing slots; slots admitted or aborted between
dispatches are merged in from host state (``_dirty_slots``). Token
streams are token-for-token identical to serial stepping at any horizon
— slots never attend to each other, so overlap moves *when* work
happens, never *what* is computed. ``run_until_drained`` is a thin
wrapper over this loop; ``overlap=False`` (or ``horizon=1``, or a draft
arm, whose speculative rounds are host decision points) degrades it to
the serial dispatch-then-walk order.

With ``sla=SLATarget(...)`` an ``SLAController`` folds every retired
request's TTFT/TPOT into a sliding window and retunes the effective
horizon and the paged prefill-group cap against the measured p95s (see
serving/metrics.py).

The horizon knob
----------------
``step(horizon=K)`` (default: the engine's ``horizon``, default 1) runs
``K`` decode+sample steps inside ONE jitted ``lax.scan`` and reads the
resulting ``(K, slots)`` token block back to the host ONCE, instead of
dispatching one jitted step and syncing one token at a time. The scan
threads the KV cache, per-slot current tokens, PRNG offsets, remaining
token budgets, and an alive-mask; a slot that emits ``eos_id`` or
exhausts ``max_new_tokens`` mid-horizon keeps decoding into masked
positions (its ``len`` freezes, it emits pad) until the horizon ends.
Paged caches are scan-safe because block tables are static across the
horizon: chains either hold the full budget at admission (draft-armed
engines) or are grown to cover the scan just before each dispatch
(on-demand engines — see _grow_chains).

What the knob trades: per-token host overhead (Python dispatch + one
device->host transfer per generated token) against admission latency —
retirement, page reclaim, and queue admission happen only at horizon
boundaries, so a freed slot can idle for up to ``K - 1`` micro-steps.
``horizon=1`` routes through the original per-token step and is
guaranteed token-for-token identical to previous releases (dense and
paged); ``horizon=K`` produces identical per-request token streams,
finish reasons, and stats — only the sync granularity changes.
``engine.decode_syncs`` / ``engine.mean_tokens_per_sync`` report how
much host traffic the fusion eliminated.

Fault tolerance
---------------
Every failure mode resolves to a typed RequestOutput finish reason —
nothing raises out of ``step()``/``stream()`` once a request is
admitted (see serving/params.py for the reason vocabulary):

  * **Deadlines** — ``SamplingParams.deadline_ms`` is checked at every
    round boundary against a host-side clock (no extra device sync);
    expired requests retire as ``deadline`` with their partial tokens
    and free their pages immediately, queued or in-flight.
  * **Backpressure** — ``max_pending`` bounds the admission queue;
    ``submit`` raises the typed ``EngineSaturated`` instead of letting
    an overload surface as an allocator error deep in a later step.
  * **On-demand paging + preemption** — paged target-only engines
    allocate prefill pages at admission and grow each chain just ahead
    of every dispatched horizon (``on_demand``); on pool exhaustion
    the lowest-priority / youngest request is preempted — tokens
    stashed host-side, chain freed, request requeued at the head — and
    later resumed by prefill-replay (teacher-forced prefill is
    bit-exact vs incremental decode and the PRNG stream is
    offset-indexed, so resumed streams are token-identical to an
    uncontended run). ``preempt_limit`` consecutive evictions retire
    the request as ``preempted_limit``. Draft-armed engines keep the
    whole-budget reservation (two rollback-symmetric chains per
    request make mid-decode growth a poor trade).
  * **Poisoned requests** — non-finite logits sample the ERR_TOKEN
    sentinel (see sampler.py); the host walk retires only that slot as
    ``error`` while the fused batch keeps decoding.
  * **Fault injection** — ``faults=FaultPlan(...)`` (serving/faults.py)
    deterministically injects pool exhaustion, NaN logits, and clock
    skew at chosen rounds/dispatches; counters land in EngineMetrics
    (``preemptions``, ``deadline_expirations``, ...).

Speculative decoding (``draft=DraftArm(...)``)
----------------------------------------------
With a draft arm (see spec_decode.py: the SAME checkpoint quantized at
an aggressive spec), every step whose active slots are all greedy runs
a *speculative round* instead: the draft arm proposes
``draft.lookahead`` tokens via the horizon scan, the target arm replays
them in ONE batched teacher-forced forward, and the longest matching
prefix (+ the target's token at the first divergence) is emitted —
1..K tokens per slot per round, token-for-token identical to
target-only greedy decoding. Any sampled request in the batch falls the
step back to the target-only path. Both arms keep per-slot caches
(paged engines: two chains per request out of ONE shared allocator,
freed together at retirement); a rejection rolls BOTH caches back to
the emitted length. ``acceptance_rate`` / ``mean_accepted_per_verify``
/ ``verify_calls`` report how much draft work converted into output.

Design notes:
  * One jitted fused decode+sample step (or K-step scan) serves every
    slot each tick; per-slot SamplingParams enter as traced arrays, so
    greedy and nucleus-sampled requests share a single executable per
    horizon length (see sampler.py).
  * Single-request prefills are padded to a small set of bucket lengths
    (powers of two up to ``max_len``) with per-sequence ``lengths``
    masking, so distinct prompt lengths stop triggering fresh XLA
    compiles; ``engine.prefill_compiles`` counts distinct compiled
    prefill shapes. (SSM/hybrid state caches have no position masking,
    so those families prefill at exact lengths.)
  * Slots retire as soon as the host sees ``eos_id`` or the
    ``max_new_tokens``-th token in the synced block; idle slots decode
    into masked positions (their ``len`` stays put) at negligible cost
    relative to the batched step.

``greedy_generate`` / ``translate`` remain as thin wrappers over a
single-shot engine so pre-request-API callers stay green.
"""

from __future__ import annotations

import collections
import dataclasses
import time
import warnings
from typing import Callable, Dict, Iterator, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..models.api import decode_block
from ..models.layers import Ctx
from ..obs import PHASES, SCHED_TID, Histogram, TraceConfig, Tracer
from ..parallel import (cache_shardings, paged_pool_shardings,
                        param_shardings, set_mesh)
from ..obs.metrics import render_prometheus
from .metrics import EngineMetrics, SLAController, SLATarget
from .paged_cache import TRASH_PAGE, PageAllocator, paged_insert, pages_needed
from .params import (GREEDY, EngineSaturated, Request, RequestOutput,
                     RequestStats, SamplingParams)
from .sampler import ERR_TOKEN, sample_tokens, sample_tokens_scan
from .spec_decode import DraftArm, accept_longest_prefix

__all__ = ["ServeEngine", "greedy_generate", "translate"]

# families safe to prefill right-padded: attention caches with pos/len
# masking AND token-only prompts (vlm logits interleave image patches, so
# the last-real-token index is not lengths-derived; ssm/hybrid recurrent
# states would absorb pad tokens)
_PAD_SAFE = ("dense", "moe", "encdec", "audio")


@dataclasses.dataclass
class _Slot:
    id: int
    tokens: list = dataclasses.field(default_factory=list)
    active: bool = False
    request: Optional[Request] = None
    seq: int = -1       # admission order (preemption picks the youngest)


class ServeEngine:
    """Fixed-slot continuous-batching engine with an internal queue.

    submit() enqueues a request (admitting it immediately if a slot is
    free); step() admits pending requests, runs one batched
    decode+sample step, retires finished slots, and returns their
    RequestOutputs; run_until_drained() loops step() until the queue
    and all slots are empty.

    The legacy slot-level surface (add_request / tick / result /
    free_slot) is kept as a thin shim over the request API.
    """

    def __init__(self, model, params, *, slots: int, max_len: int,
                 kv_dtype: str = "bf16", ctx: Optional[Ctx] = None,
                 paged: bool = False, page_size: int = 8,
                 num_pages: Optional[int] = None,
                 max_src_len: Optional[int] = None, horizon: int = 1,
                 draft: Optional[DraftArm] = None, overlap: bool = True,
                 sla: Optional[SLATarget] = None,
                 max_pending: Optional[int] = None,
                 preempt_limit: int = 3, faults=None, trace=None,
                 mesh=None):
        if horizon < 1:
            raise ValueError(f"horizon must be >= 1, got {horizon}")
        if max_pending is not None and max_pending < 1:
            raise ValueError(f"max_pending must be >= 1, got {max_pending}")
        if preempt_limit < 0:
            raise ValueError(
                f"preempt_limit must be >= 0, got {preempt_limit}")
        self.model = model
        self.params = params
        self.ctx = ctx or Ctx()
        # tensor-parallel mesh: params and KV storage are device_put
        # once at init under NamedSharding (no per-round resharding);
        # every jitted callable traces under set_mesh(self.mesh) so the
        # model's hint() constraints resolve against it
        self.mesh = mesh
        if mesh is not None:
            # TP-only weight sharding (fsdp_scope="none"): FSDP would
            # split contraction dims over the data axis, reordering
            # float accumulation enough to flip sampled tokens — the
            # engine's standing invariant is token-identical streams,
            # and inference weights are read-only so FSDP buys nothing
            self.params = jax.device_put(
                self.params,
                param_shardings(mesh, self.params, fsdp_scope="none"))
        self.kv_dtype = kv_dtype
        self.max_len = max_len
        self.n_slots = slots
        self.horizon = int(horizon)
        fam = model.cfg.family
        self.enc_cap = int(max_src_len or getattr(model.cfg, "enc_len", 0)
                           or 0)
        self.paged = bool(paged)
        self.draft = draft
        self.draft_cache = None
        if draft is not None and fam not in _PAD_SAFE:
            raise ValueError(
                f"speculative decoding supports families {_PAD_SAFE}, got "
                f"{fam!r} (the draft/verify scans need pos/len-masked "
                "attention caches)")
        if self.paged:
            if fam not in _PAD_SAFE:
                raise ValueError(
                    f"paged serving supports families {_PAD_SAFE}, got "
                    f"{fam!r} (recurrent state is O(1) per sequence; vlm "
                    "prompt lengths are not lengths-derived)")
            self.page_size = int(page_size)
            self.max_pages = pages_needed(max_len, self.page_size)
            # a draft arm doubles the default pool: both arms reserve a
            # full chain per request out of the SAME allocator id space
            usable = num_pages if num_pages is not None \
                else slots * self.max_pages * (2 if draft else 1)
            self.allocator = PageAllocator(usable + 1, reserved=1)
            if fam in ("encdec", "audio"):
                self.cache = model.init_paged_cache(
                    slots, self.max_pages, usable + 1, self.page_size,
                    kv_dtype, enc_len=self.enc_cap)
                if draft is not None:
                    self.draft_cache = model.init_paged_cache(
                        slots, self.max_pages, usable + 1, self.page_size,
                        draft.kv_dtype, enc_len=self.enc_cap)
            else:
                self.cache = model.init_paged_cache(
                    slots, self.max_pages, usable + 1, self.page_size,
                    kv_dtype)
                if draft is not None:
                    self.draft_cache = model.init_paged_cache(
                        slots, self.max_pages, usable + 1, self.page_size,
                        draft.kv_dtype)
            self._chains: Dict[int, list] = {}      # request id -> pages
            self._draft_chains: Dict[int, list] = {}
        else:
            if fam in ("encdec", "audio"):
                self.cache = model.init_cache(slots, max_len, kv_dtype,
                                              enc_len=self.enc_cap)
                if draft is not None:
                    self.draft_cache = model.init_cache(
                        slots, max_len, draft.kv_dtype, enc_len=self.enc_cap)
            else:
                self.cache = model.init_cache(slots, max_len, kv_dtype)
                if draft is not None:
                    self.draft_cache = model.init_cache(
                        slots, max_len, draft.kv_dtype)
        if mesh is not None:
            # one-time placement of the KV storage: paged pools shard
            # their head axes (block tables / allocator stay replicated
            # host state), dense caches shard per cache_shardings
            shard = paged_pool_shardings if self.paged else cache_shardings
            self.cache = jax.device_put(self.cache, shard(mesh, self.cache))
            if self.draft_cache is not None:
                self.draft_cache = jax.device_put(
                    self.draft_cache, shard(mesh, self.draft_cache))
        self.slots = [_Slot(i) for i in range(slots)]
        self.cur = jnp.zeros((slots, 1), jnp.int32)
        # per-slot sampling state — traced args of the fused step, so
        # mixed SamplingParams across slots share one executable
        self._temps = jnp.zeros((slots,), jnp.float32)
        self._top_ks = jnp.zeros((slots,), jnp.int32)
        self._top_ps = jnp.ones((slots,), jnp.float32)
        self._keys = jnp.zeros((slots, 2), jnp.uint32)
        self._offsets = jnp.zeros((slots,), jnp.int32)

        self._queue: collections.deque = collections.deque()
        self._finished: List[RequestOutput] = []
        self._next_id = 0
        self._stats: Dict[int, RequestStats] = {}
        self._last_admitted_slot = -1
        self._decode_steps = 0            # occupancy accounting
        self._active_slot_steps = 0
        self._page_slot_steps = 0
        self._decode_syncs = 0            # host-overhead accounting
        self._synced_tokens = 0
        self._verify_calls = 0            # speculative-decode accounting
        self._drafted = 0
        self._accepted = 0
        self._rejected = 0
        self.overlap = bool(overlap)      # dispatch horizon N+1 before
        self._overlap_rounds = 0          # ... syncing horizon N's block
        # slots (re)admitted since the last horizon dispatch: the carry
        # merge must take THEIR masks from host state, not the device
        self._dirty_slots: set = set()
        self.sla = (SLAController(sla, self.horizon, slots)
                    if sla is not None else None)
        # -- observability --------------------------------------------
        # trace is a Tracer, a TraceConfig (builds one), or None. Every
        # emission in the hot paths sits behind `if self.trace is not
        # None`, so the disabled path adds no allocations, clock reads,
        # or device syncs to the round loop.
        if isinstance(trace, TraceConfig):
            trace = Tracer(trace)
        self.trace: Optional[Tracer] = trace
        self._round_no = 0
        # ttft/tpot histograms record once per retirement — never in
        # the round loop — so latency percentiles in metrics() are free
        # and exist even when tracing is off. Phase timing fills only
        # under tracing (it needs extra perf_counter reads per phase).
        self._ttft_hist = Histogram()
        self._tpot_hist = Histogram()
        self._phase_ms: Dict[str, float] = dict.fromkeys(PHASES, 0.0)
        self._phase_hist: Dict[str, Histogram] = {p: Histogram()
                                                  for p in PHASES}
        # -- fault tolerance ------------------------------------------
        self.max_pending = max_pending    # bounded admission queue
        self.preempt_limit = int(preempt_limit)
        self.faults = faults              # FaultPlan (serving/faults.py)
        if faults is not None:
            faults.reset()                # one plan per engine, from 0
        self._skew_s = 0.0                # fault-injected clock skew
        # on-demand paging: target-only paged engines allocate prefill
        # pages at admission and grow chains per dispatched horizon; a
        # draft arm keeps the whole-budget reservation (two rollback-
        # symmetric chains per request make mid-decode growth moot)
        self.on_demand = self.paged and draft is None
        self._admit_seq = 0               # victim ordering (youngest)
        self._preempted: Dict[int, list] = {}       # rid -> stashed tokens
        self._preempt_counts: Dict[int, int] = {}   # rid -> eviction count
        self._flow_ids: Dict[int, int] = {}         # rid -> open trace flow
        self._disp_len: Dict[int, int] = {}  # slot -> dispatched positions
        self._no_poison = jnp.full((slots,), -1, jnp.int32)
        self._preemptions = 0
        self._resumed = 0
        self._deadline_expirations = 0
        self._admission_rejections = 0
        self._slot_errors = 0

        fam = model.cfg.family
        self._tkey = "tgt_in" if fam in ("encdec", "audio") else "tokens"
        self._bucketed = fam in _PAD_SAFE
        # dense attention caches accept an injected per-slot "active"
        # mask inside the horizon scan (paged caches carry one natively;
        # recurrent-state families neither need nor understand it — a
        # retired slot's state is resplice-overwritten at admission)
        self._mask_active = (not self.paged) and fam in _PAD_SAFE
        self._horizon_fns: Dict[int, Callable] = {}
        self.prefill_shapes: set = set()
        bucketed = self._bucketed

        def _prefill(p, batch, length, temp, top_k, top_p, key):
            one = model.init_cache(1, max_len, kv_dtype)
            one, logits = model.prefill(self.ctx, p, one, batch)
            # under bucketing the prompt is right-padded: the last real
            # token sits at length-1, not at the end of the logits
            last = logits[0, length - 1] if bucketed else logits[0, -1]
            last = last.astype(jnp.float32)
            tok = sample_tokens(last[None], temp[None], top_k[None],
                                top_p[None], key[None],
                                jnp.zeros((1,), jnp.int32))[0]
            return one, tok

        self._prefill_fn = self._jit(_prefill)

        def _step(p, cur, cache, temps, top_ks, top_ps, keys, offsets,
                  poison):
            cache, logits = model.decode_step(self.ctx, p, cur, cache)
            lg = logits[:, -1]
            # fault injection: slots the plan marked for this dispatch
            # read NaN logits — the sampler's guard turns that into the
            # ERR_TOKEN sentinel for that row only
            lg = jnp.where((poison == 0)[:, None], jnp.float32("nan"), lg)
            nxt = sample_tokens(lg, temps, top_ks, top_ps, keys, offsets)
            return cache, nxt

        self._step_fn = self._jit(_step)

        def _prefill_paged(p, inputs, lengths, slot_ids, page_rows, cache,
                           temps, top_ks, top_ps, keys):
            # one jitted call admits a whole group: batched prefill into a
            # prompt-sized dense mini-cache, fused first-token sampling,
            # then scatter of the mini-cache into page chains / cross rows
            n, s_bucket = inputs[self._tkey].shape
            mini = model.init_cache(n, s_bucket, kv_dtype)
            mini, logits = model.prefill(self.ctx, p, mini, inputs)
            last = logits[jnp.arange(n), lengths - 1].astype(jnp.float32)
            toks = sample_tokens(last, temps, top_ks, top_ps, keys,
                                 jnp.zeros((n,), jnp.int32))
            cache = paged_insert(cache, mini, slot_ids, page_rows, lengths)
            return cache, toks

        self._prefill_paged_fn = self._jit(_prefill_paged)

        if draft is not None:
            # the draft arm's prefill mirrors the target's but discards
            # the sampled token — the first emitted token is the TARGET
            # prefill's (exactness), the draft only warms its own cache
            def _draft_prefill(p, batch):
                one = model.init_cache(1, max_len, draft.kv_dtype)
                one, _ = model.prefill(draft.ctx, p, one, batch)
                return one

            self._draft_prefill_fn = self._jit(_draft_prefill)

            def _draft_prefill_paged(p, inputs, lengths, slot_ids,
                                     page_rows, cache):
                n, s_bucket = inputs[self._tkey].shape
                mini = model.init_cache(n, s_bucket, draft.kv_dtype)
                mini, _ = model.prefill(draft.ctx, p, mini, inputs)
                return paged_insert(cache, mini, slot_ids, page_rows,
                                    lengths)

            self._draft_prefill_paged_fn = self._jit(_draft_prefill_paged)

            # constant sampling args for the draft scan: temperature 0
            # everywhere makes sample_tokens_scan a pure greedy argmax
            self._z_f = jnp.zeros((slots,), jnp.float32)
            self._z_i = jnp.zeros((slots,), jnp.int32)
            self._o_f = jnp.ones((slots,), jnp.float32)
            self._z_keys = jnp.zeros((slots, 2), jnp.uint32)
            self._no_eos = jnp.full((slots,), -1, jnp.int32)
            self._draft_fns: Dict[int, Callable] = {}
            self._verify_fns: Dict[int, Callable] = {}

    def _jit(self, fn):
        """jax.jit with the engine mesh active at trace *and* call time.

        hint()/hint_pick() constraints inside the model resolve against
        the contextvar mesh when the function is traced, so a mesh-less
        engine compiles exactly the executable it always did (set_mesh
        is a no-op wrapper only for mesh-armed engines)."""
        jitted = jax.jit(fn)
        if self.mesh is None:
            return jitted
        mesh = self.mesh

        def call(*args, **kwargs):
            with set_mesh(mesh):
                return jitted(*args, **kwargs)

        return call

    # ------------------------------------------------------------------
    # request API
    # ------------------------------------------------------------------

    def submit(self, request, params: Optional[SamplingParams] = None, *,
               on_token: Optional[Callable[[int], None]] = None) -> int:
        """Enqueue a request; returns its request id.

        ``request`` is a Request or a B=1 model batch dict; ``params``
        overrides the request's SamplingParams (default: greedy). On a
        dense engine the request is admitted immediately when a slot is
        free; on a paged engine admission happens at the next step() so
        a burst of submits lands as one batched multi-slot prefill.

        ``on_token`` (or ``Request.on_token``) is the streaming hook:
        called with each generated token id as the horizon block
        carrying it lands on the host — the first token fires during
        prefill admission, before submit() even returns on a dense
        engine. Callbacks run on the scheduler walk; keep them cheap.

        With ``max_pending`` set, a full admission queue raises the
        typed ``EngineSaturated`` (backpressure: retry after a step /
        stream round drains the queue) instead of growing unboundedly
        and failing later in the allocator.
        """
        if self.max_pending is not None \
                and len(self._queue) >= self.max_pending:
            self._admission_rejections += 1
            raise EngineSaturated(len(self._queue), self.max_pending)
        if not isinstance(request, Request):
            request = Request(inputs=dict(request), params=params or GREEDY)
        elif params is not None:
            request = dataclasses.replace(request, params=params)
        if on_token is not None:
            request = dataclasses.replace(request, on_token=on_token)
        toks = jnp.asarray(request.inputs[self._tkey])
        if toks.ndim == 1:
            toks = toks[None]
        prompt_len = int(toks.shape[1])
        budget = prompt_len + request.params.max_new_tokens
        if budget > self.max_len:
            raise ValueError(
                f"request needs prompt_len + max_new_tokens = {prompt_len} + "
                f"{request.params.max_new_tokens} = {budget} cache positions "
                f"but the engine was built with max_len={self.max_len}; "
                f"shorten the request or deploy with a larger max_len")
        if self.paged:
            arms = 2 if self.draft is not None else 1
            need = pages_needed(budget, self.page_size) * arms
            usable = self.allocator.capacity - self.allocator.reserved
            if need > usable:
                # fail fast: an unfittable reservation would block the
                # FIFO admission head forever, not just wait its turn
                raise ValueError(
                    f"request needs {need} KV pages"
                    + (" (target + draft arms)" if arms == 2 else "")
                    + f" but the pool holds only {usable}; deploy with "
                    f"num_pages>={need} or shorten the request")
        se = self._src_len(request.inputs)
        if se is not None and se > self.enc_cap:
            # shorter sources are fine (the per-slot cross cache is
            # allocated at enc_cap and masked by cross_len); longer ones
            # cannot fit the allocated cross-attention leaves
            raise ValueError(
                f"source length {se} exceeds the engine's cross-attention "
                f"capacity {self.enc_cap}; deploy with max_src_len>="
                f"{se} or shorten the source")
        request = dataclasses.replace(
            request, inputs={**request.inputs, self._tkey: toks},
            id=self._next_id)
        self._next_id += 1
        arrival = self._now()
        self._stats[request.id] = RequestStats(
            arrival_s=arrival, prompt_len=prompt_len)
        if self.trace is not None:
            tid = request.id + 1
            self.trace.name_track(tid, f"req {request.id}")
            self.trace.begin(tid, "request", arrival, rid=request.id,
                             prompt_len=prompt_len,
                             max_new_tokens=request.params.max_new_tokens)
            self.trace.begin(tid, "queued", arrival)
        self._queue.append(request)
        if not self.paged:          # paged admission batches at step()
            self._admit_pending()
        return request.id

    def step(self, horizon: Optional[int] = None) -> List[RequestOutput]:
        """Admit pending requests, run one fused decode horizon, and
        return the RequestOutputs of every request finished this step.

        ``horizon=K`` fuses K decode+sample micro-steps into one jitted
        ``lax.scan`` and syncs the (K, slots) token block to the host
        once; ``horizon=1`` (and the engine default unless constructed
        otherwise) is the original per-token step, token-for-token
        identical to previous releases. The scan length is clamped to
        the power-of-two bucket of the largest remaining token budget
        among active slots, so an over-long horizon costs masked
        micro-steps only up to that bucket, never the full K. Admission
        is continuous but horizon-granular: every step first drains as
        much of the queue as freed slots (and, when paged, freed pages)
        allow, so slots refill at horizon boundaries instead of waiting
        for a full drain."""
        K = self._effective_horizon(horizon)
        if self.trace is not None:
            self._round_begin()
        self._round_boundary()
        n_active = sum(s.active for s in self.slots)
        if self._speculate_now():
            self._spec_round()
        elif n_active and K == 1:
            self._token_step()
        elif n_active:
            # clamp the scan to the (power-of-two-bucketed) largest
            # remaining budget among active slots: an over-long horizon
            # must not burn batched micro-steps every slot has already
            # retired out of, and bucketing keeps compiled scan lengths
            # bounded by log2(max_len), not one per distinct budget
            _, _, block, Kd, seqs = self._dispatch_horizon(
                min(K, self._bucket(self._max_rem())))
            self._walk_block(block, Kd, seqs)
        if self.trace is not None:
            self._round_end()
        return self._take_finished()

    def run_until_drained(self, max_steps: int = 1_000_000,
                          horizon: Optional[int] = None
                          ) -> List[RequestOutput]:
        """Serve every queued/in-flight request; returns all outputs.

        Thin wrapper over the overlapped round loop (``_rounds``):
        token-for-token identical to serial stepping at any horizon,
        but the host walk of each synced block runs while the next
        horizon is already dispatched on device (``overlap=False``
        restores the serial order). ``horizon`` overrides the engine
        default for every round."""
        outs: List[RequestOutput] = list(self._take_finished())
        for _ in self._rounds(horizon, max_rounds=max_steps):
            outs.extend(self._take_finished())
        outs.extend(self._take_finished())
        return outs

    def stream(self, horizon: Optional[int] = None,
               on_round: Optional[Callable[[], None]] = None,
               max_rounds: int = 1_000_000
               ) -> Iterator[RequestOutput]:
        """Serve until drained, yielding each RequestOutput as its
        request finishes (same overlapped loop as run_until_drained).

        ``on_round`` is called once after every scheduler round —
        external drivers inject new arrivals there (bench_serving
        ``--rate`` submits its Poisson arrivals from it), and work
        submitted by the callback keeps the loop alive. Note the
        callback never fires on an engine that is already drained at
        call time (the loop exits before its first round)."""
        yield from self._take_finished()
        for _ in self._rounds(horizon, max_rounds=max_rounds):
            if on_round is not None:
                on_round()
            yield from self._take_finished()
        yield from self._take_finished()

    def stream_request(self, request,
                       params: Optional[SamplingParams] = None,
                       horizon: Optional[int] = None) -> Iterator[int]:
        """Submit ONE request and yield its token ids as each horizon
        block lands; the finished RequestOutput is the generator's
        return value (``StopIteration.value``).

        Other in-flight requests keep being served while this one
        streams — their outputs stay claimable via run_until_drained()
        / stream(). If the request is aborted externally mid-stream the
        generator ends and returns None (abort() hands the output to
        its own caller)."""
        buf: List[int] = []
        rid = self.submit(request, params, on_token=buf.append)

        def claim():
            for i, o in enumerate(self._finished):
                if o.request_id == rid:
                    return self._finished.pop(i)
            return None

        out = claim()       # dense prefill may already have finished it
        while buf:
            yield buf.pop(0)
        rounds = self._rounds(horizon)
        try:
            while out is None:
                try:
                    next(rounds)
                except (StopIteration, RuntimeError):
                    break   # drained (abort) or round budget exhausted
                while buf:
                    yield buf.pop(0)
                out = claim()
        finally:
            # closing the round loop walks any dispatched-ahead block,
            # so other slots' synced tokens are never dropped
            rounds.close()
        while buf:
            yield buf.pop(0)
        return out

    def serve_rounds(self, horizon: Optional[int] = None,
                     max_rounds: int = 1_000_000) -> Iterator[None]:
        """Round-granular view of the overlapped scheduler loop: each
        ``next()`` advances exactly one round (admit / dispatch-ahead /
        sync+walk) and finished outputs accumulate for
        :meth:`take_finished`. This is the cluster router's drain
        primitive — interleaving several replicas' generators means
        each host sync of one replica happens while every OTHER
        replica's dispatched horizon is still running on its own
        devices. Closing the generator early walks any
        dispatched-ahead block, leaving host state consistent."""
        return self._rounds(horizon, max_rounds=max_rounds)

    def take_finished(self) -> List[RequestOutput]:
        """Claim (and clear) the outputs of every request that finished
        since the last claim — the companion to :meth:`serve_rounds`
        (``step``/``run_until_drained``/``stream`` claim internally)."""
        return self._take_finished()

    def _take_finished(self) -> List[RequestOutput]:
        out, self._finished = self._finished, []
        return out

    def _now(self) -> float:
        """The engine clock: wall time plus any fault-injected skew
        (FaultPlan deadline tests advance time without sleeping)."""
        return time.perf_counter() + self._skew_s

    def _phase_done(self, phase: str, t0: float, **args) -> None:
        """Close one scheduler phase (tracing enabled only): accumulate
        its wall duration and emit the complete event. Durations come
        from raw perf_counter deltas so a fault-injected skew jump
        inside a phase (faults tick during "admit") cannot inflate it;
        the event timestamp is anchored on the engine clock so the
        trace timeline still shows the skew."""
        dur = time.perf_counter() - t0
        self._phase_ms[phase] += dur * 1e3
        self._phase_hist[phase].record(dur * 1e3)
        self.trace.complete(SCHED_TID, phase, self._now() - dur, dur, **args)

    def _round_begin(self) -> None:
        self._round_no += 1
        self.trace.begin(SCHED_TID, "round", self._now(), n=self._round_no)

    def _round_end(self) -> None:
        self.trace.end(SCHED_TID, "round", self._now())

    def _round_boundary(self) -> None:
        """Host-side work at every scheduler round boundary: tick the
        fault plan (release/steal pages, skew the clock), expire
        deadlines, then admit from the queue. Runs on no-op rounds too,
        so transient faults clear and expired queued requests drain
        even when nothing is decoding."""
        tr = self.trace
        t0 = time.perf_counter() if tr is not None else 0.0
        if self.faults is not None:
            self.faults.on_round(self)
        self._expire_deadlines()
        self._admit_pending()
        if tr is not None:
            self._phase_done("admit", t0)

    def _deadline_passed(self, request: Request, now: float) -> bool:
        dl = request.params.deadline_ms
        if dl is None:
            return False
        return (now - self._stats[request.id].arrival_s) * 1e3 > dl

    def _expire_deadlines(self) -> None:
        """Retire every request (active or queued) whose deadline_ms
        elapsed — a pure host-clock compare at round boundaries, no
        extra device sync. Active slots free their pages through the
        ordinary _retire path; their tokens are truncated at the last
        synced position exactly like an abort."""
        now = self._now()
        for s in self.slots:
            if s.active and self._deadline_passed(s.request, now):
                self._retire(s, "deadline")
        if self._queue:
            keep = collections.deque()
            for r in self._queue:
                if self._deadline_passed(r, now):
                    self._finished.append(self._finish_queued(r, "deadline"))
                else:
                    keep.append(r)
            self._queue = keep

    def _finish_queued(self, r: Request, reason: str) -> RequestOutput:
        """Finish a request that is not (or no longer) in a slot —
        queued at expiry/abort, possibly with tokens stashed from an
        earlier preemption."""
        st = self._stats.pop(r.id)
        toks = self._preempted.pop(r.id, [])
        self._preempt_counts.pop(r.id, None)
        fid = self._flow_ids.pop(r.id, None)
        st.finished_s = self._now()
        if st.first_token_s == 0.0:
            st.first_token_s = st.finished_s
        st.new_tokens = len(toks)
        if reason == "deadline":
            self._deadline_expirations += 1
        if self.trace is not None:
            tid = r.id + 1
            self.trace.end(tid, "queued", st.finished_s)
            if fid is not None:
                # stashed request died before its resume: terminate the
                # residency link at the retirement instead
                self.trace.flow_end(tid, "resume", st.finished_s, fid,
                                    reason=reason)
            if reason == "deadline":
                self.trace.instant(tid, "deadline", st.finished_s)
            self.trace.instant(tid, "retired", st.finished_s,
                               reason=reason, tokens=st.new_tokens)
            self.trace.end(tid, "request", st.finished_s)
        return RequestOutput(r.id, r.inputs, list(toks), reason, st)

    def _effective_horizon(self, horizon: Optional[int]) -> int:
        """Resolve one round's horizon: explicit arg > SLA controller >
        engine default."""
        if horizon is not None:
            K = int(horizon)
        elif self.sla is not None:
            K = self.sla.horizon
        else:
            K = self.horizon
        if K < 1:
            raise ValueError(f"horizon must be >= 1, got {K}")
        return K

    def _speculate_now(self) -> bool:
        # speculative rounds need exact-match acceptance, which only
        # reproduces greedy sampling: any sampled request in the batch
        # falls the whole step back to the target-only path (the draft
        # cache goes stale — harmless, verification is target-owned)
        return (self.draft is not None
                and any(s.active for s in self.slots)
                and all(s.request.params.greedy
                        for s in self.slots if s.active))

    def _max_rem(self) -> int:
        """Largest remaining token budget among active slots (host view)."""
        rems = [s.request.params.max_new_tokens - len(s.tokens)
                for s in self.slots if s.active]
        return max(rems) if rems else 0

    def _emit(self, s: _Slot, tok: int, synced: bool = True) -> None:
        """Deliver one token to a slot's request: append, count, fire
        the streaming callback, retire on EOS/budget. ``synced=False``
        marks the prefill-produced first token (it never crossed the
        decode sync path). The ERR_TOKEN sentinel (non-finite logits —
        see sampler.py) is never delivered: it retires ONLY this slot
        with finish_reason "error" and its partial tokens, while the
        rest of the fused batch keeps decoding."""
        if tok == ERR_TOKEN:
            self._retire(s, "error")
            return
        s.tokens.append(tok)
        if synced:
            self._synced_tokens += 1
        cb = s.request.on_token
        if cb is not None:
            cb(tok)
        if s.active:    # the callback may have aborted its own request
            self._maybe_retire(s)

    def _token_step(self) -> None:
        """The legacy horizon=1 path: one fused decode+sample dispatch,
        one host sync per token."""
        tr = self.trace
        t0 = time.perf_counter() if tr is not None else 0.0
        self._grow_chains(1)
        self._decode_steps += 1
        self._active_slot_steps += sum(s.active for s in self.slots)
        if self.paged:
            self._page_slot_steps += self.allocator.pages_in_use
        self.cache, nxt = self._step_fn(
            self.params, self.cur, self.cache, self._temps,
            self._top_ks, self._top_ps, self._keys, self._offsets,
            self._poison_arr(1))
        self._note_dispatched(1)
        self.cur = nxt[:, None]
        self._offsets = self._offsets + 1
        if tr is not None:
            self._phase_done("dispatch", t0, K=1)
            t0 = time.perf_counter()
        self._decode_syncs += 1
        nxt_host = np.asarray(nxt)          # one sync per token
        if tr is not None:
            self._phase_done("sync", t0, K=1)
            t0 = time.perf_counter()
        for s in self.slots:
            if s.active:
                if tr is not None:
                    tr.instant(s.request.id + 1, "decode-round",
                               self._now(), planned=1)
                self._emit(s, int(nxt_host[s.id]))
        if tr is not None:
            self._phase_done("walk", t0)

    def _dispatch_horizon(self, K: int, carry=None):
        """Dispatch one K-step fused horizon WITHOUT syncing its block.

        Returns ``(alive, rem, block, K)`` — all device handles except
        K. ``carry=None`` builds the scan masks from host slot state
        (the serial path). ``carry=(alive, rem)`` reuses the previous
        dispatch's device-side final carry, so this scan launches while
        the host is still walking that block: the in-scan retirement
        rule computes exactly the alive/rem the host walk will arrive
        at for continuing slots. Slots touched since that dispatch are
        merged from host state — fresh admissions override with their
        own masks (the carry says dead), aborts force alive to 0 via
        the min (their in-flight micro-steps waste masked compute
        only). eos/sampling arrays are always host-rebuilt: stale
        values sit behind a zero alive mask.

        On-demand paged engines first grow every active chain to cover
        the K micro-steps (preempting victims on exhaustion — see
        _grow_chains), so block tables are static across the scan
        whichever allocation mode is live.
        """
        tr = self.trace
        t0 = time.perf_counter() if tr is not None else 0.0
        self._grow_chains(K)
        self._decode_steps += K
        if self.paged:
            self._page_slot_steps += K * self.allocator.pages_in_use
        fn = self._horizon_fns.get(K)
        if fn is None:
            fn = self._horizon_fns[K] = self._make_horizon_fn(K)
        alive_h, rem_h, eos = self._scan_masks()
        if carry is None:
            alive, rem = alive_h, rem_h
        else:
            alive_c, rem_c = carry
            fresh = np.zeros((self.n_slots,), bool)
            for sid in self._dirty_slots:
                fresh[sid] = True
            fresh = jnp.asarray(fresh)
            alive = jnp.where(fresh, alive_h, jnp.minimum(alive_c, alive_h))
            rem = jnp.where(fresh, rem_h, rem_c)
        self._dirty_slots.clear()
        # dispatch-time occupancy snapshot: which request generation
        # each slot row of this block belongs to (see _walk_block)
        seqs = tuple(s.seq if s.active else -1 for s in self.slots)
        self.cache, self.cur, self._offsets, alive_o, rem_o, block = fn(
            self.params, self.cur, self.cache, self._temps, self._top_ks,
            self._top_ps, self._keys, self._offsets, alive, rem, eos,
            self._poison_arr(K))
        self._note_dispatched(K)
        if tr is not None:
            self._phase_done("dispatch", t0, K=K)
        return alive_o, rem_o, block, K, seqs

    def _walk_block(self, block, K: int, seqs=None) -> None:
        """Sync one dispatched (K, slots) token block and walk it on
        the host: emit/stream/retire exactly as the serial horizon
        path. A block every slot already retired out of (possible for a
        dispatched-ahead horizon that an EOS invalidated) is dropped
        without syncing.

        ``seqs`` is the per-slot admission-sequence snapshot taken when
        the block was dispatched: a slot's rows are walked only if its
        CURRENT occupant is the same request generation the block was
        computed for. Between dispatch and walk the occupant can change
        — retire on deadline, get aborted, or be preempted for pages,
        with a new request (or the same one, resumed) admitted into the
        freed slot — and without the gate the new occupant would swallow
        the stale rows (pads after an in-scan retirement, or the dead
        request's never-observed continuation after an abort)."""
        eligible = [s for s in self.slots
                    if s.active and (seqs is None or seqs[s.id] == s.seq)]
        if not eligible:
            return
        tr = self.trace
        t0 = time.perf_counter() if tr is not None else 0.0
        self._decode_syncs += 1
        blk = np.asarray(block)             # one sync per horizon
        if tr is not None:
            self._phase_done("sync", t0, K=K)
            t0 = time.perf_counter()
        for s in eligible:
            if not s.active:    # retired by a groupmate's callback mid-walk
                continue
            if tr is not None:
                tr.instant(s.request.id + 1, "decode-round", self._now(),
                           planned=K)
            for t in range(K):              # walk until retirement
                self._active_slot_steps += 1
                self._emit(s, int(blk[t, s.id]))
                if not s.active:
                    break
        if tr is not None:
            self._phase_done("walk", t0)

    def _ahead_horizon(self, K_cfg: int, Kd: int) -> int:
        """Length of the next scan to dispatch before walking the
        in-flight Kd-step block, or 0 to stay serial. Dispatch-ahead
        only pays when some slot's budget outlasts the in-flight block
        (otherwise the extra scan is all-masked waste and would skew
        sync counts vs the serial engine); a draft arm disables it —
        speculative rounds are host decision points and remain the
        faster path for greedy batches."""
        if not self.overlap or K_cfg <= 1 or self.draft is not None:
            return 0
        rem_after = self._max_rem() - Kd
        if rem_after <= 0:
            return 0
        return min(K_cfg, self._bucket(rem_after))

    def _rounds(self, horizon: Optional[int] = None,
                max_rounds: int = 1_000_000) -> Iterator[None]:
        """The overlapped scheduler loop; yields once per round.

        Round shape: admit pending prompts into freed slots, then —
        with a block in flight — dispatch the NEXT horizon from the
        in-flight scan's device carry and only then sync+walk the
        block, so the Python walk (retire, stream callbacks, admission)
        overlaps the device's work on horizon N+1. Speculative rounds
        and horizon=1 run serially through their legacy paths (still
        streaming). Finished outputs accumulate in ``_finished`` for
        the caller to claim between rounds; closing the generator early
        walks any dispatched-ahead block first, so engine host state
        stays consistent with the device."""
        pending = None
        rounds = 0
        try:
            while True:
                tr = self.trace
                if tr is not None:
                    self._round_begin()
                self._round_boundary()
                if (pending is None and not self._queue
                        and not any(s.active for s in self.slots)):
                    if tr is not None:
                        self._round_end()
                    return
                rounds += 1
                if rounds > max_rounds:
                    if tr is not None:
                        self._round_end()
                    raise RuntimeError("run_until_drained did not converge")
                if pending is not None:
                    alive_d, rem_d, block, Kd, seqs = pending
                    pending = None
                    nk = self._ahead_horizon(
                        self._effective_horizon(horizon), Kd)
                    if nk:
                        pending = self._dispatch_horizon(
                            nk, carry=(alive_d, rem_d))
                        self._overlap_rounds += 1
                    self._walk_block(block, Kd, seqs)
                elif any(s.active for s in self.slots):
                    K = self._effective_horizon(horizon)
                    if self._speculate_now():
                        self._spec_round()
                    elif K == 1:
                        self._token_step()
                    else:
                        pending = self._dispatch_horizon(
                            min(K, self._bucket(self._max_rem())))
                        if not self.overlap:
                            _, _, block, Kd, seqs = pending
                            pending = None
                            self._walk_block(block, Kd, seqs)
                # else: queue blocked with nothing active — a no-op
                # round; the round budget turns a livelock into the
                # legacy non-convergence error
                if tr is not None:
                    self._round_end()
                yield
        finally:
            if pending is not None:
                self._walk_block(pending[2], pending[3], pending[4])

    def abort(self, request_id: int) -> Optional[RequestOutput]:
        """Cancel a queued or in-flight request. Returns its output
        (finish_reason 'abort') directly, or None if unknown.

        Under horizon-fused decode the request's tokens are truncated
        at the last *synced* position (slot token lists only ever hold
        synced tokens — any micro-steps the device ran past that point
        were never observed and are discarded); the page chain is freed
        exactly once, by the same _retire path every finish reason
        uses — a second abort of the same id returns None instead of
        double-freeing. A queued request that was previously preempted
        returns its stashed tokens; one still waiting in an admission
        group's activation loop is found active (every group slot goes
        live before any first-token callback fires — see
        _admit_group), so callback-driven aborts of groupmates retire
        them instead of leaving a dead slot to be served then thrown
        away."""
        for i, r in enumerate(self._queue):
            if r.id == request_id:
                del self._queue[i]
                return self._finish_queued(r, "abort")
        for s in self.slots:
            if s.active and s.request.id == request_id:
                self._retire(s, "abort")
                return self._finished.pop()
        return None

    @property
    def num_pending(self) -> int:
        return len(self._queue)

    @property
    def num_active(self) -> int:
        return sum(s.active for s in self.slots)

    @property
    def prefill_compiles(self) -> int:
        """Distinct prefill shapes compiled so far (bucketing keeps this
        bounded by the bucket count, not the number of prompt lengths)."""
        return len(self.prefill_shapes)

    def metrics(self) -> EngineMetrics:
        """One frozen snapshot of every engine counter, ratio, and
        gauge — the single read surface for benchmarks, the eval suite,
        and launchers (the individual properties remain for
        back-compat)."""
        return EngineMetrics(
            decode_steps=self._decode_steps,
            decode_syncs=self._decode_syncs,
            synced_tokens=self._synced_tokens,
            active_slot_steps=self._active_slot_steps,
            page_slot_steps=self._page_slot_steps,
            overlap_rounds=self._overlap_rounds,
            verify_calls=self._verify_calls,
            drafted_tokens=self._drafted,
            accepted_tokens=self._accepted,
            rejected_tokens=self._rejected,
            preemptions=self._preemptions,
            resumed_requests=self._resumed,
            deadline_expirations=self._deadline_expirations,
            admission_rejections=self._admission_rejections,
            slot_errors=self._slot_errors,
            mean_tokens_per_sync=self.mean_tokens_per_sync,
            occupancy=self.occupancy,
            page_utilization=self.page_utilization,
            acceptance_rate=self.acceptance_rate,
            mean_accepted_per_verify=self.mean_accepted_per_verify,
            ttft_p50_ms=round(self._ttft_hist.percentile(50.0), 4),
            ttft_p95_ms=round(self._ttft_hist.percentile(95.0), 4),
            tpot_p50_ms=round(self._tpot_hist.percentile(50.0), 4),
            tpot_p95_ms=round(self._tpot_hist.percentile(95.0), 4),
            phase_admit_ms=round(self._phase_ms["admit"], 4),
            phase_dispatch_ms=round(self._phase_ms["dispatch"], 4),
            phase_sync_ms=round(self._phase_ms["sync"], 4),
            phase_walk_ms=round(self._phase_ms["walk"], 4),
            kv_cache_bytes=self.kv_cache_bytes,
            prefill_compiles=self.prefill_compiles)

    def prometheus(self) -> str:
        """Prometheus text exposition of the current metrics()
        snapshot plus the latency and round-phase histograms (bucket
        series are only non-empty where the engine recorded: ttft/tpot
        always, phases on traced engines)."""
        hists = {"ttft_ms": self._ttft_hist, "tpot_ms": self._tpot_hist}
        for p in PHASES:
            hists[f"round_phase_{p}_ms"] = self._phase_hist[p]
        return render_prometheus(self.metrics(), hists)

    def latency_histograms(self) -> Dict[str, Histogram]:
        """The live TTFT/TPOT Histogram accumulators (one sample per
        retirement since the last reset). Cluster-level aggregation
        merges these across replicas via ``Histogram.merge`` — merge
        into a fresh ``Histogram()``, never in place, or the replica's
        own percentiles double-count."""
        return {"ttft_ms": self._ttft_hist, "tpot_ms": self._tpot_hist}

    def reset_metrics(self) -> None:
        """Zero every EngineMetrics counter (occupancy/page-utilization/
        host-sync/overlap/speculative-decode accumulators — e.g. after a
        warmup pass, so reported numbers cover only the measured run).
        The EngineMetrics.GAUGES fields are live state, not accumulation,
        and are unaffected."""
        self._decode_steps = 0
        self._active_slot_steps = 0
        self._page_slot_steps = 0
        self._decode_syncs = 0
        self._synced_tokens = 0
        self._overlap_rounds = 0
        self._verify_calls = 0
        self._drafted = 0
        self._accepted = 0
        self._rejected = 0
        self._preemptions = 0
        self._resumed = 0
        self._deadline_expirations = 0
        self._admission_rejections = 0
        self._slot_errors = 0
        self._ttft_hist.reset()
        self._tpot_hist.reset()
        self._phase_ms = dict.fromkeys(PHASES, 0.0)
        for h in self._phase_hist.values():
            h.reset()

    @property
    def preemptions(self) -> int:
        """Requests evicted from a slot for page pressure (each either
        resumed later via prefill-replay or, past preempt_limit,
        retired as 'preempted_limit')."""
        return self._preemptions

    @property
    def resumed_requests(self) -> int:
        """Preempted requests re-admitted via prefill-replay."""
        return self._resumed

    @property
    def deadline_expirations(self) -> int:
        """Requests retired because deadline_ms elapsed."""
        return self._deadline_expirations

    @property
    def admission_rejections(self) -> int:
        """submit() calls bounced with EngineSaturated (max_pending)."""
        return self._admission_rejections

    @property
    def slot_errors(self) -> int:
        """Slots failed by the non-finite-logits guard (finish_reason
        'error') while their batch kept decoding."""
        return self._slot_errors

    @property
    def overlap_rounds(self) -> int:
        """Rounds where the next horizon was dispatched before the
        previous block's host sync — each one is a host walk whose cost
        the device hid behind real work (the overlap tripwire metric)."""
        return self._overlap_rounds

    @property
    def verify_calls(self) -> int:
        """Speculative verify rounds run — each is ONE batched target
        forward over a drafted block, the denominator of the
        forwards-per-token win speculation exists to deliver."""
        return self._verify_calls

    @property
    def drafted_tokens(self) -> int:
        return self._drafted

    @property
    def accepted_tokens(self) -> int:
        return self._accepted

    @property
    def rejected_tokens(self) -> int:
        return self._rejected

    @property
    def acceptance_rate(self) -> float:
        """Fraction of drafted tokens the target verify accepted (the
        draft-quality metric; 0.0 before any speculative round)."""
        if not self._drafted:
            return 0.0
        return self._accepted / self._drafted

    @property
    def mean_accepted_per_verify(self) -> float:
        """Accepted draft tokens per verify round, summed over slots —
        how much draft work each batched target forward converts into
        output (on top of the 1 token/slot a round always emits)."""
        if not self._verify_calls:
            return 0.0
        return self._accepted / self._verify_calls

    @property
    def decode_steps(self) -> int:
        """Decode micro-steps the engine has run (each processes one
        token position per slot through the target or draft model). At
        horizon=1 on a target-only engine this equals the number of
        batched target-model forward dispatches — the baseline the
        speculative ``verify_calls`` count is measured against."""
        return self._decode_steps

    @property
    def decode_syncs(self) -> int:
        """Device->host syncs the decode loop has performed: one per
        step() at horizon=1, one per *horizon* when fused — the
        dispatch-overhead metric the horizon knob exists to shrink."""
        return self._decode_syncs

    @property
    def mean_tokens_per_sync(self) -> float:
        """Generated tokens delivered per host sync. At horizon=1 this
        is the mean number of busy slots (each sync carries one token
        per active slot); fusing multiplies it by up to the horizon —
        compare runs at equal occupancy to isolate the fusion win."""
        if not self._decode_syncs:
            return 0.0
        return self._synced_tokens / self._decode_syncs

    @property
    def occupancy(self) -> float:
        """Mean fraction of decode slots active per step served so far."""
        if not self._decode_steps:
            return 0.0
        return self._active_slot_steps / (self._decode_steps * self.n_slots)

    @property
    def page_utilization(self) -> float:
        """Mean fraction of the page pool in use per decode step."""
        if not self.paged or not self._decode_steps:
            return 0.0
        usable = self.allocator.capacity - self.allocator.reserved
        return self._page_slot_steps / (self._decode_steps * usable)

    @property
    def kv_cache_bytes(self) -> int:
        """Allocated KV-cache storage (the paged/dense memory knob),
        including the draft arm's cache when speculating."""
        total = 0
        for leaf in jax.tree_util.tree_leaves(self.cache):
            total += leaf.size * leaf.dtype.itemsize
        if self.draft_cache is not None:
            for leaf in jax.tree_util.tree_leaves(self.draft_cache):
                total += leaf.size * leaf.dtype.itemsize
        return total

    # ------------------------------------------------------------------
    # legacy slot-level surface (kept for pre-request-API callers)
    # ------------------------------------------------------------------

    def add_request(self, batch_one: dict, gen_tokens: int) -> int:
        """Legacy: greedy request into a free slot; returns the slot id."""
        # queued work would claim the free slot first: admission wouldn't
        # be synchronous, so the legacy contract can't be honoured
        if self._queue or self.free_slot() is None:
            raise RuntimeError("no free slots")
        rid = self.submit(batch_one, SamplingParams(max_new_tokens=gen_tokens))
        if self.paged:
            self._admit_pending()        # legacy contract: admit now
        if self._queue:                  # paged: page pool exhausted
            self.abort(rid)
            raise RuntimeError("no free pages")
        return self._last_admitted_slot

    def tick(self) -> List[int]:
        """Legacy: one step; returns the slot ids finished this step."""
        return [o.slot for o in self.step()]

    def result(self, slot: int) -> list:
        """Legacy: generated token ids of the request last served in
        ``slot`` (also available on RequestOutput.token_ids)."""
        return self.slots[slot].tokens

    def free_slot(self) -> Optional[int]:
        for s in self.slots:
            if not s.active:
                return s.id
        return None

    # ------------------------------------------------------------------
    # internals
    # ------------------------------------------------------------------

    def _scan_masks(self):
        """Per-slot (alive, remaining-budget, eos-id) arrays for one
        horizon, rebuilt from host slot state at every boundary (all
        traced args — values never trigger a recompile)."""
        alive = np.zeros((self.n_slots,), np.int32)
        rem = np.zeros((self.n_slots,), np.int32)
        eos = np.full((self.n_slots,), -1, np.int32)
        for s in self.slots:
            if not s.active:
                continue
            sp = s.request.params
            alive[s.id] = 1
            rem[s.id] = sp.max_new_tokens - len(s.tokens)
            if sp.eos_id is not None:
                eos[s.id] = sp.eos_id
        return jnp.asarray(alive), jnp.asarray(rem), jnp.asarray(eos)

    def _make_horizon_fn(self, K: int, ctx: Optional[Ctx] = None):
        """Compile the K-step fused decode scan.

        Carry: (cache, cur, offsets, alive, rem); emits the (K, slots)
        token block the host syncs once per horizon. Retirement is an
        in-scan mask: a slot that emits its eos_id or exhausts its
        budget keeps decoding into masked positions (``active`` -> 0
        freezes its ``len`` and, when paged, routes its writes to the
        trash page) and pads the rest of its block row. Block tables
        are static across the scan — every admitted request holds its
        full page budget (see _request_pages).

        ``ctx`` overrides the engine Ctx — the speculative draft scan
        reuses this exact compiled shape against the draft arm's ctx,
        params, and cache (params and cache are traced arguments).

        The scan's FINAL alive/rem carry is returned alongside the
        block: it equals the host's post-walk view of the slots (same
        EOS/budget rule), which is what lets the overlapped loop
        dispatch horizon N+1 from it before the host has walked N.
        """
        model, ctx = self.model, ctx or self.ctx
        set_active = self._mask_active or self.paged
        strip_active = self._mask_active   # dense caches: key is transient

        def _horizon(p, cur, cache, temps, top_ks, top_ps, keys, offsets,
                     alive, rem, eos_ids, poison):
            def body(carry, i):
                cache, cur, offsets, alive, rem = carry
                if set_active:
                    cache = dict(cache, active=alive)
                cache, logits = model.decode_step(ctx, p, cur, cache)
                if strip_active:
                    cache = {k: v for k, v in cache.items() if k != "active"}
                lg = logits[:, -1]
                # fault injection: slots scheduled for micro-step i read
                # NaN logits; the sampler guard emits ERR_TOKEN for that
                # row only, and the in-scan retirement below kills the
                # slot exactly like the host walk will
                lg = jnp.where((poison == i)[:, None], jnp.float32("nan"),
                               lg)
                tok = sample_tokens_scan(lg, temps, top_ks,
                                         top_ps, keys, offsets, alive)
                rem = rem - alive
                hit_eos = (alive > 0) & (eos_ids >= 0) & (tok == eos_ids)
                alive = jnp.where(hit_eos | (rem <= 0) | (tok == ERR_TOKEN),
                                  0, alive)
                return (cache, tok[:, None], offsets + 1, alive, rem), tok

            (cache, cur, offsets, alive, rem), block = jax.lax.scan(
                body, (cache, cur, offsets, alive, rem),
                jnp.arange(K, dtype=jnp.int32))
            return cache, cur, offsets, alive, rem, block

        return self._jit(_horizon)

    # -- speculative decode (quantized-draft) --------------------------

    def _make_verify_fn(self, K: int):
        """Compile the speculative verify: ONE batched target forward
        over the drafted block (a fused teacher-forced K-step replay of
        ``decode_step``), longest-matching-prefix acceptance, and the
        shared rollback that truncates BOTH arms' caches to the emitted
        length. Everything device-side; the host syncs only the emitted
        block + per-slot counts, once per round."""
        model, ctx = self.model, self.ctx
        set_active = self._mask_active or self.paged
        strip_active = self._mask_active

        def _rollback(c, roll):
            # both arms wrote exactly K positions this round; keep the
            # first n_emit of them. Dense caches also re-mask `pos` so
            # rolled-back positions read as invalid (-1) in attention.
            new = dict(c)
            new_len = c["len"] - roll
            new["len"] = new_len
            if "pos" in c:
                idx = jnp.arange(c["pos"].shape[1], dtype=c["pos"].dtype)
                new["pos"] = jnp.where(idx[None, :] >= new_len[:, None],
                                       -1, c["pos"])
            return new

        def _verify(p, cur, cache, dcache, block, alive):
            # teacher-forced feed: the pending token, then the first
            # K-1 drafts — position i's logits are the target's choice
            # given prefix (.., cur, d_0..d_{i-1})
            feed = jnp.concatenate(
                [cur, jnp.swapaxes(block[:K - 1], 0, 1)], axis=1)
            if set_active:
                cache = dict(cache, active=alive)
            cache, logits = decode_block(model, ctx, p, feed, cache)
            if strip_active:
                cache = {k: v for k, v in cache.items() if k != "active"}
            lg32 = logits.astype(jnp.float32)
            tgt = jnp.argmax(lg32, axis=-1)
            tgt = jnp.swapaxes(tgt, 0, 1).astype(block.dtype)   # (K, S)
            out, n_emit, acc, new_cur = accept_longest_prefix(
                block, tgt, alive)
            # poisoned-slot isolation on the verify path: a slot whose
            # target logits went non-finite emits ONE ERR_TOKEN (the
            # host walk retires it as "error") and accepts nothing;
            # draft-side NaN needs no guard — a non-finite draft token
            # simply diverges from the finite target argmax and
            # acceptance stops there
            bad = (alive > 0) & ~jnp.all(jnp.isfinite(lg32), axis=(1, 2))
            n_emit = jnp.where(bad, 1, n_emit)
            acc = jnp.where(bad, 0, acc)
            out = jnp.where(bad[None, :] & (jnp.arange(K)[:, None] == 0),
                            jnp.asarray(ERR_TOKEN, block.dtype), out)
            roll = jnp.where(alive > 0, K - n_emit, 0)
            return (_rollback(cache, roll), _rollback(dcache, roll),
                    out, n_emit, acc, new_cur[:, None])

        return self._jit(_verify)

    def _spec_round(self):
        """One speculative round: draft K tokens with the horizon scan
        on the draft arm, verify them in one batched target forward,
        emit the longest matching prefix + the target's token at the
        first divergence (1..K tokens per live slot)."""
        draft = self.draft
        tr = self.trace
        t0 = time.perf_counter() if tr is not None else 0.0
        max_rem = max(s.request.params.max_new_tokens - len(s.tokens)
                      for s in self.slots if s.active)
        K = max(1, min(draft.lookahead, self._bucket(max_rem)))
        self._decode_steps += K
        if self.paged:
            self._page_slot_steps += K * self.allocator.pages_in_use
        dfn = self._draft_fns.get(K)
        if dfn is None:
            dfn = self._draft_fns[K] = self._make_horizon_fn(
                K, ctx=draft.ctx)
        vfn = self._verify_fns.get(K)
        if vfn is None:
            vfn = self._verify_fns[K] = self._make_verify_fn(K)
        alive, _, _ = self._scan_masks()
        # the draft scan must not retire anyone — acceptance is the
        # verify pass's call: no EOS ids, budget that outlasts the scan
        rem = (K + 1) * alive
        self.draft_cache, _, _, _, _, block = dfn(
            draft.params, self.cur, self.draft_cache, self._z_f,
            self._z_i, self._o_f, self._z_keys, self._z_i, alive, rem,
            self._no_eos, self._no_poison)
        self.cache, self.draft_cache, out, n_emit, acc, self.cur = vfn(
            self.params, self.cur, self.cache, self.draft_cache, block,
            alive)
        self._verify_calls += 1
        if tr is not None:
            self._phase_done("dispatch", t0, K=K, spec=1)
            t0 = time.perf_counter()
        self._decode_syncs += 1
        blk = np.asarray(out)               # one sync per round
        n_emit = np.asarray(n_emit)
        acc = np.asarray(acc)
        if tr is not None:
            self._phase_done("sync", t0, K=K)
            t0 = time.perf_counter()
        for s in self.slots:
            if not s.active:
                continue
            a = int(acc[s.id])
            st = self._stats[s.request.id]
            st.drafted += K
            st.accepted += a
            st.rejected += K - a
            self._drafted += K
            self._accepted += a
            self._rejected += K - a
            if tr is not None:
                tr.instant(s.request.id + 1, "verify", self._now(),
                           drafted=K, accepted=a,
                           emitted=int(n_emit[s.id]))
            for t in range(int(n_emit[s.id])):
                self._active_slot_steps += 1
                self._emit(s, int(blk[t, s.id]))
                if not s.active:
                    break
        if tr is not None:
            self._phase_done("walk", t0)

    def _bucket(self, n: int) -> int:
        """Smallest power-of-two >= n, capped at max_len."""
        b = 1
        while b < n:
            b *= 2
        return min(b, self.max_len)

    @staticmethod
    def _src_len(inputs) -> Optional[int]:
        """Cross-attention source length of a request (None for LMs)."""
        if "src_tokens" in inputs:
            return int(jnp.asarray(inputs["src_tokens"]).shape[-1])
        if "frames" in inputs:
            return int(jnp.asarray(inputs["frames"]).shape[1])
        return None

    # -- fault tolerance: injection, on-demand paging, preemption ------

    def _poison_arr(self, K: int):
        """Per-dispatch NaN-injection schedule from the fault plan:
        entry s is the micro-step at which slot s's logits are forced
        non-finite (-1 = never). Always traced, so a clean dispatch and
        an injected one share the same executable."""
        if self.faults is None:
            return self._no_poison
        arr = self.faults.poison(self.n_slots, K)
        if arr is None:
            return self._no_poison
        if self.trace is not None:
            sched = np.asarray(arr, np.int32)
            self.trace.instant(
                SCHED_TID, "fault:nan", self._now(),
                slots=[int(i) for i in np.nonzero(sched >= 0)[0]])
        return jnp.asarray(np.asarray(arr, np.int32))

    def _pos_cap(self, request: Request) -> int:
        """Most cache positions a request can ever occupy (original
        prompt + its full token budget — a resumed request's replay
        feed is always shorter than this)."""
        return min(request.inputs[self._tkey].shape[1]
                   + request.params.max_new_tokens, self.max_len)

    def _note_dispatched(self, K: int) -> None:
        """Advance each active slot's dispatched-positions bound by the
        K micro-steps just launched (host upper bound on cache writes;
        mid-scan retirement only makes it conservative)."""
        if not self.on_demand:
            return
        for s in self.slots:
            if s.active:
                self._disp_len[s.id] = min(
                    self._disp_len[s.id] + K, self._pos_cap(s.request))

    def _grow_chains(self, K: int) -> None:
        """On-demand page allocation at a dispatch boundary: extend
        every active chain to cover the next K micro-steps, so block
        tables stay static across the scan. On pool exhaustion the
        lowest-priority / youngest request is preempted (possibly the
        grower itself) instead of raising — MemoryError never escapes
        the serving loop. Growth walks slots oldest/highest-priority
        first, so victims are exactly the requests admission would
        deprioritize."""
        if not self.on_demand:
            return
        for s in sorted((t for t in self.slots if t.active),
                        key=lambda t: (-t.request.params.priority, t.seq)):
            if not s.active:    # preempted as a victim earlier in this pass
                continue
            r = s.request
            want = min(self._disp_len[s.id] + K, self._pos_cap(r))
            chain = self._chains[r.id]
            while s.active:
                need = pages_needed(want, self.page_size) - len(chain)
                if need <= 0:
                    break
                got = self.allocator.try_alloc_chain(need)
                if got is not None:
                    start = len(chain)
                    chain.extend(got)
                    self.cache["block_tables"] = (
                        self.cache["block_tables"]
                        .at[s.id, start:start + len(got)]
                        .set(jnp.asarray(got, jnp.int32)))
                    break
                victim = min((t for t in self.slots if t.active),
                             key=lambda t: (t.request.params.priority,
                                            -t.seq))
                self._preempt(victim)

    def _preempt(self, s: _Slot) -> None:
        """Evict an in-flight request to relieve page pressure: stash
        its emitted tokens host-side, free its chain, and requeue it at
        the head for prefill-replay resume. The replay is provably
        token-identical — teacher-forced prefill is bit-exact vs
        incremental decode, and the per-token PRNG stream is
        offset-indexed — so survivors and resumed victims match an
        uncontended run token for token. Freeing mid-overlap is safe
        for the same reason abort is: device ops execute in submission
        order, so any stale in-flight writes to the freed pages land
        before the pages' next owner writes them. Past
        ``preempt_limit`` evictions the request retires as
        "preempted_limit" with its partial tokens instead of thrashing
        the pool forever."""
        r = s.request
        n = self._preempt_counts.get(r.id, 0) + 1
        self._preemptions += 1
        self._stats[r.id].preemptions = n
        if self.trace is not None:
            self.trace.instant(r.id + 1, "preempted", self._now(),
                               count=n, tokens=len(s.tokens))
        if n > self.preempt_limit:
            self._retire(s, "preempted_limit")
            return
        if self.trace is not None:
            now = self._now()
            self.trace.begin(r.id + 1, "queued", now)
            # link the two slot residencies: flow_end fires at the
            # resume (or at retirement, if the stash dies queued), so
            # Perfetto draws the continuity arrow and Tracer.check()
            # can insist every preemption link is paired
            self._flow_ids[r.id] = self.trace.flow_start(
                r.id + 1, "resume", now, count=n)
        self._preempt_counts[r.id] = n
        self._preempted[r.id] = list(s.tokens)
        s.active = False
        s.request = None
        s.tokens = []
        self._disp_len.pop(s.id, None)
        self._dirty_slots.add(s.id)
        self.allocator.free_chain(self._chains.pop(r.id))
        self.cache["block_tables"] = \
            self.cache["block_tables"].at[s.id].set(TRASH_PAGE)
        self.cache["active"] = self.cache["active"].at[s.id].set(0)
        self.cache["len"] = self.cache["len"].at[s.id].set(0)
        self._queue.appendleft(r)

    def _feed_tokens(self, r: Request):
        """Prefill feed for a request: its prompt, extended with all
        but the last stashed token when resuming a preempted request
        (the last stashed token becomes the pending decode token — the
        exact slot state at eviction)."""
        toks = r.inputs[self._tkey]
        stash = self._preempted.get(r.id)
        if stash and len(stash) > 1:
            toks = jnp.concatenate(
                [toks, jnp.asarray(stash[:-1], jnp.int32)[None]], axis=1)
        return toks

    def _admit_pending(self):
        if not self.paged:
            while self._queue and self.free_slot() is not None:
                self._admit(self._queue.popleft())
            return
        while self._queue:
            group = self._take_group()
            if not group:
                break
            self._admit_group(group)

    # -- paged admission -----------------------------------------------

    def _arm_pages(self, request: Request) -> int:
        """Pages one KV arm reserves at admission under whole-budget
        reservation (draft-armed engines): the full prompt+decode
        budget, so the request can never hit page pressure mid-decode.
        On-demand engines instead admit with prefill pages only (see
        _admit_pages) and grow per dispatch, preempting on exhaustion."""
        budget = (request.inputs[self._tkey].shape[1]
                  + request.params.max_new_tokens)
        return pages_needed(min(budget, self.max_len), self.page_size)

    def _admit_pages(self, request: Request) -> int:
        """Pages admission must allocate for one request right now:
        just the prefill feed when on-demand (decode pages come later,
        per dispatched horizon), the whole budget otherwise."""
        if self.on_demand:
            return pages_needed(self._feed_tokens(request).shape[1],
                                self.page_size)
        return self._request_pages(request)

    def _request_pages(self, request: Request) -> int:
        """Total page reservation across arms: a speculative engine
        holds a second, same-length chain in the draft arm's KV format
        out of the shared allocator."""
        arms = 2 if self.draft is not None else 1
        return self._arm_pages(request) * arms

    def _shape_key(self, request: Request):
        """Padded-batch compile key: prefill-feed bucket (prompt, plus
        replayed tokens for a resumed request) + side-input shapes."""
        key = [self._bucket(self._feed_tokens(request).shape[1])]
        for k in ("src_tokens", "frames", "img_embeds"):
            if k in request.inputs:
                key.append((k, tuple(request.inputs[k].shape[1:])))
        return tuple(key)

    def _take_group(self) -> List[Request]:
        """Pop the next batched-prefill admission group off the queue.

        FIFO scan from the head: take same-shaped requests while slots
        and pages last, then trim to a power-of-two batch so compiled
        prefill shapes stay bounded. An empty return means the head
        request is blocked (no slot, or its page reservation cannot be
        met until in-flight requests retire) — admission never skips
        over it, so no request starves.
        """
        free = sum(not s.active for s in self.slots)
        if self.sla is not None:
            # SLA-tuned prefill group cap: smaller admission batches get
            # queued heads to their first token sooner when TTFT slips
            free = min(free, self.sla.prefill_cap)
        if not free or not self._queue:
            return []
        head_key = self._shape_key(self._queue[0])
        group: List[Request] = []
        need = 0
        for r in self._queue:
            if len(group) >= free or self._shape_key(r) != head_key:
                break
            pages = self._admit_pages(r)
            if not self.allocator.can_alloc(need + pages):
                break
            group.append(r)
            need += pages
        n = 1
        while n * 2 <= len(group):
            n *= 2
        group = group[:n]
        for _ in group:
            self._queue.popleft()
        return group

    def _admit_group(self, group: List[Request]):
        """Admit a same-shape group under ONE jitted prefill+insert
        call. A resumed (previously preempted) request prefills its
        prompt + already-emitted tokens (minus the last, which becomes
        the pending decode token) — teacher-forced replay that rebuilds
        the exact KV/PRNG state it was evicted with, so its remaining
        stream is token-identical. Slot state for the WHOLE group goes
        live before any first-token callback fires, so a callback
        aborting a groupmate finds it admitted (and retirable) instead
        of racing a half-built group."""
        n = len(group)
        free = [s.id for s in self.slots if not s.active][:n]
        tr = self.trace
        if tr is not None:
            t_adm = self._now()
            for r in group:
                tr.end(r.id + 1, "queued", t_adm)
            p0 = time.perf_counter()
        toks = [self._feed_tokens(r) for r in group]
        true_lens = [t.shape[1] for t in toks]
        pad_to = self._bucket(max(true_lens))
        inputs = {self._tkey: jnp.concatenate(
            [jnp.pad(t, ((0, 0), (0, pad_to - t.shape[1]))) for t in toks])}
        inputs["lengths"] = jnp.asarray(true_lens, jnp.int32)
        for k in ("src_tokens", "frames", "img_embeds"):
            if k in group[0].inputs:
                inputs[k] = jnp.concatenate([r.inputs[k] for r in group])
        chains = []
        rows = np.zeros((n, self.max_pages), np.int32)  # 0 = trash page
        for i, r in enumerate(group):
            chain = self.allocator.alloc_chain(
                pages_needed(true_lens[i], self.page_size)
                if self.on_demand else self._arm_pages(r))
            chains.append(chain)
            rows[i, :len(chain)] = chain
        dchains = []
        if self.draft is not None:
            drows = np.zeros((n, self.max_pages), np.int32)
            for i, r in enumerate(group):
                dchain = self.allocator.alloc_chain(self._arm_pages(r))
                dchains.append(dchain)
                drows[i, :len(dchain)] = dchain
        keys = jnp.stack(
            [jax.random.PRNGKey(r.params.seed) for r in group])
        self.cache, first = self._prefill_paged_fn(
            self.params, inputs, jnp.asarray(true_lens, jnp.int32),
            jnp.asarray(free, jnp.int32), jnp.asarray(rows), self.cache,
            jnp.asarray([r.params.temperature for r in group], jnp.float32),
            jnp.asarray([r.params.top_k for r in group], jnp.int32),
            jnp.asarray([r.params.top_p for r in group], jnp.float32),
            keys)
        if self.draft is not None:
            self.draft_cache = self._draft_prefill_paged_fn(
                self.draft.params, inputs,
                jnp.asarray(true_lens, jnp.int32),
                jnp.asarray(free, jnp.int32), jnp.asarray(drows),
                self.draft_cache)
        self.prefill_shapes.add(
            tuple(sorted((k, tuple(v.shape)) for k, v in inputs.items())))
        first = np.asarray(first)
        now = self._now()
        if tr is not None:
            # one batched prefill covers the group; each member gets the
            # same complete event on its own track
            p_dur = time.perf_counter() - p0
            for r in group:
                tr.complete(r.id + 1, "prefill", now - p_dur, p_dur,
                            group=n)
        admitted = []
        for i, (r, sid) in enumerate(zip(group, free)):
            s = self.slots[sid]
            sp = r.params
            stash = self._preempted.pop(r.id, None)
            if stash:
                # resume: the replay prefill's sampled token is
                # discarded — the pending decode token is the last one
                # emitted before eviction, and the PRNG offset picks up
                # at fold len(stash), exactly the pre-eviction state
                tok = int(stash[-1])
                self._resumed += 1
                fid = self._flow_ids.pop(r.id, None)
                if tr is not None:
                    tr.instant(r.id + 1, "resumed", now,
                               replayed=len(stash))
                    if fid is not None:
                        tr.flow_end(r.id + 1, "resume", now, fid)
            else:
                tok = int(first[i])
            self.cur = self.cur.at[sid, 0].set(tok)
            self._temps = self._temps.at[sid].set(sp.temperature)
            self._top_ks = self._top_ks.at[sid].set(sp.top_k)
            self._top_ps = self._top_ps.at[sid].set(sp.top_p)
            self._keys = self._keys.at[sid].set(keys[i])
            self._offsets = self._offsets.at[sid].set(
                len(stash) if stash else 1)
            self._chains[r.id] = chains[i]
            if self.draft is not None:
                self._draft_chains[r.id] = dchains[i]
            s.request = r
            s.tokens = list(stash) if stash else []
            s.active = True
            s.seq = self._admit_seq
            self._admit_seq += 1
            if self.on_demand:
                self._disp_len[sid] = true_lens[i]
            self._last_admitted_slot = sid
            self._dirty_slots.add(sid)
            admitted.append((s, r, tok, stash is not None))
        # first-token delivery only after EVERY slot in the group is
        # live (see docstring); resumed requests already streamed their
        # stashed tokens before eviction and re-emit nothing
        for s, r, tok, resumed in admitted:
            if not s.active or s.request is not r:
                continue    # a groupmate's callback aborted it already
            if resumed:
                continue
            self._stats[r.id].first_token_s = now
            self._emit(s, tok, synced=False)

    # -- dense admission -----------------------------------------------

    def _admit(self, request: Request):
        slot = self.free_slot()
        s = self.slots[slot]
        sp = request.params
        tr = self.trace
        if tr is not None:
            tr.end(request.id + 1, "queued", self._now())
            p0 = time.perf_counter()
        inputs = dict(request.inputs)
        toks = inputs[self._tkey]
        true_len = toks.shape[1]
        if self._bucketed:
            pad_to = self._bucket(true_len)
            if pad_to > true_len:
                toks = jnp.pad(toks, ((0, 0), (0, pad_to - true_len)))
            inputs[self._tkey] = toks
            inputs["lengths"] = jnp.full((1,), true_len, jnp.int32)
        key = jax.random.PRNGKey(sp.seed)
        one_cache, tok = self._prefill_fn(
            self.params, inputs, jnp.int32(true_len),
            jnp.float32(sp.temperature), jnp.int32(sp.top_k),
            jnp.float32(sp.top_p), key)
        self.prefill_shapes.add(
            tuple(sorted((k, tuple(v.shape)) for k, v in inputs.items())))
        self.cache = self._splice(self.cache, self._pad_cross(one_cache),
                                  slot)
        if self.draft is not None:
            done = self._draft_prefill_fn(self.draft.params, inputs)
            self.draft_cache = self._splice(
                self.draft_cache, self._pad_cross(done), slot)
        tok = int(tok)
        if tr is not None:
            p_dur = time.perf_counter() - p0
            tr.complete(request.id + 1, "prefill", self._now() - p_dur,
                        p_dur)
        self.cur = self.cur.at[slot, 0].set(tok)
        self._temps = self._temps.at[slot].set(sp.temperature)
        self._top_ks = self._top_ks.at[slot].set(sp.top_k)
        self._top_ps = self._top_ps.at[slot].set(sp.top_p)
        self._keys = self._keys.at[slot].set(key)
        self._offsets = self._offsets.at[slot].set(1)  # token 0 drew fold 0
        s.request = request
        s.tokens = []                   # prefill produced the first token
        s.active = True
        s.seq = self._admit_seq
        self._admit_seq += 1
        self._last_admitted_slot = slot
        self._dirty_slots.add(slot)
        self._stats[request.id].first_token_s = self._now()
        self._emit(s, tok, synced=False)

    def _maybe_retire(self, s: _Slot):
        sp = s.request.params
        if sp.eos_id is not None and s.tokens[-1] == sp.eos_id:
            self._retire(s, "eos")
        elif len(s.tokens) >= sp.max_new_tokens:
            self._retire(s, "length")

    def _retire(self, s: _Slot, reason: str):
        rid = s.request.id
        st = self._stats.pop(rid)
        st.finished_s = self._now()
        st.new_tokens = len(s.tokens)
        out = RequestOutput(
            rid, s.request.inputs, list(s.tokens), reason, st, slot=s.id)
        self._finished.append(out)
        # every served retirement feeds the latency histograms (queued
        # requests that never reached a slot don't — see _finish_queued)
        self._ttft_hist.record(out.ttft_ms)
        self._tpot_hist.record(out.tpot_ms)
        if self.trace is not None:
            tid = rid + 1
            if reason in ("deadline", "error"):
                self.trace.instant(tid, reason, st.finished_s)
            self.trace.instant(tid, "retired", st.finished_s,
                               reason=reason, tokens=st.new_tokens)
            self.trace.end(tid, "request", st.finished_s)
        if reason == "deadline":
            self._deadline_expirations += 1
        elif reason == "error":
            self._slot_errors += 1
        if self.sla is not None and reason in ("eos", "length"):
            # only clean completions feed the percentile window: aborts
            # carry caller-truncated timings, and fault-path timings
            # (deadline / preempted_limit / error) would reward
            # load-shedding with a "better" p95
            self.sla.observe(out)
        self._preempted.pop(rid, None)
        self._preempt_counts.pop(rid, None)
        self._disp_len.pop(s.id, None)
        s.active = False
        s.request = None
        if self.paged:
            # reclaim the chain and park the slot on the trash page so
            # its idle decode writes cannot touch live pages; both arms'
            # chains are freed together, by this one path, whatever the
            # finish reason — a second free would raise in the allocator
            self.allocator.free_chain(self._chains.pop(rid))
            self.cache["block_tables"] = \
                self.cache["block_tables"].at[s.id].set(TRASH_PAGE)
            self.cache["active"] = self.cache["active"].at[s.id].set(0)
            self.cache["len"] = self.cache["len"].at[s.id].set(0)
            if self.draft is not None:
                self.allocator.free_chain(self._draft_chains.pop(rid))
                self.draft_cache["block_tables"] = \
                    self.draft_cache["block_tables"].at[s.id].set(TRASH_PAGE)
                self.draft_cache["active"] = \
                    self.draft_cache["active"].at[s.id].set(0)
                self.draft_cache["len"] = \
                    self.draft_cache["len"].at[s.id].set(0)

    def _pad_cross(self, one_cache):
        """Zero-pad a single-request cache's cross-attention leaves from
        the request's source length up to the engine's enc capacity so
        mixed source lengths splice into one batch cache (the valid span
        is tracked per slot via cross_len)."""
        if not self.enc_cap:
            return one_cache
        one_cache = dict(one_cache)
        for k, v in one_cache.items():
            if k.startswith("cross_") and v.ndim >= 3:
                se = v.shape[2]
                if se < self.enc_cap:
                    pad = [(0, 0)] * v.ndim
                    pad[2] = (0, self.enc_cap - se)
                    one_cache[k] = jnp.pad(v, pad)
        return one_cache

    _BATCH_LEADING = ("'pos'", "'len'", "'pos_roll'")

    def _splice(self, batch_cache, one_cache, slot: int):
        """Write a single-request cache into batch slot ``slot``.

        Batch axis position differs per leaf: 'pos'/'len'/'pos_roll' carry
        batch at dim 0; layer-stacked KV/state leaves carry it at dim 1.
        """
        def put(path, c, o):
            pstr = jax.tree_util.keystr(path)
            if c.ndim == 0:
                return c
            o = o.astype(c.dtype)   # e.g. f32 prefill state into bf16 cache
            if any(k in pstr for k in self._BATCH_LEADING) or c.ndim == 1:
                return c.at[slot].set(o[0])            # batch-leading leaf
            return c.at[:, slot].set(o[:, 0])          # layer-leading leaf
        return jax.tree_util.tree_map_with_path(put, batch_cache, one_cache)


# ---------------------------------------------------------------------------
# legacy one-shot wrappers (thin shims over a single-shot engine)
# ---------------------------------------------------------------------------

def _row(batch: dict, i: int) -> dict:
    return {k: v[i:i + 1] for k, v in batch.items()
            if hasattr(v, "shape") and getattr(v, "ndim", 0) >= 1}


_DEPRECATION = (
    " is deprecated and will be removed: deploy() a TranslationPipeline "
    "from repro.serving and use pipe.generate()/pipe.translate() — or the "
    "streaming surface (pipe.translate_stream / engine.submit(on_token=...)"
    " / engine.stream()) for token-at-a-time delivery")


def greedy_generate(model, ctx, params, batch, *, steps: int, max_len: int,
                    kv_dtype: str = "bf16", eos_id: Optional[int] = None):
    """Deprecated prefill + greedy decode shim; see ``_DEPRECATION``.

    Returns (tokens (B, steps), cache)."""
    warnings.warn("greedy_generate" + _DEPRECATION, DeprecationWarning,
                  stacklevel=2)
    return _greedy_generate(model, ctx, params, batch, steps=steps,
                            max_len=max_len, kv_dtype=kv_dtype,
                            eos_id=eos_id)


def _greedy_generate(model, ctx, params, batch, *, steps: int, max_len: int,
                     kv_dtype: str = "bf16", eos_id: Optional[int] = None):
    """Prefill + greedy decode. Returns (tokens (B, steps), cache).

    Thin wrapper over a single-shot ServeEngine (one slot per batch row).
    When ``eos_id`` is set, a sequence stops at its first EOS and the
    remaining positions are masked with ``eos_id`` (the returned shape
    stays (B, steps)); ``eos_id=None`` (default) never stops early.
    """
    tkey = "tgt_in" if model.cfg.family in ("encdec", "audio") else "tokens"
    B = batch[tkey].shape[0]
    eng = ServeEngine(model, params, slots=B, max_len=max_len,
                      kv_dtype=kv_dtype, ctx=ctx)
    sp = SamplingParams(max_new_tokens=steps, eos_id=eos_id)
    ids = [eng.submit(_row(batch, i), sp) for i in range(B)]
    outs = {o.request_id: o for o in eng.run_until_drained()}
    pad = 0 if eos_id is None else eos_id
    rows = [outs[r].token_ids + [pad] * (steps - len(outs[r].token_ids))
            for r in ids]
    return jnp.asarray(rows, jnp.int32), eng.cache


def translate(model, ctx, params, src_tokens, lang_code: int, *,
              steps: int, max_len: int = 0,
              kv_dtype: str = "bf16", eos_id: Optional[int] = None):
    """Deprecated NMT shim (paper Fig. 2b): many-to-many via target lang
    code; see ``_DEPRECATION`` — TranslationPipeline.translate /
    translate_stream is the supported surface.

    ``max_len`` defaults to the decoder prompt length (the 1-token lang
    code) + ``steps``; an explicit ``max_len`` too small for the request
    raises instead of silently wrapping the KV cache.
    """
    warnings.warn("translate" + _DEPRECATION, DeprecationWarning,
                  stacklevel=2)
    B = src_tokens.shape[0]
    prompt_len = 1                       # decoder prompt = target lang code
    max_len = max_len or prompt_len + steps
    if prompt_len + steps > max_len:
        raise ValueError(
            f"translate needs prompt_len + steps = {prompt_len} + {steps} "
            f"= {prompt_len + steps} cache positions but max_len={max_len}")
    tgt_in = jnp.full((B, 1), lang_code, jnp.int32)
    batch = {"src_tokens": src_tokens, "tgt_in": tgt_in}
    toks, _ = _greedy_generate(model, ctx, params, batch, steps=steps,
                               max_len=max_len, kv_dtype=kv_dtype,
                               eos_id=eos_id)
    return toks
