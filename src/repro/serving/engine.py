"""Serving engine: batched prefill + decode with continuous batching.

The paper's deployment is real-time translation on edge FPGAs; the TPU
counterpart is a batched decode loop over a (possibly int8-quantized) KV
cache. Slots model continuous batching: each sequence in the fixed batch
is an independent request slot with its own length; finished slots are
re-primed with new requests without recompiling (per-seq `len`/`pos`
masking makes ragged batches correct by construction).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..models.layers import Ctx

__all__ = ["ServeEngine", "greedy_generate", "translate"]


def greedy_generate(model, ctx, params, batch, *, steps: int,
                    max_len: int, kv_dtype: str = "bf16", eos_id: int = 0):
    """Prefill + greedy decode. Returns (tokens (B, steps), cache)."""
    tkey = "tgt_in" if model.cfg.family in ("encdec", "audio") else "tokens"
    B = batch[tkey].shape[0]
    cache = model.init_cache(B, max_len, kv_dtype)
    cache, logits = model.prefill(ctx, params, cache, batch)
    tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    out = [tok]
    for _ in range(steps - 1):
        cache, logits = model.decode_step(ctx, params, tok, cache)
        tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        out.append(tok)
    return jnp.concatenate(out, axis=1), cache


def translate(model, ctx, params, src_tokens, lang_code: int, *,
              steps: int, max_len: int = 0, kv_dtype: str = "bf16"):
    """NMT entry point (paper Fig. 2b): many-to-many via target lang code."""
    B = src_tokens.shape[0]
    max_len = max_len or steps + 4
    tgt_in = jnp.full((B, 1), lang_code, jnp.int32)
    batch = {"src_tokens": src_tokens, "tgt_in": tgt_in}
    toks, _ = greedy_generate(model, ctx, params, batch, steps=steps,
                              max_len=max_len, kv_dtype=kv_dtype)
    return toks


@dataclasses.dataclass
class _Slot:
    id: int
    remaining: int = 0
    tokens: list = dataclasses.field(default_factory=list)
    active: bool = False


class ServeEngine:
    """Fixed-slot continuous-batching decode engine.

    One jitted decode_step serves all slots every tick; idle slots decode
    into masked positions (len stays put) at negligible cost relative to
    the batched step. add_request() primes a slot via a single-slot
    prefill and splices its cache into the batch cache.
    """

    def __init__(self, model, params, *, slots: int, max_len: int,
                 kv_dtype: str = "bf16", ctx: Optional[Ctx] = None):
        self.model = model
        self.params = params
        self.ctx = ctx or Ctx()
        self.kv_dtype = kv_dtype
        self.max_len = max_len
        self.cache = model.init_cache(slots, max_len, kv_dtype)
        self.slots = [_Slot(i) for i in range(slots)]
        self.cur = jnp.zeros((slots, 1), jnp.int32)
        self._decode = jax.jit(
            lambda p, t, c: model.decode_step(self.ctx, p, t, c))

    def free_slot(self) -> Optional[int]:
        for s in self.slots:
            if not s.active:
                return s.id
        return None

    _BATCH_LEADING = ("'pos'", "'len'", "'pos_roll'")

    def _splice(self, batch_cache, one_cache, slot: int):
        """Write a single-request cache into batch slot ``slot``.

        Batch axis position differs per leaf: 'pos'/'len'/'pos_roll' carry
        batch at dim 0; layer-stacked KV/state leaves carry it at dim 1.
        """
        def put(path, c, o):
            pstr = jax.tree_util.keystr(path)
            if c.ndim == 0:
                return c
            if any(k in pstr for k in self._BATCH_LEADING) or c.ndim == 1:
                return c.at[slot].set(o[0])            # batch-leading leaf
            return c.at[:, slot].set(o[:, 0])          # layer-leading leaf
        return jax.tree_util.tree_map_with_path(put, batch_cache, one_cache)

    def add_request(self, batch_one: dict, gen_tokens: int) -> int:
        slot = self.free_slot()
        if slot is None:
            raise RuntimeError("no free slots")
        one_cache = self.model.init_cache(1, self.max_len, self.kv_dtype)
        one_cache, logits = self.model.prefill(self.ctx, self.params,
                                               one_cache, batch_one)
        self.cache = self._splice(self.cache, one_cache, slot)
        tok = int(jnp.argmax(logits[0, -1]))
        self.cur = self.cur.at[slot, 0].set(tok)
        s = self.slots[slot]
        # prefill already produced the first generated token
        s.tokens = [tok]
        s.remaining = gen_tokens - 1
        s.active = s.remaining > 0
        return slot

    def tick(self) -> List[int]:
        """One batched decode step for every active slot."""
        self.cache, logits = self._decode(self.params, self.cur, self.cache)
        nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        self.cur = nxt[:, None]
        done = []
        for s in self.slots:
            if not s.active:
                continue
            s.tokens.append(int(nxt[s.id]))
            s.remaining -= 1
            if s.remaining <= 0:
                s.active = False
                done.append(s.id)
        return done

    def result(self, slot: int) -> list:
        return self.slots[slot].tokens
