"""Quant sweep: one trained checkpoint across precision presets.

The paper's Tables IV-V reduced to a function: deploy the same trained
parameters at each requested preset (bf16, fp8, int8 — including the
calibrated w8a8 arm via core.calibration — int4, fp4, nf4), run the full
pair matrix through each deployed engine, and emit one row per format
with quality (mean BLEU/chrF over the grid), model bytes
(core.tree_nbytes via the pipeline), compression, throughput, and the
per-format quality delta against the bf16 anchor — the number the
paper's "quality parity under sub-octet precision" claim lives or dies
on, per pair and per direction.

One engine is deployed per format and reused for every pair (the pair
matrix streams through it request-by-request); nothing here decodes
outside `repro.serving`.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence, Tuple

from ..core import resolve_spec
from ..obs import PHASES
from ..serving import TraceConfig, deploy
from .suite import PairScore, evaluate_pairs, summarize

__all__ = ["FormatRow", "quant_sweep", "ANCHOR"]

ANCHOR = "bf16"        # deltas are measured against this spec name


@dataclasses.dataclass(frozen=True)
class FormatRow:
    """One precision spec's quality-vs-size-vs-throughput summary."""

    fmt: str                           # the spec as requested (alias ok)
    spec: str                          # fully-resolved grammar string
    model_bytes: int                   # quantized parameter storage
    fp_bytes: int                      # pre-quantization parameter bytes
    compression: float
    kv_cache_bytes: int
    mean_bleu: float
    mean_chrf: float
    mean_token_acc: float
    mean_tok_s: float
    gen_tokens: int
    # worst-direction serving latency over the pair grid (schema v4) —
    # the numbers an SLATarget for this format is written against
    ttft_p95_ms: Optional[float]
    tpot_p95_ms: Optional[float]
    # scheduler round-phase wall-time totals for the whole grid
    # ({admit,dispatch,sync,walk}_ms, schema v5) — where this format's
    # serving time went; None when the sweep ran untraced
    round_phases: Optional[Dict[str, float]]
    bleu_delta: Optional[float]        # vs the anchor row (None = anchor
    chrf_delta: Optional[float]        # itself, or anchor not in sweep)
    calibrated: bool                   # per-site static act scales set?
    pair_scores: Tuple[PairScore, ...]

    def as_row(self) -> Dict[str, Any]:
        d = dataclasses.asdict(self)
        d["pair_scores"] = [s.as_row() for s in self.pair_scores]
        return d


def quant_sweep(arch_or_cfg, formats: Sequence[str], *, params: Any,
                pair_list: Optional[Sequence[Tuple[str, str]]] = None,
                languages: Optional[Sequence[str]] = None,
                n_sent: int = 8, seed: int = 0,
                max_new_tokens: Optional[int] = None,
                calib_batches_fn=None,
                deploy_kwargs: Optional[Dict[str, Any]] = None,
                trace: bool = False, log=print) -> List[FormatRow]:
    """Evaluate one checkpoint across precision presets.

    params:     trained parameter tree (pre-quantization); each format
                deploys its own quantized copy of it.
    formats:    quantization specs — registered aliases and/or grammar
                strings (core.resolve_spec), evaluated in order. Put
                ``"bf16"`` among them to populate the delta columns.
    calib_batches_fn: zero-arg callable returning a fresh iterable of
                calibration batches; invoked once per act-quantizing
                spec (a8 / afp8 arms) and passed to
                ``deploy(calib_batches=...)``. None = dynamic per-token
                activation quantization.
    deploy_kwargs: serving knobs forwarded to every deploy() call —
                slots, max_len, paged, page_size, num_pages, horizon,
                matmul_impl/paged_attn_impl, smoke, ctx,
                draft_spec/draft_lookahead (speculative decoding: the
                grid's token streams are unchanged by the
                greedy-equivalence invariant, but every pair row gains
                its acceptance_rate column)... (deploy() itself derives
                each format's activation route from the spec, so one
                ctx serves the whole sweep).
    trace:      deploy each format's engine with lifecycle tracing on
                and record its scheduler round-phase totals in the
                row's ``round_phases`` column (schema v5) — token
                streams and scores are unchanged (tracing is a pure
                observer); untraced sweeps record None.
    """
    resolved = [resolve_spec(f) for f in formats]   # fail fast on typos
    dk = dict(deploy_kwargs or {})
    rows: List[FormatRow] = []
    anchor: Optional[FormatRow] = None
    for fmt, spec in zip(formats, resolved):
        calib = None
        if calib_batches_fn is not None and spec.quantizes_act:
            calib = calib_batches_fn()
        if trace:
            dk["trace"] = TraceConfig()   # fresh Tracer per engine
        pipe = deploy(arch_or_cfg, fmt, params=params,
                      calib_batches=calib, **dk)
        scores = evaluate_pairs(pipe, pair_list, n_sent=n_sent, seed=seed,
                                max_new_tokens=max_new_tokens,
                                languages=languages)
        agg = summarize(scores)
        phases = None
        if trace:
            m = pipe.engine.metrics()
            phases = {f"{p}_ms": round(getattr(m, f"phase_{p}_ms"), 3)
                      for p in PHASES}
        row = FormatRow(
            fmt=fmt, spec=pipe.spec_str, model_bytes=pipe.quantized_bytes,
            fp_bytes=pipe.fp_bytes,
            compression=round(pipe.compression, 3),
            kv_cache_bytes=pipe.engine.kv_cache_bytes,
            mean_bleu=agg["mean_bleu"], mean_chrf=agg["mean_chrf"],
            mean_token_acc=agg["mean_token_acc"],
            mean_tok_s=round(agg["mean_tok_s"], 1),
            gen_tokens=agg["gen_tokens"],
            ttft_p95_ms=round(max(s.ttft_p95_ms for s in scores), 3)
            if scores else None,
            tpot_p95_ms=round(max(s.tpot_p95_ms for s in scores), 3)
            if scores else None,
            round_phases=phases,
            bleu_delta=None, chrf_delta=None,
            calibrated=pipe.ctx.act_scales is not None,
            pair_scores=tuple(scores))
        if fmt == ANCHOR:
            anchor = row
        rows.append(row)
        log(f"[sweep] {fmt:5s} ({row.spec}) bleu {row.mean_bleu:.3f} chrf "
            f"{row.mean_chrf:.3f} bytes {row.model_bytes} "
            f"({row.compression:.2f}x) tok/s {row.mean_tok_s}")
    if anchor is not None:
        rows = [dataclasses.replace(
            r, bleu_delta=None if r.fmt == ANCHOR
            else round(r.mean_bleu - anchor.mean_bleu, 6),
            chrf_delta=None if r.fmt == ANCHOR
            else round(r.mean_chrf - anchor.mean_chrf, 6)) for r in rows]
    return rows
