"""Quality-report artifact: stable JSON schema + markdown rendering.

CI's eval-smoke job uploads these next to the perf BENCH JSONs, so a
run-over-run quality trajectory exists for the same commits the perf
trajectory covers. The schema is deliberately boring and guaranteed to
round-trip: ``load(dump(report)) == report`` (enforced by ``save`` on
every write and by a CI guard) — dicts/lists/str/int/float/bool/None
only, non-finite floats mapped to None, numpy scalars unwrapped.

    report = make_report(arch="nllb600m", rows=[r.as_row() for r in rows],
                         config={"formats": [...], "pairs": [...]})
    save(report, "eval_report.json")
    print(render_markdown(report))
"""

from __future__ import annotations

import json
import math
import subprocess
from typing import Any, Dict, List, Optional, Sequence

__all__ = ["SCHEMA_VERSION", "make_report", "dump", "load", "save",
           "render_markdown"]

# v2: every sweep row records the fully-resolved quantization spec
# string ("spec") next to the requested alias ("fmt").
# v3: every per-pair entry carries an "acceptance_rate" column
# (speculative-decode draft acceptance; None for target-only runs).
# v4: every sweep row carries format-level "ttft_p95_ms"/"tpot_p95_ms"
# columns (worst direction over the pair grid — the numbers an
# SLATarget is written against; None for pre-v4 runs).
# v5: every sweep row carries a "round_phases" column — the serving
# engine's scheduler round-phase wall-time totals
# ({admit,dispatch,sync,walk}_ms from the obs tracer) for the grid
# that produced the row; None for untraced (and all pre-v5) runs.
# Older reports are upgraded on load, one version at a time.
SCHEMA_VERSION = 5


def _git_rev() -> Optional[str]:
    try:
        out = subprocess.run(["git", "rev-parse", "--short", "HEAD"],
                             capture_output=True, text=True, timeout=10)
        return out.stdout.strip() or None if out.returncode == 0 else None
    except (OSError, subprocess.SubprocessError):
        return None


def _jsonify(x: Any) -> Any:
    """Coerce to round-trippable JSON types (see module docstring)."""
    if hasattr(x, "item") and not isinstance(x, (str, bytes)):
        x = x.item()                   # numpy scalars
    if isinstance(x, float):
        return x if math.isfinite(x) else None
    if isinstance(x, (str, int, bool)) or x is None:
        return x
    if isinstance(x, dict):
        return {str(k): _jsonify(v) for k, v in x.items()}
    if isinstance(x, (list, tuple, set)):
        return [_jsonify(v) for v in x]
    raise TypeError(f"cannot serialize {type(x).__name__} into a report")


def make_report(*, arch: str, rows: Sequence[Dict[str, Any]],
                config: Optional[Dict[str, Any]] = None) -> Dict[str, Any]:
    """Assemble a current-schema report dict (already JSON-clean).

    ``rows`` is one dict per precision format (FormatRow.as_row()), each
    carrying its nested per-pair grid. ``config`` records how the run
    was produced (formats, pairs, train steps, serving knobs, seed) so
    trajectories compare like with like.
    """
    return _jsonify({
        "schema": SCHEMA_VERSION,
        "kind": "repro.eval",
        "arch": arch,
        "git_rev": _git_rev(),
        "config": config or {},
        "rows": list(rows),
    })


def dump(report: Dict[str, Any]) -> str:
    return json.dumps(report, indent=2, sort_keys=True, allow_nan=False)


def _upgrade_v1(report: Dict[str, Any]) -> Dict[str, Any]:
    """Schema 1 -> 2: derive each row's resolved spec string from its
    format alias (falling back to the alias itself for names the current
    registry no longer resolves)."""
    from ..core import resolve_spec
    rows = []
    for row in report.get("rows", []):
        row = dict(row)
        if "spec" not in row:
            try:
                row["spec"] = str(resolve_spec(row.get("fmt")))
            except (ValueError, TypeError):
                row["spec"] = row.get("fmt")
        rows.append(row)
    return {**report, "schema": 2, "rows": rows}


def _upgrade_v2(report: Dict[str, Any]) -> Dict[str, Any]:
    """Schema 2 -> 3: per-pair entries gain the speculative-decode
    "acceptance_rate" column — None, the exact value a target-only run
    records, since pre-v3 runs had no draft arm."""
    rows = []
    for row in report.get("rows", []):
        row = dict(row)
        if row.get("pair_scores"):
            row["pair_scores"] = [
                {"acceptance_rate": None, **p} for p in row["pair_scores"]]
        rows.append(row)
    return {**report, "schema": 3, "rows": rows}


def _upgrade_v3(report: Dict[str, Any]) -> Dict[str, Any]:
    """Schema 3 -> 4: sweep rows gain format-level "ttft_p95_ms" /
    "tpot_p95_ms" latency columns. Pre-v4 runs measured per-pair
    percentiles but never rolled them up, so the roll-up is recomputed
    where pair data exists (max over directions, matching quant_sweep)
    and None otherwise."""
    rows = []
    for row in report.get("rows", []):
        row = dict(row)
        for col in ("ttft_p95_ms", "tpot_p95_ms"):
            if col not in row:
                vals = [p[col] for p in row.get("pair_scores") or []
                        if isinstance(p.get(col), (int, float))]
                row[col] = max(vals) if vals else None
        rows.append(row)
    return {**report, "schema": 4, "rows": rows}


def _upgrade_v4(report: Dict[str, Any]) -> Dict[str, Any]:
    """Schema 4 -> 5: sweep rows gain the "round_phases" column — the
    scheduler's per-phase wall-time totals from the obs tracer. Pre-v5
    runs were never traced, so the value is None: exactly what an
    untraced v5 run records."""
    rows = []
    for row in report.get("rows", []):
        row = dict(row)
        if "round_phases" not in row:
            row["round_phases"] = None
        rows.append(row)
    return {**report, "schema": 5, "rows": rows}


_UPGRADES = {1: _upgrade_v1, 2: _upgrade_v2, 3: _upgrade_v3, 4: _upgrade_v4}


def load(text: str) -> Dict[str, Any]:
    """Parse a report; older artifacts are upgraded one schema version
    at a time (current-schema reports round-trip unchanged:
    load(dump(x)) == x)."""
    report = json.loads(text)
    if isinstance(report, dict) and report.get("kind") == "repro.eval":
        while report.get("schema") in _UPGRADES:
            report = _UPGRADES[report["schema"]](report)
    return report


def save(report: Dict[str, Any], path: str) -> None:
    """Write the artifact; refuses to emit anything that won't round-trip."""
    text = dump(report)
    if load(text) != report:
        raise ValueError(
            "report does not round-trip through JSON — non-native types "
            "slipped past make_report")
    with open(path, "w") as f:
        f.write(text + "\n")


# ---------------------------------------------------------------------------
# markdown rendering
# ---------------------------------------------------------------------------

def _fmt(v: Any, nd: int = 3, signed: bool = False) -> str:
    if v is None:
        return "—"
    if isinstance(v, float):
        return f"{v:+.{nd}f}" if signed else f"{v:.{nd}f}"
    return str(v)


def _sweep_table(rows: List[Dict[str, Any]]) -> List[str]:
    head = ("| format | spec | BLEU | ΔBLEU | chrF | ΔchrF | model MB "
            "| compr | kv MB | tok/s | ttft p95 | tpot p95 | calib |")
    sep = "|---" * 13 + "|"
    lines = [head, sep]
    for r in rows:
        lines.append(
            f"| {r['fmt']} | {r.get('spec', r['fmt'])}"
            f" | {_fmt(r['mean_bleu'])}"
            f" | {_fmt(r['bleu_delta'], signed=True)}"
            f" | {_fmt(r['mean_chrf'])}"
            f" | {_fmt(r['chrf_delta'], signed=True)}"
            f" | {r['model_bytes'] / 2**20:.2f} | {_fmt(r['compression'], 2)}x"
            f" | {r['kv_cache_bytes'] / 2**20:.2f}"
            f" | {_fmt(r['mean_tok_s'], 1)}"
            f" | {_fmt(r.get('ttft_p95_ms'), 1)}"
            f" | {_fmt(r.get('tpot_p95_ms'), 2)}"
            f" | {'static' if r.get('calibrated') else 'dyn'} |")
    return lines


def _pair_grid(pair_scores: List[Dict[str, Any]], metric: str) -> List[str]:
    """src-rows x tgt-cols grid of one metric ('—' for absent cells)."""
    srcs = sorted({p["src"] for p in pair_scores})
    tgts = sorted({p["tgt"] for p in pair_scores})
    cell = {(p["src"], p["tgt"]): p[metric] for p in pair_scores}
    lines = ["| src\\tgt | " + " | ".join(tgts) + " |",
             "|---" * (len(tgts) + 1) + "|"]
    for s in srcs:
        vals = [_fmt(cell.get((s, t))) for t in tgts]
        lines.append(f"| {s} | " + " | ".join(vals) + " |")
    return lines


def render_markdown(report: Dict[str, Any], metric: str = "chrf") -> str:
    """Human-readable summary: sweep table + per-format pair grids."""
    rows = report.get("rows", [])
    lines = [f"# {report.get('kind', 'repro.eval')} — "
             f"{report.get('arch', '?')} @ {report.get('git_rev') or 'dirty'}",
             ""]
    cfg = report.get("config") or {}
    if cfg:
        lines += ["```", json.dumps(cfg, sort_keys=True), "```", ""]
    if rows:
        lines += ["## Quality vs precision (pair-grid means)", ""]
        lines += _sweep_table(rows)
        lines.append("")
        for r in rows:
            ps = r.get("pair_scores") or []
            if not ps:
                continue
            lines += [f"## {r['fmt']}: per-pair {metric}", ""]
            lines += _pair_grid(ps, metric)
            lines.append("")
    return "\n".join(lines)
