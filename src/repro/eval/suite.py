"""Bidirectional language-pair matrix runner (paper Fig. 9 grid).

Given a deployed `TranslationPipeline` and a pair list, generates a
held-out `SyntheticTranslation` eval set per (src, tgt) direction and
serves every sentence **through the request-level engine** —
``engine.submit`` + ``run_until_drained``, so whatever the pipeline was
deployed with (dense or paged KV, any decode horizon, any kernel route)
is exactly what gets measured; the suite contains no decode loop of its
own. Scores therefore inherit the engine's equivalence guarantees:
dense == paged and horizon=1 == horizon=K produce identical grids
(asserted in tests/test_eval_suite.py).

Per pair the suite reports corpus BLEU / chrF / token accuracy / exact
match (streamed through `metrics.CorpusStat`) plus serving figures from
`RequestStats`: tokens/s and the shared p50/p95 TTFT / per-output-token
percentiles (`serving.latency_percentiles` — same columns as
benchmarks/bench_serving.py). Speculative deployments
(`deploy(..., draft_spec=...)`) additionally get a per-pair
`acceptance_rate` column (None on target-only pipelines), and
`assert_spec_decode_equivalence` gates the subsystem's core invariant:
the greedy spec-decode grid must equal the target-only grid
token-for-token, whatever the draft spec, cache layout, or horizon.
`assert_serving_equivalence` is the same gate generalized to any two
deployments of one checkpoint — a tensor-parallel mesh engine or a
ReplicaRouter cluster (``repro.cluster``) must reproduce the
single-device grid exactly.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence, Tuple

import jax.numpy as jnp

from ..data import LANG_CODES, SyntheticTranslation, pairs as fig9_pairs
from ..serving import SamplingParams, latency_percentiles
from .metrics import CorpusStat

__all__ = ["PairScore", "evaluate_pairs", "summarize",
           "decode_token_grid", "assert_spec_decode_equivalence",
           "assert_serving_equivalence"]


@dataclasses.dataclass(frozen=True)
class PairScore:
    """Quality + serving figures for one (src -> tgt) direction."""

    src: str
    tgt: str
    bleu: float
    chrf: float
    token_acc: float
    exact_match: float
    n_sent: int
    gen_tokens: int
    tok_s: float                      # generated tokens / pair wall-clock
    ttft_p50_ms: float
    ttft_p95_ms: float
    tpot_p50_ms: float
    tpot_p95_ms: float
    # speculative decoding only: fraction of this pair's drafted tokens
    # the target verify accepted (None on target-only deployments —
    # acceptance is a *speed* signal, quality columns are identical by
    # the greedy-equivalence invariant)
    acceptance_rate: Optional[float] = None

    def as_row(self) -> Dict:
        return dataclasses.asdict(self)


def _ordered_langs(pair_list: Sequence[Tuple[str, str]]) -> List[str]:
    """Languages covered by the pairs, in canonical LANG_CODES order —
    permutation draws depend on language order, so train and eval must
    derive the tuple the same way (launch.eval uses this helper too)."""
    used = {lang for pair in pair_list for lang in pair}
    return [lang for lang in LANG_CODES if lang in used]


def evaluate_pairs(pipe, pair_list: Optional[Sequence[Tuple[str, str]]] = None,
                   *, n_sent: int = 8, seed: int = 0,
                   max_new_tokens: Optional[int] = None,
                   languages: Optional[Sequence[str]] = None,
                   warmup: bool = True) -> List[PairScore]:
    """Score every (src, tgt) direction through the deployed engine.

    pair_list:  (src, tgt) directions to evaluate; default is the full
                bidirectional Indic<->overseas Fig. 9 grid (72 cells).
    n_sent:     held-out sentences per direction.
    seed:       dataset seed — MUST match the seed the checkpoint was
                trained with so the per-language permutations line up
                (the eval *content* stream is disjoint regardless;
                see SyntheticTranslation split="eval").
    max_new_tokens: decode budget per sentence; default = the reference
                length, clamped to the engine's max_len - 1 (the 1-token
                lang-code prompt takes one cache position). References
                are truncated to the same budget so corpus statistics
                compare equal spans.
    languages:  language tuple the corpus was built over; default = the
                languages appearing in pair_list, in LANG_CODES order.
                Pass the training tuple explicitly when it was larger.
    warmup:     serve the first pair once untimed before measuring, so
                XLA compiles don't land in the first pair's tok_s/TTFT
                columns (same discipline as bench_serving; scores are
                deterministic, only the serving figures change).
    """
    if pipe.cfg.family != "encdec":
        raise TypeError(
            f"pair evaluation needs a token-to-token enc-dec pipeline "
            f"(the synthetic corpus is src_tokens -> tgt), got family "
            f"{pipe.cfg.family!r}")
    pair_list = list(pair_list) if pair_list is not None else fig9_pairs()
    if not pair_list:
        raise ValueError("pair_list is empty")
    langs = list(languages) if languages is not None \
        else _ordered_langs(pair_list)
    cfg = pipe.cfg
    ds = SyntheticTranslation(cfg.vocab_size, cfg.enc_len, seed=seed,
                              languages=langs, split="eval")
    ref_len = cfg.enc_len - 2          # non-pad target span per sentence
    budget = pipe.engine.max_len - 1   # minus the lang-code prompt token
    gen = min(max_new_tokens or ref_len, ref_len, budget)
    if gen < 1:
        raise ValueError(
            f"engine max_len {pipe.engine.max_len} leaves no decode budget")
    sp = SamplingParams(max_new_tokens=gen)     # greedy, deterministic

    if warmup:
        # prime the engine's prefill/decode executables on the first
        # pair's exact request shapes, then drop the compile-tainted
        # run. A separate dataset instance keeps the scored content
        # stream identical whether or not warmup ran.
        wds = SyntheticTranslation(cfg.vocab_size, cfg.enc_len, seed=seed,
                                   languages=langs, split="eval")
        wsrc, wtgt = pair_list[0]
        pipe.translate(jnp.asarray(
            wds.sample(n_sent, pair=(wsrc, wtgt))["src_tokens"]), wtgt, sp)
        pipe.engine.reset_metrics()

    eng = pipe.engine
    scores: List[PairScore] = []
    for src_l, tgt_l in pair_list:
        batch = ds.sample(n_sent, pair=(src_l, tgt_l))
        refs = batch["tgt_out"][:, :gen]
        d0, a0 = eng.drafted_tokens, eng.accepted_tokens
        t0 = time.perf_counter()
        outs = pipe.translate(jnp.asarray(batch["src_tokens"]), tgt_l, sp)
        dt = time.perf_counter() - t0
        # per-pair acceptance from the counter deltas (None when the
        # pair ran target-only: no draft arm, or no speculative rounds)
        drafted = eng.drafted_tokens - d0
        acc_rate = round((eng.accepted_tokens - a0) / drafted, 4) \
            if drafted else None

        stat = CorpusStat()
        for out, ref in zip(outs, refs):
            stat.update(out.token_ids, [int(t) for t in ref])
        m = stat.results()
        toks = sum(o.num_generated for o in outs)
        lat = latency_percentiles(outs)
        scores.append(PairScore(
            src=src_l, tgt=tgt_l, bleu=m["bleu"], chrf=m["chrf"],
            token_acc=m["token_acc"], exact_match=m["exact_match"],
            n_sent=n_sent, gen_tokens=toks,
            tok_s=round(toks / dt, 1) if dt > 0 else 0.0,
            acceptance_rate=acc_rate, **lat))
    return scores


def summarize(scores: Sequence[PairScore]) -> Dict[str, float]:
    """Grid-level aggregate (unweighted mean over directions)."""
    n = max(len(scores), 1)
    return {"pairs": len(scores),
            "mean_bleu": sum(s.bleu for s in scores) / n,
            "mean_chrf": sum(s.chrf for s in scores) / n,
            "mean_token_acc": sum(s.token_acc for s in scores) / n,
            "gen_tokens": sum(s.gen_tokens for s in scores),
            "mean_tok_s": sum(s.tok_s for s in scores) / n}


def decode_token_grid(pipe, pair_list: Optional[Sequence[Tuple[str, str]]]
                      = None, *, n_sent: int = 4, seed: int = 0,
                      max_new_tokens: Optional[int] = None,
                      languages: Optional[Sequence[str]] = None
                      ) -> Dict[Tuple[str, str], tuple]:
    """The raw greedy token grid: (src, tgt) -> per-sentence
    (token_ids, finish_reason) tuples, served through the engine exactly
    like evaluate_pairs but without scoring — the comparable object for
    equivalence gates (dense vs paged, horizon=1 vs K, spec-decode vs
    target-only)."""
    if pipe.cfg.family != "encdec":
        raise TypeError(
            f"token grids need a token-to-token enc-dec pipeline, got "
            f"family {pipe.cfg.family!r}")
    pair_list = list(pair_list) if pair_list is not None else fig9_pairs()
    langs = list(languages) if languages is not None \
        else _ordered_langs(pair_list)
    cfg = pipe.cfg
    ds = SyntheticTranslation(cfg.vocab_size, cfg.enc_len, seed=seed,
                              languages=langs, split="eval")
    ref_len = cfg.enc_len - 2
    budget = pipe.engine.max_len - 1
    gen = min(max_new_tokens or ref_len, ref_len, budget)
    sp = SamplingParams(max_new_tokens=gen)
    grid: Dict[Tuple[str, str], tuple] = {}
    for src_l, tgt_l in pair_list:
        batch = ds.sample(n_sent, pair=(src_l, tgt_l))
        outs = pipe.translate(jnp.asarray(batch["src_tokens"]), tgt_l, sp)
        grid[(src_l, tgt_l)] = tuple(
            (tuple(o.token_ids), o.finish_reason) for o in outs)
    return grid


def assert_spec_decode_equivalence(spec_pipe, target_pipe,
                                   pair_list: Optional[
                                       Sequence[Tuple[str, str]]] = None,
                                   **grid_kwargs) -> None:
    """Gate the speculative-decoding invariant: the greedy grid served
    by a draft-armed pipeline must equal the target-only pipeline's
    grid token-for-token (finish reasons included). Raises
    AssertionError naming the first diverging pair. ``grid_kwargs``
    are forwarded to decode_token_grid (n_sent / seed / max_new_tokens
    / languages)."""
    want = decode_token_grid(target_pipe, pair_list, **grid_kwargs)
    got = decode_token_grid(spec_pipe, pair_list, **grid_kwargs)
    for pair, ref in want.items():
        if got[pair] != ref:
            raise AssertionError(
                f"speculative decode diverged from target-only on "
                f"{pair[0]}->{pair[1]} (draft "
                f"{spec_pipe.draft_spec_str}): {got[pair]} != {ref}")


def assert_serving_equivalence(pipe, ref_pipe,
                               pair_list: Optional[
                                   Sequence[Tuple[str, str]]] = None,
                               label: str = "deployment",
                               **grid_kwargs) -> None:
    """Gate the cluster invariant: ``pipe`` (a tensor-parallel mesh
    engine, a ReplicaRouter deployment — any serving stack over the
    same checkpoint) must serve the identical greedy grid as
    ``ref_pipe``, token-for-token with finish reasons. Raises
    AssertionError naming ``label`` and the first diverging pair;
    ``grid_kwargs`` forward to decode_token_grid."""
    want = decode_token_grid(ref_pipe, pair_list, **grid_kwargs)
    got = decode_token_grid(pipe, pair_list, **grid_kwargs)
    for pair, ref in want.items():
        if got[pair] != ref:
            raise AssertionError(
                f"{label} serving diverged from reference on "
                f"{pair[0]}->{pair[1]}: {got[pair]} != {ref}")
