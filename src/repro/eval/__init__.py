"""Quality evaluation: the paper's experimental grid as a subsystem.

The paper's central claim is quality parity under sub-octet precision —
bidirectional Indic<->international translation holds up at FP8/INT8/
INT4/FP4 while model size and latency drop ~4x (paper §IV, Fig. 9,
Tables IV-V). This package measures that claim end to end:

  metrics  — dependency-free corpus BLEU / chrF / chrF++ over token-id
             sequences, streaming accumulators for unbounded corpora;
  suite    — bidirectional language-pair matrix runner driven through
             the `repro.serving` request-level engine (no hand-rolled
             decode loops);
  sweep    — one trained checkpoint evaluated across precision presets,
             quality-vs-size-vs-throughput with bf16-anchor deltas;
  report   — JSON + markdown artifact writer with a stable round-trip
             schema, so CI runs form a quality trajectory next to the
             perf BENCH JSONs.

CLI: ``python -m repro.launch.eval --smoke --json out.json``.
"""

from .metrics import (BleuScore, BleuStat, ChrFStat, CorpusStat,
                      corpus_bleu, corpus_chrf, exact_match, token_accuracy)
from .report import load, make_report, render_markdown, save
from .suite import (PairScore, assert_serving_equivalence,
                    assert_spec_decode_equivalence, decode_token_grid,
                    evaluate_pairs, summarize)
from .sweep import FormatRow, quant_sweep

__all__ = ["BleuScore", "BleuStat", "ChrFStat", "CorpusStat", "corpus_bleu",
           "corpus_chrf", "exact_match", "token_accuracy", "PairScore",
           "evaluate_pairs", "summarize", "FormatRow", "quant_sweep",
           "make_report", "render_markdown", "save", "load",
           "decode_token_grid", "assert_spec_decode_equivalence",
           "assert_serving_equivalence"]
