"""Corpus translation-quality metrics, dependency-free.

BLEU (Papineni 2002) and chrF/chrF++ (Popović 2015/2017) implemented
directly over *token-id sequences* so the synthetic permutation-
translation task (data/synthetic.py) scores without a tokenizer: each
token id plays the role of a word (BLEU) or a character (chrF). An
optional ``detok`` callable maps an id sequence to a string, recovering
the standard text-level definitions for real checkpoints.

Everything streams: the per-metric accumulators (`BleuStat`, `ChrFStat`)
fold one (hypothesis, reference) pair at a time and merge across shards,
so million-sentence corpora never need materialization — `CorpusStat`
bundles all four metrics behind one ``update``.

Conventions (matching sacrebleu where a choice exists):
  * BLEU: clipped n-gram precisions up to ``max_n`` (default 4),
    multiplicative brevity penalty ``exp(1 - ref/hyp)`` for short
    hypotheses, smoothing ``"none"`` | ``"add-k"`` (k added to the
    numerator and denominator of every order > 1) | ``"floor"``
    (zero-match orders contribute ``eps`` precision).
  * chrF: per-order match/total counts summed over the corpus; the
    final score averages precision and recall over orders that appear
    in hypothesis or reference, then takes the F_beta (beta=2). A
    ``word_order`` of n > 0 (chrF++ uses 2) appends n-gram slots over
    the word stream (``detok(ids).split()`` when detok is given, the
    raw id sequence otherwise).
  * Degenerate corpora score 0.0 rather than raising: empty hypothesis,
    empty corpus, or no overlapping orders.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, List, Optional, Sequence, Tuple

__all__ = ["BleuScore", "BleuStat", "ChrFStat", "CorpusStat", "corpus_bleu",
           "corpus_chrf", "token_accuracy", "exact_match"]

Seq = Sequence  # token ids (ints) or characters (str elements)


def _ngram_counts(seq: Seq, n: int) -> Dict[Tuple, int]:
    counts: Dict[Tuple, int] = {}
    for i in range(len(seq) - n + 1):
        g = tuple(seq[i:i + n])
        counts[g] = counts.get(g, 0) + 1
    return counts


def _clipped_matches(hyp_counts: Dict, ref_counts: Dict) -> int:
    return sum(min(c, ref_counts.get(g, 0)) for g, c in hyp_counts.items())


# ---------------------------------------------------------------------------
# BLEU
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class BleuScore:
    """Corpus BLEU decomposition (score in [0, 1], not percent)."""

    score: float
    precisions: Tuple[float, ...]
    brevity_penalty: float
    hyp_len: int
    ref_len: int


class BleuStat:
    """Streaming corpus-BLEU sufficient statistics.

    ``update`` folds one sentence pair; ``merge`` combines shards;
    ``score`` is pure (call it at any point, keep updating after).
    """

    def __init__(self, max_n: int = 4):
        if max_n < 1:
            raise ValueError(f"max_n must be >= 1, got {max_n}")
        self.max_n = max_n
        self.matched = [0] * max_n       # clipped matches per order
        self.total = [0] * max_n         # hypothesis n-grams per order
        self.hyp_len = 0
        self.ref_len = 0

    def update(self, hyp: Seq, ref: Seq) -> None:
        self.hyp_len += len(hyp)
        self.ref_len += len(ref)
        for n in range(1, self.max_n + 1):
            hc = _ngram_counts(hyp, n)
            self.matched[n - 1] += _clipped_matches(hc, _ngram_counts(ref, n))
            self.total[n - 1] += max(len(hyp) - n + 1, 0)

    def merge(self, other: "BleuStat") -> "BleuStat":
        if other.max_n != self.max_n:
            raise ValueError(
                f"cannot merge BleuStat(max_n={other.max_n}) into max_n="
                f"{self.max_n}")
        self.matched = [a + b for a, b in zip(self.matched, other.matched)]
        self.total = [a + b for a, b in zip(self.total, other.total)]
        self.hyp_len += other.hyp_len
        self.ref_len += other.ref_len
        return self

    def score(self, smoothing: str = "add-k", k: float = 1.0,
              eps: float = 0.1) -> BleuScore:
        precisions = []
        for n in range(1, self.max_n + 1):
            m, t = self.matched[n - 1], self.total[n - 1]
            if smoothing == "add-k" and n > 1:
                m, t = m + k, t + k
            if t == 0:
                precisions.append(0.0)
                continue
            p = m / t
            if smoothing == "floor" and p == 0.0:
                p = eps / t
            precisions.append(p)
        if smoothing not in ("none", "add-k", "floor"):
            raise ValueError(f"unknown smoothing {smoothing!r}")
        if self.hyp_len == 0 or any(p == 0.0 for p in precisions):
            return BleuScore(0.0, tuple(precisions), 0.0 if not self.hyp_len
                             else self._bp(), self.hyp_len, self.ref_len)
        bp = self._bp()
        log_mean = sum(math.log(p) for p in precisions) / self.max_n
        return BleuScore(bp * math.exp(log_mean), tuple(precisions), bp,
                         self.hyp_len, self.ref_len)

    def _bp(self) -> float:
        if self.hyp_len >= self.ref_len:
            return 1.0
        return math.exp(1.0 - self.ref_len / self.hyp_len)


def corpus_bleu(hyps: Sequence[Seq], refs: Sequence[Seq], *, max_n: int = 4,
                smoothing: str = "add-k", k: float = 1.0,
                detok: Optional[Callable[[Seq], str]] = None) -> BleuScore:
    """One-shot corpus BLEU over parallel (hypothesis, reference) lists.

    With ``detok`` the unit is whitespace-split words of ``detok(ids)``;
    without it, the raw token ids.
    """
    if len(hyps) != len(refs):
        raise ValueError(f"got {len(hyps)} hypotheses vs {len(refs)} refs")
    stat = BleuStat(max_n)
    for h, r in zip(hyps, refs):
        if detok is not None:
            h, r = detok(h).split(), detok(r).split()
        stat.update(h, r)
    return stat.score(smoothing=smoothing, k=k)


# ---------------------------------------------------------------------------
# chrF / chrF++
# ---------------------------------------------------------------------------

class ChrFStat:
    """Streaming chrF sufficient statistics (char orders + word orders).

    Slots 0..max_n-1 hold character (= token id, unless detokenized)
    n-gram counts; slots max_n..max_n+word_order-1 hold word n-gram
    counts (the chrF++ extension; ``word_order=0`` is plain chrF).
    """

    def __init__(self, max_n: int = 6, beta: float = 2.0,
                 word_order: int = 0):
        if max_n < 1:
            raise ValueError(f"max_n must be >= 1, got {max_n}")
        self.max_n = max_n
        self.beta = beta
        self.word_order = word_order
        slots = max_n + word_order
        self.matched = [0] * slots
        self.hyp_total = [0] * slots
        self.ref_total = [0] * slots

    def _fold(self, slot: int, hyp: Seq, ref: Seq, n: int) -> None:
        hc = _ngram_counts(hyp, n)
        rc = _ngram_counts(ref, n)
        self.matched[slot] += _clipped_matches(hc, rc)
        self.hyp_total[slot] += sum(hc.values())
        self.ref_total[slot] += sum(rc.values())

    def update(self, hyp: Seq, ref: Seq,
               hyp_words: Optional[Seq] = None,
               ref_words: Optional[Seq] = None) -> None:
        """Fold one pair. ``hyp``/``ref`` are the character streams; the
        word streams default to them when chrF++ word orders are on."""
        for n in range(1, self.max_n + 1):
            self._fold(n - 1, hyp, ref, n)
        if self.word_order:
            hw = hyp if hyp_words is None else hyp_words
            rw = ref if ref_words is None else ref_words
            for n in range(1, self.word_order + 1):
                self._fold(self.max_n + n - 1, hw, rw, n)

    def merge(self, other: "ChrFStat") -> "ChrFStat":
        if (other.max_n, other.word_order) != (self.max_n, self.word_order):
            raise ValueError("cannot merge ChrFStat of different orders")
        self.matched = [a + b for a, b in zip(self.matched, other.matched)]
        self.hyp_total = [a + b
                          for a, b in zip(self.hyp_total, other.hyp_total)]
        self.ref_total = [a + b
                          for a, b in zip(self.ref_total, other.ref_total)]
        return self

    def score(self) -> float:
        """Average P and R over populated orders, then F_beta."""
        precisions: List[float] = []
        recalls: List[float] = []
        for m, ht, rt in zip(self.matched, self.hyp_total, self.ref_total):
            if ht == 0 and rt == 0:
                continue                 # order absent from both streams
            precisions.append(m / ht if ht else 0.0)
            recalls.append(m / rt if rt else 0.0)
        if not precisions:
            return 0.0
        p = sum(precisions) / len(precisions)
        r = sum(recalls) / len(recalls)
        if p == 0.0 or r == 0.0:
            return 0.0
        b2 = self.beta ** 2
        return (1 + b2) * p * r / (b2 * p + r)


def corpus_chrf(hyps: Sequence[Seq], refs: Sequence[Seq], *, max_n: int = 6,
                beta: float = 2.0, word_order: int = 0,
                detok: Optional[Callable[[Seq], str]] = None) -> float:
    """One-shot corpus chrF (``word_order=2`` gives chrF++).

    With ``detok`` the character stream is the detokenized string and
    the word stream its whitespace split; without it both are the raw
    token-id sequence.
    """
    if len(hyps) != len(refs):
        raise ValueError(f"got {len(hyps)} hypotheses vs {len(refs)} refs")
    stat = ChrFStat(max_n, beta, word_order)
    for h, r in zip(hyps, refs):
        if detok is not None:
            hs, rs = detok(h), detok(r)
            stat.update(hs, rs, hs.split(), rs.split())
        else:
            stat.update(h, r)
    return stat.score()


# ---------------------------------------------------------------------------
# token accuracy / exact match
# ---------------------------------------------------------------------------

def token_accuracy(hyp: Seq, ref: Seq) -> float:
    """Position-aligned token accuracy; length mismatch counts as error."""
    denom = max(len(hyp), len(ref))
    if denom == 0:
        return 1.0
    hits = sum(1 for a, b in zip(hyp, ref) if a == b)
    return hits / denom


def exact_match(hyp: Seq, ref: Seq) -> bool:
    return len(hyp) == len(ref) and all(a == b for a, b in zip(hyp, ref))


# ---------------------------------------------------------------------------
# combined streaming accumulator
# ---------------------------------------------------------------------------

class CorpusStat:
    """All four metrics behind one streaming ``update(hyp, ref)``.

    Used by the pair-matrix suite so a pair's corpus is scored without
    ever holding more than one sentence pair (plus O(orders) counters).
    """

    def __init__(self, max_n: int = 4, chrf_max_n: int = 6,
                 beta: float = 2.0, word_order: int = 0,
                 detok: Optional[Callable[[Seq], str]] = None):
        self.bleu = BleuStat(max_n)
        self.chrf = ChrFStat(chrf_max_n, beta, word_order)
        self.detok = detok
        self.n_sent = 0
        self._acc_sum = 0.0
        self._exact = 0

    def update(self, hyp: Seq, ref: Seq) -> None:
        self.n_sent += 1
        self._acc_sum += token_accuracy(hyp, ref)
        self._exact += int(exact_match(hyp, ref))
        if self.detok is not None:
            hs, rs = self.detok(hyp), self.detok(ref)
            self.bleu.update(hs.split(), rs.split())
            self.chrf.update(hs, rs, hs.split(), rs.split())
        else:
            self.bleu.update(hyp, ref)
            self.chrf.update(hyp, ref)

    def merge(self, other: "CorpusStat") -> "CorpusStat":
        self.bleu.merge(other.bleu)
        self.chrf.merge(other.chrf)
        self.n_sent += other.n_sent
        self._acc_sum += other._acc_sum
        self._exact += other._exact
        return self

    def results(self, smoothing: str = "add-k") -> Dict[str, float]:
        n = max(self.n_sent, 1)
        return {"bleu": self.bleu.score(smoothing=smoothing).score,
                "chrf": self.chrf.score(),
                "token_acc": self._acc_sum / n,
                "exact_match": self._exact / n}
