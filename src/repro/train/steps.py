"""Loss + train-step factories (full training and QLoRA finetuning).

Losses:
  * LM families: next-token CE, labels = tokens shifted left, pad-masked;
    VLM slices the text-aligned logits (image patches produce no loss).
  * enc-dec (NLLB/whisper): teacher-forced CE vs tgt_out with label
    smoothing 0.1 (NMT standard, matches the paper's training recipe
    lineage) + the MoE load-balancing aux loss (paper §II-A).

Steps:
  * make_train_step  — full AdamW training, optional microbatch gradient
    accumulation (lax.scan over microbatches) and remat; donated state.
  * make_qlora_step  — paper §III: base weights stay quantized+frozen,
    only LoRA adapters receive gradients/updates.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from ..core.qlora import extract_adapters, inject_adapters
from ..models.layers import Ctx
from ..optim import adamw_init, adamw_update

__all__ = ["compute_loss", "make_train_step", "make_qlora_step"]


def _xent(logits, labels, mask, label_smoothing: float = 0.0):
    """Masked token-mean cross-entropy, f32. logits (B,S,V)."""
    V = logits.shape[-1]
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    if label_smoothing > 0:
        smooth = -jnp.mean(logp, axis=-1)
        nll = (1 - label_smoothing) * nll + label_smoothing * smooth
    mask = mask.astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def compute_loss(ctx: Ctx, model, params, batch, *, remat: bool = False,
                 label_smoothing: Optional[float] = None):
    cfg = model.cfg
    logits, aux = model.forward(ctx, params, batch, remat=remat)
    if cfg.family in ("encdec", "audio"):
        ls = 0.1 if label_smoothing is None else label_smoothing
        loss = _xent(logits, batch["tgt_out"], batch["loss_mask"], ls)
    else:
        tokens = batch["tokens"]
        mask = batch.get("loss_mask", jnp.ones_like(tokens, jnp.float32))
        if cfg.family == "vlm" and "img_embeds" in batch:
            P = batch["img_embeds"].shape[1]
            S = tokens.shape[1]
            # position P-1+i predicts text token i: slice is already shifted
            logits = logits[:, P - 1:P + S - 1]
        else:
            logits = logits[:, :-1]
            tokens, mask = tokens[:, 1:], mask[:, 1:]
        ls = 0.0 if label_smoothing is None else label_smoothing
        loss = _xent(logits, tokens, mask, ls)
    aux_w = cfg.moe.aux_loss_weight if cfg.moe is not None else 0.0
    total = loss + aux_w * aux
    return total, {"loss": loss, "aux_loss": aux, "total_loss": total}


def _split_microbatches(batch, n: int):
    def split(x):
        if hasattr(x, "shape") and x.ndim >= 1 and x.shape[0] % n == 0:
            return x.reshape((n, x.shape[0] // n) + x.shape[1:])
        return None
    return jax.tree.map(split, batch)


def make_train_step(model, *, lr_fn, weight_decay=0.01, clip_norm=1.0,
                    state_bits=32, microbatches: int = 1, remat: bool = False,
                    label_smoothing: Optional[float] = None,
                    ctx: Optional[Ctx] = None, donate: bool = True,
                    param_dtype=None):
    """Returns (init_state_fn, step_fn). step(state, batch)->(state, metrics).

    param_dtype=jnp.bfloat16 enables the Megatron-style distributed
    optimizer: live params are bf16 (TP-sharded), an f32 master copy +
    moments live in opt state (FSDP-sharded over DP) — see
    parallel.param_shardings(fsdp_scope="opt").
    """
    ctx = ctx or Ctx()
    master = param_dtype is not None

    def init_state(params):
        if master:
            params = jax.tree.map(
                lambda p: p.astype(param_dtype)
                if hasattr(p, "dtype") and jnp.issubdtype(p.dtype, jnp.floating)
                else p, params)
        return {"params": params,
                "opt": adamw_init(params, state_bits=state_bits,
                                  master=master)}

    def loss_fn(params, batch):
        return compute_loss(ctx, model, params, batch, remat=remat,
                            label_smoothing=label_smoothing)

    def step(state, batch):
        params = state["params"]
        if microbatches > 1:
            mb = _split_microbatches(batch, microbatches)

            def acc_body(carry, mbatch):
                gsum, msum = carry
                (_, metrics), g = jax.value_and_grad(
                    loss_fn, has_aux=True)(params, mbatch)
                gsum = jax.tree.map(jnp.add, gsum, g)
                msum = jax.tree.map(jnp.add, msum, metrics)
                return (gsum, msum), None

            zero_g = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            zero_m = {"loss": 0.0, "aux_loss": 0.0, "total_loss": 0.0}
            zero_m = jax.tree.map(jnp.float32, zero_m)
            (grads, metrics), _ = jax.lax.scan(acc_body, (zero_g, zero_m), mb)
            grads = jax.tree.map(lambda g: g / microbatches, grads)
            metrics = jax.tree.map(lambda m: m / microbatches, metrics)
        else:
            (_, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)

        lr = lr_fn(state["opt"]["step"])
        new_params, new_opt, om = adamw_update(
            grads, state["opt"], params, lr=lr, weight_decay=weight_decay,
            clip_norm=clip_norm, state_bits=state_bits)
        metrics = dict(metrics, **om, lr=lr)
        return {"params": new_params, "opt": new_opt}, metrics

    return init_state, step


def make_qlora_step(model, *, lr_fn, clip_norm=1.0, remat=False,
                    label_smoothing=None, ctx: Optional[Ctx] = None):
    """QLoRA finetune step: grads/updates on adapters only (paper §III)."""
    ctx = ctx or Ctx()

    def init_state(qparams):
        adapters = extract_adapters(qparams)
        return {"adapters": adapters,
                "opt": adamw_init(adapters, state_bits=32)}

    def step(state, qparams, batch):
        def loss_fn(adapters):
            p = inject_adapters(qparams, adapters)
            return compute_loss(ctx, model, p, batch, remat=remat,
                                label_smoothing=label_smoothing)

        (_, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(state["adapters"])
        lr = lr_fn(state["opt"]["step"])
        new_ad, new_opt, om = adamw_update(
            grads, state["opt"], state["adapters"], lr=lr, weight_decay=0.0,
            clip_norm=clip_norm)
        return {"adapters": new_ad, "opt": new_opt}, dict(metrics, **om, lr=lr)

    return init_state, step
