from .loop import TrainLoop
from .steps import compute_loss, make_train_step, make_qlora_step

__all__ = ["compute_loss", "make_train_step", "make_qlora_step", "TrainLoop"]
