"""Production train loop: checkpoint/auto-resume, preemption, stragglers.

Fleet-scale behaviours (exercised on 1 device here, designed for 512+):
  * auto-resume from the latest checkpoint (elastic: restore reshards);
  * periodic async checkpoints + final checkpoint on preemption signal;
  * straggler watchdog: per-step wall time EMA; steps slower than
    ``straggler_factor`` x EMA are counted and logged — on a real fleet
    this feeds the scheduler's hot-spare / requeue policy;
  * loss-spike guard: optional skip-update on non-finite grads (flaky
    node / bitflip tolerance).
"""

from __future__ import annotations

import time
from typing import Callable, Iterator

import jax

from ..checkpoint import CheckpointManager

__all__ = ["TrainLoop"]


class TrainLoop:
    def __init__(self, step_fn: Callable, ckpt_dir: str, *,
                 ckpt_every: int = 100, keep: int = 3,
                 straggler_factor: float = 3.0,
                 log_every: int = 10,
                 log_fn: Callable[[str], None] = print):
        self.step_fn = step_fn
        self.mgr = CheckpointManager(ckpt_dir, keep=keep)
        self.ckpt_every = ckpt_every
        self.straggler_factor = straggler_factor
        self.log_every = log_every
        self.log = log_fn
        self.step_times: list[float] = []
        self.stragglers = 0

    def maybe_resume(self, state):
        step = self.mgr.latest_step()
        if step is None:
            return state, 0
        restored, step, _ = self.mgr.restore_latest(state)
        self.log(f"[resume] restored checkpoint at step {step}")
        return restored, step

    def run(self, state, batches: Iterator[dict], num_steps: int,
            start_step: int = 0):
        ema = None
        history = []
        for i in range(start_step, num_steps):
            batch = next(batches)
            t0 = time.perf_counter()
            state, metrics = self.step_fn(state, batch)
            jax.block_until_ready(metrics["loss"])
            dt = time.perf_counter() - t0

            # straggler watchdog
            if ema is None:
                ema = dt
            else:
                if dt > self.straggler_factor * ema and i > start_step + 2:
                    self.stragglers += 1
                    self.log(f"[straggler] step {i}: {dt:.3f}s vs EMA "
                             f"{ema:.3f}s")
                ema = 0.9 * ema + 0.1 * dt
            self.step_times.append(dt)

            loss = float(metrics["loss"])
            history.append(loss)
            if i % self.log_every == 0:
                self.log(f"step {i:5d} loss {loss:.4f} "
                         f"({dt*1e3:.0f} ms/step)")

            if self.ckpt_every and (i + 1) % self.ckpt_every == 0:
                self.mgr.save(state, i + 1)

            if self.mgr.preempted:      # SIGTERM fault tolerance
                self.log(f"[preempt] checkpoint + exit at step {i + 1}")
                self.mgr.save(state, i + 1, blocking=True)
                break

        self.mgr.wait()
        return state, history
