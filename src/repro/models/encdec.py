"""Encoder-decoder transformer: NLLB-600M (the paper's model) + whisper.

Paper §II-A: distilled NLLB-200 600M — pre-norm residual encoder/decoder
stacks, multi-head attention, two-layer FFNs, per-language tokenizers,
many-to-many translation driven by target-language code tokens; the MoE
variant (Fig. 3b) swaps the FFN for top-k experts. Whisper-base reuses the
same skeleton with a stub conv frontend (input_specs feeds precomputed
frame embeddings) and cross-attention from the decoder.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..core.qlinear import embed_lookup
from ..core.qtensor import maybe_dequantize
from ..parallel import hint, hint_pick
from . import moe as moe_mod
from .layers import (Ctx, attention_init, attn_apply, decode_attn_apply,
                     mlp, mlp_init, rms_norm)
from .transformer import (_commit_decode_position, _dense_kv, _fp8_token_kv,
                          _quantize_token_kv, _scatter_tokens, paged_attn,
                          paged_view)

__all__ = ["encdec_init", "encdec_encode", "encdec_forward",
           "encdec_init_cache", "encdec_init_paged_cache", "encdec_prefill",
           "encdec_decode_step"]


def _enc_layer_init(key, cfg):
    k1, k2 = jax.random.split(key)
    p = {
        "attn": attention_init(k1, cfg.d_model, cfg.num_heads,
                               cfg.num_kv_heads, cfg.head_dim),
        "norm1_scale": jnp.ones((cfg.d_model,), jnp.float32),
        "norm2_scale": jnp.ones((cfg.d_model,), jnp.float32),
    }
    if cfg.moe is not None:   # paper Fig. 3b: MoE encoder variant
        p["moe"] = moe_mod.moe_init(k2, cfg.d_model, cfg.d_ff,
                                    cfg.moe.num_experts, cfg.mlp_act)
    else:
        p["mlp"] = mlp_init(k2, cfg.d_model, cfg.d_ff, cfg.mlp_act)
    return p


def _dec_layer_init(key, cfg):
    k1, k2, k3 = jax.random.split(key, 3)
    p = {
        "attn": attention_init(k1, cfg.d_model, cfg.num_heads,
                               cfg.num_kv_heads, cfg.head_dim),
        "cross": attention_init(k2, cfg.d_model, cfg.num_heads,
                                cfg.num_kv_heads, cfg.head_dim),
        "norm1_scale": jnp.ones((cfg.d_model,), jnp.float32),
        "norm2_scale": jnp.ones((cfg.d_model,), jnp.float32),
        "norm3_scale": jnp.ones((cfg.d_model,), jnp.float32),
    }
    if cfg.moe is not None:
        p["moe"] = moe_mod.moe_init(k3, cfg.d_model, cfg.d_ff,
                                    cfg.moe.num_experts, cfg.mlp_act)
    else:
        p["mlp"] = mlp_init(k3, cfg.d_model, cfg.d_ff, cfg.mlp_act)
    return p


def encdec_init(key, cfg):
    ke, k1, k2, kh = jax.random.split(key, 4)
    enc_keys = jax.random.split(k1, cfg.enc_layers)
    dec_keys = jax.random.split(k2, cfg.num_layers)
    params = {
        "embedding": jax.random.normal(
            ke, (cfg.vocab_size, cfg.d_model), jnp.float32) * 0.02,
        "encoder": {
            "layers": jax.vmap(lambda k: _enc_layer_init(k, cfg))(enc_keys),
            "norm_f_scale": jnp.ones((cfg.d_model,), jnp.float32)},
        "decoder": {
            "layers": jax.vmap(lambda k: _dec_layer_init(k, cfg))(dec_keys),
            "norm_f_scale": jnp.ones((cfg.d_model,), jnp.float32)},
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = jax.random.normal(
            kh, (cfg.d_model, cfg.vocab_size), jnp.float32) * cfg.d_model ** -0.5
    return params


def encdec_encode(ctx: Ctx, params, cfg, src_tokens=None, frames=None,
                  remat: bool = False):
    """Bidirectional encoder. src_tokens (B,Se) or frames (B,F,d) (audio)."""
    if frames is not None:
        x = frames.astype(ctx.compute_dtype)          # stub conv frontend
    else:
        x = embed_lookup(params["embedding"], src_tokens, ctx.compute_dtype)
    x = hint(x, "batch", None, None)
    B, Se, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(Se, dtype=jnp.int32), (B, Se))

    def body(x, lp):
        h = rms_norm(x, lp["norm1_scale"], cfg.norm_eps)
        y, _ = attn_apply(ctx, lp["attn"], h, positions,
                          num_heads=cfg.num_heads,
                          num_kv_heads=cfg.num_kv_heads,
                          head_dim=cfg.head_dim, causal=False, window=0,
                          rope_theta=cfg.rope_theta, norm_eps=cfg.norm_eps,
                          site="enc.attn")
        x = x + y
        h = rms_norm(x, lp["norm2_scale"], cfg.norm_eps)
        if cfg.moe is not None:
            y, _ = moe_mod.moe_apply(ctx, lp["moe"], h, top_k=cfg.moe.top_k,
                                     capacity_factor=cfg.moe.capacity_factor,
                                     act=cfg.mlp_act,
                                     parallel_mode=cfg.moe.parallel_mode,
                                     dispatch_groups=cfg.moe.dispatch_groups)
        else:
            y = mlp(ctx, lp["mlp"], h, cfg.mlp_act, site="enc.ffn")
        x = x + y
        return hint_pick(x, ("batch", "model", None),
                         ("batch", None, None)), None

    body_fn = jax.checkpoint(body) if remat else body
    x, _ = jax.lax.scan(body_fn, x, params["encoder"]["layers"])
    return rms_norm(x, params["encoder"]["norm_f_scale"], cfg.norm_eps)


def _dec_layer(ctx, cfg, lp, x, positions, enc_kv, collect_kv):
    """enc_kv = (k, v, enc_positions) precomputed cross K/V."""
    h = rms_norm(x, lp["norm1_scale"], cfg.norm_eps)
    y, kv = attn_apply(ctx, lp["attn"], h, positions,
                       num_heads=cfg.num_heads, num_kv_heads=cfg.num_kv_heads,
                       head_dim=cfg.head_dim, causal=True, window=0,
                       rope_theta=cfg.rope_theta, norm_eps=cfg.norm_eps,
                       site="dec.attn")
    x = x + y
    h = rms_norm(x, lp["norm2_scale"], cfg.norm_eps)
    y, _ = attn_apply(ctx, lp["cross"], h, positions,
                      num_heads=cfg.num_heads, num_kv_heads=cfg.num_kv_heads,
                      head_dim=cfg.head_dim, causal=False, window=0,
                      kv_override=enc_kv, use_rope=False,
                      norm_eps=cfg.norm_eps, site="dec.cross")
    x = x + y
    h = rms_norm(x, lp["norm3_scale"], cfg.norm_eps)
    if cfg.moe is not None:
        y, aux = moe_mod.moe_apply(ctx, lp["moe"], h, top_k=cfg.moe.top_k,
                                   capacity_factor=cfg.moe.capacity_factor,
                                   act=cfg.mlp_act,
                                   parallel_mode=cfg.moe.parallel_mode,
                                     dispatch_groups=cfg.moe.dispatch_groups)
    else:
        y, aux = (mlp(ctx, lp["mlp"], h, cfg.mlp_act, site="dec.ffn"),
                  jnp.zeros((), jnp.float32))
    return hint_pick(x + y, ("batch", "model", None),
                     ("batch", None, None)), aux, kv


def _cross_kv(ctx, lp, cfg, enc_out):
    """Per-layer cross-attention K/V from encoder output."""
    B, Se, _ = enc_out.shape
    k = ctx.dot(enc_out, lp["cross"]["wk"], site="dec.cross.kv").reshape(
        B, Se, cfg.num_kv_heads, cfg.head_dim)
    v = ctx.dot(enc_out, lp["cross"]["wv"], site="dec.cross.kv").reshape(
        B, Se, cfg.num_kv_heads, cfg.head_dim)
    return k, v


def _head(ctx, params, cfg, x):
    if cfg.tie_embeddings:
        w = maybe_dequantize(params["embedding"], ctx.compute_dtype)
        logits = jnp.einsum("bsd,vd->bsv", x.astype(ctx.compute_dtype), w)
    else:
        logits = ctx.dot(x, params["lm_head"], site="head")
    return hint_pick(logits.astype(jnp.float32),
                     ("batch", "model", None), ("batch", None, "model"))


def encdec_forward(ctx: Ctx, params, cfg, tgt_tokens, src_tokens=None,
                   frames=None, remat: bool = False):
    """Teacher-forced decoder pass. Returns (logits, aux_loss)."""
    enc_out = encdec_encode(ctx, params, cfg, src_tokens, frames, remat)
    B, Sd = tgt_tokens.shape
    Se = enc_out.shape[1]
    x = embed_lookup(params["embedding"], tgt_tokens, ctx.compute_dtype)
    x = hint(x, "batch", None, None)
    positions = jnp.broadcast_to(jnp.arange(Sd, dtype=jnp.int32), (B, Sd))
    enc_pos = jnp.broadcast_to(jnp.arange(Se, dtype=jnp.int32), (B, Se))

    def body(carry, lp):
        x, aux = carry
        k, v = _cross_kv(ctx, lp, cfg, enc_out)
        x, aux_l, _ = _dec_layer(ctx, cfg, lp, x, positions,
                                 (k, v, enc_pos), False)
        return (x, aux + aux_l), None

    body_fn = jax.checkpoint(body) if remat else body
    (x, aux), _ = jax.lax.scan(body_fn, (x, jnp.zeros((), jnp.float32)),
                               params["decoder"]["layers"])
    x = rms_norm(x, params["decoder"]["norm_f_scale"], cfg.norm_eps)
    return _head(ctx, params, cfg, x), aux


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------

def encdec_init_cache(cfg, batch: int, max_len: int, enc_len: int,
                      kv_dtype: str = "bf16"):
    L, Hkv, hd = cfg.num_layers, cfg.num_kv_heads, cfg.head_dim
    cache = {
        "pos": jnp.full((batch, max_len), -1, jnp.int32),
        "len": jnp.zeros((batch,), jnp.int32),
        # valid cross-attention length per slot: requests whose source is
        # shorter than the allocated enc_len mask the tail instead of
        # forcing every admitted request to share one source length
        "cross_len": jnp.full((batch,), enc_len, jnp.int32),
    }
    if kv_dtype == "int8":
        # the paper's quantization applied to BOTH self and cross caches
        # (SS Perf iteration on the whisper decode cell: the static cross
        # cache is read every step and dominated decode bytes)
        cache.update(
            k_codes=jnp.zeros((L, batch, max_len, Hkv, hd), jnp.int8),
            k_scales=jnp.zeros((L, batch, max_len, Hkv), jnp.float32),
            v_codes=jnp.zeros((L, batch, max_len, Hkv, hd), jnp.int8),
            v_scales=jnp.zeros((L, batch, max_len, Hkv), jnp.float32),
            cross_k_codes=jnp.zeros((L, batch, enc_len, Hkv, hd), jnp.int8),
            cross_k_scales=jnp.zeros((L, batch, enc_len, Hkv), jnp.float32),
            cross_v_codes=jnp.zeros((L, batch, enc_len, Hkv, hd), jnp.int8),
            cross_v_scales=jnp.zeros((L, batch, enc_len, Hkv), jnp.float32))
        return cache
    if kv_dtype == "fp8":
        # e4m3 codes + per-(token, head) f32 scales, self AND cross —
        # same layout as int8 but with float8 storage ("k"/"v" keys so
        # the fp8 path is "k_scales present, k_codes absent")
        f8 = jnp.float8_e4m3fn
        cache.update(
            k=jnp.zeros((L, batch, max_len, Hkv, hd), f8),
            k_scales=jnp.zeros((L, batch, max_len, Hkv), jnp.float32),
            v=jnp.zeros((L, batch, max_len, Hkv, hd), f8),
            v_scales=jnp.zeros((L, batch, max_len, Hkv), jnp.float32),
            cross_k=jnp.zeros((L, batch, enc_len, Hkv, hd), f8),
            cross_k_scales=jnp.zeros((L, batch, enc_len, Hkv), jnp.float32),
            cross_v=jnp.zeros((L, batch, enc_len, Hkv, hd), f8),
            cross_v_scales=jnp.zeros((L, batch, enc_len, Hkv), jnp.float32))
        return cache
    dt = jnp.float32 if kv_dtype == "f32" else jnp.bfloat16
    cache.update(
        cross_k=jnp.zeros((L, batch, enc_len, Hkv, hd), dt),
        cross_v=jnp.zeros((L, batch, enc_len, Hkv, hd), dt),
        k=jnp.zeros((L, batch, max_len, Hkv, hd), dt),
        v=jnp.zeros((L, batch, max_len, Hkv, hd), dt))
    return cache


def encdec_prefill(ctx: Ctx, params, cfg, cache, tgt_tokens, src_tokens=None,
                   frames=None, lengths=None):
    """Encode source, run decoder prompt, fill self+cross caches."""
    enc_out = encdec_encode(ctx, params, cfg, src_tokens, frames)
    B, Sd = tgt_tokens.shape
    Se = enc_out.shape[1]
    x = embed_lookup(params["embedding"], tgt_tokens, ctx.compute_dtype)
    positions = jnp.broadcast_to(jnp.arange(Sd, dtype=jnp.int32), (B, Sd))
    enc_pos = jnp.broadcast_to(jnp.arange(Se, dtype=jnp.int32), (B, Se))

    def body(carry, lp):
        x, = carry
        ck, cv = _cross_kv(ctx, lp, cfg, enc_out)
        x, _, kv = _dec_layer(ctx, cfg, lp, x, positions,
                              (ck, cv, enc_pos), True)
        return (x,), (kv[0], kv[1], ck, cv)

    (x,), (ks, vs, cks, cvs) = jax.lax.scan(
        body, (x,), params["decoder"]["layers"])
    x = rms_norm(x, params["decoder"]["norm_f_scale"], cfg.norm_eps)
    logits = _head(ctx, params, cfg, x)

    lens = lengths if lengths is not None else jnp.full((B,), Sd, jnp.int32)
    new_cache = dict(cache)
    if "k_codes" in cache:
        kc, ksc = _quantize_token_kv(ks)
        vc, vsc = _quantize_token_kv(vs)
        new_cache["k_codes"] = cache["k_codes"].at[:, :, :Sd].set(kc)
        new_cache["k_scales"] = cache["k_scales"].at[:, :, :Sd].set(ksc)
        new_cache["v_codes"] = cache["v_codes"].at[:, :, :Sd].set(vc)
        new_cache["v_scales"] = cache["v_scales"].at[:, :, :Sd].set(vsc)
        ckc, cksc = _quantize_token_kv(cks)
        cvc, cvsc = _quantize_token_kv(cvs)
        new_cache["cross_k_codes"], new_cache["cross_k_scales"] = ckc, cksc
        new_cache["cross_v_codes"], new_cache["cross_v_scales"] = cvc, cvsc
    elif "k_scales" in cache:   # fp8 self + cross caches
        kc, ksc = _fp8_token_kv(ks)
        vc, vsc = _fp8_token_kv(vs)
        new_cache["k"] = cache["k"].at[:, :, :Sd].set(kc)
        new_cache["k_scales"] = cache["k_scales"].at[:, :, :Sd].set(ksc)
        new_cache["v"] = cache["v"].at[:, :, :Sd].set(vc)
        new_cache["v_scales"] = cache["v_scales"].at[:, :, :Sd].set(vsc)
        ckc, cksc = _fp8_token_kv(cks)
        cvc, cvsc = _fp8_token_kv(cvs)
        new_cache["cross_k"], new_cache["cross_k_scales"] = ckc, cksc
        new_cache["cross_v"], new_cache["cross_v_scales"] = cvc, cvsc
    else:
        new_cache["cross_k"] = cks.astype(cache["cross_k"].dtype)
        new_cache["cross_v"] = cvs.astype(cache["cross_v"].dtype)
        new_cache["k"] = cache["k"].at[:, :, :Sd].set(ks.astype(cache["k"].dtype))
        new_cache["v"] = cache["v"].at[:, :, :Sd].set(vs.astype(cache["v"].dtype))
    pos = jnp.where(positions < lens[:, None], positions, -1)
    new_cache["pos"] = cache["pos"].at[:, :Sd].set(pos)
    new_cache["len"] = lens
    new_cache["cross_len"] = jnp.full((B,), Se, jnp.int32)
    return new_cache, logits


def _enc_positions(cache, B: int, Se: int):
    """Cross-attention key positions, -1 beyond each slot's source."""
    enc_pos = jnp.broadcast_to(jnp.arange(Se, dtype=jnp.int32), (B, Se))
    cross_len = cache.get("cross_len")
    if cross_len is None:
        return enc_pos
    return jnp.where(enc_pos < cross_len[:, None], enc_pos, -1)


def encdec_decode_step(ctx: Ctx, params, cfg, tokens, cache):
    """One decoder token against self + cross caches. tokens (B,1).

    A cache carrying ``block_tables`` routes to the block-paged step.
    Like ``lm_decode_step``, a dense cache may carry an optional
    ``active`` (B,) i32 mask (injected by the engine's horizon-fused
    scan): inactive slots decode into masked positions (``pos`` stays
    -1) and their ``len`` freezes."""
    if "block_tables" in cache:
        return encdec_paged_decode_step(ctx, params, cfg, tokens, cache)
    B = tokens.shape[0]
    positions = cache["len"][:, None]
    x = embed_lookup(params["embedding"], tokens, ctx.compute_dtype)
    quant = "k_codes" in cache
    fp8 = "k_scales" in cache and not quant
    scaled = quant or fp8
    Se = (cache["cross_k_codes"] if quant else cache["cross_k"]).shape[2]
    enc_pos = _enc_positions(cache, B, Se)

    if quant:
        xs = (params["decoder"]["layers"], cache["k_codes"], cache["k_scales"],
              cache["v_codes"], cache["v_scales"], cache["cross_k_codes"],
              cache["cross_k_scales"], cache["cross_v_codes"],
              cache["cross_v_scales"])
    elif fp8:
        xs = (params["decoder"]["layers"], cache["k"], cache["k_scales"],
              cache["v"], cache["v_scales"], cache["cross_k"],
              cache["cross_k_scales"], cache["cross_v"],
              cache["cross_v_scales"])
    else:
        xs = (params["decoder"]["layers"], cache["k"], cache["v"],
              cache["cross_k"], cache["cross_v"])

    def body(x, layer_xs):
        if scaled:
            lp, kc, ksc, vc, vsc, ckc, cksc, cvc, cvsc = layer_xs
            k_dense, v_dense = _dense_kv(kc, ksc), _dense_kv(vc, vsc)
            ck, cv = _dense_kv(ckc, cksc), _dense_kv(cvc, cvsc)
        else:
            lp, k_dense, v_dense, ck, cv = layer_xs
            kc, vc, ksc, vsc = k_dense, v_dense, None, None
        h = rms_norm(x, lp["norm1_scale"], cfg.norm_eps)
        y, k_new, v_new = decode_attn_apply(
            ctx, lp["attn"], h, positions, k_dense, v_dense, cache["pos"],
            num_heads=cfg.num_heads, num_kv_heads=cfg.num_kv_heads,
            head_dim=cfg.head_dim, window=0, rope_theta=cfg.rope_theta,
            norm_eps=cfg.norm_eps, site="dec.attn")
        x = x + y
        h = rms_norm(x, lp["norm2_scale"], cfg.norm_eps)
        y, _ = attn_apply(ctx, lp["cross"], h, positions,
                          num_heads=cfg.num_heads,
                          num_kv_heads=cfg.num_kv_heads,
                          head_dim=cfg.head_dim, causal=False, window=0,
                          kv_override=(ck, cv, enc_pos), use_rope=False,
                          norm_eps=cfg.norm_eps, site="dec.cross")
        x = x + y
        h = rms_norm(x, lp["norm3_scale"], cfg.norm_eps)
        if cfg.moe is not None:
            y, _ = moe_mod.moe_apply(ctx, lp["moe"], h, top_k=cfg.moe.top_k,
                                     capacity_factor=cfg.moe.capacity_factor,
                                     act=cfg.mlp_act,
                                     parallel_mode=cfg.moe.parallel_mode,
                                     dropless=True,
                                     dispatch_groups=cfg.moe.dispatch_groups)
        else:
            y = mlp(ctx, lp["mlp"], h, cfg.mlp_act, site="dec.ffn")
        x = x + y
        if scaled:
            qfn = _quantize_token_kv if quant else _fp8_token_kv
            nkc, nks = qfn(k_new)
            nvc, nvs = qfn(v_new)
            return x, (_scatter_tokens(kc, nkc, cache["len"]),
                       _scatter_tokens(ksc, nks, cache["len"]),
                       _scatter_tokens(vc, nvc, cache["len"]),
                       _scatter_tokens(vsc, nvs, cache["len"]))
        return x, (_scatter_tokens(kc, k_new, cache["len"]),
                   _scatter_tokens(vc, v_new, cache["len"]))

    x, new_kv = jax.lax.scan(body, x, xs)
    x = rms_norm(x, params["decoder"]["norm_f_scale"], cfg.norm_eps)
    logits = _head(ctx, params, cfg, x)
    new_cache = dict(cache)
    if quant:
        (new_cache["k_codes"], new_cache["k_scales"],
         new_cache["v_codes"], new_cache["v_scales"]) = new_kv
    elif fp8:
        (new_cache["k"], new_cache["k_scales"],
         new_cache["v"], new_cache["v_scales"]) = new_kv
    else:
        new_cache["k"], new_cache["v"] = new_kv
    return _commit_decode_position(new_cache, cache, positions), logits


def encdec_init_paged_cache(cfg, slots: int, max_pages: int, num_pages: int,
                            page_size: int, kv_dtype: str = "bf16",
                            enc_len: int = 0):
    """Paged enc-dec serving cache.

    The decoder's self-attention KV is block-paged (shared pool); the
    cross-attention cache stays per-slot dense at ``enc_len`` capacity —
    it is written once per request and never grows, so paging buys
    nothing there — with per-slot ``cross_len`` masking so mixed source
    lengths coexist.
    """
    from ..serving.paged_cache import TRASH_PAGE, init_paged_kv
    L, Hkv, hd = cfg.num_layers, cfg.num_kv_heads, cfg.head_dim
    enc_len = enc_len or cfg.enc_len
    cache = init_paged_kv(L, num_pages, page_size, Hkv, hd, kv_dtype)
    if kv_dtype == "int8":
        cache.update(
            cross_k_codes=jnp.zeros((L, slots, enc_len, Hkv, hd), jnp.int8),
            cross_k_scales=jnp.zeros((L, slots, enc_len, Hkv), jnp.float32),
            cross_v_codes=jnp.zeros((L, slots, enc_len, Hkv, hd), jnp.int8),
            cross_v_scales=jnp.zeros((L, slots, enc_len, Hkv), jnp.float32))
    elif kv_dtype == "fp8":
        f8 = jnp.float8_e4m3fn
        cache.update(
            cross_k=jnp.zeros((L, slots, enc_len, Hkv, hd), f8),
            cross_k_scales=jnp.zeros((L, slots, enc_len, Hkv), jnp.float32),
            cross_v=jnp.zeros((L, slots, enc_len, Hkv, hd), f8),
            cross_v_scales=jnp.zeros((L, slots, enc_len, Hkv), jnp.float32))
    else:
        dt = jnp.float32 if kv_dtype == "f32" else jnp.bfloat16
        cache.update(
            cross_k=jnp.zeros((L, slots, enc_len, Hkv, hd), dt),
            cross_v=jnp.zeros((L, slots, enc_len, Hkv, hd), dt))
    cache["cross_len"] = jnp.zeros((slots,), jnp.int32)
    cache["block_tables"] = jnp.full((slots, max_pages), TRASH_PAGE,
                                     jnp.int32)
    cache["len"] = jnp.zeros((slots,), jnp.int32)
    cache["active"] = jnp.zeros((slots,), jnp.int32)
    return cache


def encdec_paged_decode_step(ctx: Ctx, params, cfg, tokens, cache):
    """One decoder token: paged self-attention + per-slot dense cross."""
    tables, active = cache["block_tables"], cache["active"]
    B = tokens.shape[0]
    positions = cache["len"][:, None]
    view_pos, pid, off = paged_view(cache)
    x = embed_lookup(params["embedding"], tokens, ctx.compute_dtype)
    quant = "k_codes" in cache
    fp8 = "k_scales" in cache and not quant
    scaled = quant or fp8
    Se = (cache["cross_k_codes"] if quant else cache["cross_k"]).shape[2]
    enc_pos = _enc_positions(cache, B, Se)
    use_kernel = ctx.paged_attn_impl == "kernel"
    lengths_now = jnp.where(active > 0, cache["len"] + 1, 0)

    if quant:
        xs = (params["decoder"]["layers"], cache["k_codes"],
              cache["k_scales"], cache["v_codes"], cache["v_scales"],
              cache["cross_k_codes"], cache["cross_k_scales"],
              cache["cross_v_codes"], cache["cross_v_scales"])
    elif fp8:
        xs = (params["decoder"]["layers"], cache["k"], cache["k_scales"],
              cache["v"], cache["v_scales"], cache["cross_k"],
              cache["cross_k_scales"], cache["cross_v"],
              cache["cross_v_scales"])
    else:
        xs = (params["decoder"]["layers"], cache["k"], cache["v"],
              cache["cross_k"], cache["cross_v"])

    def body(x, layer_xs):
        if scaled:
            lp, *leaves = layer_xs[:5]
            ckc, cksc, cvc, cvsc = layer_xs[5:]
            ck, cv = _dense_kv(ckc, cksc), _dense_kv(cvc, cvsc)
        else:
            lp, *leaves = layer_xs[:3]
            ck, cv = layer_xs[3:]
        h = rms_norm(x, lp["norm1_scale"], cfg.norm_eps)
        y, new_leaves = paged_attn(
            ctx, lp["attn"], h, positions, leaves, view_pos, pid, off,
            lengths_now, tables, use_kernel=use_kernel,
            num_heads=cfg.num_heads, num_kv_heads=cfg.num_kv_heads,
            head_dim=cfg.head_dim, window=0, rope_theta=cfg.rope_theta,
            norm_eps=cfg.norm_eps, site="dec.attn")
        x = x + y
        h = rms_norm(x, lp["norm2_scale"], cfg.norm_eps)
        y, _ = attn_apply(ctx, lp["cross"], h, positions,
                          num_heads=cfg.num_heads,
                          num_kv_heads=cfg.num_kv_heads,
                          head_dim=cfg.head_dim, causal=False, window=0,
                          kv_override=(ck, cv, enc_pos), use_rope=False,
                          norm_eps=cfg.norm_eps, site="dec.cross")
        x = x + y
        h = rms_norm(x, lp["norm3_scale"], cfg.norm_eps)
        if cfg.moe is not None:
            y, _ = moe_mod.moe_apply(ctx, lp["moe"], h, top_k=cfg.moe.top_k,
                                     capacity_factor=cfg.moe.capacity_factor,
                                     act=cfg.mlp_act,
                                     parallel_mode=cfg.moe.parallel_mode,
                                     dropless=True,
                                     dispatch_groups=cfg.moe.dispatch_groups)
        else:
            y = mlp(ctx, lp["mlp"], h, cfg.mlp_act, site="dec.ffn")
        return x + y, new_leaves

    x, new_kv = jax.lax.scan(body, x, xs)
    x = rms_norm(x, params["decoder"]["norm_f_scale"], cfg.norm_eps)
    logits = _head(ctx, params, cfg, x)
    new_cache = dict(cache)
    if quant:
        (new_cache["k_codes"], new_cache["k_scales"],
         new_cache["v_codes"], new_cache["v_scales"]) = new_kv
    elif fp8:
        (new_cache["k"], new_cache["k_scales"],
         new_cache["v"], new_cache["v_scales"]) = new_kv
    else:
        new_cache["k"], new_cache["v"] = new_kv
    new_cache["len"] = jnp.where(active > 0, cache["len"] + 1, cache["len"])
    return new_cache, logits
