"""Griffin-style hybrid LM (recurrentgemma-9b): RG-LRU + local attention.

Layer pattern is 2 recurrent : 1 local-attention (arXiv:2402.19427). The
38-layer stack runs as a scan over 12 uniform super-blocks of
(rglru, rglru, attn) plus a scanned 2-layer recurrent tail — compile-time
O(1) in depth while keeping the heterogeneous pattern.

Decode state is O(1) per recurrent layer (conv + h) and the attention
layers use a *rolling* KV buffer of window size W (2048): slot = pos % W,
with absolute positions stored so the window mask self-invalidates stale
slots. This is what makes long_500k feasible (DESIGN.md §4).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..core.qlinear import embed_lookup
from ..core.qtensor import maybe_dequantize
from ..parallel import hint, hint_pick
from .layers import (Ctx, attention_init, attn_apply, decode_attn_apply,
                     mlp, mlp_init, rms_norm)
from .rglru import rglru_apply, rglru_decode_step, rglru_init

__all__ = ["hybrid_init", "hybrid_forward", "hybrid_init_cache",
           "hybrid_prefill", "hybrid_decode_step", "hybrid_layout"]


def hybrid_layout(cfg):
    """(#super-blocks, #tail recurrent layers) for the 2:1 pattern."""
    n_super = cfg.num_layers // 3
    tail = cfg.num_layers - 3 * n_super
    return n_super, tail


def _mixer_block_init(key, cfg, kind: str):
    k1, k2 = jax.random.split(key)
    p = {"norm_t_scale": jnp.ones((cfg.d_model,), jnp.float32),
         "norm_m_scale": jnp.ones((cfg.d_model,), jnp.float32),
         "mlp": mlp_init(k2, cfg.d_model, cfg.d_ff, cfg.mlp_act)}
    if kind == "rglru":
        p["rglru"] = rglru_init(k1, cfg.d_model, cfg.d_rec)
    else:
        p["attn"] = attention_init(k1, cfg.d_model, cfg.num_heads,
                                   cfg.num_kv_heads, cfg.head_dim)
    return p


def hybrid_init(key, cfg):
    n_super, tail = hybrid_layout(cfg)
    ke, kb, kt, kh = jax.random.split(key, 4)

    def super_init(k):
        k1, k2, k3 = jax.random.split(k, 3)
        return {"r1": _mixer_block_init(k1, cfg, "rglru"),
                "r2": _mixer_block_init(k2, cfg, "rglru"),
                "at": _mixer_block_init(k3, cfg, "attn")}

    params = {
        "embedding": jax.random.normal(
            ke, (cfg.vocab_size, cfg.d_model), jnp.float32) * 0.02,
        "blocks": jax.vmap(super_init)(jax.random.split(kb, n_super)),
        "norm_f_scale": jnp.ones((cfg.d_model,), jnp.float32),
    }
    if tail:
        params["tail"] = jax.vmap(
            lambda k: _mixer_block_init(k, cfg, "rglru")
        )(jax.random.split(kt, tail))
    if not cfg.tie_embeddings:
        params["lm_head"] = jax.random.normal(
            kh, (cfg.d_model, cfg.vocab_size), jnp.float32) * cfg.d_model ** -0.5
    return params


def _residual_mixer(ctx, cfg, bp, x, positions, kind: str, state=None,
                    collect=False):
    """One (mixer + MLP) residual pair. Returns (x, new_state_or_kv)."""
    h = rms_norm(x, bp["norm_t_scale"], cfg.norm_eps)
    out_state = None
    if kind == "rglru":
        if state is not None or collect:
            y, out_state = rglru_apply(ctx, bp["rglru"], h, state,
                                       return_state=True)
        else:
            y = rglru_apply(ctx, bp["rglru"], h)
    else:
        y, kv = attn_apply(ctx, bp["attn"], h, positions,
                           num_heads=cfg.num_heads,
                           num_kv_heads=cfg.num_kv_heads,
                           head_dim=cfg.head_dim, causal=True,
                           window=cfg.local_window,
                           rope_theta=cfg.rope_theta, norm_eps=cfg.norm_eps)
        out_state = kv
    x = x + y
    h = rms_norm(x, bp["norm_m_scale"], cfg.norm_eps)
    x = x + mlp(ctx, bp["mlp"], h, cfg.mlp_act)
    return hint_pick(x, ("batch", "model", None),
                     ("batch", None, None)), out_state


def _head(ctx, params, cfg, x):
    x = rms_norm(x, params["norm_f_scale"], cfg.norm_eps)
    if cfg.tie_embeddings:
        w = maybe_dequantize(params["embedding"], ctx.compute_dtype)
        logits = jnp.einsum("bsd,vd->bsv", x.astype(ctx.compute_dtype), w)
    else:
        logits = ctx.dot(x, params["lm_head"])
    return hint_pick(logits.astype(jnp.float32),
                     ("batch", "model", None), ("batch", None, "model"))


def hybrid_forward(ctx: Ctx, params, cfg, tokens, remat: bool = False):
    """Full-sequence forward. Returns (logits, aux=0)."""
    B, S = tokens.shape
    x = embed_lookup(params["embedding"], tokens, ctx.compute_dtype)
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model ** 0.5, ctx.compute_dtype)
    x = hint(x, "batch", None, None)
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))

    def body(x, bp):
        x, _ = _residual_mixer(ctx, cfg, bp["r1"], x, positions, "rglru")
        x, _ = _residual_mixer(ctx, cfg, bp["r2"], x, positions, "rglru")
        x, _ = _residual_mixer(ctx, cfg, bp["at"], x, positions, "attn")
        return x, None

    body_fn = jax.checkpoint(body) if remat else body
    x, _ = jax.lax.scan(body_fn, x, params["blocks"])
    if "tail" in params:
        def tail_body(x, bp):
            x, _ = _residual_mixer(ctx, cfg, bp, x, positions, "rglru")
            return x, None
        x, _ = jax.lax.scan(jax.checkpoint(tail_body) if remat else tail_body,
                            x, params["tail"])
    return _head(ctx, params, cfg, x), jnp.zeros((), jnp.float32)


# ---------------------------------------------------------------------------
# serving: O(1) recurrent state + rolling local-attention KV
# ---------------------------------------------------------------------------

def hybrid_init_cache(cfg, batch: int, max_len: int, kv_dtype: str = "bf16"):
    n_super, tail = hybrid_layout(cfg)
    W = min(cfg.local_window, max_len)
    dr, Hkv, hd = cfg.d_rec, cfg.num_kv_heads, cfg.head_dim
    cache = {
        "b_conv1": jnp.zeros((n_super, batch, 3, dr), jnp.bfloat16),
        "b_h1": jnp.zeros((n_super, batch, dr), jnp.float32),
        "b_conv2": jnp.zeros((n_super, batch, 3, dr), jnp.bfloat16),
        "b_h2": jnp.zeros((n_super, batch, dr), jnp.float32),
        "b_k": jnp.zeros((n_super, batch, W, Hkv, hd), jnp.bfloat16),
        "b_v": jnp.zeros((n_super, batch, W, Hkv, hd), jnp.bfloat16),
        "pos_roll": jnp.full((batch, W), -1, jnp.int32),
        "len": jnp.zeros((batch,), jnp.int32),
    }
    if tail:
        cache["t_conv"] = jnp.zeros((tail, batch, 3, dr), jnp.bfloat16)
        cache["t_h"] = jnp.zeros((tail, batch, dr), jnp.float32)
    return cache


def _roll_slots(S: int, W: int):
    """Rolling-buffer fill for a prompt of length S (python-static)."""
    if S <= W:
        return jnp.arange(S), jnp.arange(S)          # src rows, dst slots
    src = jnp.arange(S - W, S)
    return src, src % W


def hybrid_prefill(ctx: Ctx, params, cfg, tokens, cache, lengths=None):
    B, S = tokens.shape
    W = cache["b_k"].shape[2]
    x = embed_lookup(params["embedding"], tokens, ctx.compute_dtype)
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model ** 0.5, ctx.compute_dtype)
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    src, dst = _roll_slots(S, W)

    def body(x, xs):
        bp, c1, h1, c2, h2 = xs
        x, st1 = _residual_mixer(ctx, cfg, bp["r1"], x, positions, "rglru",
                                 state=(c1, h1))
        x, st2 = _residual_mixer(ctx, cfg, bp["r2"], x, positions, "rglru",
                                 state=(c2, h2))
        x, kv = _residual_mixer(ctx, cfg, bp["at"], x, positions, "attn",
                                collect=True)
        k, v = kv
        k_roll = jnp.zeros((B, W) + k.shape[2:], jnp.bfloat16
                           ).at[:, dst].set(k[:, src].astype(jnp.bfloat16))
        v_roll = jnp.zeros((B, W) + v.shape[2:], jnp.bfloat16
                           ).at[:, dst].set(v[:, src].astype(jnp.bfloat16))
        return x, (st1[0].astype(jnp.bfloat16), st1[1],
                   st2[0].astype(jnp.bfloat16), st2[1], k_roll, v_roll)

    x, (c1, h1, c2, h2, kr, vr) = jax.lax.scan(
        body, x, (params["blocks"], cache["b_conv1"], cache["b_h1"],
                  cache["b_conv2"], cache["b_h2"]))
    new_cache = dict(cache, b_conv1=c1, b_h1=h1, b_conv2=c2, b_h2=h2,
                     b_k=kr, b_v=vr)
    if "tail" in params:
        def tail_body(x, xs):
            bp, c, h = xs
            x, st = _residual_mixer(ctx, cfg, bp, x, positions, "rglru",
                                    state=(c, h))
            return x, (st[0].astype(jnp.bfloat16), st[1])
        x, (tc, th) = jax.lax.scan(tail_body, x,
                                   (params["tail"], cache["t_conv"],
                                    cache["t_h"]))
        new_cache["t_conv"], new_cache["t_h"] = tc, th

    logits = _head(ctx, params, cfg, x)
    lens = lengths if lengths is not None else jnp.full((B,), S, jnp.int32)
    pos_roll = jnp.full((B, W), -1, jnp.int32).at[:, dst].set(
        jnp.broadcast_to(src, (B, src.shape[0])).astype(jnp.int32))
    new_cache["pos_roll"] = pos_roll
    new_cache["len"] = lens
    return new_cache, logits


def hybrid_decode_step(ctx: Ctx, params, cfg, tokens, cache):
    B = tokens.shape[0]
    W = cache["b_k"].shape[2]
    positions = cache["len"][:, None]
    slot = cache["len"] % W
    x = embed_lookup(params["embedding"], tokens, ctx.compute_dtype)
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model ** 0.5, ctx.compute_dtype)

    def upd(c, t, i):
        return jax.lax.dynamic_update_slice(
            c, t.astype(c.dtype), (i,) + (0,) * (c.ndim - 1))

    def body(x, xs):
        bp, c1, h1, c2, h2, kc, vc = xs

        h = rms_norm(x, bp["r1"]["norm_t_scale"], cfg.norm_eps)
        y, st1 = rglru_decode_step(ctx, bp["r1"]["rglru"], h, (c1, h1))
        x = x + y
        h = rms_norm(x, bp["r1"]["norm_m_scale"], cfg.norm_eps)
        x = x + mlp(ctx, bp["r1"]["mlp"], h, cfg.mlp_act)

        h = rms_norm(x, bp["r2"]["norm_t_scale"], cfg.norm_eps)
        y, st2 = rglru_decode_step(ctx, bp["r2"]["rglru"], h, (c2, h2))
        x = x + y
        h = rms_norm(x, bp["r2"]["norm_m_scale"], cfg.norm_eps)
        x = x + mlp(ctx, bp["r2"]["mlp"], h, cfg.mlp_act)

        h = rms_norm(x, bp["at"]["norm_t_scale"], cfg.norm_eps)
        y, k_new, v_new = decode_attn_apply(
            ctx, bp["at"]["attn"], h, positions, kc, vc, cache["pos_roll"],
            num_heads=cfg.num_heads, num_kv_heads=cfg.num_kv_heads,
            head_dim=cfg.head_dim, window=cfg.local_window,
            rope_theta=cfg.rope_theta, norm_eps=cfg.norm_eps)
        x = x + y
        h = rms_norm(x, bp["at"]["norm_m_scale"], cfg.norm_eps)
        x = x + mlp(ctx, bp["at"]["mlp"], h, cfg.mlp_act)

        kc = jax.vmap(upd)(kc, k_new, slot)
        vc = jax.vmap(upd)(vc, v_new, slot)
        return x, (st1[0].astype(jnp.bfloat16), st1[1],
                   st2[0].astype(jnp.bfloat16), st2[1], kc, vc)

    x, (c1, h1, c2, h2, kr, vr) = jax.lax.scan(
        body, x, (params["blocks"], cache["b_conv1"], cache["b_h1"],
                  cache["b_conv2"], cache["b_h2"], cache["b_k"],
                  cache["b_v"]))
    new_cache = dict(cache, b_conv1=c1, b_h1=h1, b_conv2=c2, b_h2=h2,
                     b_k=kr, b_v=vr)
    if "tail" in params:
        def tail_body(x, xs):
            bp, c, h = xs
            hh = rms_norm(x, bp["norm_t_scale"], cfg.norm_eps)
            y, st = rglru_decode_step(ctx, bp["rglru"], hh, (c, h))
            x2 = x + y
            hh = rms_norm(x2, bp["norm_m_scale"], cfg.norm_eps)
            x2 = x2 + mlp(ctx, bp["mlp"], hh, cfg.mlp_act)
            return x2, (st[0].astype(jnp.bfloat16), st[1])
        x, (tc, th) = jax.lax.scan(tail_body, x,
                                   (params["tail"], cache["t_conv"],
                                    cache["t_h"]))
        new_cache["t_conv"], new_cache["t_h"] = tc, th

    logits = _head(ctx, params, cfg, x)
    new_cache["pos_roll"] = jax.vmap(
        lambda c, t, i: jax.lax.dynamic_update_slice(c, t, (i,))
    )(cache["pos_roll"], positions, slot)
    new_cache["len"] = cache["len"] + 1
    return new_cache, logits
