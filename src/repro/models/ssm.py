"""Mamba-2 SSD layer (state-space duality, arXiv:2405.21060).

Training/prefill uses the chunked SSD dual form: quadratic attention-like
math inside chunks of length Q, a linear recurrence across chunk states
(lax.scan) — O(S·Q) work, O(S/Q) sequential depth. Decode carries an O(1)
recurrent state (B, nh, hp, ds), which is what makes the long_500k cell
feasible for this family (no KV cache at all).

Projections (in_proj/out_proj, ~90% of params) are quantizable via the
paper's policy; the recurrence itself runs in f32 (DESIGN.md
§Arch-applicability: state recurrences are precision-sensitive).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import Ctx, rms_norm

__all__ = ["ssm_init", "ssm_apply", "ssm_decode_step", "ssm_init_state",
           "ssm_naive_ref"]

_CONV_W = 4


def _dims(d_model, ssm_cfg):
    d_inner = ssm_cfg.expand * d_model
    nh = d_inner // ssm_cfg.head_dim
    ds = ssm_cfg.state_dim
    conv_dim = d_inner + 2 * ds          # x + B + C (n_groups = 1)
    d_in_proj = 2 * d_inner + 2 * ds + nh
    return d_inner, nh, ds, conv_dim, d_in_proj


def ssm_init(key, d_model: int, ssm_cfg, dtype=jnp.float32):
    d_inner, nh, ds, conv_dim, d_in_proj = _dims(d_model, ssm_cfg)
    ks = jax.random.split(key, 4)
    s = d_model ** -0.5
    return {
        "in_proj": jax.random.normal(ks[0], (d_model, d_in_proj), dtype) * s,
        "out_proj": jax.random.normal(ks[1], (d_inner, d_model), dtype)
                    * d_inner ** -0.5,
        "conv_w": jax.random.normal(ks[2], (_CONV_W, conv_dim), dtype) * 0.2,
        "conv_bias": jnp.zeros((conv_dim,), dtype),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, nh).astype(dtype)),
        "dt_bias": jnp.zeros((nh,), dtype),
        "D": jnp.ones((nh,), dtype),
        "norm_scale": jnp.ones((d_inner,), dtype),
    }


def _split_proj(ctx: Ctx, params, x, d_model, ssm_cfg):
    d_inner, nh, ds, conv_dim, _ = _dims(d_model, ssm_cfg)
    zxbcdt = ctx.dot(x, params["in_proj"])
    z = zxbcdt[..., :d_inner]
    xbc = zxbcdt[..., d_inner:d_inner + conv_dim]
    dt = zxbcdt[..., d_inner + conv_dim:]
    return z, xbc, dt


def _causal_conv(xbc, conv_w, conv_bias, init_state=None):
    """Depthwise causal conv, width 4. xbc (B,S,Cd); state (B,3,Cd)."""
    B, S, Cd = xbc.shape
    if init_state is None:
        init_state = jnp.zeros((B, _CONV_W - 1, Cd), xbc.dtype)
    xp = jnp.concatenate([init_state, xbc], axis=1)
    out = jnp.zeros_like(xbc, dtype=jnp.float32)
    for i in range(_CONV_W):
        out = out + xp[:, i:i + S].astype(jnp.float32) * conv_w[i].astype(jnp.float32)
    new_state = xp[:, -(_CONV_W - 1):]
    return jax.nn.silu(out + conv_bias.astype(jnp.float32)).astype(xbc.dtype), new_state


def _ssd_chunked(xh, Bm, Cm, dt, A, chunk: int):
    """Chunked SSD. xh (B,S,nh,hp); Bm/Cm (B,S,ds); dt (B,S,nh); A (nh,)<0.

    Returns y (B,S,nh,hp) and final state (B,nh,hp,ds). f32 throughout.
    """
    Bsz, S, nh, hp = xh.shape
    ds = Bm.shape[-1]
    Q = min(chunk, S)
    while S % Q:          # largest chunk <= requested that divides S
        Q -= 1
    nc = S // Q

    xh = xh.astype(jnp.float32).reshape(Bsz, nc, Q, nh, hp)
    Bm = Bm.astype(jnp.float32).reshape(Bsz, nc, Q, ds)
    Cm = Cm.astype(jnp.float32).reshape(Bsz, nc, Q, ds)
    dt = dt.astype(jnp.float32).reshape(Bsz, nc, Q, nh)

    a = dt * A[None, None, None, :]                  # (B,nc,Q,nh) log-decay
    cum = jnp.cumsum(a, axis=2)                      # within-chunk cumsum
    tot = cum[:, :, -1:, :]                          # (B,nc,1,nh)

    # intra-chunk (dual quadratic form); mask the *exponent* so backward
    # never sees 0 * exp(+large) = NaN
    li = cum[:, :, :, None, :] - cum[:, :, None, :, :]      # (B,nc,Q,Q,nh)
    iota = jnp.arange(Q)
    causal = (iota[:, None] >= iota[None, :])[None, None, :, :, None]
    L = jnp.exp(jnp.where(causal, li, -1e30))
    cb = jnp.einsum("bcqs,bcks->bcqk", Cm, Bm)              # (B,nc,Q,Q)
    xdt = xh * dt[..., None]                                # (B,nc,Q,nh,hp)
    y_intra = jnp.einsum("bcqk,bcqkh,bckhp->bcqhp", cb, L, xdt)

    # chunk states: S_c = sum_j exp(tot - cum_j) dt_j B_j (x) x_j
    decay_out = jnp.exp(tot - cum)                          # (B,nc,Q,nh)
    sc = jnp.einsum("bcqs,bcqh,bcqhp->bchps", Bm, decay_out * dt, xh)

    # inter-chunk recurrence over nc (sequential, length S/Q)
    chunk_decay = jnp.exp(tot[:, :, 0, :])                  # (B,nc,nh)

    def step(h, inp):
        dec, s_c = inp                                      # (B,nh), (B,nh,hp,ds)
        h_new = h * dec[:, :, None, None] + s_c
        return h_new, h                                     # emit state *before* chunk

    h0 = jnp.zeros((Bsz, nh, hp, ds), jnp.float32)
    h_last, h_prev = jax.lax.scan(
        step, h0, (jnp.moveaxis(chunk_decay, 1, 0), jnp.moveaxis(sc, 1, 0)))
    h_prev = jnp.moveaxis(h_prev, 0, 1)                     # (B,nc,nh,hp,ds)

    # inter-chunk contribution: C_i . h_prev * exp(cum_i)
    y_inter = jnp.einsum("bcqs,bchps,bcqh->bcqhp", Cm, h_prev, jnp.exp(cum))

    y = (y_intra + y_inter).reshape(Bsz, S, nh, hp)
    return y, h_last


def ssm_apply(ctx: Ctx, params, x, *, d_model: int, ssm_cfg,
              conv_state=None, ssm_state=None, return_state: bool = False):
    """Full-sequence SSD block. x (B,S,d) -> y (B,S,d)."""
    d_inner, nh, ds, conv_dim, _ = _dims(d_model, ssm_cfg)
    B, S, _ = x.shape
    z, xbc, dt = _split_proj(ctx, params, x, d_model, ssm_cfg)
    xbc, new_conv = _causal_conv(xbc, params["conv_w"], params["conv_bias"],
                                 conv_state)
    xs = xbc[..., :d_inner].reshape(B, S, nh, ssm_cfg.head_dim)
    Bm = xbc[..., d_inner:d_inner + ds]
    Cm = xbc[..., d_inner + ds:]
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(params["a_log"].astype(jnp.float32))

    y, h_last = _ssd_chunked(xs, Bm, Cm, dt, A, ssm_cfg.chunk)
    y = y + xs.astype(jnp.float32) * params["D"].astype(jnp.float32)[None, None, :, None]
    y = y.reshape(B, S, d_inner).astype(ctx.compute_dtype)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(ctx.compute_dtype)
    y = rms_norm(y, params["norm_scale"])
    out = ctx.dot(y, params["out_proj"])
    if return_state:
        return out, (new_conv, h_last)
    return out


def ssm_init_state(cfg, batch: int, d_model: int, ssm_cfg):
    d_inner, nh, ds, conv_dim, _ = _dims(d_model, ssm_cfg)
    return (jnp.zeros((batch, _CONV_W - 1, conv_dim), jnp.bfloat16),
            jnp.zeros((batch, nh, ssm_cfg.head_dim, ds), jnp.float32))


def ssm_decode_step(ctx: Ctx, params, x, state, *, d_model: int, ssm_cfg):
    """One-token recurrent update. x (B,1,d); state (conv, h)."""
    d_inner, nh, ds, conv_dim, _ = _dims(d_model, ssm_cfg)
    B = x.shape[0]
    conv_state, h = state
    z, xbc, dt = _split_proj(ctx, params, x, d_model, ssm_cfg)

    # conv over [state, new token]
    xp = jnp.concatenate([conv_state.astype(xbc.dtype), xbc], axis=1)  # (B,4,Cd)
    conv = jnp.einsum("bwc,wc->bc", xp.astype(jnp.float32),
                      params["conv_w"].astype(jnp.float32))
    xbc1 = jax.nn.silu(conv + params["conv_bias"].astype(jnp.float32))  # (B,Cd)
    new_conv = xp[:, 1:]

    xs = xbc1[:, :d_inner].reshape(B, nh, ssm_cfg.head_dim)
    Bm = xbc1[:, d_inner:d_inner + ds]
    Cm = xbc1[:, d_inner + ds:]
    dtv = jax.nn.softplus(dt[:, 0].astype(jnp.float32)
                          + params["dt_bias"].astype(jnp.float32))      # (B,nh)
    A = -jnp.exp(params["a_log"].astype(jnp.float32))

    decay = jnp.exp(dtv * A[None, :])                                   # (B,nh)
    h = h * decay[:, :, None, None] + jnp.einsum(
        "bh,bhp,bs->bhps", dtv, xs.astype(jnp.float32), Bm.astype(jnp.float32))
    y = jnp.einsum("bhps,bs->bhp", h, Cm.astype(jnp.float32))
    y = y + xs.astype(jnp.float32) * params["D"].astype(jnp.float32)[None, :, None]
    y = y.reshape(B, 1, d_inner).astype(ctx.compute_dtype)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(ctx.compute_dtype)
    y = rms_norm(y, params["norm_scale"])
    return ctx.dot(y, params["out_proj"]), (new_conv, h)


def ssm_naive_ref(ctx: Ctx, params, x, *, d_model: int, ssm_cfg):
    """Step-by-step recurrence oracle (tests: chunked == naive)."""
    B, S, _ = x.shape
    state = ssm_init_state(None, B, d_model, ssm_cfg)
    outs = []
    for t in range(S):
        y, state = ssm_decode_step(ctx, params, x[:, t:t + 1], state,
                                   d_model=d_model, ssm_cfg=ssm_cfg)
        outs.append(y)
    return jnp.concatenate(outs, axis=1)
