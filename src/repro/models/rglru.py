"""RG-LRU recurrent block (Griffin / RecurrentGemma, arXiv:2402.19427).

The hybrid arch interleaves 2 recurrent blocks : 1 local-attention block.
The RG-LRU is a gated first-order linear recurrence:

    r_t = sigmoid(x_t W_rg)          (recurrence gate)
    i_t = sigmoid(x_t W_ig)          (input gate)
    a_t = exp(-c * softplus(L) * r_t)
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

Full-sequence form runs as a log-depth jax.lax.associative_scan; decode
carries h (B, d_rec) — O(1) state, so long_500k is feasible (DESIGN.md).
Recurrence math stays f32; the surrounding projections are quantizable.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import Ctx

__all__ = ["rglru_init", "rglru_apply", "rglru_decode_step",
           "rglru_init_state"]

_C = 8.0
_CONV_W = 4


def rglru_init(key, d_model: int, d_rec: int, dtype=jnp.float32):
    ks = jax.random.split(key, 6)
    s = d_model ** -0.5
    sr = d_rec ** -0.5
    return {
        "gate_proj": jax.random.normal(ks[0], (d_model, d_rec), dtype) * s,
        "in_proj": jax.random.normal(ks[1], (d_model, d_rec), dtype) * s,
        "conv_w": jax.random.normal(ks[2], (_CONV_W, d_rec), dtype) * 0.2,
        "conv_bias": jnp.zeros((d_rec,), dtype),
        "w_rg": jax.random.normal(ks[3], (d_rec, d_rec), dtype) * sr,
        "w_ig": jax.random.normal(ks[4], (d_rec, d_rec), dtype) * sr,
        "a_param": jnp.full((d_rec,), -4.0, dtype),   # a ~ 0.95 at r=0.5
        "out_proj": jax.random.normal(ks[5], (d_rec, d_model), dtype) * sr,
    }


def _gates(ctx: Ctx, params, xr):
    r = jax.nn.sigmoid(ctx.dot(xr, params["w_rg"]).astype(jnp.float32))
    i = jax.nn.sigmoid(ctx.dot(xr, params["w_ig"]).astype(jnp.float32))
    log_a = -_C * jax.nn.softplus(params["a_param"].astype(jnp.float32)) * r
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * i * xr.astype(jnp.float32)
    return a, b


def _conv(x, w, bias, state=None):
    B, S, C = x.shape
    if state is None:
        state = jnp.zeros((B, _CONV_W - 1, C), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)
    out = jnp.zeros((B, S, C), jnp.float32)
    for i in range(_CONV_W):
        out = out + xp[:, i:i + S].astype(jnp.float32) * w[i].astype(jnp.float32)
    return (out + bias.astype(jnp.float32)).astype(x.dtype), xp[:, -(_CONV_W - 1):]


def rglru_apply(ctx: Ctx, params, x, state=None, return_state: bool = False):
    """Full-sequence recurrent block. x (B,S,d) -> (B,S,d)."""
    gate = ctx.naf(ctx.dot(x, params["gate_proj"]), "gelu")
    xr = ctx.dot(x, params["in_proj"])
    conv_state, h0 = state if state is not None else (None, None)
    xr, new_conv = _conv(xr, params["conv_w"], params["conv_bias"], conv_state)

    a, b = _gates(ctx, params, xr)                      # (B,S,d_rec) f32
    if h0 is not None:  # fold initial state into the first step
        b = b.at[:, 0].add(a[:, 0] * h0)

    def combine(lhs, rhs):
        al, bl = lhs
        ar, br = rhs
        return al * ar, bl * ar + br

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    y = (h.astype(ctx.compute_dtype) * gate)
    out = ctx.dot(y, params["out_proj"])
    if return_state:
        return out, (new_conv, h[:, -1])
    return out


def rglru_init_state(batch: int, d_rec: int):
    return (jnp.zeros((batch, _CONV_W - 1, d_rec), jnp.bfloat16),
            jnp.zeros((batch, d_rec), jnp.float32))


def rglru_decode_step(ctx: Ctx, params, x, state):
    """One-token step. x (B,1,d); state = (conv (B,3,d_rec), h (B,d_rec))."""
    conv_state, h = state
    gate = ctx.naf(ctx.dot(x, params["gate_proj"]), "gelu")   # (B,1,d_rec)
    xr = ctx.dot(x, params["in_proj"])
    xp = jnp.concatenate([conv_state.astype(xr.dtype), xr], axis=1)  # (B,4,dr)
    conv = jnp.einsum("bwc,wc->bc", xp.astype(jnp.float32),
                      params["conv_w"].astype(jnp.float32))
    xr1 = (conv + params["conv_bias"].astype(jnp.float32))[:, None, :]  # (B,1,dr)
    a, b = _gates(ctx, params, xr1.astype(ctx.compute_dtype))
    h_new = a[:, 0] * h + b[:, 0]
    y = h_new[:, None, :].astype(ctx.compute_dtype) * gate
    return ctx.dot(y, params["out_proj"]), (xp[:, 1:], h_new)
