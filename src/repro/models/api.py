"""Unified model facade: one callable surface per architecture family.

build_model(cfg) -> ModelAPI with
  init(key)                         -> params
  forward(ctx, params, batch, ...)  -> (logits, aux_loss)
  init_cache(batch, max_len, kv)    -> dense serving cache
  init_paged_cache(slots, max_pages, num_pages, page_size, kv)
                                    -> block-paged serving cache
                                       (attention families only)
  prefill(ctx, params, cache, batch)-> (cache, logits)
  decode_step(ctx, params, tok, c)  -> (cache, logits)

decode_step dispatches on the cache layout: a cache carrying
``block_tables`` (from init_paged_cache) runs the paged attention path,
anything else the dense path — one call site serves both.

Batches are dicts:
  LM families:   {"tokens" (B,S)}  [+ "img_embeds" (B,P,d) for vlm]
  enc-dec:       {"tgt_in" (B,Sd)} + {"src_tokens" (B,Se) | "frames" (B,F,d)}
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from . import encdec as ed
from . import hybrid as hy
from . import transformer as tf

__all__ = ["ModelAPI", "build_model", "decode_block"]


@dataclasses.dataclass(frozen=True)
class ModelAPI:
    cfg: Any
    init: Callable
    forward: Callable
    init_cache: Callable
    prefill: Callable
    decode_step: Callable
    init_paged_cache: Optional[Callable] = None


def decode_block(model: "ModelAPI", ctx, params, tokens, cache):
    """Teacher-forced multi-token decode: feed ``tokens`` (B, K) through
    K fused ``decode_step`` micro-steps (one on-device ``lax.scan``) and
    return ``(cache, logits (B, K, V))``.

    This is the speculative-decoding verify path: one batched target
    forward over a drafted block. Per-slot valid-length masking rides on
    the cache's own machinery — dense caches mask by ``pos``/``len``,
    paged caches by ``block_tables``/``len``/``active`` — identically to
    single-token decode, so the logits at position i are exactly what a
    sequential decode of the same prefix would produce. Callers that
    need retired slots frozen inject an ``active`` mask into the cache
    first (it is constant across the block, so once is enough).
    """
    def body(c, tok):
        c, logits = model.decode_step(ctx, params, tok[:, None], c)
        return c, logits[:, -1]

    cache, lg = jax.lax.scan(body, cache, jnp.swapaxes(tokens, 0, 1))
    return cache, jnp.swapaxes(lg, 0, 1)


def _no_paged_cache(fam: str) -> Callable:
    def init_paged_cache(*a, **k):
        raise ValueError(
            f"family {fam!r} keeps O(1)-per-sequence recurrent state; "
            "block-paged KV caches apply to attention families only")
    return init_paged_cache


def build_model(cfg) -> ModelAPI:
    fam = cfg.family

    if fam in ("dense", "moe", "ssm", "vlm"):
        def forward(ctx, params, batch, remat=False):
            logits, aux, _ = tf.lm_forward(
                ctx, params, cfg, batch["tokens"],
                img_embeds=batch.get("img_embeds"), remat=remat)
            return logits, aux

        def init_cache(batch_size, max_len, kv_dtype="bf16"):
            return tf.lm_init_cache(cfg, batch_size, max_len, kv_dtype)

        def prefill(ctx, params, cache, batch):
            return tf.lm_prefill(ctx, params, cfg, batch["tokens"], cache,
                                 lengths=batch.get("lengths"),
                                 img_embeds=batch.get("img_embeds"))

        def decode_step(ctx, params, tokens, cache):
            return tf.lm_decode_step(ctx, params, cfg, tokens, cache)

        def init_paged_cache(slots, max_pages, num_pages, page_size,
                             kv_dtype="bf16"):
            return tf.lm_init_paged_cache(cfg, slots, max_pages, num_pages,
                                          page_size, kv_dtype)

        return ModelAPI(cfg, lambda key: tf.lm_init(key, cfg), forward,
                        init_cache, prefill, decode_step,
                        _no_paged_cache(fam) if fam == "ssm"
                        else init_paged_cache)

    if fam == "hybrid":
        def forward(ctx, params, batch, remat=False):
            return hy.hybrid_forward(ctx, params, cfg, batch["tokens"],
                                     remat=remat)

        def init_cache(batch_size, max_len, kv_dtype="bf16"):
            return hy.hybrid_init_cache(cfg, batch_size, max_len, kv_dtype)

        def prefill(ctx, params, cache, batch):
            return hy.hybrid_prefill(ctx, params, cfg, batch["tokens"], cache,
                                     lengths=batch.get("lengths"))

        def decode_step(ctx, params, tokens, cache):
            return hy.hybrid_decode_step(ctx, params, cfg, tokens, cache)

        return ModelAPI(cfg, lambda key: hy.hybrid_init(key, cfg), forward,
                        init_cache, prefill, decode_step,
                        _no_paged_cache(fam))

    if fam in ("encdec", "audio"):
        def forward(ctx, params, batch, remat=False):
            return ed.encdec_forward(ctx, params, cfg, batch["tgt_in"],
                                     src_tokens=batch.get("src_tokens"),
                                     frames=batch.get("frames"), remat=remat)

        def init_cache(batch_size, max_len, kv_dtype="bf16", enc_len=None):
            return ed.encdec_init_cache(cfg, batch_size, max_len,
                                        enc_len or cfg.enc_len, kv_dtype)

        def prefill(ctx, params, cache, batch):
            return ed.encdec_prefill(ctx, params, cfg, cache,
                                     batch["tgt_in"],
                                     src_tokens=batch.get("src_tokens"),
                                     frames=batch.get("frames"),
                                     lengths=batch.get("lengths"))

        def decode_step(ctx, params, tokens, cache):
            return ed.encdec_decode_step(ctx, params, cfg, tokens, cache)

        def init_paged_cache(slots, max_pages, num_pages, page_size,
                             kv_dtype="bf16", enc_len=None):
            return ed.encdec_init_paged_cache(
                cfg, slots, max_pages, num_pages, page_size, kv_dtype,
                enc_len=enc_len or cfg.enc_len)

        return ModelAPI(cfg, lambda key: ed.encdec_init(key, cfg), forward,
                        init_cache, prefill, decode_step, init_paged_cache)

    raise ValueError(f"unknown family {fam!r}")
