"""Decoder-only LM family: dense / MoE / VLM-backbone / SSM (Mamba-2).

One skeleton (embed -> scanned layer stack -> final norm -> lm head) with
the temporal mixer and FFN chosen per config. Layer stacks run under
jax.lax.scan with stacked weights (compile-time O(1) in depth — required
for the 512-device dry-run) and optional per-layer remat.

Decode caches are pytrees scanned as xs/ys alongside the layer weights:
  attn archs:  k/v (L, B, Smax, Hkv, hd) — bf16, or int8 codes + scales
               when the policy quantizes the KV cache (paper's technique
               applied to activations-at-rest);
  mamba2:      conv (L, B, 3, conv_dim) + ssd state (L, B, nh, hp, ds).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..core.qlinear import embed_lookup
from ..core.qtensor import maybe_dequantize
from ..kernels.paging import gather_pages as _gather_pages
from ..kernels.paging import scatter_token as _scatter_token
from ..parallel import hint, hint_pick
from . import moe as moe_mod
from . import ssm as ssm_mod
from .layers import (Ctx, attention_init, attn_apply, decode_attn_apply,
                     linear, mlp, mlp_init, rms_norm, rope)

__all__ = ["lm_init", "lm_forward", "lm_init_cache", "lm_init_paged_cache",
           "lm_prefill", "lm_decode_step", "window_array"]


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def window_array(cfg) -> jnp.ndarray:
    """Per-layer attention window (0 = full). Encodes gemma3's 5:1 pattern."""
    if cfg.window_pattern:
        pat = list(cfg.window_pattern)
        wins = [pat[i % len(pat)] for i in range(cfg.num_layers)]
    else:
        wins = [0] * cfg.num_layers
    return jnp.asarray(wins, jnp.int32)


def _layer_init(key, cfg):
    k1, k2 = jax.random.split(key)
    p = {"norm1_scale": jnp.ones((cfg.d_model,), jnp.float32),
         "norm2_scale": jnp.ones((cfg.d_model,), jnp.float32)}
    if cfg.family == "ssm":
        p["ssm"] = ssm_mod.ssm_init(k1, cfg.d_model, cfg.ssm)
        del p["norm2_scale"]
        return p
    p["attn"] = attention_init(k1, cfg.d_model, cfg.num_heads,
                               cfg.num_kv_heads, cfg.head_dim,
                               qkv_bias=cfg.qkv_bias, qk_norm=cfg.qk_norm)
    if cfg.moe is not None:
        p["moe"] = moe_mod.moe_init(k2, cfg.d_model, cfg.d_ff,
                                    cfg.moe.num_experts, cfg.mlp_act)
    else:
        p["mlp"] = mlp_init(k2, cfg.d_model, cfg.d_ff, cfg.mlp_act)
    return p


def lm_init(key, cfg):
    ke, kl, kh = jax.random.split(key, 3)
    # stacked per-layer params: init each leaf once, tile via vmap over keys
    layer_keys = jax.random.split(kl, cfg.num_layers)
    layers = jax.vmap(lambda k: _layer_init(k, cfg))(layer_keys)
    params = {
        "embedding": jax.random.normal(
            ke, (cfg.vocab_size, cfg.d_model), jnp.float32) * 0.02,
        "layers": layers,
        "norm_f_scale": jnp.ones((cfg.d_model,), jnp.float32),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = jax.random.normal(
            kh, (cfg.d_model, cfg.vocab_size), jnp.float32) * cfg.d_model ** -0.5
    return params


# ---------------------------------------------------------------------------
# full-sequence forward (train / prefill)
# ---------------------------------------------------------------------------

def _layer_fwd(ctx: Ctx, cfg, lp, window, x, positions, collect_kv: bool):
    h = hint(rms_norm(x, lp["norm1_scale"], cfg.norm_eps),
             "batch", None, None)   # gather S for the projections
    if cfg.family == "ssm":
        y = ssm_mod.ssm_apply(ctx, lp["ssm"], h, d_model=cfg.d_model,
                              ssm_cfg=cfg.ssm)
        return x + y, jnp.zeros((), jnp.float32), None
    y, kv = attn_apply(ctx, lp["attn"], h, positions,
                       num_heads=cfg.num_heads, num_kv_heads=cfg.num_kv_heads,
                       head_dim=cfg.head_dim, causal=True, window=window,
                       rope_theta=cfg.rope_theta, norm_eps=cfg.norm_eps)
    x = x + y
    h = hint(rms_norm(x, lp["norm2_scale"], cfg.norm_eps), "batch", None, None)
    if cfg.moe is not None:
        y, aux = moe_mod.moe_apply(
            ctx, lp["moe"], h, top_k=cfg.moe.top_k,
            capacity_factor=cfg.moe.capacity_factor, act=cfg.mlp_act,
            parallel_mode=cfg.moe.parallel_mode,
                dispatch_groups=cfg.moe.dispatch_groups)
    else:
        y, aux = mlp(ctx, lp["mlp"], h, cfg.mlp_act), jnp.zeros((), jnp.float32)
    x = x + y
    # residual stream sequence-sharded between layers (Megatron-SP): remat
    # saves shrink by the model-axis size; projections re-gather via hints
    x = hint_pick(x, ("batch", "model", None), ("batch", None, None))
    return x, aux, (kv if collect_kv else None)


def _embed(ctx: Ctx, params, cfg, tokens, img_embeds=None):
    x = embed_lookup(params["embedding"], tokens, ctx.compute_dtype)
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model ** 0.5, ctx.compute_dtype)
    if img_embeds is not None:  # VLM: prepend stub-frontend patch embeddings
        x = jnp.concatenate([img_embeds.astype(ctx.compute_dtype), x], axis=1)
    return hint_pick(x, ("batch", "model", None), ("batch", None, None))


def _head(ctx: Ctx, params, cfg, x):
    x = rms_norm(x, params["norm_f_scale"], cfg.norm_eps)
    if cfg.tie_embeddings:
        w = maybe_dequantize(params["embedding"], ctx.compute_dtype)
        logits = jnp.einsum("bsd,vd->bsv", x.astype(ctx.compute_dtype), w)
    else:
        logits = ctx.dot(x, params["lm_head"], site="head")
    # prefer sequence-sharded logits (local full-vocab softmax in the loss)
    return hint_pick(logits.astype(jnp.float32),
                     ("batch", "model", None), ("batch", None, "model"))


def _pick_groups(L: int) -> int:
    """Divisor of L closest to sqrt(L): minimizes (G + L/G) save stacks."""
    best, best_cost = 1, L + 1
    for g in range(1, L + 1):
        if L % g == 0:
            cost = g + L // g
            if cost < best_cost:
                best, best_cost = g, cost
    return best


def grouped_scan(body, carry, xs, L: int, *, remat: bool, groups: int = 0):
    """Two-level remat scan over a stacked layer axis.

    Memory under remat drops from L x residual to (G + L/G) x residual:
    the outer scan checkpoints per *group* (saves G carries), the inner
    scan checkpoints per layer during the group's backward recompute
    (transient L/G carries) — the standard trick for deep stacks at
    fixed HBM (MaxText "layer grouping").
    """
    if not remat:
        return jax.lax.scan(body, carry, xs)
    G = groups or _pick_groups(L)
    if G <= 1 or L % G != 0:
        return jax.lax.scan(jax.checkpoint(body), carry, xs)
    xs2 = jax.tree.map(lambda a: a.reshape((G, L // G) + a.shape[1:]), xs)

    def outer(c, xs_g):
        c, ys = jax.lax.scan(jax.checkpoint(body), c, xs_g)
        return c, ys

    carry, ys = jax.lax.scan(jax.checkpoint(outer), carry, xs2)
    ys = jax.tree.map(
        lambda a: a.reshape((L,) + a.shape[2:]) if a is not None else None,
        ys, is_leaf=lambda a: a is None)
    return carry, ys


def lm_forward(ctx: Ctx, params, cfg, tokens, positions=None,
               img_embeds=None, remat: bool = False, collect_kv: bool = False):
    """tokens (B, S) -> (logits (B, S_total, V) f32, aux_loss, kv_stack|None)."""
    B = tokens.shape[0]
    x = _embed(ctx, params, cfg, tokens, img_embeds)
    S = x.shape[1]
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    windows = window_array(cfg)

    def body(carry, xs):
        x, aux = carry
        lp, window = xs
        # entry hint pins the layout of the remat-saved per-layer input
        # stack (sequence-sharded -> saves shrink by the model-axis size)
        x = hint_pick(x, ("batch", "model", None), ("batch", None, None))
        x, aux_l, kv = _layer_fwd(ctx, cfg, lp, window, x, positions,
                                  collect_kv)
        return (x, aux + aux_l), kv

    (x, aux), kvs = grouped_scan(body, (x, jnp.zeros((), jnp.float32)),
                                 (params["layers"], windows),
                                 cfg.num_layers, remat=remat)
    logits = _head(ctx, params, cfg, x)
    return logits, aux, kvs


# ---------------------------------------------------------------------------
# KV / state caches
# ---------------------------------------------------------------------------

def lm_init_cache(cfg, batch: int, max_len: int, kv_dtype: str = "bf16"):
    L = cfg.num_layers
    if cfg.family == "ssm":
        d_inner = cfg.ssm.expand * cfg.d_model
        nh = d_inner // cfg.ssm.head_dim
        conv_dim = d_inner + 2 * cfg.ssm.state_dim
        return {
            "conv": jnp.zeros((L, batch, 3, conv_dim), jnp.bfloat16),
            "ssd": jnp.zeros((L, batch, nh, cfg.ssm.head_dim,
                              cfg.ssm.state_dim), jnp.float32),
            "len": jnp.zeros((batch,), jnp.int32),
        }
    Hkv, hd = cfg.num_kv_heads, cfg.head_dim
    cache = {
        "pos": jnp.full((batch, max_len), -1, jnp.int32),
        "len": jnp.zeros((batch,), jnp.int32),
    }
    if kv_dtype == "int8":
        cache.update(
            k_codes=jnp.zeros((L, batch, max_len, Hkv, hd), jnp.int8),
            k_scales=jnp.zeros((L, batch, max_len, Hkv), jnp.float32),
            v_codes=jnp.zeros((L, batch, max_len, Hkv, hd), jnp.int8),
            v_scales=jnp.zeros((L, batch, max_len, Hkv), jnp.float32))
    elif kv_dtype == "fp8":
        cache.update(k=jnp.zeros((L, batch, max_len, Hkv, hd), jnp.float8_e4m3fn),
                     k_scales=jnp.zeros((L, batch, max_len, Hkv), jnp.float32),
                     v=jnp.zeros((L, batch, max_len, Hkv, hd), jnp.float8_e4m3fn),
                     v_scales=jnp.zeros((L, batch, max_len, Hkv), jnp.float32))
    else:
        dt = jnp.float32 if kv_dtype == "f32" else jnp.bfloat16
        cache.update(k=jnp.zeros((L, batch, max_len, Hkv, hd), dt),
                     v=jnp.zeros((L, batch, max_len, Hkv, hd), dt))
    return cache


def lm_init_paged_cache(cfg, slots: int, max_pages: int, num_pages: int,
                        page_size: int, kv_dtype: str = "bf16"):
    """Block-paged serving cache: shared page pool + per-slot block table.

    ``max_pages`` bounds one sequence's chain (= ceil(max_len / ps));
    ``num_pages`` sizes the shared pool (page 0 is the reserved trash
    page). See serving/paged_cache.py for the layout contract.
    """
    # deferred: serving -> models is the package's import direction
    from ..serving.paged_cache import TRASH_PAGE, init_paged_kv
    if cfg.family == "ssm":
        raise ValueError("paged KV caches need an attention family; "
                         "ssm states are O(1) per sequence already")
    cache = init_paged_kv(cfg.num_layers, num_pages, page_size,
                          cfg.num_kv_heads, cfg.head_dim, kv_dtype)
    cache["block_tables"] = jnp.full((slots, max_pages), TRASH_PAGE,
                                     jnp.int32)
    cache["len"] = jnp.zeros((slots,), jnp.int32)
    cache["active"] = jnp.zeros((slots,), jnp.int32)
    return cache


def paged_view(cache):
    """Decode-time view of a paged cache: per-slot write coordinates and
    the dense gather positions.

    Returns (positions (B, S_view) with -1 beyond each length, page_ids
    (B,), offsets (B,)) where S_view = maxp * ps. Idle slots (active=0)
    write to the trash page and keep their length frozen.
    """
    tables, lens = cache["block_tables"], cache["len"]
    active = cache["active"]
    B, maxp = tables.shape
    ps = (cache["k_codes"] if "k_codes" in cache else cache["k"]).shape[2]
    s_view = maxp * ps
    pos = jnp.broadcast_to(jnp.arange(s_view, dtype=jnp.int32), (B, s_view))
    pos = jnp.where(pos < lens[:, None], pos, -1)
    pid = tables[jnp.arange(B), jnp.clip(lens // ps, 0, maxp - 1)]
    pid = jnp.where(active > 0, pid, 0)          # 0 = trash page
    off = jnp.where(active > 0, lens % ps, 0)
    return pos, pid, off


def _token_kv_quantizer(codes_dtype):
    """Per-token KV quantizer matching a page pool's storage dtype."""
    return _quantize_token_kv if codes_dtype == jnp.int8 else _fp8_token_kv


def paged_attn(ctx, ap, x, positions, leaves, view_pos, pid, off,
               lengths_now, tables, *, use_kernel, num_heads, num_kv_heads,
               head_dim, window=0, rope_theta=1e4, norm_eps=1e-6,
               site="attn"):
    """One layer of paged decode self-attention + KV commit.

    The single source of the paged attend/commit contract, shared by the
    LM and enc-dec decode steps. Dispatches between the gather path
    (dense chain view through decode_attn_apply — bit-identical to the
    dense engine) and the Pallas-kernel path. ``leaves`` is (k, v) for
    bf16/f32 pages or (codes, scales, codes, scales) for int8/fp8 pages
    (the codes dtype picks the token quantizer). Returns
    (attn_out_projection, updated_leaves).
    """
    if use_kernel:
        return _paged_attn_kernel_apply(
            ctx, ap, x, positions, leaves, pid, off, lengths_now, tables,
            num_heads=num_heads, num_kv_heads=num_kv_heads,
            head_dim=head_dim, rope_theta=rope_theta, norm_eps=norm_eps,
            site=site)
    if len(leaves) == 4:                       # int8 / fp8 pages
        kc, ksc, vc, vsc = leaves
        k_dense = _dense_kv(_gather_pages(kc, tables),
                            _gather_pages(ksc, tables))
        v_dense = _dense_kv(_gather_pages(vc, tables),
                            _gather_pages(vsc, tables))
    else:
        kc, vc = leaves
        k_dense = _gather_pages(kc, tables)
        v_dense = _gather_pages(vc, tables)
    y, k_new, v_new = decode_attn_apply(
        ctx, ap, x, positions, k_dense, v_dense, view_pos,
        num_heads=num_heads, num_kv_heads=num_kv_heads, head_dim=head_dim,
        window=window, rope_theta=rope_theta, norm_eps=norm_eps, site=site)
    if len(leaves) == 4:
        qfn = _token_kv_quantizer(kc.dtype)
        nkc, nks = qfn(k_new)
        nvc, nvs = qfn(v_new)
        new_leaves = (_scatter_token(kc, nkc[:, 0], pid, off),
                      _scatter_token(ksc, nks[:, 0], pid, off),
                      _scatter_token(vc, nvc[:, 0], pid, off),
                      _scatter_token(vsc, nvs[:, 0], pid, off))
    else:
        new_leaves = (_scatter_token(kc, k_new[:, 0], pid, off),
                      _scatter_token(vc, v_new[:, 0], pid, off))
    return y, new_leaves


def _paged_attn_kernel_apply(ctx, ap, x, positions, leaves, pid, off,
                             lengths_now, tables, *, num_heads, num_kv_heads,
                             head_dim, rope_theta=1e4, norm_eps=1e-6,
                             site="attn"):
    """Paged decode attention through the Pallas kernel (TPU path).

    Write-then-attend: the new token's K/V is committed to its page
    first (quantized on int8/fp8 caches — vLLM semantics, unlike the
    gather path which attends the fresh token at full precision), then
    one kernel call covers the whole chain at ``lengths_now`` = len + 1
    (idle slots pass 0 and attend nothing). ``leaves`` is this layer's
    page pool — (k, v) or (k_codes, k_scales, v_codes, v_scales).
    Returns (attn_out_projection, updated_leaves).
    """
    from ..kernels import ops as kops
    B = x.shape[0]
    H, Hkv, hd = num_heads, num_kv_heads, head_dim
    qkv = f"{site}.qkv"
    q = linear(ctx, x, ap["wq"], ap.get("bias_q"),
               site=qkv).reshape(B, 1, H, hd)
    k_new = linear(ctx, x, ap["wk"], ap.get("bias_k"),
                   site=qkv).reshape(B, 1, Hkv, hd)
    v_new = linear(ctx, x, ap["wv"], ap.get("bias_v"),
                   site=qkv).reshape(B, 1, Hkv, hd)
    if "q_norm_scale" in ap:
        q = rms_norm(q, ap["q_norm_scale"], norm_eps)
        k_new = rms_norm(k_new, ap["k_norm_scale"], norm_eps)
    q = rope(q, positions, rope_theta)
    k_new = rope(k_new, positions, rope_theta)

    if len(leaves) == 4:                       # int8 / fp8 pages
        kc, ksc, vc, vsc = leaves
        qfn = _token_kv_quantizer(kc.dtype)
        nkc, nks = qfn(k_new)
        nvc, nvs = qfn(v_new)
        kc = _scatter_token(kc, nkc[:, 0], pid, off)
        ksc = _scatter_token(ksc, nks[:, 0], pid, off)
        vc = _scatter_token(vc, nvc[:, 0], pid, off)
        vsc = _scatter_token(vsc, nvs[:, 0], pid, off)
        out = kops.paged_decode_attention(
            q[:, 0], kc, vc, tables, lengths_now, k_scales=ksc, v_scales=vsc,
            out_dtype=jnp.float32)
        new_leaves = (kc, ksc, vc, vsc)
    else:                                      # bf16/f32 pages
        kp, vp = leaves
        kp = _scatter_token(kp, k_new[:, 0], pid, off)
        vp = _scatter_token(vp, v_new[:, 0], pid, off)
        out = kops.paged_decode_attention(
            q[:, 0], kp, vp, tables, lengths_now, out_dtype=jnp.float32)
        new_leaves = (kp, vp)
    y = ctx.dot(out.astype(x.dtype).reshape(B, 1, H * hd), ap["wo"],
                site=f"{site}.out")
    return y, new_leaves


def _quantize_token_kv(t):
    """(B, S, Hkv, hd) -> int8 codes + per-(token, head) scales."""
    absmax = jnp.max(jnp.abs(t.astype(jnp.float32)), axis=-1)
    scales = jnp.where(absmax == 0, 1.0, absmax / 127.0)
    codes = jnp.clip(jnp.round(t / scales[..., None]), -127, 127).astype(jnp.int8)
    return codes, scales.astype(jnp.float32)


def _fp8_token_kv(t):
    absmax = jnp.max(jnp.abs(t.astype(jnp.float32)), axis=-1)
    scales = jnp.where(absmax == 0, 1.0, absmax / 448.0)
    codes = (t / scales[..., None]).astype(jnp.float8_e4m3fn)
    return codes, scales.astype(jnp.float32)


def _dense_kv(cache_layer_k, scales):
    if scales is None:
        return cache_layer_k
    return (cache_layer_k.astype(jnp.float32) * scales[..., None]
            ).astype(jnp.bfloat16)


def _scatter_tokens(cache, new, lens):
    """Insert (B, S_new, ...) rows into (B, Smax, ...) at per-seq offsets."""
    def upd(c, t, i):
        return jax.lax.dynamic_update_slice(
            c, t.astype(c.dtype), (i,) + (0,) * (c.ndim - 1))
    return jax.vmap(upd)(cache, new, lens)


def _commit_decode_position(new_cache, cache, positions):
    """Dense-cache epilogue of one decode step (shared by the LM and
    enc-dec paths): record the written position and advance per-slot
    lengths, honoring the optional ``active`` mask — an inactive slot
    (retired mid-horizon in the engine's fused scan) writes ``pos=-1``
    so its K/V lands on a masked position, and its ``len`` freezes; a
    dead slot never grows a valid cache tail."""
    active = cache.get("active")
    if active is None:
        new_cache["pos"] = _scatter_tokens(cache["pos"], positions,
                                           cache["len"])
        new_cache["len"] = cache["len"] + 1
    else:
        pos_val = jnp.where(active[:, None] > 0, positions, -1)
        new_cache["pos"] = _scatter_tokens(cache["pos"], pos_val,
                                           cache["len"])
        new_cache["len"] = cache["len"] + (active > 0)
    return new_cache


# ---------------------------------------------------------------------------
# prefill
# ---------------------------------------------------------------------------

def lm_prefill(ctx: Ctx, params, cfg, tokens, cache, lengths=None,
               img_embeds=None, positions=None):
    """Run the full prompt, fill the cache. tokens (B, S_prompt)."""
    B, S = tokens.shape
    if cfg.family == "ssm":
        # recurrent prefill: chunked scan already yields final state per layer
        x = _embed(ctx, params, cfg, tokens, img_embeds)
        if positions is None:
            positions = jnp.broadcast_to(jnp.arange(x.shape[1], dtype=jnp.int32),
                                         (B, x.shape[1]))

        def body(x, xs):
            lp, conv0, ssd0 = xs
            h = rms_norm(x, lp["norm1_scale"], cfg.norm_eps)
            y, (conv, ssd) = ssm_mod.ssm_apply(
                ctx, lp["ssm"], h, d_model=cfg.d_model, ssm_cfg=cfg.ssm,
                conv_state=conv0, ssm_state=ssd0, return_state=True)
            return x + y, (conv, ssd)

        x, (convs, ssds) = jax.lax.scan(
            body, x, (params["layers"], cache["conv"], cache["ssd"]))
        logits = _head(ctx, params, cfg, x)
        lens = lengths if lengths is not None else jnp.full((B,), S, jnp.int32)
        new_cache = dict(cache, conv=convs, ssd=ssds, len=lens)
        return new_cache, logits

    logits, _aux, kvs = lm_forward(ctx, params, cfg, tokens,
                                   positions=positions,
                                   img_embeds=img_embeds, collect_kv=True)
    ks, vs = kvs                                   # (L, B, S_tot, Hkv, hd)
    S_tot = ks.shape[2]
    lens = lengths if lengths is not None else jnp.full((B,), S_tot, jnp.int32)
    new_cache = dict(cache)
    if "k_codes" in cache:   # prompt fills slots [0, S_tot)
        kc, ksc = _quantize_token_kv(ks)
        vc, vsc = _quantize_token_kv(vs)
        new_cache["k_codes"] = cache["k_codes"].at[:, :, :S_tot].set(kc)
        new_cache["k_scales"] = cache["k_scales"].at[:, :, :S_tot].set(ksc)
        new_cache["v_codes"] = cache["v_codes"].at[:, :, :S_tot].set(vc)
        new_cache["v_scales"] = cache["v_scales"].at[:, :, :S_tot].set(vsc)
    elif "k_scales" in cache:  # fp8
        kc, ksc = _fp8_token_kv(ks)
        vc, vsc = _fp8_token_kv(vs)
        new_cache["k"] = cache["k"].at[:, :, :S_tot].set(kc)
        new_cache["k_scales"] = cache["k_scales"].at[:, :, :S_tot].set(ksc)
        new_cache["v"] = cache["v"].at[:, :, :S_tot].set(vc)
        new_cache["v_scales"] = cache["v_scales"].at[:, :, :S_tot].set(vsc)
    else:
        new_cache["k"] = cache["k"].at[:, :, :S_tot].set(ks.astype(cache["k"].dtype))
        new_cache["v"] = cache["v"].at[:, :, :S_tot].set(vs.astype(cache["v"].dtype))
    pos = jnp.broadcast_to(jnp.arange(S_tot, dtype=jnp.int32), (B, S_tot))
    pos = jnp.where(pos < lens[:, None], pos, -1)
    new_cache["pos"] = cache["pos"].at[:, :S_tot].set(pos)
    new_cache["len"] = lens
    return new_cache, logits


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------

def lm_paged_decode_step(ctx: Ctx, params, cfg, tokens, cache):
    """One decode step against a block-paged cache. tokens (B, 1).

    Per layer: the slot's page chain is gathered into a dense
    (B, maxp*ps, ...) view (the CPU-path twin of the Pallas kernel's
    block-table DMA walk in kernels/paged_attn.py), attention runs with
    chain-order positions, and the new token's K/V scatters into page
    ``tables[b, len // ps]`` at offset ``len % ps``. Idle slots write
    to the reserved trash page and their length stays frozen.
    """
    tables, active = cache["block_tables"], cache["active"]
    positions = cache["len"][:, None]                        # (B, 1)
    view_pos, pid, off = paged_view(cache)
    x = _embed(ctx, params, cfg, tokens)
    windows = window_array(cfg)
    # the kernel path has no local-window masking: gather handles
    # windowed archs (gemma3 pattern) regardless of the requested impl
    use_kernel = ctx.paged_attn_impl == "kernel" and not cfg.window_pattern
    lengths_now = jnp.where(active > 0, cache["len"] + 1, 0)

    quant = "k_codes" in cache
    fp8 = "k_scales" in cache and not quant
    if quant:
        xs = (params["layers"], windows, cache["k_codes"], cache["k_scales"],
              cache["v_codes"], cache["v_scales"])
    elif fp8:
        xs = (params["layers"], windows, cache["k"], cache["k_scales"],
              cache["v"], cache["v_scales"])
    else:
        xs = (params["layers"], windows, cache["k"], cache["v"])

    def body(x, layer_xs):
        lp, window, *leaves = layer_xs
        h = rms_norm(x, lp["norm1_scale"], cfg.norm_eps)
        y, new_leaves = paged_attn(
            ctx, lp["attn"], h, positions, leaves, view_pos, pid, off,
            lengths_now, tables, use_kernel=use_kernel,
            num_heads=cfg.num_heads, num_kv_heads=cfg.num_kv_heads,
            head_dim=cfg.head_dim, window=window,
            rope_theta=cfg.rope_theta, norm_eps=cfg.norm_eps)
        x = x + y
        h = rms_norm(x, lp["norm2_scale"], cfg.norm_eps)
        if cfg.moe is not None:
            y, _ = moe_mod.moe_apply(
                ctx, lp["moe"], h, top_k=cfg.moe.top_k,
                capacity_factor=cfg.moe.capacity_factor, act=cfg.mlp_act,
                parallel_mode=cfg.moe.parallel_mode, dropless=True,
                dispatch_groups=cfg.moe.dispatch_groups)
        else:
            y = mlp(ctx, lp["mlp"], h, cfg.mlp_act)
        return x + y, new_leaves

    x, new_kv = jax.lax.scan(body, x, xs)
    logits = _head(ctx, params, cfg, x)
    new_cache = dict(cache)
    if quant:
        (new_cache["k_codes"], new_cache["k_scales"],
         new_cache["v_codes"], new_cache["v_scales"]) = new_kv
    elif fp8:
        (new_cache["k"], new_cache["k_scales"],
         new_cache["v"], new_cache["v_scales"]) = new_kv
    else:
        new_cache["k"], new_cache["v"] = new_kv
    new_cache["len"] = jnp.where(active > 0, cache["len"] + 1, cache["len"])
    return new_cache, logits


def lm_decode_step(ctx: Ctx, params, cfg, tokens, cache):
    """One decode step. tokens (B, 1) -> (new_cache, logits (B, 1, V)).

    Dispatches on the cache layout: a cache carrying ``block_tables``
    is block-paged (see lm_init_paged_cache), otherwise dense. A dense
    cache may carry an optional ``active`` (B,) i32 mask (the engine's
    horizon-fused scan injects it): inactive slots keep decoding but
    their ``len`` freezes and their writes land on masked positions
    (``pos`` stays -1), so a slot retired mid-horizon never grows a
    phantom valid cache tail."""
    if "block_tables" in cache:
        return lm_paged_decode_step(ctx, params, cfg, tokens, cache)
    B = tokens.shape[0]
    positions = cache["len"][:, None]                       # (B,1)
    x = _embed(ctx, params, cfg, tokens)
    windows = window_array(cfg)

    if cfg.family == "ssm":
        def body(x, xs):
            lp, conv0, ssd0 = xs
            h = rms_norm(x, lp["norm1_scale"], cfg.norm_eps)
            y, (conv, ssd) = ssm_mod.ssm_decode_step(
                ctx, lp["ssm"], h, (conv0, ssd0),
                d_model=cfg.d_model, ssm_cfg=cfg.ssm)
            return x + y, (conv, ssd)

        x, (convs, ssds) = jax.lax.scan(
            body, x, (params["layers"], cache["conv"], cache["ssd"]))
        logits = _head(ctx, params, cfg, x)
        new_cache = dict(cache, conv=convs, ssd=ssds, len=cache["len"] + 1)
        return new_cache, logits

    quant = "k_codes" in cache
    fp8 = "k_scales" in cache and not quant
    if quant:
        xs = (params["layers"], windows, cache["k_codes"], cache["k_scales"],
              cache["v_codes"], cache["v_scales"])
    elif fp8:
        xs = (params["layers"], windows, cache["k"], cache["k_scales"],
              cache["v"], cache["v_scales"])
    else:
        xs = (params["layers"], windows, cache["k"], cache["v"])

    def body(x, layer_xs):
        if quant or fp8:
            lp, window, kc, ksc, vc, vsc = layer_xs
            k_dense = _dense_kv(kc, ksc)
            v_dense = _dense_kv(vc, vsc)
        else:
            lp, window, k_dense, v_dense = layer_xs
            ksc = vsc = None
            kc, vc = k_dense, v_dense
        h = rms_norm(x, lp["norm1_scale"], cfg.norm_eps)
        y, k_new, v_new = decode_attn_apply(
            ctx, lp["attn"], h, positions, k_dense, v_dense, cache["pos"],
            num_heads=cfg.num_heads, num_kv_heads=cfg.num_kv_heads,
            head_dim=cfg.head_dim, window=window, rope_theta=cfg.rope_theta,
            norm_eps=cfg.norm_eps)
        x = x + y
        h = rms_norm(x, lp["norm2_scale"], cfg.norm_eps)
        if cfg.moe is not None:
            y, _ = moe_mod.moe_apply(
                ctx, lp["moe"], h, top_k=cfg.moe.top_k,
                capacity_factor=cfg.moe.capacity_factor, act=cfg.mlp_act,
                parallel_mode=cfg.moe.parallel_mode, dropless=True,
                dispatch_groups=cfg.moe.dispatch_groups)
        else:
            y = mlp(ctx, lp["mlp"], h, cfg.mlp_act)
        x = x + y
        # commit the new token into this layer's cache slice
        if quant:
            nkc, nks = _quantize_token_kv(k_new)
            nvc, nvs = _quantize_token_kv(v_new)
            return x, (_scatter_tokens(kc, nkc, cache["len"]),
                       _scatter_tokens(ksc, nks, cache["len"]),
                       _scatter_tokens(vc, nvc, cache["len"]),
                       _scatter_tokens(vsc, nvs, cache["len"]))
        if fp8:
            nkc, nks = _fp8_token_kv(k_new)
            nvc, nvs = _fp8_token_kv(v_new)
            return x, (_scatter_tokens(kc, nkc, cache["len"]),
                       _scatter_tokens(ksc, nks, cache["len"]),
                       _scatter_tokens(vc, nvc, cache["len"]),
                       _scatter_tokens(vsc, nvs, cache["len"]))
        return x, (_scatter_tokens(kc, k_new, cache["len"]),
                   _scatter_tokens(vc, v_new, cache["len"]))

    x, new_kv = jax.lax.scan(body, x, xs)
    logits = _head(ctx, params, cfg, x)
    new_cache = dict(cache)
    if quant:
        (new_cache["k_codes"], new_cache["k_scales"],
         new_cache["v_codes"], new_cache["v_scales"]) = new_kv
    elif fp8:
        (new_cache["k"], new_cache["k_scales"],
         new_cache["v"], new_cache["v_scales"]) = new_kv
    else:
        new_cache["k"], new_cache["v"] = new_kv
    return _commit_decode_position(new_cache, cache, positions), logits
