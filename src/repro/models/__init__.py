"""Model zoo: dense / MoE / SSM / hybrid / VLM / enc-dec families."""

from .api import ModelAPI, build_model, decode_block
from .layers import Ctx

__all__ = ["ModelAPI", "build_model", "decode_block", "Ctx"]
