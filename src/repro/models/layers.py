"""Shared neural layers (functional, quantization-aware).

Every matmul routes through core.qlinear.qmatmul so any layer deploys at
any PrecisionPolicy format. Activation functions come from the FASST NAF
datapath (kernels.fasst._naf) — a single source of truth shared by the
Pallas kernel and the differentiable model path.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp

from ..core.qlinear import act_quant_eligible, qmatmul, quantize_activations
from ..kernels.fasst import _naf
from ..parallel import hint, hint_pick

__all__ = ["Ctx", "rms_norm", "layer_norm", "rope", "linear", "mlp",
           "mlp_init", "attention", "attention_init", "attn_apply",
           "decode_attn_apply"]


@dataclasses.dataclass(frozen=True)
class Ctx:
    """Per-call execution context threaded through model code."""
    compute_dtype: Any = jnp.bfloat16
    act_fmt: str = "bf16"          # matmul act format (bf16 | int8 | fp8)
    # attention-matmul (QK / PV einsum) activation format — QuantSpec's
    # x<fmt> slot. These are act x act products with no weight tree, so
    # they can't route through qmatmul; attn_dot() fake-quants both
    # operands instead. bf16 = untouched wide-accumulate einsum.
    attn_act_fmt: str = "bf16"
    attn_impl: str = "full"        # full | chunked
    attn_chunk: int = 1024
    use_fasst_kernel: bool = False # route NAFs through the Pallas kernel
    matmul_impl: str = "xla"       # xla | pallas (quantized weights)
    # paged decode attention: "gather" materializes each chain as a
    # dense view (CPU path, bit-identical to the dense engine);
    # "kernel" routes through kernels/paged_attn.py (block-table DMA
    # walk, write-then-attend — the TPU serving path). The Pallas
    # kernel computes QK/PV in bf16 regardless of attn_act_fmt — the
    # x<fmt> fake-quant route is the "gather"/dense path only.
    paged_attn_impl: str = "gather"
    # calibrated static activation scales for the quantized act paths:
    # a tuple of (site, scale) pairs (hashable, so Ctx stays usable as
    # a static arg) from core.calibration.calibrate_act_scales, set by
    # deploy(calib_batches=). None — or a site absent from the registry
    # — falls back to dynamic per-token quantization.
    act_scales: Any = None
    # calibration sink: when set, dot() ships the per-site |x| max of
    # every activation entering a quantized-weight matmul to the host
    # via jax.debug.callback (scan-safe — model forwards scan over
    # layers). A core.calibration.SiteCollector; excluded from eq/hash
    # so Ctx stays usable as a static arg.
    act_collector: Any = dataclasses.field(
        default=None, compare=False, repr=False)

    @functools.cached_property
    def _site_scales(self):
        return dict(self.act_scales) if self.act_scales is not None else {}

    def scale_for(self, site):
        """Calibrated static activation scale for a matmul site (None =
        dynamic per-token quantization)."""
        if site is None:
            return None
        return self._site_scales.get(site)

    def dot(self, x, w, site=None):
        """x @ w with the context's activation route. ``site`` is the
        matmul's calibration label (e.g. "dec.ffn.in"): the collector
        files absmax observations under it, and the static-scale
        registry is keyed by it — unlabelled sites stay dynamic."""
        if self.act_collector is not None and act_quant_eligible(w):
            jax.debug.callback(self.act_collector.bind(site),
                               jnp.max(jnp.abs(x.astype(jnp.float32))))
        return qmatmul(x, w, act=self.act_fmt, compute_dtype=self.compute_dtype,
                       impl=self.matmul_impl, act_scale=self.scale_for(site))

    def _attn_fq(self, x, site):
        """Fake-quantize one attention-matmul operand at the context's
        attention format: observe the pre-quant f32 absmax when
        calibrating, quantize at the calibrated static scale (or
        dynamic per-token absmax), dequantize back to f32."""
        if self.act_collector is not None:
            jax.debug.callback(self.act_collector.bind(site),
                               jnp.max(jnp.abs(x)))
        codes, scale = quantize_activations(x, fmt=self.attn_act_fmt,
                                            scale=self.scale_for(site))
        return codes.astype(jnp.float32) * scale

    def attn_dot(self, subscripts, a, b, site=None):
        """QK / PV attention einsum with the context's attention route.

        bf16 is bit-identical to the pre-x<fmt> path (one einsum with
        f32 accumulation). Quantized formats fake-quant BOTH operands —
        calibration sites "{site}.a" / "{site}.b" — and contract in f32
        (the sparseml QuantizableMatMul shape: two quantized inputs,
        wide accumulate, no weight tree involved)."""
        if self.attn_act_fmt == "bf16":
            return jnp.einsum(subscripts, a, b,
                              preferred_element_type=jnp.float32)
        af = self._attn_fq(a.astype(jnp.float32), f"{site}.a")
        bf = self._attn_fq(b.astype(jnp.float32), f"{site}.b")
        return jnp.einsum(subscripts, af, bf)

    def naf(self, x, mode):
        if self.use_fasst_kernel:
            from ..kernels import ops as kops
            return kops.fasst(x, mode)
        return _naf(x.astype(jnp.float32), mode).astype(x.dtype)


# -- norms -------------------------------------------------------------------

def rms_norm(x, scale, eps=1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)
            ).astype(x.dtype)


def layer_norm(x, scale, bias, eps=1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (out * scale.astype(jnp.float32) + bias.astype(jnp.float32)
            ).astype(x.dtype)


# -- rotary position embedding ------------------------------------------------

def rope(x, positions, theta: float = 1e4):
    """x (..., S, H, hd), positions (..., S) -> rotated x (pairs convention)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = jnp.exp(-jnp.arange(0, half, dtype=jnp.float32) *
                    (jnp.log(theta) / half))
    ang = positions.astype(jnp.float32)[..., None] * freqs  # (..., S, half)
    cos = jnp.cos(ang)[..., None, :]                        # (..., S, 1, half)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    return jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1).astype(x.dtype)


def linear(ctx: Ctx, x, w, b=None, site=None):
    y = ctx.dot(x, w, site=site)
    if b is not None:
        y = y + b.astype(y.dtype)
    return y


# -- MLP ----------------------------------------------------------------------

GLU_ACTS = {"silu_glu": "silu", "gelu_glu": "gelu", "relu_glu": "relu"}
PLAIN_ACTS = {"squared_relu": "squared_relu", "gelu": "gelu", "relu": "relu",
              "silu": "silu"}


def mlp_init(key, d_model: int, d_ff: int, act: str, dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(key, 3)
    s_in = d_model ** -0.5
    s_out = d_ff ** -0.5
    if act in GLU_ACTS:
        return {"w_gate": jax.random.normal(k1, (d_model, d_ff), dtype) * s_in,
                "w_up": jax.random.normal(k2, (d_model, d_ff), dtype) * s_in,
                "w_down": jax.random.normal(k3, (d_ff, d_model), dtype) * s_out}
    return {"w_in": jax.random.normal(k1, (d_model, d_ff), dtype) * s_in,
            "w_out": jax.random.normal(k2, (d_ff, d_model), dtype) * s_out}


def mlp(ctx: Ctx, params, x, act: str, site="ffn"):
    if act in GLU_ACTS:
        h = ctx.naf(ctx.dot(x, params["w_gate"], site=f"{site}.in"),
                    GLU_ACTS[act])
        h = h * ctx.dot(x, params["w_up"], site=f"{site}.in")
        h = hint(h, None, None, "model")
        return ctx.dot(h, params["w_down"], site=f"{site}.out")
    h = ctx.naf(ctx.dot(x, params["w_in"], site=f"{site}.in"),
                PLAIN_ACTS[act])
    h = hint(h, None, None, "model")
    return ctx.dot(h, params["w_out"], site=f"{site}.out")


# -- attention ----------------------------------------------------------------

def attention_init(key, d_model: int, num_heads: int, num_kv_heads: int,
                   head_dim: int, qkv_bias: bool = False, qk_norm: bool = False,
                   dtype=jnp.float32):
    ks = jax.random.split(key, 4)
    s = d_model ** -0.5
    p = {"wq": jax.random.normal(ks[0], (d_model, num_heads * head_dim), dtype) * s,
         "wk": jax.random.normal(ks[1], (d_model, num_kv_heads * head_dim), dtype) * s,
         "wv": jax.random.normal(ks[2], (d_model, num_kv_heads * head_dim), dtype) * s,
         "wo": jax.random.normal(ks[3], (num_heads * head_dim, d_model), dtype)
               * (num_heads * head_dim) ** -0.5}
    if qkv_bias:
        p["bias_q"] = jnp.zeros((num_heads * head_dim,), dtype)
        p["bias_k"] = jnp.zeros((num_kv_heads * head_dim,), dtype)
        p["bias_v"] = jnp.zeros((num_kv_heads * head_dim,), dtype)
    if qk_norm:
        p["q_norm_scale"] = jnp.ones((head_dim,), dtype)
        p["k_norm_scale"] = jnp.ones((head_dim,), dtype)
    return p


def _mask(pos_q, pos_k, window, causal: bool):
    """Attention mask (..., Sq, Sk). pos_k < 0 marks invalid cache slots.

    ``window`` may be a traced scalar: 0 => full span, w>0 => local window
    (enables gemma3's 5:1 local:global pattern inside one scanned stack).
    """
    pq = pos_q[..., :, None]
    pk = pos_k[..., None, :]
    m = pk >= 0
    if causal:
        m &= pk <= pq
    w = jnp.asarray(window)
    m &= jnp.where(w > 0, (pq - pk) < w, True) & jnp.where(w > 0, (pk - pq) < w, True)
    return m


def _sdpa(ctx: Ctx, q, k, v, mask, sm_scale, site="attn"):
    """q (B,Sq,Hkv,G,hd), k/v (B,Sk,Hkv,hd), mask (B,Sq,Sk) -> (B,Sq,Hkv,G,hd).

    bf16 MXU einsums with f32 accumulation (paper's quire-style wide
    accumulate, cast once); both matmuls route through ctx.attn_dot so
    the x<fmt> spec slot reaches QK ("{site}.qk") and PV ("{site}.pv").
    Scores are explicitly sharding-hinted: KV-head sharding when the
    head count divides the model axis (Megatron attention), otherwise
    batch-only (heads replicated on the model axis — revisit per-arch
    in §Perf).
    """
    scores = ctx.attn_dot("bqhgd,bkhd->bhgqk", q, k.astype(q.dtype),
                          site=f"{site}.qk") * sm_scale
    # layout preference: (1) KV-heads on model (zero-comm Megatron attention,
    # kv=16 archs); (2) *query-sequence* on model — softmax over Sk stays
    # local, K/V are gathered once per layer; removes the 16x head
    # replication for GQA kv=8 / MQA kv=1 archs (SS Perf iteration 2);
    # (3) batch-only fallback.
    score_specs = (("batch", "model", None, None, None),
                   ("batch", None, None, "model", None),
                   ("batch",))
    scores = hint_pick(scores, *score_specs)
    scores = jnp.where(mask[:, None, None, :, :], scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    p = hint_pick(p, *score_specs)
    out = ctx.attn_dot("bhgqk,bkhd->bqhgd", p, v, site=f"{site}.pv")
    out = hint_pick(out, ("batch", None, "model", None, None),
                    ("batch", "model", None, None, None), ("batch",))
    return out.astype(v.dtype)


def attn_apply(ctx: Ctx, params, x, positions, *, num_heads, num_kv_heads,
               head_dim, causal=True, window=0, rope_theta=1e4,
               kv_override=None, kv_positions=None, use_rope=True,
               norm_eps=1e-6, site="attn"):
    """Self- (or cross-, via kv_override) attention block body."""
    B, S, _ = x.shape
    H, Hkv = num_heads, num_kv_heads
    G = H // Hkv

    q = linear(ctx, x, params["wq"], params.get("bias_q"), site=f"{site}.qkv")
    q = q.reshape(B, S, H, head_dim)
    if kv_override is None:
        xk = linear(ctx, x, params["wk"], params.get("bias_k"),
                    site=f"{site}.qkv")
        xv = linear(ctx, x, params["wv"], params.get("bias_v"),
                    site=f"{site}.qkv")
        k = xk.reshape(B, S, Hkv, head_dim)
        v = xv.reshape(B, S, Hkv, head_dim)
        pos_k = positions
    else:
        k, v, pos_k = kv_override          # precomputed (cross-attn / cache)

    if "q_norm_scale" in params:
        q = rms_norm(q, params["q_norm_scale"], norm_eps)
        if kv_override is None:
            k = rms_norm(k, params["k_norm_scale"], norm_eps)
    if use_rope:
        q = rope(q, positions, rope_theta)
        if kv_override is None:
            k = rope(k, pos_k, rope_theta)

    q = hint(q, "batch", None, "model", None)
    k = hint(k, "batch", None, None, None)
    v = hint(v, "batch", None, None, None)

    qg = q.reshape(B, S, Hkv, G, head_dim)
    sm_scale = head_dim ** -0.5
    mask = _mask(positions, pos_k if kv_positions is None else kv_positions,
                 window, causal)
    if mask.ndim == 2:
        mask = mask[None]
    mask = jnp.broadcast_to(mask, (B,) + mask.shape[-2:])

    if ctx.attn_impl == "chunked" and S > ctx.attn_chunk and S % ctx.attn_chunk == 0:
        nc = S // ctx.attn_chunk
        qc = qg.reshape(B, nc, ctx.attn_chunk, Hkv, G, head_dim)
        mc = mask.reshape(B, nc, ctx.attn_chunk, mask.shape[-1])

        def body(_, qm):
            qi, mi = qm
            return None, _sdpa(ctx, qi, k, v, mi, sm_scale, site=site)

        _, oc = jax.lax.scan(body, None,
                             (jnp.moveaxis(qc, 1, 0), jnp.moveaxis(mc, 1, 0)))
        out = jnp.moveaxis(oc, 0, 1).reshape(B, S, H, head_dim)
    else:
        out = _sdpa(ctx, qg, k, v, mask, sm_scale,
                    site=site).reshape(B, S, H, head_dim)

    out = hint(out, "batch", None, "model", None)
    y = ctx.dot(out.reshape(B, S, H * head_dim), params["wo"],
                site=f"{site}.out")
    return y, (k, v)


def decode_attn_apply(ctx: Ctx, params, x, positions, cache_k, cache_v,
                      cache_positions, *, num_heads, num_kv_heads, head_dim,
                      window=0, rope_theta=1e4, norm_eps=1e-6, site="attn"):
    """One-token decode against a (possibly quantized) KV cache.

    x (B, 1, d); cache_k/v (B, Smax, Hkv, hd) dense view (dequantized by
    the caller if stored int8); cache_positions (B, Smax) with -1 = empty.
    Returns (y, new_k_token, new_v_token).
    """
    B, S, _ = x.shape
    assert S == 1
    H, Hkv = num_heads, num_kv_heads

    qkv = f"{site}.qkv"
    q = linear(ctx, x, params["wq"], params.get("bias_q"),
               site=qkv).reshape(B, 1, H, head_dim)
    k_new = linear(ctx, x, params["wk"], params.get("bias_k"),
                   site=qkv).reshape(B, 1, Hkv, head_dim)
    v_new = linear(ctx, x, params["wv"], params.get("bias_v"),
                   site=qkv).reshape(B, 1, Hkv, head_dim)
    if "q_norm_scale" in params:
        q = rms_norm(q, params["q_norm_scale"], norm_eps)
        k_new = rms_norm(k_new, params["k_norm_scale"], norm_eps)
    q = rope(q, positions, rope_theta)
    k_new = rope(k_new, positions, rope_theta)

    G = H // Hkv
    qg = q.reshape(B, 1, Hkv, G, head_dim)
    # Flash-decoding split softmax: scores against the (sharded) cache and
    # the current token are merged through a numerically-stable two-part
    # combine — NO concat, so the cache keeps its (divisible) sequence dim
    # and sequence-sharded KV decomposes into per-shard partials + a small
    # reduce, instead of an all-gather of the whole cache. The caller
    # commits (and possibly quantizes) k_new/v_new into the cache after.
    sm_scale = head_dim ** -0.5
    cd = qg.dtype
    # both QK products share the "{site}.qk" calibration site (same q
    # operand, same key role — the cache and the current token must see
    # one scale) and the cache-side PV product carries "{site}.pv"; the
    # e_new * v_new single-token term is an elementwise f32 product, not
    # a matmul, so it stays full-precision
    s_cache = ctx.attn_dot("bqhgd,bkhd->bhgqk", qg, cache_k.astype(cd),
                           site=f"{site}.qk") * sm_scale
    s_cache = hint_pick(s_cache, ("batch", "model", None, None, None),
                        ("batch", None, None, None, "model"), ("batch",))
    mask = _mask(positions, cache_positions, window, causal=True)  # (B,1,S)
    s_cache = jnp.where(mask[:, None, None, :, :], s_cache, -1e30)
    s_new = ctx.attn_dot("bqhgd,bqhd->bhgq", qg, k_new.astype(cd),
                         site=f"{site}.qk")[..., None] * sm_scale

    m = jnp.maximum(jnp.max(s_cache, axis=-1, keepdims=True), s_new)
    e_cache = jnp.exp(s_cache - m)                       # (B,Hkv,G,1,S)
    e_new = jnp.exp(s_new - m)                           # (B,Hkv,G,1,1)
    denom = jnp.sum(e_cache, axis=-1, keepdims=True) + e_new
    out = ctx.attn_dot("bhgqk,bkhd->bqhgd", e_cache.astype(cd),
                       cache_v.astype(cd), site=f"{site}.pv")
    out = out + e_new.transpose(0, 3, 1, 2, 4) * v_new[:, :, :, None, :].astype(jnp.float32)
    out = out / denom.transpose(0, 3, 1, 2, 4)
    out = hint_pick(out, ("batch", None, "model", None, None), ("batch",))
    y = ctx.dot(out.astype(cd).reshape(B, 1, H * head_dim), params["wo"],
                site=f"{site}.out")
    return y, k_new, v_new
