"""Mixture-of-Experts FFN (paper §II-A: NLLB's MoE resource-scaling layer).

Top-k gating with a load-balancing auxiliary loss ("load-balancing loss
penalizes skewed expert usage to avoid collapse on fixed experts") and a
sort-based capacity dispatch that is entirely static-shaped (TPU/XLA
friendly — no ragged tensors, no giant one-hot dispatch einsum):

  1. every token emits top_k (expert, weight) assignments;
  2. assignments are sorted by expert id; position-within-expert comes
     from the sorted offset minus the expert's start (cumsum of counts);
  3. tokens beyond an expert's capacity C = ceil(T*k/E * cf) are dropped
     (routed to a trash row), matching GShard/Switch semantics;
  4. expert FFNs run as one batched einsum over the (E, C, d) buffer;
  5. results scatter-add back to token order with gate weights.

Expert placement (DESIGN.md): "expert" mode shards E over the mesh's
model axis (expert parallelism — XLA inserts the token all-to-all);
"tensor" mode replicates E and shards d_ff (no all-to-all, pays an
all-reduce) — the trade is a §Perf hillclimb axis.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..parallel import hint
from .layers import Ctx, GLU_ACTS

__all__ = ["moe_init", "moe_apply"]


def moe_init(key, d_model: int, d_ff: int, num_experts: int, act: str,
             dtype=jnp.float32):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    s_in, s_out = d_model ** -0.5, d_ff ** -0.5
    E = num_experts
    p = {"router": jax.random.normal(k1, (d_model, E), dtype) * s_in}
    if act in GLU_ACTS:
        p["experts"] = {
            "w_gate": jax.random.normal(k2, (E, d_model, d_ff), dtype) * s_in,
            "w_up": jax.random.normal(k3, (E, d_model, d_ff), dtype) * s_in,
            "w_down": jax.random.normal(k4, (E, d_ff, d_model), dtype) * s_out,
        }
    else:
        p["experts"] = {
            "w_in": jax.random.normal(k2, (E, d_model, d_ff), dtype) * s_in,
            "w_out": jax.random.normal(k3, (E, d_ff, d_model), dtype) * s_out,
        }
    return p


def _expert_ffn(ctx: Ctx, experts, buf, act: str, parallel_mode: str):
    """buf (G, E, C, d) -> (G, E, C, d) via per-expert FFN (batched einsum).

    The hint on buf is the explicit (G@dp, E@model) re-shard boundary —
    the real all-to-all of the MoE layer ("expert" placement). "tensor"
    placement keeps E local and shards d_ff instead (no all-to-all, pays
    an all-reduce of the outputs).
    """
    from ..core.qtensor import maybe_dequantize
    cd = ctx.compute_dtype
    if parallel_mode == "expert":
        espec = ("batch", "model", None, None)
        ffn_axis = None
    else:
        espec = ("batch", None, None, None)
        ffn_axis = "model"
    buf = hint(buf, *espec)
    if "w_gate" in experts:
        wg = maybe_dequantize(experts["w_gate"], cd)
        wu = maybe_dequantize(experts["w_up"], cd)
        wd = maybe_dequantize(experts["w_down"], cd)
        h = ctx.naf(jnp.einsum("gecd,edf->gecf", buf.astype(cd), wg),
                    GLU_ACTS[act])
        h = h * jnp.einsum("gecd,edf->gecf", buf.astype(cd), wu)
        h = hint(h, espec[0], espec[1], None, ffn_axis)
        out = jnp.einsum("gecf,efd->gecd", h.astype(cd), wd)
    else:
        wi = maybe_dequantize(experts["w_in"], cd)
        wo = maybe_dequantize(experts["w_out"], cd)
        h = ctx.naf(jnp.einsum("gecd,edf->gecf", buf.astype(cd), wi), act)
        h = hint(h, espec[0], espec[1], None, ffn_axis)
        out = jnp.einsum("gecf,efd->gecd", h.astype(cd), wo)
    return hint(out, *espec)


def _pick_groups(B: int, target: int = 32) -> int:
    """Largest divisor of B not exceeding ``target`` (DP-aligned groups)."""
    g = min(target, B)
    while g > 1 and B % g:
        g -= 1
    return max(g, 1)


def moe_apply(ctx: Ctx, params, x, *, top_k: int, capacity_factor: float = 1.25,
              act: str = "silu_glu", parallel_mode: str = "expert",
              dropless: bool = False, dispatch_groups: int = 0):
    """x (B, S, d) -> (y (B, S, d), aux_loss scalar).

    dropless=True sets capacity C=Tg (no token ever dropped) — used at
    decode, where T = batch is small and train/serve routing must agree.

    Dispatch is *group-local* (§Perf iteration 1 on the MoE cells): tokens
    sort/scatter within `dispatch_groups` leading batch groups that stay
    aligned with the DP mesh axis, so the capacity buffer is built with
    zero cross-device traffic; the only collective is the (G@data, E@model)
    buffer re-shard around the expert einsum — a true all-to-all of
    T*k*cf*d bytes instead of XLA's all-gather-everything fallback for a
    globally-indexed scatter (observed 258 GB -> ~0.2 GB per device per
    olmoe train step).
    """
    B, S, d = x.shape
    T = B * S
    E = params["router"].shape[-1]
    G = dispatch_groups or _pick_groups(B)
    Tg = T // G
    xt = x.reshape(G, Tg, d)
    xt = hint(xt, "batch", None, None)

    # --- routing (f32 for stability) ---
    logits = jnp.einsum("gtd,de->gte", xt.astype(jnp.float32),
                        params["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    gate_w, gate_e = jax.lax.top_k(probs, top_k)                 # (G, Tg, k)
    gate_w = gate_w / jnp.maximum(jnp.sum(gate_w, -1, keepdims=True), 1e-9)

    # --- load-balancing aux loss (Switch/GShard form, global statistics) ---
    me = jnp.mean(probs, axis=(0, 1))
    one_hot = jax.nn.one_hot(gate_e, E, dtype=jnp.float32)       # (G,Tg,k,E)
    ce = jnp.mean(jnp.sum(one_hot, axis=2), axis=(0, 1)) / top_k
    aux_loss = E * jnp.sum(me * ce)

    # --- group-local sort-based capacity dispatch ---
    if dropless:
        C = Tg
    else:
        C = int(max(1, round(Tg * top_k / E * capacity_factor)))
    TK = Tg * top_k
    flat_e = gate_e.reshape(G, TK)
    flat_w = gate_w.reshape(G, TK).astype(jnp.float32)
    flat_t = jnp.broadcast_to(
        jnp.repeat(jnp.arange(Tg), top_k)[None], (G, TK))        # token ids

    order = jnp.argsort(flat_e, axis=1, stable=True)
    e_sorted = jnp.take_along_axis(flat_e, order, axis=1)
    counts = jnp.sum(jax.nn.one_hot(flat_e, E, dtype=jnp.int32), axis=1)
    starts = jnp.cumsum(counts, axis=1) - counts                 # (G, E)
    pos_in_e = jnp.arange(TK)[None] - jnp.take_along_axis(
        starts, e_sorted, axis=1)                                # rank in expert
    keep = pos_in_e < C
    buf_idx = jnp.where(keep, e_sorted * C + pos_in_e, E * C)    # trash row
    t_sorted = jnp.take_along_axis(flat_t, order, axis=1)
    w_sorted = jnp.take_along_axis(flat_w, order, axis=1)

    def scatter_group(xg, idx, tg):
        buf = jnp.zeros((E * C + 1, d), ctx.compute_dtype)
        return buf.at[idx].set(xg[tg].astype(ctx.compute_dtype))

    buf = jax.vmap(scatter_group)(xt, buf_idx, t_sorted)         # (G,EC+1,d)
    # pin the scatter output to the DP-local domain: the (G@dp -> E@model)
    # re-shard then happens ONCE on this dense buffer (a true all-to-all)
    # instead of GSPMD turning the scatter/gather themselves into
    # token-granular f32 all-reduces over the model axis (observed
    # 4.3 GB x several per layer on olmoe train).
    buf = hint(buf, "batch", None, None)
    buf_e = buf[:, :E * C].reshape(G, E, C, d)
    out_buf = _expert_ffn(ctx, params["experts"], buf_e, act, parallel_mode)

    # --- combine (gate-weighted gather-add back to token order) ---
    out_buf = hint(out_buf, "batch", None, None, None)   # back to DP-local
    rows = out_buf.reshape(G, E * C, d)
    rows = jnp.concatenate(
        [rows, jnp.zeros((G, 1, d), rows.dtype)], axis=1)

    def combine_group(rows_g, idx, tg, wg):
        gathered = rows_g[idx] * wg[:, None].astype(rows_g.dtype)
        return jnp.zeros((Tg, d), ctx.compute_dtype).at[tg].add(gathered)

    y = jax.vmap(combine_group)(rows, buf_idx, t_sorted, w_sorted)
    y = hint(y, "batch", None, None)
    return y.reshape(B, S, d), aux_loss
