from .adamw import adamw_init, adamw_update
from .compression import compressed_psum, quantize_grads_int8
from .schedules import warmup_cosine, warmup_linear

__all__ = ["adamw_init", "adamw_update", "warmup_cosine", "warmup_linear",
           "compressed_psum", "quantize_grads_int8"]
