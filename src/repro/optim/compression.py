"""Gradient compression for cross-replica reduction (beyond-paper).

The paper's blockwise-int8 idea applied to the DP all-reduce: each
replica quantizes its local gradient shard to int8 codes + per-block f32
scales, the *codes* are summed with a widened dtype via psum, and the
result is rescaled. Used inside shard_map over the DP axes, this cuts
all-reduce bytes ~4x (int8+scales vs f32) at ~1e-3 relative error —
attractive when the roofline says a train step is collective-bound on
cross-pod DCN links.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["quantize_grads_int8", "compressed_psum"]

_BLOCK = 256


def quantize_grads_int8(g: jnp.ndarray):
    flat = g.astype(jnp.float32).reshape(-1)
    n = flat.shape[0]
    pad = (-n) % _BLOCK
    fp = jnp.pad(flat, (0, pad)).reshape(-1, _BLOCK)
    absmax = jnp.max(jnp.abs(fp), axis=1, keepdims=True)
    scale = jnp.where(absmax == 0, 1.0, absmax / 127.0)
    codes = jnp.clip(jnp.round(fp / scale), -127, 127).astype(jnp.int8)
    return codes, scale.astype(jnp.float32)


def _dequant(codes, scale, n, shape, dtype):
    flat = (codes.astype(jnp.float32) * scale).reshape(-1)[:n]
    return flat.reshape(shape).astype(dtype)


def compressed_psum(tree, axis_name):
    """Blockwise-int8 compressed psum over ``axis_name`` (inside shard_map).

    Codes are psummed in int32 (exact), scales psummed separately is wrong
    (scales differ per replica) — instead each replica contributes
    codes*its-scale reconstructed... To keep the reduction associative and
    cheap we psum (codes in int32) with a *shared* scale = psum(max-scale)
    upper bound: quantize against the axis-max scale so all replicas use
    one scale, then a single int32 psum + one rescale is exact w.r.t. the
    shared grid.
    """
    def one(g):
        if g is None:
            return None
        flat = g.astype(jnp.float32).reshape(-1)
        n = flat.shape[0]
        pad = (-n) % _BLOCK
        fp = jnp.pad(flat, (0, pad)).reshape(-1, _BLOCK)
        absmax = jnp.max(jnp.abs(fp), axis=1, keepdims=True)
        # shared per-block grid across replicas (axis-max absmax)
        absmax = jax.lax.pmax(absmax, axis_name)
        scale = jnp.where(absmax == 0, 1.0, absmax / 127.0)
        codes = jnp.clip(jnp.round(fp / scale), -127, 127).astype(jnp.int8)
        total = jax.lax.psum(codes.astype(jnp.int32), axis_name)
        return _dequant(total, scale, n, g.shape, g.dtype)

    return jax.tree.map(one, tree)
