"""AdamW with optional blockwise-int8 moment states (8-bit optimizer).

The paper's quantization lineage (BitsAndBytes) includes 8-bit blockwise
optimizers; at 1000+-node scale Adam moments dominate training memory
(8 bytes/param fp32), so we expose ``state_bits=8``: m and v are stored
as int8 codes + per-256-block f32 absmax scales (~2.06 bytes/param),
dequantized-updated-requantized each step. Beyond-paper feature, same
blockwise-absmax machinery as core.quantize.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["adamw_init", "adamw_update"]

_BLOCK = 256


def _q8(x: jnp.ndarray):
    """Flat blockwise int8 quantization (array leaves only — jit/pytree
    clean; the logical shape is recovered from the matching param leaf)."""
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % _BLOCK
    fp = jnp.pad(flat, (0, pad)).reshape(-1, _BLOCK)
    absmax = jnp.max(jnp.abs(fp), axis=1, keepdims=True)
    scale = jnp.where(absmax == 0, 1.0, absmax / 127.0)
    codes = jnp.clip(jnp.round(fp / scale), -127, 127).astype(jnp.int8)
    return {"codes": codes, "scale": scale.astype(jnp.float32)}


def _dq8(q, ref) -> jnp.ndarray:
    """Dequantize against the shape of the matching parameter leaf."""
    flat = (q["codes"].astype(jnp.float32) * q["scale"]).reshape(-1)
    n = 1
    for d in ref.shape:
        n *= d
    return flat[:n].reshape(ref.shape)


def _is_q8(x) -> bool:
    return isinstance(x, dict) and "codes" in x


def adamw_init(params, state_bits: int = 32, master: bool = False):
    """Moments over *float* leaves only (QTensor int payloads get None).

    master=True additionally stores an f32 master copy of the params
    (Megatron-style distributed optimizer: live params stay bf16 and
    TP-sharded; master+moments are FSDP-sharded over the DP axis).
    """
    def mk(p):
        if not hasattr(p, "dtype") or not jnp.issubdtype(p.dtype, jnp.floating):
            return None
        z = jnp.zeros(p.shape, jnp.float32)
        return _q8(z) if state_bits == 8 else z

    st = {"m": jax.tree.map(mk, params), "v": jax.tree.map(mk, params),
          "step": jnp.zeros((), jnp.int32)}
    if master:
        st["master"] = jax.tree.map(
            lambda p: p.astype(jnp.float32)
            if hasattr(p, "dtype") and jnp.issubdtype(p.dtype, jnp.floating)
            else None, params)
    return st


def adamw_update(grads, state, params, *, lr, b1=0.9, b2=0.95, eps=1e-8,
                 weight_decay=0.0, clip_norm: float = 1.0,
                 state_bits: int = 32):
    """Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1

    # global-norm clipping
    leaves = [g for g in jax.tree.leaves(grads) if g is not None]
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                         for g in leaves))
    scale = jnp.minimum(1.0, clip_norm / jnp.maximum(gnorm, 1e-12))

    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)
    has_master = "master" in state

    def upd(p, g, m, v, mp):
        if g is None or m is None:
            return p, m, v, mp
        g = g.astype(jnp.float32) * scale
        m_f = _dq8(m, p) if _is_q8(m) else m
        # v is stored in sqrt-space: linear int8 of raw v flushes small
        # second moments to zero and 1/(sqrt(v)+eps) then explodes — the
        # reason bitsandbytes uses a nonlinear grid. sqrt compresses the
        # dynamic range enough for a linear grid to be stable.
        v_f = jnp.square(_dq8(v, p)) if _is_q8(v) else v
        m_f = b1 * m_f + (1 - b1) * g
        v_f = b2 * v_f + (1 - b2) * g * g
        u = (m_f / bc1) / (jnp.sqrt(v_f / bc2) + eps)
        src = mp if mp is not None else p.astype(jnp.float32)
        if weight_decay:
            u = u + weight_decay * src
        new_master = src - lr * u
        new_p = new_master.astype(p.dtype)
        if _is_q8(m):
            m_f, v_f = _q8(m_f), _q8(jnp.sqrt(v_f))
        return new_p, m_f, v_f, (new_master if mp is not None else None)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    flat_mp = (treedef.flatten_up_to(state["master"]) if has_master
               else [None] * len(flat_p))
    out = [upd(p, g, m, v, mp) for p, g, m, v, mp in
           zip(flat_p, flat_g, flat_m, flat_v, flat_mp)]
    new_state = {"m": treedef.unflatten([o[1] for o in out]),
                 "v": treedef.unflatten([o[2] for o in out]),
                 "step": step}
    if has_master:
        new_state["master"] = treedef.unflatten([o[3] for o in out])
    new_p = treedef.unflatten([o[0] for o in out])
    return new_p, new_state, {"grad_norm": gnorm}
